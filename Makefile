# msf-CNN reproduction — build / verify entry points.
#
# `make verify` is the regression gate: tier-1 (release build + tests)
# plus bench compilation (`cargo bench --no-run`, so the perf-trajectory
# benches can't silently rot), the static plan verifier over freshly
# planned zoo artifacts (`make analysis` = `msfcnn verify --zoo`),
# clippy -D warnings, rustfmt --check, and rustdoc -D warnings when the
# components are installed. CI runs the same target
# (.github/workflows/ci.yml), so the seed suite can't rot again.

CARGO ?= cargo

.PHONY: verify build test bench-build analysis clippy fmt doc bench bench-snapshot bench-smoke artifacts clean

verify: build test bench-build analysis clippy fmt doc

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Benches are binaries too: keep them compiling without paying their
# runtime on every verify.
bench-build:
	$(CARGO) bench --no-run

# Static plan analysis over freshly planned zoo artifacts: plan every
# model x strategy pair, serialize both the f32 plan and its quantized
# int8 twin, and run both verifier domains over the files — byte-interval
# dataflow plus the numeric value-range pass. `msfcnn verify` exits
# nonzero on any Error-severity finding (warnings are reported, and the
# structured report lands in target/ANALYSIS_zoo.json under the
# self-validated msfcnn.analysis/v1 schema).
analysis:
	$(CARGO) run --release --bin msfcnn -- verify --zoo --json target/ANALYSIS_zoo.json

clippy:
	@if $(CARGO) clippy --version >/dev/null 2>&1; then \
		$(CARGO) clippy --all-targets -- -D warnings; \
	else \
		echo "cargo clippy unavailable; skipping lint"; \
	fi

fmt:
	@if $(CARGO) fmt --version >/dev/null 2>&1; then \
		$(CARGO) fmt --all -- --check; \
	else \
		echo "cargo fmt unavailable; skipping format check"; \
	fi

# The public API must stay documented: broken intra-doc links and missing
# docs on the redesigned surface fail the gate.
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

bench:
	$(CARGO) bench

# Regenerate the committed perf snapshots (BENCH_infer.json /
# BENCH_serve.json / BENCH_kernels.json) at full fidelity, then gate
# them on the stable schema (`msfcnn bench check` = the obs::export
# validators).
bench-snapshot:
	$(CARGO) bench --bench infer_hot
	$(CARGO) bench --bench serve_load
	$(CARGO) bench --bench kernels
	$(CARGO) run --release --bin msfcnn -- bench check

# Seconds-scale smoke pass (CI): validate the committed snapshots, rerun
# the harnesses in smoke mode, and validate the fresh output — schema
# drift fails on either side. The kernels bench doubles as a parity
# smoke run: it asserts naive-vs-optimized bit-identity (f32) / exact
# identity (int8) before timing anything. Don't commit the smoke
# numbers. The final step exercises the msfcnn.analysis/v1 exporter the
# same way (the CLI self-validates the document before writing it).
bench-smoke:
	$(CARGO) run --release --bin msfcnn -- bench check
	MSFCNN_BENCH_SMOKE=1 $(CARGO) bench --bench infer_hot
	MSFCNN_BENCH_SMOKE=1 $(CARGO) bench --bench serve_load
	MSFCNN_BENCH_SMOKE=1 $(CARGO) bench --bench kernels
	$(CARGO) run --release --bin msfcnn -- bench check
	$(CARGO) run --release --bin msfcnn -- verify --zoo --json target/ANALYSIS_smoke.json

# Build-time Python: AOT-lower the JAX/Pallas model to HLO-text artifacts
# (requires jax; the Rust suite skips artifact tests when absent).
artifacts:
	cd python/compile && python3 aot.py --out ../../artifacts/model.hlo.txt

clean:
	$(CARGO) clean
