//! Audio keyword spotting on the smallest board in the catalog — the
//! paper's §1 motivating use-case family ("sequence time series analysis
//! (e.g. audio application)"): a depthwise-separable CNN over a 49×10
//! MFCC spectrogram, deployed to the 16 kB SiFive HiFive1 through the
//! Planner pipeline.
//!
//! ```sh
//! cargo run --offline --release --example audio_kws
//! ```

use msf_cnn::backend::{EngineBackend, InferBackend};
use msf_cnn::mcu::{board_by_name, estimate_latency_ms};
use msf_cnn::ops::ParamGen;
use msf_cnn::optimizer::{strategy, Constraint, Constraints, Planner};
use msf_cnn::report::kb;
use msf_cnn::zoo;

fn main() {
    let model = zoo::kws_cnn();
    let board = board_by_name("hifive1b").unwrap();
    println!(
        "KWS model: {} ({} layers), vanilla peak {:.3} kB; target board {} ({} kB RAM)",
        model.name,
        model.num_layers(),
        kb(model.vanilla_peak_ram()),
        board.name,
        board.ram_kb
    );

    let mut planner = Planner::for_model(model.clone());
    let vanilla = planner
        .plan_with(&strategy::Vanilla, Constraints::none())
        .expect("vanilla always exists");
    let fits_vanilla = vanilla.cost().peak_ram <= board.ram_bytes();
    println!(
        "vanilla: {:.3} kB -> {}",
        kb(vanilla.cost().peak_ram),
        if fits_vanilla { "fits" } else { "OOM on the HiFive1" }
    );

    // Find the fastest setting that fits the 16 kB budget (problem P2).
    let plan = planner
        .plan_with(
            &strategy::P2,
            Constraints::none().with(Constraint::Ram(board.ram_bytes())),
        )
        .expect("msf-CNN should squeeze KWS into 16 kB");
    let lat = estimate_latency_ms(&model, &plan.setting, board);
    println!(
        "msf-CNN: {} -> {:.3} kB at F={:.2}, simulated {:.1} ms/frame on {}",
        plan.setting.describe(),
        kb(plan.cost().peak_ram),
        plan.cost().overhead,
        lat.total_ms,
        board.name
    );
    assert!(plan.cost().peak_ram <= board.ram_bytes());

    // Execute a synthetic MFCC frame behind the backend trait to prove it.
    // The tracked executor runs full-width f32 band pyramids (its live
    // set sits above the Eq. 11 tile model by the documented W/t factor
    // - see EXPERIMENTS.md), so report both sides.
    let mut backend = EngineBackend::from_plan(&plan).expect("zoo model");
    let shape = backend.model().shapes[0];
    let frame = ParamGen::new(99).fill(shape.elems() as usize, 2.0);
    let logits = backend.run(&frame).expect("runs");
    let best = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    println!(
        "executed: analytical plan {:.3} kB (fits 16 kB), band-executor measured {:.3} kB; \
         predicted keyword class {} (logit {:.3})",
        kb(backend.peak_ram()),
        kb(backend.measured_peak().unwrap_or(0)),
        best.0,
        best.1
    );
    // Real-time check: a 1 s audio window at 5 frames/s needs < 200 ms.
    println!(
        "real-time margin at 5 fps: {:.1}% of the 200 ms frame budget",
        100.0 * lat.total_ms / 200.0
    );
}
