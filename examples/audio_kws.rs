//! Audio keyword spotting on the smallest board in the catalog — the
//! paper's §1 motivating use-case family ("sequence time series analysis
//! (e.g. audio application)"): a depthwise-separable CNN over a 49×10
//! MFCC spectrogram, deployed to the 16 kB SiFive HiFive1.
//!
//! ```sh
//! cargo run --offline --release --example audio_kws
//! ```

use msf_cnn::exec::Engine;
use msf_cnn::graph::FusionDag;
use msf_cnn::mcu::{board_by_name, estimate_latency_ms};
use msf_cnn::memory::Arena;
use msf_cnn::ops::{ParamGen, Tensor};
use msf_cnn::optimizer::{minimize_macs, vanilla_setting};
use msf_cnn::report::kb;
use msf_cnn::zoo;

fn main() {
    let model = zoo::kws_cnn();
    let board = board_by_name("hifive1b").unwrap();
    println!(
        "KWS model: {} ({} layers), vanilla peak {:.3} kB; target board {} ({} kB RAM)",
        model.name,
        model.num_layers(),
        kb(model.vanilla_peak_ram()),
        board.name,
        board.ram_kb
    );

    let dag = FusionDag::build(&model, None);
    let vanilla = vanilla_setting(&dag);
    let fits_vanilla = vanilla.cost.peak_ram <= board.ram_bytes();
    println!(
        "vanilla: {:.3} kB -> {}",
        kb(vanilla.cost.peak_ram),
        if fits_vanilla { "fits" } else { "OOM on the HiFive1" }
    );

    // Find the fastest setting that fits the 16 kB budget.
    let setting = minimize_macs(&dag, board.ram_bytes())
        .expect("msf-CNN should squeeze KWS into 16 kB");
    let lat = estimate_latency_ms(&model, &setting, board);
    println!(
        "msf-CNN: {} -> {:.3} kB at F={:.2}, simulated {:.1} ms/frame on {}",
        setting.describe(),
        kb(setting.cost.peak_ram),
        setting.cost.overhead,
        lat.total_ms,
        board.name
    );
    assert!(setting.cost.peak_ram <= board.ram_bytes());

    // Execute a synthetic MFCC frame under the board budget to prove it.
    let engine = Engine::new(model.clone());
    let shape = model.shapes[0];
    let frame = Tensor::from_data(
        shape.h as usize,
        shape.w as usize,
        shape.c as usize,
        ParamGen::new(99).fill(shape.elems() as usize, 2.0),
    );
    // The tracked executor runs full-width f32 band pyramids (its live
    // set sits above the Eq. 11 tile model by the documented W/t factor
    // - see EXPERIMENTS.md), so execute unbounded and report both sides.
    let mut arena = Arena::unbounded();
    let report = engine.run(&setting, &frame, &mut arena).expect("runs");
    let best = report
        .output
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    println!(
        "executed: analytical plan {:.3} kB (fits 16 kB), band-executor measured {:.3} kB; \
         predicted keyword class {} (logit {:.3})",
        kb(setting.cost.peak_ram),
        kb(report.peak_ram),
        best.0,
        best.1
    );
    // Real-time check: a 1 s audio window at 5 frames/s needs < 200 ms.
    println!(
        "real-time margin at 5 fps: {:.1}% of the 200 ms frame budget",
        100.0 * lat.total_ms / 200.0
    );
}
