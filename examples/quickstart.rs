//! Quickstart: plan a small CNN with the `Planner` pipeline and execute
//! the plan through the unified backend trait.
//!
//! ```sh
//! cargo run --offline --release --example quickstart
//! ```

use msf_cnn::backend::{EngineBackend, InferBackend};
use msf_cnn::optimizer::{strategy, Constraint, Constraints, Planner};
use msf_cnn::ops::ParamGen;
use msf_cnn::report::kb;
use msf_cnn::zoo;

fn main() {
    // 1. Pick a model from the zoo (the same CNN the AOT artifacts bake)
    //    and open a planning pipeline: the planner owns the fusion DAG
    //    and the per-model edge-cost memo, so every solve below shares
    //    them.
    let model = zoo::quickstart();
    println!("model: {} ({} layers)", model.name, model.num_layers());
    println!("vanilla peak RAM: {:.3} kB\n", kb(model.vanilla_peak_ram()));
    let mut planner = Planner::for_model(model);
    {
        let dag = planner.dag();
        println!(
            "DAG: {} nodes, {} edges (single layers + fusion candidates)",
            dag.n_nodes,
            dag.num_edges()
        );
    }

    // 2. Solve the two dual problems (paper §6) — strategies are
    //    interchangeable on the same planner.
    let min_ram = planner.plan().expect("complete path"); // default: P1
    println!(
        "P1 (min RAM, F_max=inf):   {}  ->  {:.3} kB at F={:.2}",
        min_ram.setting.describe(),
        kb(min_ram.cost().peak_ram),
        min_ram.cost().overhead
    );
    let budget = planner
        .plan_with(
            &strategy::P2,
            Constraints::none().with(Constraint::Ram(4_000)),
        )
        .expect("4 kB budget is feasible here");
    println!(
        "P2 (min MACs, P_max=4kB):  {}  ->  {:.3} kB at F={:.2}\n",
        budget.setting.describe(),
        kb(budget.cost().peak_ram),
        budget.cost().overhead
    );
    let vanilla = planner
        .plan_with(&strategy::Vanilla, Constraints::none())
        .expect("vanilla always exists");

    // 3. Execute both plans behind the unified backend trait and compare
    //    numerics + measured RAM.
    let mut fused_backend = EngineBackend::from_plan(&min_ram).expect("zoo model");
    let mut vanilla_backend = EngineBackend::from_plan(&vanilla).expect("zoo model");
    let input = ParamGen::new(1).fill(32 * 32 * 3, 2.0);

    let out_vanilla = vanilla_backend.run(&input).expect("vanilla run");
    let out_fused = fused_backend.run(&input).expect("fused run");

    let max_diff = out_vanilla
        .iter()
        .zip(&out_fused)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    let peak_vanilla = vanilla_backend.measured_peak().expect("tracked");
    let peak_fused = fused_backend.measured_peak().expect("tracked");
    println!("executed vanilla: peak {:.3} kB measured", kb(peak_vanilla));
    println!("executed fused:   peak {:.3} kB measured", kb(peak_fused));
    println!(
        "max |logit diff| fused vs vanilla: {max_diff:.2e} (schedule transform, not a numerics transform)"
    );
    assert!(max_diff < 1e-3);
    println!(
        "\nRAM saved: {:.1}% — paid for with {:.0}% extra MACs.",
        100.0 * (1.0 - peak_fused as f64 / peak_vanilla as f64),
        100.0 * (min_ram.cost().overhead - 1.0)
    );
}
