//! Quickstart: optimize a small CNN with msf-CNN and execute the plan.
//!
//! ```sh
//! cargo run --offline --release --example quickstart
//! ```

use msf_cnn::exec::Engine;
use msf_cnn::graph::FusionDag;
use msf_cnn::memory::Arena;
use msf_cnn::ops::{ParamGen, Tensor};
use msf_cnn::optimizer::{minimize_macs, minimize_ram_unconstrained, vanilla_setting};
use msf_cnn::report::kb;
use msf_cnn::zoo;

fn main() {
    // 1. Pick a model from the zoo (the same CNN the AOT artifacts bake).
    let model = zoo::quickstart();
    println!("model: {} ({} layers)", model.name, model.num_layers());
    println!("vanilla peak RAM: {:.3} kB\n", kb(model.vanilla_peak_ram()));

    // 2. Build the fusion-candidate DAG (paper §5).
    let dag = FusionDag::build(&model, None);
    println!(
        "DAG: {} nodes, {} edges (single layers + fusion candidates)",
        dag.n_nodes,
        dag.num_edges()
    );

    // 3. Solve the two dual problems (paper §6).
    let min_ram = minimize_ram_unconstrained(&dag).expect("complete path");
    println!(
        "P1 (min RAM, F_max=inf):   {}  ->  {:.3} kB at F={:.2}",
        min_ram.describe(),
        kb(min_ram.cost.peak_ram),
        min_ram.cost.overhead
    );
    let budget = minimize_macs(&dag, 4_000).expect("4 kB budget is feasible here");
    println!(
        "P2 (min MACs, P_max=4kB):  {}  ->  {:.3} kB at F={:.2}\n",
        budget.describe(),
        kb(budget.cost.peak_ram),
        budget.cost.overhead
    );

    // 4. Execute both plans with tracked RAM and compare numerics.
    let engine = Engine::new(model.clone());
    let input = Tensor::from_data(32, 32, 3, ParamGen::new(1).fill(32 * 32 * 3, 2.0));

    let mut a_vanilla = Arena::unbounded();
    let vanilla = engine
        .run(&vanilla_setting(&dag), &input, &mut a_vanilla)
        .expect("vanilla run");
    let mut a_fused = Arena::unbounded();
    let fused = engine.run(&min_ram, &input, &mut a_fused).expect("fused run");

    let max_diff = vanilla
        .output
        .iter()
        .zip(&fused.output)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("executed vanilla: peak {:.3} kB measured", kb(vanilla.peak_ram));
    println!("executed fused:   peak {:.3} kB measured", kb(fused.peak_ram));
    println!(
        "max |logit diff| fused vs vanilla: {max_diff:.2e} (schedule transform, not a numerics transform)"
    );
    assert!(max_diff < 1e-3);
    println!(
        "\nRAM saved: {:.1}% — paid for with {:.0}% extra MACs.",
        100.0 * (1.0 - fused.peak_ram as f64 / vanilla.peak_ram as f64),
        100.0 * (min_ram.cost.overhead - 1.0)
    );
}
