//! Optimize the paper's three evaluation models under the full constraint
//! grids — the workload behind Table 1 / Fig. 4 — as one parallel
//! [`PlanBatch`] sweep, and print the frontier.
//!
//! ```sh
//! cargo run --offline --release --example optimize_zoo
//! ```

use msf_cnn::optimizer::{PlanBatch, PlanOutcome};
use msf_cnn::report::{kb, F_MAX_GRID, P_MAX_GRID_KB};
use msf_cnn::zoo;

fn main() {
    // One batch over all models × (baselines + P1 grid + P2 grid): every
    // cell is an independent solve, so the whole sweep fans out across
    // the worker pool with a shared per-model edge-cost memo.
    let mut batch = PlanBatch::new();
    let models = zoo::paper_models();
    let p_grid_bytes: Vec<u64> = P_MAX_GRID_KB.iter().map(|&p| p * 1000).collect();
    for (label, model) in &models {
        let idx = batch.add_model(*label, model.clone());
        batch.push_grid(idx, F_MAX_GRID, &p_grid_bytes);
    }
    let per_model = 3 + F_MAX_GRID.len() + P_MAX_GRID_KB.len();

    let t0 = std::time::Instant::now();
    let serial = batch.solve_serial();
    let t_serial = t0.elapsed();
    let t1 = std::time::Instant::now();
    let outcomes = batch.solve();
    let t_parallel = t1.elapsed();

    // The parallel sweep must be bit-identical to the serial path.
    for (s, p) in serial.iter().zip(&outcomes) {
        let same = match (&s.setting, &p.setting) {
            (None, None) => true,
            (Some(a), Some(b)) => a.spans == b.spans && a.cost.peak_ram == b.cost.peak_ram,
            _ => false,
        };
        assert!(same, "parallel sweep diverged from serial");
    }

    let fmt = |o: &PlanOutcome| -> String {
        match &o.setting {
            None => "(no solution)".into(),
            Some(s) => format!(
                "{:>9.3} kB  F={:.2}  {} blocks  {}",
                kb(s.cost.peak_ram),
                s.cost.overhead,
                s.num_fused_blocks(),
                s.describe()
            ),
        }
    };

    for (mi, (label, model)) in models.iter().enumerate() {
        let block = &outcomes[mi * per_model..(mi + 1) * per_model];
        println!("\n=== {label} ({}; {} layers)", model.name, model.num_layers());
        println!("  vanilla          {}", fmt(&block[0]));
        println!("  MCUNetV2 heur.   {}", fmt(&block[1]));
        println!("  StreamNet 1-blk  {}", fmt(&block[2]));

        println!("  -- P1: minimize RAM s.t. F <= F_max");
        for (fi, &f_max) in F_MAX_GRID.iter().enumerate() {
            let label = if f_max.is_infinite() { "inf".into() } else { format!("{f_max}") };
            println!("     F_max={label:<5}  {}", fmt(&block[3 + fi]));
        }

        println!("  -- P2: minimize MACs s.t. P <= P_max");
        for (pi, &p_kb) in P_MAX_GRID_KB.iter().enumerate() {
            println!(
                "     P_max={p_kb:>3}kB  {}",
                fmt(&block[3 + F_MAX_GRID.len() + pi])
            );
        }
        // Sanity: every outcome in this block is for this model.
        assert!(block.iter().all(|o| o.job.model == mi));
    }

    println!(
        "\n[{} configurations: serial {:.1} ms, parallel {:.1} ms ({:.2}x) — paper: \"few seconds\"]",
        outcomes.len(),
        t_serial.as_secs_f64() * 1e3,
        t_parallel.as_secs_f64() * 1e3,
        t_serial.as_secs_f64() / t_parallel.as_secs_f64().max(1e-9),
    );
}
