//! Optimize the paper's three evaluation models under the full constraint
//! grids — the workload behind Table 1 / Fig. 4 — and print the frontier.
//!
//! ```sh
//! cargo run --offline --release --example optimize_zoo
//! ```

use msf_cnn::graph::FusionDag;
use msf_cnn::optimizer::{
    heuristic_head_fusion, minimize_macs, minimize_ram, minimize_ram_unconstrained,
    streamnet_single_block, vanilla_setting,
};
use msf_cnn::report::{kb, F_MAX_GRID, P_MAX_GRID_KB};
use msf_cnn::zoo;

fn main() {
    for (label, model) in zoo::paper_models() {
        let t0 = std::time::Instant::now();
        let dag = FusionDag::build(&model, None);
        println!(
            "\n=== {label} ({}; {} layers, {} fusion candidates, built in {:.1} ms)",
            model.name,
            model.num_layers(),
            dag.num_edges(),
            t0.elapsed().as_secs_f64() * 1e3
        );

        let v = vanilla_setting(&dag);
        let h = heuristic_head_fusion(&dag);
        let sn = streamnet_single_block(&dag, None).unwrap();
        println!("  vanilla          {:>9.3} kB  F=1.00", kb(v.cost.peak_ram));
        println!(
            "  MCUNetV2 heur.   {:>9.3} kB  F={:.2}",
            kb(h.cost.peak_ram),
            h.cost.overhead
        );
        println!(
            "  StreamNet 1-blk  {:>9.3} kB  F={:.2}",
            kb(sn.cost.peak_ram),
            sn.cost.overhead
        );

        println!("  -- P1: minimize RAM s.t. F <= F_max");
        for &f_max in F_MAX_GRID {
            let s = if f_max.is_infinite() {
                minimize_ram_unconstrained(&dag)
            } else {
                minimize_ram(&dag, f_max)
            };
            match s {
                Some(s) => println!(
                    "     F_max={:<5}  {:>9.3} kB  F={:.2}  {} blocks  {}",
                    if f_max.is_infinite() { "inf".into() } else { format!("{f_max}") },
                    kb(s.cost.peak_ram),
                    s.cost.overhead,
                    s.num_fused_blocks(),
                    s.describe()
                ),
                None => println!("     F_max={f_max:<5}  (no solution)"),
            }
        }

        println!("  -- P2: minimize MACs s.t. P <= P_max");
        for &p_kb in P_MAX_GRID_KB {
            match minimize_macs(&dag, p_kb * 1000) {
                Some(s) => println!(
                    "     P_max={p_kb:>3}kB  {:>9.3} kB  F={:.2}  {} blocks",
                    kb(s.cost.peak_ram),
                    s.cost.overhead,
                    s.num_fused_blocks()
                ),
                None => println!("     P_max={p_kb:>3}kB  (no solution)"),
            }
        }
        println!(
            "  [whole grid solved in {:.0} ms — paper: \"few seconds\"]",
            t0.elapsed().as_secs_f64() * 1e3
        );
    }
}
