//! Deployment advisor: pick a board, find the best fusion setting that
//! fits its RAM, and simulate the result — the paper's §8 workflow
//! ("users can produce optimal CNN fusion configurations tailored to
//! specific industrial hardware requirements").
//!
//! One `Planner` per model serves the whole board column: every P2 solve
//! shares the model's DAG and memoized edge costs.
//!
//! ```sh
//! cargo run --offline --release --example mcu_deploy
//! ```

use msf_cnn::backend::{EngineBackend, InferBackend};
use msf_cnn::mcu::{estimate_latency_ms, BOARDS};
use msf_cnn::ops::ParamGen;
use msf_cnn::optimizer::{strategy, Constraint, Constraints, Planner};
use msf_cnn::report::kb;
use msf_cnn::zoo;

fn main() {
    let models = zoo::paper_models();
    let mut planners: Vec<Planner> =
        models.iter().map(|(_, m)| Planner::for_model(m.clone())).collect();
    println!("Deployment matrix: best (lowest-latency) setting that fits each board.\n");
    println!(
        "{:<18} {:>10}  {:<12} {:>11} {:>7} {:>12}",
        "board", "RAM", "model", "peak RAM", "F", "latency"
    );
    println!("{}", "-".repeat(76));

    for board in BOARDS {
        for ((label, model), planner) in models.iter().zip(planners.iter_mut()) {
            // P2 with the board's physical RAM as the budget: the fastest
            // plan that fits.
            let c = Constraints::none().with(Constraint::Ram(board.ram_bytes()));
            match planner.plan_with(&strategy::P2, c) {
                Err(_) => {
                    println!(
                        "{:<18} {:>7} kB  {:<12} {:>11} {:>7} {:>12}",
                        board.name, board.ram_kb, label, "-", "-", "OOM"
                    );
                }
                Ok(plan) => {
                    let lat = estimate_latency_ms(model, &plan.setting, board);
                    println!(
                        "{:<18} {:>7} kB  {:<12} {:>8.1} kB {:>7.2} {:>9.1} ms",
                        board.name,
                        board.ram_kb,
                        label,
                        kb(plan.cost().peak_ram),
                        plan.cost().overhead,
                        lat.total_ms
                    );
                }
            }
        }
    }

    // Deep dive: deploy the VWW model on the mid-range board and *execute*
    // the plan behind the backend trait to prove it truly fits. The
    // planner warmed by the matrix above re-solves from its memoized DAG.
    let board = msf_cnn::mcu::board_by_name("nucleo-f412zg").unwrap();
    let vww_idx = models
        .iter()
        .position(|(label, _)| *label == "MN2-vww5")
        .expect("vww5 is a paper model");
    let plan = planners[vww_idx]
        .plan_with(
            &strategy::P2,
            Constraints::none().with(Constraint::Ram(board.ram_bytes())),
        )
        .expect("fits 256 kB");
    println!(
        "\nExecuting {} on {} ({} kB budget): setting {}",
        plan.model,
        board.name,
        board.ram_kb,
        plan.setting.describe()
    );
    let mut backend = EngineBackend::from_plan(&plan).expect("zoo model");
    let shape = backend.model().shapes[0];
    let input = ParamGen::new(3).fill(shape.elems() as usize, 2.0);
    match backend.run(&input) {
        Ok(logits) => println!(
            "fits: analytic peak {:.3} kB of {} kB (measured band executor {:.3} kB); \
             logits[0..2] = {:?}",
            kb(backend.peak_ram()),
            board.ram_kb,
            kb(backend.measured_peak().unwrap_or(0)),
            &logits[..2]
        ),
        Err(e) => println!("unexpected {e}"),
    }
}
