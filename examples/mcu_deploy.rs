//! Deployment advisor: pick a board, find the best fusion setting that
//! fits its RAM, and simulate the result — the paper's §8 workflow
//! ("users can produce optimal CNN fusion configurations tailored to
//! specific industrial hardware requirements").
//!
//! ```sh
//! cargo run --offline --release --example mcu_deploy
//! ```

use msf_cnn::exec::Engine;
use msf_cnn::graph::FusionDag;
use msf_cnn::mcu::{estimate_latency_ms, BOARDS};
use msf_cnn::memory::Arena;
use msf_cnn::ops::{ParamGen, Tensor};
use msf_cnn::optimizer::minimize_macs;
use msf_cnn::report::kb;
use msf_cnn::zoo;

fn main() {
    let models = zoo::paper_models();
    println!("Deployment matrix: best (lowest-latency) setting that fits each board.\n");
    println!(
        "{:<18} {:>10}  {:<12} {:>11} {:>7} {:>12}",
        "board", "RAM", "model", "peak RAM", "F", "latency"
    );
    println!("{}", "-".repeat(76));

    for board in BOARDS {
        for (label, model) in &models {
            let dag = FusionDag::build(model, None);
            // P2 with the board's physical RAM as the budget: the fastest
            // plan that fits.
            match minimize_macs(&dag, board.ram_bytes()) {
                None => {
                    println!(
                        "{:<18} {:>7} kB  {:<12} {:>11} {:>7} {:>12}",
                        board.name, board.ram_kb, label, "-", "-", "OOM"
                    );
                }
                Some(s) => {
                    let lat = estimate_latency_ms(model, &s, board);
                    println!(
                        "{:<18} {:>7} kB  {:<12} {:>8.1} kB {:>7.2} {:>9.1} ms",
                        board.name,
                        board.ram_kb,
                        label,
                        kb(s.cost.peak_ram),
                        s.cost.overhead,
                        lat.total_ms
                    );
                }
            }
        }
    }

    // Deep dive: deploy the VWW model on the mid-range board and *execute*
    // the plan against the board budget to prove it truly fits.
    let board = msf_cnn::mcu::board_by_name("nucleo-f412zg").unwrap();
    let model = zoo::mcunet_vww5();
    let dag = FusionDag::build(&model, None);
    let setting = minimize_macs(&dag, board.ram_bytes()).expect("fits 256 kB");
    println!(
        "\nExecuting {} on {} ({} kB budget): setting {}",
        model.name,
        board.name,
        board.ram_kb,
        setting.describe()
    );
    let engine = Engine::new(model.clone());
    let shape = model.shapes[0];
    let input = Tensor::from_data(
        shape.h as usize,
        shape.w as usize,
        shape.c as usize,
        ParamGen::new(3).fill(shape.elems() as usize, 2.0),
    );
    let mut arena = Arena::with_budget(board.ram_bytes());
    match engine.run(&setting, &input, &mut arena) {
        Ok(r) => println!(
            "fits: measured peak {:.3} kB of {} kB; logits[0..2] = {:?}",
            kb(r.peak_ram),
            board.ram_kb,
            &r.output[..2]
        ),
        Err(oom) => println!("unexpected {oom}"),
    }
}
