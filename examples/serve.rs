//! Serving: the L3 coordinator driving the AOT-compiled PJRT artifacts —
//! Python is not involved at any point in this binary.
//!
//! ```sh
//! make artifacts   # once, build-time Python
//! cargo run --offline --release --example serve
//! ```

use msf_cnn::coordinator::{InferenceServer, ServerConfig};
use msf_cnn::ops::ParamGen;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let server = InferenceServer::start(
        &artifacts,
        ServerConfig { entry: "model_fused".into(), queue_cap: 128, batch_max: 8 },
    )?;
    let handle = server.handle();

    // Warm the compile cache with one request.
    let mut gen = ParamGen::new(42);
    handle.infer(gen.fill(32 * 32 * 3, 2.0))?;

    // Drive 400 requests from 4 client threads.
    let t0 = std::time::Instant::now();
    let mut clients = Vec::new();
    for t in 0..4u64 {
        let h = server.handle();
        clients.push(std::thread::spawn(move || {
            let mut gen = ParamGen::new(1000 + t);
            let mut ok = 0usize;
            for _ in 0..100 {
                match h.infer(gen.fill(32 * 32 * 3, 2.0)) {
                    Ok(logits) => {
                        assert_eq!(logits.len(), 10);
                        ok += 1;
                    }
                    Err(_) => std::thread::sleep(std::time::Duration::from_millis(1)),
                }
            }
            ok
        }));
    }
    let ok: usize = clients.into_iter().map(|c| c.join().unwrap()).sum();
    let dt = t0.elapsed();

    let metrics = handle.metrics();
    let stats = metrics.stats().expect("requests completed");
    println!("served {ok}/400 requests in {:.2} s", dt.as_secs_f64());
    println!("throughput: {:.1} req/s", ok as f64 / dt.as_secs_f64());
    println!(
        "latency: mean {:.0} us, p50 {:.0} us, p99 {:.0} us, max {:.0} us",
        stats.mean_us, stats.p50_us, stats.p99_us, stats.max_us
    );
    println!(
        "micro-batches: {}, backpressure rejections: {}",
        metrics.batches(),
        metrics.rejections()
    );
    drop(handle);
    server.shutdown();
    Ok(())
}
