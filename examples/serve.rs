//! Serving: the L3 deployment control plane driving a **live registry of
//! named plans** — several models served concurrently, each by its own
//! executor thread draining per-model micro-batches, with models
//! deployed, hot-swapped, and retired while traffic flows.
//!
//! Plans come from the `Planner` pipeline and reach the server the way a
//! fleet would ship them: saved as plan JSON files into a directory, and
//! synced onto the running server through a `PlanRegistry` (deploy on
//! first sight, hot-swap on file change, retire on file delete). When
//! `artifacts/` has been built (`make artifacts`), the AOT quickstart
//! entry joins as an extra model behind the same front door via a direct
//! runtime `deploy`.
//!
//! ```sh
//! cargo run --offline --release --example serve
//! ```

use msf_cnn::coordinator::{ModelSpec, MultiModelServer, PlanRegistry};
use msf_cnn::ops::ParamGen;
use msf_cnn::optimizer::strategy::Vanilla;
use msf_cnn::optimizer::Planner;
use msf_cnn::util::error::Result;
use msf_cnn::zoo;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // A plans/ directory is the deploy artifact a fleet ships.
    let plans_dir = std::env::temp_dir().join(format!("msfcnn-serve-plans-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&plans_dir);
    std::fs::create_dir_all(&plans_dir)?;
    Planner::for_model(zoo::quickstart())
        .plan()?
        .save(plans_dir.join("quickstart.plan.json"))?;
    Planner::for_model(zoo::kws_cnn())
        .plan()?
        .save(plans_dir.join("kws.plan.json"))?;

    // Control plane: start empty, sync the registry onto it.
    let mut registry = PlanRegistry::open(&plans_dir)?;
    let server = MultiModelServer::new();
    let handle = server.handle();
    let report = registry.sync(&handle)?;
    println!("deployed from {}: {:?}", plans_dir.display(), report.added);

    // An artifact-backed model deploys straight through the same handle.
    if std::path::Path::new(&artifacts).join("manifest.json").exists() {
        handle
            .deploy(ModelSpec::artifact("aot-fused", &artifacts, "model_fused"))
            .map_err(|e| msf_cnn::anyhow!("{e}"))?;
    }
    let ids = handle.model_ids();
    println!("registry: {}", ids.join(", "));

    // Drive 100 requests per model from 2 client threads each.
    let t0 = std::time::Instant::now();
    let mut clients = Vec::new();
    for (mi, id) in ids.iter().enumerate() {
        let input_len = match id.as_str() {
            "kws" => 49 * 10,
            _ => 32 * 32 * 3,
        };
        for t in 0..2u64 {
            let h = server.bound_handle(id.clone());
            clients.push(std::thread::spawn(move || {
                let mut gen = ParamGen::new(1000 + 100 * mi as u64 + t);
                let mut ok = 0usize;
                for _ in 0..50 {
                    match h.infer(gen.fill(input_len, 2.0)) {
                        Ok(logits) => {
                            assert!(logits.iter().all(|v| v.is_finite()));
                            ok += 1;
                        }
                        Err(_) => std::thread::sleep(std::time::Duration::from_millis(1)),
                    }
                }
                ok
            }));
        }
    }

    // Meanwhile, exercise the control plane under live traffic: rewrite
    // the quickstart plan file (vanilla spans) and re-sync — the running
    // model hot-swaps with queued requests draining on the old plan.
    Planner::for_model(zoo::quickstart())
        .strategy(Vanilla)
        .plan()?
        .save(plans_dir.join("quickstart.plan.json"))?;
    let changes = registry.sync(&handle)?;
    println!(
        "hot-swapped under load: {:?} (now v{})",
        changes.updated,
        registry.latest("quickstart").map(|e| e.version).unwrap_or(0)
    );

    let ok: usize = clients.into_iter().map(|c| c.join().unwrap()).sum();
    let dt = t0.elapsed();
    let total = 100 * ids.len();
    println!(
        "served {ok}/{total} requests in {:.2} s ({:.1} req/s)",
        dt.as_secs_f64(),
        ok as f64 / dt.as_secs_f64()
    );

    let metrics = handle.metrics();
    for (id, m) in metrics.per_model() {
        match m.stats() {
            Some(stats) => println!(
                "  {id:<12} {} done | p50 {:>6.0} us  p99 {:>6.0} us | {} micro-batches | \
                 queue depth {} | {} rejections | {} shutdown drops",
                stats.count,
                stats.p50_us,
                stats.p99_us,
                m.batches(),
                m.queue_depth(),
                m.rejections(),
                m.shutdown_drops()
            ),
            // e.g. a stale artifacts/ dir whose backend failed to init.
            None => println!("  {id:<12} no completed requests"),
        }
    }

    // Retire one model, then shut the whole plane down.
    handle.retire("kws").map_err(|e| msf_cnn::anyhow!("{e}"))?;
    println!("retired kws; remaining: {}", handle.model_ids().join(", "));
    drop(handle);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&plans_dir);
    Ok(())
}
