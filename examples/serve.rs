//! Serving: the L3 coordinator driving a **registry of named plans** —
//! several models served concurrently, each by its own executor thread
//! draining per-model micro-batches.
//!
//! Plans come from the `Planner` pipeline: one is registered in-memory,
//! one round-trips through a plan JSON on disk (the deploy artifact a
//! fleet would ship), and — when `artifacts/` has been built
//! (`make artifacts`) — the AOT quickstart entry joins as a third model
//! behind the same front door.
//!
//! ```sh
//! cargo run --offline --release --example serve
//! ```

use msf_cnn::coordinator::{ModelSpec, MultiModelServer};
use msf_cnn::ops::ParamGen;
use msf_cnn::optimizer::{Plan, Planner};
use msf_cnn::util::error::Result;
use msf_cnn::zoo;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // Plan the registry through the one pipeline.
    let quickstart_plan = Planner::for_model(zoo::quickstart()).plan()?;
    let kws_plan = Planner::for_model(zoo::kws_cnn()).plan()?;

    // The kws plan takes the full deploy round-trip: save to disk, load
    // back, register from the file — serving never re-runs the optimizer.
    let plan_path = std::env::temp_dir().join("msfcnn-serve-example.plan.json");
    kws_plan.save(&plan_path)?;
    println!("kws plan persisted: {}", Plan::load(&plan_path)?.describe());

    let mut specs = vec![
        ModelSpec::plan("quickstart", quickstart_plan),
        ModelSpec::plan_file("kws", &plan_path)?,
    ];
    let have_artifacts = std::path::Path::new(&artifacts).join("manifest.json").exists();
    if have_artifacts {
        specs.push(ModelSpec::artifact("aot-fused", &artifacts, "model_fused"));
    }
    let ids: Vec<String> = specs.iter().map(|s| s.id.clone()).collect();
    println!("registry: {}", ids.join(", "));

    let server = MultiModelServer::start(specs)?;

    // Drive 100 requests per model from 2 client threads each.
    let t0 = std::time::Instant::now();
    let mut clients = Vec::new();
    for (mi, id) in ids.iter().enumerate() {
        let input_len = match id.as_str() {
            "kws" => 49 * 10,
            _ => 32 * 32 * 3,
        };
        for t in 0..2u64 {
            let h = server.bound_handle(id.clone());
            clients.push(std::thread::spawn(move || {
                let mut gen = ParamGen::new(1000 + 100 * mi as u64 + t);
                let mut ok = 0usize;
                for _ in 0..50 {
                    match h.infer(gen.fill(input_len, 2.0)) {
                        Ok(logits) => {
                            assert!(logits.iter().all(|v| v.is_finite()));
                            ok += 1;
                        }
                        Err(_) => std::thread::sleep(std::time::Duration::from_millis(1)),
                    }
                }
                ok
            }));
        }
    }
    let ok: usize = clients.into_iter().map(|c| c.join().unwrap()).sum();
    let dt = t0.elapsed();
    let total = 100 * ids.len();
    println!("served {ok}/{total} requests in {:.2} s ({:.1} req/s)",
        dt.as_secs_f64(), ok as f64 / dt.as_secs_f64());

    let handle = server.handle();
    let metrics = handle.metrics();
    for (id, m) in metrics.per_model() {
        match m.stats() {
            Some(stats) => println!(
                "  {id:<12} {} done | p50 {:>6.0} us  p99 {:>6.0} us | {} micro-batches | \
                 queue depth {} | {} rejections | {} shutdown drops",
                stats.count,
                stats.p50_us,
                stats.p99_us,
                m.batches(),
                m.queue_depth(),
                m.rejections(),
                m.shutdown_drops()
            ),
            // e.g. a stale artifacts/ dir whose backend failed to init.
            None => println!("  {id:<12} no completed requests"),
        }
    }
    drop(handle);
    server.shutdown();
    let _ = std::fs::remove_file(&plan_path);
    Ok(())
}
