//! END-TO-END VALIDATION DRIVER (the run recorded in EXPERIMENTS.md §E2E).
//!
//! Proves all three layers of the stack compose on one real workload:
//!
//!   1. msf-CNN optimizer (L3) plans a 4 kB deployment of the quickstart
//!      CNN — the same architecture `python/compile/` AOT-lowered with
//!      Pallas kernels (L1) inside a JAX graph (L2) into `artifacts/`.
//!   2. The pure-Rust executor runs vanilla + fused plans under a tracked
//!      arena, verifying numerics and the measured peak-RAM cut.
//!   3. The PJRT runtime loads the HLO artifacts (same weights via
//!      `weights.json`) and must agree with the Rust executor.
//!   4. The serving coordinator then handles 200 batched requests on the
//!      fused artifact and reports latency/throughput.
//!
//! ```sh
//! make artifacts && cargo run --offline --release --example e2e_deploy
//! ```

use msf_cnn::coordinator::{InferenceServer, ServerConfig};
use msf_cnn::exec::Engine;
use msf_cnn::graph::FusionDag;
use msf_cnn::memory::Arena;
use msf_cnn::ops::{ParamGen, Tensor};
use msf_cnn::optimizer::{minimize_ram_unconstrained, vanilla_setting};
use msf_cnn::report::kb;
use msf_cnn::runtime::Runtime;
use msf_cnn::util::error::Result;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("== msf-CNN end-to-end validation ==\n");

    // --- Stage 1: plan -------------------------------------------------
    let engine = Engine::quickstart_from_artifacts(&artifacts)?;
    let model = engine.model().clone();
    let dag = FusionDag::build(&model, None);
    let fused = minimize_ram_unconstrained(&dag).expect("setting");
    let vanilla = vanilla_setting(&dag);
    println!("[1] optimizer: vanilla {:.3} kB -> fused {} @ {:.3} kB (F={:.2})",
        kb(vanilla.cost.peak_ram), fused.describe(), kb(fused.cost.peak_ram), fused.cost.overhead);

    // --- Stage 2: execute with tracked RAM -----------------------------
    let x: Vec<f32> = ParamGen::new(2024).fill(32 * 32 * 3, 2.0);
    let input = Tensor::from_data(32, 32, 3, x.clone());
    let mut a1 = Arena::unbounded();
    let rv = engine.run(&vanilla, &input, &mut a1)?;
    let mut a2 = Arena::unbounded();
    let rf = engine.run(&fused, &input, &mut a2)?;
    let exec_diff = rv
        .output
        .iter()
        .zip(&rf.output)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "[2] executor: measured peaks {:.3} kB (vanilla) vs {:.3} kB (fused), Δlogits {exec_diff:.2e}",
        kb(rv.peak_ram),
        kb(rf.peak_ram)
    );
    assert!(exec_diff < 1e-3, "fused execution must be numerically invisible");
    assert!(rf.peak_ram < rv.peak_ram, "fusion must cut measured RAM");

    // --- Stage 3: cross-check against the XLA artifacts ----------------
    let mut rt = Runtime::open(&artifacts)?;
    let xla_vanilla = rt.run_f32("model_vanilla", &x)?;
    let xla_fused = rt.run_f32("model_fused", &x)?;
    let stack_diff = xla_vanilla
        .iter()
        .zip(&rv.output)
        .chain(xla_fused.iter().zip(&rf.output))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "[3] PJRT artifacts (Pallas->JAX->HLO) agree with Rust executor: Δ {stack_diff:.2e}"
    );
    assert!(stack_diff < 1e-2, "three-layer stack disagrees");

    // --- Stage 4: serve -------------------------------------------------
    let server = InferenceServer::start(
        &artifacts,
        ServerConfig { entry: "model_fused".into(), queue_cap: 128, batch_max: 8 },
    )?;
    let handle = server.handle();
    handle.infer(x.clone())?; // warm
    let t0 = std::time::Instant::now();
    let mut threads = Vec::new();
    for t in 0..4u64 {
        let h = server.handle();
        threads.push(std::thread::spawn(move || {
            let mut gen = ParamGen::new(31 + t);
            let mut ok = 0;
            for _ in 0..50 {
                if h.infer(gen.fill(32 * 32 * 3, 2.0)).is_ok() {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let ok: usize = threads.into_iter().map(|j| j.join().unwrap()).sum();
    let dt = t0.elapsed();
    let stats = handle.metrics().stats().expect("stats");
    println!(
        "[4] coordinator: {ok}/200 requests, {:.0} req/s, p50 {:.0} us, p99 {:.0} us",
        ok as f64 / dt.as_secs_f64(),
        stats.p50_us,
        stats.p99_us
    );
    assert_eq!(ok, 200);
    drop(handle);
    server.shutdown();

    println!(
        "\nE2E PASS: optimizer -> tracked executor -> PJRT artifacts -> serving, \
         RAM cut {:.1}% at F={:.2}.",
        100.0 * (1.0 - rf.peak_ram as f64 / rv.peak_ram as f64),
        fused.cost.overhead
    );
    Ok(())
}
