//! END-TO-END VALIDATION DRIVER (the run recorded in EXPERIMENTS.md §E2E).
//!
//! Proves all three layers of the stack compose on one real workload,
//! through the unified Planner/Backend surface:
//!
//!   1. The `Planner` (L3) solves vanilla + min-RAM plans of the
//!      quickstart CNN — the same architecture `python/compile/`
//!      AOT-lowered with Pallas kernels (L1) inside a JAX graph (L2)
//!      into `artifacts/`.
//!   2. Both plans execute behind `InferBackend` (engine side) with
//!      tracked RAM, verifying numerics and the measured peak-RAM cut.
//!   3. The artifact runtime serves the same weights behind the same
//!      trait and must agree with the engine side.
//!   4. The control plane deploys the fused artifact into a running
//!      (initially empty) `MultiModelServer`, handles 200 batched
//!      requests, and reports latency/throughput.
//!
//! ```sh
//! make artifacts && cargo run --offline --release --example e2e_deploy
//! ```

use msf_cnn::backend::{ArtifactBackend, EngineBackend, InferBackend};
use msf_cnn::coordinator::{ModelSpec, MultiModelServer};
use msf_cnn::exec::Engine;
use msf_cnn::ops::ParamGen;
use msf_cnn::optimizer::{strategy, Constraints, Planner};
use msf_cnn::report::kb;
use msf_cnn::util::error::Result;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("== msf-CNN end-to-end validation ==\n");

    // --- Stage 1: plan -------------------------------------------------
    let engine = Engine::quickstart_from_artifacts(&artifacts)?;
    let mut planner = Planner::for_model(engine.model().clone());
    let fused = planner.plan()?;
    let vanilla = planner.plan_with(&strategy::Vanilla, Constraints::none())?;
    println!(
        "[1] planner: vanilla {:.3} kB -> fused {} @ {:.3} kB (F={:.2})",
        kb(vanilla.cost().peak_ram),
        fused.setting.describe(),
        kb(fused.cost().peak_ram),
        fused.cost().overhead
    );

    // --- Stage 2: execute with tracked RAM -----------------------------
    let x: Vec<f32> = ParamGen::new(2024).fill(32 * 32 * 3, 2.0);
    let engine_vanilla = Engine::quickstart_from_artifacts(&artifacts)?;
    let mut bv = EngineBackend::with_engine(engine_vanilla, vanilla.setting.clone());
    let mut bf = EngineBackend::with_engine(engine, fused.setting.clone());
    let out_vanilla = bv.run(&x)?;
    let out_fused = bf.run(&x)?;
    let peak_vanilla = bv.measured_peak().expect("tracked");
    let peak_fused = bf.measured_peak().expect("tracked");
    let exec_diff = out_vanilla
        .iter()
        .zip(&out_fused)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "[2] executor: measured peaks {:.3} kB (vanilla) vs {:.3} kB (fused), Δlogits {exec_diff:.2e}",
        kb(peak_vanilla),
        kb(peak_fused)
    );
    assert!(exec_diff < 1e-3, "fused execution must be numerically invisible");
    assert!(peak_fused < peak_vanilla, "fusion must cut measured RAM");

    // --- Stage 3: cross-check against the XLA artifacts ----------------
    let mut xla_vanilla_backend = ArtifactBackend::open(&artifacts, "model_vanilla")?;
    let mut xla_fused_backend = ArtifactBackend::open(&artifacts, "model_fused")?;
    let xla_vanilla = xla_vanilla_backend.run(&x)?;
    let xla_fused = xla_fused_backend.run(&x)?;
    let stack_diff = xla_vanilla
        .iter()
        .zip(&out_vanilla)
        .chain(xla_fused.iter().zip(&out_fused))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "[3] PJRT artifacts (Pallas->JAX->HLO) agree with the engine backend: Δ {stack_diff:.2e} \
         (artifact plan peak {:.3} kB)",
        kb(xla_fused_backend.peak_ram())
    );
    assert!(stack_diff < 1e-2, "three-layer stack disagrees");
    assert_eq!(
        xla_fused_backend.peak_ram(),
        fused.cost().peak_ram,
        "both backends must report the same analytic plan peak"
    );

    // --- Stage 4: serve through the control plane -----------------------
    let server = MultiModelServer::new();
    server
        .handle()
        .deploy(
            ModelSpec::artifact("model_fused", &artifacts, "model_fused")
                .with_queue(128, 8),
        )
        .map_err(|e| msf_cnn::anyhow!("{e}"))?;
    let handle = server.bound_handle("model_fused");
    handle.infer(x.clone())?; // warm
    let t0 = std::time::Instant::now();
    let mut threads = Vec::new();
    for t in 0..4u64 {
        let h = server.bound_handle("model_fused");
        threads.push(std::thread::spawn(move || {
            let mut gen = ParamGen::new(31 + t);
            let mut ok = 0;
            for _ in 0..50 {
                if h.infer(gen.fill(32 * 32 * 3, 2.0)).is_ok() {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let ok: usize = threads.into_iter().map(|j| j.join().unwrap()).sum();
    let dt = t0.elapsed();
    let stats = handle.metrics().stats().expect("stats");
    println!(
        "[4] coordinator: {ok}/200 requests, {:.0} req/s, p50 {:.0} us, p99 {:.0} us",
        ok as f64 / dt.as_secs_f64(),
        stats.p50_us,
        stats.p99_us
    );
    assert_eq!(ok, 200);
    drop(handle);
    server.shutdown();

    println!(
        "\nE2E PASS: planner -> engine backend -> PJRT artifacts -> serving, \
         RAM cut {:.1}% at F={:.2}.",
        100.0 * (1.0 - peak_fused as f64 / peak_vanilla as f64),
        fused.cost().overhead
    );
    Ok(())
}
