"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes/strides/tile sizes; every kernel output must be
allclose to ``ref.py``. The fused pyramid is additionally checked against
layer-by-layer execution of the same stack (the fused-vs-vanilla identity
the whole paper rests on).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.conv2d import conv2d
from compile.kernels.fused_conv import LayerCfg, band_rows_needed, fused_pyramid
from compile.kernels.iter_dense import dense_iter
from compile.kernels.iter_pool import global_avg_pool_iter

RTOL, ATOL = 1e-4, 1e-4
HYP = dict(max_examples=25, deadline=None)


def rnd(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------- conv2d

@settings(**HYP)
@given(
    h=st.integers(6, 20),
    w=st.integers(6, 20),
    cin=st.sampled_from([1, 3, 8]),
    cout=st.sampled_from([4, 16]),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from([0, 1]),
    act=st.booleans(),
    tile_rows=st.sampled_from([1, 2, 4, 7]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(h, w, cin, cout, k, stride, padding, act, tile_rows, seed):
    if h + 2 * padding < k or w + 2 * padding < k:
        return
    rng = np.random.default_rng(seed)
    x = rnd(rng, h, w, cin)
    wk = rnd(rng, k, k, cin, cout)
    b = rnd(rng, cout)
    got = conv2d(x, wk, b, stride=stride, padding=padding, act=act, tile_rows=tile_rows)
    exp = ref.conv2d_ref(x, wk, b, stride=stride, padding=padding, act=act)
    np.testing.assert_allclose(got, exp, rtol=RTOL, atol=ATOL)


def test_conv2d_1x1_pointwise():
    rng = np.random.default_rng(7)
    x, wk, b = rnd(rng, 9, 9, 16), rnd(rng, 1, 1, 16, 4), rnd(rng, 4)
    np.testing.assert_allclose(
        conv2d(x, wk, b), ref.conv2d_ref(x, wk, b), rtol=RTOL, atol=ATOL
    )


def test_conv2d_output_shape_with_stride_and_pad():
    rng = np.random.default_rng(1)
    x, wk, b = rnd(rng, 15, 11, 3), rnd(rng, 3, 3, 3, 2), rnd(rng, 2)
    out = conv2d(x, wk, b, stride=2, padding=1)
    assert out.shape == ((15 + 2 - 3) // 2 + 1, (11 + 2 - 3) // 2 + 1, 2)


# ---------------------------------------------------------- fused pyramid

def _mk_stack(rng, cin, specs):
    """specs: list of (k, stride, cout_or_None_for_dw, act)."""
    cfgs, params, layers = [], [], []
    c = cin
    for (k, s, cout, act) in specs:
        dw = cout is None
        if dw:
            w = rnd(rng, k, k, c)
        else:
            w = rnd(rng, k, k, c, cout)
            c = cout
        b = rnd(rng, c)
        cfgs.append(LayerCfg(k, s, act, dw))
        params += [w, b]
        layers.append(dict(w=w, b=b, stride=s, act=act, depthwise=dw))
    return tuple(cfgs), tuple(params), layers


@settings(**HYP)
@given(
    h=st.integers(10, 24),
    w=st.integers(10, 24),
    tile_rows=st.sampled_from([1, 2, 3, 5]),
    depth=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_pyramid_matches_layerwise(h, w, tile_rows, depth, seed):
    rng = np.random.default_rng(seed)
    choices = [(3, 1, 6, True), (3, 2, 4, False), (1, 1, 8, True), (3, 1, None, True)]
    specs = [choices[rng.integers(len(choices))] for _ in range(depth)]
    # Ensure spatial dims stay >= kernel through the stack.
    hh, ww = h, w
    ok = True
    for (k, s, _c, _a) in specs:
        if hh < k or ww < k:
            ok = False
            break
        hh, ww = (hh - k) // s + 1, (ww - k) // s + 1
    if not ok:
        return
    cfgs, params, layers = _mk_stack(rng, 3, specs)
    xin = rnd(rng, h, w, 3)
    got = fused_pyramid(xin, params, cfgs, tile_rows=tile_rows)
    exp = ref.pyramid_ref(xin, layers)
    np.testing.assert_allclose(got, exp, rtol=RTOL, atol=ATOL)


def test_fused_pyramid_strided_downsampling():
    rng = np.random.default_rng(3)
    cfgs, params, layers = _mk_stack(
        rng, 3, [(3, 2, 8, True), (3, 2, 16, True)]
    )
    x = rnd(rng, 21, 21, 3)
    got = fused_pyramid(x, params, cfgs, tile_rows=2)
    exp = ref.pyramid_ref(x, layers)
    assert got.shape == exp.shape
    np.testing.assert_allclose(got, exp, rtol=RTOL, atol=ATOL)


def test_fused_pyramid_depthwise_mix():
    rng = np.random.default_rng(4)
    cfgs, params, layers = _mk_stack(
        rng, 4, [(1, 1, 12, True), (3, 1, None, True), (1, 1, 6, False)]
    )
    x = rnd(rng, 12, 12, 4)
    got = fused_pyramid(x, params, cfgs, tile_rows=3)
    exp = ref.pyramid_ref(x, layers)
    np.testing.assert_allclose(got, exp, rtol=RTOL, atol=ATOL)


def test_band_rows_needed_recursion():
    # Two 3x3 s1 layers: 1 output row needs 3 rows mid, 5 rows input.
    cfgs = (LayerCfg(3, 1, False, False), LayerCfg(3, 1, False, False))
    assert band_rows_needed(cfgs, 1) == [5, 3]
    # Stride-2 layer doubles the step: (r-1)*2 + 3.
    cfgs = (LayerCfg(3, 2, False, False),)
    assert band_rows_needed(cfgs, 4) == [9]


# ------------------------------------------------------- iterative pooling

@settings(**HYP)
@given(
    h=st.integers(1, 16),
    w=st.integers(1, 16),
    c=st.sampled_from([1, 8, 32]),
    chunk=st.sampled_from([1, 2, 3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_iter_pool_matches_ref(h, w, c, chunk, seed):
    rng = np.random.default_rng(seed)
    x = rnd(rng, h, w, c)
    got = global_avg_pool_iter(x, chunk_rows=chunk)
    np.testing.assert_allclose(got, ref.global_avg_pool_ref(x), rtol=RTOL, atol=ATOL)


def test_iter_pool_7x7_paper_case():
    """The paper's Fig. 2 example: 7×7 map streamed row-by-row."""
    rng = np.random.default_rng(9)
    x = rnd(rng, 7, 7, 64)
    np.testing.assert_allclose(
        global_avg_pool_iter(x, chunk_rows=1), ref.global_avg_pool_ref(x),
        rtol=RTOL, atol=ATOL,
    )


# --------------------------------------------------------- iterative dense

@settings(**HYP)
@given(
    d=st.integers(1, 128),
    f=st.sampled_from([1, 10, 64]),
    chunk=st.sampled_from([1, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_iter_dense_matches_ref(d, f, chunk, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rnd(rng, d), rnd(rng, d, f), rnd(rng, f)
    got = dense_iter(x, w, b, chunk=chunk)
    np.testing.assert_allclose(got, ref.dense_ref(x, w, b), rtol=RTOL, atol=ATOL)


def test_iter_dense_1024_to_256_paper_case():
    """The paper's Fig. 3 example: 1024→256 dense."""
    rng = np.random.default_rng(11)
    x, w, b = rnd(rng, 1024), rnd(rng, 1024, 256), rnd(rng, 256)
    got = dense_iter(x, w, b, chunk=32)
    np.testing.assert_allclose(got, ref.dense_ref(x, w, b), rtol=1e-3, atol=1e-3)
