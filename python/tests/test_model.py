"""L2 model tests: vanilla vs fused vs pure-jnp oracle, and AOT manifest
shape consistency.

The key identity: ``forward_fused`` (one 3-conv patch-based pyramid +
iterative pool/dense) must produce the same logits as ``forward_vanilla``
(layer-by-layer, full feature maps) and as the jnp oracle — msf-CNN's
fusion is a *schedule* transform, never a numerics transform.
"""

from __future__ import annotations

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.aot import build_entries, to_hlo_text
import jax

RTOL, ATOL = 1e-3, 1e-3


@pytest.fixture(scope="module")
def params():
    return model.init_params()


@pytest.fixture(scope="module")
def image():
    rng = np.random.default_rng(42)
    return jnp.asarray(rng.standard_normal(model.INPUT_SHAPE), jnp.float32)


def test_vanilla_matches_oracle(params, image):
    got = model.forward_vanilla(image, params)
    exp = model.forward_ref(image, params)
    assert got.shape == (model.NUM_CLASSES,)
    np.testing.assert_allclose(got, exp, rtol=RTOL, atol=ATOL)


def test_fused_matches_vanilla(params, image):
    fused = model.forward_fused(image, params)
    vanilla = model.forward_vanilla(image, params)
    np.testing.assert_allclose(fused, vanilla, rtol=RTOL, atol=ATOL)


def test_fused_matches_oracle_many_inputs(params):
    rng = np.random.default_rng(0)
    for _ in range(5):
        x = jnp.asarray(rng.standard_normal(model.INPUT_SHAPE), jnp.float32)
        np.testing.assert_allclose(
            model.forward_fused(x, params), model.forward_ref(x, params),
            rtol=RTOL, atol=ATOL,
        )


def test_init_params_deterministic():
    p1, p2 = model.init_params(), model.init_params()
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])


def test_conv_cfg_shapes_consistent(params):
    """The conv chain's channel plumbing must be self-consistent."""
    cin = model.INPUT_SHAPE[2]
    for i, (k, _s, ci, co, _a) in enumerate(model.CONV_CFG):
        assert ci == cin, f"layer {i} cin mismatch"
        assert params[f"w{i}"].shape == (k, k, ci, co)
        cin = co
    assert model.DENSE_IN == cin


def test_aot_entries_lower_to_hlo_text():
    """Every AOT entry point must lower to parseable HLO text containing an
    ENTRY computation (what HloModuleProto::from_text_file consumes)."""
    for name, (fn, example_args) in build_entries().items():
        text = to_hlo_text(jax.jit(fn).lower(*example_args))
        assert "ENTRY" in text, name
        assert "HloModule" in text, name


def test_artifacts_manifest_consistent():
    """If artifacts were built, the manifest must describe real files with
    the shapes the model defines."""
    adir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(adir, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    manifest = json.load(open(mpath))
    for name in ("model_vanilla", "model_fused"):
        ent = manifest[name]
        assert os.path.exists(os.path.join(adir, ent["file"]))
        assert ent["inputs"][0]["shape"] == list(model.INPUT_SHAPE)
        assert ent["outputs"][0]["shape"] == [model.NUM_CLASSES]
