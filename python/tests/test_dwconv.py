"""Standalone depthwise Pallas kernel vs the jnp oracle."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dwconv import dwconv2d

RTOL, ATOL = 1e-4, 1e-4


def rnd(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(6, 18),
    w=st.integers(6, 18),
    c=st.sampled_from([1, 8, 16]),
    k=st.sampled_from([3, 5]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from([0, 1, 2]),
    act=st.booleans(),
    tile_rows=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dwconv_matches_ref(h, w, c, k, stride, padding, act, tile_rows, seed):
    if h + 2 * padding < k or w + 2 * padding < k:
        return
    rng = np.random.default_rng(seed)
    x = rnd(rng, h, w, c)
    wk = rnd(rng, k, k, c)
    b = rnd(rng, c)
    got = dwconv2d(x, wk, b, stride=stride, padding=padding, act=act, tile_rows=tile_rows)
    exp = ref.dwconv2d_ref(x, wk, b, stride=stride, padding=padding, act=act)
    np.testing.assert_allclose(got, exp, rtol=RTOL, atol=ATOL)


def test_dwconv_mbv2_shape():
    """The MBV2 depthwise stage shape: 3x3 s2 p1 on an even map."""
    rng = np.random.default_rng(5)
    x = rnd(rng, 16, 16, 24)
    wk = rnd(rng, 3, 3, 24)
    b = rnd(rng, 24)
    out = dwconv2d(x, wk, b, stride=2, padding=1, act=True)
    assert out.shape == (8, 8, 24)
    np.testing.assert_allclose(
        out, ref.dwconv2d_ref(x, wk, b, stride=2, padding=1, act=True), rtol=RTOL, atol=ATOL
    )
