"""L2: the quickstart CNN in JAX, calling the L1 Pallas kernels.

The model is deliberately small — it is the end-to-end *wiring proof* of
the three-layer stack (Pallas kernel → JAX graph → HLO text → Rust PJRT),
not the paper's evaluation models (those live in ``rust/src/zoo`` where the
analytical optimizer operates). The exact same architecture is defined in
``rust/src/zoo/quickstart.rs``; the Rust executor cross-checks its own
pure-Rust inference against these artifacts.

Architecture (VALID convs so the fusion block needs no per-layer padding):

    input  32×32×3
    conv0  3×3 s1  3→8,  relu6  ┐
    conv1  3×3 s2  8→16, relu6  ├─ fusion-block candidates
    conv2  3×3 s2 16→32, relu6  ┘
    global-avg-pool → 32
    dense  32→10

Two entry points are lowered: ``forward_vanilla`` (layer-by-layer, full
feature maps — the paper's "vanilla") and ``forward_fused`` (all three
convs as one patch-based pyramid + iterative pooling + iterative dense —
an msf-CNN fusion setting). Weights are baked into the HLO as constants
(deterministic seed) so the Rust side feeds only the image.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .kernels.conv2d import conv2d
from .kernels.fused_conv import LayerCfg, fused_pyramid
from .kernels.iter_dense import dense_iter
from .kernels.iter_pool import global_avg_pool_iter
from .kernels import ref

INPUT_SHAPE = (32, 32, 3)
NUM_CLASSES = 10

# (k, stride, cin, cout, act) — keep in sync with rust/src/zoo/quickstart.rs
CONV_CFG = [
    (3, 1, 3, 8, True),
    (3, 2, 8, 16, True),
    (3, 2, 16, 32, True),
]
DENSE_IN, DENSE_OUT = 32, NUM_CLASSES
SEED = 0x5F3C


def init_params(seed: int = SEED) -> dict[str, jnp.ndarray]:
    """Deterministic He-scaled weights; baked into the AOT artifacts."""
    rng = np.random.default_rng(seed)
    params: dict[str, jnp.ndarray] = {}
    for i, (k, _s, cin, cout, _a) in enumerate(CONV_CFG):
        scale = np.sqrt(2.0 / (k * k * cin))
        params[f"w{i}"] = jnp.asarray(rng.standard_normal((k, k, cin, cout)) * scale, jnp.float32)
        params[f"b{i}"] = jnp.asarray(rng.standard_normal(cout) * 0.01, jnp.float32)
    params["wd"] = jnp.asarray(
        rng.standard_normal((DENSE_IN, DENSE_OUT)) * np.sqrt(1.0 / DENSE_IN), jnp.float32
    )
    params["bd"] = jnp.asarray(rng.standard_normal(DENSE_OUT) * 0.01, jnp.float32)
    return params


def forward_vanilla(x: jnp.ndarray, params: dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Layer-by-layer inference via the single-layer Pallas conv kernel."""
    out = x
    for i, (_k, s, _cin, _cout, act) in enumerate(CONV_CFG):
        out = conv2d(out, params[f"w{i}"], params[f"b{i}"], stride=s, act=act)
    pooled = global_avg_pool_iter(out, chunk_rows=out.shape[0])  # whole map = common pooling
    return ref.dense_ref(pooled, params["wd"], params["bd"])


def forward_fused(x: jnp.ndarray, params: dict[str, jnp.ndarray]) -> jnp.ndarray:
    """msf-CNN fusion setting: one 3-conv pyramid + iterative pool/dense."""
    cfgs = tuple(LayerCfg(k, s, act, False) for (k, s, _ci, _co, act) in CONV_CFG)
    flat: list[jnp.ndarray] = []
    for i in range(len(CONV_CFG)):
        flat += [params[f"w{i}"], params[f"b{i}"]]
    out = fused_pyramid(x, tuple(flat), cfgs, tile_rows=2)
    pooled = global_avg_pool_iter(out, chunk_rows=1)  # row-streamed (Fig. 2)
    return dense_iter(pooled, params["wd"], params["bd"], chunk=8)


def forward_ref(x: jnp.ndarray, params: dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Pure-jnp oracle for both entry points."""
    layers = [
        dict(w=params[f"w{i}"], b=params[f"b{i}"], stride=s, act=act)
        for i, (_k, s, _ci, _co, act) in enumerate(CONV_CFG)
    ]
    out = ref.pyramid_ref(x, layers)
    pooled = ref.global_avg_pool_ref(out)
    return ref.dense_ref(pooled, params["wd"], params["bd"])
