"""AOT lowering: JAX entry points → HLO *text* artifacts for the Rust PJRT
runtime.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts written (all single-input, weights baked as constants):

    model_vanilla.hlo.txt   quickstart CNN, layer-by-layer      [32,32,3] → [10]
    model_fused.hlo.txt     quickstart CNN, msf-CNN fused       [32,32,3] → [10]
    fused_block.hlo.txt     2-conv fusion block alone           [32,32,3] → [15,15,16]
    conv2d.hlo.txt          single conv layer                   [32,32,3] → [30,30,8]
    iter_pool.hlo.txt       iterative global avg pool           [7,7,32]  → [32]
    iter_dense.hlo.txt      iterative dense                     [32]      → [10]
    manifest.json           entry-point → input/output shapes (for rust/src/runtime)

``make artifacts`` is the only place Python runs; the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.conv2d import conv2d
from .kernels.fused_conv import LayerCfg, fused_pyramid
from .kernels.iter_dense import dense_iter
from .kernels.iter_pool import global_avg_pool_iter


def to_hlo_text(lowered) -> str:
    """Convert a jax.jit(...).lower(...) result to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def build_entries() -> dict[str, tuple]:
    """name -> (fn, example_args). Weights are closed over (HLO constants)."""
    params = model.init_params()
    img = jax.ShapeDtypeStruct(model.INPUT_SHAPE, jnp.float32)

    cfgs2 = tuple(LayerCfg(k, s, act, False) for (k, s, _ci, _co, act) in model.CONV_CFG[:2])
    flat2 = (params["w0"], params["b0"], params["w1"], params["b1"])

    return {
        "model_vanilla": (lambda x: (model.forward_vanilla(x, params),), (img,)),
        "model_fused": (lambda x: (model.forward_fused(x, params),), (img,)),
        "fused_block": (lambda x: (fused_pyramid(x, flat2, cfgs2, tile_rows=2),), (img,)),
        "conv2d": (
            lambda x: (conv2d(x, params["w0"], params["b0"], stride=1, act=True),),
            (img,),
        ),
        "iter_pool": (
            lambda x: (global_avg_pool_iter(x, chunk_rows=1),),
            (jax.ShapeDtypeStruct((7, 7, 32), jnp.float32),),
        ),
        "iter_dense": (
            lambda x: (dense_iter(x, params["wd"], params["bd"], chunk=8),),
            (jax.ShapeDtypeStruct((32,), jnp.float32),),
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="sentinel path; artifacts land in its directory")
    args = ap.parse_args()
    outdir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(outdir, exist_ok=True)

    manifest: dict[str, dict] = {}
    for name, (fn, example_args) in build_entries().items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_avals = [
            {"shape": list(a.shape), "dtype": str(a.dtype)}
            for a in jax.tree_util.tree_leaves(lowered.out_info)
        ]
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in example_args],
            "outputs": out_avals,
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)

    # Dump the baked weights so the Rust engine can run the *same* network
    # and cross-check its pure-Rust executor against the XLA artifacts
    # (rust/tests/artifacts_roundtrip.rs).
    params = model.init_params()
    weights = {
        k: {"shape": list(v.shape), "data": [float(x) for x in v.reshape(-1)]}
        for k, v in params.items()
    }
    with open(os.path.join(outdir, "weights.json"), "w") as f:
        json.dump(weights, f)
    print(f"weights: {os.path.join(outdir, 'weights.json')}")
    # The Makefile's sentinel target: touch the requested path last so the
    # artifacts rule is satisfied and re-runs only when inputs change.
    with open(os.path.abspath(args.out), "a"):
        os.utime(os.path.abspath(args.out))
    print(f"manifest: {os.path.join(outdir, 'manifest.json')}")


if __name__ == "__main__":
    main()
