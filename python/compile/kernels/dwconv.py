"""Single-layer depthwise-conv Pallas kernel (row-tiled).

Same structure as ``conv2d.py`` but with the per-channel contraction of
the MobileNetV2/MCUNet depthwise stage: each tap contributes
``patch * w[ky, kx]`` broadcast over channels — on TPU this is a VPU
(vector) op rather than an MXU matmul, which is exactly why dw layers are
bandwidth-bound and fuse so profitably with their neighboring pointwise
convs (the L3 optimizer sees this as cheap MACs vs large maps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref, *, stride: int, tile_rows: int, act: bool):
    i = pl.program_id(0)
    k = w_ref.shape[0]
    wo = o_ref.shape[1]
    c = o_ref.shape[2]
    row0 = i * tile_rows * stride
    band_rows = (tile_rows - 1) * stride + k
    x_band = x_ref[pl.dslice(row0, band_rows)]
    acc = jnp.zeros((tile_rows, wo, c), jnp.float32)
    for ky in range(k):
        for kx in range(k):
            patch = jax.lax.slice(
                x_band,
                (ky, kx, 0),
                (ky + (tile_rows - 1) * stride + 1, kx + (wo - 1) * stride + 1, c),
                (stride, stride, 1),
            )
            acc = acc + patch * w_ref[ky, kx]
    acc = acc + b_ref[...]
    if act:
        acc = jnp.clip(acc, 0.0, 6.0)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("stride", "padding", "act", "tile_rows"))
def dwconv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    stride: int = 1,
    padding: int = 0,
    act: bool = False,
    tile_rows: int = 4,
) -> jnp.ndarray:
    """Pallas depthwise conv. x: [H, W, C], w: [K, K, C], b: [C]."""
    h, w_in, c = x.shape
    k = w.shape[0]
    if padding:
        x = jnp.pad(x, ((padding, padding), (padding, padding), (0, 0)))
        h, w_in = h + 2 * padding, w_in + 2 * padding
    ho = (h - k) // stride + 1
    wo = (w_in - k) // stride + 1
    tile_rows = min(tile_rows, ho)
    n_tiles = -(-ho // tile_rows)
    ho_pad = n_tiles * tile_rows
    rows_needed = (ho_pad - 1) * stride + k
    if rows_needed > h:
        x = jnp.pad(x, ((0, rows_needed - h), (0, 0), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, stride=stride, tile_rows=tile_rows, act=act),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_rows, wo, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ho_pad, wo, c), jnp.float32),
        interpret=True,
    )(x, w, b)
    return out[:ho]
