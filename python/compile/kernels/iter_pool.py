"""Iterative global average pooling (paper Fig. 2).

Standard global pooling needs the whole H×W×C input resident; the paper's
iterative form receives a few rows per step and updates a running sum, so
live memory is one row-band + the C-sized accumulator (≈2% of the original
for a 7×7 map). Here the grid streams row-chunks and the output block is
the accumulator that persists across grid steps — the exact computation
order the Rust executor's `ops::pool::GlobalPoolIter` mirrors.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref, *, inv_n: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    chunk = x_ref[...]  # [chunk_rows, W, C]
    o_ref[...] += jnp.sum(chunk, axis=(0, 1)) * inv_n


@functools.partial(jax.jit, static_argnames=("chunk_rows",))
def global_avg_pool_iter(x: jnp.ndarray, chunk_rows: int = 1) -> jnp.ndarray:
    """Iterative global average pool. x: [H, W, C] -> [C]."""
    h, w, c = x.shape
    if h % chunk_rows != 0:
        pad = chunk_rows - h % chunk_rows
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))  # zero rows add nothing
    n_chunks = x.shape[0] // chunk_rows
    return pl.pallas_call(
        functools.partial(_kernel, inv_n=1.0 / float(h * w)),
        grid=(n_chunks,),
        in_specs=[pl.BlockSpec((chunk_rows, w, c), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((c,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))
