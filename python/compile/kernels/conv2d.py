"""Single-layer Pallas conv2d kernel (tiled over output rows).

TPU mapping of the paper's per-layer compute: the grid walks row-tiles of
the output feature map; each grid step holds one input row-band plus one
output row-tile in VMEM and contracts over the K×K window with MXU-shaped
``[rows·W, Cin] @ [Cin, Cout]`` matmuls (one per kernel tap, unrolled —
taps are static so XLA fuses them into a single loop nest).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO. Real-TPU VMEM/MXU
behaviour is estimated analytically in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_rows(x_band: jnp.ndarray, w: jnp.ndarray, stride: int, out_rows: int, wo: int) -> jnp.ndarray:
    """Convolve a band of input rows into ``out_rows`` output rows.

    x_band: [rows_in, W, Cin] (already padded), w: [K, K, Cin, Cout].
    Returns [out_rows, wo, Cout].
    """
    k = w.shape[0]
    cout = w.shape[3]
    acc = jnp.zeros((out_rows, wo, cout), jnp.float32)
    # Static unroll over kernel taps: each tap is one strided slice + matmul.
    for ki in range(k):
        for kj in range(k):
            # rows ki, ki+stride, ... ; cols kj, kj+stride, ...
            patch = jax.lax.slice(
                x_band,
                (ki, kj, 0),
                (ki + (out_rows - 1) * stride + 1, kj + (wo - 1) * stride + 1, x_band.shape[2]),
                (stride, stride, 1),
            )  # [out_rows, wo, Cin]
            acc = acc + jax.lax.dot_general(
                patch,
                w[ki, kj],
                (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
    return acc


def _kernel(x_ref, w_ref, b_ref, o_ref, *, stride: int, tile_rows: int, act: bool):
    i = pl.program_id(0)
    k = w_ref.shape[0]
    wo = o_ref.shape[1]
    # Input row band covering this output row-tile (+ halo of k-stride rows).
    row0 = i * tile_rows * stride
    band_rows = (tile_rows - 1) * stride + k
    x_band = x_ref[pl.dslice(row0, band_rows)]
    out = _conv_rows(x_band, w_ref[...], stride, tile_rows, wo)
    out = out + b_ref[...]
    if act:
        out = jnp.clip(out, 0.0, 6.0)
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=("stride", "padding", "act", "tile_rows"))
def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    stride: int = 1,
    padding: int = 0,
    act: bool = False,
    tile_rows: int = 4,
) -> jnp.ndarray:
    """Pallas conv2d. x: [H, W, Cin], w: [K, K, Cin, Cout], b: [Cout]."""
    h, w_in, _cin = x.shape
    k, _, _, cout = w.shape
    if padding:
        x = jnp.pad(x, ((padding, padding), (padding, padding), (0, 0)))
        h, w_in = h + 2 * padding, w_in + 2 * padding
    ho = (h - k) // stride + 1
    wo = (w_in - k) // stride + 1
    tile_rows = min(tile_rows, ho)
    # Pad output rows up to a multiple of the tile; pad input rows to match
    # the last tile's halo so the in-kernel dynamic slice stays in bounds.
    n_tiles = -(-ho // tile_rows)
    ho_pad = n_tiles * tile_rows
    rows_needed = (ho_pad - 1) * stride + k
    if rows_needed > h:
        x = jnp.pad(x, ((0, rows_needed - h), (0, 0), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, stride=stride, tile_rows=tile_rows, act=act),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0, 0, 0)),  # full input resident
            pl.BlockSpec(w.shape, lambda i: (0, 0, 0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_rows, wo, cout), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ho_pad, wo, cout), jnp.float32),
        interpret=True,
    )(x, w, b)
    return out[:ho]
