"""Iterative dense layer (paper Fig. 3).

The matmul ``y = x @ W + b`` is decomposed column-block-wise over the
*input* dimension: each step multiplies a slice of ``x`` with the matching
rows of ``W`` and accumulates into the F-sized output. Live memory is one
input slice + one weight slice + the accumulator — 20% of the common form
for the paper's 1024→256 example. The grid is the streaming loop; the
output block persists across steps as the accumulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = b_ref[...]

    o_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("chunk",))
def dense_iter(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, chunk: int = 8) -> jnp.ndarray:
    """Iterative dense. x: [D], w: [D, F], b: [F] -> [F]."""
    d, f = w.shape
    chunk = min(chunk, d)
    if d % chunk != 0:
        pad = chunk - d % chunk
        x = jnp.pad(x, (0, pad))  # zero inputs contribute nothing
        w = jnp.pad(w, ((0, pad), (0, 0)))
        d += pad
    n_chunks = d // chunk
    return pl.pallas_call(
        _kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((chunk, f), lambda i: (i, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((f,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((f,), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32), b.astype(jnp.float32))
