"""Patch-based multi-stage fused conv pyramid — the paper's hot-spot kernel.

This is the TPU re-think of msf-CNN's fusion block (DESIGN.md
§Hardware-Adaptation): instead of threadblock tiles in GPU shared memory /
MCU SRAM patches, the grid walks **row-bands of the final layer's output**
and each grid step computes the whole pyramid for its band inside VMEM:

    input row-band  --conv L0-->  band  --conv L1-->  ...  --conv Ln-->  output tile

Only the band pyramid is live at any step, which is exactly the paper's
peak-RAM argument (Eq. 5): ``P = I_band + O_band (+ cache)``. Rows are the
streaming axis, matching the paper's H-cache orientation (full rows are the
cache unit). This kernel uses the *fully-recompute* variant in-kernel — the
overlap rows of each band are recomputed, which is the compute-overhead `F`
the optimizer (L3) trades off; the H-cached execution variant is measured
in the Rust executor where RAM accounting lives.

Layers are a static tuple of ``LayerCfg`` (shape/stride/act/depthwise);
weights arrive as runtime arrays. Per-layer padding must be zero inside a
fusion block (pre-pad the block input instead) — the same restriction the
analytical model in ``rust/src/fusion`` applies.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


class LayerCfg(NamedTuple):
    """Static per-layer config for a fusion block member."""

    k: int
    stride: int
    act: bool
    depthwise: bool


def _conv_band(x_band, w, b, stride: int, out_rows: int, act: bool):
    """Standard conv of a row band. x_band: [rows_in, W, Cin] -> [out_rows, wo, Cout]."""
    k = w.shape[0]
    wo = (x_band.shape[1] - k) // stride + 1
    cout = w.shape[3]
    acc = jnp.zeros((out_rows, wo, cout), jnp.float32)
    for ki in range(k):
        for kj in range(k):
            patch = jax.lax.slice(
                x_band,
                (ki, kj, 0),
                (ki + (out_rows - 1) * stride + 1, kj + (wo - 1) * stride + 1, x_band.shape[2]),
                (stride, stride, 1),
            )
            acc = acc + jax.lax.dot_general(
                patch, w[ki, kj], (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )
    acc = acc + b
    if act:
        acc = jnp.clip(acc, 0.0, 6.0)
    return acc


def _dwconv_band(x_band, w, b, stride: int, out_rows: int, act: bool):
    """Depthwise conv of a row band. x_band: [rows_in, W, C], w: [K, K, C]."""
    k = w.shape[0]
    wo = (x_band.shape[1] - k) // stride + 1
    c = x_band.shape[2]
    acc = jnp.zeros((out_rows, wo, c), jnp.float32)
    for ki in range(k):
        for kj in range(k):
            patch = jax.lax.slice(
                x_band,
                (ki, kj, 0),
                (ki + (out_rows - 1) * stride + 1, kj + (wo - 1) * stride + 1, c),
                (stride, stride, 1),
            )
            acc = acc + patch * w[ki, kj]  # [out_rows, wo, C] * [C]
    acc = acc + b
    if act:
        acc = jnp.clip(acc, 0.0, 6.0)
    return acc


def band_rows_needed(cfgs: tuple[LayerCfg, ...], out_rows: int) -> list[int]:
    """Back-propagate the receptive row count through the pyramid.

    Returns ``rows[i]`` = rows of layer i's *input* band needed to produce
    ``out_rows`` rows of the final output (the paper's tile-size recursion
    behind Eq. 11/12).
    """
    rows = out_rows
    needed = []
    for cfg in reversed(cfgs):
        rows = (rows - 1) * cfg.stride + cfg.k
        needed.append(rows)
    return list(reversed(needed))


def _kernel(*refs, cfgs: tuple[LayerCfg, ...], tile_rows: int, strides_prod: tuple[int, ...]):
    x_ref = refs[0]
    o_ref = refs[-1]
    wb_refs = refs[1:-1]  # alternating w, b per layer
    i = pl.program_id(0)

    rows_needed = band_rows_needed(cfgs, tile_rows)
    # Row offset of this tile's receptive field in the (pre-padded) input:
    # the final tile starts at output row i*tile_rows; each layer multiplies
    # the row offset by its stride going backwards.
    row0 = i * tile_rows * strides_prod[0]

    band = x_ref[pl.dslice(row0 * 1, rows_needed[0])]
    out_rows = tile_rows
    # Compute per-layer band output row counts forward.
    row_counts = rows_needed[1:] + [tile_rows]
    for li, cfg in enumerate(cfgs):
        w = wb_refs[2 * li][...]
        b = wb_refs[2 * li + 1][...]
        fn = _dwconv_band if cfg.depthwise else _conv_band
        band = fn(band, w, b, cfg.stride, row_counts[li], cfg.act)
    o_ref[...] = band[:out_rows]


@functools.partial(jax.jit, static_argnames=("cfgs", "tile_rows"))
def fused_pyramid(
    x: jnp.ndarray,
    params: tuple[jnp.ndarray, ...],
    cfgs: tuple[LayerCfg, ...],
    tile_rows: int = 2,
) -> jnp.ndarray:
    """Run a fusion block of convs patch-by-patch.

    x: [H, W, Cin]; params: flat (w0, b0, w1, b1, ...) matching ``cfgs``.
    Returns the final layer's full output, identical (up to f32 assoc.) to
    running the stack layer-by-layer (``ref.pyramid_ref``).
    """
    h, w_in, _ = x.shape
    # Forward shape inference to get final output dims.
    ho, wo, cout = h, w_in, x.shape[2]
    for li, cfg in enumerate(cfgs):
        warr = params[2 * li]
        ho = (ho - cfg.k) // cfg.stride + 1
        wo = (wo - cfg.k) // cfg.stride + 1
        cout = warr.shape[2] if cfg.depthwise else warr.shape[3]
    tile_rows = min(tile_rows, ho)
    n_tiles = -(-ho // tile_rows)
    ho_pad = n_tiles * tile_rows

    # Cumulative stride products: offset multiplier from final-output rows
    # back to each layer's input rows (index 0 = model input).
    sp = [1]
    for cfg in reversed(cfgs):
        sp.insert(0, sp[0] * cfg.stride)

    # Pad input rows so the last (padded) tile's receptive field is in bounds.
    rows_in_needed = (ho_pad - tile_rows) * sp[0] + band_rows_needed(cfgs, tile_rows)[0]
    if rows_in_needed > h:
        x = jnp.pad(x, ((0, rows_in_needed - h), (0, 0), (0, 0)))

    in_specs = [pl.BlockSpec(x.shape, lambda i: (0, 0, 0))]
    for p in params:
        in_specs.append(pl.BlockSpec(p.shape, lambda i, _n=len(p.shape): tuple([0] * _n)))

    out = pl.pallas_call(
        functools.partial(_kernel, cfgs=cfgs, tile_rows=tile_rows, strides_prod=tuple(sp)),
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_rows, wo, cout), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ho_pad, wo, cout), jnp.float32),
        interpret=True,
    )(x, *params)
    return out[:ho]
