"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
is pytest-checked against the matching function here (see
``python/tests/test_kernel.py``), and the fused patch-based pyramid is
additionally checked against layer-by-layer execution of the same stack.

All tensors are NHWC with the batch dim dropped (HWC) — the TinyML setting
is single-image inference — and f32. Quantization effects are modeled at
L3 (the Rust executor sizes tensors as int8); numerics here stay in f32 so
the oracle is exact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def relu6(x: jnp.ndarray) -> jnp.ndarray:
    """Clipped ReLU used throughout the MobileNetV2 family."""
    return jnp.clip(x, 0.0, 6.0)


def conv2d_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None = None,
    stride: int = 1,
    padding: int = 0,
    act: bool = False,
) -> jnp.ndarray:
    """Reference conv. x: [H, W, Cin], w: [K, K, Cin, Cout], b: [Cout].

    ``padding`` is symmetric spatial zero-padding (the paper's ``p``).
    """
    lhs = x[None].astype(jnp.float32)  # [1, H, W, Cin]
    out = jax.lax.conv_general_dilated(
        lhs,
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0]
    if b is not None:
        out = out + b
    if act:
        out = relu6(out)
    return out


def dwconv2d_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None = None,
    stride: int = 1,
    padding: int = 0,
    act: bool = False,
) -> jnp.ndarray:
    """Depthwise conv. x: [H, W, C], w: [K, K, C] (one filter per channel)."""
    c = x.shape[-1]
    lhs = x[None].astype(jnp.float32)
    rhs = w[:, :, None, :].astype(jnp.float32)  # [K, K, 1, C] with HWIO + groups=C
    out = jax.lax.conv_general_dilated(
        lhs,
        rhs,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )[0]
    if b is not None:
        out = out + b
    if act:
        out = relu6(out)
    return out


def pyramid_ref(x: jnp.ndarray, layers: list[dict]) -> jnp.ndarray:
    """Run a conv stack layer-by-layer (the *vanilla*, unfused execution).

    ``layers`` is a list of dicts with keys: ``w``, ``b``, ``stride``,
    ``padding``, ``act``, and optional ``depthwise``.
    """
    out = x
    for ly in layers:
        fn = dwconv2d_ref if ly.get("depthwise", False) else conv2d_ref
        out = fn(
            out,
            ly["w"],
            ly.get("b"),
            stride=ly.get("stride", 1),
            padding=ly.get("padding", 0),
            act=ly.get("act", False),
        )
    return out


def global_avg_pool_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Global average pool. x: [H, W, C] -> [C]."""
    return jnp.mean(x.astype(jnp.float32), axis=(0, 1))


def dense_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None) -> jnp.ndarray:
    """Dense layer. x: [D], w: [D, F], b: [F]."""
    out = x.astype(jnp.float32) @ w.astype(jnp.float32)
    if b is not None:
        out = out + b
    return out


def maxpool2d_ref(x: jnp.ndarray, k: int = 2, stride: int | None = None) -> jnp.ndarray:
    """Max pool. x: [H, W, C]."""
    stride = stride or k
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(k, k, 1),
        window_strides=(stride, stride, 1),
        padding="VALID",
    )


def avgpool2d_ref(x: jnp.ndarray, k: int = 2, stride: int | None = None) -> jnp.ndarray:
    """Average pool. x: [H, W, C]."""
    stride = stride or k
    summed = jax.lax.reduce_window(
        x.astype(jnp.float32),
        0.0,
        jax.lax.add,
        window_dimensions=(k, k, 1),
        window_strides=(stride, stride, 1),
        padding="VALID",
    )
    return summed / float(k * k)
