//! Graph algorithms for the fusion DAG (paper §6, App. D).
//!
//! All paths run `v_0 → v_n` on a DAG whose edges always advance the node
//! index, so topological-order DP gives the Dijkstra results in O(E) —
//! we keep the heap-free DP (the nodes *are* the topological order), which
//! is both simpler and faster than Dijkstra+Fibonacci for this graph
//! family while preserving the paper's complexity bounds.

use super::dag::FusionDag;

/// Aggregate cost of a complete compute path (Eq. 6 and Eq. 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PathCost {
    /// `max` of edge RAM along the path (Eq. 6).
    pub peak_ram: u64,
    /// `sum` of edge MACs along the path (Eq. 7).
    pub macs: u64,
}

/// Cost of an explicit edge-index path.
pub fn path_cost(dag: &FusionDag, path: &[usize]) -> PathCost {
    let mut peak = 0u64;
    let mut macs = 0u64;
    for &e in path {
        peak = peak.max(dag.edges[e].cost.ram_bytes);
        macs += dag.edges[e].cost.macs;
    }
    PathCost { peak_ram: peak, macs }
}

/// Shortest (min-MAC-sum) complete path, `None` if `v_n` unreachable.
/// Topological DP: O(V + E).
pub fn min_sum_path(dag: &FusionDag) -> Option<Vec<usize>> {
    let n = dag.n_nodes;
    let mut dist = vec![u64::MAX; n];
    let mut prev: Vec<Option<usize>> = vec![None; n];
    dist[0] = 0;
    for v in 0..n {
        if dist[v] == u64::MAX {
            continue;
        }
        for &e in &dag.out[v] {
            let edge = &dag.edges[e];
            let nd = dist[v].saturating_add(edge.cost.macs);
            if nd < dist[edge.b] {
                dist[edge.b] = nd;
                prev[edge.b] = Some(e);
            }
        }
    }
    reconstruct(dag, &prev, n - 1)
}

/// Minimax (min over paths of max edge RAM) complete path — the modified
/// Dijkstra of §6.1's unconstrained P1. Topological DP with `max` as the
/// accumulation. Tie-break on lower MAC sum so the returned setting is the
/// cheapest among equally-small-RAM paths (matches the paper's "compress
/// RAM without incurring overhead where possible" observation).
pub fn minimax_path(dag: &FusionDag) -> Option<Vec<usize>> {
    let n = dag.n_nodes;
    let mut best: Vec<(u64, u64)> = vec![(u64::MAX, u64::MAX); n]; // (bottleneck, macs)
    let mut prev: Vec<Option<usize>> = vec![None; n];
    best[0] = (0, 0);
    for v in 0..n {
        if best[v].0 == u64::MAX {
            continue;
        }
        for &e in &dag.out[v] {
            let edge = &dag.edges[e];
            let cand = (
                best[v].0.max(edge.cost.ram_bytes),
                best[v].1.saturating_add(edge.cost.macs),
            );
            if cand < best[edge.b] {
                best[edge.b] = cand;
                prev[edge.b] = Some(e);
            }
        }
    }
    reconstruct(dag, &prev, n - 1)
}

fn reconstruct(dag: &FusionDag, prev: &[Option<usize>], target: usize) -> Option<Vec<usize>> {
    let mut path = Vec::new();
    let mut v = target;
    while v != 0 {
        let e = prev[v]?;
        path.push(e);
        v = dag.edges[e].a;
    }
    path.reverse();
    Some(path)
}

/// Enumerate *all* complete compute paths (App. D: up to `2^{V-2}` on a
/// complete DAG). Only for tests/small models — the exhaustive baseline the
/// pruned optimizer is property-checked against.
pub fn enumerate_paths(dag: &FusionDag) -> Vec<Vec<usize>> {
    let mut all = Vec::new();
    let mut stack = vec![(0usize, Vec::new())];
    while let Some((v, path)) = stack.pop() {
        if v == dag.n_nodes - 1 {
            all.push(path);
            continue;
        }
        for &e in &dag.out[v] {
            let mut p = path.clone();
            p.push(e);
            stack.push((dag.edges[e].b, p));
        }
    }
    all
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::EdgeCost;
    use crate::graph::DagEdge;

    /// Hand-built DAG matching the paper's Figure 1b topology: 5 nodes,
    /// four single-layer edges plus two fusion candidates.
    fn fig1b() -> FusionDag {
        let mk = |a: usize, b: usize, ram: u64, macs: u64| DagEdge {
            a,
            b,
            cost: EdgeCost { ram_bytes: ram, macs },
            iterative_tail: false,
            param_bytes: 0,
            band_iterations: 1,
            latency_macs: macs,
        };
        let edges = vec![
            mk(0, 1, 100, 10), // e1
            mk(1, 2, 80, 12),  // e2
            mk(2, 3, 60, 8),   // e3
            mk(3, 4, 30, 5),   // e4
            mk(0, 3, 40, 45),  // e5: fusion of layers 0..3
            mk(1, 4, 35, 50),  // e6: fusion of layers 1..4
        ];
        let mut out = vec![Vec::new(); 5];
        for (i, e) in edges.iter().enumerate() {
            out[e.a].push(i);
        }
        FusionDag { n_nodes: 5, out, edges, vanilla_macs: 35 }
    }

    #[test]
    fn min_sum_picks_vanilla_when_cheapest() {
        let dag = fig1b();
        let p = min_sum_path(&dag).unwrap();
        assert_eq!(p, vec![0, 1, 2, 3]); // all singles: 35 MACs
        assert_eq!(path_cost(&dag, &p).macs, 35);
    }

    #[test]
    fn minimax_prefers_fused_low_ram_route() {
        let dag = fig1b();
        let p = minimax_path(&dag).unwrap();
        // e5 (ram 40) then e4 via e3? e5: 0->3 (40), e4: 3->4 (30) => peak 40.
        assert_eq!(path_cost(&dag, &p).peak_ram, 40);
        assert_eq!(p, vec![4, 3]);
    }

    #[test]
    fn enumerate_counts_all_routes() {
        let dag = fig1b();
        let all = enumerate_paths(&dag);
        // Routes: 1-2-3-4, 1-2-(e6), (e5)-4 => 3 complete paths.
        assert_eq!(all.len(), 3);
    }

    #[test]
    fn path_cost_is_max_and_sum() {
        let dag = fig1b();
        let c = path_cost(&dag, &[0, 1, 2, 3]);
        assert_eq!(c.peak_ram, 100);
        assert_eq!(c.macs, 35);
    }

    #[test]
    fn unreachable_target_returns_none() {
        let mut dag = fig1b();
        dag.out[3].clear(); // cut e4
        dag.out[1].retain(|&e| e != 5); // cut e6
        assert!(min_sum_path(&dag).is_none());
        assert!(minimax_path(&dag).is_none());
    }

    #[test]
    fn complete_dag_path_count_is_2_pow_v_minus_2() {
        // App. D induction: complete DAG on V nodes has 2^{V-2} paths.
        for v in 2..9usize {
            let mut edges = Vec::new();
            let mut out = vec![Vec::new(); v];
            for a in 0..v {
                for b in a + 1..v {
                    out[a].push(edges.len());
                    edges.push(DagEdge {
                        a,
                        b,
                        cost: EdgeCost { ram_bytes: 1, macs: 1 },
                        iterative_tail: false,
                        param_bytes: 0,
                        band_iterations: 1,
                        latency_macs: 1,
                    });
                }
            }
            let dag = FusionDag { n_nodes: v, out, edges, vanilla_macs: 1 };
            assert_eq!(enumerate_paths(&dag).len(), 1 << (v - 2));
        }
    }
}
