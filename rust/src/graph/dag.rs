//! DAG construction from a [`ModelChain`] (paper §5.1–5.3).

use crate::fusion::{span_edge_cost, CacheScheme, CostMemo, EdgeCost};
use crate::model::ModelChain;

/// Named construction options for [`FusionDag::build`], replacing the old
/// opaque `max_depth: Option<usize>` positional argument.
///
/// `DagOptions::default()` is the paper's configuration: unbounded fusion
/// depth under the H-cache scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DagOptions {
    /// Cap on fusion-block length (`None` = unbounded, the paper's
    /// default); depth pruning is used by ablations and the scaling bench.
    pub max_depth: Option<usize>,
    /// Intra-block cache scheme (§9 "Caching Paradigm" ablation).
    pub scheme: CacheScheme,
}

impl DagOptions {
    /// Cap fusion-block length at `depth` layers.
    #[must_use]
    pub fn max_depth(mut self, depth: usize) -> Self {
        self.max_depth = Some(depth);
        self
    }

    /// Build edge costs under `scheme` instead of the default H-cache.
    #[must_use]
    pub fn scheme(mut self, scheme: CacheScheme) -> Self {
        self.scheme = scheme;
        self
    }
}

/// One edge of the inverted dataflow graph: layers `[a, b)` executed as a
/// single layer (`b == a+1`) or as an H-cache fusion block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DagEdge {
    pub a: usize,
    pub b: usize,
    pub cost: EdgeCost,
    /// Block streams its tail into the iterative pool/dense rewrite (§7).
    pub iterative_tail: bool,
    /// Weight bytes of the span's layers — the flash traffic term of the
    /// latency model ([`crate::mcu::edge_latency_cycles`]).
    pub param_bytes: u64,
    /// Band iterations the span runs (1 for single layers, one per final
    /// output row for fusion blocks) — §8.3's per-iteration flash refetch.
    pub band_iterations: u64,
    /// MAC count the latency model charges this span — always the
    /// H-cache [`crate::fusion::block_macs`] figure, so per-edge latency
    /// sums agree exactly with
    /// [`crate::mcu::estimate_latency_ms`] on the resulting setting.
    pub latency_macs: u64,
}

/// The fusion-candidate DAG of a model: `n_layers + 1` nodes, one edge per
/// single layer plus one per fusable span (`ModelChain::fusable_span`).
#[derive(Debug, Clone)]
pub struct FusionDag {
    pub n_nodes: usize,
    /// Adjacency: `out[i]` lists indices into `edges` of edges leaving `v_i`.
    pub out: Vec<Vec<usize>>,
    pub edges: Vec<DagEdge>,
    pub vanilla_macs: u64,
}

impl FusionDag {
    /// Build the full candidate graph under `options`
    /// ([`DagOptions::default`] = the paper's configuration).
    pub fn build(model: &ModelChain, options: DagOptions) -> Self {
        Self::build_inner(model, options, None)
    }

    /// [`Self::build`] drawing edge costs from a shared per-model
    /// [`CostMemo`], so repeated builds over the same model (budget
    /// sweeps, [`crate::optimizer::Planner`] re-solves,
    /// [`crate::optimizer::PlanBatch`] workers) stop recomputing
    /// Eq. 5/11/12 from scratch. The memo must belong to `model` — keys
    /// carry no model identity.
    pub fn build_memoized(model: &ModelChain, options: DagOptions, memo: &CostMemo) -> Self {
        Self::build_inner(model, options, Some(memo))
    }

    fn build_inner(model: &ModelChain, options: DagOptions, memo: Option<&CostMemo>) -> Self {
        let DagOptions { max_depth, scheme } = options;
        let n_layers = model.num_layers();
        let n_nodes = n_layers + 1;
        let mut edges = Vec::new();
        let mut out = vec![Vec::new(); n_nodes];
        let cost_of = |a: usize, b: usize, tail: bool| -> EdgeCost {
            match memo {
                Some(m) => m.edge_cost(model, a, b, tail, scheme),
                None => span_edge_cost(model, a, b, tail, scheme),
            }
        };
        // Latency ingredients mirror `mcu::estimate_latency_ms` per span:
        // weight bytes, band iterations, and the H-cache MAC figure.
        let latency_of = |a: usize, b: usize| -> (u64, u64, u64) {
            let params: u64 = (a..b).map(|i| model.layers[i].param_bytes()).sum();
            if b - a == 1 {
                (params, 1, model.layer_macs(a))
            } else {
                let iterations = model.output_of(b - 1).h as u64;
                (params, iterations, crate::fusion::block_macs(model, a, b))
            }
        };

        for a in 0..n_layers {
            // Single-layer edge always exists.
            let (param_bytes, band_iterations, latency_macs) = latency_of(a, a + 1);
            out[a].push(edges.len());
            edges.push(DagEdge {
                a,
                b: a + 1,
                cost: cost_of(a, a + 1, false),
                iterative_tail: false,
                param_bytes,
                band_iterations,
                latency_macs,
            });

            // Fusion-block candidates [a, b).
            let depth_cap = max_depth.unwrap_or(n_layers);
            for b in a + 2..=n_layers.min(a + depth_cap) {
                if !model.fusable_span(a, b) {
                    // Spans only grow; a non-streamable layer at the end
                    // blocks all longer spans too.
                    if !model.layers[b - 1].kind.streamable() {
                        break;
                    }
                    continue;
                }
                let (param_bytes, band_iterations, latency_macs) = latency_of(a, b);
                out[a].push(edges.len());
                edges.push(DagEdge {
                    a,
                    b,
                    cost: cost_of(a, b, false),
                    iterative_tail: false,
                    param_bytes,
                    band_iterations,
                    latency_macs,
                });
                // §7: when the rest of the chain is exactly
                // [GlobalPool, Dense*], add a candidate that streams the
                // block's rows straight into the iterative tail — one edge
                // jumping to the output node, never materializing v_b.
                if model.iterative_tail_at(b) {
                    let (param_bytes, band_iterations, latency_macs) =
                        latency_of(a, n_layers);
                    out[a].push(edges.len());
                    edges.push(DagEdge {
                        a,
                        b: n_layers,
                        cost: cost_of(a, b, true),
                        iterative_tail: true,
                        param_bytes,
                        band_iterations,
                        latency_macs,
                    });
                }
            }
        }
        Self {
            n_nodes,
            out,
            edges,
            vanilla_macs: model.total_macs(),
        }
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// A subgraph with the given edges removed (paper Eq. 9's iterative
    /// max-RAM-edge elimination). O(E); edges keep their indices via a
    /// keep-mask so paths remain comparable across iterations.
    pub fn without_edges(&self, remove: &[usize]) -> Self {
        let mut g = self.clone();
        let mut dead = vec![false; g.edges.len()];
        for &e in remove {
            dead[e] = true;
        }
        for adj in g.out.iter_mut() {
            adj.retain(|&e| !dead[e]);
        }
        g
    }

    /// Indices of all edges whose RAM equals the current maximum (the
    /// elimination set of Eq. 9).
    pub fn max_ram_edges(&self) -> Vec<usize> {
        let live: Vec<usize> = self.out.iter().flatten().copied().collect();
        let max = live
            .iter()
            .map(|&e| self.edges[e].cost.ram_bytes)
            .max()
            .unwrap_or(0);
        live.into_iter()
            .filter(|&e| self.edges[e].cost.ram_bytes == max)
            .collect()
    }

    /// Max RAM over live edges (None if graph is empty).
    pub fn max_live_ram(&self) -> Option<u64> {
        self.out
            .iter()
            .flatten()
            .map(|&e| self.edges[e].cost.ram_bytes)
            .max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Activation, Layer, TensorShape};

    fn conv_chain(n: usize) -> ModelChain {
        let layers = (0..n)
            .map(|i| Layer::conv(format!("c{i}"), 3, 1, 1, 3, 3, Activation::Relu6))
            .collect();
        ModelChain::new("c", TensorShape::new(24, 24, 3), layers)
    }

    #[test]
    fn complete_dag_edge_count() {
        // n fully-fusable layers: edges = n singles + C(n,2) fused spans...
        // spans [a,b) with b-a>=2: count = n*(n+1)/2 total pairs minus n
        // singles... for n=4: singles 4, spans (0,2..4),(1,3..4),(2,4) = 3+2+1=6.
        let dag = FusionDag::build(&conv_chain(4), DagOptions::default());
        assert_eq!(dag.num_edges(), 4 + 6);
        assert_eq!(dag.n_nodes, 5);
    }

    #[test]
    fn depth_cap_prunes_long_spans() {
        let dag = FusionDag::build(&conv_chain(4), DagOptions::default().max_depth(2));
        // singles 4 + spans of exactly 2: (0,2),(1,3),(2,4) = 3.
        assert_eq!(dag.num_edges(), 7);
    }

    #[test]
    fn nonfusable_tail_stops_span_growth() {
        let m = ModelChain::new(
            "t",
            TensorShape::new(8, 8, 3),
            vec![
                Layer::conv("c0", 3, 1, 1, 3, 4, Activation::Relu6),
                Layer::conv("c1", 3, 1, 1, 4, 8, Activation::Relu6),
                Layer::global_pool("gp", 8),
                Layer::dense("fc", 8, 2),
            ],
        );
        let dag = FusionDag::build(&m, DagOptions::default());
        // 4 singles + (0,2) fused + the (0,4) iterative-tail candidate
        // (gp/fc not streamable, but §7 lets them fuse as a tail).
        assert_eq!(dag.num_edges(), 6);
        let tail = dag.edges.iter().find(|e| e.iterative_tail).unwrap();
        assert_eq!((tail.a, tail.b), (0, 4));
    }

    #[test]
    fn memo_build_is_identical_and_reuses_costs() {
        use crate::fusion::CostMemo;
        let m = conv_chain(5);
        let memo = CostMemo::new();
        let plain = FusionDag::build(&m, DagOptions::default());
        let cached = FusionDag::build_memoized(&m, DagOptions::default(), &memo);
        let again = FusionDag::build_memoized(&m, DagOptions::default(), &memo);
        assert_eq!(plain.edges, cached.edges);
        assert_eq!(cached.edges, again.edges);
        // The second build hits the memo for every edge.
        let (hits, misses) = memo.stats();
        assert_eq!(misses, plain.num_edges() as u64);
        assert_eq!(hits, plain.num_edges() as u64);
    }

    #[test]
    fn options_are_named_and_chainable() {
        let opts = DagOptions::default()
            .max_depth(3)
            .scheme(CacheScheme::FullyCache);
        assert_eq!(opts.max_depth, Some(3));
        assert_eq!(opts.scheme, CacheScheme::FullyCache);
        let dag = FusionDag::build(&conv_chain(4), opts);
        let full = FusionDag::build(&conv_chain(4), DagOptions::default());
        assert!(dag.num_edges() < full.num_edges());
    }

    #[test]
    fn removal_keeps_indices_stable() {
        let dag = FusionDag::build(&conv_chain(3), DagOptions::default());
        let worst = dag.max_ram_edges();
        let sub = dag.without_edges(&worst);
        assert!(sub.max_live_ram().unwrap() < dag.max_live_ram().unwrap());
        assert_eq!(sub.edges.len(), dag.edges.len()); // mask, not compaction
    }
}
