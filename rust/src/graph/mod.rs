//! Inverted dataflow DAG (paper §5) and the graph algorithms §6 relies on.
//!
//! Nodes are tensor boundaries `v_0..v_n`; edges are single layers or
//! candidate fusion blocks, weighted with `(ram_bytes, macs)`
//! ([`crate::fusion::EdgeCost`]). A *complete compute path* `v_0 → v_n`
//! is a fusion setting (paper §5.1).

mod algo;
mod dag;

pub use algo::{
    enumerate_paths, min_sum_path, minimax_path, path_cost, PathCost,
};
pub use dag::{DagEdge, DagOptions, FusionDag};
