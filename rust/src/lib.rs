//! # msf-CNN — Patch-based Multi-Stage Fusion for TinyML
//!
//! Reproduction of Huang & Baccelli, *msf-CNN: Patch-based Multi-Stage
//! Fusion with Convolutional Neural Networks for TinyML*
//! (arXiv 2505.11483, cs.LG 2025), as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * **L3 (this crate)** — the paper's contribution: CNN chain IR
//!   ([`model`], [`zoo`]), H-cache fusion analytics ([`fusion`]), the
//!   inverted dataflow DAG ([`graph`]), the P1/P2 constrained optimizers
//!   and baselines ([`optimizer`]), a pure-Rust patch-based executor with
//!   RAM tracking ([`ops`], [`memory`], [`exec`]), an MCU board/latency
//!   simulator ([`mcu`]), the artifact runtime ([`runtime`]), a
//!   multi-model serving coordinator ([`coordinator`]), and the paper's
//!   table/figure renderers ([`report`]).
//! * **L2/L1 (build-time Python)** — `python/compile/`: a JAX model whose
//!   hot ops are Pallas kernels (patch-based fused pyramid, iterative
//!   pooling/dense), AOT-lowered to HLO text in `artifacts/`.
//!
//! Quickstart:
//!
//! ```no_run
//! use msf_cnn::graph::FusionDag;
//! use msf_cnn::optimizer::{minimize_macs, minimize_ram_unconstrained};
//! use msf_cnn::zoo;
//!
//! let model = zoo::mbv2(0.35, 144, 1000);
//! let dag = FusionDag::build(&model, None);
//! let min_ram = minimize_ram_unconstrained(&dag).unwrap();
//! println!("min peak RAM: {} kB (F={:.2})",
//!          min_ram.cost.peak_ram as f64 / 1000.0, min_ram.cost.overhead);
//! let budget = minimize_macs(&dag, 64_000).unwrap(); // fit a 64 kB MCU
//! println!("64 kB setting: {}", budget.describe());
//! ```
//!
//! ## Scaling surfaces
//!
//! * **Batch planning** — [`optimizer::PlanBatch`] solves a whole
//!   `(model, board, budget)` grid concurrently on a scoped worker pool
//!   with shared per-model edge-cost memos ([`fusion::CostMemo`]),
//!   bit-identical to the serial sweep:
//!
//! ```no_run
//! use msf_cnn::optimizer::{PlanBatch, PlanJob, PlanObjective};
//! use msf_cnn::zoo;
//!
//! let mut batch = PlanBatch::new();
//! let idx = batch.add_model("kws", zoo::kws_cnn());
//! batch.push(PlanJob::new(idx, PlanObjective::MinRam { f_max: f64::INFINITY }));
//! batch.push(PlanJob::new(idx, PlanObjective::MinMacs { p_max_bytes: 16_000 }));
//! for outcome in batch.solve() {
//!     if let Some(s) = outcome.setting {
//!         println!("{:?} -> {}", outcome.job.objective, s.describe());
//!     }
//! }
//! ```
//!
//! * **Multi-model serving** — [`coordinator::MultiModelServer`] routes
//!   requests across a registry of named plans (artifact- or
//!   engine-backed), one executor thread + bounded queue per model, with
//!   per-model metrics and a structured shutdown drain:
//!
//! ```no_run
//! use msf_cnn::coordinator::{ModelSpec, MultiModelServer};
//! use msf_cnn::graph::FusionDag;
//! use msf_cnn::optimizer::minimize_ram_unconstrained;
//! use msf_cnn::zoo;
//!
//! let model = zoo::quickstart();
//! let plan = minimize_ram_unconstrained(&FusionDag::build(&model, None)).unwrap();
//! let server = MultiModelServer::start(vec![
//!     ModelSpec::engine("quickstart", model, plan),
//! ]).unwrap();
//! let logits = server.handle().infer("quickstart", vec![0.0; 32 * 32 * 3]).unwrap();
//! # drop(logits);
//! server.shutdown();
//! ```

pub mod coordinator;
pub mod exec;
pub mod fusion;
pub mod graph;
pub mod mcu;
pub mod memory;
pub mod model;
pub mod ops;
pub mod optimizer;
pub mod report;
pub mod runtime;
pub mod util;
pub mod zoo;
