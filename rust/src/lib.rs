//! # msf-CNN — Patch-based Multi-Stage Fusion for TinyML
//!
//! Reproduction of Huang & Baccelli, *msf-CNN: Patch-based Multi-Stage
//! Fusion with Convolutional Neural Networks for TinyML*
//! (arXiv 2505.11483, cs.LG 2025), as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * **L3 (this crate)** — the paper's contribution: CNN chain IR
//!   ([`model`], [`zoo`]), H-cache fusion analytics ([`fusion`]), the
//!   inverted dataflow DAG ([`graph`]), the [`optimizer::Planner`]
//!   pipeline over interchangeable [`optimizer::PlanStrategy`] solvers
//!   (P1/P2 and the §8 baselines), a pure-Rust patch-based executor with
//!   RAM tracking plus its compile-once form ([`ops`], [`memory`],
//!   [`exec`]), an MCU board/latency simulator ([`mcu`]), the artifact
//!   runtime ([`runtime`]), the [`backend::InferBackend`] trait unifying
//!   the executors, a multi-model serving coordinator ([`coordinator`]),
//!   and the paper's table/figure renderers ([`report`]).
//! * **L2/L1 (build-time Python)** — `python/compile/`: a JAX model whose
//!   hot ops are Pallas kernels (patch-based fused pyramid, iterative
//!   pooling/dense), AOT-lowered to HLO text in `artifacts/`.
//!
//! ## Quickstart: one pipeline from zoo model to served plan
//!
//! ```no_run
//! use msf_cnn::backend::{EngineBackend, InferBackend};
//! use msf_cnn::optimizer::{Constraint, Planner};
//! use msf_cnn::zoo;
//!
//! // Plan: minimize peak RAM (strategy P1, the default) under a 64 kB
//! // MCU budget.
//! let plan = Planner::for_model(zoo::mbv2(0.35, 144, 1000))
//!     .constraint(Constraint::Ram(64_000))
//!     .plan()
//!     .unwrap();
//! println!("{}", plan.describe());
//!
//! // Persist: the plan round-trips through JSON, so serving can load it
//! // without re-running the optimizer.
//! plan.save("mbv2.plan.json").unwrap();
//!
//! // Execute: any backend behind the same trait.
//! let mut backend = EngineBackend::from_plan(
//!     &msf_cnn::optimizer::Plan::load("mbv2.plan.json").unwrap(),
//! )
//! .unwrap();
//! let logits = backend.run(&vec![0.0; 144 * 144 * 3]).unwrap();
//! println!("{} logits, plan peak {} B", logits.len(), backend.peak_ram());
//! ```
//!
//! Baselines are a strategy swap on the same pipeline
//! ([`optimizer::strategy`]): `P1`, `P2`, `Vanilla`, MCUNetV2-style
//! `HeadFusion`, StreamNet-style `StreamNet`, and exact `Exhaustive`
//! enumeration all implement [`optimizer::PlanStrategy`]. Deployment
//! budgets compose on any of them: `Constraint::Ram`,
//! `Constraint::Overhead`, and the board-bound `Constraint::LatencyMs`
//! (Table 5's axis), with `strategy::LatencyAware` walking the fusion
//! DAG for the minimum-RAM setting inside a latency budget:
//!
//! ```no_run
//! use msf_cnn::mcu::board_by_name;
//! use msf_cnn::optimizer::strategy::LatencyAware;
//! use msf_cnn::optimizer::{Constraint, Planner};
//! use msf_cnn::zoo;
//!
//! let board = board_by_name("nucleo-f767zi").unwrap();
//! let plan = Planner::for_model(zoo::mcunet_vww5())
//!     .constraint(Constraint::Ram(board.ram_bytes()))
//!     .constraint(Constraint::LatencyMs { board, budget: 500.0 })
//!     .strategy(LatencyAware::default())
//!     .plan()
//!     .unwrap();
//! // The plan records its latency estimate + board: a complete deploy
//! // artifact for a registry to serve.
//! let lat = plan.latency.as_ref().unwrap();
//! println!("{}: {:.1} ms on {}", plan.model, lat.estimate_ms, lat.board);
//! ```
//!
//! ## Compile-then-serve: allocation-free execution plans
//!
//! A plan is decided once and then executed on a fixed memory budget —
//! the MCU deployment model. The serving path mirrors it end to end:
//!
//! ```text
//! Planner ──▶ Plan (JSON: setting + costs + pool layout)
//!                 │
//!                 ▼ compile once (connect() / Engine::compile)
//!            CompiledPlan: static step list + offset-assigned pool
//!                 │
//!                 ▼ run many (PlanPool, warm)
//!            allocation-free inference, bit-identical to exec::Engine
//! ```
//!
//! [`exec::CompiledPlan`] replays the span walk once
//! ([`memory::schedule_intervals`]) to derive every buffer lifetime —
//! band pyramids, iterative-tail accumulators, residual stashes, logits —
//! and offset-assigns them into one static pool
//! ([`memory::assign_offsets`]); the layout is recorded in the serialized
//! [`optimizer::Plan`] (`pool`), so a deploy artifact fully describes its
//! memory map. The interpreted [`exec::Engine`] remains the
//! budget-enforcing, arena-traced parity oracle:
//!
//! ```no_run
//! use msf_cnn::exec::Engine;
//! use msf_cnn::ops::Tensor;
//! use msf_cnn::optimizer::Planner;
//! use msf_cnn::zoo;
//!
//! let m = zoo::quickstart();
//! let setting = Planner::for_model(m.clone()).setting().unwrap();
//! let compiled = Engine::new(m).compile(&setting);   // compile once
//! let mut pool = compiled.make_pool();               // the only allocation
//! let x = Tensor::zeros(32, 32, 3);
//! let report = compiled.run(&x, &mut pool);          // allocation-free
//! println!("peak {} B in a {} B pool", report.peak_ram, compiled.pool_bytes());
//! ```
//!
//! ## Scaling surfaces
//!
//! * **Batch planning** — [`optimizer::PlanBatch`] solves a whole
//!   `(model, board, budget)` grid concurrently on a scoped worker pool
//!   with shared per-model edge-cost memos ([`fusion::CostMemo`]),
//!   bit-identical to the serial sweep:
//!
//! ```no_run
//! use msf_cnn::optimizer::{PlanBatch, PlanJob, PlanObjective};
//! use msf_cnn::zoo;
//!
//! let mut batch = PlanBatch::new();
//! let idx = batch.add_model("kws", zoo::kws_cnn());
//! batch.push(PlanJob::new(idx, PlanObjective::MinRam { f_max: f64::INFINITY }));
//! batch.push(PlanJob::new(idx, PlanObjective::MinMacs { p_max_bytes: 16_000 }));
//! for outcome in batch.solve() {
//!     if let Some(s) = outcome.setting {
//!         println!("{:?} -> {}", outcome.job.objective, s.describe());
//!     }
//! }
//! ```
//!
//! * **Multi-model serving with live deployment** —
//!   [`coordinator::MultiModelServer`] routes requests across a live
//!   registry of named plans (artifact-, engine-, or plan-file-backed
//!   [`backend::BackendSpec`]s), one executor thread + bounded queue per
//!   model, with per-model metrics and a structured shutdown drain.
//!   Models are deployed, hot-swapped (in-flight requests drain on the
//!   old backend), and retired at runtime through the handle, and
//!   [`coordinator::PlanRegistry`] feeds the control plane from a
//!   directory of plan JSON files (versioned, re-scanned on demand):
//!
//! ```no_run
//! use msf_cnn::coordinator::{ModelSpec, MultiModelServer, PlanRegistry};
//! use msf_cnn::optimizer::Planner;
//! use msf_cnn::zoo;
//!
//! // Static bring-up…
//! let plan = Planner::for_model(zoo::quickstart()).plan().unwrap();
//! let server = MultiModelServer::start(vec![
//!     ModelSpec::plan("quickstart", plan),
//! ]).unwrap();
//! let handle = server.handle();
//! let logits = handle.infer("quickstart", vec![0.0; 32 * 32 * 3]).unwrap();
//! # drop(logits);
//!
//! // …and live mutation: swap in a new plan for the same id, retire it,
//! // or sync a whole plans/ directory onto the running server.
//! let v2 = Planner::for_model(zoo::quickstart()).plan().unwrap();
//! handle.swap(ModelSpec::plan("quickstart", v2)).unwrap();
//! let mut registry = PlanRegistry::open("plans").unwrap();
//! registry.sync(&handle).unwrap(); // deploy/swap/retire to match the dir
//! server.shutdown();
//! ```
//!
//! ## Observability
//!
//! The [`obs`] module threads measurement through both execution layers
//! without touching the hot path. [`exec::CompiledPlan::run_profiled`]
//! takes a monomorphized [`obs::StepProfiler`]; with the default
//! [`obs::NoProfiler`] it compiles to exactly the allocation-free
//! `run_into` loop (bit-identical logits and MACs), while
//! [`obs::StepRecorder`] + [`obs::profile_plan`] attribute wall time to
//! every compiled step (`msfcnn profile`, `report::table_steps`) — and,
//! inside fused spans, to every sub-step **unit** (block layer,
//! copy-out sink, global-pool / dense / logits tail stage) through the
//! [`ops::UnitProfiler`] brackets, so a fused step is no longer an
//! opaque span ([`obs::UnitStat`]). On the
//! serving side, [`coordinator::Metrics`] keeps per-model
//! queue-wait/execute splits, throughput, and mergeable fixed-bucket
//! [`obs::LatencyHistogram`]s next to its exact sample window, and the
//! control plane emits structured [`obs::TraceEvent`]s (deploy / swap /
//! retire / drain / registry sync) into a pluggable [`obs::TraceSink`].
//! [`obs::export`] freezes all of it into versioned JSON snapshots
//! (`BENCH_infer.json`, `BENCH_serve.json`, `BENCH_kernels.json`,
//! `msfcnn profile --json`) with validators that pin the schema.
//!
//! ## Quantized execution
//!
//! The f32 engine *prices* RAM at int8 widths (the paper's Eq. 5/6
//! accounting); [`qexec`] executes that regime for real. A calibration
//! pass ([`qexec::calibrate_default`]) observes per-tensor ranges over a
//! deterministic input, and [`qexec::QCompiledPlan`] lowers the same
//! step list as [`exec::CompiledPlan`] onto an int8 byte pool —
//! activations at 1 byte per element, i32 accumulators at 4 — using the
//! fused-requantize kernel twins in [`ops::quant`]. The measured pool
//! watermark equals the analytic Eq. 5/6 peak exactly, warm serving is
//! allocation-free end to end (input quantization included), and the
//! [`optimizer::Plan`] JSON carries the `quant` block so a deploy
//! artifact is self-contained:
//!
//! ```no_run
//! use msf_cnn::exec::Engine;
//! use msf_cnn::ops::Tensor;
//! use msf_cnn::optimizer::Planner;
//! use msf_cnn::qexec::{calibrate_default, QCompiledPlan};
//! use msf_cnn::zoo;
//!
//! let m = zoo::quickstart();
//! let setting = Planner::for_model(m.clone()).setting().unwrap();
//! let spec = calibrate_default(&m, Engine::new(m.clone()).params());
//! let q = QCompiledPlan::compile(m, setting, spec);   // compile once
//! let mut pool = q.make_pool();                       // only allocations
//! let x = Tensor::zeros(32, 32, 3);
//! let mut logits = vec![0.0; q.output_len()];
//! q.run_into(x.as_map(), &mut pool, &mut logits);     // int8 end to end
//! assert_eq!(q.measured_peak(), q.layout().watermark); // Eq. 5/6, exact
//! ```
//!
//! ## Kernel engineering
//!
//! Both kernel families — the f32 `*_into` kernels in [`ops`] and their
//! int8 `q*_into` twins — are structured around an **interior/halo
//! decomposition**: output pixels whose receptive field is fully inside
//! the input run a branch-free contiguous sweep (the zero-padding
//! predicate is hoisted out of the per-pixel loops), thin borders keep
//! the guarded path, and the epilogue (bias + activation for f32,
//! requantize-clamp for int8) is folded into the accumulation sweep so
//! no second full pass over the output remains. The f32 kernels
//! preserve the exact per-element accumulation order — the compiled
//! path stays pinned **bit-identical** to the interpreted engine —
//! while the int8 kernels exploit associative i32 accumulation with
//! blocked channel accumulators and zero-point skipping. The original
//! naive loop nests are retained in [`ops::reference`] as parity
//! oracles: `rust/tests/kernel_parity.rs` fuzzes shapes, strides, and
//! paddings against them, and `benches/kernels.rs` times both variants
//! into the committed `BENCH_kernels.json` trajectory.
//!
//! ## Static analysis
//!
//! On-MCU failures are unrecoverable, so a plan must be provably
//! well-formed *before* it is deployed — not discovered broken by the
//! hot path's `debug_assert!`s. The [`analysis`] module is a static
//! verifier with two abstract domains, neither of which executes a MAC:
//!
//! * **Memory** — byte-interval dataflow over the compiled step list
//!   (def-before-use, alias/hazard, lifetime conformance, shape/size
//!   agreement, dead-store lint) plus layout integrity (exhaustive
//!   collision checking, watermark recomputation, divergence against a
//!   fresh schedule replay).
//! * **Numerics** — value-interval abstract interpretation over a
//!   quantized plan's per-step arithmetic
//!   ([`analysis::verify_ranges`]): worst-case i32 accumulator bounds
//!   (overflow freedom), calibration well-formedness (degenerate
//!   scales, out-of-range zero points), and requant saturation risk.
//!
//! Findings are structured diagnostics — step index, buffer name, byte
//! range, defect class, severity — collected exhaustively into an
//! [`analysis::AnalysisReport`]. `Error` findings block deployment;
//! `Warn` findings (saturation risk, dead stores) are rendered
//! distinctly, logged, and never block. The gate is wired end to end:
//! [`exec::CompiledPlan`] asserts the hazard invariants once at
//! compile-time-of-plan, [`optimizer::Plan::validate`] analyzes every
//! serialized layout at parse, [`coordinator::PlanRegistry`] refuses to
//! deploy any file with errors (the scan's
//! [`coordinator::PlanVerdict`]s say why, warnings included), and
//! `msfcnn verify` exposes the same verifier on the CLI — nonzero exit
//! on errors, `--json FILE` exporting every report under the validated
//! `msfcnn.analysis/v1` schema ([`obs::export`]).

pub mod analysis;
pub mod backend;
pub mod coordinator;
pub mod exec;
pub mod fusion;
pub mod graph;
pub mod mcu;
pub mod memory;
pub mod model;
pub mod obs;
pub mod ops;
pub mod optimizer;
pub mod qexec;
pub mod report;
pub mod runtime;
pub mod util;
pub mod zoo;
