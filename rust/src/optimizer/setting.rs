//! A concrete fusion setting: the optimizer's output, the executor's input.

use crate::graph::{path_cost, FusionDag};

/// Cost summary of a setting (Eq. 6–7 plus the overhead factor F).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SettingCost {
    pub peak_ram: u64,
    pub macs: u64,
    /// `F = macs / vanilla_macs` (§5.3).
    pub overhead: f64,
}

/// A complete compute path through the fusion DAG, i.e. a partition of the
/// layer chain into single layers and fusion blocks.
#[must_use = "a FusionSetting is the optimizer's product; drop it and the solve was wasted"]
#[derive(Debug, Clone, PartialEq)]
pub struct FusionSetting {
    /// Edge indices into the originating [`FusionDag`], in execution order.
    pub path: Vec<usize>,
    /// `(a, b, iterative_tail)` spans, in execution order.
    pub spans: Vec<(usize, usize, bool)>,
    pub cost: SettingCost,
}

impl FusionSetting {
    pub fn from_path(dag: &FusionDag, path: Vec<usize>) -> Self {
        let pc = path_cost(dag, &path);
        let spans = path
            .iter()
            .map(|&e| {
                let edge = &dag.edges[e];
                (edge.a, edge.b, edge.iterative_tail)
            })
            .collect();
        Self {
            path,
            spans,
            cost: SettingCost {
                peak_ram: pc.peak_ram,
                macs: pc.macs,
                overhead: pc.macs as f64 / dag.vanilla_macs as f64,
            },
        }
    }

    /// Number of multi-layer fusion blocks in the setting.
    pub fn num_fused_blocks(&self) -> usize {
        self.spans.iter().filter(|(a, b, _)| b - a > 1).count()
    }

    /// Compact human-readable form, e.g. `[0..5|5|5..9*]` (`*` = iterative
    /// tail, `|`-separated spans).
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self
            .spans
            .iter()
            .map(|&(a, b, it)| {
                let star = if it { "*" } else { "" };
                if b - a == 1 {
                    format!("{a}{star}")
                } else {
                    format!("{a}..{b}{star}")
                }
            })
            .collect();
        format!("[{}]", parts.join("|"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DagOptions;
    use crate::model::{Activation, Layer, ModelChain, TensorShape};

    #[test]
    fn from_path_reconstructs_spans() {
        let m = ModelChain::new(
            "s",
            TensorShape::new(16, 16, 3),
            vec![
                Layer::conv("c0", 3, 1, 0, 3, 4, Activation::Relu6),
                Layer::conv("c1", 3, 1, 0, 4, 4, Activation::Relu6),
                Layer::conv("c2", 3, 1, 0, 4, 4, Activation::Relu6),
            ],
        );
        let dag = FusionDag::build(&m, DagOptions::default());
        // Find the edge (0,2) then single 2.
        let e02 = (0..dag.edges.len())
            .find(|&e| dag.edges[e].a == 0 && dag.edges[e].b == 2)
            .unwrap();
        let e2 = (0..dag.edges.len())
            .find(|&e| dag.edges[e].a == 2 && dag.edges[e].b == 3)
            .unwrap();
        let s = FusionSetting::from_path(&dag, vec![e02, e2]);
        assert_eq!(s.spans, vec![(0, 2, false), (2, 3, false)]);
        assert_eq!(s.num_fused_blocks(), 1);
        assert_eq!(s.describe(), "[0..2|2]");
        assert!(s.cost.overhead >= 1.0);
    }
}
