//! [`Planner`]: the one pipeline from a zoo model to a served plan.
//!
//! The builder owns DAG construction and the per-model edge-cost memo
//! ([`crate::fusion::CostMemo`]), so repeated solves on the same model
//! (constraint sweeps, baseline comparisons, table rows) share caches
//! instead of every caller rebuilding `FusionDag` by hand. Its output is a
//! serializable [`Plan`] — setting + costs + provenance — that round-trips
//! through JSON, so a serving process can load pre-solved plans without
//! re-running the optimizer.

use std::path::Path;

use crate::fusion::{CacheScheme, CostMemo};
use crate::graph::{DagOptions, FusionDag};
use crate::memory::{plan_layout, PoolBuffer, PoolLayout};
use crate::model::ModelChain;
use crate::ops::{QParams, QuantSpec};
use crate::util::error::{Context, Result};
use crate::util::json::{escape, Json};
use crate::{anyhow, bail};

use super::setting::{FusionSetting, SettingCost};
use super::strategy::{Constraint, Constraints, P1, PlanStrategy};

/// Latency provenance recorded in a [`Plan`]: the board the estimate was
/// made for and the estimated milliseconds — what turns a plan file into
/// a complete deploy artifact for a registry
/// ([`crate::coordinator::PlanRegistry`]) to serve.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanLatency {
    /// Board name ([`crate::mcu::board_by_name`] key) the estimate used.
    pub board: String,
    /// Estimated inference latency in milliseconds
    /// ([`crate::mcu::estimate_latency_ms`]).
    pub estimate_ms: f64,
}

/// Reference to a [`crate::runtime`] artifact directory backing a plan:
/// the model (and, at serving time, its parameters) resolve through the
/// AOT manifest instead of the zoo, so a plan file can ship alongside
/// compiled artifacts as one self-contained deploy bundle.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanArtifact {
    /// Artifact directory holding `manifest.json` (+ `weights.json`).
    pub dir: String,
    /// Manifest entry this plan executes (must exist in the manifest).
    pub entry: String,
}

/// A solved, serializable fusion plan: the concrete [`FusionSetting`] plus
/// the provenance needed to audit or re-serve it (model name, strategy,
/// constraints, DAG options, and — for latency-constrained solves — the
/// recorded latency estimate with its board).
#[must_use = "a Plan is the deployment artifact; drop it and the solve was wasted"]
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    /// Name of the planned model (resolvable via [`crate::zoo::by_name`]
    /// for zoo models).
    pub model: String,
    /// [`PlanStrategy::name`] of the strategy that produced the setting.
    pub strategy: String,
    /// Constraints the solve ran under.
    pub constraints: Constraints,
    /// Cache scheme the DAG's edge costs were built with.
    pub scheme: CacheScheme,
    /// Fusion-depth cap the DAG was built with, if any.
    pub max_depth: Option<usize>,
    /// Latency estimate + board provenance (recorded whenever the solve
    /// ran under a [`Constraint::LatencyMs`] bound).
    pub latency: Option<PlanLatency>,
    /// Static pool layout of the compiled schedule
    /// ([`crate::memory::plan_layout`]): per-buffer offsets, pool size,
    /// and the concurrent-footprint watermark — the deploy memory map.
    /// `None` on plan JSON written before the compile-once refactor
    /// (old files still load; the layout is recomputed at compile time).
    pub pool: Option<PoolLayout>,
    /// Calibrated per-tensor/per-weight quantization parameters
    /// ([`crate::qexec::calibrate`]). `Some` marks this as a quantized
    /// deploy artifact: serving lowers it through
    /// [`crate::qexec::QCompiledPlan`] (int8 pool) instead of the f32
    /// [`crate::exec::CompiledPlan`].
    pub quant: Option<QuantSpec>,
    /// When set, the model resolves through this [`crate::runtime`]
    /// artifact directory ([`Plan::resolve_model`]) instead of
    /// [`crate::zoo::by_name`].
    pub artifact: Option<PlanArtifact>,
    /// The solved fusion setting (spans + encoded costs).
    pub setting: FusionSetting,
}

impl Plan {
    /// Cost summary of the underlying setting.
    pub fn cost(&self) -> &SettingCost {
        &self.setting.cost
    }

    /// One-line human-readable summary.
    pub fn describe(&self) -> String {
        let lat = match &self.latency {
            Some(l) => format!(", {:.1} ms on {}", l.estimate_ms, l.board),
            None => String::new(),
        };
        format!(
            "{}: {} via {} [{}] -> {:.3} kB at F={:.2}{lat}",
            self.model,
            self.setting.describe(),
            self.strategy,
            self.constraints.describe(),
            self.setting.cost.peak_ram as f64 / 1000.0,
            self.setting.cost.overhead,
        )
    }

    /// Serialize to the crate's plan JSON (stable across sessions; see
    /// [`Plan::from_json`]).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str("  \"version\": 1,\n");
        out.push_str(&format!("  \"model\": \"{}\",\n", escape(&self.model)));
        out.push_str(&format!("  \"strategy\": \"{}\",\n", escape(&self.strategy)));
        out.push_str("  \"constraints\": {");
        let mut parts = Vec::new();
        if let Some(p) = self.constraints.ram_bytes {
            parts.push(format!("\"ram_bytes\": {p}"));
        }
        match self.constraints.overhead {
            Some(f) if f.is_finite() => parts.push(format!("\"overhead\": {f}")),
            _ => {}
        }
        if let Some(l) = self.constraints.latency_bound() {
            parts.push(format!("\"latency_board\": \"{}\"", escape(l.board.name)));
            parts.push(format!("\"latency_ms\": {}", l.budget_ms));
        }
        out.push_str(&parts.join(", "));
        out.push_str("},\n");
        out.push_str(&format!("  \"scheme\": \"{}\",\n", self.scheme.name()));
        match self.max_depth {
            Some(d) => out.push_str(&format!("  \"max_depth\": {d},\n")),
            None => out.push_str("  \"max_depth\": null,\n"),
        }
        if let Some(l) = &self.latency {
            out.push_str(&format!(
                "  \"latency\": {{\"board\": \"{}\", \"estimate_ms\": {}}},\n",
                escape(&l.board),
                l.estimate_ms
            ));
        }
        if let Some(a) = &self.artifact {
            out.push_str(&format!(
                "  \"artifact\": {{\"dir\": \"{}\", \"entry\": \"{}\"}},\n",
                escape(&a.dir),
                escape(&a.entry)
            ));
        }
        if let Some(p) = &self.pool {
            out.push_str(&format!(
                "  \"pool\": {{\"pool_bytes\": {}, \"watermark\": {}, \"buffers\": [\n",
                p.pool_bytes, p.watermark
            ));
            let rows: Vec<String> = p
                .buffers
                .iter()
                .map(|b| {
                    format!(
                        "    {{\"label\": \"{}\", \"offset\": {}, \"bytes\": {}, \"elems\": {}, \"elem_bytes\": {}, \"birth\": {}, \"death\": {}}}",
                        escape(&b.label),
                        b.offset,
                        b.bytes,
                        b.elems,
                        b.elem_bytes,
                        b.birth,
                        b.death
                    )
                })
                .collect();
            out.push_str(&rows.join(",\n"));
            out.push_str("\n  ]},\n");
        }
        if let Some(q) = &self.quant {
            fn qrow(p: &QParams) -> String {
                format!("{{\"scale\": {}, \"zero_point\": {}}}", p.scale, p.zero_point)
            }
            let tensors: Vec<String> = q.tensors.iter().map(qrow).collect();
            let weights: Vec<String> = q.weights.iter().map(qrow).collect();
            out.push_str(&format!(
                "  \"quant\": {{\n    \"tensors\": [{}],\n    \"weights\": [{}]\n  }},\n",
                tensors.join(", "),
                weights.join(", ")
            ));
        }
        out.push_str("  \"setting\": {\n");
        let path: Vec<String> = self.setting.path.iter().map(|e| e.to_string()).collect();
        out.push_str(&format!("    \"path\": [{}],\n", path.join(", ")));
        let spans: Vec<String> = self
            .setting
            .spans
            .iter()
            .map(|&(a, b, it)| format!("[{a}, {b}, {it}]"))
            .collect();
        out.push_str(&format!("    \"spans\": [{}],\n", spans.join(", ")));
        out.push_str(&format!(
            "    \"cost\": {{\"peak_ram\": {}, \"macs\": {}, \"overhead\": {}}}\n",
            self.setting.cost.peak_ram, self.setting.cost.macs, self.setting.cost.overhead
        ));
        out.push_str("  }\n");
        out.push_str("}\n");
        out
    }

    /// Parse a plan previously produced by [`Plan::to_json`].
    pub fn from_json(text: &str) -> Result<Plan> {
        let root = Json::parse(text).map_err(|e| anyhow!("plan json: {e}"))?;
        let str_field = |key: &str| -> Result<String> {
            Ok(root
                .get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("plan json: missing string '{key}'"))?
                .to_string())
        };
        let model = str_field("model")?;
        let strategy = str_field("strategy")?;
        let scheme_name = str_field("scheme")?;
        let scheme = CacheScheme::ALL
            .into_iter()
            .find(|s| s.name() == scheme_name)
            .ok_or_else(|| anyhow!("plan json: unknown scheme '{scheme_name}'"))?;

        let mut constraints = Constraints::none();
        if let Some(c) = root.get("constraints") {
            if let Some(p) = c.get("ram_bytes").and_then(Json::as_f64) {
                constraints = constraints.with(Constraint::Ram(p as u64));
            }
            if let Some(f) = c.get("overhead").and_then(Json::as_f64) {
                constraints = constraints.with(Constraint::Overhead(f));
            }
            if let Some(budget) = c.get("latency_ms").and_then(Json::as_f64) {
                let name = c
                    .get("latency_board")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("plan json: 'latency_ms' without 'latency_board'"))?;
                let board = crate::mcu::board_by_name(name)
                    .ok_or_else(|| anyhow!("plan json: unknown board '{name}'"))?;
                constraints = constraints.with(Constraint::LatencyMs { board, budget });
            }
        }
        let max_depth = match root.get("max_depth") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_usize()
                    .ok_or_else(|| anyhow!("plan json: bad 'max_depth'"))?,
            ),
        };
        let latency = match root.get("latency") {
            None | Some(Json::Null) => None,
            Some(v) => {
                let board = v
                    .get("board")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("plan json: 'latency' missing 'board'"))?
                    .to_string();
                let estimate_ms = v
                    .get("estimate_ms")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("plan json: 'latency' missing 'estimate_ms'"))?;
                Some(PlanLatency { board, estimate_ms })
            }
        };

        // Pool-layout numbers must be non-negative integers: a negative
        // or fractional value is corruption, not something to saturate
        // into a plausible-looking offset.
        let uint = |v: &Json, key: &str, ctx: &str| -> Result<u64> {
            let f = v
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("plan json: {ctx} missing '{key}'"))?;
            if f < 0.0 || f.fract() != 0.0 {
                bail!("plan json: {ctx} has non-integer '{key}' = {f}");
            }
            Ok(f as u64)
        };
        let pool = match root.get("pool") {
            None | Some(Json::Null) => None,
            Some(v) => {
                let pool_bytes = uint(v, "pool_bytes", "'pool'")?;
                let watermark = uint(v, "watermark", "'pool'")?;
                let bufs_v = v
                    .get("buffers")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("plan json: 'pool' missing 'buffers'"))?;
                let mut buffers = Vec::with_capacity(bufs_v.len());
                for bv in bufs_v {
                    let label = bv
                        .get("label")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("plan json: pool buffer missing 'label'"))?
                        .to_string();
                    let offset = uint(bv, "offset", "pool buffer")?;
                    let bytes = uint(bv, "bytes", "pool buffer")?;
                    // Width fields arrived with the quantized-execution
                    // schema; absent means "undeclared" (legacy layouts),
                    // which verify_layout treats as making no width claim.
                    let elems = match bv.get("elems") {
                        None | Some(Json::Null) => 0,
                        Some(_) => uint(bv, "elems", "pool buffer")?,
                    };
                    let elem_bytes = match bv.get("elem_bytes") {
                        None | Some(Json::Null) => 0,
                        Some(_) => uint(bv, "elem_bytes", "pool buffer")? as u32,
                    };
                    let birth = uint(bv, "birth", "pool buffer")? as usize;
                    let death = uint(bv, "death", "pool buffer")? as usize;
                    buffers.push(PoolBuffer { label, offset, bytes, elems, elem_bytes, birth, death });
                }
                Some(PoolLayout { buffers, pool_bytes, watermark })
            }
        };

        let artifact = match root.get("artifact") {
            None | Some(Json::Null) => None,
            Some(v) => {
                let field = |key: &str| -> Result<String> {
                    Ok(v.get(key)
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("plan json: 'artifact' missing '{key}'"))?
                        .to_string())
                };
                Some(PlanArtifact { dir: field("dir")?, entry: field("entry")? })
            }
        };

        let quant = match root.get("quant") {
            None | Some(Json::Null) => None,
            Some(v) => {
                let parse_params = |key: &str| -> Result<Vec<QParams>> {
                    v.get(key)
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("plan json: 'quant' missing '{key}'"))?
                        .iter()
                        .map(|e| {
                            let scale = e
                                .get("scale")
                                .and_then(Json::as_f64)
                                .ok_or_else(|| {
                                    anyhow!("plan json: 'quant.{key}' entry missing 'scale'")
                                })? as f32;
                            if !(scale > 0.0 && scale.is_finite()) {
                                bail!("plan json: 'quant.{key}' scale {scale} is not positive finite");
                            }
                            let zp = e
                                .get("zero_point")
                                .and_then(Json::as_f64)
                                .ok_or_else(|| {
                                    anyhow!("plan json: 'quant.{key}' entry missing 'zero_point'")
                                })?;
                            if zp.fract() != 0.0 || !(-128.0..=127.0).contains(&zp) {
                                bail!("plan json: 'quant.{key}' zero_point {zp} is not an i8 value");
                            }
                            Ok(QParams { scale, zero_point: zp as i32 })
                        })
                        .collect()
                };
                Some(QuantSpec {
                    tensors: parse_params("tensors")?,
                    weights: parse_params("weights")?,
                })
            }
        };

        let setting_v = root
            .get("setting")
            .ok_or_else(|| anyhow!("plan json: missing 'setting'"))?;
        let path: Vec<usize> = setting_v
            .get("path")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("plan json: missing 'setting.path'"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("plan json: bad path index")))
            .collect::<Result<_>>()?;
        let spans_v = setting_v
            .get("spans")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("plan json: missing 'setting.spans'"))?;
        let mut spans = Vec::with_capacity(spans_v.len());
        for sv in spans_v {
            let triple = sv
                .as_arr()
                .filter(|a| a.len() == 3)
                .ok_or_else(|| anyhow!("plan json: span is not [a, b, tail]"))?;
            let a = triple[0]
                .as_usize()
                .ok_or_else(|| anyhow!("plan json: bad span start"))?;
            let b = triple[1]
                .as_usize()
                .ok_or_else(|| anyhow!("plan json: bad span end"))?;
            let it = match &triple[2] {
                Json::Bool(v) => *v,
                _ => bail!("plan json: bad span tail flag"),
            };
            spans.push((a, b, it));
        }
        let cost_v = setting_v
            .get("cost")
            .ok_or_else(|| anyhow!("plan json: missing 'setting.cost'"))?;
        let num = |key: &str| -> Result<f64> {
            cost_v
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("plan json: missing 'setting.cost.{key}'"))
        };
        let cost = SettingCost {
            peak_ram: num("peak_ram")? as u64,
            macs: num("macs")? as u64,
            overhead: num("overhead")?,
        };

        let plan = Plan {
            model,
            strategy,
            constraints,
            scheme,
            max_depth,
            latency,
            pool,
            quant,
            artifact,
            setting: FusionSetting { path, spans, cost },
        };
        plan.validate()?;
        Ok(plan)
    }

    /// Structural validation: spans must partition the layer chain in
    /// execution order (an iterative-tail span may only end the chain),
    /// the recorded peak RAM must be a positive byte count (a zero
    /// here means a negative or missing cost was saturated away during
    /// parsing — no real plan runs in 0 bytes), and a serialized pool
    /// layout must pass [`crate::analysis::verify_layout`] in full.
    pub fn validate(&self) -> Result<()> {
        if self.setting.spans.is_empty() {
            bail!("plan for '{}' has no spans", self.model);
        }
        if self.setting.cost.peak_ram == 0 {
            bail!(
                "plan for '{}' has non-positive peak_ram (cost was negative, zero, or lost in parsing)",
                self.model
            );
        }
        let mut at = 0usize;
        for (i, &(a, b, _)) in self.setting.spans.iter().enumerate() {
            if a != at || b <= a {
                bail!(
                    "plan for '{}': span {i} = [{a}, {b}) does not continue from layer {at}",
                    self.model
                );
            }
            at = b;
        }
        if let Some(p) = &self.pool {
            // Full static layout analysis (exhaustive collisions, bounds,
            // lifetimes, watermark recomputation) — every finding, not
            // just the first, rendered into the rejection.
            let report = crate::analysis::verify_layout(p);
            if report.has_errors() {
                bail!(
                    "plan for '{}': pool layout failed static analysis:\n{}",
                    self.model,
                    report.render()
                );
            }
        }
        Ok(())
    }

    /// Validate against a concrete model (span coverage of all layers,
    /// quant spec arity).
    pub fn validate_for(&self, model: &ModelChain) -> Result<()> {
        self.validate()?;
        let end = self.setting.spans.last().map(|&(_, b, _)| b).unwrap_or(0);
        if end != model.num_layers() {
            bail!(
                "plan for '{}' covers layers 0..{end} but model '{}' has {} layers",
                self.model,
                model.name,
                model.num_layers()
            );
        }
        if let Some(q) = &self.quant {
            let n = model.num_layers();
            if q.tensors.len() != n + 1 || q.weights.len() != n {
                bail!(
                    "plan for '{}': quant spec has {} tensor / {} weight params but model '{}' needs {} / {}",
                    self.model,
                    q.tensors.len(),
                    q.weights.len(),
                    model.name,
                    n + 1,
                    n
                );
            }
        }
        Ok(())
    }

    /// Attach a calibrated [`QuantSpec`] (builder-style), marking this
    /// plan as an int8 deploy artifact: serving routes it through
    /// [`crate::qexec::QCompiledPlan`] and the spec rides along in the
    /// plan JSON, so the artifact fully determines its own numerics.
    pub fn with_quant(mut self, spec: QuantSpec) -> Plan {
        self.quant = Some(spec);
        self
    }

    /// Resolve the model this plan executes. Artifact-backed plans
    /// (`artifact` set) load through the referenced [`crate::runtime`]
    /// directory — the entry must exist in its `manifest.json`; plain
    /// plans resolve `model` via [`crate::zoo::by_name`].
    pub fn resolve_model(&self) -> Result<ModelChain> {
        if let Some(art) = &self.artifact {
            let manifest = crate::runtime::ArtifactManifest::load(
                Path::new(&art.dir).join("manifest.json"),
            )
            .with_context(|| format!("plan '{}': loading artifact manifest", self.model))?;
            if !manifest.entries.contains_key(&art.entry) {
                bail!(
                    "plan '{}': artifact dir '{}' has no entry '{}' (entries: {})",
                    self.model,
                    art.dir,
                    art.entry,
                    manifest.entries.keys().cloned().collect::<Vec<_>>().join(", ")
                );
            }
            let engine = crate::exec::Engine::quickstart_from_artifacts(&art.dir)
                .with_context(|| format!("plan '{}': loading artifact-backed model", self.model))?;
            Ok(engine.model().clone())
        } else {
            crate::zoo::by_name(&self.model)
                .ok_or_else(|| anyhow!("plan references unknown model '{}'", self.model))
        }
    }

    /// Write the plan JSON to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing plan to {}", path.display()))
    }

    /// Load a plan JSON from `path`.
    pub fn load(path: impl AsRef<Path>) -> Result<Plan> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan from {}", path.display()))?;
        Plan::from_json(&text).with_context(|| format!("parsing plan {}", path.display()))
    }
}

/// Builder-style planning pipeline:
///
/// ```no_run
/// use msf_cnn::optimizer::{Constraint, Planner};
/// use msf_cnn::optimizer::strategy::P1;
/// use msf_cnn::zoo;
///
/// let plan = Planner::for_model(zoo::mbv2(0.35, 144, 1000))
///     .constraint(Constraint::Ram(64_000))
///     .strategy(P1::default())
///     .plan()
///     .unwrap();
/// println!("{}", plan.describe());
/// ```
///
/// The planner owns the model's [`FusionDag`] and [`CostMemo`]: the DAG is
/// built once (lazily) and every edge cost is memoized, so re-solving
/// under different strategies or constraints ([`Planner::plan_with`]) and
/// rebuilding after [`Planner::dag_options`] changes reuse prior work.
#[derive(Debug)]
pub struct Planner {
    model: ModelChain,
    options: DagOptions,
    constraints: Constraints,
    strategy: Box<dyn PlanStrategy>,
    memo: CostMemo,
    dag: Option<FusionDag>,
}

impl Planner {
    /// Start a pipeline for `model`. Defaults: [`P1`] (unconstrained
    /// min-RAM, the paper's headline objective) under
    /// [`DagOptions::default`].
    ///
    /// The produced [`Plan`] records `model.name` verbatim — that string
    /// is the serving-side resolution key ([`crate::zoo::by_name`]), so
    /// serving ids live on [`crate::coordinator::ModelSpec::id`], never
    /// on the plan itself.
    pub fn for_model(model: ModelChain) -> Self {
        Self {
            model,
            options: DagOptions::default(),
            constraints: Constraints::none(),
            strategy: Box::new(P1),
            memo: CostMemo::new(),
            dag: None,
        }
    }

    /// Add a deployment constraint (repeatable; one bound per axis).
    #[must_use]
    pub fn constraint(mut self, c: Constraint) -> Self {
        self.constraints = self.constraints.with(c);
        self
    }

    /// Select the solving strategy (default: [`P1`]).
    #[must_use]
    pub fn strategy(mut self, strategy: impl PlanStrategy + 'static) -> Self {
        self.strategy = Box::new(strategy);
        self
    }

    /// DAG construction options (§9 ablation axes). Invalidates the cached
    /// DAG; edge costs for the same scheme stay memoized.
    #[must_use]
    pub fn dag_options(mut self, options: DagOptions) -> Self {
        self.set_dag_options(options);
        self
    }

    /// In-place variant of [`Planner::dag_options`] for planners held by
    /// reference (scheme/depth sweeps).
    pub fn set_dag_options(&mut self, options: DagOptions) {
        if options != self.options {
            self.options = options;
            self.dag = None;
        }
    }

    /// The planned model.
    pub fn model(&self) -> &ModelChain {
        &self.model
    }

    /// The model's fusion-candidate DAG (built on first use, memoized).
    pub fn dag(&mut self) -> &FusionDag {
        self.ensure_dag();
        self.dag.as_ref().unwrap()
    }

    /// Memo `(hits, misses)` — cache reuse across re-solves and rebuilds.
    pub fn memo_stats(&self) -> (u64, u64) {
        self.memo.stats()
    }

    fn ensure_dag(&mut self) {
        if self.dag.is_none() {
            self.dag = Some(FusionDag::build_memoized(&self.model, self.options, &self.memo));
        }
    }

    fn make_plan(
        &self,
        strategy_name: &str,
        constraints: Constraints,
        setting: FusionSetting,
    ) -> Plan {
        // A latency-bound solve records its estimate + board, so the plan
        // file is a complete deploy artifact (registry entries can be
        // admission-checked without re-running the latency model).
        let latency = constraints.latency_bound().map(|l| PlanLatency {
            board: l.board.name.to_string(),
            estimate_ms: crate::mcu::estimate_latency_ms(&self.model, &setting, l.board).total_ms,
        });
        // Compile-once memory map: offset-assign the full fused schedule
        // so the plan file fully describes its static pool.
        let pool = Some(plan_layout(&self.model, &setting));
        Plan {
            model: self.model.name.clone(),
            strategy: strategy_name.to_string(),
            constraints,
            scheme: self.options.scheme,
            max_depth: self.options.max_depth,
            latency,
            pool,
            quant: None,
            artifact: None,
            setting,
        }
    }

    /// Solve with the configured strategy and constraints.
    pub fn plan(&mut self) -> Result<Plan> {
        self.ensure_dag();
        let dag = self.dag.as_ref().unwrap();
        let setting = self.strategy.solve(dag, &self.constraints).ok_or_else(|| {
            anyhow!(
                "no feasible plan for '{}' via {} [{}]",
                self.model.name,
                self.strategy.name(),
                self.constraints.describe()
            )
        })?;
        Ok(self.make_plan(self.strategy.name(), self.constraints, setting))
    }

    /// Solve with an explicit strategy/constraints pair, sharing this
    /// planner's DAG and memo — the cheap way to sweep baselines or
    /// budget grids on one model.
    pub fn plan_with(
        &mut self,
        strategy: &dyn PlanStrategy,
        constraints: Constraints,
    ) -> Result<Plan> {
        self.ensure_dag();
        let dag = self.dag.as_ref().unwrap();
        let setting = strategy.solve(dag, &constraints).ok_or_else(|| {
            anyhow!(
                "no feasible plan for '{}' via {} [{}]",
                self.model.name,
                strategy.name(),
                constraints.describe()
            )
        })?;
        Ok(self.make_plan(strategy.name(), constraints, setting))
    }

    /// Convenience: [`Planner::plan`] reduced to the bare setting.
    pub fn setting(&mut self) -> Result<FusionSetting> {
        Ok(self.plan()?.setting)
    }
}

#[cfg(test)]
mod tests {
    use super::super::strategy::{Exhaustive, HeadFusion, LatencyAware, P2, StreamNet, Vanilla};
    use super::*;
    use crate::zoo;

    #[test]
    fn builder_pipeline_solves_the_paper_objectives() {
        let m = zoo::quickstart();
        let vanilla_peak = m.vanilla_peak_ram();
        let plan = Planner::for_model(m).plan().unwrap();
        assert_eq!(plan.model, "quickstart");
        assert_eq!(plan.strategy, "p1-min-ram");
        assert!(plan.cost().peak_ram < vanilla_peak);

        let budget = Planner::for_model(zoo::quickstart())
            .constraint(Constraint::Ram(4_000))
            .strategy(P2)
            .plan()
            .unwrap();
        assert!(budget.cost().peak_ram <= 4_000);
        assert_eq!(budget.constraints.ram_bytes, Some(4_000));
    }

    #[test]
    fn infeasible_constraints_are_an_error_not_a_panic() {
        let err = Planner::for_model(zoo::quickstart())
            .constraint(Constraint::Ram(8))
            .strategy(P2)
            .plan()
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("no feasible plan"), "{msg}");
        assert!(msg.contains("quickstart"), "{msg}");
    }

    #[test]
    fn plan_with_shares_the_dag_and_memo_across_strategies() {
        let mut planner = Planner::for_model(zoo::quickstart());
        let msf = planner.plan().unwrap();
        let (_, misses_after_first) = planner.memo_stats();
        for s in [
            &Vanilla as &dyn PlanStrategy,
            &HeadFusion,
            &StreamNet,
            &P2,
            &Exhaustive,
        ] {
            let p = planner.plan_with(s, Constraints::none()).unwrap();
            assert!(
                msf.cost().peak_ram <= p.cost().peak_ram,
                "{} beat msf-CNN on RAM",
                s.name()
            );
        }
        // Re-solves never rebuilt an edge: one DAG, zero new misses.
        let (_, misses) = planner.memo_stats();
        assert_eq!(misses, misses_after_first);
    }

    #[test]
    fn dag_options_rebuild_draws_from_the_memo() {
        use crate::graph::DagOptions;
        let mut planner = Planner::for_model(zoo::quickstart());
        let _ = planner.plan().unwrap();
        let (_, misses_first) = planner.memo_stats();
        // Same scheme, capped depth: every surviving edge is a memo hit.
        planner = planner.dag_options(DagOptions::default().max_depth(2));
        let _ = planner.plan().unwrap();
        let (hits, misses) = planner.memo_stats();
        assert_eq!(misses, misses_first, "depth-capped rebuild recomputed edges");
        assert!(hits > 0);
    }

    #[test]
    fn json_roundtrip_preserves_the_plan() {
        let plan = Planner::for_model(zoo::kws_cnn())
            .constraint(Constraint::Ram(16_000))
            .constraint(Constraint::Overhead(1.5))
            .plan()
            .unwrap();
        let text = plan.to_json();
        let back = Plan::from_json(&text).unwrap();
        assert_eq!(plan, back);

        // An infinite overhead bound is normalized at construction, so
        // the round-trip stays exact for it too.
        let inf = Planner::for_model(zoo::tiny_cnn())
            .constraint(Constraint::Overhead(f64::INFINITY))
            .plan()
            .unwrap();
        assert_eq!(inf.constraints.overhead, None);
        assert_eq!(Plan::from_json(&inf.to_json()).unwrap(), inf);
    }

    #[test]
    fn latency_constrained_plan_records_estimate_within_budget() {
        let board = crate::mcu::board_by_name("nucleo-f767zi").unwrap();
        let m = zoo::tiny_cnn();
        let vanilla_ms = {
            let mut p = Planner::for_model(m.clone());
            let v = p.plan_with(&Vanilla, Constraints::none()).unwrap().setting;
            crate::mcu::estimate_latency_ms(&m, &v, board).total_ms
        };
        let budget = vanilla_ms * 1.5;
        let plan = Planner::for_model(m.clone())
            .constraint(Constraint::LatencyMs { board, budget })
            .strategy(LatencyAware::default())
            .plan()
            .unwrap();
        let lat = plan.latency.clone().expect("latency provenance recorded");
        assert_eq!(lat.board, "nucleo-f767zi");
        assert!(lat.estimate_ms <= budget * (1.0 + 1e-9) + 1e-9, "{lat:?} vs {budget}");
        // The recorded number is the latency model's, not a copy of the
        // budget: recomputing from the setting reproduces it.
        let re = crate::mcu::estimate_latency_ms(&m, &plan.setting, board).total_ms;
        assert_eq!(re, lat.estimate_ms);
        assert!(plan.describe().contains("ms on nucleo-f767zi"), "{}", plan.describe());

        // The constraint and the estimate both survive the JSON round
        // trip — a registry entry is a complete deploy artifact.
        let back = Plan::from_json(&plan.to_json()).unwrap();
        assert_eq!(back, plan);
        assert_eq!(back.constraints.latency_bound().unwrap().board.name, "nucleo-f767zi");
    }

    #[test]
    fn pool_layout_roundtrips_and_old_json_without_it_loads() {
        let plan = Planner::for_model(zoo::quickstart()).plan().unwrap();
        let pool = plan.pool.as_ref().expect("planner records the pool layout");
        assert!(pool.pool_bytes >= pool.watermark);
        assert!(!pool.buffers.is_empty());
        // The layout survives the JSON round trip byte-for-byte.
        let back = Plan::from_json(&plan.to_json()).unwrap();
        assert_eq!(back.pool, plan.pool);
        assert_eq!(back, plan);

        // Pre-refactor plan JSON (no "pool" key) still loads: the layout
        // is simply absent and gets recomputed at compile time.
        let mut old = plan.clone();
        old.pool = None;
        let text = old.to_json();
        assert!(!text.contains("\"pool\""), "{text}");
        let loaded = Plan::from_json(&text).unwrap();
        assert_eq!(loaded.pool, None);
        assert_eq!(loaded.setting, plan.setting);

        // A corrupted layout (buffer overrunning the pool) is rejected.
        let mut bad = plan.clone();
        if let Some(p) = bad.pool.as_mut() {
            p.pool_bytes = 1;
        }
        assert!(bad.validate().is_err());

        // Two live-overlapping buffers sharing pool space are rejected.
        let mut collide = plan.clone();
        let p = collide.pool.as_mut().unwrap();
        assert!(p.buffers.len() >= 2, "quickstart layout has many buffers");
        let (off, birth, death) =
            (p.buffers[0].offset, p.buffers[0].birth, p.buffers[0].death);
        p.buffers[1].offset = off;
        p.buffers[1].birth = birth;
        p.buffers[1].death = death;
        assert!(collide.validate().is_err());

        // Negative / fractional pool numbers are corruption, not data to
        // saturate into plausible offsets.
        let neg = plan.to_json().replacen("\"offset\": 0", "\"offset\": -8", 1);
        assert_ne!(neg, plan.to_json(), "expected an offset-0 buffer to corrupt");
        assert!(Plan::from_json(&neg).is_err());
    }

    #[test]
    fn pool_watermark_matches_vanilla_closed_form() {
        // For the vanilla setting the schedule watermark has a closed
        // form: the Eq. 5 peak. The serialized layout must agree.
        let m = zoo::kws_cnn();
        let plan = Planner::for_model(m.clone())
            .strategy(Vanilla)
            .plan()
            .unwrap();
        let pool = plan.pool.as_ref().unwrap();
        assert_eq!(pool.watermark, m.vanilla_peak_ram());
    }

    #[test]
    fn validate_rejects_nonpositive_peak_ram() {
        let plan = Planner::for_model(zoo::tiny_cnn()).plan().unwrap();
        // A negative cost in the JSON saturates to 0 during parsing and
        // must be rejected, not served.
        let json = plan
            .to_json()
            .replace(&format!("\"peak_ram\": {}", plan.cost().peak_ram), "\"peak_ram\": -5");
        let err = Plan::from_json(&json).unwrap_err();
        assert!(format!("{err:#}").contains("peak_ram"), "{err:#}");

        let mut zeroed = plan;
        zeroed.setting.cost.peak_ram = 0;
        assert!(zeroed.validate().is_err());
    }

    #[test]
    fn load_errors_name_the_offending_file() {
        let dir = std::env::temp_dir();
        let missing = dir.join("msfcnn-no-such-plan.json");
        let err = format!("{:#}", Plan::load(&missing).unwrap_err());
        assert!(err.contains("msfcnn-no-such-plan.json"), "{err}");

        let garbage = dir.join("msfcnn-garbage-plan.json");
        std::fs::write(&garbage, "{ not json").unwrap();
        let err = format!("{:#}", Plan::load(&garbage).unwrap_err());
        let _ = std::fs::remove_file(&garbage);
        assert!(err.contains("msfcnn-garbage-plan.json"), "{err}");
        assert!(err.contains("parsing plan"), "{err}");
    }

    #[test]
    fn save_load_roundtrip_on_disk() {
        let plan = Planner::for_model(zoo::tiny_cnn()).plan().unwrap();
        let path = std::env::temp_dir().join("msfcnn-planner-test.plan.json");
        plan.save(&path).unwrap();
        let back = Plan::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(plan, back);
        back.validate_for(&zoo::tiny_cnn()).unwrap();
    }

    #[test]
    fn validation_rejects_malformed_plans() {
        let mut plan = Planner::for_model(zoo::tiny_cnn()).plan().unwrap();
        assert!(plan.validate().is_ok());
        // Wrong model: span coverage mismatch.
        assert!(plan.validate_for(&zoo::lenet()).is_err());
        // Corrupt the span chain.
        plan.setting.spans[0].1 += 1;
        if plan.setting.spans.len() > 1 {
            assert!(plan.validate().is_err());
        } else {
            assert!(plan.validate_for(&zoo::tiny_cnn()).is_err());
        }
    }

    #[test]
    fn load_rejects_garbage() {
        assert!(Plan::from_json("not json").is_err());
        assert!(Plan::from_json("{}").is_err());
        assert!(Plan::load("/nonexistent/plan.json").is_err());
    }

    #[test]
    fn quant_spec_and_buffer_widths_roundtrip_through_json() {
        let m = zoo::quickstart();
        let params: Vec<crate::ops::LayerParams> = m
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| crate::ops::LayerParams::for_layer(l, i))
            .collect();
        let spec = crate::qexec::calibrate_default(&m, &params);
        let plan = Planner::for_model(m.clone()).plan().unwrap().with_quant(spec);
        plan.validate_for(&m).unwrap();

        let text = plan.to_json();
        assert!(text.contains("\"quant\""), "{text}");
        let back = Plan::from_json(&text).unwrap();
        assert_eq!(back, plan, "quant spec or widths lost in the round trip");

        // The serialized layout carries the mixed Eq. 5/6 element widths.
        let pool = back.pool.as_ref().unwrap();
        assert!(pool.buffers.iter().all(|b| b.elems > 0));
        assert!(pool.buffers.iter().any(|b| b.elem_bytes == 1));
        assert!(pool.buffers.iter().any(|b| b.elem_bytes == 4));

        // Wrong-arity quant specs are rejected against the model.
        let mut bad = plan.clone();
        bad.quant.as_mut().unwrap().tensors.pop();
        assert!(bad.validate_for(&m).is_err());

        // Corrupt quant numbers are parse errors, not silent saturation.
        let mut zp_broken = plan.clone();
        zp_broken.quant.as_mut().unwrap().tensors[0].zero_point = 900;
        assert!(Plan::from_json(&zp_broken.to_json()).is_err());
    }

    #[test]
    fn width_inconsistent_pool_is_rejected_naming_the_buffer() {
        let mut plan = Planner::for_model(zoo::quickstart()).plan().unwrap();
        let victim = {
            let p = plan.pool.as_mut().unwrap();
            // Claim f32-wide elements behind an int8-sized byte count.
            p.buffers[0].elem_bytes *= 4;
            p.buffers[0].label.clone()
        };
        let err = format!("{:#}", plan.validate().unwrap_err());
        assert!(err.contains("width-mismatch"), "{err}");
        assert!(err.contains(&victim), "finding must name '{victim}':\n{err}");
    }

    #[test]
    fn artifact_backed_plans_roundtrip_and_resolve() {
        let mut plan = Planner::for_model(zoo::quickstart()).plan().unwrap();
        plan.artifact =
            Some(PlanArtifact { dir: "artifacts".to_string(), entry: "model_vanilla".to_string() });
        let back = Plan::from_json(&plan.to_json()).unwrap();
        assert_eq!(back, plan, "artifact reference lost in the round trip");

        // Plain plans resolve through the zoo by canonical name.
        let zoo_plan = Planner::for_model(zoo::tiny_cnn()).plan().unwrap();
        assert_eq!(zoo_plan.resolve_model().unwrap().name, zoo::tiny_cnn().name);

        // A dangling artifact directory is an error, not a zoo fallback.
        let mut dangling = zoo_plan.clone();
        dangling.artifact = Some(PlanArtifact {
            dir: "/nonexistent/artifacts".to_string(),
            entry: "model_vanilla".to_string(),
        });
        assert!(dangling.resolve_model().is_err());

        // Full resolution when the AOT artifacts have been built.
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        let built = std::path::Path::new(dir).join("manifest.json").exists()
            && std::path::Path::new(dir).join("weights.json").exists();
        if built {
            let mut real = Planner::for_model(zoo::quickstart()).plan().unwrap();
            real.artifact =
                Some(PlanArtifact { dir: dir.to_string(), entry: "model_vanilla".to_string() });
            assert_eq!(real.resolve_model().unwrap().name, "quickstart");
            // Entries absent from the manifest are rejected by name.
            real.artifact.as_mut().unwrap().entry = "no_such_entry".to_string();
            let err = format!("{:#}", real.resolve_model().unwrap_err());
            assert!(err.contains("no_such_entry"), "{err}");
        }
    }
}
