//! `PlanBatch`: parallel fusion planning over many `(model, board,
//! budget)` configurations.
//!
//! The MCUNet-style co-design workload is a *sweep* — many models × many
//! boards × many RAM/compute budgets — and every cell is an independent
//! strategy solve. `PlanBatch` runs the whole sweep on a
//! [`std::thread::scope`] worker pool in two phases:
//!
//! 1. one DAG build per distinct model, backed by the batch's
//!    *persistent* per-model [`CostMemo`]: within a solve the DAG is
//!    shared by every job, and across solves (bench iterations, repeated
//!    table generation, scheme sweeps on the same batch) rebuilds draw
//!    every Eq. 5/11/12 edge cost from the memo instead of recomputing;
//! 2. all jobs drained from a lock-free index queue, each solving against
//!    the (immutable, shared) DAG of its model.
//!
//! Every job dispatches through the same [`PlanStrategy`] trait objects
//! the [`crate::optimizer::Planner`] uses ([`PlanObjective::dispatch`]),
//! so [`PlanBatch::solve`] is bit-identical to
//! [`PlanBatch::solve_serial`] — asserted by `benches/plan_batch.rs` and
//! the `plan_batch_parallel_matches_serial` property test.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::fusion::CostMemo;
use crate::graph::{DagOptions, FusionDag};
use crate::mcu::Board;
use crate::model::ModelChain;

use super::strategy::{
    Constraint, Constraints, HeadFusion, LatencyAware, P1, P2, PlanStrategy, StreamNet, Vanilla,
};
use super::FusionSetting;

/// What one configuration solves for. Each variant denotes a
/// [`PlanStrategy`] + [`Constraints`] pair (see [`PlanObjective::dispatch`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PlanObjective {
    /// P1: minimize peak RAM s.t. `F ≤ f_max` (`f64::INFINITY` ⇒ the
    /// unconstrained minimax path).
    MinRam { f_max: f64 },
    /// P2: minimize MACs s.t. peak RAM `≤ p_max_bytes`.
    MinMacs { p_max_bytes: u64 },
    /// [`LatencyAware`]: minimize peak RAM s.t. the estimated latency on
    /// `board` stays within `budget_ms` (Table 5's axis), optionally
    /// jointly with a RAM cap.
    MinRamLatency {
        board: &'static Board,
        budget_ms: f64,
        p_max_bytes: Option<u64>,
    },
    /// The un-fused baseline.
    Vanilla,
    /// MCUNetV2-style head-fusion heuristic baseline.
    Heuristic,
    /// StreamNet-style single-block baseline.
    StreamNet,
}

impl PlanObjective {
    /// Collapse the objective into the strategy trait object and
    /// constraint set it denotes — the single place the enum is matched.
    pub fn dispatch(&self) -> (Box<dyn PlanStrategy>, Constraints) {
        match *self {
            PlanObjective::MinRam { f_max } => (
                Box::new(P1),
                Constraints::none().with(Constraint::Overhead(f_max)),
            ),
            PlanObjective::MinMacs { p_max_bytes } => (
                Box::new(P2),
                Constraints::none().with(Constraint::Ram(p_max_bytes)),
            ),
            PlanObjective::MinRamLatency { board, budget_ms, p_max_bytes } => {
                let mut c =
                    Constraints::none().with(Constraint::LatencyMs { board, budget: budget_ms });
                if let Some(p) = p_max_bytes {
                    c = c.with(Constraint::Ram(p));
                }
                (Box::new(LatencyAware), c)
            }
            PlanObjective::Vanilla => (Box::new(Vanilla), Constraints::none()),
            PlanObjective::Heuristic => (Box::new(HeadFusion), Constraints::none()),
            PlanObjective::StreamNet => (Box::new(StreamNet), Constraints::none()),
        }
    }
}

/// One planning configuration: a model (by index into the batch's model
/// list), an optional target board (reporting / board-derived budgets),
/// and an objective.
#[derive(Debug, Clone)]
pub struct PlanJob {
    pub model: usize,
    pub board: Option<&'static Board>,
    pub objective: PlanObjective,
}

impl PlanJob {
    pub fn new(model: usize, objective: PlanObjective) -> Self {
        Self { model, board: None, objective }
    }

    /// P2 job fitting `board`'s physical RAM (the deployment-advisor cell).
    pub fn fit_board(model: usize, board: &'static Board) -> Self {
        Self {
            model,
            board: Some(board),
            objective: PlanObjective::MinMacs { p_max_bytes: board.ram_bytes() },
        }
    }
}

/// Result of one job, in the order the jobs were pushed.
#[derive(Debug, Clone)]
pub struct PlanOutcome {
    pub job: PlanJob,
    /// `None` is the paper's "(No Solution)" cell.
    pub setting: Option<FusionSetting>,
}

/// A batch of planning configurations over a set of models.
#[derive(Debug, Default)]
pub struct PlanBatch {
    models: Vec<(String, ModelChain)>,
    /// One persistent edge-cost memo per model (same index), reused
    /// across every [`Self::solve`] call on this batch.
    memos: Vec<CostMemo>,
    jobs: Vec<PlanJob>,
    options: DagOptions,
}

impl PlanBatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Batch under non-default DAG options (§9 ablations: cache scheme /
    /// fusion-depth cap).
    pub fn with_options(options: DagOptions) -> Self {
        Self { options, ..Self::default() }
    }

    /// Register a model; the returned index is what [`PlanJob::model`]
    /// refers to.
    pub fn add_model(&mut self, label: impl Into<String>, model: ModelChain) -> usize {
        self.models.push((label.into(), model));
        self.memos.push(CostMemo::new());
        self.models.len() - 1
    }

    /// Queue one configuration. Panics if the model index is unknown.
    pub fn push(&mut self, job: PlanJob) {
        assert!(job.model < self.models.len(), "unknown model index {}", job.model);
        self.jobs.push(job);
    }

    /// Convenience: queue the full paper constraint grid (baselines + P1
    /// F-grid + P2 P-grid) for one model.
    pub fn push_grid(&mut self, model: usize, f_grid: &[f64], p_grid_bytes: &[u64]) {
        self.push(PlanJob::new(model, PlanObjective::Vanilla));
        self.push(PlanJob::new(model, PlanObjective::Heuristic));
        self.push(PlanJob::new(model, PlanObjective::StreamNet));
        for &f_max in f_grid {
            self.push(PlanJob::new(model, PlanObjective::MinRam { f_max }));
        }
        for &p in p_grid_bytes {
            self.push(PlanJob::new(model, PlanObjective::MinMacs { p_max_bytes: p }));
        }
    }

    pub fn models(&self) -> &[(String, ModelChain)] {
        &self.models
    }

    /// Aggregate `(hits, misses)` of the per-model edge-cost memos — the
    /// reuse the bench reports.
    pub fn memo_stats(&self) -> (u64, u64) {
        self.memos.iter().map(CostMemo::stats).fold((0, 0), |(h, m), (h2, m2)| (h + h2, m + m2))
    }

    pub fn jobs(&self) -> &[PlanJob] {
        &self.jobs
    }

    /// Solve every job on a worker pool sized to the machine.
    pub fn solve(&self) -> Vec<PlanOutcome> {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        self.solve_with_threads(threads)
    }

    /// Solve every job on `threads` scoped workers. Outcomes preserve job
    /// order and are bit-identical to [`Self::solve_serial`].
    pub fn solve_with_threads(&self, threads: usize) -> Vec<PlanOutcome> {
        let threads = threads.max(1);

        // Phase 1: one DAG per distinct model, built in parallel from the
        // batch's persistent memos (first solve populates them; repeated
        // solves rebuild every edge from cache).
        let dag_slots: Vec<Mutex<Option<FusionDag>>> =
            self.models.iter().map(|_| Mutex::new(None)).collect();
        let next_model = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..threads.min(self.models.len().max(1)) {
                s.spawn(|| loop {
                    let i = next_model.fetch_add(1, Ordering::Relaxed);
                    if i >= self.models.len() {
                        break;
                    }
                    let dag = FusionDag::build_memoized(
                        &self.models[i].1,
                        self.options,
                        &self.memos[i],
                    );
                    *dag_slots[i].lock().unwrap() = Some(dag);
                });
            }
        });
        let dags: Vec<FusionDag> = dag_slots
            .into_iter()
            .map(|slot| slot.into_inner().unwrap().expect("dag built"))
            .collect();

        // Phase 2: drain the job queue.
        let out_slots: Vec<Mutex<Option<PlanOutcome>>> =
            self.jobs.iter().map(|_| Mutex::new(None)).collect();
        let next_job = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..threads.min(self.jobs.len().max(1)) {
                s.spawn(|| loop {
                    let j = next_job.fetch_add(1, Ordering::Relaxed);
                    if j >= self.jobs.len() {
                        break;
                    }
                    let job = self.jobs[j].clone();
                    let setting = solve_one(&dags[job.model], &job);
                    *out_slots[j].lock().unwrap() = Some(PlanOutcome { job, setting });
                });
            }
        });
        out_slots
            .into_iter()
            .map(|slot| slot.into_inner().unwrap().expect("job solved"))
            .collect()
    }

    /// The reference serial sweep: one thread, no memo — exactly what a
    /// loop over `FusionDag::build` + strategy solves would do.
    pub fn solve_serial(&self) -> Vec<PlanOutcome> {
        let dags: Vec<FusionDag> = self
            .models
            .iter()
            .map(|(_, m)| FusionDag::build(m, self.options))
            .collect();
        self.jobs
            .iter()
            .map(|job| PlanOutcome { job: job.clone(), setting: solve_one(&dags[job.model], job) })
            .collect()
    }
}

fn solve_one(dag: &FusionDag, job: &PlanJob) -> Option<FusionSetting> {
    let (strategy, constraints) = job.objective.dispatch();
    strategy.solve(dag, &constraints)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    fn zoo_batch() -> PlanBatch {
        let mut batch = PlanBatch::new();
        for name in ["quickstart", "tiny", "kws", "lenet"] {
            let idx = batch.add_model(name, zoo::by_name(name).unwrap());
            batch.push_grid(
                idx,
                &[1.1, 1.3, f64::INFINITY],
                &[4_000, 16_000, 64_000],
            );
        }
        batch
    }

    fn assert_same(a: &[PlanOutcome], b: &[PlanOutcome]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.job.model, y.job.model);
            assert_eq!(x.job.objective, y.job.objective);
            match (&x.setting, &y.setting) {
                (None, None) => {}
                (Some(s), Some(t)) => {
                    assert_eq!(s.spans, t.spans, "model {} {:?}", x.job.model, x.job.objective);
                    assert_eq!(s.cost.peak_ram, t.cost.peak_ram);
                    assert_eq!(s.cost.macs, t.cost.macs);
                }
                (s, t) => panic!("feasibility mismatch: {s:?} vs {t:?}"),
            }
        }
    }

    #[test]
    fn parallel_matches_serial_bit_for_bit() {
        let batch = zoo_batch();
        let serial = batch.solve_serial();
        for threads in [1, 2, 8] {
            assert_same(&serial, &batch.solve_with_threads(threads));
        }
        assert_same(&serial, &batch.solve());
    }

    #[test]
    fn objective_dispatch_matches_direct_strategy_calls() {
        // The enum is sugar over the trait objects: solving a job must be
        // identical to invoking the corresponding strategy by hand.
        let m = zoo::quickstart();
        let dag = FusionDag::build(&m, DagOptions::default());
        let board = crate::mcu::board_by_name("nucleo-f767zi").unwrap();
        let cases = [
            PlanObjective::Vanilla,
            PlanObjective::Heuristic,
            PlanObjective::StreamNet,
            PlanObjective::MinRam { f_max: 1.2 },
            PlanObjective::MinRam { f_max: f64::INFINITY },
            PlanObjective::MinMacs { p_max_bytes: 4_000 },
            PlanObjective::MinRamLatency {
                board,
                budget_ms: 1e6,
                p_max_bytes: Some(64_000),
            },
        ];
        for objective in cases {
            let (strategy, constraints) = objective.dispatch();
            let direct = strategy.solve(&dag, &constraints);
            let via_job = solve_one(&dag, &PlanJob::new(0, objective));
            assert_eq!(
                direct.as_ref().map(|s| (&s.spans, s.cost.peak_ram)),
                via_job.as_ref().map(|s| (&s.spans, s.cost.peak_ram)),
                "{objective:?}"
            );
        }
    }

    #[test]
    fn outcomes_preserve_job_order() {
        let batch = zoo_batch();
        let out = batch.solve();
        assert_eq!(out.len(), batch.jobs().len());
        for (o, j) in out.iter().zip(batch.jobs()) {
            assert_eq!(o.job.model, j.model);
            assert_eq!(o.job.objective, j.objective);
        }
    }

    #[test]
    fn repeated_solves_hit_the_memo() {
        let batch = zoo_batch();
        let first = batch.solve();
        let (hits_after_first, misses) = batch.memo_stats();
        assert!(misses > 0, "first solve populates the memos");
        let second = batch.solve();
        let (hits_after_second, misses_after_second) = batch.memo_stats();
        assert_eq!(misses_after_second, misses, "second solve recomputes nothing");
        assert!(
            hits_after_second >= hits_after_first + misses,
            "second solve draws every edge from the memo"
        );
        assert_same(&first, &second);
    }

    #[test]
    fn fit_board_jobs_respect_board_ram() {
        let board = crate::mcu::board_by_name("hifive1b").unwrap();
        let mut batch = PlanBatch::new();
        let idx = batch.add_model("quickstart", zoo::quickstart());
        batch.push(PlanJob::fit_board(idx, board));
        let out = batch.solve();
        let s = out[0].setting.as_ref().expect("quickstart fits 16 kB");
        assert!(s.cost.peak_ram <= board.ram_bytes());
        assert_eq!(out[0].job.board.unwrap().name, "hifive1b");
    }

    #[test]
    #[should_panic(expected = "unknown model index")]
    fn pushing_unknown_model_panics() {
        let mut batch = PlanBatch::new();
        batch.push(PlanJob::new(3, PlanObjective::Vanilla));
    }
}
