//! Fusion-setting optimization (paper §6): the [`Planner`] pipeline,
//! interchangeable [`PlanStrategy`] solvers, and batch planning.
//!
//! * [`Planner`] — builder-style pipeline from a model to a serializable
//!   [`Plan`]: owns DAG construction and the per-model edge-cost memo so
//!   repeated solves share caches.
//! * [`strategy`] — the [`PlanStrategy`] implementations: paper solvers
//!   [`strategy::P1`] (min RAM s.t. `F ≤ F_max`, Eq. 8–10) and
//!   [`strategy::P2`] (min MACs s.t. `P ≤ P_max`), the
//!   latency-constrained [`strategy::LatencyAware`] walk (Table 5's
//!   axis, via [`Constraint::LatencyMs`]), plus the §8 baselines
//!   ([`strategy::Vanilla`], MCUNetV2-style [`strategy::HeadFusion`],
//!   [`strategy::StreamNet`]) and exact [`strategy::Exhaustive`]
//!   enumeration — all interchangeable behind trait objects.
//! * [`batch`] — [`PlanBatch`]: the P1/P2/latency sweep over many
//!   `(model, board, budget)` configurations, parallelized on a scoped
//!   worker pool with shared per-model edge-cost memos; bit-identical to
//!   the serial path. [`PlanObjective`] dispatch collapses into the same
//!   strategy trait objects.
//!
//! The pre-0.2 free functions (`minimize_ram`, `minimize_macs`,
//! `vanilla_setting`, …) are gone; every solve goes through a
//! [`PlanStrategy`].

mod baselines;
mod batch;
mod exhaustive;
mod p1;
mod p2;
mod planner;
mod setting;
pub mod strategy;

pub use batch::{PlanBatch, PlanJob, PlanObjective, PlanOutcome};
pub use exhaustive::{exhaustive_p1, exhaustive_p2};
pub use planner::{Plan, PlanArtifact, PlanLatency, Planner};
pub use setting::{FusionSetting, SettingCost};
pub use strategy::{Constraint, Constraints, LatencyBound, PlanStrategy};

use crate::graph::FusionDag;

/// Shared outcome type: a concrete fusion setting with its encoded costs,
/// or `None` when no complete path satisfies the constraints (the paper's
/// "(No Solution)" cells in Table 1).
pub type OptResult = Option<FusionSetting>;

/// Compute-overhead factor `F = C_S / C_vanilla` (§5.3).
pub fn overhead_factor(dag: &FusionDag, macs: u64) -> f64 {
    macs as f64 / dag.vanilla_macs as f64
}
