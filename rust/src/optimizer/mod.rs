//! Fusion-setting optimizers (paper §6) and baselines.
//!
//! * [`p1`] — minimize peak RAM s.t. compute-overhead `F ≤ F_max`
//!   (minimax path; constrained variant prunes max-RAM edges iteratively,
//!   Eq. 8–10, O(V³) worst case).
//! * [`p2`] — minimize MACs s.t. peak RAM `P ≤ P_max`
//!   (filter over-limit edges, then shortest path).
//! * [`baselines`] — vanilla, MCUNetV2-style head-fusion heuristic,
//!   StreamNet-style single-block brute force.
//! * [`exhaustive`] — exact enumeration (tests/property-checks only).
//! * [`batch`] — [`PlanBatch`]: the P1/P2 sweep over many
//!   `(model, board, budget)` configurations, parallelized on a scoped
//!   worker pool with shared per-model edge-cost memos; bit-identical to
//!   the serial path.

mod baselines;
mod batch;
mod exhaustive;
mod p1;
mod p2;
mod setting;

pub use baselines::{heuristic_head_fusion, streamnet_single_block, vanilla_setting};
pub use batch::{PlanBatch, PlanJob, PlanObjective, PlanOutcome};
pub use exhaustive::{exhaustive_p1, exhaustive_p2};
pub use p1::{minimize_ram, minimize_ram_unconstrained};
pub use p2::{minimize_macs, minimize_macs_unconstrained};
pub use setting::{FusionSetting, SettingCost};

use crate::graph::FusionDag;

/// Shared outcome type: a concrete fusion setting with its encoded costs,
/// or `None` when no complete path satisfies the constraints (the paper's
/// "(No Solution)" cells in Table 1).
pub type OptResult = Option<FusionSetting>;

/// Compute-overhead factor `F = C_S / C_vanilla` (§5.3).
pub fn overhead_factor(dag: &FusionDag, macs: u64) -> f64 {
    macs as f64 / dag.vanilla_macs as f64
}
