//! [`PlanStrategy`]: the interchangeable solver surface of the planner.
//!
//! The paper's P1/P2 optimizers and every §8 baseline (vanilla, the
//! MCUNetV2-style head-fusion heuristic, StreamNet single-block, exact
//! exhaustive enumeration) implement one trait, so Table 1/2-style
//! comparisons are a strategy swap instead of a different free function
//! per row:
//!
//! ```no_run
//! use msf_cnn::optimizer::strategy::{HeadFusion, P2};
//! use msf_cnn::optimizer::{Constraint, Planner};
//! use msf_cnn::zoo;
//!
//! let mut planner = Planner::for_model(zoo::quickstart());
//! let msf = planner.plan().unwrap(); // default strategy: P1, min RAM
//! let fits = Planner::for_model(zoo::quickstart())
//!     .constraint(Constraint::Ram(4_000))
//!     .strategy(P2)
//!     .plan()
//!     .unwrap();
//! let baseline = Planner::for_model(zoo::quickstart())
//!     .strategy(HeadFusion)
//!     .plan()
//!     .unwrap();
//! assert!(msf.cost().peak_ram <= baseline.cost().peak_ram);
//! assert!(fits.cost().peak_ram <= 4_000);
//! ```

use std::fmt;

use crate::graph::{enumerate_paths, path_cost, FusionDag};

use super::baselines::{solve_head_fusion, solve_streamnet, solve_vanilla};
use super::p1::{solve_p1, solve_p1_unconstrained};
use super::p2::{solve_p2, solve_p2_unconstrained};
use super::FusionSetting;

/// One deployment constraint (the paper's §6 budget axes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Constraint {
    /// Peak RAM budget in bytes (`P ≤ P_max`, problem P2's axis).
    Ram(u64),
    /// Compute-overhead budget (`F = C_S / C_vanilla ≤ F_max`, problem
    /// P1's axis).
    Overhead(f64),
}

/// The accumulated constraint set a strategy solves under. Every axis is
/// optional; [`Constraints::none`] is the unconstrained problem.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Constraints {
    /// Peak RAM budget in bytes, if any.
    pub ram_bytes: Option<u64>,
    /// Compute-overhead budget `F_max`, if any (an infinite budget is
    /// treated as absent).
    pub overhead: Option<f64>,
}

impl Constraints {
    /// No constraints: the unconstrained minimization problem.
    pub fn none() -> Self {
        Self::default()
    }

    /// Add `c` to the set (replacing any previous bound on the same
    /// axis). A non-finite overhead bound is normalized to "no bound", so
    /// `Overhead(f64::INFINITY)` round-trips through [`Plan`] JSON
    /// exactly.
    ///
    /// [`Plan`]: crate::optimizer::Plan
    #[must_use]
    pub fn with(mut self, c: Constraint) -> Self {
        match c {
            Constraint::Ram(bytes) => self.ram_bytes = Some(bytes),
            Constraint::Overhead(f_max) => {
                self.overhead = Some(f_max).filter(|f| f.is_finite());
            }
        }
        self
    }

    /// The effective overhead bound (`None` for absent *or* infinite).
    fn overhead_bound(&self) -> Option<f64> {
        self.overhead.filter(|f| f.is_finite())
    }

    /// Whether `setting` satisfies every bound (overhead within float
    /// tolerance, RAM exactly).
    pub fn satisfied_by(&self, setting: &FusionSetting) -> bool {
        if let Some(p_max) = self.ram_bytes {
            if setting.cost.peak_ram > p_max {
                return false;
            }
        }
        if let Some(f_max) = self.overhead_bound() {
            if setting.cost.overhead > f_max + 1e-9 {
                return false;
            }
        }
        true
    }

    /// Human-readable form for provenance / describe lines.
    pub fn describe(&self) -> String {
        match (self.ram_bytes, self.overhead_bound()) {
            (None, None) => "unconstrained".into(),
            (Some(p), None) => format!("P<={p}B"),
            (None, Some(f)) => format!("F<={f}"),
            (Some(p), Some(f)) => format!("P<={p}B,F<={f}"),
        }
    }
}

/// The integer MAC budget an overhead bound induces — exactly the Eq. 8
/// `floor(F_max · C_vanilla)` rule the P1 solver prunes with, so every
/// strategy enforces the overhead axis bit-identically.
fn mac_budget(dag: &FusionDag, constraints: &Constraints) -> Option<u64> {
    constraints
        .overhead_bound()
        .map(|f_max| (f_max * dag.vanilla_macs as f64).floor() as u64)
}

/// The uniform feasibility filter: RAM bound exactly, overhead bound via
/// the integer MAC budget.
fn admit(
    dag: &FusionDag,
    constraints: &Constraints,
    setting: Option<FusionSetting>,
) -> Option<FusionSetting> {
    let budget = mac_budget(dag, constraints);
    setting.filter(|s| {
        let ram_ok = match constraints.ram_bytes {
            Some(p_max) => s.cost.peak_ram <= p_max,
            None => true,
        };
        let macs_ok = match budget {
            Some(b) => s.cost.macs <= b,
            None => true,
        };
        ram_ok && macs_ok
    })
}

/// A planning strategy: turns a fusion-candidate DAG into a concrete
/// [`FusionSetting`] under a [`Constraints`] set, or `None` when no
/// complete path satisfies the bounds (the paper's "(No Solution)" cells).
///
/// Implementations are interchangeable behind `&dyn PlanStrategy` /
/// `Box<dyn PlanStrategy>`: the [`crate::optimizer::Planner`] builder,
/// [`crate::optimizer::PlanBatch`] jobs, and the report generators all
/// dispatch through this trait.
pub trait PlanStrategy: fmt::Debug + Send + Sync {
    /// Stable identifier recorded in [`crate::optimizer::Plan`] provenance.
    fn name(&self) -> &'static str;

    /// Solve for the strategy's objective under `constraints`.
    fn solve(&self, dag: &FusionDag, constraints: &Constraints) -> Option<FusionSetting>;
}

/// Paper problem P1: minimize peak RAM, subject to the overhead bound
/// (Eq. 8–10 pruning when `F_max` is finite, the minimax path otherwise).
/// A RAM bound, if also present, acts as a feasibility check on the
/// optimum.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct P1;

impl PlanStrategy for P1 {
    fn name(&self) -> &'static str {
        "p1-min-ram"
    }

    fn solve(&self, dag: &FusionDag, constraints: &Constraints) -> Option<FusionSetting> {
        let candidate = match constraints.overhead_bound() {
            None => solve_p1_unconstrained(dag),
            Some(f_max) => solve_p1(dag, f_max),
        };
        admit(dag, constraints, candidate)
    }
}

/// Paper problem P2: minimize MACs, subject to the RAM bound (§6.2
/// edge-filtered shortest path; plain shortest path when unbounded). An
/// overhead bound, if also present, acts as a feasibility check.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct P2;

impl PlanStrategy for P2 {
    fn name(&self) -> &'static str {
        "p2-min-macs"
    }

    fn solve(&self, dag: &FusionDag, constraints: &Constraints) -> Option<FusionSetting> {
        let candidate = match constraints.ram_bytes {
            None => solve_p2_unconstrained(dag),
            Some(p_max) => solve_p2(dag, p_max),
        };
        admit(dag, constraints, candidate)
    }
}

/// The un-fused baseline: every layer its own span (`F = 1`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Vanilla;

impl PlanStrategy for Vanilla {
    fn name(&self) -> &'static str {
        "vanilla"
    }

    fn solve(&self, dag: &FusionDag, constraints: &Constraints) -> Option<FusionSetting> {
        admit(dag, constraints, Some(solve_vanilla(dag)))
    }
}

/// MCUNetV2-style baseline (§2, §6.3): fuse only the best network *head*,
/// run everything after it unfused.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HeadFusion;

impl PlanStrategy for HeadFusion {
    fn name(&self) -> &'static str {
        "mcunetv2-head-fusion"
    }

    fn solve(&self, dag: &FusionDag, constraints: &Constraints) -> Option<FusionSetting> {
        admit(dag, constraints, Some(solve_head_fusion(dag)))
    }
}

/// StreamNet-style baseline: exactly one fusion block, position and depth
/// swept exhaustively; honors the RAM bound during the sweep.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamNet;

impl PlanStrategy for StreamNet {
    fn name(&self) -> &'static str {
        "streamnet-single-block"
    }

    fn solve(&self, dag: &FusionDag, constraints: &Constraints) -> Option<FusionSetting> {
        admit(dag, constraints, solve_streamnet(dag, constraints.ram_bytes))
    }
}

/// Exact exhaustive enumeration (App. D, `O(2^{V-2})`): minimum peak RAM
/// over every complete path satisfying the constraints, ties toward fewer
/// MACs. Tractable on test-sized chains only; the property suite uses it
/// as ground truth for P1/P2.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Exhaustive;

impl PlanStrategy for Exhaustive {
    fn name(&self) -> &'static str {
        "exhaustive"
    }

    fn solve(&self, dag: &FusionDag, constraints: &Constraints) -> Option<FusionSetting> {
        let budget = mac_budget(dag, constraints);
        enumerate_paths(dag)
            .into_iter()
            .map(|p| {
                let c = path_cost(dag, &p);
                (c.peak_ram, c.macs, p)
            })
            .filter(|&(ram, macs, _)| {
                let ram_ok = match constraints.ram_bytes {
                    Some(p_max) => ram <= p_max,
                    None => true,
                };
                let macs_ok = match budget {
                    Some(b) => macs <= b,
                    None => true,
                };
                ram_ok && macs_ok
            })
            .min_by_key(|&(ram, macs, _)| (ram, macs))
            .map(|(_, _, p)| FusionSetting::from_path(dag, p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DagOptions;
    use crate::model::{Activation, Layer, ModelChain, TensorShape};

    fn model() -> ModelChain {
        ModelChain::new(
            "strat",
            TensorShape::new(24, 24, 3),
            vec![
                Layer::conv("c0", 3, 1, 1, 3, 8, Activation::Relu6),
                Layer::conv("c1", 3, 2, 1, 8, 16, Activation::Relu6),
                Layer::conv("c2", 3, 1, 1, 16, 16, Activation::Relu6),
                Layer::global_pool("gp", 16),
                Layer::dense("fc", 16, 10),
            ],
        )
    }

    fn dag() -> FusionDag {
        FusionDag::build(&model(), DagOptions::default())
    }

    /// All strategies, as the trait objects the planner dispatches on.
    fn all() -> Vec<Box<dyn PlanStrategy>> {
        vec![
            Box::new(P1),
            Box::new(P2),
            Box::new(Vanilla),
            Box::new(HeadFusion),
            Box::new(StreamNet),
            Box::new(Exhaustive),
        ]
    }

    #[test]
    fn strategies_match_their_legacy_solvers() {
        let d = dag();
        let none = Constraints::none();
        assert_eq!(
            P1.solve(&d, &none).unwrap().spans,
            solve_p1_unconstrained(&d).unwrap().spans
        );
        assert_eq!(
            P1.solve(&d, &none.with(Constraint::Overhead(1.3)))
                .map(|s| s.cost.peak_ram),
            solve_p1(&d, 1.3).map(|s| s.cost.peak_ram)
        );
        assert_eq!(
            P2.solve(&d, &none.with(Constraint::Ram(4_000)))
                .map(|s| s.cost.macs),
            solve_p2(&d, 4_000).map(|s| s.cost.macs)
        );
        assert_eq!(Vanilla.solve(&d, &none).unwrap().spans, solve_vanilla(&d).spans);
        assert_eq!(
            HeadFusion.solve(&d, &none).unwrap().spans,
            solve_head_fusion(&d).spans
        );
        assert_eq!(
            StreamNet.solve(&d, &none).map(|s| s.spans),
            solve_streamnet(&d, None).map(|s| s.spans)
        );
    }

    #[test]
    fn every_strategy_honors_constraints_through_the_trait() {
        let d = dag();
        let c = Constraints::none()
            .with(Constraint::Ram(6_000))
            .with(Constraint::Overhead(1.5));
        for s in all() {
            if let Some(setting) = s.solve(&d, &c) {
                assert!(c.satisfied_by(&setting), "{} violated constraints", s.name());
            }
        }
    }

    #[test]
    fn infinite_overhead_bound_is_unconstrained() {
        let d = dag();
        let inf = Constraints::none().with(Constraint::Overhead(f64::INFINITY));
        assert_eq!(
            P1.solve(&d, &inf).unwrap().cost.peak_ram,
            P1.solve(&d, &Constraints::none()).unwrap().cost.peak_ram
        );
    }

    #[test]
    fn exhaustive_is_the_floor_for_p1() {
        let d = dag();
        for f_max in [1.1f64, 1.5, f64::INFINITY] {
            let c = Constraints::none().with(Constraint::Overhead(f_max));
            let exact = Exhaustive.solve(&d, &c);
            let fast = P1.solve(&d, &c);
            match (exact, fast) {
                (Some(e), Some(f)) => assert!(f.cost.peak_ram >= e.cost.peak_ram),
                (None, None) => {}
                (e, f) => panic!("feasibility mismatch at F_max={f_max}: {e:?} vs {f:?}"),
            }
        }
    }

    #[test]
    fn infeasible_ram_bound_is_no_solution_for_all() {
        let d = dag();
        let hopeless = Constraints::none().with(Constraint::Ram(8));
        for s in all() {
            assert!(s.solve(&d, &hopeless).is_none(), "{} fabricated a plan", s.name());
        }
    }

    #[test]
    fn constraint_describe_is_stable() {
        assert_eq!(Constraints::none().describe(), "unconstrained");
        assert_eq!(
            Constraints::none().with(Constraint::Ram(64_000)).describe(),
            "P<=64000B"
        );
        let both = Constraints::none()
            .with(Constraint::Ram(16_000))
            .with(Constraint::Overhead(1.3));
        assert_eq!(both.describe(), "P<=16000B,F<=1.3");
    }
}
