//! [`PlanStrategy`]: the interchangeable solver surface of the planner.
//!
//! The paper's P1/P2 optimizers, the latency-constrained walk
//! ([`LatencyAware`], Table 5's axis via [`Constraint::LatencyMs`]), and
//! every §8 baseline (vanilla, the MCUNetV2-style head-fusion heuristic,
//! StreamNet single-block, exact exhaustive enumeration) implement one
//! trait, so Table 1/2/5-style comparisons are a strategy swap instead
//! of a different free function per row:
//!
//! ```no_run
//! use msf_cnn::optimizer::strategy::{HeadFusion, P2};
//! use msf_cnn::optimizer::{Constraint, Planner};
//! use msf_cnn::zoo;
//!
//! let mut planner = Planner::for_model(zoo::quickstart());
//! let msf = planner.plan().unwrap(); // default strategy: P1, min RAM
//! let fits = Planner::for_model(zoo::quickstart())
//!     .constraint(Constraint::Ram(4_000))
//!     .strategy(P2)
//!     .plan()
//!     .unwrap();
//! let baseline = Planner::for_model(zoo::quickstart())
//!     .strategy(HeadFusion)
//!     .plan()
//!     .unwrap();
//! assert!(msf.cost().peak_ram <= baseline.cost().peak_ram);
//! assert!(fits.cost().peak_ram <= 4_000);
//! ```

use std::fmt;

use crate::graph::{enumerate_paths, path_cost, FusionDag};
use crate::mcu::{edge_latency_cycles, path_latency_ms, Board, LatencyModel};

use super::baselines::{solve_head_fusion, solve_streamnet, solve_vanilla};
use super::p1::{solve_p1, solve_p1_unconstrained};
use super::p2::{solve_p2, solve_p2_unconstrained};
use super::FusionSetting;

/// One deployment constraint (the paper's §6 budget axes plus Table 5's
/// latency axis).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Constraint {
    /// Peak RAM budget in bytes (`P ≤ P_max`, problem P2's axis).
    Ram(u64),
    /// Compute-overhead budget (`F = C_S / C_vanilla ≤ F_max`, problem
    /// P1's axis).
    Overhead(f64),
    /// Estimated-latency budget in milliseconds on a concrete board
    /// (Table 5's axis): the [`crate::mcu::estimate_latency_ms`] model,
    /// which prices in §8.3's flash-refetch and per-iteration overheads
    /// that the F factor alone misses.
    LatencyMs {
        /// Target board — its ISA and clock set the latency model.
        board: &'static Board,
        /// Budget in milliseconds.
        budget: f64,
    },
}

/// A latency budget bound to a concrete board (the resolved form of
/// [`Constraint::LatencyMs`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyBound {
    /// Target board — ISA picks the [`LatencyModel`], MHz scales cycles.
    pub board: &'static Board,
    /// Budget in milliseconds.
    pub budget_ms: f64,
}

impl LatencyBound {
    /// The budget converted to CPU cycles on the bound board.
    pub fn budget_cycles(&self) -> f64 {
        self.budget_ms * self.board.mhz as f64 * 1000.0
    }
}

/// The accumulated constraint set a strategy solves under. Every axis is
/// optional; [`Constraints::none`] is the unconstrained problem.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Constraints {
    /// Peak RAM budget in bytes, if any.
    pub ram_bytes: Option<u64>,
    /// Compute-overhead budget `F_max`, if any (an infinite budget is
    /// treated as absent).
    pub overhead: Option<f64>,
    /// Board-bound latency budget, if any (an infinite budget is treated
    /// as absent).
    pub latency: Option<LatencyBound>,
}

impl Constraints {
    /// No constraints: the unconstrained minimization problem.
    pub fn none() -> Self {
        Self::default()
    }

    /// Add `c` to the set (replacing any previous bound on the same
    /// axis). A non-finite overhead bound is normalized to "no bound", so
    /// `Overhead(f64::INFINITY)` round-trips through [`Plan`] JSON
    /// exactly.
    ///
    /// [`Plan`]: crate::optimizer::Plan
    #[must_use]
    pub fn with(mut self, c: Constraint) -> Self {
        match c {
            Constraint::Ram(bytes) => self.ram_bytes = Some(bytes),
            Constraint::Overhead(f_max) => {
                self.overhead = Some(f_max).filter(|f| f.is_finite());
            }
            Constraint::LatencyMs { board, budget } => {
                self.latency = Some(LatencyBound { board, budget_ms: budget })
                    .filter(|l| l.budget_ms.is_finite());
            }
        }
        self
    }

    /// The effective overhead bound (`None` for absent *or* infinite).
    fn overhead_bound(&self) -> Option<f64> {
        self.overhead.filter(|f| f.is_finite())
    }

    /// The effective latency bound (`None` for absent *or* infinite).
    pub fn latency_bound(&self) -> Option<LatencyBound> {
        self.latency.filter(|l| l.budget_ms.is_finite())
    }

    /// Whether `setting` satisfies the RAM and overhead bounds (overhead
    /// within float tolerance, RAM exactly). The latency axis needs the
    /// originating DAG — see [`Constraints::satisfied_on`].
    pub fn satisfied_by(&self, setting: &FusionSetting) -> bool {
        if let Some(p_max) = self.ram_bytes {
            if setting.cost.peak_ram > p_max {
                return false;
            }
        }
        if let Some(f_max) = self.overhead_bound() {
            if setting.cost.overhead > f_max + 1e-9 {
                return false;
            }
        }
        true
    }

    /// [`Constraints::satisfied_by`] plus the latency axis, evaluated
    /// against the DAG the setting was solved on.
    pub fn satisfied_on(&self, dag: &FusionDag, setting: &FusionSetting) -> bool {
        if !self.satisfied_by(setting) {
            return false;
        }
        match self.latency_bound() {
            None => true,
            Some(l) => {
                path_latency_ms(dag, &setting.path, l.board) <= l.budget_ms * (1.0 + 1e-9) + 1e-9
            }
        }
    }

    /// Human-readable form for provenance / describe lines.
    pub fn describe(&self) -> String {
        let mut parts = Vec::new();
        if let Some(p) = self.ram_bytes {
            parts.push(format!("P<={p}B"));
        }
        if let Some(f) = self.overhead_bound() {
            parts.push(format!("F<={f}"));
        }
        if let Some(l) = self.latency_bound() {
            parts.push(format!("lat<={}ms@{}", l.budget_ms, l.board.name));
        }
        if parts.is_empty() {
            "unconstrained".into()
        } else {
            parts.join(",")
        }
    }
}

/// The integer MAC budget an overhead bound induces — exactly the Eq. 8
/// `floor(F_max · C_vanilla)` rule the P1 solver prunes with, so every
/// strategy enforces the overhead axis bit-identically.
fn mac_budget(dag: &FusionDag, constraints: &Constraints) -> Option<u64> {
    constraints
        .overhead_bound()
        .map(|f_max| (f_max * dag.vanilla_macs as f64).floor() as u64)
}

/// The uniform feasibility filter: RAM bound exactly, overhead bound via
/// the integer MAC budget, latency bound via the per-edge path sum — so
/// *every* strategy (including the fixed-setting baselines) honors a
/// joint constraint set identically.
fn admit(
    dag: &FusionDag,
    constraints: &Constraints,
    setting: Option<FusionSetting>,
) -> Option<FusionSetting> {
    let budget = mac_budget(dag, constraints);
    setting.filter(|s| {
        let ram_ok = match constraints.ram_bytes {
            Some(p_max) => s.cost.peak_ram <= p_max,
            None => true,
        };
        let macs_ok = match budget {
            Some(b) => s.cost.macs <= b,
            None => true,
        };
        let latency_ok = match constraints.latency_bound() {
            Some(l) => {
                path_latency_ms(dag, &s.path, l.board) <= l.budget_ms * (1.0 + 1e-9) + 1e-9
            }
            None => true,
        };
        ram_ok && macs_ok && latency_ok
    })
}

/// A planning strategy: turns a fusion-candidate DAG into a concrete
/// [`FusionSetting`] under a [`Constraints`] set, or `None` when no
/// complete path satisfies the bounds (the paper's "(No Solution)" cells).
///
/// Implementations are interchangeable behind `&dyn PlanStrategy` /
/// `Box<dyn PlanStrategy>`: the [`crate::optimizer::Planner`] builder,
/// [`crate::optimizer::PlanBatch`] jobs, and the report generators all
/// dispatch through this trait.
pub trait PlanStrategy: fmt::Debug + Send + Sync {
    /// Stable identifier recorded in [`crate::optimizer::Plan`] provenance.
    fn name(&self) -> &'static str;

    /// Solve for the strategy's objective under `constraints`.
    fn solve(&self, dag: &FusionDag, constraints: &Constraints) -> Option<FusionSetting>;
}

/// Paper problem P1: minimize peak RAM, subject to the overhead bound
/// (Eq. 8–10 pruning when `F_max` is finite, the minimax path otherwise).
/// A RAM bound, if also present, acts as a feasibility check on the
/// optimum.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct P1;

impl PlanStrategy for P1 {
    fn name(&self) -> &'static str {
        "p1-min-ram"
    }

    fn solve(&self, dag: &FusionDag, constraints: &Constraints) -> Option<FusionSetting> {
        let candidate = match constraints.overhead_bound() {
            None => solve_p1_unconstrained(dag),
            Some(f_max) => solve_p1(dag, f_max),
        };
        admit(dag, constraints, candidate)
    }
}

/// Paper problem P2: minimize MACs, subject to the RAM bound (§6.2
/// edge-filtered shortest path; plain shortest path when unbounded). An
/// overhead bound, if also present, acts as a feasibility check.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct P2;

impl PlanStrategy for P2 {
    fn name(&self) -> &'static str {
        "p2-min-macs"
    }

    fn solve(&self, dag: &FusionDag, constraints: &Constraints) -> Option<FusionSetting> {
        let candidate = match constraints.ram_bytes {
            None => solve_p2_unconstrained(dag),
            Some(p_max) => solve_p2(dag, p_max),
        };
        admit(dag, constraints, candidate)
    }
}

/// The un-fused baseline: every layer its own span (`F = 1`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Vanilla;

impl PlanStrategy for Vanilla {
    fn name(&self) -> &'static str {
        "vanilla"
    }

    fn solve(&self, dag: &FusionDag, constraints: &Constraints) -> Option<FusionSetting> {
        admit(dag, constraints, Some(solve_vanilla(dag)))
    }
}

/// MCUNetV2-style baseline (§2, §6.3): fuse only the best network *head*,
/// run everything after it unfused.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HeadFusion;

impl PlanStrategy for HeadFusion {
    fn name(&self) -> &'static str {
        "mcunetv2-head-fusion"
    }

    fn solve(&self, dag: &FusionDag, constraints: &Constraints) -> Option<FusionSetting> {
        admit(dag, constraints, Some(solve_head_fusion(dag)))
    }
}

/// StreamNet-style baseline: exactly one fusion block, position and depth
/// swept exhaustively; honors the RAM bound during the sweep.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamNet;

impl PlanStrategy for StreamNet {
    fn name(&self) -> &'static str {
        "streamnet-single-block"
    }

    fn solve(&self, dag: &FusionDag, constraints: &Constraints) -> Option<FusionSetting> {
        admit(dag, constraints, solve_streamnet(dag, constraints.ram_bytes))
    }
}

/// Latency-constrained planning (Table 5's axis): minimize peak RAM
/// subject to the board-bound latency budget of
/// [`Constraint::LatencyMs`], walking the fusion DAG with a bicriteria
/// (latency, prefix-max-RAM) label search that prunes every partial
/// setting whose estimated latency already exceeds the budget. RAM and
/// MAC budgets, when also present, prune during the same walk (both are
/// monotone along a path), so joint Table 5 budgets are solved exactly.
///
/// Without a latency bound the search degenerates to the minimax-RAM
/// path, i.e. the [`P1`] objective.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LatencyAware;

/// One partial setting of the bicriteria walk, stored in a parent-pointer
/// arena (paths are only materialized for the winning label).
#[derive(Clone, Copy)]
struct LatencyLabel {
    /// Estimated latency cycles of the prefix.
    cycles: f64,
    /// Max edge RAM along the prefix (the prefix's peak).
    peak_ram: u64,
    /// Total MACs of the prefix (tiebreak + overhead-budget pruning).
    macs: u64,
    /// Edge that produced this label (`usize::MAX` for the source label).
    edge: usize,
    /// Arena index of the predecessor label (`usize::MAX` for the source).
    parent: usize,
}

impl PlanStrategy for LatencyAware {
    fn name(&self) -> &'static str {
        "latency-aware-min-ram"
    }

    fn solve(&self, dag: &FusionDag, constraints: &Constraints) -> Option<FusionSetting> {
        let bound = constraints.latency_bound();
        let lm = bound.map(|l| LatencyModel::for_isa(l.board.isa));
        let budget_cycles = bound.map(|l| l.budget_cycles());
        let mac_cap = mac_budget(dag, constraints);

        // Keep each node's labels as a Pareto front over (cycles,
        // prefix-max RAM) — plus MACs when an overhead budget is active,
        // since a pricier-but-leaner-on-MACs prefix may be the only one
        // whose extensions survive the MAC cap. All three quantities are
        // monotone along a path, so dominated labels can never recover.
        let mac_active = mac_cap.is_some();
        let mut arena: Vec<LatencyLabel> = Vec::new();
        let prune = move |front: &mut Vec<usize>, arena: &[LatencyLabel]| {
            front.sort_by(|&x, &y| {
                let (a, b) = (&arena[x], &arena[y]);
                a.cycles
                    .partial_cmp(&b.cycles)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.peak_ram.cmp(&b.peak_ram))
                    .then(a.macs.cmp(&b.macs))
            });
            let mut kept: Vec<usize> = Vec::new();
            // Sorted by cycles asc: every kept label is no slower, so
            // dominance reduces to the remaining axes. Without a MAC cap
            // that is a strictly-decreasing-RAM skyline (O(k)); with one,
            // a label survives only if it improves on RAM or MACs.
            if mac_active {
                for i in std::mem::take(front) {
                    let cand = &arena[i];
                    let dominated = kept.iter().any(|&k| {
                        let held = &arena[k];
                        held.peak_ram <= cand.peak_ram && held.macs <= cand.macs
                    });
                    if !dominated {
                        kept.push(i);
                    }
                }
            } else {
                let mut min_ram = u64::MAX;
                for i in std::mem::take(front) {
                    if arena[i].peak_ram < min_ram {
                        min_ram = arena[i].peak_ram;
                        kept.push(i);
                    }
                }
            }
            *front = kept;
        };

        let sink = dag.n_nodes - 1;
        let mut fronts: Vec<Vec<usize>> = vec![Vec::new(); dag.n_nodes];
        arena.push(LatencyLabel {
            cycles: 0.0,
            peak_ram: 0,
            macs: 0,
            edge: usize::MAX,
            parent: usize::MAX,
        });
        fronts[0].push(0);
        for v in 0..sink {
            let mut front = std::mem::take(&mut fronts[v]);
            if front.is_empty() {
                continue;
            }
            prune(&mut front, &arena);
            for &li in &front {
                for &e in &dag.out[v] {
                    let edge = &dag.edges[e];
                    let label = arena[li];
                    let cycles = label.cycles
                        + lm.as_ref().map_or(0.0, |m| edge_latency_cycles(edge, m));
                    if let Some(cap) = budget_cycles {
                        // The same epsilon `admit` verifies with, in
                        // cycles, so the walk never prunes a setting the
                        // filter would admit (or vice versa).
                        if cycles > cap * (1.0 + 1e-9) + 1e-9 {
                            continue;
                        }
                    }
                    let peak_ram = label.peak_ram.max(edge.cost.ram_bytes);
                    if constraints.ram_bytes.is_some_and(|p_max| peak_ram > p_max) {
                        continue;
                    }
                    let macs = label.macs + edge.cost.macs;
                    if mac_cap.is_some_and(|cap| macs > cap) {
                        continue;
                    }
                    arena.push(LatencyLabel { cycles, peak_ram, macs, edge: e, parent: li });
                    fronts[edge.b].push(arena.len() - 1);
                }
            }
        }

        let mut sink_front = std::mem::take(&mut fronts[sink]);
        prune(&mut sink_front, &arena);
        let best = sink_front.into_iter().min_by(|&x, &y| {
            let (a, b) = (&arena[x], &arena[y]);
            (a.peak_ram, a.macs)
                .cmp(&(b.peak_ram, b.macs))
                .then(a.cycles.partial_cmp(&b.cycles).unwrap_or(std::cmp::Ordering::Equal))
        })?;

        // Materialize the winning path by walking the parent chain.
        let mut path = Vec::new();
        let mut at = best;
        while arena[at].edge != usize::MAX {
            path.push(arena[at].edge);
            at = arena[at].parent;
        }
        path.reverse();
        admit(dag, constraints, Some(FusionSetting::from_path(dag, path)))
    }
}

/// Exact exhaustive enumeration (App. D, `O(2^{V-2})`): minimum peak RAM
/// over every complete path satisfying the constraints, ties toward fewer
/// MACs. Tractable on test-sized chains only; the property suite uses it
/// as ground truth for P1/P2.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Exhaustive;

impl PlanStrategy for Exhaustive {
    fn name(&self) -> &'static str {
        "exhaustive"
    }

    fn solve(&self, dag: &FusionDag, constraints: &Constraints) -> Option<FusionSetting> {
        let budget = mac_budget(dag, constraints);
        let latency = constraints.latency_bound();
        enumerate_paths(dag)
            .into_iter()
            .map(|p| {
                let c = path_cost(dag, &p);
                (c.peak_ram, c.macs, p)
            })
            .filter(|(ram, macs, p)| {
                let ram_ok = match constraints.ram_bytes {
                    Some(p_max) => *ram <= p_max,
                    None => true,
                };
                let macs_ok = match budget {
                    Some(b) => *macs <= b,
                    None => true,
                };
                let latency_ok = match latency {
                    Some(l) => {
                        path_latency_ms(dag, p, l.board) <= l.budget_ms * (1.0 + 1e-9) + 1e-9
                    }
                    None => true,
                };
                ram_ok && macs_ok && latency_ok
            })
            .min_by_key(|&(ram, macs, _)| (ram, macs))
            .map(|(_, _, p)| FusionSetting::from_path(dag, p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DagOptions;
    use crate::model::{Activation, Layer, ModelChain, TensorShape};

    fn model() -> ModelChain {
        ModelChain::new(
            "strat",
            TensorShape::new(24, 24, 3),
            vec![
                Layer::conv("c0", 3, 1, 1, 3, 8, Activation::Relu6),
                Layer::conv("c1", 3, 2, 1, 8, 16, Activation::Relu6),
                Layer::conv("c2", 3, 1, 1, 16, 16, Activation::Relu6),
                Layer::global_pool("gp", 16),
                Layer::dense("fc", 16, 10),
            ],
        )
    }

    fn dag() -> FusionDag {
        FusionDag::build(&model(), DagOptions::default())
    }

    /// All strategies, as the trait objects the planner dispatches on.
    fn all() -> Vec<Box<dyn PlanStrategy>> {
        vec![
            Box::new(P1),
            Box::new(P2),
            Box::new(Vanilla),
            Box::new(HeadFusion),
            Box::new(StreamNet),
            Box::new(LatencyAware),
            Box::new(Exhaustive),
        ]
    }

    #[test]
    fn strategies_match_their_legacy_solvers() {
        let d = dag();
        let none = Constraints::none();
        assert_eq!(
            P1.solve(&d, &none).unwrap().spans,
            solve_p1_unconstrained(&d).unwrap().spans
        );
        assert_eq!(
            P1.solve(&d, &none.with(Constraint::Overhead(1.3)))
                .map(|s| s.cost.peak_ram),
            solve_p1(&d, 1.3).map(|s| s.cost.peak_ram)
        );
        assert_eq!(
            P2.solve(&d, &none.with(Constraint::Ram(4_000)))
                .map(|s| s.cost.macs),
            solve_p2(&d, 4_000).map(|s| s.cost.macs)
        );
        assert_eq!(Vanilla.solve(&d, &none).unwrap().spans, solve_vanilla(&d).spans);
        assert_eq!(
            HeadFusion.solve(&d, &none).unwrap().spans,
            solve_head_fusion(&d).spans
        );
        assert_eq!(
            StreamNet.solve(&d, &none).map(|s| s.spans),
            solve_streamnet(&d, None).map(|s| s.spans)
        );
    }

    #[test]
    fn every_strategy_honors_constraints_through_the_trait() {
        let d = dag();
        let board = crate::mcu::board_by_name("nucleo-f767zi").unwrap();
        let c = Constraints::none()
            .with(Constraint::Ram(6_000))
            .with(Constraint::Overhead(1.5))
            .with(Constraint::LatencyMs { board, budget: 1e6 });
        for s in all() {
            if let Some(setting) = s.solve(&d, &c) {
                assert!(c.satisfied_on(&d, &setting), "{} violated constraints", s.name());
            }
        }
    }

    #[test]
    fn latency_aware_unconstrained_matches_p1_min_ram() {
        let d = dag();
        let none = Constraints::none();
        assert_eq!(
            LatencyAware.solve(&d, &none).unwrap().cost.peak_ram,
            P1.solve(&d, &none).unwrap().cost.peak_ram
        );
    }

    #[test]
    fn latency_budget_prunes_the_walk_and_holds_on_the_result() {
        let d = dag();
        let board = crate::mcu::board_by_name("nucleo-f767zi").unwrap();
        let vanilla = Vanilla.solve(&d, &Constraints::none()).unwrap();
        let vanilla_ms = crate::mcu::path_latency_ms(&d, &vanilla.path, board);
        let free = LatencyAware.solve(&d, &Constraints::none()).unwrap();
        let free_ms = crate::mcu::path_latency_ms(&d, &free.path, board);
        assert!(free_ms > vanilla_ms, "fusion must cost latency here");

        // A budget between the two forces a trade-off: still feasible
        // (vanilla qualifies), still minimal among feasible settings.
        let budget = (vanilla_ms + free_ms) / 2.0;
        let c = Constraints::none().with(Constraint::LatencyMs { board, budget });
        let s = LatencyAware.solve(&d, &c).unwrap();
        assert!(c.satisfied_on(&d, &s));
        assert!(s.cost.peak_ram <= vanilla.cost.peak_ram);
        assert!(s.cost.peak_ram >= free.cost.peak_ram);

        // And it is exactly the exhaustive optimum under the same budget.
        let exact = Exhaustive.solve(&d, &c).unwrap();
        assert_eq!(s.cost.peak_ram, exact.cost.peak_ram);

        // A zero budget is infeasible for every complete path.
        let hopeless = Constraints::none().with(Constraint::LatencyMs { board, budget: 0.0 });
        assert!(LatencyAware.solve(&d, &hopeless).is_none());

        // An infinite budget is normalized to "no bound".
        let inf =
            Constraints::none().with(Constraint::LatencyMs { board, budget: f64::INFINITY });
        assert_eq!(inf.latency_bound(), None);
    }

    #[test]
    fn infinite_overhead_bound_is_unconstrained() {
        let d = dag();
        let inf = Constraints::none().with(Constraint::Overhead(f64::INFINITY));
        assert_eq!(
            P1.solve(&d, &inf).unwrap().cost.peak_ram,
            P1.solve(&d, &Constraints::none()).unwrap().cost.peak_ram
        );
    }

    #[test]
    fn exhaustive_is_the_floor_for_p1() {
        let d = dag();
        for f_max in [1.1f64, 1.5, f64::INFINITY] {
            let c = Constraints::none().with(Constraint::Overhead(f_max));
            let exact = Exhaustive.solve(&d, &c);
            let fast = P1.solve(&d, &c);
            match (exact, fast) {
                (Some(e), Some(f)) => assert!(f.cost.peak_ram >= e.cost.peak_ram),
                (None, None) => {}
                (e, f) => panic!("feasibility mismatch at F_max={f_max}: {e:?} vs {f:?}"),
            }
        }
    }

    #[test]
    fn infeasible_ram_bound_is_no_solution_for_all() {
        let d = dag();
        let hopeless = Constraints::none().with(Constraint::Ram(8));
        for s in all() {
            assert!(s.solve(&d, &hopeless).is_none(), "{} fabricated a plan", s.name());
        }
    }

    #[test]
    fn constraint_describe_is_stable() {
        assert_eq!(Constraints::none().describe(), "unconstrained");
        assert_eq!(
            Constraints::none().with(Constraint::Ram(64_000)).describe(),
            "P<=64000B"
        );
        let both = Constraints::none()
            .with(Constraint::Ram(16_000))
            .with(Constraint::Overhead(1.3));
        assert_eq!(both.describe(), "P<=16000B,F<=1.3");
    }
}
