//! Problem P1: minimize peak RAM subject to a compute-cost limit (§6.1).
//!
//! The entry point is [`crate::optimizer::strategy::P1`] driven through a
//! [`crate::optimizer::Planner`].

use crate::graph::{min_sum_path, minimax_path, FusionDag};

use super::{FusionSetting, OptResult};

/// Unconstrained P1 (`F_max = ∞`): the minimax-path solution.
pub(crate) fn solve_p1_unconstrained(dag: &FusionDag) -> OptResult {
    minimax_path(dag).map(|p| FusionSetting::from_path(dag, p))
}

/// Constrained P1 via the paper's pruning strategy (Eq. 8–10):
///
/// 1. `G_0 = G`; candidate `S_i` = min-MAC path of `G_i`;
/// 2. `G_{i+1}` = `G_i` minus all edges of maximal RAM;
/// 3. stop when `v_n` becomes unreachable;
/// 4. among candidates with `F ≤ F_max`, return the one with the smallest
///    peak RAM (ties broken toward fewer MACs).
///
/// Worst case O(V³): up to E = O(V²) elimination rounds × O(E) DP.
pub(crate) fn solve_p1(dag: &FusionDag, f_max: f64) -> OptResult {
    let mac_budget = (f_max * dag.vanilla_macs as f64).floor() as u64;
    let mut g = dag.clone();
    let mut best: Option<FusionSetting> = None;

    loop {
        match min_sum_path(&g) {
            None => break, // target unreachable: all candidates collected
            Some(path) => {
                let s = FusionSetting::from_path(dag, path);
                if s.cost.macs <= mac_budget {
                    let better = match &best {
                        None => true,
                        Some(b) => {
                            (s.cost.peak_ram, s.cost.macs) < (b.cost.peak_ram, b.cost.macs)
                        }
                    };
                    if better {
                        best = Some(s);
                    }
                }
                // Eq. 9: drop every edge at the current max RAM.
                let worst = g.max_ram_edges();
                if worst.is_empty() {
                    break;
                }
                g = g.without_edges(&worst);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DagOptions;
    use crate::model::{Activation, Layer, ModelChain, TensorShape};

    fn model() -> ModelChain {
        ModelChain::new(
            "p1",
            TensorShape::new(32, 32, 3),
            vec![
                Layer::conv("c0", 3, 1, 1, 3, 8, Activation::Relu6),
                Layer::conv("c1", 3, 2, 1, 8, 16, Activation::Relu6),
                Layer::conv("c2", 3, 1, 1, 16, 16, Activation::Relu6),
                Layer::conv("c3", 3, 2, 1, 16, 32, Activation::Relu6),
                Layer::global_pool("gp", 32),
                Layer::dense("fc", 32, 10),
            ],
        )
    }

    #[test]
    fn unconstrained_beats_vanilla() {
        let m = model();
        let dag = FusionDag::build(&m, DagOptions::default());
        let s = solve_p1_unconstrained(&dag).unwrap();
        assert!(s.cost.peak_ram < m.vanilla_peak_ram());
        assert!(s.num_fused_blocks() >= 1);
    }

    #[test]
    fn constraint_is_respected() {
        let m = model();
        let dag = FusionDag::build(&m, DagOptions::default());
        for f_max in [1.05, 1.2, 1.5, 2.0] {
            if let Some(s) = solve_p1(&dag, f_max) {
                assert!(
                    s.cost.overhead <= f_max + 1e-9,
                    "F={} > F_max={f_max}",
                    s.cost.overhead
                );
            }
        }
    }

    #[test]
    fn looser_budget_never_hurts() {
        let m = model();
        let dag = FusionDag::build(&m, DagOptions::default());
        let tight = solve_p1(&dag, 1.1).map(|s| s.cost.peak_ram);
        let loose = solve_p1(&dag, 2.0).map(|s| s.cost.peak_ram);
        if let (Some(t), Some(l)) = (tight, loose) {
            assert!(l <= t, "loose {l} > tight {t}");
        }
    }

    #[test]
    fn f_max_one_returns_vanilla_or_free_fusion() {
        let m = model();
        let dag = FusionDag::build(&m, DagOptions::default());
        let s = solve_p1(&dag, 1.0).expect("vanilla path always satisfies F=1");
        assert!(s.cost.overhead <= 1.0 + 1e-9);
        // RAM can still beat vanilla via zero-overhead fusion (iterative tail).
        assert!(s.cost.peak_ram <= m.vanilla_peak_ram());
    }

    #[test]
    fn huge_budget_matches_unconstrained() {
        let dag = FusionDag::build(&model(), DagOptions::default());
        let c = solve_p1(&dag, 1e9).unwrap();
        let u = solve_p1_unconstrained(&dag).unwrap();
        assert_eq!(c.cost.peak_ram, u.cost.peak_ram);
    }
}
