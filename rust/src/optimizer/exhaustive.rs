//! Exact exhaustive optimizers — `O(2^{V-2})` enumeration (App. D).
//!
//! Used only in tests and ablation benches: the property suite asserts the
//! polynomial-time P1/P2 solvers match these on every small random model.

use crate::graph::{enumerate_paths, path_cost, FusionDag};

use super::{FusionSetting, OptResult};

/// Exact P1: enumerate all complete paths, keep those with `F ≤ f_max`,
/// return min peak-RAM (ties toward fewer MACs).
pub fn exhaustive_p1(dag: &FusionDag, f_max: f64) -> OptResult {
    let budget = (f_max * dag.vanilla_macs as f64).floor() as u64;
    enumerate_paths(dag)
        .into_iter()
        .map(|p| {
            let c = path_cost(dag, &p);
            (c.peak_ram, c.macs, p)
        })
        .filter(|&(_, macs, _)| macs <= budget)
        .min_by_key(|&(ram, macs, _)| (ram, macs))
        .map(|(_, _, p)| FusionSetting::from_path(dag, p))
}

/// Exact P2: enumerate, keep `P ≤ p_max`, return min MACs (ties toward
/// lower RAM).
pub fn exhaustive_p2(dag: &FusionDag, p_max_bytes: u64) -> OptResult {
    enumerate_paths(dag)
        .into_iter()
        .map(|p| {
            let c = path_cost(dag, &p);
            (c.peak_ram, c.macs, p)
        })
        .filter(|&(ram, _, _)| ram <= p_max_bytes)
        .min_by_key(|&(ram, macs, _)| (macs, ram))
        .map(|(_, _, p)| FusionSetting::from_path(dag, p))
}

#[cfg(test)]
mod tests {
    use super::super::p1::solve_p1;
    use super::super::p2::solve_p2;
    use super::*;
    use crate::graph::DagOptions;
    use crate::model::{Activation, Layer, ModelChain, TensorShape};

    fn model(n: usize) -> ModelChain {
        let mut layers = Vec::new();
        let mut c = 3u32;
        for i in 0..n {
            let (s, co) = if i % 2 == 1 { (2, c * 2) } else { (1, c) };
            layers.push(Layer::conv(format!("c{i}"), 3, s, 1, c, co, Activation::Relu6));
            c = co;
        }
        ModelChain::new("x", TensorShape::new(40, 40, 3), layers)
    }

    #[test]
    fn p2_matches_exhaustive() {
        let m = model(6);
        let dag = FusionDag::build(&m, DagOptions::default());
        for p_max in [2_000u64, 8_000, 20_000, 100_000] {
            let fast = solve_p2(&dag, p_max);
            let slow = exhaustive_p2(&dag, p_max);
            match (fast, slow) {
                (None, None) => {}
                (Some(f), Some(s)) => {
                    assert_eq!(f.cost.macs, s.cost.macs, "P_max={p_max}");
                }
                (f, s) => panic!("feasibility mismatch at P_max={p_max}: {f:?} vs {s:?}"),
            }
        }
    }

    #[test]
    fn p1_feasible_and_bounded_by_exhaustive() {
        // The paper's pruning heuristic is exact on the RAM axis in our
        // tests; at minimum it must stay feasible and within the candidate
        // set's envelope.
        let m = model(6);
        let dag = FusionDag::build(&m, DagOptions::default());
        for f_max in [1.05f64, 1.2, 1.5, 3.0] {
            let fast = solve_p1(&dag, f_max);
            let slow = exhaustive_p1(&dag, f_max);
            match (&fast, &slow) {
                (None, None) => {}
                (Some(f), Some(s)) => {
                    assert!(f.cost.overhead <= f_max + 1e-9);
                    assert!(
                        f.cost.peak_ram >= s.cost.peak_ram,
                        "pruned search cannot beat the exact optimum"
                    );
                }
                (None, Some(_)) => {
                    panic!("pruned P1 missed a feasible solution at F_max={f_max}")
                }
                (Some(_), None) => panic!("pruned P1 fabricated a solution at F_max={f_max}"),
            }
        }
    }
}
