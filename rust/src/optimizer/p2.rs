//! Problem P2: minimize compute cost subject to a RAM limit (§6.2).
//!
//! The entry point is [`crate::optimizer::strategy::P2`] driven through a
//! [`crate::optimizer::Planner`].

use crate::graph::{min_sum_path, FusionDag};

use super::{FusionSetting, OptResult};

/// Unconstrained P2 (`P_max = ∞`): plain shortest (min-MAC) path.
pub(crate) fn solve_p2_unconstrained(dag: &FusionDag) -> OptResult {
    min_sum_path(dag).map(|p| FusionSetting::from_path(dag, p))
}

/// Constrained P2: eliminate every edge whose RAM exceeds `p_max_bytes`
/// (so all remaining paths automatically satisfy the limit — §6.2), then
/// take the shortest path. `None` ⇒ the paper's "(No Solution)".
pub(crate) fn solve_p2(dag: &FusionDag, p_max_bytes: u64) -> OptResult {
    let over: Vec<usize> = (0..dag.edges.len())
        .filter(|&e| dag.edges[e].cost.ram_bytes > p_max_bytes)
        .collect();
    let g = dag.without_edges(&over);
    min_sum_path(&g).map(|p| FusionSetting::from_path(dag, p))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DagOptions;
    use crate::model::{Activation, Layer, ModelChain, TensorShape};

    fn model() -> ModelChain {
        ModelChain::new(
            "p2",
            TensorShape::new(32, 32, 3),
            vec![
                Layer::conv("c0", 3, 1, 1, 3, 8, Activation::Relu6),
                Layer::conv("c1", 3, 2, 1, 8, 16, Activation::Relu6),
                Layer::conv("c2", 3, 1, 1, 16, 16, Activation::Relu6),
                Layer::conv("c3", 3, 2, 1, 16, 32, Activation::Relu6),
                Layer::global_pool("gp", 32),
                Layer::dense("fc", 32, 10),
            ],
        )
    }

    #[test]
    fn unconstrained_is_vanilla_or_better() {
        let m = model();
        let dag = FusionDag::build(&m, DagOptions::default());
        let s = solve_p2_unconstrained(&dag).unwrap();
        assert!(s.cost.macs <= m.total_macs());
    }

    #[test]
    fn ram_limit_respected() {
        let m = model();
        let dag = FusionDag::build(&m, DagOptions::default());
        for p_max in [4_000u64, 8_000, 16_000, 64_000] {
            if let Some(s) = solve_p2(&dag, p_max) {
                assert!(s.cost.peak_ram <= p_max);
            }
        }
    }

    #[test]
    fn infeasible_limit_returns_none() {
        let dag = FusionDag::build(&model(), DagOptions::default());
        assert!(solve_p2(&dag, 16).is_none()); // 16 bytes: hopeless
    }

    #[test]
    fn tighter_limit_costs_more_macs() {
        let m = model();
        let dag = FusionDag::build(&m, DagOptions::default());
        let u = solve_p2_unconstrained(&dag).unwrap();
        // Force below the unconstrained solution's RAM: more recompute.
        if let Some(t) = solve_p2(&dag, u.cost.peak_ram / 2) {
            assert!(t.cost.macs >= u.cost.macs);
            assert!(t.cost.peak_ram <= u.cost.peak_ram / 2);
        }
    }

    #[test]
    fn duality_with_p1() {
        // P2's solution at P_max = P1(F_max=inf).peak_ram must exist and
        // cost no more MACs than the P1 solution (it optimizes MACs there).
        let dag = FusionDag::build(&model(), DagOptions::default());
        let p1 = super::super::p1::solve_p1_unconstrained(&dag).unwrap();
        let p2 = solve_p2(&dag, p1.cost.peak_ram).unwrap();
        assert!(p2.cost.macs <= p1.cost.macs);
        assert!(p2.cost.peak_ram <= p1.cost.peak_ram);
    }
}
