//! Comparator settings: vanilla, the MCUNetV2-style head-fusion heuristic,
//! and a StreamNet-style single-block brute force (§8's baselines).
//!
//! The entry points are the [`crate::optimizer::strategy`]
//! implementations ([`strategy::Vanilla`], [`strategy::HeadFusion`],
//! [`strategy::StreamNet`]) driven through a
//! [`crate::optimizer::Planner`].
//!
//! [`strategy::Vanilla`]: crate::optimizer::strategy::Vanilla
//! [`strategy::HeadFusion`]: crate::optimizer::strategy::HeadFusion
//! [`strategy::StreamNet`]: crate::optimizer::strategy::StreamNet

use crate::graph::FusionDag;

use super::{FusionSetting, OptResult};

/// The un-fused model: every edge a single layer.
pub(crate) fn solve_vanilla(dag: &FusionDag) -> FusionSetting {
    let mut path = Vec::new();
    for v in 0..dag.n_nodes - 1 {
        let e = dag.out[v]
            .iter()
            .copied()
            .find(|&e| dag.edges[e].b == v + 1)
            .expect("single-layer edge always present");
        path.push(e);
    }
    FusionSetting::from_path(dag, path)
}

/// MCUNetV2's heuristic (§2, §6.3): fuse only the *head* of the network —
/// pick the single prefix block `[0, b)` that minimizes the setting's peak
/// RAM, executing every later layer unfused. Simple, but blind to interior
/// RAM peaks, which is exactly where msf-CNN finds better solutions.
pub(crate) fn solve_head_fusion(dag: &FusionDag) -> FusionSetting {
    let mut best: Option<FusionSetting> = None;
    for &e in &dag.out[0] {
        let b = dag.edges[e].b;
        let mut path = vec![e];
        let mut v = b;
        while v < dag.n_nodes - 1 {
            let single = dag.out[v]
                .iter()
                .copied()
                .find(|&se| dag.edges[se].b == v + 1)
                .expect("single-layer edge always present");
            path.push(single);
            v += 1;
        }
        let s = FusionSetting::from_path(dag, path);
        let better = match &best {
            None => true,
            Some(cur) => (s.cost.peak_ram, s.cost.macs) < (cur.cost.peak_ram, cur.cost.macs),
        };
        if better {
            best = Some(s);
        }
    }
    best.expect("at least the vanilla prefix exists")
}

/// StreamNet-style brute force: exactly **one** fusion block anywhere in
/// the chain (2-D tensor cache ≈ our H-cache), position and depth chosen
/// by exhaustive sweep to minimize peak RAM; ties toward fewer MACs.
/// Optionally capped by a RAM limit (`None` ⇒ unconstrained minimum).
pub(crate) fn solve_streamnet(dag: &FusionDag, p_max_bytes: Option<u64>) -> OptResult {
    let mut best: Option<FusionSetting> = None;
    // Candidate blocks: every fused edge; plus the pure vanilla path.
    let mut candidates: Vec<Option<usize>> = dag
        .edges
        .iter()
        .enumerate()
        .filter(|(_, e)| e.b - e.a > 1)
        .map(|(i, _)| Some(i))
        .collect();
    candidates.push(None); // vanilla

    for cand in candidates {
        let mut path = Vec::new();
        let mut v = 0usize;
        while v < dag.n_nodes - 1 {
            let next = match cand {
                Some(fe) if dag.edges[fe].a == v => fe,
                _ => dag.out[v]
                    .iter()
                    .copied()
                    .find(|&se| dag.edges[se].b == v + 1)
                    .expect("single-layer edge always present"),
            };
            path.push(next);
            v = dag.edges[next].b;
        }
        let s = FusionSetting::from_path(dag, path);
        if let Some(pm) = p_max_bytes {
            if s.cost.peak_ram > pm {
                continue;
            }
        }
        let better = match &best {
            None => true,
            Some(cur) => (s.cost.peak_ram, s.cost.macs) < (cur.cost.peak_ram, cur.cost.macs),
        };
        if better {
            best = Some(s);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::super::p1::solve_p1_unconstrained;
    use super::*;
    use crate::graph::DagOptions;
    use crate::model::{Activation, Layer, ModelChain, TensorShape};

    fn model() -> ModelChain {
        ModelChain::new(
            "b",
            TensorShape::new(32, 32, 3),
            vec![
                Layer::conv("c0", 3, 1, 1, 3, 8, Activation::Relu6),
                Layer::conv("c1", 3, 2, 1, 8, 16, Activation::Relu6),
                Layer::conv("c2", 3, 1, 1, 16, 16, Activation::Relu6),
                Layer::conv("c3", 3, 2, 1, 16, 32, Activation::Relu6),
                Layer::global_pool("gp", 32),
                Layer::dense("fc", 32, 10),
            ],
        )
    }

    #[test]
    fn vanilla_has_no_fused_blocks_and_f_1() {
        let m = model();
        let dag = FusionDag::build(&m, DagOptions::default());
        let v = solve_vanilla(&dag);
        assert_eq!(v.num_fused_blocks(), 0);
        assert!((v.cost.overhead - 1.0).abs() < 1e-12);
        assert_eq!(v.cost.peak_ram, m.vanilla_peak_ram());
    }

    #[test]
    fn heuristic_beats_vanilla_on_head_heavy_model() {
        let m = model();
        let dag = FusionDag::build(&m, DagOptions::default());
        let h = solve_head_fusion(&dag);
        assert!(h.cost.peak_ram < m.vanilla_peak_ram());
    }

    #[test]
    fn msf_beats_or_ties_all_baselines() {
        // The paper's headline: the multi-stage search dominates both the
        // head heuristic and single-block StreamNet on peak RAM.
        let dag = FusionDag::build(&model(), DagOptions::default());
        let msf = solve_p1_unconstrained(&dag).unwrap();
        let h = solve_head_fusion(&dag);
        let sn = solve_streamnet(&dag, None).unwrap();
        assert!(msf.cost.peak_ram <= h.cost.peak_ram);
        assert!(msf.cost.peak_ram <= sn.cost.peak_ram);
    }

    #[test]
    fn streamnet_uses_at_most_one_block() {
        let dag = FusionDag::build(&model(), DagOptions::default());
        let sn = solve_streamnet(&dag, None).unwrap();
        assert!(sn.num_fused_blocks() <= 1);
    }

    #[test]
    fn streamnet_respects_ram_cap() {
        let dag = FusionDag::build(&model(), DagOptions::default());
        let unconstrained = solve_streamnet(&dag, None).unwrap();
        if let Some(s) = solve_streamnet(&dag, Some(unconstrained.cost.peak_ram)) {
            assert!(s.cost.peak_ram <= unconstrained.cost.peak_ram);
        }
        assert!(solve_streamnet(&dag, Some(1)).is_none());
    }
}
