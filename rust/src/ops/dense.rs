//! Dense layer: common matvec and the paper's iterative form (Fig. 3).

/// Common dense: `y = x·W + b`, `w` laid out `[din][dout]` row-major
/// (column `w(n)` of the paper's Fig. 3 is `w[n*dout..]`).
pub fn dense(x: &[f32], w: &[f32], b: &[f32], dout: usize) -> Vec<f32> {
    let mut y = b.to_vec();
    dense_into(x, w, b, dout, &mut y);
    y
}

/// Allocation-free [`dense`] into a preallocated `[dout]` slice — same
/// accumulation order as `dense` and the element-wise [`DenseIter`] chain,
/// so all three are bit-identical. The compiled executor's classifier /
/// iterative-tail kernel. Weight rows are walked with `chunks_exact` so
/// the inner matvec is a pair of bounds-check-free slice zips.
pub fn dense_into(x: &[f32], w: &[f32], b: &[f32], dout: usize, out: &mut [f32]) {
    debug_assert_eq!(w.len(), x.len() * dout);
    debug_assert_eq!(out.len(), dout);
    out.copy_from_slice(b);
    for (row, &xi) in w.chunks_exact(dout).zip(x) {
        for (yj, wj) in out.iter_mut().zip(row) {
            *yj += xi * wj;
        }
    }
}

/// Iterative dense (paper Fig. 3): consumes the input vector element by
/// element (or in chunks), accumulating `x[i] · w(i)` into the output —
/// live memory is the `dout` accumulator plus one weight column instead of
/// the whole input vector (20% of the common form for 1024→256).
///
/// Mirrors `python/compile/kernels/iter_dense.py`.
#[derive(Debug, Clone)]
pub struct DenseIter {
    acc: Vec<f32>,
    next_idx: usize,
    din: usize,
}

impl DenseIter {
    pub fn new(din: usize, b: &[f32]) -> Self {
        Self { acc: b.to_vec(), next_idx: 0, din }
    }

    /// Feed the next chunk of input elements with the matching weight rows
    /// (`w_rows` = `[chunk][dout]` slice of the weight matrix).
    pub fn push(&mut self, x_chunk: &[f32], w_rows: &[f32]) {
        let dout = self.acc.len();
        debug_assert_eq!(w_rows.len(), x_chunk.len() * dout);
        for (i, &xi) in x_chunk.iter().enumerate() {
            let row = &w_rows[i * dout..(i + 1) * dout];
            for (a, wv) in self.acc.iter_mut().zip(row) {
                *a += xi * wv;
            }
        }
        self.next_idx += x_chunk.len();
    }

    /// RAM held by the accumulator (the §7 footprint).
    pub fn state_bytes(&self) -> u64 {
        (self.acc.len() * 4) as u64
    }

    pub fn finish(self) -> Vec<f32> {
        assert_eq!(self.next_idx, self.din, "short/over-fed dense");
        self.acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::ParamGen;

    #[test]
    fn dense_known_values() {
        // x=[1,2], W=[[1,0],[0,1]] (din=2,dout=2), b=[10,20].
        let y = dense(&[1.0, 2.0], &[1., 0., 0., 1.], &[10., 20.], 2);
        assert_eq!(y, vec![11.0, 22.0]);
    }

    #[test]
    fn iterative_matches_common() {
        let mut g = ParamGen::new(5);
        let din = 100;
        let dout = 24;
        let x = g.fill(din, 1.0);
        let w = g.fill(din * dout, 0.3);
        let b = g.fill(dout, 0.1);
        let common = dense(&x, &w, &b, dout);
        let mut it = DenseIter::new(din, &b);
        for chunk in 0..(din / 10) {
            let lo = chunk * 10;
            it.push(&x[lo..lo + 10], &w[lo * dout..(lo + 10) * dout]);
        }
        let iter = it.finish();
        for (a, b) in common.iter().zip(&iter) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn iterative_paper_ratio() {
        // Fig. 3: 1024 -> 256 dense compresses live activation memory to
        // ~20%: acc (256) vs input+acc (1024+256) -> 256/1280 = 20%.
        let it = DenseIter::new(1024, &vec![0.0; 256]);
        let common_live = (1024 + 256) * 4;
        assert_eq!(it.state_bytes() as usize * 5, common_live);
    }

    #[test]
    #[should_panic(expected = "short/over-fed")]
    fn short_feed_panics() {
        let it = DenseIter::new(8, &[0.0; 2]);
        it.finish();
    }
}
