//! Pure-Rust tensor operator substrate — the on-device inference engine
//! the paper builds on microTVM, rebuilt here so fused execution can be
//! *measured* (numerics + tracked RAM), not just predicted.
//!
//! The reference kernels are f32 HWC single-image (numerics match the
//! L1/L2 Python oracles). Each hot `*_into` kernel also has an int8 twin
//! in [`quant`] (i8 in, i32 accumulate, fused requantize epilogue) — the
//! regime [`crate::model::ModelChain::elem_bytes`]' analytic sizing
//! assumes, executed for real by [`crate::qexec`].
//!
//! The hot kernels are engineered around an interior/halo decomposition
//! (branch-free contiguous interior sweeps, guarded borders, epilogues
//! fused into the accumulation pass); [`reference`] retains the original
//! naive loop nests as the parity oracle for both numeric contracts
//! (f32 bit-identity, int8 exact identity).

mod conv;
mod dense;
mod fused_block;
mod pool;
mod quant;
pub mod reference;
mod tensor;

pub use conv::{conv2d, conv2d_into, dwconv2d, dwconv2d_into};
pub use dense::{dense, dense_into, DenseIter};
pub use fused_block::{
    BandGeom, BandRange, BlockStats, FusedBlock, HCache, NoUnitProfiler, UnitProfiler,
};
pub use pool::{
    accumulate_row_major, avg_pool2d, avg_pool2d_into, global_avg_pool, global_avg_pool_into,
    max_pool2d, max_pool2d_into, scale_avg, GlobalPoolIter,
};
pub use quant::{
    dequantize_into, get_i32, qavg_pool2d_into, qconv2d, qconv2d_into, qdense_into,
    qdwconv2d_into, qgap_accumulate, qgap_finish, qgap_reset, qmax_pool2d_into, qresidual_add,
    quantize_into, set_i32, QLayerParams, QMapRef, QParams, QTensor, QuantSpec,
};
pub(crate) use conv::{interior_hi, interior_lo};
pub(crate) use fused_block::required_input;
pub(crate) use quant::{qact, QBLOCK};
pub use tensor::{MapRef, Tensor};

use crate::model::{Activation, Layer, LayerKind};

/// Apply a layer's activation in place.
pub fn activate(buf: &mut [f32], act: Activation) {
    match act {
        Activation::None => {}
        Activation::Relu => {
            for v in buf.iter_mut() {
                *v = v.max(0.0);
            }
        }
        Activation::Relu6 => {
            for v in buf.iter_mut() {
                *v = v.clamp(0.0, 6.0);
            }
        }
    }
}

/// Deterministic per-layer parameters for reproducible runs: a tiny
/// xorshift-based generator seeded from the layer index (the executor and
/// all tests draw weights through this, so fused-vs-vanilla comparisons
/// are exact and repeatable without a `rand` dependency).
pub struct ParamGen {
    state: u64,
}

impl ParamGen {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    /// Uniform in [-0.5, 0.5), scaled by `scale`.
    pub fn next(&mut self, scale: f32) -> f32 {
        // xorshift64*
        self.state ^= self.state >> 12;
        self.state ^= self.state << 25;
        self.state ^= self.state >> 27;
        let r = self.state.wrapping_mul(0x2545F4914F6CDD1D);
        let unit = (r >> 11) as f32 / (1u64 << 53) as f32; // [0,1)
        (unit - 0.5) * scale
    }

    pub fn fill(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.next(scale)).collect()
    }
}

/// Weights (+bias) of one layer, generated deterministically.
#[derive(Debug, Clone)]
pub struct LayerParams {
    pub weights: Vec<f32>,
    pub bias: Vec<f32>,
}

impl LayerParams {
    /// He-ish scaled deterministic parameters for layer `li` of a chain.
    pub fn for_layer(layer: &Layer, li: usize) -> Self {
        let mut gen = ParamGen::new(0x5F3C ^ ((li as u64) << 32) ^ li as u64);
        let (n_w, fan_in) = match layer.kind {
            LayerKind::Conv2d => (
                (layer.k * layer.k * layer.cin * layer.cout) as usize,
                (layer.k * layer.k * layer.cin) as usize,
            ),
            LayerKind::DwConv2d => (
                (layer.k * layer.k * layer.cin) as usize,
                (layer.k * layer.k) as usize,
            ),
            LayerKind::Dense => ((layer.cin * layer.cout) as usize, layer.cin as usize),
            _ => (0, 1),
        };
        let scale = (2.0 / fan_in.max(1) as f32).sqrt();
        let weights = gen.fill(n_w, scale);
        let bias = gen.fill(layer.cout as usize, 0.02);
        Self { weights, bias }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paramgen_is_deterministic() {
        let a: Vec<f32> = ParamGen::new(7).fill(16, 1.0);
        let b: Vec<f32> = ParamGen::new(7).fill(16, 1.0);
        assert_eq!(a, b);
        let c: Vec<f32> = ParamGen::new(8).fill(16, 1.0);
        assert_ne!(a, c);
    }

    #[test]
    fn paramgen_range() {
        let v = ParamGen::new(3).fill(10_000, 2.0);
        assert!(v.iter().all(|x| *x >= -1.0 && *x < 1.0));
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn activate_relu6_clamps() {
        let mut buf = vec![-1.0, 0.5, 7.0];
        activate(&mut buf, Activation::Relu6);
        assert_eq!(buf, vec![0.0, 0.5, 6.0]);
    }
}
