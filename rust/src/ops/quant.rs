//! int8 quantization substrate — the numeric regime the paper's RAM
//! accounting assumes ("quantized ResNet-34", int8 tensor sizing).
//!
//! Symmetric-affine per-tensor scheme (TFLite-style): `real = scale ·
//! (q - zero_point)`, int8 activations/weights, i32 accumulators, with a
//! requantization step after each op. The executor runs f32 for oracle
//! exactness; this module proves the int8 path stays within quantization
//! error of it, which is what licenses `elem_bytes = 1` in Eq. 5.

use super::Tensor;

/// Per-tensor affine quantization parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zero_point: i32,
}

impl QParams {
    /// Parameters covering `[lo, hi]` with int8 range (asymmetric).
    pub fn from_range(lo: f32, hi: f32) -> Self {
        let lo = lo.min(0.0);
        let hi = hi.max(f32::EPSILON);
        let scale = (hi - lo) / 255.0;
        let zero_point = (-128.0 - lo / scale).round().clamp(-128.0, 127.0) as i32;
        Self { scale, zero_point }
    }

    /// Parameters for observed data.
    pub fn observe(data: &[f32]) -> Self {
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in data {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if !lo.is_finite() || !hi.is_finite() {
            return Self { scale: 1.0, zero_point: 0 };
        }
        Self::from_range(lo, hi)
    }

    #[inline]
    pub fn quantize(&self, v: f32) -> i8 {
        ((v / self.scale).round() as i32 + self.zero_point).clamp(-128, 127) as i8
    }

    #[inline]
    pub fn dequantize(&self, q: i8) -> f32 {
        (q as i32 - self.zero_point) as f32 * self.scale
    }
}

/// An int8-quantized HWC tensor.
#[derive(Debug, Clone)]
pub struct QTensor {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<i8>,
    pub qp: QParams,
}

impl QTensor {
    pub fn quantize(t: &Tensor) -> Self {
        let qp = QParams::observe(&t.data);
        Self {
            h: t.h,
            w: t.w,
            c: t.c,
            data: t.data.iter().map(|&v| qp.quantize(v)).collect(),
            qp,
        }
    }

    pub fn dequantize(&self) -> Tensor {
        Tensor::from_data(
            self.h,
            self.w,
            self.c,
            self.data.iter().map(|&q| self.qp.dequantize(q)).collect(),
        )
    }

    /// RAM bytes of the quantized activation (what Eq. 5 sizes).
    pub fn bytes(&self) -> u64 {
        self.data.len() as u64
    }
}

/// int8 conv2d with i32 accumulation and f32 requantization — the MCU
/// inner loop the latency model's `cycles_per_mac` abstracts.
/// `w_q`/`b` follow the same `[k,k,cin,cout]` layout as the f32 path.
#[allow(clippy::too_many_arguments)]
pub fn qconv2d(
    x: &QTensor,
    w_q: &[i8],
    w_qp: QParams,
    bias: &[f32],
    k: usize,
    stride: usize,
    padding: usize,
    cout: usize,
    out_qp: QParams,
    relu6: bool,
) -> QTensor {
    let cin = x.c;
    let ho = (x.h + 2 * padding - k) / stride + 1;
    let wo = (x.w + 2 * padding - k) / stride + 1;
    let mut out = vec![0i8; ho * wo * cout];
    let x_zp = x.qp.zero_point;
    let w_zp = w_qp.zero_point;
    let real_scale = x.qp.scale * w_qp.scale;

    for oy in 0..ho {
        for ox in 0..wo {
            for co in 0..cout {
                let mut acc: i32 = 0;
                for ky in 0..k {
                    let sy = (oy * stride + ky) as isize - padding as isize;
                    if sy < 0 || sy as usize >= x.h {
                        continue;
                    }
                    for kx in 0..k {
                        let sx = (ox * stride + kx) as isize - padding as isize;
                        if sx < 0 || sx as usize >= x.w {
                            continue;
                        }
                        let xoff = ((sy as usize) * x.w + sx as usize) * cin;
                        let woff = (ky * k + kx) * cin * cout;
                        for ci in 0..cin {
                            let xv = x.data[xoff + ci] as i32 - x_zp;
                            let wv = w_q[woff + ci * cout + co] as i32 - w_zp;
                            acc += xv * wv;
                        }
                    }
                }
                let mut real = acc as f32 * real_scale + bias[co];
                if relu6 {
                    real = real.clamp(0.0, 6.0);
                }
                out[(oy * wo + ox) * cout + co] = out_qp.quantize(real);
            }
        }
    }
    QTensor { h: ho, w: wo, c: cout, data: out, qp: out_qp }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Activation;
    use crate::ops::{conv2d, ParamGen};

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let mut g = ParamGen::new(1);
        let t = Tensor::from_data(4, 4, 3, g.fill(48, 4.0));
        let q = QTensor::quantize(&t);
        let back = q.dequantize();
        let max_err = t.max_abs_diff(&back);
        // Error bounded by half a quantization step.
        assert!(max_err <= q.qp.scale * 0.51, "err {max_err} scale {}", q.qp.scale);
    }

    #[test]
    fn qparams_cover_range() {
        let qp = QParams::from_range(-1.0, 3.0);
        assert_eq!(qp.quantize(-1.0), -128);
        assert_eq!(qp.quantize(3.0), 127);
        assert!((qp.dequantize(qp.quantize(0.0))).abs() < qp.scale);
    }

    #[test]
    fn int8_activation_is_quarter_of_f32() {
        let t = Tensor::zeros(8, 8, 4);
        let q = QTensor::quantize(&t);
        assert_eq!(q.bytes() * 4, (t.elems() * 4) as u64);
    }

    #[test]
    fn qconv_matches_f32_conv_within_quant_error() {
        let mut g = ParamGen::new(7);
        let x = Tensor::from_data(10, 10, 3, g.fill(300, 2.0));
        let w = g.fill(3 * 3 * 3 * 8, 0.6);
        let b = g.fill(8, 0.1);
        let f32_out = conv2d(&x, &w, &b, 3, 1, 1, 8, Activation::Relu6);

        let xq = QTensor::quantize(&x);
        let w_qp = QParams::observe(&w);
        let w_q: Vec<i8> = w.iter().map(|&v| w_qp.quantize(v)).collect();
        let out_qp = QParams::observe(&f32_out.data);
        let q_out = qconv2d(&xq, &w_q, w_qp, &b, 3, 1, 1, 8, out_qp, true);
        let deq = q_out.dequantize();

        assert_eq!(deq.shape(), f32_out.shape());
        // int8 conv error: dominated by input/weight quantization noise,
        // amplified by the k²·cin accumulation; a small multiple of the
        // output step covers it.
        let tol = 6.0 * out_qp.scale + 0.05;
        let max_err = deq.max_abs_diff(&f32_out);
        assert!(max_err < tol, "max_err {max_err} vs tol {tol}");
    }

    #[test]
    fn qconv_zero_points_cancel_on_constant_input() {
        // A constant input through an all-ones 1x1 kernel must reproduce
        // the constant (x scale/zp bookkeeping is exact for exact values).
        let t = Tensor::from_data(2, 2, 1, vec![1.0; 4]);
        let xq = QTensor::quantize(&t);
        let w_qp = QParams::from_range(0.0, 1.0);
        let w_q = vec![w_qp.quantize(1.0)];
        let out_qp = QParams::from_range(0.0, 2.0);
        let out = qconv2d(&xq, &w_q, w_qp, &[0.0], 1, 1, 0, 1, out_qp, false);
        let deq = out.dequantize();
        for v in &deq.data {
            assert!((v - 1.0).abs() < 0.03, "{v}");
        }
    }
}
