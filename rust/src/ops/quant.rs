//! int8 quantization substrate — the numeric regime the paper's RAM
//! accounting assumes ("quantized ResNet-34", int8 tensor sizing).
//!
//! Symmetric-affine per-tensor scheme (TFLite-style): `real = scale ·
//! (q - zero_point)`, int8 activations/weights, i32 accumulators, with a
//! requantization step after each op. Two layers of machinery live here:
//!
//! * the original oracle-side types ([`QParams`], [`QTensor`], the
//!   allocating [`qconv2d`]) that *prove* the int8 path stays within
//!   quantization error of the f32 executor — what licenses
//!   `elem_bytes = 1` in Eq. 5; and
//! * the allocation-free `q*_into` kernel twins of the f32 `*_into`
//!   family (i8 in, i32 accumulate, fused requantize-to-i8 epilogue that
//!   folds the activation clamp — no per-element dequantize round trip),
//!   which [`crate::qexec::QCompiledPlan`] wires to pool slices so a
//!   whole plan executes end-to-end in int8 storage.
//!
//! Weight layouts are byte-for-byte the f32 layouts (`[k,k,cin,cout]`
//! conv, `[k,k,c]` depthwise, `[din][dout]` dense); biases stay f32 and
//! are folded into the epilogue, TinyEngine-style.

use crate::model::Activation;

use super::{LayerParams, Tensor};

/// Per-tensor affine quantization parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zero_point: i32,
}

impl QParams {
    /// Parameters covering `[lo, hi]` with int8 range (asymmetric).
    pub fn from_range(lo: f32, hi: f32) -> Self {
        let lo = lo.min(0.0);
        let hi = hi.max(f32::EPSILON);
        let scale = (hi - lo) / 255.0;
        let zero_point = (-128.0 - lo / scale).round().clamp(-128.0, 127.0) as i32;
        Self { scale, zero_point }
    }

    /// Parameters for observed data.
    pub fn observe(data: &[f32]) -> Self {
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in data {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if !lo.is_finite() || !hi.is_finite() {
            return Self { scale: 1.0, zero_point: 0 };
        }
        Self::from_range(lo, hi)
    }

    #[inline]
    pub fn quantize(&self, v: f32) -> i8 {
        ((v / self.scale).round() as i32 + self.zero_point).clamp(-128, 127) as i8
    }

    #[inline]
    pub fn dequantize(&self, q: i8) -> f32 {
        (q as i32 - self.zero_point) as f32 * self.scale
    }

    /// Smallest scale the verifier treats as non-degenerate. Calibration
    /// never produces less ([`Self::from_range`] floors the span at
    /// `f32::EPSILON`, giving `scale >= EPSILON / 255 ≈ 4.7e-10`), so
    /// anything below is a corrupted or hand-edited spec.
    pub const MIN_SCALE: f32 = 1e-12;

    /// True when the scale cannot drive a meaningful affine map:
    /// non-finite, non-positive, or below [`Self::MIN_SCALE`]. Such a
    /// spec quantizes everything to a clamp edge.
    pub fn is_degenerate(&self) -> bool {
        !self.scale.is_finite() || self.scale < Self::MIN_SCALE
    }

    /// Worst-case bounds of the zero-point-corrected term `q - zp` over
    /// the full int8 range `q ∈ [-128, 127]` — the per-operand factor of
    /// the accumulator overflow bound (i64: an out-of-range zero point
    /// must widen the bound, not wrap it).
    pub fn q_dev_bounds(&self) -> (i64, i64) {
        (-128 - self.zero_point as i64, 127 - self.zero_point as i64)
    }

    /// Largest magnitude of `|q - zp|` over the full int8 range.
    pub fn max_abs_q_dev(&self) -> i64 {
        let (lo, hi) = self.q_dev_bounds();
        lo.abs().max(hi.abs())
    }

    /// The real-valued interval this tensor can represent:
    /// `[dequantize(-128), dequantize(127)]` — what the requantization
    /// epilogue clamps into.
    pub fn representable(&self) -> (f32, f32) {
        (self.dequantize(-128), self.dequantize(127))
    }
}

/// Full quantization configuration of one plan: a [`QParams`] per
/// boundary tensor `v_0..v_n` (observed by a calibration pass,
/// [`crate::qexec::calibrate`]) and one per layer's weights. Serialized
/// into [`crate::optimizer::Plan`] JSON so a quantized deploy artifact
/// fully determines its own numerics.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantSpec {
    /// `tensors[i]` quantizes boundary tensor `v_i` (`num_layers + 1`).
    pub tensors: Vec<QParams>,
    /// `weights[i]` quantizes layer `i`'s weight array (`num_layers`).
    pub weights: Vec<QParams>,
}

/// One layer's parameters in the quantized regime: int8 weights (same
/// memory layout as the f32 array they were quantized from) plus the f32
/// bias folded into the requantization epilogue.
#[derive(Debug, Clone)]
pub struct QLayerParams {
    pub w_q: Vec<i8>,
    pub w_qp: QParams,
    pub bias: Vec<f32>,
}

impl QLayerParams {
    /// Quantize `p`'s weights under `w_qp` (the spec entry a calibration
    /// pass observed for this layer).
    pub fn from_params(p: &LayerParams, w_qp: QParams) -> Self {
        Self {
            w_q: p.weights.iter().map(|&v| w_qp.quantize(v)).collect(),
            w_qp,
            bias: p.bias.clone(),
        }
    }
}

/// Borrowed int8 HWC map view — the i8 twin of [`super::MapRef`], the
/// read surface [`crate::qexec::QCompiledPlan`] streams pool slices
/// through.
#[derive(Clone, Copy)]
pub struct QMapRef<'a> {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: &'a [i8],
}

impl<'a> QMapRef<'a> {
    /// View over a raw pool slice with explicit dims.
    pub fn new(h: usize, w: usize, c: usize, data: &'a [i8]) -> Self {
        debug_assert_eq!(data.len(), h * w * c);
        Self { h, w, c, data }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    /// Copy rows `[y0, y0+rows)` into `dst`, filling rows outside
    /// `[0, h)` with `fill` — the quantized twin of
    /// [`super::MapRef::read_band_into`]. Padding rows carry the owning
    /// tensor's *zero point*, so a conv's `(x - zp)` contribution over
    /// them is exactly 0, matching the f32 path's zero padding.
    pub fn read_band_into(&self, y0: isize, rows: usize, dst: &mut [i8], fill: i8) {
        let rowlen = self.w * self.c;
        debug_assert!(dst.len() >= rows * rowlen);
        for r in 0..rows {
            let sy = y0 + r as isize;
            let dsts = &mut dst[r * rowlen..(r + 1) * rowlen];
            if sy < 0 || sy as usize >= self.h {
                dsts.fill(fill);
                continue;
            }
            let src = sy as usize * rowlen;
            dsts.copy_from_slice(&self.data[src..src + rowlen]);
        }
    }
}

/// The requantization epilogue's activation fold: clamp `real` exactly
/// as the f32 kernels' post-activation would, *before* quantizing.
#[inline]
pub(crate) fn qact(real: f32, act: Activation) -> f32 {
    match act {
        Activation::None => real,
        Activation::Relu => real.max(0.0),
        Activation::Relu6 => real.clamp(0.0, 6.0),
    }
}

/// Quantize an f32 slice into an i8 slice under `qp` (same lengths).
pub fn quantize_into(src: &[f32], qp: QParams, dst: &mut [i8]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = qp.quantize(s);
    }
}

/// Dequantize an i8 slice into an f32 slice under `qp` (same lengths).
pub fn dequantize_into(src: &[i8], qp: QParams, dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = qp.dequantize(s);
    }
}

/// Read the `idx`-th little-endian i32 packed into a byte pool slice
/// (i32 accumulator stashes live inside the int8 pool; alignment-free).
#[inline]
pub fn get_i32(buf: &[i8], idx: usize) -> i32 {
    let o = idx * 4;
    i32::from_le_bytes([buf[o] as u8, buf[o + 1] as u8, buf[o + 2] as u8, buf[o + 3] as u8])
}

/// Write the `idx`-th little-endian i32 into a byte pool slice.
#[inline]
pub fn set_i32(buf: &mut [i8], idx: usize, v: i32) {
    let b = v.to_le_bytes();
    let o = idx * 4;
    buf[o] = b[0] as i8;
    buf[o + 1] = b[1] as i8;
    buf[o + 2] = b[2] as i8;
    buf[o + 3] = b[3] as i8;
}

/// Output-channel / channel block width of the int8 interior kernels:
/// a `[i32; QBLOCK]` stack accumulator lets each loaded input byte feed
/// a whole block of output channels (the TinyEngine-style reuse that
/// makes int8 conv memory-bound on weights, not activations). i32
/// accumulation is associative, so any block width is exact.
pub(crate) const QBLOCK: usize = 64;

/// int8 twin of [`super::conv2d_into`]: i8 in, i32 accumulation of
/// `(x - zp_x)(w - zp_w)`, one fused f32 epilogue per output element
/// (`acc · s_x·s_w + bias`, activation clamp, requantize) — no
/// intermediate dequantized map ever exists.
///
/// Interior/halo decomposition as in the f32 twin, but the interior is
/// restructured output-channel-blocked ([`QBLOCK`]-wide i32 stack
/// accumulators): each input byte is loaded once and swept across the
/// block's weight row, with an exact `x == zero_point` skip (that
/// term's contribution is 0). i32 sums are associative, so results are
/// **exactly identical** to [`super::reference::qconv2d_naive`].
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_into(
    x: QMapRef<'_>,
    x_qp: QParams,
    p: &QLayerParams,
    k: usize,
    stride: usize,
    padding: usize,
    cout: usize,
    act: Activation,
    out_qp: QParams,
    out: &mut [i8],
) {
    let cin = x.c;
    let ho = (x.h + 2 * padding - k) / stride + 1;
    let wo = (x.w + 2 * padding - k) / stride + 1;
    debug_assert!(out.len() >= ho * wo * cout, "output buffer too small");
    let zx = x_qp.zero_point;
    let zw = p.w_qp.zero_point;
    let real_scale = x_qp.scale * p.w_qp.scale;

    let oy_lo = super::conv::interior_lo(stride, padding, ho);
    let oy_hi = super::conv::interior_hi(x.h, k, stride, padding, ho);
    let ox_lo = super::conv::interior_lo(stride, padding, wo);
    let ox_hi = super::conv::interior_hi(x.w, k, stride, padding, wo);

    let guarded = |out_px: &mut [i8], oy: usize, ox: usize| {
        for co in 0..cout {
            let mut acc: i32 = 0;
            for ky in 0..k {
                let sy = (oy * stride + ky) as isize - padding as isize;
                if sy < 0 || sy as usize >= x.h {
                    continue;
                }
                for kx in 0..k {
                    let sx = (ox * stride + kx) as isize - padding as isize;
                    if sx < 0 || sx as usize >= x.w {
                        continue;
                    }
                    let xoff = ((sy as usize) * x.w + sx as usize) * cin;
                    let woff = (ky * k + kx) * cin * cout;
                    for ci in 0..cin {
                        let xv = x.data[xoff + ci] as i32 - zx;
                        let wv = p.w_q[woff + ci * cout + co] as i32 - zw;
                        acc += xv * wv;
                    }
                }
            }
            let real = qact(acc as f32 * real_scale + p.bias[co], act);
            out_px[co] = out_qp.quantize(real);
        }
    };

    let mut acc = [0i32; QBLOCK];
    for oy in 0..ho {
        let row_base = oy * wo;
        if oy < oy_lo || oy >= oy_hi {
            for ox in 0..wo {
                let base = (row_base + ox) * cout;
                guarded(&mut out[base..base + cout], oy, ox);
            }
            continue;
        }
        let y0 = oy * stride - padding;
        for ox in 0..ox_lo {
            let base = (row_base + ox) * cout;
            guarded(&mut out[base..base + cout], oy, ox);
        }
        for ox in ox_lo..ox_hi {
            let base = (row_base + ox) * cout;
            let x0 = ox * stride - padding;
            let mut co0 = 0;
            while co0 < cout {
                let bl = QBLOCK.min(cout - co0);
                let accs = &mut acc[..bl];
                accs.fill(0);
                for ky in 0..k {
                    let xrow = ((y0 + ky) * x.w + x0) * cin;
                    let wrow = ky * k * cin;
                    for (t, &xq) in x.data[xrow..xrow + k * cin].iter().enumerate() {
                        let xv = xq as i32 - zx;
                        if xv == 0 {
                            continue;
                        }
                        let woff = (wrow + t) * cout + co0;
                        let ws = &p.w_q[woff..woff + bl];
                        for (a, &wq) in accs.iter_mut().zip(ws) {
                            *a += xv * (wq as i32 - zw);
                        }
                    }
                }
                for (j, &a) in accs.iter().enumerate() {
                    let real = qact(a as f32 * real_scale + p.bias[co0 + j], act);
                    out[base + co0 + j] = out_qp.quantize(real);
                }
                co0 += bl;
            }
        }
        for ox in ox_hi.max(ox_lo)..wo {
            let base = (row_base + ox) * cout;
            guarded(&mut out[base..base + cout], oy, ox);
        }
    }
}

/// int8 twin of [`super::dwconv2d_into`] (`[k,k,c]` weight layout).
///
/// Interior pixels run channel-blocked: a [`QBLOCK`]-wide i32 stack
/// accumulator sweeps contiguous input/weight channel slices per tap,
/// so the per-channel scalar loop (and its per-tap bounds predicate)
/// only survives on the halo. Exactly identical to
/// [`super::reference::qdwconv2d_naive`].
#[allow(clippy::too_many_arguments)]
pub fn qdwconv2d_into(
    x: QMapRef<'_>,
    x_qp: QParams,
    p: &QLayerParams,
    k: usize,
    stride: usize,
    padding: usize,
    act: Activation,
    out_qp: QParams,
    out: &mut [i8],
) {
    let c = x.c;
    let ho = (x.h + 2 * padding - k) / stride + 1;
    let wo = (x.w + 2 * padding - k) / stride + 1;
    debug_assert!(out.len() >= ho * wo * c, "output buffer too small");
    let zx = x_qp.zero_point;
    let zw = p.w_qp.zero_point;
    let real_scale = x_qp.scale * p.w_qp.scale;

    let oy_lo = super::conv::interior_lo(stride, padding, ho);
    let oy_hi = super::conv::interior_hi(x.h, k, stride, padding, ho);
    let ox_lo = super::conv::interior_lo(stride, padding, wo);
    let ox_hi = super::conv::interior_hi(x.w, k, stride, padding, wo);

    let guarded = |out_px: &mut [i8], oy: usize, ox: usize| {
        for ci in 0..c {
            let mut acc: i32 = 0;
            for ky in 0..k {
                let sy = (oy * stride + ky) as isize - padding as isize;
                if sy < 0 || sy as usize >= x.h {
                    continue;
                }
                for kx in 0..k {
                    let sx = (ox * stride + kx) as isize - padding as isize;
                    if sx < 0 || sx as usize >= x.w {
                        continue;
                    }
                    let xoff = ((sy as usize) * x.w + sx as usize) * c;
                    let woff = (ky * k + kx) * c;
                    let xv = x.data[xoff + ci] as i32 - zx;
                    let wv = p.w_q[woff + ci] as i32 - zw;
                    acc += xv * wv;
                }
            }
            let real = qact(acc as f32 * real_scale + p.bias[ci], act);
            out_px[ci] = out_qp.quantize(real);
        }
    };

    let mut acc = [0i32; QBLOCK];
    for oy in 0..ho {
        let row_base = oy * wo;
        if oy < oy_lo || oy >= oy_hi {
            for ox in 0..wo {
                let base = (row_base + ox) * c;
                guarded(&mut out[base..base + c], oy, ox);
            }
            continue;
        }
        let y0 = oy * stride - padding;
        for ox in 0..ox_lo {
            let base = (row_base + ox) * c;
            guarded(&mut out[base..base + c], oy, ox);
        }
        for ox in ox_lo..ox_hi {
            let base = (row_base + ox) * c;
            let x0 = ox * stride - padding;
            let mut c0 = 0;
            while c0 < c {
                let bl = QBLOCK.min(c - c0);
                let accs = &mut acc[..bl];
                accs.fill(0);
                for ky in 0..k {
                    let xrow = ((y0 + ky) * x.w + x0) * c;
                    let wrow = ky * k * c;
                    for kx in 0..k {
                        let xs = &x.data[xrow + kx * c + c0..xrow + kx * c + c0 + bl];
                        let ws = &p.w_q[wrow + kx * c + c0..wrow + kx * c + c0 + bl];
                        for ((a, &xq), &wq) in accs.iter_mut().zip(xs).zip(ws) {
                            *a += (xq as i32 - zx) * (wq as i32 - zw);
                        }
                    }
                }
                for (j, &a) in accs.iter().enumerate() {
                    let real = qact(a as f32 * real_scale + p.bias[c0 + j], act);
                    out[base + c0 + j] = out_qp.quantize(real);
                }
                c0 += bl;
            }
        }
        for ox in ox_hi.max(ox_lo)..wo {
            let base = (row_base + ox) * c;
            guarded(&mut out[base..base + c], oy, ox);
        }
    }
}

/// int8 twin of [`super::avg_pool2d_into`] (unpadded): i32 window sum of
/// raw q values over contiguous row slices in [`QBLOCK`]-wide channel
/// blocks, one epilogue per output element. Exactly identical to
/// [`super::reference::qavg_pool2d_naive`].
pub fn qavg_pool2d_into(
    x: QMapRef<'_>,
    x_qp: QParams,
    k: usize,
    stride: usize,
    out_qp: QParams,
    out: &mut [i8],
) {
    let c = x.c;
    let ho = (x.h - k) / stride + 1;
    let wo = (x.w - k) / stride + 1;
    debug_assert!(out.len() >= ho * wo * c, "output buffer too small");
    let count = (k * k) as f32;
    let zx = x_qp.zero_point as f32;
    let mut acc = [0i32; QBLOCK];
    for oy in 0..ho {
        for ox in 0..wo {
            let base = (oy * wo + ox) * c;
            let mut c0 = 0;
            while c0 < c {
                let bl = QBLOCK.min(c - c0);
                let accs = &mut acc[..bl];
                accs.fill(0);
                for ky in 0..k {
                    let row = ((oy * stride + ky) * x.w + ox * stride) * c;
                    for kx in 0..k {
                        let xs = &x.data[row + kx * c + c0..row + kx * c + c0 + bl];
                        for (a, &xq) in accs.iter_mut().zip(xs) {
                            *a += xq as i32;
                        }
                    }
                }
                for (j, &sum) in accs.iter().enumerate() {
                    let real = (sum as f32 - count * zx) * x_qp.scale / count;
                    out[base + c0 + j] = out_qp.quantize(real);
                }
                c0 += bl;
            }
        }
    }
}

/// int8 twin of [`super::max_pool2d_into`]: max over raw q values (the
/// max is monotone under one affine map) in [`QBLOCK`]-wide channel
/// blocks over contiguous row slices, then a single requantize. Exactly
/// identical to [`super::reference::qmax_pool2d_naive`].
pub fn qmax_pool2d_into(
    x: QMapRef<'_>,
    x_qp: QParams,
    k: usize,
    stride: usize,
    out_qp: QParams,
    out: &mut [i8],
) {
    let c = x.c;
    let ho = (x.h - k) / stride + 1;
    let wo = (x.w - k) / stride + 1;
    debug_assert!(out.len() >= ho * wo * c, "output buffer too small");
    let mut acc = [i8::MIN; QBLOCK];
    for oy in 0..ho {
        for ox in 0..wo {
            let base = (oy * wo + ox) * c;
            let mut c0 = 0;
            while c0 < c {
                let bl = QBLOCK.min(c - c0);
                let accs = &mut acc[..bl];
                accs.fill(i8::MIN);
                for ky in 0..k {
                    let row = ((oy * stride + ky) * x.w + ox * stride) * c;
                    for kx in 0..k {
                        let xs = &x.data[row + kx * c + c0..row + kx * c + c0 + bl];
                        for (a, &xq) in accs.iter_mut().zip(xs) {
                            *a = (*a).max(xq);
                        }
                    }
                }
                for (j, &m) in accs.iter().enumerate() {
                    out[base + c0 + j] = out_qp.quantize(x_qp.dequantize(m));
                }
                c0 += bl;
            }
        }
    }
}

/// int8 twin of [`super::dense_into`] (`[din][dout]` weight layout):
/// i32 accumulation over [`QBLOCK`]-wide output blocks (each input byte
/// is loaded once per block and swept across a contiguous weight-row
/// slice, with an exact `x == zero_point` skip), fused epilogue written
/// straight to i8 — dense accumulators never materialize. Exactly
/// identical to [`super::reference::qdense_naive`].
pub fn qdense_into(
    x: &[i8],
    x_qp: QParams,
    p: &QLayerParams,
    dout: usize,
    out_qp: QParams,
    out: &mut [i8],
) {
    debug_assert!(out.len() >= dout, "output buffer too small");
    let zx = x_qp.zero_point;
    let zw = p.w_qp.zero_point;
    let real_scale = x_qp.scale * p.w_qp.scale;
    let mut acc = [0i32; QBLOCK];
    let mut j0 = 0;
    while j0 < dout {
        let bl = QBLOCK.min(dout - j0);
        let accs = &mut acc[..bl];
        accs.fill(0);
        for (i, &xq) in x.iter().enumerate() {
            let xv = xq as i32 - zx;
            if xv == 0 {
                continue;
            }
            let ws = &p.w_q[i * dout + j0..i * dout + j0 + bl];
            for (a, &wq) in accs.iter_mut().zip(ws) {
                *a += xv * (wq as i32 - zw);
            }
        }
        for (j, &a) in accs.iter().enumerate() {
            out[j0 + j] = out_qp.quantize(a as f32 * real_scale + p.bias[j0 + j]);
        }
        j0 += bl;
    }
}

/// Zero the i32 global-pool accumulator region (`4*c` leading bytes of
/// `acc`) — the quantized twin of `acc.fill(0.0)`.
pub fn qgap_reset(acc: &mut [i8], c: usize) {
    debug_assert!(acc.len() >= 4 * c, "accumulator region too small");
    acc[..4 * c].fill(0);
}

/// Add one row-major row of raw q values into the i32 accumulator
/// region — the quantized twin of [`super::accumulate_row_major`].
pub fn qgap_accumulate(acc: &mut [i8], row: &[i8], c: usize) {
    debug_assert_eq!(row.len() % c, 0, "row not channel-aligned");
    for chunk in row.chunks_exact(c) {
        for (ci, &v) in chunk.iter().enumerate() {
            set_i32(acc, ci, get_i32(acc, ci) + v as i32);
        }
    }
}

/// Finish a global average pool: turn each channel's raw-q i32 sum over
/// `n_pixels` into `scale·(sum/n − zp)`, requantized under `out_qp`
/// into the first `c` bytes of `acc` (the i8 payload convention of
/// [`crate::qexec::QPlanPool`] buffers).
pub fn qgap_finish(acc: &mut [i8], c: usize, n_pixels: usize, x_qp: QParams, out_qp: QParams) {
    debug_assert!(acc.len() >= 4 * c && n_pixels > 0);
    let n = n_pixels as f32;
    let zx = x_qp.zero_point as f32;
    for ci in 0..c {
        // Reads of entry `ci` (bytes [4ci, 4ci+4)) always stay ahead of
        // the payload writes (byte ci), so the in-place finish is safe.
        let sum = get_i32(acc, ci);
        let real = (sum as f32 - n * zx) * x_qp.scale / n;
        acc[ci] = out_qp.quantize(real);
    }
}

/// Cross-span residual add on i8 payloads: `out += stash` in real
/// space, requantized back under `out`'s own parameters (the one place
/// the quantized path multiplies by a scale outside an epilogue —
/// exactly one dequant/requant pair per skip connection, matching the
/// f32 engine's post-kernel add).
pub fn qresidual_add(out: &mut [i8], out_qp: QParams, stash: &[i8], stash_qp: QParams) {
    for (o, &s) in out.iter_mut().zip(stash) {
        let real = out_qp.dequantize(*o) + stash_qp.dequantize(s);
        *o = out_qp.quantize(real);
    }
}

/// An int8-quantized HWC tensor.
#[derive(Debug, Clone)]
pub struct QTensor {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<i8>,
    pub qp: QParams,
}

impl QTensor {
    pub fn quantize(t: &Tensor) -> Self {
        let qp = QParams::observe(&t.data);
        Self {
            h: t.h,
            w: t.w,
            c: t.c,
            data: t.data.iter().map(|&v| qp.quantize(v)).collect(),
            qp,
        }
    }

    pub fn dequantize(&self) -> Tensor {
        Tensor::from_data(
            self.h,
            self.w,
            self.c,
            self.data.iter().map(|&q| self.qp.dequantize(q)).collect(),
        )
    }

    /// RAM bytes of the quantized activation (what Eq. 5 sizes).
    pub fn bytes(&self) -> u64 {
        self.data.len() as u64
    }
}

/// int8 conv2d with i32 accumulation and f32 requantization — the MCU
/// inner loop the latency model's `cycles_per_mac` abstracts.
/// `w_q`/`b` follow the same `[k,k,cin,cout]` layout as the f32 path.
#[allow(clippy::too_many_arguments)]
pub fn qconv2d(
    x: &QTensor,
    w_q: &[i8],
    w_qp: QParams,
    bias: &[f32],
    k: usize,
    stride: usize,
    padding: usize,
    cout: usize,
    out_qp: QParams,
    relu6: bool,
) -> QTensor {
    let ho = (x.h + 2 * padding - k) / stride + 1;
    let wo = (x.w + 2 * padding - k) / stride + 1;
    let mut out = vec![0i8; ho * wo * cout];
    let p = QLayerParams { w_q: w_q.to_vec(), w_qp, bias: bias.to_vec() };
    let act = if relu6 { Activation::Relu6 } else { Activation::None };
    qconv2d_into(
        QMapRef::new(x.h, x.w, x.c, &x.data),
        x.qp,
        &p,
        k,
        stride,
        padding,
        cout,
        act,
        out_qp,
        &mut out,
    );
    QTensor { h: ho, w: wo, c: cout, data: out, qp: out_qp }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Activation;
    use crate::ops::{avg_pool2d, conv2d, dense, dwconv2d, max_pool2d, ParamGen};

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let mut g = ParamGen::new(1);
        let t = Tensor::from_data(4, 4, 3, g.fill(48, 4.0));
        let q = QTensor::quantize(&t);
        let back = q.dequantize();
        let max_err = t.max_abs_diff(&back);
        // Error bounded by half a quantization step.
        assert!(max_err <= q.qp.scale * 0.51, "err {max_err} scale {}", q.qp.scale);
    }

    #[test]
    fn qparams_cover_range() {
        let qp = QParams::from_range(-1.0, 3.0);
        assert_eq!(qp.quantize(-1.0), -128);
        assert_eq!(qp.quantize(3.0), 127);
        assert!((qp.dequantize(qp.quantize(0.0))).abs() < qp.scale);
    }

    #[test]
    fn worst_case_bound_helpers_match_definitions() {
        let qp = QParams::from_range(-1.0, 3.0);
        let (lo, hi) = qp.q_dev_bounds();
        assert_eq!(lo, -128 - qp.zero_point as i64);
        assert_eq!(hi, 127 - qp.zero_point as i64);
        assert_eq!(qp.max_abs_q_dev(), lo.abs().max(hi.abs()));
        let (rlo, rhi) = qp.representable();
        assert!(rlo <= -1.0 + qp.scale && rhi >= 3.0 - qp.scale, "{rlo}..{rhi}");
        assert!(!qp.is_degenerate());
        assert!(QParams { scale: 0.0, zero_point: 0 }.is_degenerate());
        assert!(QParams { scale: f32::NAN, zero_point: 0 }.is_degenerate());
        assert!(QParams { scale: 1e-13, zero_point: 0 }.is_degenerate());
        // An out-of-range zero point widens the deviation bound past 255.
        assert!(QParams { scale: 1.0, zero_point: 300 }.max_abs_q_dev() > 255);
    }

    #[test]
    fn int8_activation_is_quarter_of_f32() {
        let t = Tensor::zeros(8, 8, 4);
        let q = QTensor::quantize(&t);
        assert_eq!(q.bytes() * 4, (t.elems() * 4) as u64);
    }

    #[test]
    fn qconv_matches_f32_conv_within_quant_error() {
        let mut g = ParamGen::new(7);
        let x = Tensor::from_data(10, 10, 3, g.fill(300, 2.0));
        let w = g.fill(3 * 3 * 3 * 8, 0.6);
        let b = g.fill(8, 0.1);
        let f32_out = conv2d(&x, &w, &b, 3, 1, 1, 8, Activation::Relu6);

        let xq = QTensor::quantize(&x);
        let w_qp = QParams::observe(&w);
        let w_q: Vec<i8> = w.iter().map(|&v| w_qp.quantize(v)).collect();
        let out_qp = QParams::observe(&f32_out.data);
        let q_out = qconv2d(&xq, &w_q, w_qp, &b, 3, 1, 1, 8, out_qp, true);
        let deq = q_out.dequantize();

        assert_eq!(deq.shape(), f32_out.shape());
        // int8 conv error: dominated by input/weight quantization noise,
        // amplified by the k²·cin accumulation; a small multiple of the
        // output step covers it.
        let tol = 6.0 * out_qp.scale + 0.05;
        let max_err = deq.max_abs_diff(&f32_out);
        assert!(max_err < tol, "max_err {max_err} vs tol {tol}");
    }

    #[test]
    fn qconv_zero_points_cancel_on_constant_input() {
        // A constant input through an all-ones 1x1 kernel must reproduce
        // the constant (x scale/zp bookkeeping is exact for exact values).
        let t = Tensor::from_data(2, 2, 1, vec![1.0; 4]);
        let xq = QTensor::quantize(&t);
        let w_qp = QParams::from_range(0.0, 1.0);
        let w_q = vec![w_qp.quantize(1.0)];
        let out_qp = QParams::from_range(0.0, 2.0);
        let out = qconv2d(&xq, &w_q, w_qp, &[0.0], 1, 1, 0, 1, out_qp, false);
        let deq = out.dequantize();
        for v in &deq.data {
            assert!((v - 1.0).abs() < 0.03, "{v}");
        }
    }

    fn quantized_pair(seed: u64, n_x: usize, n_w: usize, n_b: usize) -> (Tensor, Vec<f32>, Vec<f32>) {
        let mut g = ParamGen::new(seed);
        (
            Tensor::from_data(1, 1, n_x, g.fill(n_x, 2.0)),
            g.fill(n_w, 0.5),
            g.fill(n_b, 0.1),
        )
    }

    #[test]
    fn qdwconv_into_matches_f32_within_quant_error() {
        let mut g = ParamGen::new(9);
        let x = Tensor::from_data(9, 9, 4, g.fill(9 * 9 * 4, 2.0));
        let w = g.fill(3 * 3 * 4, 0.6);
        let b = g.fill(4, 0.1);
        let f = dwconv2d(&x, &w, &b, 3, 1, 1, Activation::Relu6);

        let xq = QTensor::quantize(&x);
        let w_qp = QParams::observe(&w);
        let p = QLayerParams::from_params(&LayerParams { weights: w, bias: b }, w_qp);
        let out_qp = QParams::observe(&f.data);
        let mut out = vec![0i8; f.elems()];
        qdwconv2d_into(
            QMapRef::new(9, 9, 4, &xq.data),
            xq.qp,
            &p,
            3,
            1,
            1,
            Activation::Relu6,
            out_qp,
            &mut out,
        );
        let mut deq = vec![0.0f32; out.len()];
        dequantize_into(&out, out_qp, &mut deq);
        let max_err = deq
            .iter()
            .zip(&f.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        let tol = 6.0 * out_qp.scale + 0.05;
        assert!(max_err < tol, "max_err {max_err} vs tol {tol}");
    }

    #[test]
    fn qpool_twins_match_f32_within_quant_error() {
        let mut g = ParamGen::new(11);
        let x = Tensor::from_data(8, 8, 3, g.fill(8 * 8 * 3, 3.0));
        let xq = QTensor::quantize(&x);
        let xm = QMapRef::new(8, 8, 3, &xq.data);

        let favg = avg_pool2d(&x, 2, 2);
        let aqp = QParams::observe(&favg.data);
        let mut qa = vec![0i8; favg.elems()];
        qavg_pool2d_into(xm, xq.qp, 2, 2, aqp, &mut qa);
        for (q, f) in qa.iter().zip(&favg.data) {
            assert!((aqp.dequantize(*q) - f).abs() < 2.0 * aqp.scale + 2.0 * xq.qp.scale);
        }

        let fmax = max_pool2d(&x, 2, 2);
        let mqp = QParams::observe(&fmax.data);
        let mut qm = vec![0i8; fmax.elems()];
        qmax_pool2d_into(xm, xq.qp, 2, 2, mqp, &mut qm);
        for (q, f) in qm.iter().zip(&fmax.data) {
            assert!((mqp.dequantize(*q) - f).abs() < 2.0 * mqp.scale + 2.0 * xq.qp.scale);
        }
    }

    #[test]
    fn qdense_into_matches_f32_within_quant_error() {
        let (x, w, b) = quantized_pair(13, 24, 24 * 10, 10);
        let f = dense(&x.data, &w, &b, 10);
        let xq = QTensor::quantize(&x);
        let w_qp = QParams::observe(&w);
        let p = QLayerParams::from_params(&LayerParams { weights: w, bias: b }, w_qp);
        let out_qp = QParams::observe(&f);
        let mut out = vec![0i8; 10];
        qdense_into(&xq.data, xq.qp, &p, 10, out_qp, &mut out);
        for (q, fv) in out.iter().zip(&f) {
            let err = (out_qp.dequantize(*q) - fv).abs();
            let tol = 6.0 * out_qp.scale + 0.05;
            assert!(err < tol, "err {err} vs tol {tol}");
        }
    }

    #[test]
    fn i32_pool_packing_roundtrips() {
        let mut buf = vec![0i8; 16];
        for (i, v) in [0, -1, i32::MAX, i32::MIN].into_iter().enumerate() {
            set_i32(&mut buf, i, v);
        }
        assert_eq!(get_i32(&buf, 0), 0);
        assert_eq!(get_i32(&buf, 1), -1);
        assert_eq!(get_i32(&buf, 2), i32::MAX);
        assert_eq!(get_i32(&buf, 3), i32::MIN);
    }

    #[test]
    fn qgap_streaming_matches_direct_mean() {
        let mut g = ParamGen::new(17);
        let x = Tensor::from_data(5, 4, 3, g.fill(60, 2.0));
        let xq = QTensor::quantize(&x);
        let mean: Vec<f32> = (0..3)
            .map(|ci| {
                (0..5)
                    .flat_map(|y| (0..4).map(move |xx| (y, xx)))
                    .map(|(y, xx)| x.at(y, xx, ci))
                    .sum::<f32>()
                    / 20.0
            })
            .collect();
        let out_qp = QParams::observe(&mean);
        let mut acc = vec![0i8; 12];
        qgap_reset(&mut acc, 3);
        for row in xq.data.chunks_exact(4 * 3) {
            qgap_accumulate(&mut acc, row, 3);
        }
        qgap_finish(&mut acc, 3, 20, xq.qp, out_qp);
        for (ci, m) in mean.iter().enumerate() {
            let err = (out_qp.dequantize(acc[ci]) - m).abs();
            assert!(err < 2.0 * out_qp.scale + 2.0 * xq.qp.scale, "ch {ci}: {err}");
        }
    }

    #[test]
    fn band_read_fills_padding_with_zero_point() {
        let data: Vec<i8> = vec![1, 2, 3, 4, 5, 6];
        let m = QMapRef::new(3, 2, 1, &data);
        let mut buf = vec![9i8; 6];
        m.read_band_into(2, 3, &mut buf, -7);
        assert_eq!(buf, vec![5, 6, -7, -7, -7, -7]);
    }

    #[test]
    fn qresidual_add_matches_real_addition() {
        let a_qp = QParams::from_range(-2.0, 2.0);
        let b_qp = QParams::from_range(-1.0, 1.0);
        let mut out = vec![a_qp.quantize(0.5), a_qp.quantize(-1.0)];
        let stash = vec![b_qp.quantize(0.25), b_qp.quantize(0.75)];
        qresidual_add(&mut out, a_qp, &stash, b_qp);
        assert!((a_qp.dequantize(out[0]) - 0.75).abs() < 2.0 * a_qp.scale + b_qp.scale);
        assert!((a_qp.dequantize(out[1]) + 0.25).abs() < 2.0 * a_qp.scale + b_qp.scale);
    }
}
