//! Standard and depthwise convolution (HWC, zero padding).
//!
//! Weight layout matches the Python side: conv `[k][k][cin][cout]`,
//! depthwise `[k][k][c]` — so artifact cross-checks can share weights.

use crate::model::Activation;

use super::{activate, Tensor};

/// Standard conv2d. `w` is `[k,k,cin,cout]` flattened, `b` is `[cout]`.
pub fn conv2d(
    x: &Tensor,
    w: &[f32],
    b: &[f32],
    k: usize,
    stride: usize,
    padding: usize,
    cout: usize,
    act: Activation,
) -> Tensor {
    let cin = x.c;
    debug_assert_eq!(w.len(), k * k * cin * cout);
    debug_assert_eq!(b.len(), cout);
    let ho = (x.h + 2 * padding - k) / stride + 1;
    let wo = (x.w + 2 * padding - k) / stride + 1;
    let mut out = Tensor::zeros(ho, wo, cout);

    for oy in 0..ho {
        for ox in 0..wo {
            let base = (oy * wo + ox) * cout;
            let acc = &mut out.data[base..base + cout];
            acc.copy_from_slice(b);
            for ky in 0..k {
                let sy = (oy * stride + ky) as isize - padding as isize;
                if sy < 0 || sy as usize >= x.h {
                    continue;
                }
                for kx in 0..k {
                    let sx = (ox * stride + kx) as isize - padding as isize;
                    if sx < 0 || sx as usize >= x.w {
                        continue;
                    }
                    let xoff = ((sy as usize) * x.w + sx as usize) * cin;
                    let woff = (ky * k + kx) * cin * cout;
                    for ci in 0..cin {
                        let xv = x.data[xoff + ci];
                        let wrow = &w[woff + ci * cout..woff + (ci + 1) * cout];
                        for (a, wv) in acc.iter_mut().zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            }
        }
    }
    activate(&mut out.data, act);
    out
}

/// Depthwise conv2d. `w` is `[k,k,c]` flattened, `b` is `[c]`.
pub fn dwconv2d(
    x: &Tensor,
    w: &[f32],
    b: &[f32],
    k: usize,
    stride: usize,
    padding: usize,
    act: Activation,
) -> Tensor {
    let c = x.c;
    debug_assert_eq!(w.len(), k * k * c);
    debug_assert_eq!(b.len(), c);
    let ho = (x.h + 2 * padding - k) / stride + 1;
    let wo = (x.w + 2 * padding - k) / stride + 1;
    let mut out = Tensor::zeros(ho, wo, c);

    for oy in 0..ho {
        for ox in 0..wo {
            let base = (oy * wo + ox) * c;
            out.data[base..base + c].copy_from_slice(b);
            for ky in 0..k {
                let sy = (oy * stride + ky) as isize - padding as isize;
                if sy < 0 || sy as usize >= x.h {
                    continue;
                }
                for kx in 0..k {
                    let sx = (ox * stride + kx) as isize - padding as isize;
                    if sx < 0 || sx as usize >= x.w {
                        continue;
                    }
                    let xoff = ((sy as usize) * x.w + sx as usize) * c;
                    let woff = (ky * k + kx) * c;
                    for ci in 0..c {
                        out.data[base + ci] += x.data[xoff + ci] * w[woff + ci];
                    }
                }
            }
        }
    }
    activate(&mut out.data, act);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_1x1_conv() {
        // 1x1 conv with identity weights returns the input.
        let x = Tensor::from_data(2, 2, 2, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let w = vec![1., 0., 0., 1.]; // [1,1,2,2] identity
        let out = conv2d(&x, &w, &[0., 0.], 1, 1, 0, 2, Activation::None);
        assert_eq!(out.data, x.data);
    }

    #[test]
    fn known_3x3_sum_kernel() {
        // All-ones 3x3 kernel on all-ones input, no padding: every output
        // element is 9 (cin=1, cout=1).
        let x = Tensor::from_data(4, 4, 1, vec![1.0; 16]);
        let w = vec![1.0; 9];
        let out = conv2d(&x, &w, &[0.0], 3, 1, 0, 1, Activation::None);
        assert_eq!(out.h, 2);
        assert!(out.data.iter().all(|&v| (v - 9.0).abs() < 1e-6));
    }

    #[test]
    fn padding_shrinks_border_sums() {
        let x = Tensor::from_data(3, 3, 1, vec![1.0; 9]);
        let w = vec![1.0; 9];
        let out = conv2d(&x, &w, &[0.0], 3, 1, 1, 1, Activation::None);
        assert_eq!(out.h, 3);
        assert_eq!(out.at(0, 0, 0), 4.0); // corner sees 2x2 window
        assert_eq!(out.at(1, 1, 0), 9.0); // center sees all 9
        assert_eq!(out.at(0, 1, 0), 6.0); // edge sees 2x3
    }

    #[test]
    fn stride_subsamples() {
        let x = Tensor::from_data(5, 5, 1, (0..25).map(|i| i as f32).collect());
        let w = vec![1.0]; // 1x1 identity
        let out = conv2d(&x, &w, &[0.0], 1, 2, 0, 1, Activation::None);
        assert_eq!(out.h, 3);
        assert_eq!(out.at(1, 1, 0), x.at(2, 2, 0));
    }

    #[test]
    fn dwconv_is_per_channel() {
        // Two channels, channel 1 weighted 0: stays bias.
        let x = Tensor::from_data(3, 3, 2, (0..18).map(|i| i as f32).collect());
        let mut w = vec![0.0; 9 * 2];
        for ky in 0..3 {
            for kx in 0..3 {
                w[(ky * 3 + kx) * 2] = 1.0; // channel 0: sum kernel
            }
        }
        let out = dwconv2d(&x, &w, &[0.0, 7.0], 3, 1, 0, Activation::None);
        assert_eq!(out.h, 1);
        let ch0_sum: f32 = (0..9).map(|i| x.data[i * 2]).sum();
        assert_eq!(out.at(0, 0, 0), ch0_sum);
        assert_eq!(out.at(0, 0, 1), 7.0);
    }

    #[test]
    fn relu6_applied() {
        let x = Tensor::from_data(1, 1, 1, vec![100.0]);
        let out = conv2d(&x, &[1.0], &[0.0], 1, 1, 0, 1, Activation::Relu6);
        assert_eq!(out.data[0], 6.0);
    }
}
