//! Standard and depthwise convolution (HWC, zero padding).
//!
//! Weight layout matches the Python side: conv `[k][k][cin][cout]`,
//! depthwise `[k][k][c]` — so artifact cross-checks can share weights.

use crate::model::Activation;

use super::{activate, MapRef, Tensor};

/// Standard conv2d. `w` is `[k,k,cin,cout]` flattened, `b` is `[cout]`.
pub fn conv2d(
    x: &Tensor,
    w: &[f32],
    b: &[f32],
    k: usize,
    stride: usize,
    padding: usize,
    cout: usize,
    act: Activation,
) -> Tensor {
    let ho = (x.h + 2 * padding - k) / stride + 1;
    let wo = (x.w + 2 * padding - k) / stride + 1;
    let mut out = Tensor::zeros(ho, wo, cout);
    conv2d_into(x.as_map(), w, b, k, stride, padding, cout, act, &mut out.data);
    out
}

/// First output index whose whole `k`-tap window starts inside the map
/// (`o*stride - padding >= 0`), clamped to `n_out`.
#[inline]
pub(crate) fn interior_lo(stride: usize, padding: usize, n_out: usize) -> usize {
    ((padding + stride - 1) / stride).min(n_out)
}

/// One past the last output index whose whole `k`-tap window ends inside
/// a map of extent `n_in` (`o*stride - padding + k <= n_in`), clamped to
/// `n_out`. Empty (0) when even output 0's window overruns the map.
#[inline]
pub(crate) fn interior_hi(
    n_in: usize,
    k: usize,
    stride: usize,
    padding: usize,
    n_out: usize,
) -> usize {
    if n_in + padding >= k {
        ((n_in + padding - k) / stride + 1).min(n_out)
    } else {
        0
    }
}

/// Allocation-free [`conv2d`]: writes the `[ho, wo, cout]` output row-major
/// into `out` (a preallocated pool slice) — the compiled executor's
/// single-layer kernel.
///
/// Interior/halo decomposition: output pixels whose whole `k×k` window
/// lands inside the map take a branch-free path (the `k·cin` window row
/// is one contiguous slice, walked against contiguous `cout`-wide weight
/// rows), while the thin padded borders keep the guarded per-tap path
/// (moved verbatim to [`super::reference::conv2d_naive`]). Both paths
/// accumulate per output element in the same `(ky, kx, ci)` order and
/// fold the activation clamp into the per-pixel epilogue, so results
/// stay **bit-identical** to the naive reference — f32 summation order
/// is load-bearing (the compiled path is pinned bit-identical to the
/// interpreted engine).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_into(
    x: MapRef<'_>,
    w: &[f32],
    b: &[f32],
    k: usize,
    stride: usize,
    padding: usize,
    cout: usize,
    act: Activation,
    out: &mut [f32],
) {
    let cin = x.c;
    debug_assert_eq!(w.len(), k * k * cin * cout);
    debug_assert_eq!(b.len(), cout);
    let ho = (x.h + 2 * padding - k) / stride + 1;
    let wo = (x.w + 2 * padding - k) / stride + 1;
    debug_assert_eq!(out.len(), ho * wo * cout);

    let oy_lo = interior_lo(stride, padding, ho);
    let oy_hi = interior_hi(x.h, k, stride, padding, ho);
    let ox_lo = interior_lo(stride, padding, wo);
    let ox_hi = interior_hi(x.w, k, stride, padding, wo);

    // Halo path: per-tap bounds predicate, same loop nest as the naive
    // reference, activation fused per pixel (elementwise — identical to
    // the reference's trailing pass).
    let guarded = |acc: &mut [f32], oy: usize, ox: usize| {
        acc.copy_from_slice(b);
        for ky in 0..k {
            let sy = (oy * stride + ky) as isize - padding as isize;
            if sy < 0 || sy as usize >= x.h {
                continue;
            }
            for kx in 0..k {
                let sx = (ox * stride + kx) as isize - padding as isize;
                if sx < 0 || sx as usize >= x.w {
                    continue;
                }
                let xoff = ((sy as usize) * x.w + sx as usize) * cin;
                let woff = (ky * k + kx) * cin * cout;
                for ci in 0..cin {
                    let xv = x.data[xoff + ci];
                    let wrow = &w[woff + ci * cout..woff + (ci + 1) * cout];
                    for (a, wv) in acc.iter_mut().zip(wrow) {
                        *a += xv * wv;
                    }
                }
            }
        }
        activate(acc, act);
    };

    for oy in 0..ho {
        let row_base = oy * wo;
        if oy < oy_lo || oy >= oy_hi {
            for ox in 0..wo {
                let base = (row_base + ox) * cout;
                guarded(&mut out[base..base + cout], oy, ox);
            }
            continue;
        }
        let y0 = oy * stride - padding;
        for ox in 0..ox_lo {
            let base = (row_base + ox) * cout;
            guarded(&mut out[base..base + cout], oy, ox);
        }
        for ox in ox_lo..ox_hi {
            let base = (row_base + ox) * cout;
            let acc = &mut out[base..base + cout];
            acc.copy_from_slice(b);
            let x0 = ox * stride - padding;
            for ky in 0..k {
                let xrow = ((y0 + ky) * x.w + x0) * cin;
                let wrow = ky * k * cin;
                // The k horizontal taps collapse into one contiguous
                // k·cin walk; tap order stays (kx, ci) lexicographic.
                for (t, &xv) in x.data[xrow..xrow + k * cin].iter().enumerate() {
                    let ws = &w[(wrow + t) * cout..(wrow + t + 1) * cout];
                    for (a, wv) in acc.iter_mut().zip(ws) {
                        *a += xv * wv;
                    }
                }
            }
            activate(acc, act);
        }
        for ox in ox_hi.max(ox_lo)..wo {
            let base = (row_base + ox) * cout;
            guarded(&mut out[base..base + cout], oy, ox);
        }
    }
}

/// Depthwise conv2d. `w` is `[k,k,c]` flattened, `b` is `[c]`.
pub fn dwconv2d(
    x: &Tensor,
    w: &[f32],
    b: &[f32],
    k: usize,
    stride: usize,
    padding: usize,
    act: Activation,
) -> Tensor {
    let ho = (x.h + 2 * padding - k) / stride + 1;
    let wo = (x.w + 2 * padding - k) / stride + 1;
    let mut out = Tensor::zeros(ho, wo, x.c);
    dwconv2d_into(x.as_map(), w, b, k, stride, padding, act, &mut out.data);
    out
}

/// Allocation-free [`dwconv2d`] into a preallocated slice (bit-identical).
///
/// Same interior/halo decomposition as [`conv2d_into`]: branch-free
/// interior pixels walk `k` contiguous `k·c` window rows against the
/// matching weight rows; halo pixels keep the guarded per-tap path; the
/// activation folds into the per-pixel epilogue. Accumulation order per
/// element is `(ky, kx)` in both paths — bit-identical to
/// [`super::reference::dwconv2d_naive`].
#[allow(clippy::too_many_arguments)]
pub fn dwconv2d_into(
    x: MapRef<'_>,
    w: &[f32],
    b: &[f32],
    k: usize,
    stride: usize,
    padding: usize,
    act: Activation,
    out: &mut [f32],
) {
    let c = x.c;
    debug_assert_eq!(w.len(), k * k * c);
    debug_assert_eq!(b.len(), c);
    let ho = (x.h + 2 * padding - k) / stride + 1;
    let wo = (x.w + 2 * padding - k) / stride + 1;
    debug_assert_eq!(out.len(), ho * wo * c);

    let oy_lo = interior_lo(stride, padding, ho);
    let oy_hi = interior_hi(x.h, k, stride, padding, ho);
    let ox_lo = interior_lo(stride, padding, wo);
    let ox_hi = interior_hi(x.w, k, stride, padding, wo);

    let guarded = |acc: &mut [f32], oy: usize, ox: usize| {
        acc.copy_from_slice(b);
        for ky in 0..k {
            let sy = (oy * stride + ky) as isize - padding as isize;
            if sy < 0 || sy as usize >= x.h {
                continue;
            }
            for kx in 0..k {
                let sx = (ox * stride + kx) as isize - padding as isize;
                if sx < 0 || sx as usize >= x.w {
                    continue;
                }
                let xoff = ((sy as usize) * x.w + sx as usize) * c;
                let woff = (ky * k + kx) * c;
                let xs = &x.data[xoff..xoff + c];
                let ws = &w[woff..woff + c];
                for ((a, xv), wv) in acc.iter_mut().zip(xs).zip(ws) {
                    *a += xv * wv;
                }
            }
        }
        activate(acc, act);
    };

    for oy in 0..ho {
        let row_base = oy * wo;
        if oy < oy_lo || oy >= oy_hi {
            for ox in 0..wo {
                let base = (row_base + ox) * c;
                guarded(&mut out[base..base + c], oy, ox);
            }
            continue;
        }
        let y0 = oy * stride - padding;
        for ox in 0..ox_lo {
            let base = (row_base + ox) * c;
            guarded(&mut out[base..base + c], oy, ox);
        }
        for ox in ox_lo..ox_hi {
            let base = (row_base + ox) * c;
            let acc = &mut out[base..base + c];
            acc.copy_from_slice(b);
            let x0 = ox * stride - padding;
            for ky in 0..k {
                let xrow = ((y0 + ky) * x.w + x0) * c;
                let wrow = ky * k * c;
                for (kx, win) in x.data[xrow..xrow + k * c].chunks_exact(c).enumerate() {
                    let ws = &w[wrow + kx * c..wrow + (kx + 1) * c];
                    for ((a, xv), wv) in acc.iter_mut().zip(win).zip(ws) {
                        *a += xv * wv;
                    }
                }
            }
            activate(acc, act);
        }
        for ox in ox_hi.max(ox_lo)..wo {
            let base = (row_base + ox) * c;
            guarded(&mut out[base..base + c], oy, ox);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_1x1_conv() {
        // 1x1 conv with identity weights returns the input.
        let x = Tensor::from_data(2, 2, 2, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let w = vec![1., 0., 0., 1.]; // [1,1,2,2] identity
        let out = conv2d(&x, &w, &[0., 0.], 1, 1, 0, 2, Activation::None);
        assert_eq!(out.data, x.data);
    }

    #[test]
    fn known_3x3_sum_kernel() {
        // All-ones 3x3 kernel on all-ones input, no padding: every output
        // element is 9 (cin=1, cout=1).
        let x = Tensor::from_data(4, 4, 1, vec![1.0; 16]);
        let w = vec![1.0; 9];
        let out = conv2d(&x, &w, &[0.0], 3, 1, 0, 1, Activation::None);
        assert_eq!(out.h, 2);
        assert!(out.data.iter().all(|&v| (v - 9.0).abs() < 1e-6));
    }

    #[test]
    fn padding_shrinks_border_sums() {
        let x = Tensor::from_data(3, 3, 1, vec![1.0; 9]);
        let w = vec![1.0; 9];
        let out = conv2d(&x, &w, &[0.0], 3, 1, 1, 1, Activation::None);
        assert_eq!(out.h, 3);
        assert_eq!(out.at(0, 0, 0), 4.0); // corner sees 2x2 window
        assert_eq!(out.at(1, 1, 0), 9.0); // center sees all 9
        assert_eq!(out.at(0, 1, 0), 6.0); // edge sees 2x3
    }

    #[test]
    fn stride_subsamples() {
        let x = Tensor::from_data(5, 5, 1, (0..25).map(|i| i as f32).collect());
        let w = vec![1.0]; // 1x1 identity
        let out = conv2d(&x, &w, &[0.0], 1, 2, 0, 1, Activation::None);
        assert_eq!(out.h, 3);
        assert_eq!(out.at(1, 1, 0), x.at(2, 2, 0));
    }

    #[test]
    fn dwconv_is_per_channel() {
        // Two channels, channel 1 weighted 0: stays bias.
        let x = Tensor::from_data(3, 3, 2, (0..18).map(|i| i as f32).collect());
        let mut w = vec![0.0; 9 * 2];
        for ky in 0..3 {
            for kx in 0..3 {
                w[(ky * 3 + kx) * 2] = 1.0; // channel 0: sum kernel
            }
        }
        let out = dwconv2d(&x, &w, &[0.0, 7.0], 3, 1, 0, Activation::None);
        assert_eq!(out.h, 1);
        let ch0_sum: f32 = (0..9).map(|i| x.data[i * 2]).sum();
        assert_eq!(out.at(0, 0, 0), ch0_sum);
        assert_eq!(out.at(0, 0, 1), 7.0);
    }

    #[test]
    fn into_variants_are_bit_identical_on_pool_slices() {
        use crate::ops::ParamGen;
        let mut g = ParamGen::new(11);
        let x = Tensor::from_data(7, 6, 3, g.fill(7 * 6 * 3, 2.0));
        let w = g.fill(3 * 3 * 3 * 4, 0.5);
        let b = g.fill(4, 0.1);
        let owned = conv2d(&x, &w, &b, 3, 2, 1, 4, Activation::Relu6);
        let mut pool = vec![7.0f32; owned.data.len()];
        conv2d_into(x.as_map(), &w, &b, 3, 2, 1, 4, Activation::Relu6, &mut pool);
        assert_eq!(pool, owned.data);

        let wd = g.fill(3 * 3 * 3, 0.5);
        let bd = g.fill(3, 0.1);
        let owned = dwconv2d(&x, &wd, &bd, 3, 1, 1, Activation::Relu);
        let mut pool = vec![7.0f32; owned.data.len()];
        dwconv2d_into(x.as_map(), &wd, &bd, 3, 1, 1, Activation::Relu, &mut pool);
        assert_eq!(pool, owned.data);
    }

    #[test]
    fn relu6_applied() {
        let x = Tensor::from_data(1, 1, 1, vec![100.0]);
        let out = conv2d(&x, &[1.0], &[0.0], 1, 1, 0, 1, Activation::Relu6);
        assert_eq!(out.data[0], 6.0);
    }
}
