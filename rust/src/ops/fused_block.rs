//! Patch-based fused execution of a layer span — the measured counterpart
//! of the analytical model in [`crate::fusion`].
//!
//! One iteration produces **one row of the block's final output** (the
//! paper fixes output elements per iteration to one, §9). Per iteration
//! the required input row band is derived by walking the receptive-field
//! recursion backwards (including per-layer zero padding), then the band
//! pyramid is computed layer by layer entirely inside preallocated band
//! buffers — the H-cache scheme: horizontal positions are computed once
//! per band (full-width rows), vertical overlap between consecutive bands
//! is recomputed. Numerics are bit-comparable to layer-by-layer execution;
//! MACs are counted as performed so tests can reconcile the Eq. 12–15
//! predictions against reality.
//!
//! Since the compile-once refactor the band pyramid is **storage-
//! agnostic**: [`BandGeom`] describes the per-layer band shapes and their
//! element offsets inside one contiguous backing region, and [`HCache`]
//! *borrows* that region (plus a range-scratch slice) from whoever owns
//! it — a throwaway `Vec` in the interpreted [`crate::exec::Engine`] path,
//! or a fixed slice of the offset-assigned pool in
//! [`crate::exec::CompiledPlan`]'s allocation-free hot path.
//!
//! This mirrors the L1 Pallas kernel
//! (`python/compile/kernels/fused_conv.py`) — same streaming axis, same
//! recursion — so the three layers of the stack implement one schedule.

use crate::model::{Layer, LayerKind, ModelChain};

use super::{activate, LayerParams, MapRef, Tensor};

/// Observer of per-unit execution inside a fused span. One "unit" is one
/// block layer's band sweep (plus its zero-fill / residual bookkeeping);
/// the copy-out sink and the compiled executor's iterative-tail stages
/// (global pool finish, dense layers, logits copy) get unit indices of
/// their own. [`crate::obs::StepRecorder`] implements this to break an
/// opaque `fused[..)` profile step into per-layer latency rows; the hot
/// path passes [`NoUnitProfiler`] and pays nothing.
pub trait UnitProfiler {
    /// A unit's work is about to start.
    fn unit_begin(&mut self);
    /// The unit with index `unit` finished; `macs` is the work it did in
    /// this bracket (summed across streaming iterations by the observer).
    fn unit_end(&mut self, unit: usize, macs: u64);
}

/// Zero-cost [`UnitProfiler`]: every hook is an empty `#[inline(always)]`
/// body, so the unprofiled hot path compiles as if no hooks existed.
pub struct NoUnitProfiler;

impl UnitProfiler for NoUnitProfiler {
    #[inline(always)]
    fn unit_begin(&mut self) {}
    #[inline(always)]
    fn unit_end(&mut self, _unit: usize, _macs: u64) {}
}

/// Row range in *unpadded* coordinates of a boundary tensor; `start` may be
/// negative / extend past the map (zero padding rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BandRange {
    pub start: isize,
    pub rows: usize,
}

/// Input rows of `layer` needed to produce output rows `out`.
/// Shared with the quantized band executor ([`crate::qexec`]) so both
/// walk the identical receptive-field recursion.
pub(crate) fn required_input(layer: &Layer, out: BandRange) -> BandRange {
    let s = layer.stride as isize;
    let p = layer.padding as isize;
    BandRange {
        start: out.start * s - p,
        rows: (out.rows - 1) * layer.stride as usize + layer.k as usize,
    }
}

/// Shape of a fusion block's band pyramid: per-band `(rows, w, c)` dims
/// and element offsets into one contiguous f32 backing region.
/// `dims[i]` is the input band of block layer `i`; `dims[depth]` is the
/// final-output row band. Computed once at compile time
/// ([`FusedBlock::band_geom`]); iteration-invariant.
#[derive(Debug, Clone)]
pub struct BandGeom {
    /// `(rows, w, c)` of each band; index `depth` = output band.
    pub dims: Vec<(usize, usize, usize)>,
    /// Element offset of band `i` in the backing storage; the final entry
    /// (`offs[depth + 1]`) is the total element count.
    pub offs: Vec<usize>,
}

impl BandGeom {
    /// f32 elements the backing storage must provide.
    pub fn total_elems(&self) -> usize {
        *self.offs.last().unwrap()
    }

    /// Total bytes of all band buffers (the measured counterpart of the
    /// Eq. 11 `Buf` + input-strip terms, f32 storage sizing).
    pub fn bytes(&self) -> u64 {
        (self.total_elems() * 4) as u64
    }
}

/// The band-buffer state of one fused-block execution, **borrowing** its
/// storage: `storage` backs every band at the offsets in `geom`, and
/// `ranges` is the per-iteration row-range scratch (`depth + 1` entries).
/// Owning nothing is the point — the serving hot path hands in slices of
/// a preallocated pool and runs allocation-free.
pub struct HCache<'p> {
    geom: &'p BandGeom,
    storage: &'p mut [f32],
    ranges: &'p mut [BandRange],
}

impl<'p> HCache<'p> {
    /// Assemble a cache view over borrowed storage. `storage` must hold at
    /// least [`BandGeom::total_elems`] elements and `ranges` exactly
    /// `dims.len()` entries.
    pub fn new(geom: &'p BandGeom, storage: &'p mut [f32], ranges: &'p mut [BandRange]) -> Self {
        assert!(storage.len() >= geom.total_elems(), "band storage too small");
        assert_eq!(ranges.len(), geom.dims.len(), "range scratch length mismatch");
        Self { geom, storage, ranges }
    }

    /// Total bytes of all band buffers.
    pub fn bytes(&self) -> u64 {
        self.geom.bytes()
    }
}

/// Statistics of one fused-block execution.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BlockStats {
    /// Multiply-accumulates actually performed.
    pub macs: u64,
    /// Bytes of band buffers held live during the block.
    pub cache_bytes: u64,
    /// Iterations (final output rows) executed.
    pub iterations: u64,
}

/// Executes layers `[a, b)` of `model` patch-by-patch.
pub struct FusedBlock<'m> {
    model: &'m ModelChain,
    a: usize,
    b: usize,
    params: &'m [LayerParams],
}

/// Read-only view of one band inside the pyramid.
#[derive(Clone, Copy)]
struct BandIn<'a> {
    w: usize,
    c: usize,
    data: &'a [f32],
}

/// Mutable view of one band inside the pyramid.
struct BandOut<'a> {
    h: usize,
    w: usize,
    c: usize,
    data: &'a mut [f32],
}

impl<'m> FusedBlock<'m> {
    /// `params[i]` must be the parameters of model layer `i` (absolute
    /// indexing, same generator as the vanilla path).
    pub fn new(model: &'m ModelChain, a: usize, b: usize, params: &'m [LayerParams]) -> Self {
        assert!(model.fusable_span(a, b), "span [{a},{b}) is not fusable");
        Self { model, a, b, params }
    }

    /// Per-iteration band row ranges for final output row `r`:
    /// `ranges[depth]` = the output row, `ranges[0]` = input band of the
    /// first layer.
    fn ranges_for(&self, r: usize) -> Vec<BandRange> {
        let depth = self.b - self.a;
        let mut ranges = vec![BandRange { start: 0, rows: 0 }; depth + 1];
        ranges[depth] = BandRange { start: r as isize, rows: 1 };
        for idx in (0..depth).rev() {
            ranges[idx] = required_input(&self.model.layers[self.a + idx], ranges[idx + 1]);
        }
        ranges
    }

    /// The block's band-pyramid geometry (band sizes are iteration-
    /// invariant; one output row per iteration).
    pub fn band_geom(&self) -> BandGeom {
        let depth = self.b - self.a;
        let ranges0 = self.ranges_for(0);
        let out_shape = self.model.output_of(self.b - 1);
        let mut dims = Vec::with_capacity(depth + 1);
        for (idx, r0) in ranges0.iter().enumerate() {
            let shape = if idx < depth {
                self.model.input_of(self.a + idx)
            } else {
                out_shape
            };
            dims.push((r0.rows, shape.w as usize, shape.c as usize));
        }
        let mut offs = Vec::with_capacity(depth + 2);
        offs.push(0usize);
        for &(r, w, c) in &dims {
            offs.push(offs.last().unwrap() + r * w * c);
        }
        BandGeom { dims, offs }
    }

    /// Run the block over `source` (the full `v_a` map — *streamed*: only
    /// row bands are read, never the whole map at once) inside the
    /// borrowed `cache`, calling `sink(row_index, row_data)` for each
    /// produced final output row (`row_data` is the `w*c` row-major output
    /// band). Zero heap allocations: every buffer the pyramid touches is
    /// borrowed through `cache`.
    pub fn run_streaming_in(
        &self,
        source: MapRef<'_>,
        cache: HCache<'_>,
        sink: impl FnMut(usize, &[f32]),
    ) -> BlockStats {
        self.run_streaming_units(source, cache, sink, &mut NoUnitProfiler)
    }

    /// [`Self::run_streaming_in`] with per-unit observation: block layer
    /// `idx` is bracketed as unit `idx` (including its zero-fill and
    /// residual bookkeeping) and the sink as unit `depth`, every
    /// streaming iteration — so a [`UnitProfiler`] accumulates where the
    /// time inside the fused span actually goes. With
    /// [`NoUnitProfiler`] this *is* the hot path (the hooks vanish).
    pub fn run_streaming_units<U: UnitProfiler>(
        &self,
        source: MapRef<'_>,
        cache: HCache<'_>,
        mut sink: impl FnMut(usize, &[f32]),
        prof: &mut U,
    ) -> BlockStats {
        let out_shape = self.model.output_of(self.b - 1);
        let h_out = out_shape.h as usize;
        let depth = self.b - self.a;
        let mut stats = BlockStats {
            cache_bytes: cache.bytes(),
            ..BlockStats::default()
        };
        let HCache { geom, storage, ranges } = cache;

        for r in 0..h_out {
            ranges[depth] = BandRange { start: r as isize, rows: 1 };
            for idx in (0..depth).rev() {
                ranges[idx] = required_input(&self.model.layers[self.a + idx], ranges[idx + 1]);
            }
            // Materialize the first band from the streamed source.
            source.read_band_into(
                ranges[0].start,
                ranges[0].rows,
                &mut storage[geom.offs[0]..geom.offs[1]],
            );

            for idx in 0..depth {
                let li = self.a + idx;
                let layer = &self.model.layers[li];
                let h_map = if idx + 1 < depth {
                    self.model.input_of(li + 1).h as usize
                } else {
                    h_out
                };
                let (head, tail) = storage.split_at_mut(geom.offs[idx + 1]);
                let (_, in_w, in_c) = geom.dims[idx];
                let (out_rows, out_w, out_c) = geom.dims[idx + 1];
                let in_band = BandIn { w: in_w, c: in_c, data: &head[geom.offs[idx]..] };
                let mut out_band = BandOut {
                    h: out_rows,
                    w: out_w,
                    c: out_c,
                    data: &mut tail[..out_rows * out_w * out_c],
                };
                // Only rows inside the real map are computed; rows that are
                // the next layer's padding are zero-filled without work
                // (keeps measured MACs aligned with Eq. 12–15 and skips
                // wasted convolution at the map edges).
                let r_out = ranges[idx + 1];
                let lo = (-r_out.start).max(0) as usize;
                let hi = (h_map as isize - r_out.start).clamp(0, r_out.rows as isize) as usize;
                prof.unit_begin();
                let layer_macs = band_layer(
                    layer,
                    &self.params[li],
                    in_band,
                    &mut out_band,
                    lo,
                    hi.max(lo),
                );
                stats.macs += layer_macs;
                // Zero rows that fall outside the real map: they are the
                // next layer's padding rows and must be exactly 0.
                zero_outside(&mut out_band, r_out, h_map);
                // Residual add from inside the block (stride-1 spans):
                // src < current layer, so its band lives in `head`.
                if let Some(src) = layer.residual_from {
                    if src >= self.a && src < self.b {
                        let src_idx = src - self.a;
                        let (src_rows, src_w, src_c) = geom.dims[src_idx];
                        let src_band = BandIn {
                            w: src_w,
                            c: src_c,
                            data: &head[geom.offs[src_idx]
                                ..geom.offs[src_idx] + src_rows * src_w * src_c],
                        };
                        add_aligned(src_band, ranges[src_idx], &mut out_band, ranges[idx + 1]);
                    }
                }
                prof.unit_end(idx, layer_macs);
            }
            let (out_rows, out_w, out_c) = geom.dims[depth];
            let out_lo = geom.offs[depth];
            prof.unit_begin();
            sink(r, &storage[out_lo..out_lo + out_rows * out_w * out_c]);
            prof.unit_end(depth, 0);
            stats.iterations += 1;
        }
        stats
    }

    /// Convenience over [`Self::run_streaming_in`] with throwaway owned
    /// scratch — the interpreted engine's path (the compiled path borrows
    /// pool slices instead).
    pub fn run_streaming(
        &self,
        source: &Tensor,
        sink: impl FnMut(usize, &[f32]),
    ) -> BlockStats {
        let geom = self.band_geom();
        let mut storage = vec![0.0f32; geom.total_elems()];
        let mut ranges = vec![BandRange { start: 0, rows: 0 }; geom.dims.len()];
        self.run_streaming_in(
            source.as_map(),
            HCache::new(&geom, &mut storage, &mut ranges),
            sink,
        )
    }

    /// Convenience: run the block and materialize the full output map.
    pub fn run(&self, source: &Tensor) -> (Tensor, BlockStats) {
        let out_shape = self.model.output_of(self.b - 1);
        let mut out = Tensor::from_shape(out_shape);
        let wo = out.w;
        let co = out.c;
        let stats = self.run_streaming(source, |r, row| {
            let dst = r * wo * co;
            out.data[dst..dst + wo * co].copy_from_slice(&row[..wo * co]);
        });
        (out, stats)
    }
}

/// Compute band-local output rows `[row_lo, row_hi)` of `layer` from
/// `in_band` into `out_band` (vertical padding pre-materialized in the
/// band; horizontal padding applied here). Returns MACs performed.
fn band_layer(
    layer: &Layer,
    params: &LayerParams,
    in_band: BandIn<'_>,
    out_band: &mut BandOut<'_>,
    row_lo: usize,
    row_hi: usize,
) -> u64 {
    let k = layer.k as usize;
    let s = layer.stride as usize;
    let p = layer.padding as usize;
    let cin = in_band.c;
    let wo = (in_band.w + 2 * p - k) / s + 1;
    debug_assert!(out_band.w == wo && out_band.h >= row_hi);
    let cout = out_band.c;

    match layer.kind {
        LayerKind::Conv2d if k == 1 && p == 0 && s == 1 => {
            // Perf iteration 2: pointwise fast path - a row-level GEMV
            // with no window bookkeeping. The MBV2/MCUNet expand/project
            // layers put most MACs here. Activation folds into the
            // per-pixel epilogue (elementwise — identical to a trailing
            // full-slice pass).
            let w = &params.weights; // [cin][cout]
            for oy in row_lo..row_hi {
                for ox in 0..wo {
                    let base = (oy * wo + ox) * cout;
                    let acc = &mut out_band.data[base..base + cout];
                    acc.copy_from_slice(&params.bias);
                    let xoff = (oy * in_band.w + ox) * cin;
                    for ci in 0..cin {
                        let xv = in_band.data[xoff + ci];
                        if xv == 0.0 {
                            continue; // relu sparsity: skip dead activations
                        }
                        let wrow = &w[ci * cout..(ci + 1) * cout];
                        for (a, wv) in acc.iter_mut().zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                    activate(acc, layer.act);
                }
            }
            ((row_hi - row_lo) * wo * cout * cin) as u64
        }
        LayerKind::Conv2d => {
            // Vertical padding is pre-materialized in the band, so only a
            // horizontal interior/halo split is needed: interior columns
            // walk the contiguous k·cin window row branch-free (same
            // (ky, kx, ci) accumulation order — bit-identical), the two
            // padded edges keep the guarded path.
            let w = &params.weights;
            let ox_lo = super::conv::interior_lo(s, p, wo);
            let ox_hi = super::conv::interior_hi(in_band.w, k, s, p, wo);
            for oy in row_lo..row_hi {
                let edge = |data: &mut [f32], ox: usize| {
                    let base = (oy * wo + ox) * cout;
                    data[base..base + cout].copy_from_slice(&params.bias);
                    for ky in 0..k {
                        let sy = oy * s + ky; // vertical pad already in band
                        for kx in 0..k {
                            let sx = (ox * s + kx) as isize - p as isize;
                            if sx < 0 || sx as usize >= in_band.w {
                                continue;
                            }
                            let xoff = (sy * in_band.w + sx as usize) * cin;
                            let woff = (ky * k + kx) * cin * cout;
                            for ci in 0..cin {
                                let xv = in_band.data[xoff + ci];
                                let wrow = &w[woff + ci * cout..woff + (ci + 1) * cout];
                                for (acc, wv) in data[base..base + cout].iter_mut().zip(wrow) {
                                    *acc += xv * wv;
                                }
                            }
                        }
                    }
                    activate(&mut data[base..base + cout], layer.act);
                };
                for ox in 0..ox_lo {
                    edge(&mut *out_band.data, ox);
                }
                for ox in ox_lo..ox_hi {
                    let base = (oy * wo + ox) * cout;
                    let acc = &mut out_band.data[base..base + cout];
                    acc.copy_from_slice(&params.bias);
                    let x0 = ox * s - p;
                    for ky in 0..k {
                        let xrow = ((oy * s + ky) * in_band.w + x0) * cin;
                        let wrow = ky * k * cin;
                        for (t, &xv) in in_band.data[xrow..xrow + k * cin].iter().enumerate() {
                            let ws = &w[(wrow + t) * cout..(wrow + t + 1) * cout];
                            for (a, wv) in acc.iter_mut().zip(ws) {
                                *a += xv * wv;
                            }
                        }
                    }
                    activate(acc, layer.act);
                }
                for ox in ox_hi.max(ox_lo)..wo {
                    edge(&mut *out_band.data, ox);
                }
            }
            ((row_hi - row_lo) * wo * cout * k * k * cin) as u64
        }
        LayerKind::DwConv2d => {
            // Perf iteration 3: split interior columns (no horizontal
            // clamping possible) from the two padded edges, removing the
            // per-element bounds branch from the k*k inner loop.
            let w = &params.weights;
            // Interior: ox*s + kx - p in [0, w) for all kx in [0, k).
            let ox_lo = super::conv::interior_lo(s, p, wo);
            let ox_hi = super::conv::interior_hi(in_band.w, k, s, p, wo);
            for oy in row_lo..row_hi {
                let edge = |data: &mut [f32], ox: usize| {
                    let base = (oy * wo + ox) * cout;
                    data[base..base + cout].copy_from_slice(&params.bias);
                    for ky in 0..k {
                        let sy = oy * s + ky;
                        for kx in 0..k {
                            let sx = (ox * s + kx) as isize - p as isize;
                            if sx < 0 || sx as usize >= in_band.w {
                                continue;
                            }
                            let xoff = (sy * in_band.w + sx as usize) * cin;
                            let woff = (ky * k + kx) * cin;
                            for ci in 0..cin {
                                data[base + ci] += in_band.data[xoff + ci] * w[woff + ci];
                            }
                        }
                    }
                    activate(&mut data[base..base + cout], layer.act);
                };
                for ox in 0..ox_lo {
                    edge(&mut *out_band.data, ox);
                }
                for ox in ox_lo..ox_hi {
                    let base = (oy * wo + ox) * cout;
                    let acc = &mut out_band.data[base..base + cout];
                    acc.copy_from_slice(&params.bias);
                    let x0 = ox * s - p;
                    for ky in 0..k {
                        let sy = oy * s + ky;
                        let row = (sy * in_band.w + x0) * cin;
                        let wrow = ky * k * cin;
                        for kx in 0..k {
                            let xs = &in_band.data[row + kx * cin..row + (kx + 1) * cin];
                            let ws = &w[wrow + kx * cin..wrow + (kx + 1) * cin];
                            for ((a, xv), wv) in acc.iter_mut().zip(xs).zip(ws) {
                                *a += xv * wv;
                            }
                        }
                    }
                    activate(acc, layer.act);
                }
                for ox in ox_hi.max(ox_lo)..wo {
                    edge(&mut *out_band.data, ox);
                }
            }
            ((row_hi - row_lo) * wo * cout * k * k) as u64
        }
        LayerKind::AvgPool | LayerKind::MaxPool => {
            // Pools are unpadded here, so every window row is one
            // contiguous k·cin slice — row-slice iteration as in
            // `avg_pool2d_into`, no per-element channel offsets.
            let is_avg = matches!(layer.kind, LayerKind::AvgPool);
            let inv = 1.0 / (k * k) as f32;
            for oy in row_lo..row_hi {
                for ox in 0..wo {
                    let base = (oy * wo + ox) * cout;
                    let acc = &mut out_band.data[base..base + cout];
                    acc.fill(if is_avg { 0.0 } else { f32::NEG_INFINITY });
                    for ky in 0..k {
                        let row = ((oy * s + ky) * in_band.w + ox * s) * cin;
                        for win in in_band.data[row..row + k * cin].chunks_exact(cin) {
                            if is_avg {
                                for (a, v) in acc.iter_mut().zip(win) {
                                    *a += v * inv;
                                }
                            } else {
                                for (a, v) in acc.iter_mut().zip(win) {
                                    *a = a.max(*v);
                                }
                            }
                        }
                    }
                }
            }
            ((row_hi - row_lo) * wo * cout * k * k) as u64
        }
        _ => unreachable!("non-streamable layer inside fused block"),
    }
}

/// Zero band rows whose absolute index lies outside `[0, h_map)`.
fn zero_outside(band: &mut BandOut<'_>, range: BandRange, h_map: usize) {
    let rowlen = band.w * band.c;
    for row in 0..range.rows {
        let abs = range.start + row as isize;
        if abs < 0 || abs as usize >= h_map {
            let off = row * rowlen;
            band.data[off..off + rowlen].fill(0.0);
        }
    }
}

/// `dst[rows of dst_range] += src[same absolute rows]` (residual add).
fn add_aligned(
    src: BandIn<'_>,
    src_range: BandRange,
    dst: &mut BandOut<'_>,
    dst_range: BandRange,
) {
    debug_assert_eq!(src.w, dst.w);
    debug_assert_eq!(src.c, dst.c);
    let rowlen = dst.w * dst.c;
    for row in 0..dst_range.rows {
        let abs = dst_range.start + row as isize;
        let s_row = abs - src_range.start;
        if s_row < 0 || s_row as usize >= src_range.rows {
            continue; // outside the stashed band: padding rows, add 0
        }
        let soff = s_row as usize * rowlen;
        let doff = row * rowlen;
        for i in 0..rowlen {
            dst.data[doff + i] += src.data[soff + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorShape;
    use crate::ops::{conv2d, dwconv2d, ParamGen};

    fn run_vanilla(model: &ModelChain, params: &[LayerParams], input: &Tensor) -> Tensor {
        let mut cur = input.clone();
        let mut stash: Vec<Option<Tensor>> = vec![None; model.num_layers() + 1];
        for (i, l) in model.layers.iter().enumerate() {
            for (j, ll) in model.layers.iter().enumerate() {
                if ll.residual_from == Some(i) && j >= i {
                    stash[i] = Some(cur.clone());
                }
            }
            let mut out = match l.kind {
                LayerKind::Conv2d => conv2d(
                    &cur,
                    &params[i].weights,
                    &params[i].bias,
                    l.k as usize,
                    l.stride as usize,
                    l.padding as usize,
                    l.cout as usize,
                    l.act,
                ),
                LayerKind::DwConv2d => dwconv2d(
                    &cur,
                    &params[i].weights,
                    &params[i].bias,
                    l.k as usize,
                    l.stride as usize,
                    l.padding as usize,
                    l.act,
                ),
                LayerKind::AvgPool => crate::ops::avg_pool2d(&cur, l.k as usize, l.stride as usize),
                LayerKind::MaxPool => crate::ops::max_pool2d(&cur, l.k as usize, l.stride as usize),
                _ => break,
            };
            if let Some(src) = l.residual_from {
                let st = stash[src].as_ref().expect("stash");
                for (o, s) in out.data.iter_mut().zip(&st.data) {
                    *o += s;
                }
            }
            cur = out;
        }
        cur
    }

    fn rand_input(shape: TensorShape, seed: u64) -> Tensor {
        let mut g = ParamGen::new(seed);
        let n = shape.elems() as usize;
        Tensor::from_data(
            shape.h as usize,
            shape.w as usize,
            shape.c as usize,
            g.fill(n, 2.0),
        )
    }

    fn params_for(model: &ModelChain) -> Vec<LayerParams> {
        model
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| LayerParams::for_layer(l, i))
            .collect()
    }

    #[test]
    fn fused_equals_vanilla_valid_convs() {
        use crate::model::{Activation, Layer};
        let m = ModelChain::new(
            "t",
            TensorShape::new(17, 13, 3),
            vec![
                Layer::conv("c0", 3, 1, 0, 3, 6, Activation::Relu6),
                Layer::conv("c1", 3, 2, 0, 6, 4, Activation::None),
            ],
        );
        let p = params_for(&m);
        let x = rand_input(m.shapes[0], 1);
        let expect = run_vanilla(&m, &p, &x);
        let (got, stats) = FusedBlock::new(&m, 0, 2, &p).run(&x);
        assert_eq!(got.shape(), expect.shape());
        assert!(got.max_abs_diff(&expect) < 1e-4);
        assert_eq!(stats.iterations as u32, m.output_of(1).h);
    }

    #[test]
    fn fused_equals_vanilla_with_padding_and_dw() {
        use crate::model::{Activation, Layer};
        let m = ModelChain::new(
            "t",
            TensorShape::new(16, 16, 4),
            vec![
                Layer::conv("c0", 3, 2, 1, 4, 8, Activation::Relu6),
                Layer::dwconv("d1", 3, 1, 1, 8, Activation::Relu6),
                Layer::pointwise("p2", 8, 6, Activation::None),
            ],
        );
        let p = params_for(&m);
        let x = rand_input(m.shapes[0], 2);
        let expect = run_vanilla(&m, &p, &x);
        let (got, _) = FusedBlock::new(&m, 0, 3, &p).run(&x);
        assert!(got.max_abs_diff(&expect) < 1e-4, "diff {}", got.max_abs_diff(&expect));
    }

    #[test]
    fn fused_equals_vanilla_with_pool_member() {
        use crate::model::{Activation, Layer};
        let m = ModelChain::new(
            "t",
            TensorShape::new(12, 12, 2),
            vec![
                Layer::conv("c0", 3, 1, 0, 2, 4, Activation::Relu),
                Layer::avg_pool("pl", 2, 2, 4),
            ],
        );
        let p = params_for(&m);
        let x = rand_input(m.shapes[0], 3);
        let expect = run_vanilla(&m, &p, &x);
        let (got, _) = FusedBlock::new(&m, 0, 2, &p).run(&x);
        assert!(got.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn fused_handles_internal_residual() {
        use crate::model::{Activation, Layer};
        let m = ModelChain::new(
            "res",
            TensorShape::new(10, 10, 6),
            vec![
                Layer::pointwise("expand", 6, 12, Activation::Relu6),
                Layer::dwconv("dw", 3, 1, 1, 12, Activation::Relu6),
                Layer::pointwise("project", 12, 6, Activation::None).with_residual(0),
            ],
        );
        let p = params_for(&m);
        let x = rand_input(m.shapes[0], 4);
        let expect = run_vanilla(&m, &p, &x);
        let (got, _) = FusedBlock::new(&m, 0, 3, &p).run(&x);
        assert!(got.max_abs_diff(&expect) < 1e-4, "diff {}", got.max_abs_diff(&expect));
    }

    #[test]
    fn fused_macs_match_analytical_model() {
        use crate::model::{Activation, Layer};
        let m = ModelChain::new(
            "t",
            TensorShape::new(20, 20, 3),
            vec![
                Layer::conv("c0", 3, 1, 1, 3, 6, Activation::Relu6),
                Layer::conv("c1", 3, 1, 1, 6, 4, Activation::Relu6),
            ],
        );
        let p = params_for(&m);
        let x = rand_input(m.shapes[0], 5);
        let (_, stats) = FusedBlock::new(&m, 0, 2, &p).run(&x);
        let predicted = crate::fusion::block_macs(&m, 0, 2);
        let ratio = stats.macs as f64 / predicted as f64;
        assert!(
            (0.9..=1.1).contains(&ratio),
            "measured {} vs predicted {predicted} (ratio {ratio})",
            stats.macs
        );
    }

    #[test]
    fn deep_stride_chain_correct() {
        use crate::model::{Activation, Layer};
        let m = ModelChain::new(
            "deep",
            TensorShape::new(33, 29, 3),
            vec![
                Layer::conv("c0", 3, 2, 1, 3, 4, Activation::Relu6),
                Layer::conv("c1", 3, 1, 0, 4, 4, Activation::Relu6),
                Layer::conv("c2", 3, 2, 1, 4, 8, Activation::None),
                Layer::conv("c3", 1, 1, 0, 8, 5, Activation::Relu6),
            ],
        );
        let p = params_for(&m);
        let x = rand_input(m.shapes[0], 6);
        let expect = run_vanilla(&m, &p, &x);
        let (got, _) = FusedBlock::new(&m, 0, 4, &p).run(&x);
        assert!(got.max_abs_diff(&expect) < 1e-4, "diff {}", got.max_abs_diff(&expect));
    }

    #[test]
    fn borrowed_cache_matches_owned_scratch_bitwise() {
        use crate::model::{Activation, Layer};
        let m = ModelChain::new(
            "pool-borrow",
            TensorShape::new(14, 11, 3),
            vec![
                Layer::conv("c0", 3, 1, 1, 3, 5, Activation::Relu6),
                Layer::dwconv("d1", 3, 2, 1, 5, Activation::Relu6),
            ],
        );
        let p = params_for(&m);
        let x = rand_input(m.shapes[0], 7);
        let block = FusedBlock::new(&m, 0, 2, &p);
        let (owned, owned_stats) = block.run(&x);

        // Same block through an explicitly borrowed, oversized, dirty pool
        // slice — the compiled executor's calling convention.
        let geom = block.band_geom();
        let mut pool = vec![3.5f32; geom.total_elems() + 32];
        let mut ranges = vec![BandRange { start: 0, rows: 0 }; geom.dims.len()];
        let mut got = Tensor::from_shape(m.output_of(1));
        let (wo, co) = (got.w, got.c);
        let stats = block.run_streaming_in(
            x.as_map(),
            HCache::new(&geom, &mut pool[..geom.total_elems()], &mut ranges),
            |r, row| got.data[r * wo * co..(r + 1) * wo * co].copy_from_slice(&row[..wo * co]),
        );
        assert_eq!(got.data, owned.data, "borrowed cache diverged");
        assert_eq!(stats, owned_stats);
        assert_eq!(geom.bytes(), stats.cache_bytes);
    }
}
