//! Patch-based fused execution of a layer span — the measured counterpart
//! of the analytical model in [`crate::fusion`].
//!
//! One iteration produces **one row of the block's final output** (the
//! paper fixes output elements per iteration to one, §9). Per iteration
//! the required input row band is derived by walking the receptive-field
//! recursion backwards (including per-layer zero padding), then the band
//! pyramid is computed layer by layer entirely inside preallocated band
//! buffers — the H-cache scheme: horizontal positions are computed once
//! per band (full-width rows), vertical overlap between consecutive bands
//! is recomputed. Numerics are bit-comparable to layer-by-layer execution;
//! MACs are counted as performed so tests can reconcile the Eq. 12–15
//! predictions against reality.
//!
//! This mirrors the L1 Pallas kernel
//! (`python/compile/kernels/fused_conv.py`) — same streaming axis, same
//! recursion — so the three layers of the stack implement one schedule.

use crate::model::{Layer, LayerKind, ModelChain};

use super::{activate, LayerParams, Tensor};

/// Row range in *unpadded* coordinates of a boundary tensor; `start` may be
/// negative / extend past the map (zero padding rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BandRange {
    pub start: isize,
    pub rows: usize,
}

/// Input rows of `layer` needed to produce output rows `out`.
fn required_input(layer: &Layer, out: BandRange) -> BandRange {
    let s = layer.stride as isize;
    let p = layer.padding as isize;
    BandRange {
        start: out.start * s - p,
        rows: (out.rows - 1) * layer.stride as usize + layer.k as usize,
    }
}

/// The per-layer band buffers of a fusion block — the executor's concrete
/// "H-cache" state. `bands[i]` holds the input band of block layer `i`;
/// `bands[depth]` holds the final output rows of one iteration.
pub struct HCache {
    pub bands: Vec<Tensor>,
    /// Unpadded row ranges each band currently represents.
    pub ranges: Vec<BandRange>,
}

impl HCache {
    /// Total bytes of all band buffers (the measured counterpart of the
    /// Eq. 11 `Buf` + input-strip terms).
    pub fn bytes(&self) -> u64 {
        self.bands.iter().map(|b| (b.elems() * 4) as u64).sum()
    }
}

/// Statistics of one fused-block execution.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BlockStats {
    /// Multiply-accumulates actually performed.
    pub macs: u64,
    /// Bytes of band buffers held live during the block.
    pub cache_bytes: u64,
    /// Iterations (final output rows) executed.
    pub iterations: u64,
}

/// Executes layers `[a, b)` of `model` patch-by-patch.
pub struct FusedBlock<'m> {
    model: &'m ModelChain,
    a: usize,
    b: usize,
    params: &'m [LayerParams],
}

impl<'m> FusedBlock<'m> {
    /// `params[i]` must be the parameters of model layer `i` (absolute
    /// indexing, same generator as the vanilla path).
    pub fn new(model: &'m ModelChain, a: usize, b: usize, params: &'m [LayerParams]) -> Self {
        assert!(model.fusable_span(a, b), "span [{a},{b}) is not fusable");
        Self { model, a, b, params }
    }

    /// Per-iteration band row ranges for final output row `r`:
    /// `ranges[depth]` = the output row, `ranges[0]` = input band of the
    /// first layer.
    fn ranges_for(&self, r: usize) -> Vec<BandRange> {
        let depth = self.b - self.a;
        let mut ranges = vec![BandRange { start: 0, rows: 0 }; depth + 1];
        ranges[depth] = BandRange { start: r as isize, rows: 1 };
        for idx in (0..depth).rev() {
            ranges[idx] = required_input(&self.model.layers[self.a + idx], ranges[idx + 1]);
        }
        ranges
    }

    /// Run the block over `source` (the full `v_a` map — *streamed*: only
    /// `row_band` slices are read, never the whole map at once), calling
    /// `sink(row_index, row_tensor)` for each produced final output row.
    /// Returns execution stats.
    pub fn run_streaming(
        &self,
        source: &Tensor,
        mut sink: impl FnMut(usize, &Tensor),
    ) -> BlockStats {
        let out_shape = self.model.output_of(self.b - 1);
        let h_out = out_shape.h as usize;
        let depth = self.b - self.a;
        let mut stats = BlockStats::default();

        // Preallocate band buffers (sizes are iteration-invariant).
        let ranges0 = self.ranges_for(0);
        let mut cache = HCache {
            bands: (0..=depth)
                .map(|idx| {
                    let shape = if idx < depth {
                        self.model.input_of(self.a + idx)
                    } else {
                        out_shape
                    };
                    Tensor::zeros(ranges0[idx].rows, shape.w as usize, shape.c as usize)
                })
                .collect(),
            ranges: ranges0,
        };
        stats.cache_bytes = cache.bytes();

        // Perf iteration 1: reuse one ranges vector and the preallocated
        // first band across iterations - zero allocations in the hot loop.
        let mut ranges = cache.ranges.clone();
        for r in 0..h_out {
            ranges[depth] = BandRange { start: r as isize, rows: 1 };
            for idx in (0..depth).rev() {
                ranges[idx] = required_input(&self.model.layers[self.a + idx], ranges[idx + 1]);
            }
            // Materialize the first band from the streamed source.
            source.row_band_into(ranges[0].start, ranges[0].rows, &mut cache.bands[0]);
            cache.ranges.copy_from_slice(&ranges);

            for idx in 0..depth {
                let li = self.a + idx;
                let layer = &self.model.layers[li];
                let out_rows = ranges[idx + 1].rows;
                let h_map = if idx + 1 < depth {
                    self.model.input_of(li + 1).h as usize
                } else {
                    h_out
                };
                let (head, tail) = cache.bands.split_at_mut(idx + 1);
                let in_band = &head[idx];
                let out_band = &mut tail[0];
                // Only rows inside the real map are computed; rows that are
                // the next layer's padding are zero-filled without work
                // (keeps measured MACs aligned with Eq. 12–15 and skips
                // wasted convolution at the map edges).
                let r_out = ranges[idx + 1];
                let lo = (-r_out.start).max(0) as usize;
                let hi = (h_map as isize - r_out.start).clamp(0, r_out.rows as isize) as usize;
                stats.macs += band_layer(
                    layer,
                    &self.params[li],
                    in_band,
                    out_band,
                    lo,
                    hi.max(lo),
                );
                // Zero rows that fall outside the real map: they are the
                // next layer's padding rows and must be exactly 0.
                zero_outside(out_band, r_out, h_map);
                let _ = out_rows;
                // Residual add from inside the block (stride-1 spans):
                // src < current layer, so its band lives in `head`.
                if let Some(src) = layer.residual_from {
                    if src >= self.a && src < self.b {
                        let src_idx = src - self.a;
                        add_aligned(&head[src_idx], ranges[src_idx], out_band, ranges[idx + 1]);
                    }
                }
            }
            sink(r, &cache.bands[depth]);
            stats.iterations += 1;
        }
        stats
    }

    /// Convenience: run the block and materialize the full output map.
    pub fn run(&self, source: &Tensor) -> (Tensor, BlockStats) {
        let out_shape = self.model.output_of(self.b - 1);
        let mut out = Tensor::from_shape(out_shape);
        let wo = out.w;
        let co = out.c;
        let stats = self.run_streaming(source, |r, row| {
            let dst = r * wo * co;
            out.data[dst..dst + wo * co].copy_from_slice(&row.data[..wo * co]);
        });
        (out, stats)
    }
}

/// Compute band-local output rows `[row_lo, row_hi)` of `layer` from
/// `in_band` into `out_band` (vertical padding pre-materialized in the
/// band; horizontal padding applied here). Returns MACs performed.
fn band_layer(
    layer: &Layer,
    params: &LayerParams,
    in_band: &Tensor,
    out_band: &mut Tensor,
    row_lo: usize,
    row_hi: usize,
) -> u64 {
    let k = layer.k as usize;
    let s = layer.stride as usize;
    let p = layer.padding as usize;
    let cin = in_band.c;
    let wo = (in_band.w + 2 * p - k) / s + 1;
    debug_assert!(out_band.w == wo && out_band.h >= row_hi);
    let cout = out_band.c;

    match layer.kind {
        LayerKind::Conv2d if k == 1 && p == 0 && s == 1 => {
            // Perf iteration 2: pointwise fast path - a row-level GEMV
            // with no window bookkeeping. The MBV2/MCUNet expand/project
            // layers put most MACs here.
            let w = &params.weights; // [cin][cout]
            for oy in row_lo..row_hi {
                for ox in 0..wo {
                    let base = (oy * wo + ox) * cout;
                    let acc = &mut out_band.data[base..base + cout];
                    acc.copy_from_slice(&params.bias);
                    let xoff = (oy * in_band.w + ox) * cin;
                    for ci in 0..cin {
                        let xv = in_band.data[xoff + ci];
                        if xv == 0.0 {
                            continue; // relu sparsity: skip dead activations
                        }
                        let wrow = &w[ci * cout..(ci + 1) * cout];
                        for (a, wv) in acc.iter_mut().zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            }
            let slice = &mut out_band.data[row_lo * wo * cout..row_hi * wo * cout];
            activate(slice, layer.act);
            ((row_hi - row_lo) * wo * cout * cin) as u64
        }
        LayerKind::Conv2d => {
            let w = &params.weights;
            for oy in row_lo..row_hi {
                for ox in 0..wo {
                    let base = (oy * wo + ox) * cout;
                    out_band.data[base..base + cout].copy_from_slice(&params.bias);
                    for ky in 0..k {
                        let sy = oy * s + ky; // vertical pad already in band
                        for kx in 0..k {
                            let sx = (ox * s + kx) as isize - p as isize;
                            if sx < 0 || sx as usize >= in_band.w {
                                continue;
                            }
                            let xoff = (sy * in_band.w + sx as usize) * cin;
                            let woff = (ky * k + kx) * cin * cout;
                            for ci in 0..cin {
                                let xv = in_band.data[xoff + ci];
                                let wrow = &w[woff + ci * cout..woff + (ci + 1) * cout];
                                for (acc, wv) in
                                    out_band.data[base..base + cout].iter_mut().zip(wrow)
                                {
                                    *acc += xv * wv;
                                }
                            }
                        }
                    }
                }
            }
            let slice = &mut out_band.data[row_lo * wo * cout..row_hi * wo * cout];
            activate(slice, layer.act);
            ((row_hi - row_lo) * wo * cout * k * k * cin) as u64
        }
        LayerKind::DwConv2d => {
            // Perf iteration 3: split interior columns (no horizontal
            // clamping possible) from the two padded edges, removing the
            // per-element bounds branch from the k*k inner loop.
            let w = &params.weights;
            // Interior: ox*s + kx - p in [0, w) for all kx in [0, k).
            let ox_lo = (p + s - 1) / s; // first ox with ox*s - p >= 0
            let ox_hi = if in_band.w + p >= k {
                ((in_band.w + p - k) / s + 1).min(wo)
            } else {
                0
            };
            for oy in row_lo..row_hi {
                let edge = |out_band: &mut Tensor, ox: usize| {
                    let base = (oy * wo + ox) * cout;
                    out_band.data[base..base + cout].copy_from_slice(&params.bias);
                    for ky in 0..k {
                        let sy = oy * s + ky;
                        for kx in 0..k {
                            let sx = (ox * s + kx) as isize - p as isize;
                            if sx < 0 || sx as usize >= in_band.w {
                                continue;
                            }
                            let xoff = (sy * in_band.w + sx as usize) * cin;
                            let woff = (ky * k + kx) * cin;
                            for ci in 0..cin {
                                out_band.data[base + ci] +=
                                    in_band.data[xoff + ci] * w[woff + ci];
                            }
                        }
                    }
                };
                for ox in 0..ox_lo.min(wo) {
                    edge(out_band, ox);
                }
                for ox in ox_lo..ox_hi {
                    let base = (oy * wo + ox) * cout;
                    out_band.data[base..base + cout].copy_from_slice(&params.bias);
                    let x0 = ox * s - p;
                    for ky in 0..k {
                        let sy = oy * s + ky;
                        let row = (sy * in_band.w + x0) * cin;
                        let wrow = ky * k * cin;
                        let acc = &mut out_band.data[base..base + cout];
                        for kx in 0..k {
                            let xs = &in_band.data[row + kx * cin..row + (kx + 1) * cin];
                            let ws = &w[wrow + kx * cin..wrow + (kx + 1) * cin];
                            for ((a, xv), wv) in acc.iter_mut().zip(xs).zip(ws) {
                                *a += xv * wv;
                            }
                        }
                    }
                }
                for ox in ox_hi.max(ox_lo)..wo {
                    edge(out_band, ox);
                }
            }
            let slice = &mut out_band.data[row_lo * wo * cout..row_hi * wo * cout];
            activate(slice, layer.act);
            ((row_hi - row_lo) * wo * cout * k * k) as u64
        }
        LayerKind::AvgPool | LayerKind::MaxPool => {
            let is_avg = matches!(layer.kind, LayerKind::AvgPool);
            let inv = 1.0 / (k * k) as f32;
            for oy in row_lo..row_hi {
                for ox in 0..wo {
                    let base = (oy * wo + ox) * cout;
                    for ci in 0..cout {
                        out_band.data[base + ci] =
                            if is_avg { 0.0 } else { f32::NEG_INFINITY };
                    }
                    for ky in 0..k {
                        let sy = oy * s + ky;
                        for kx in 0..k {
                            let sx = ox * s + kx; // pools are unpadded here
                            let xoff = (sy * in_band.w + sx) * cin;
                            for ci in 0..cout {
                                let v = in_band.data[xoff + ci];
                                let acc = &mut out_band.data[base + ci];
                                if is_avg {
                                    *acc += v * inv;
                                } else {
                                    *acc = acc.max(v);
                                }
                            }
                        }
                    }
                }
            }
            ((row_hi - row_lo) * wo * cout * k * k) as u64
        }
        _ => unreachable!("non-streamable layer inside fused block"),
    }
}

/// Zero band rows whose absolute index lies outside `[0, h_map)`.
fn zero_outside(band: &mut Tensor, range: BandRange, h_map: usize) {
    for row in 0..range.rows {
        let abs = range.start + row as isize;
        if abs < 0 || abs as usize >= h_map {
            let off = row * band.w * band.c;
            band.data[off..off + band.w * band.c].fill(0.0);
        }
    }
}

/// `dst[rows of dst_range] += src[same absolute rows]` (residual add).
fn add_aligned(src: &Tensor, src_range: BandRange, dst: &mut Tensor, dst_range: BandRange) {
    debug_assert_eq!(src.w, dst.w);
    debug_assert_eq!(src.c, dst.c);
    let rowlen = dst.w * dst.c;
    for row in 0..dst_range.rows {
        let abs = dst_range.start + row as isize;
        let s_row = abs - src_range.start;
        if s_row < 0 || s_row as usize >= src_range.rows {
            continue; // outside the stashed band: padding rows, add 0
        }
        let soff = s_row as usize * rowlen;
        let doff = row * rowlen;
        for i in 0..rowlen {
            dst.data[doff + i] += src.data[soff + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorShape;
    use crate::ops::{conv2d, dwconv2d, ParamGen};

    fn run_vanilla(model: &ModelChain, params: &[LayerParams], input: &Tensor) -> Tensor {
        let mut cur = input.clone();
        let mut stash: Vec<Option<Tensor>> = vec![None; model.num_layers() + 1];
        for (i, l) in model.layers.iter().enumerate() {
            for (j, ll) in model.layers.iter().enumerate() {
                if ll.residual_from == Some(i) && j >= i {
                    stash[i] = Some(cur.clone());
                }
            }
            let mut out = match l.kind {
                LayerKind::Conv2d => conv2d(
                    &cur,
                    &params[i].weights,
                    &params[i].bias,
                    l.k as usize,
                    l.stride as usize,
                    l.padding as usize,
                    l.cout as usize,
                    l.act,
                ),
                LayerKind::DwConv2d => dwconv2d(
                    &cur,
                    &params[i].weights,
                    &params[i].bias,
                    l.k as usize,
                    l.stride as usize,
                    l.padding as usize,
                    l.act,
                ),
                LayerKind::AvgPool => crate::ops::avg_pool2d(&cur, l.k as usize, l.stride as usize),
                LayerKind::MaxPool => crate::ops::max_pool2d(&cur, l.k as usize, l.stride as usize),
                _ => break,
            };
            if let Some(src) = l.residual_from {
                let st = stash[src].as_ref().expect("stash");
                for (o, s) in out.data.iter_mut().zip(&st.data) {
                    *o += s;
                }
            }
            cur = out;
        }
        cur
    }

    fn rand_input(shape: TensorShape, seed: u64) -> Tensor {
        let mut g = ParamGen::new(seed);
        let n = shape.elems() as usize;
        Tensor::from_data(
            shape.h as usize,
            shape.w as usize,
            shape.c as usize,
            g.fill(n, 2.0),
        )
    }

    fn params_for(model: &ModelChain) -> Vec<LayerParams> {
        model
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| LayerParams::for_layer(l, i))
            .collect()
    }

    #[test]
    fn fused_equals_vanilla_valid_convs() {
        use crate::model::{Activation, Layer};
        let m = ModelChain::new(
            "t",
            TensorShape::new(17, 13, 3),
            vec![
                Layer::conv("c0", 3, 1, 0, 3, 6, Activation::Relu6),
                Layer::conv("c1", 3, 2, 0, 6, 4, Activation::None),
            ],
        );
        let p = params_for(&m);
        let x = rand_input(m.shapes[0], 1);
        let expect = run_vanilla(&m, &p, &x);
        let (got, stats) = FusedBlock::new(&m, 0, 2, &p).run(&x);
        assert_eq!(got.shape(), expect.shape());
        assert!(got.max_abs_diff(&expect) < 1e-4);
        assert_eq!(stats.iterations as u32, m.output_of(1).h);
    }

    #[test]
    fn fused_equals_vanilla_with_padding_and_dw() {
        use crate::model::{Activation, Layer};
        let m = ModelChain::new(
            "t",
            TensorShape::new(16, 16, 4),
            vec![
                Layer::conv("c0", 3, 2, 1, 4, 8, Activation::Relu6),
                Layer::dwconv("d1", 3, 1, 1, 8, Activation::Relu6),
                Layer::pointwise("p2", 8, 6, Activation::None),
            ],
        );
        let p = params_for(&m);
        let x = rand_input(m.shapes[0], 2);
        let expect = run_vanilla(&m, &p, &x);
        let (got, _) = FusedBlock::new(&m, 0, 3, &p).run(&x);
        assert!(got.max_abs_diff(&expect) < 1e-4, "diff {}", got.max_abs_diff(&expect));
    }

    #[test]
    fn fused_equals_vanilla_with_pool_member() {
        use crate::model::{Activation, Layer};
        let m = ModelChain::new(
            "t",
            TensorShape::new(12, 12, 2),
            vec![
                Layer::conv("c0", 3, 1, 0, 2, 4, Activation::Relu),
                Layer::avg_pool("pl", 2, 2, 4),
            ],
        );
        let p = params_for(&m);
        let x = rand_input(m.shapes[0], 3);
        let expect = run_vanilla(&m, &p, &x);
        let (got, _) = FusedBlock::new(&m, 0, 2, &p).run(&x);
        assert!(got.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn fused_handles_internal_residual() {
        use crate::model::{Activation, Layer};
        let m = ModelChain::new(
            "res",
            TensorShape::new(10, 10, 6),
            vec![
                Layer::pointwise("expand", 6, 12, Activation::Relu6),
                Layer::dwconv("dw", 3, 1, 1, 12, Activation::Relu6),
                Layer::pointwise("project", 12, 6, Activation::None).with_residual(0),
            ],
        );
        let p = params_for(&m);
        let x = rand_input(m.shapes[0], 4);
        let expect = run_vanilla(&m, &p, &x);
        let (got, _) = FusedBlock::new(&m, 0, 3, &p).run(&x);
        assert!(got.max_abs_diff(&expect) < 1e-4, "diff {}", got.max_abs_diff(&expect));
    }

    #[test]
    fn fused_macs_match_analytical_model() {
        use crate::model::{Activation, Layer};
        let m = ModelChain::new(
            "t",
            TensorShape::new(20, 20, 3),
            vec![
                Layer::conv("c0", 3, 1, 1, 3, 6, Activation::Relu6),
                Layer::conv("c1", 3, 1, 1, 6, 4, Activation::Relu6),
            ],
        );
        let p = params_for(&m);
        let x = rand_input(m.shapes[0], 5);
        let (_, stats) = FusedBlock::new(&m, 0, 2, &p).run(&x);
        let predicted = crate::fusion::block_macs(&m, 0, 2);
        let ratio = stats.macs as f64 / predicted as f64;
        assert!(
            (0.9..=1.1).contains(&ratio),
            "measured {} vs predicted {predicted} (ratio {ratio})",
            stats.macs
        );
    }

    #[test]
    fn deep_stride_chain_correct() {
        use crate::model::{Activation, Layer};
        let m = ModelChain::new(
            "deep",
            TensorShape::new(33, 29, 3),
            vec![
                Layer::conv("c0", 3, 2, 1, 3, 4, Activation::Relu6),
                Layer::conv("c1", 3, 1, 0, 4, 4, Activation::Relu6),
                Layer::conv("c2", 3, 2, 1, 4, 8, Activation::None),
                Layer::conv("c3", 1, 1, 0, 8, 5, Activation::Relu6),
            ],
        );
        let p = params_for(&m);
        let x = rand_input(m.shapes[0], 6);
        let expect = run_vanilla(&m, &p, &x);
        let (got, _) = FusedBlock::new(&m, 0, 4, &p).run(&x);
        assert!(got.max_abs_diff(&expect) < 1e-4, "diff {}", got.max_abs_diff(&expect));
    }
}
