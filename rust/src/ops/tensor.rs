//! Minimal HWC f32 tensor with row-band views for the patch executor.

use crate::model::TensorShape;

/// Dense HWC f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        Self { h, w, c, data: vec![0.0; h * w * c] }
    }

    pub fn from_shape(s: TensorShape) -> Self {
        Self::zeros(s.h as usize, s.w as usize, s.c as usize)
    }

    pub fn from_data(h: usize, w: usize, c: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), h * w * c, "data length mismatch");
        Self { h, w, c, data }
    }

    /// 1-D vector tensor (dense activations).
    pub fn vector(data: Vec<f32>) -> Self {
        let c = data.len();
        Self { h: 1, w: 1, c, data }
    }

    #[inline]
    pub fn at(&self, y: usize, x: usize, ch: usize) -> f32 {
        self.data[(y * self.w + x) * self.c + ch]
    }

    #[inline]
    pub fn at_mut(&mut self, y: usize, x: usize, ch: usize) -> &mut f32 {
        &mut self.data[(y * self.w + x) * self.c + ch]
    }

    /// Zero-padded read: out-of-bounds coordinates return 0 (conv padding).
    #[inline]
    pub fn at_padded(&self, y: isize, x: isize, ch: usize) -> f32 {
        if y < 0 || x < 0 || y as usize >= self.h || x as usize >= self.w {
            0.0
        } else {
            self.at(y as usize, x as usize, ch)
        }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    pub fn shape(&self) -> TensorShape {
        TensorShape::new(self.h as u32, self.w as u32, self.c as u32)
    }

    /// Copy rows `[y0, y0+rows)` into a new tensor (clamped, zero-filled
    /// beyond the bottom edge) — the streaming read of a row band.
    pub fn row_band(&self, y0: isize, rows: usize) -> Tensor {
        let mut out = Tensor::zeros(rows, self.w, self.c);
        self.row_band_into(y0, rows, &mut out);
        out
    }

    /// Allocation-free variant of [`Self::row_band`]: fill `dst` (same
    /// width/channels, `dst.h >= rows`) — the fused executor's per-
    /// iteration streaming read reuses one buffer (§Perf iteration 1).
    pub fn row_band_into(&self, y0: isize, rows: usize, dst: &mut Tensor) {
        debug_assert!(dst.w == self.w && dst.c == self.c && dst.h >= rows);
        MapRef::from(self).read_band_into(y0, rows, &mut dst.data);
    }

    /// Borrowed view of this tensor (pool-slice-friendly read surface).
    pub fn as_map(&self) -> MapRef<'_> {
        MapRef::from(self)
    }

    /// Max |a-b| against another tensor (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Borrowed HWC map view: the read surface shared by owned [`Tensor`]s and
/// pool slices, so the compiled executor ([`crate::exec::CompiledPlan`])
/// can stream from an offset-assigned pool without materializing tensors.
#[derive(Clone, Copy)]
pub struct MapRef<'a> {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: &'a [f32],
}

impl<'a> From<&'a Tensor> for MapRef<'a> {
    fn from(t: &'a Tensor) -> Self {
        Self { h: t.h, w: t.w, c: t.c, data: &t.data }
    }
}

impl<'a> MapRef<'a> {
    /// View over a raw pool slice with explicit dims.
    pub fn new(h: usize, w: usize, c: usize, data: &'a [f32]) -> Self {
        debug_assert_eq!(data.len(), h * w * c);
        Self { h, w, c, data }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    /// Copy rows `[y0, y0+rows)` into `dst` (row-major, `rows * w * c`
    /// leading elements), zero-filling rows outside `[0, h)` — the
    /// streaming band read of the patch executor.
    pub fn read_band_into(&self, y0: isize, rows: usize, dst: &mut [f32]) {
        let rowlen = self.w * self.c;
        debug_assert!(dst.len() >= rows * rowlen);
        for r in 0..rows {
            let sy = y0 + r as isize;
            let dsts = &mut dst[r * rowlen..(r + 1) * rowlen];
            if sy < 0 || sy as usize >= self.h {
                dsts.fill(0.0);
                continue;
            }
            let src = sy as usize * rowlen;
            dsts.copy_from_slice(&self.data[src..src + rowlen]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapref_band_matches_tensor_band() {
        let t = Tensor::from_data(3, 2, 1, vec![1., 2., 3., 4., 5., 6.]);
        let mut buf = vec![9.0; 6];
        t.as_map().read_band_into(2, 3, &mut buf);
        assert_eq!(buf, vec![5., 6., 0., 0., 0., 0.]);
    }

    #[test]
    fn indexing_roundtrip() {
        let mut t = Tensor::zeros(3, 4, 2);
        *t.at_mut(1, 2, 1) = 5.0;
        assert_eq!(t.at(1, 2, 1), 5.0);
        assert_eq!(t.data[(1 * 4 + 2) * 2 + 1], 5.0);
    }

    #[test]
    fn padded_reads_zero_outside() {
        let mut t = Tensor::zeros(2, 2, 1);
        *t.at_mut(0, 0, 0) = 3.0;
        assert_eq!(t.at_padded(-1, 0, 0), 0.0);
        assert_eq!(t.at_padded(0, 5, 0), 0.0);
        assert_eq!(t.at_padded(0, 0, 0), 3.0);
    }

    #[test]
    fn row_band_clamps_and_zero_fills() {
        let t = Tensor::from_data(3, 1, 1, vec![1.0, 2.0, 3.0]);
        let band = t.row_band(2, 3);
        assert_eq!(band.data, vec![3.0, 0.0, 0.0]);
        let band = t.row_band(-1, 2);
        assert_eq!(band.data, vec![0.0, 1.0]);
    }
}
