//! Pooling ops: windowed avg/max, common global pooling, and the paper's
//! iterative global pooling (Fig. 2).

use super::{MapRef, Tensor};

pub fn avg_pool2d(x: &Tensor, k: usize, stride: usize) -> Tensor {
    let ho = (x.h - k) / stride + 1;
    let wo = (x.w - k) / stride + 1;
    let mut out = Tensor::zeros(ho, wo, x.c);
    avg_pool2d_into(x.as_map(), k, stride, &mut out.data);
    out
}

/// Allocation-free [`avg_pool2d`] into a preallocated slice
/// (bit-identical; the compiled executor's single-layer kernel).
///
/// Pool windows never cross padding (unpadded contract), so every pixel
/// is "interior": each of the `k` window rows is one contiguous `k·c`
/// slice walked with `chunks_exact(c)` instead of recomputing a channel
/// offset per element. Tap order stays `(ky, kx)` and each tap still
/// does one multiply-add, so results match
/// [`super::reference::avg_pool2d_naive`] bit-for-bit.
pub fn avg_pool2d_into(x: MapRef<'_>, k: usize, stride: usize, out: &mut [f32]) {
    let ho = (x.h - k) / stride + 1;
    let wo = (x.w - k) / stride + 1;
    let c = x.c;
    debug_assert_eq!(out.len(), ho * wo * c);
    let inv = 1.0 / (k * k) as f32;
    for oy in 0..ho {
        for ox in 0..wo {
            let base = (oy * wo + ox) * c;
            let acc = &mut out[base..base + c];
            acc.fill(0.0);
            for ky in 0..k {
                let row = ((oy * stride + ky) * x.w + ox * stride) * c;
                for win in x.data[row..row + k * c].chunks_exact(c) {
                    for (a, v) in acc.iter_mut().zip(win) {
                        *a += v * inv;
                    }
                }
            }
        }
    }
}

pub fn max_pool2d(x: &Tensor, k: usize, stride: usize) -> Tensor {
    let ho = (x.h - k) / stride + 1;
    let wo = (x.w - k) / stride + 1;
    let mut out = Tensor::zeros(ho, wo, x.c);
    max_pool2d_into(x.as_map(), k, stride, &mut out.data);
    out
}

/// Allocation-free [`max_pool2d`] into a preallocated slice (bit-identical).
///
/// Row-slice iteration as in [`avg_pool2d_into`]; `f32::max` per tap in
/// the same `(ky, kx)` order as [`super::reference::max_pool2d_naive`].
pub fn max_pool2d_into(x: MapRef<'_>, k: usize, stride: usize, out: &mut [f32]) {
    let ho = (x.h - k) / stride + 1;
    let wo = (x.w - k) / stride + 1;
    let c = x.c;
    debug_assert_eq!(out.len(), ho * wo * c);
    for oy in 0..ho {
        for ox in 0..wo {
            let base = (oy * wo + ox) * c;
            let acc = &mut out[base..base + c];
            acc.fill(f32::NEG_INFINITY);
            for ky in 0..k {
                let row = ((oy * stride + ky) * x.w + ox * stride) * c;
                for win in x.data[row..row + k * c].chunks_exact(c) {
                    for (a, v) in acc.iter_mut().zip(win) {
                        *a = a.max(*v);
                    }
                }
            }
        }
    }
}

/// Common (whole-map) global average pooling: `[H,W,C] -> [C]`.
pub fn global_avg_pool(x: &Tensor) -> Vec<f32> {
    let mut acc = vec![0.0f32; x.c];
    global_avg_pool_into(x.as_map(), &mut acc);
    acc
}

/// Allocation-free [`global_avg_pool`] into a preallocated `[C]` slice
/// (bit-identical accumulation order).
pub fn global_avg_pool_into(x: MapRef<'_>, acc: &mut [f32]) {
    debug_assert_eq!(acc.len(), x.c);
    acc.fill(0.0);
    for y in 0..x.h {
        for xx in 0..x.w {
            let off = (y * x.w + xx) * x.c;
            for ci in 0..x.c {
                acc[ci] += x.data[off + ci];
            }
        }
    }
    scale_avg(acc, x.h * x.w);
}

/// Accumulate row-major HWC `data` (`len % acc.len() == 0`) into a
/// `C`-sized accumulator — the **single** accumulation loop behind
/// [`GlobalPoolIter::push_row_major`] and the compiled executor's
/// pool-slice streaming ([`crate::exec::CompiledPlan`]). Bit-identity
/// between the two paths is load-bearing; change both or neither.
pub fn accumulate_row_major(acc: &mut [f32], data: &[f32]) {
    debug_assert_eq!(data.len() % acc.len(), 0);
    for px in data.chunks_exact(acc.len()) {
        for (a, v) in acc.iter_mut().zip(px) {
            *a += v;
        }
    }
}

/// Finish an average accumulation: scale by `1 / total_elems` in place —
/// shared by [`GlobalPoolIter::finish`] and the compiled executor (same
/// single multiply per element, so results are bit-identical).
pub fn scale_avg(acc: &mut [f32], total_elems: usize) {
    let inv = 1.0 / total_elems as f32;
    for v in acc.iter_mut() {
        *v *= inv;
    }
}

/// Iterative global average pooling (paper Fig. 2): receives row bands and
/// updates a running C-sized accumulator — live memory is `C` floats
/// instead of the whole `H×W×C` map (≈2% for a 7×7 map).
///
/// Mirrors `python/compile/kernels/iter_pool.py`.
#[derive(Debug, Clone)]
pub struct GlobalPoolIter {
    acc: Vec<f32>,
    seen_elems: usize,
    total_elems: usize,
}

impl GlobalPoolIter {
    /// `total_rows × w` spatial elements expected, `c` channels.
    pub fn new(c: usize, total_rows: usize, w: usize) -> Self {
        Self { acc: vec![0.0; c], seen_elems: 0, total_elems: total_rows * w }
    }

    /// Feed a row band `[rows, w, c]`.
    pub fn push_rows(&mut self, band: &Tensor) {
        assert_eq!(band.c, self.acc.len());
        self.push_row_major(&band.data);
    }

    /// Feed row-major HWC data directly from a slice (`len % c == 0`) —
    /// the borrowed-band form the pool-slice executor streams with.
    /// Accumulation order matches [`Self::push_rows`] bit-for-bit.
    pub fn push_row_major(&mut self, data: &[f32]) {
        accumulate_row_major(&mut self.acc, data);
        self.seen_elems += data.len() / self.acc.len();
    }

    /// RAM held by the accumulator (the §7 footprint).
    pub fn state_bytes(&self) -> u64 {
        (self.acc.len() * 4) as u64
    }

    /// Finish; panics if fed a different number of elements than declared.
    pub fn finish(mut self) -> Vec<f32> {
        assert_eq!(self.seen_elems, self.total_elems, "short/over-fed pooling");
        scale_avg(&mut self.acc, self.total_elems);
        self.acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(h: usize, w: usize, c: usize) -> Tensor {
        Tensor::from_data(h, w, c, (0..h * w * c).map(|i| i as f32 * 0.1).collect())
    }

    #[test]
    fn avg_pool_2x2() {
        let x = Tensor::from_data(2, 2, 1, vec![1., 2., 3., 4.]);
        let out = avg_pool2d(&x, 2, 2);
        assert_eq!(out.data, vec![2.5]);
    }

    #[test]
    fn max_pool_2x2() {
        let x = Tensor::from_data(2, 2, 1, vec![1., 7., 3., 4.]);
        let out = max_pool2d(&x, 2, 2);
        assert_eq!(out.data, vec![7.0]);
    }

    #[test]
    fn iterative_matches_common_pool() {
        let x = ramp(7, 7, 16);
        let common = global_avg_pool(&x);
        let mut it = GlobalPoolIter::new(16, 7, 7);
        for y in 0..7 {
            it.push_rows(&x.row_band(y as isize, 1));
        }
        let iter = it.finish();
        for (a, b) in common.iter().zip(&iter) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn iterative_state_is_tiny() {
        // Paper Fig. 2: 7x7 map -> accumulator is ~2% of the map.
        let it = GlobalPoolIter::new(16, 7, 7);
        let map_bytes = 7 * 7 * 16 * 4u64;
        assert!(it.state_bytes() * 49 == map_bytes);
    }

    #[test]
    #[should_panic(expected = "short/over-fed")]
    fn short_feed_panics() {
        let it = GlobalPoolIter::new(4, 3, 3);
        it.finish();
    }
}
