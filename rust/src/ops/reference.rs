//! Retained naive reference kernels — the pre-interior/halo loop nests.
//!
//! When the hot `*_into` / `q*_into` kernels were restructured around the
//! interior/halo decomposition (branch-free interiors, fused epilogues),
//! their original per-pixel guarded loops moved here verbatim. They are
//! the parity oracles: `rust/tests/kernel_parity.rs` fuzzes shapes,
//! strides, and paddings and asserts the optimized kernels are
//! **bit-identical** (f32) / **exactly identical** (int8) to these, and
//! `benches/kernels.rs` times both variants so the committed
//! `BENCH_kernels.json` carries a real before/after delta per kernel
//! shape.
//!
//! The f32 references accumulate per output element in `(ky, kx, ci)`
//! order with one trailing `activate` pass — exactly the order the
//! optimized kernels preserve (f32 addition is not associative, and the
//! compiled path is pinned bit-identical to the interpreted engine). The
//! int8 references accumulate in i32, where any summation order yields
//! the same integer — the optimized twins exploit that freely.

use crate::model::Activation;

use super::{activate, qact, MapRef, QLayerParams, QMapRef, QParams};

/// Naive [`super::conv2d_into`]: per-pixel guarded taps, trailing
/// activation pass. Bit-identical to the optimized kernel.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_naive(
    x: MapRef<'_>,
    w: &[f32],
    b: &[f32],
    k: usize,
    stride: usize,
    padding: usize,
    cout: usize,
    act: Activation,
    out: &mut [f32],
) {
    let cin = x.c;
    let ho = (x.h + 2 * padding - k) / stride + 1;
    let wo = (x.w + 2 * padding - k) / stride + 1;
    debug_assert_eq!(out.len(), ho * wo * cout);
    for oy in 0..ho {
        for ox in 0..wo {
            let base = (oy * wo + ox) * cout;
            let acc = &mut out[base..base + cout];
            acc.copy_from_slice(b);
            for ky in 0..k {
                let sy = (oy * stride + ky) as isize - padding as isize;
                if sy < 0 || sy as usize >= x.h {
                    continue;
                }
                for kx in 0..k {
                    let sx = (ox * stride + kx) as isize - padding as isize;
                    if sx < 0 || sx as usize >= x.w {
                        continue;
                    }
                    let xoff = ((sy as usize) * x.w + sx as usize) * cin;
                    let woff = (ky * k + kx) * cin * cout;
                    for ci in 0..cin {
                        let xv = x.data[xoff + ci];
                        let wrow = &w[woff + ci * cout..woff + (ci + 1) * cout];
                        for (a, wv) in acc.iter_mut().zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            }
        }
    }
    activate(out, act);
}

/// Naive [`super::dwconv2d_into`] (bit-identical oracle).
#[allow(clippy::too_many_arguments)]
pub fn dwconv2d_naive(
    x: MapRef<'_>,
    w: &[f32],
    b: &[f32],
    k: usize,
    stride: usize,
    padding: usize,
    act: Activation,
    out: &mut [f32],
) {
    let c = x.c;
    let ho = (x.h + 2 * padding - k) / stride + 1;
    let wo = (x.w + 2 * padding - k) / stride + 1;
    debug_assert_eq!(out.len(), ho * wo * c);
    for oy in 0..ho {
        for ox in 0..wo {
            let base = (oy * wo + ox) * c;
            out[base..base + c].copy_from_slice(b);
            for ky in 0..k {
                let sy = (oy * stride + ky) as isize - padding as isize;
                if sy < 0 || sy as usize >= x.h {
                    continue;
                }
                for kx in 0..k {
                    let sx = (ox * stride + kx) as isize - padding as isize;
                    if sx < 0 || sx as usize >= x.w {
                        continue;
                    }
                    let xoff = ((sy as usize) * x.w + sx as usize) * c;
                    let woff = (ky * k + kx) * c;
                    for ci in 0..c {
                        out[base + ci] += x.data[xoff + ci] * w[woff + ci];
                    }
                }
            }
        }
    }
    activate(out, act);
}

/// Naive [`super::avg_pool2d_into`]: per-element offset recomputation in
/// four nested loops (bit-identical oracle).
pub fn avg_pool2d_naive(x: MapRef<'_>, k: usize, stride: usize, out: &mut [f32]) {
    let ho = (x.h - k) / stride + 1;
    let wo = (x.w - k) / stride + 1;
    debug_assert_eq!(out.len(), ho * wo * x.c);
    out.fill(0.0);
    let inv = 1.0 / (k * k) as f32;
    for oy in 0..ho {
        for ox in 0..wo {
            for ky in 0..k {
                for kx in 0..k {
                    let xoff = ((oy * stride + ky) * x.w + ox * stride + kx) * x.c;
                    let base = (oy * wo + ox) * x.c;
                    for ci in 0..x.c {
                        out[base + ci] += x.data[xoff + ci] * inv;
                    }
                }
            }
        }
    }
}

/// Naive [`super::max_pool2d_into`] (bit-identical oracle).
pub fn max_pool2d_naive(x: MapRef<'_>, k: usize, stride: usize, out: &mut [f32]) {
    let ho = (x.h - k) / stride + 1;
    let wo = (x.w - k) / stride + 1;
    debug_assert_eq!(out.len(), ho * wo * x.c);
    out.fill(f32::NEG_INFINITY);
    for oy in 0..ho {
        for ox in 0..wo {
            for ky in 0..k {
                for kx in 0..k {
                    let xoff = ((oy * stride + ky) * x.w + ox * stride + kx) * x.c;
                    let base = (oy * wo + ox) * x.c;
                    for ci in 0..x.c {
                        out[base + ci] = out[base + ci].max(x.data[xoff + ci]);
                    }
                }
            }
        }
    }
}

/// Naive [`super::dense_into`] (bit-identical oracle).
pub fn dense_naive(x: &[f32], w: &[f32], b: &[f32], dout: usize, out: &mut [f32]) {
    debug_assert_eq!(w.len(), x.len() * dout);
    debug_assert_eq!(out.len(), dout);
    out.copy_from_slice(b);
    for (i, &xi) in x.iter().enumerate() {
        let row = &w[i * dout..(i + 1) * dout];
        for (yj, wj) in out.iter_mut().zip(row) {
            *yj += xi * wj;
        }
    }
}

/// Naive [`super::qconv2d_into`]: one scalar i32 accumulator per output
/// channel, guarded taps (exact-identity oracle).
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_naive(
    x: QMapRef<'_>,
    x_qp: QParams,
    p: &QLayerParams,
    k: usize,
    stride: usize,
    padding: usize,
    cout: usize,
    act: Activation,
    out_qp: QParams,
    out: &mut [i8],
) {
    let cin = x.c;
    let ho = (x.h + 2 * padding - k) / stride + 1;
    let wo = (x.w + 2 * padding - k) / stride + 1;
    debug_assert!(out.len() >= ho * wo * cout, "output buffer too small");
    let zx = x_qp.zero_point;
    let zw = p.w_qp.zero_point;
    let real_scale = x_qp.scale * p.w_qp.scale;
    for oy in 0..ho {
        for ox in 0..wo {
            for co in 0..cout {
                let mut acc: i32 = 0;
                for ky in 0..k {
                    let sy = (oy * stride + ky) as isize - padding as isize;
                    if sy < 0 || sy as usize >= x.h {
                        continue;
                    }
                    for kx in 0..k {
                        let sx = (ox * stride + kx) as isize - padding as isize;
                        if sx < 0 || sx as usize >= x.w {
                            continue;
                        }
                        let xoff = ((sy as usize) * x.w + sx as usize) * cin;
                        let woff = (ky * k + kx) * cin * cout;
                        for ci in 0..cin {
                            let xv = x.data[xoff + ci] as i32 - zx;
                            let wv = p.w_q[woff + ci * cout + co] as i32 - zw;
                            acc += xv * wv;
                        }
                    }
                }
                let real = qact(acc as f32 * real_scale + p.bias[co], act);
                out[(oy * wo + ox) * cout + co] = out_qp.quantize(real);
            }
        }
    }
}

/// Naive [`super::qdwconv2d_into`] (exact-identity oracle).
#[allow(clippy::too_many_arguments)]
pub fn qdwconv2d_naive(
    x: QMapRef<'_>,
    x_qp: QParams,
    p: &QLayerParams,
    k: usize,
    stride: usize,
    padding: usize,
    act: Activation,
    out_qp: QParams,
    out: &mut [i8],
) {
    let c = x.c;
    let ho = (x.h + 2 * padding - k) / stride + 1;
    let wo = (x.w + 2 * padding - k) / stride + 1;
    debug_assert!(out.len() >= ho * wo * c, "output buffer too small");
    let zx = x_qp.zero_point;
    let zw = p.w_qp.zero_point;
    let real_scale = x_qp.scale * p.w_qp.scale;
    for oy in 0..ho {
        for ox in 0..wo {
            for ci in 0..c {
                let mut acc: i32 = 0;
                for ky in 0..k {
                    let sy = (oy * stride + ky) as isize - padding as isize;
                    if sy < 0 || sy as usize >= x.h {
                        continue;
                    }
                    for kx in 0..k {
                        let sx = (ox * stride + kx) as isize - padding as isize;
                        if sx < 0 || sx as usize >= x.w {
                            continue;
                        }
                        let xoff = ((sy as usize) * x.w + sx as usize) * c;
                        let woff = (ky * k + kx) * c;
                        let xv = x.data[xoff + ci] as i32 - zx;
                        let wv = p.w_q[woff + ci] as i32 - zw;
                        acc += xv * wv;
                    }
                }
                let real = qact(acc as f32 * real_scale + p.bias[ci], act);
                out[(oy * wo + ox) * c + ci] = out_qp.quantize(real);
            }
        }
    }
}

/// Naive [`super::qavg_pool2d_into`] (exact-identity oracle).
pub fn qavg_pool2d_naive(
    x: QMapRef<'_>,
    x_qp: QParams,
    k: usize,
    stride: usize,
    out_qp: QParams,
    out: &mut [i8],
) {
    let c = x.c;
    let ho = (x.h - k) / stride + 1;
    let wo = (x.w - k) / stride + 1;
    debug_assert!(out.len() >= ho * wo * c, "output buffer too small");
    let count = (k * k) as f32;
    let zx = x_qp.zero_point as f32;
    for oy in 0..ho {
        for ox in 0..wo {
            for ci in 0..c {
                let mut sum: i32 = 0;
                for ky in 0..k {
                    for kx in 0..k {
                        let xoff = ((oy * stride + ky) * x.w + ox * stride + kx) * c;
                        sum += x.data[xoff + ci] as i32;
                    }
                }
                let real = (sum as f32 - count * zx) * x_qp.scale / count;
                out[(oy * wo + ox) * c + ci] = out_qp.quantize(real);
            }
        }
    }
}

/// Naive [`super::qmax_pool2d_into`] (exact-identity oracle).
pub fn qmax_pool2d_naive(
    x: QMapRef<'_>,
    x_qp: QParams,
    k: usize,
    stride: usize,
    out_qp: QParams,
    out: &mut [i8],
) {
    let c = x.c;
    let ho = (x.h - k) / stride + 1;
    let wo = (x.w - k) / stride + 1;
    debug_assert!(out.len() >= ho * wo * c, "output buffer too small");
    for oy in 0..ho {
        for ox in 0..wo {
            for ci in 0..c {
                let mut m: i8 = i8::MIN;
                for ky in 0..k {
                    for kx in 0..k {
                        let xoff = ((oy * stride + ky) * x.w + ox * stride + kx) * c;
                        m = m.max(x.data[xoff + ci]);
                    }
                }
                out[(oy * wo + ox) * c + ci] = out_qp.quantize(x_qp.dequantize(m));
            }
        }
    }
}

/// Naive [`super::qdense_into`] (exact-identity oracle).
pub fn qdense_naive(
    x: &[i8],
    x_qp: QParams,
    p: &QLayerParams,
    dout: usize,
    out_qp: QParams,
    out: &mut [i8],
) {
    debug_assert!(out.len() >= dout, "output buffer too small");
    let zx = x_qp.zero_point;
    let zw = p.w_qp.zero_point;
    let real_scale = x_qp.scale * p.w_qp.scale;
    for (j, o) in out.iter_mut().take(dout).enumerate() {
        let mut acc: i32 = 0;
        for (i, &xq) in x.iter().enumerate() {
            let xv = xq as i32 - zx;
            let wv = p.w_q[i * dout + j] as i32 - zw;
            acc += xv * wv;
        }
        *o = out_qp.quantize(acc as f32 * real_scale + p.bias[j]);
    }
}
