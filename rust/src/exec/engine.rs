//! End-to-end execution of a fusion setting over the pure-Rust ops,
//! with every buffer routed through the tracking [`Arena`].
//!
//! RAM accounting mirrors the analytical convention (`fusion::ram`):
//! boundary tensors and fusion band buffers are arena-allocated with
//! int8-element sizing (`ModelChain::elem_bytes`); iterative-tail
//! accumulators are 4-byte floats. The measured `Arena::peak_bytes` is the
//! number the integration tests reconcile against the optimizer's Eq. 5–6
//! prediction, and `macs` against Eq. 12–15.

use crate::memory::{AllocId, Arena, OomError};
use crate::model::{LayerKind, ModelChain};
use crate::ops::{
    avg_pool2d, conv2d, dense, dwconv2d, global_avg_pool, max_pool2d, DenseIter, FusedBlock,
    GlobalPoolIter, LayerParams, Tensor,
};
use crate::optimizer::FusionSetting;

/// Per-span execution record.
#[derive(Debug, Clone, Copy)]
pub struct SpanStat {
    pub a: usize,
    pub b: usize,
    pub fused: bool,
    pub macs: u64,
    /// Arena live bytes at this span's own peak.
    pub live_peak: u64,
}

/// Result of one inference run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Final activations (logits for classifier models).
    pub output: Vec<f32>,
    /// Arena high-water mark (bytes, int8-element sizing).
    pub peak_ram: u64,
    /// MACs actually performed.
    pub macs: u64,
    pub spans: Vec<SpanStat>,
}

/// Deterministic-weight inference engine for a model chain.
pub struct Engine {
    model: ModelChain,
    params: Vec<LayerParams>,
}

impl Engine {
    /// Engine with deterministic per-layer parameters (same generator the
    /// tests and the vanilla path use, so fused == vanilla bit-for-bit).
    pub fn new(model: ModelChain) -> Self {
        let params = model
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| LayerParams::for_layer(l, i))
            .collect();
        Self { model, params }
    }

    /// Engine with explicit parameters (`params[i]` for layer `i`).
    pub fn with_params(model: ModelChain, params: Vec<LayerParams>) -> Self {
        assert_eq!(params.len(), model.num_layers());
        Self { model, params }
    }

    /// Load the parameters `python/compile/aot.py` baked into the
    /// artifacts (`weights.json`) for the [`crate::zoo::quickstart`]
    /// model, enabling bit-comparable cross-checks between this executor
    /// and the XLA artifacts.
    pub fn quickstart_from_artifacts(
        dir: impl AsRef<std::path::Path>,
    ) -> crate::util::error::Result<Self> {
        use crate::util::json::Json;
        let model = crate::zoo::quickstart();
        let text = std::fs::read_to_string(dir.as_ref().join("weights.json"))?;
        let root = Json::parse(&text).map_err(|e| crate::anyhow!("weights.json: {e}"))?;
        let flat = |key: &str| -> crate::util::error::Result<Vec<f32>> {
            Ok(root
                .get(key)
                .and_then(|v| v.get("data"))
                .and_then(Json::as_arr)
                .ok_or_else(|| crate::anyhow!("missing '{key}' in weights.json"))?
                .iter()
                .map(|x| x.as_f64().unwrap_or(0.0) as f32)
                .collect())
        };
        let mut params = Vec::new();
        for (i, l) in model.layers.iter().enumerate() {
            let p = match l.kind {
                LayerKind::Conv2d => LayerParams {
                    weights: flat(&format!("w{i}"))?,
                    bias: flat(&format!("b{i}"))?,
                },
                LayerKind::Dense => LayerParams { weights: flat("wd")?, bias: flat("bd")? },
                _ => LayerParams { weights: vec![], bias: vec![] },
            };
            params.push(p);
        }
        Ok(Self::with_params(model, params))
    }

    pub fn model(&self) -> &ModelChain {
        &self.model
    }

    pub fn params(&self) -> &[LayerParams] {
        &self.params
    }

    /// Execute `setting` on `input`. The arena enforces the board budget
    /// (if any) and measures the peak; `Err` is the paper's OOM cell.
    pub fn run(
        &self,
        setting: &FusionSetting,
        input: &Tensor,
        arena: &mut Arena,
    ) -> Result<RunReport, OomError> {
        assert_eq!(input.shape(), self.model.shapes[0], "input shape mismatch");
        let mut spans_out = Vec::new();
        let mut total_macs = 0u64;

        // Current boundary tensor + its arena allocation (None = streamed).
        let mut cur: Tensor = input.clone();
        let mut cur_alloc: Option<AllocId> = None;

        // Residual stashes: boundary index -> (tensor, alloc).
        let mut stash: Vec<Option<(Tensor, AllocId)>> = vec![None; self.model.num_layers() + 1];

        // v_0 is materialized only if the first span is a single layer
        // (fused heads stream the input — the decoupling property).
        let first_fused = setting.spans.first().map(|&(a, b, _)| b - a > 1).unwrap_or(false);
        if !first_fused {
            cur_alloc = Some(arena.alloc(self.model.tensor_bytes(0), "v0:input")?);
        }

        for (si, &(a, b, iter_tail)) in setting.spans.iter().enumerate() {
            let span_live_before = arena.live_bytes();
            let fused = b - a > 1;
            let mut span_macs = 0u64;

            // Stash the current tensor if a later layer skips from here
            // across a span boundary (skips inside one fused span are
            // handled by the block executor) — the predicate is shared
            // with the compile-time schedule replay
            // (`memory::schedule_intervals`), which must mirror this walk
            // tick for tick.
            if crate::memory::stash_needed(&self.model, a, b, fused) {
                let id = arena.alloc(self.model.tensor_bytes(a), format!("stash:v{a}"))?;
                stash[a] = Some((cur.clone(), id));
            }

            if fused {
                // With an iterative tail the edge jumps to the output node;
                // the conv pyramid itself ends at the GlobalAvgPool index.
                let conv_end = crate::memory::conv_end_of(&self.model, a, b, iter_tail);
                let block = FusedBlock::new(&self.model, a, conv_end, &self.params);
                // Band buffers live for the whole block; accounted
                // analytically-equivalently (preallocated band elements ×
                // elem size — same shared formula as the schedule replay).
                let band_bytes = crate::memory::band_sizes(&self.model, a, conv_end).0;
                let band_alloc = arena.alloc(band_bytes, format!("bands:{a}..{conv_end}"))?;

                if iter_tail {
                    // Stream final rows into iterative pool -> dense chain.
                    let out_shape = self.model.output_of(conv_end - 1);
                    let gp = conv_end; // GlobalAvgPool layer index
                    let mut pool = GlobalPoolIter::new(
                        out_shape.c as usize,
                        out_shape.h as usize,
                        out_shape.w as usize,
                    );
                    let pool_alloc = arena.alloc(4 * out_shape.c as u64, "iter-pool-acc")?;
                    let stats = block.run_streaming(&cur, |_r, row| {
                        pool.push_row_major(row);
                    });
                    span_macs += stats.macs + out_shape.elems();
                    let mut vec_act = pool.finish();
                    arena.free(pool_alloc);
                    // Iterative dense chain for every trailing Dense layer.
                    for li in gp + 1..b {
                        let l = &self.model.layers[li];
                        let p = &self.params[li];
                        let dout = l.cout as usize;
                        let acc_alloc = arena.alloc(4 * dout as u64, format!("iter-dense:{li}"))?;
                        let mut it = DenseIter::new(vec_act.len(), &p.bias);
                        for (i, &x) in vec_act.iter().enumerate() {
                            it.push(&[x], &p.weights[i * dout..(i + 1) * dout]);
                        }
                        span_macs += (vec_act.len() * dout) as u64;
                        vec_act = it.finish();
                        arena.free(acc_alloc);
                    }
                    if let Some(id) = cur_alloc.take() {
                        arena.free(id);
                    }
                    arena.free(band_alloc);
                    cur = Tensor::vector(vec_act);
                    cur_alloc = Some(arena.alloc(4 * cur.c as u64, "logits")?);
                } else {
                    let out_id =
                        arena.alloc(self.model.tensor_bytes(b), format!("v{b}"))?;
                    let (out, stats) = block.run(&cur);
                    span_macs += stats.macs;
                    if let Some(id) = cur_alloc.take() {
                        arena.free(id);
                    }
                    arena.free(band_alloc);
                    cur = out;
                    cur_alloc = Some(out_id);
                }
            } else {
                // Single layer.
                let li = a;
                let l = &self.model.layers[li];
                let p = &self.params[li];
                let (out, out_id): (Tensor, Option<AllocId>) = match l.kind {
                    LayerKind::Conv2d => {
                        let id = arena.alloc(self.model.tensor_bytes(b), format!("v{b}"))?;
                        span_macs += self.model.layer_macs(li);
                        (
                            conv2d(
                                &cur,
                                &p.weights,
                                &p.bias,
                                l.k as usize,
                                l.stride as usize,
                                l.padding as usize,
                                l.cout as usize,
                                l.act,
                            ),
                            Some(id),
                        )
                    }
                    LayerKind::DwConv2d => {
                        let id = arena.alloc(self.model.tensor_bytes(b), format!("v{b}"))?;
                        span_macs += self.model.layer_macs(li);
                        (
                            dwconv2d(
                                &cur,
                                &p.weights,
                                &p.bias,
                                l.k as usize,
                                l.stride as usize,
                                l.padding as usize,
                                l.act,
                            ),
                            Some(id),
                        )
                    }
                    LayerKind::AvgPool => {
                        let id = arena.alloc(self.model.tensor_bytes(b), format!("v{b}"))?;
                        span_macs += self.model.layer_macs(li);
                        (avg_pool2d(&cur, l.k as usize, l.stride as usize), Some(id))
                    }
                    LayerKind::MaxPool => {
                        let id = arena.alloc(self.model.tensor_bytes(b), format!("v{b}"))?;
                        span_macs += self.model.layer_macs(li);
                        (max_pool2d(&cur, l.k as usize, l.stride as usize), Some(id))
                    }
                    LayerKind::GlobalAvgPool => {
                        let id = arena.alloc(4 * l.cout as u64, format!("v{b}:gap"))?;
                        span_macs += cur.elems() as u64;
                        (Tensor::vector(global_avg_pool(&cur)), Some(id))
                    }
                    LayerKind::Dense => {
                        let id = arena.alloc(4 * l.cout as u64, format!("v{b}:fc"))?;
                        span_macs += self.model.layer_macs(li);
                        (
                            Tensor::vector(dense(
                                &cur.data,
                                &p.weights,
                                &p.bias,
                                l.cout as usize,
                            )),
                            Some(id),
                        )
                    }
                };
                let mut out = out;
                // Cross-span residual add.
                if let Some(src) = l.residual_from {
                    if let Some((st, sid)) = stash[src].take() {
                        for (o, s) in out.data.iter_mut().zip(&st.data) {
                            *o += s;
                        }
                        arena.free(sid);
                    }
                }
                if let Some(id) = cur_alloc.take() {
                    arena.free(id);
                }
                cur = out;
                cur_alloc = out_id;
            }

            total_macs += span_macs;
            spans_out.push(SpanStat {
                a,
                b,
                fused,
                macs: span_macs,
                live_peak: arena.peak_bytes().max(span_live_before),
            });
            let _ = si;
        }

        if let Some(id) = cur_alloc.take() {
            arena.free(id);
        }
        // Any leftover stash (skip whose consumer was inside a fused span).
        for s in stash.into_iter().flatten() {
            arena.free(s.1);
        }

        Ok(RunReport {
            output: cur.data,
            peak_ram: arena.peak_bytes(),
            macs: total_macs,
            spans: spans_out,
        })
    }

    /// One-time compilation of `setting` for this engine's model and
    /// parameters: a static step list plus an offset-assigned pool, after
    /// which every inference is allocation-free
    /// ([`crate::exec::CompiledPlan::run_into`]) and bit-identical to
    /// [`Engine::run`]. The interpreted `run` stays as the
    /// budget-enforcing, arena-traced parity oracle.
    pub fn compile(&self, setting: &FusionSetting) -> crate::exec::CompiledPlan {
        crate::exec::CompiledPlan::with_params(
            self.model.clone(),
            self.params.clone(),
            setting.clone(),
        )
    }

    /// Run the vanilla (unfused) path — convenience for comparisons.
    pub fn run_vanilla(
        &self,
        input: &Tensor,
        arena: &mut Arena,
    ) -> Result<RunReport, OomError> {
        let vanilla = crate::optimizer::Planner::for_model(self.model.clone())
            .strategy(crate::optimizer::strategy::Vanilla)
            .setting()
            .expect("vanilla path always exists");
        self.run(&vanilla, input, arena)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::Arena;
    use crate::ops::ParamGen;
    use crate::optimizer::{strategy, Constraints, FusionSetting, Planner};
    use crate::zoo;

    fn rand_input(model: &ModelChain, seed: u64) -> Tensor {
        let s = model.shapes[0];
        let mut g = ParamGen::new(seed);
        Tensor::from_data(
            s.h as usize,
            s.w as usize,
            s.c as usize,
            g.fill(s.elems() as usize, 2.0),
        )
    }

    /// `(vanilla, min-RAM)` settings off one shared planner.
    fn plans_for(m: &ModelChain) -> (FusionSetting, FusionSetting) {
        let mut planner = Planner::for_model(m.clone());
        let fused = planner.setting().unwrap();
        let vanilla = planner
            .plan_with(&strategy::Vanilla, Constraints::none())
            .unwrap()
            .setting;
        (vanilla, fused)
    }

    #[test]
    fn fused_setting_matches_vanilla_numerics() {
        let m = zoo::quickstart();
        let engine = Engine::new(m.clone());
        let x = rand_input(&m, 11);
        let (vanilla, fused) = plans_for(&m);
        assert!(fused.num_fused_blocks() >= 1);

        let mut a1 = Arena::unbounded();
        let mut a2 = Arena::unbounded();
        let rv = engine.run(&vanilla, &x, &mut a1).unwrap();
        let rf = engine.run(&fused, &x, &mut a2).unwrap();
        assert_eq!(rv.output.len(), rf.output.len());
        for (a, b) in rv.output.iter().zip(&rf.output) {
            assert!((a - b).abs() < 1e-3, "vanilla {a} vs fused {b}");
        }
        assert!(rf.peak_ram < rv.peak_ram, "fusion must reduce measured peak");
    }

    #[test]
    fn vanilla_measured_peak_matches_analytic() {
        let m = zoo::quickstart();
        let engine = Engine::new(m.clone());
        let x = rand_input(&m, 3);
        let mut arena = Arena::unbounded();
        let r = engine.run_vanilla(&x, &mut arena).unwrap();
        // Measured live set is I+O per layer: identical to Eq. 5 vanilla.
        assert_eq!(r.peak_ram, m.vanilla_peak_ram());
    }

    #[test]
    fn budget_enforced_as_oom() {
        let m = zoo::quickstart();
        let engine = Engine::new(m.clone());
        let x = rand_input(&m, 4);
        let mut arena = Arena::with_budget(64); // absurdly small
        assert!(engine.run_vanilla(&x, &mut arena).is_err());
    }

    #[test]
    fn residual_model_fused_vs_vanilla() {
        let m = zoo::mcunet_vww5();
        let engine = Engine::new(m.clone());
        let x = rand_input(&m, 7);
        let (vanilla, fused) = plans_for(&m);
        let mut a1 = Arena::unbounded();
        let mut a2 = Arena::unbounded();
        let rv = engine.run(&vanilla, &x, &mut a1).unwrap();
        let rf = engine.run(&fused, &x, &mut a2).unwrap();
        let max_out = rv
            .output
            .iter()
            .zip(&rf.output)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_out < 1e-2, "diff {max_out}");
        assert!(rf.peak_ram < rv.peak_ram / 2, "paper: >50% RAM reduction");
    }

    #[test]
    fn no_leaks_after_run() {
        let m = zoo::tiny_cnn();
        let engine = Engine::new(m.clone());
        let x = rand_input(&m, 9);
        let mut arena = Arena::unbounded();
        engine.run_vanilla(&x, &mut arena).unwrap();
        assert_eq!(arena.live_bytes(), 0, "live: {:?}", arena.live_labels());
    }
}
