//! Plan executor: runs a [`FusionSetting`] end-to-end with numerics +
//! tracked RAM — the measurement half of the reproduction.

mod engine;

pub use engine::{Engine, RunReport, SpanStat};
