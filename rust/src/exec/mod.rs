//! Plan executors: the measurement half of the reproduction, two ways.
//!
//! * [`Engine`] — the **interpreted** executor: re-walks the
//!   [`crate::optimizer::FusionSetting`] per run with every buffer routed
//!   through the tracking [`crate::memory::Arena`] (budget enforcement,
//!   alloc traces, OOM cells). The parity oracle.
//! * [`CompiledPlan`] — the **compile-once** executor: the setting is
//!   lowered once to a static step list + offset-assigned pool
//!   ([`crate::memory::plan_layout`]), then every run is allocation-free
//!   inside a warm [`PlanPool`] and bit-identical to the interpreter.
//!   The serving hot path.

mod compiled;
mod engine;

pub use compiled::{BufAccess, CompiledPlan, PlanPool, RtBufInfo, StepAccess};
pub(crate) use compiled::{lower_steps, Lowered, Src, Step};
pub use engine::{Engine, RunReport, SpanStat};
