//! Compile-once execution plans: `(ModelChain, FusionSetting)` lowered to
//! a static step list plus one offset-assigned memory pool, so every
//! inference runs **allocation-free** inside that pool — the
//! MCU deployment model (TinyEngine-style offset-assigned arenas), and
//! the serving hot path behind [`crate::backend::EngineBackend`].
//!
//! Compilation replays the span walk once
//! ([`crate::memory::schedule_intervals`]) to derive every buffer's
//! lifetime interval, offset-assigns two layouts from the same intervals —
//! the *accounting* layout (Arena/Eq. 5–6 byte convention, serialized into
//! [`crate::optimizer::Plan`]) and the *runtime* f32 storage layout — and
//! resolves each span into a step referencing pool slices by offset.
//! Parameters are generated once at compile time, band-pyramid geometry
//! ([`BandGeom`]) once per fused step.
//!
//! Numerics are **bit-identical** to the interpreted [`super::Engine`]:
//! every step runs the same kernel loops ([`crate::ops`]' `*_into`
//! variants and the shared [`FusedBlock`] band executor), in the same
//! order, on pool slices instead of freshly allocated tensors. MAC
//! counting follows the engine too, so `RunReport`s reconcile exactly.

use std::ops::Range;

use crate::memory::{
    assign_offsets, layout_from_schedule, schedule_intervals, BufRole, PoolLayout, ScheduledBuf,
};
use crate::model::{Layer, LayerKind, ModelChain};
use crate::obs::{NoProfiler, StepMeta, StepProfiler};
use crate::ops::{
    accumulate_row_major, avg_pool2d_into, conv2d_into, dense_into, dwconv2d_into,
    global_avg_pool_into, max_pool2d_into, scale_avg, BandGeom, BandRange, FusedBlock, HCache,
    LayerParams, MapRef, Tensor, UnitProfiler,
};
use crate::optimizer::FusionSetting;

use super::RunReport;

/// Where a step reads its boundary input from. Crate-visible: the int8
/// [`crate::qexec::QCompiledPlan`] executes the same lowered step list.
#[derive(Debug, Clone, Copy)]
pub(crate) enum Src {
    /// The external input tensor (fused heads stream it; never pooled).
    Input,
    /// A pool buffer (schedule index, shared by every lowering).
    Buf(usize),
}

/// Runtime view of one pool buffer: f32 element offset + dims.
#[derive(Debug, Clone, Copy)]
struct RtBuf {
    off: usize,
    elems: usize,
    /// `(h, w, c)`; vectors are `(1, 1, len)`.
    dims: (usize, usize, usize),
}

/// Schedule-derived identity of a runtime buffer (label + lifetime),
/// kept alongside the offset table for the static verifier's reports.
#[derive(Debug, Clone)]
struct BufMeta {
    label: String,
    birth: usize,
    /// Runtime free tick (exclusive) — the `rt_death` the offsets were
    /// assigned under.
    rt_death: usize,
}

/// One buffer slice a compiled step touches: `len` f32 elements starting
/// at element `start` *within* buffer `buf` (index into
/// [`CompiledPlan::runtime_buffers`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BufAccess {
    pub buf: usize,
    pub start: usize,
    pub len: usize,
}

/// The full access set of one compiled step — the symbolic footprint the
/// static verifier ([`crate::analysis`]) checks without executing.
/// `reads` are consumed, `scratch` ranges are produced *before* the
/// step's `writes` (band pyramids, iterative accumulators), and
/// `in_place_safe` sanctions read/write overlap for kernels declared
/// safe to operate in place (none of the current kernels are).
#[derive(Debug, Clone)]
pub struct StepAccess {
    pub index: usize,
    /// Step kind tag (same vocabulary as [`crate::obs::StepMeta`]).
    pub kind: &'static str,
    pub label: String,
    /// True when the step streams the external input tensor (never
    /// pooled, so it carries no [`BufAccess`]).
    pub reads_external_input: bool,
    pub reads: Vec<BufAccess>,
    pub writes: Vec<BufAccess>,
    pub scratch: Vec<BufAccess>,
    pub in_place_safe: bool,
}

/// Public, label-carrying view of one runtime pool buffer
/// ([`CompiledPlan::runtime_buffers`]): f32 element offset/extent plus
/// the lifetime interval its offset was assigned under.
#[derive(Debug, Clone)]
pub struct RtBufInfo {
    pub label: String,
    /// f32 element offset into the pool.
    pub off: usize,
    pub elems: usize,
    /// `(h, w, c)`; vectors are `(1, 1, len)`.
    pub dims: (usize, usize, usize),
    /// Alive during schedule ticks `[birth, death)`.
    pub birth: usize,
    pub death: usize,
}

/// One compiled execution step. Buffer fields are **schedule indices**
/// (offset-independent), so the same lowered list drives both the f32
/// [`CompiledPlan`] and the int8 [`crate::qexec::QCompiledPlan`] against
/// their own offset tables.
pub(crate) enum Step {
    /// Copy the current boundary into a residual stash slice.
    StashSave { src: Src, dst: usize },
    /// Single (unfused) layer via the allocation-free `*_into` kernels.
    Single { layer: usize, src: Src, out: usize, residual: Option<usize> },
    /// Fused block `[a, conv_end)` streaming rows into the output map.
    Fused { a: usize, conv_end: usize, src: Src, bands: usize, out: usize, geom: BandGeom },
    /// Fused block with the §7 iterative tail: rows stream into the
    /// global-pool accumulator, then the iterative dense chain, then the
    /// logits copy.
    FusedIter {
        a: usize,
        conv_end: usize,
        src: Src,
        bands: usize,
        geom: BandGeom,
        pool_acc: usize,
        /// `(model layer index, accumulator buffer)` per trailing Dense.
        dense: Vec<(usize, usize)>,
        logits: usize,
    },
}

/// Product of the shared step lowering: the step list plus the
/// distinguished buffers, all as schedule indices.
pub(crate) struct Lowered {
    pub(crate) steps: Vec<Step>,
    /// `v_0` pool buffer to copy the external input into (only when the
    /// first span is a single layer; fused heads stream the input).
    pub(crate) input_buf: Option<usize>,
    pub(crate) out_buf: usize,
    /// Band-range scratch entries the deepest fused step needs.
    pub(crate) ranges_scratch: usize,
}

/// Lower `(model, setting)` against its schedule into the step list both
/// compiled executors share. Buffer references are indices into `sched`;
/// each executor resolves them through its own offset assignment (f32
/// element offsets vs int8 byte offsets).
pub(crate) fn lower_steps(
    model: &ModelChain,
    params: &[LayerParams],
    setting: &FusionSetting,
    sched: &[ScheduledBuf],
) -> Lowered {
    let find = |role: BufRole| -> usize {
        sched
            .iter()
            .position(|s| s.role == role)
            .unwrap_or_else(|| panic!("schedule is missing buffer {role:?}"))
    };

    let first_fused = setting.spans.first().map(|&(a, b, _)| b - a > 1).unwrap_or(false);
    let input_buf = if first_fused { None } else { Some(find(BufRole::Input)) };
    let mut cur: Src = match input_buf {
        Some(id) => Src::Buf(id),
        None => Src::Input,
    };
    let mut steps: Vec<Step> = Vec::new();
    let mut ranges_scratch = 0usize;
    let mut stash_ids: Vec<Option<usize>> = vec![None; model.num_layers() + 1];

    for (si, &(a, b, iter_tail)) in setting.spans.iter().enumerate() {
        let fused = b - a > 1;

        // Same (shared) stash decision as the engine / schedule walk.
        if crate::memory::stash_needed(model, a, b, fused) {
            let dst = find(BufRole::Stash { tensor: a });
            stash_ids[a] = Some(dst);
            steps.push(Step::StashSave { src: cur, dst });
        }

        if fused {
            let conv_end = crate::memory::conv_end_of(model, a, b, iter_tail);
            let bands = find(BufRole::Bands { a, b: conv_end });
            let geom = FusedBlock::new(model, a, conv_end, params).band_geom();
            debug_assert_eq!(
                geom.total_elems(),
                sched[bands].elems,
                "band geometry / schedule divergence"
            );
            ranges_scratch = ranges_scratch.max(geom.dims.len());
            if iter_tail {
                let pool_acc = find(BufRole::PoolAcc { span: si });
                let dense: Vec<(usize, usize)> = (conv_end + 1..b)
                    .map(|li| (li, find(BufRole::DenseAcc { layer: li })))
                    .collect();
                let logits = find(BufRole::Logits);
                steps.push(Step::FusedIter {
                    a,
                    conv_end,
                    src: cur,
                    bands,
                    geom,
                    pool_acc,
                    dense,
                    logits,
                });
                cur = Src::Buf(logits);
            } else {
                let out = find(BufRole::Boundary { tensor: b });
                steps.push(Step::Fused { a, conv_end, src: cur, bands, out, geom });
                cur = Src::Buf(out);
            }
        } else {
            let out = find(BufRole::Boundary { tensor: b });
            let residual = model.layers[a].residual_from.and_then(|src| stash_ids[src].take());
            steps.push(Step::Single { layer: a, src: cur, out, residual });
            cur = Src::Buf(out);
        }
    }

    let out_buf = match cur {
        Src::Buf(id) => id,
        Src::Input => unreachable!("setting with no spans"),
    };
    Lowered { steps, input_buf, out_buf, ranges_scratch }
}

/// The per-serving-slot mutable state of a compiled plan: one fixed f32
/// pool plus the band-range scratch. Created once
/// ([`CompiledPlan::make_pool`]); the hot path never allocates again —
/// [`Self::storage_allocs`] stays at its creation value forever.
pub struct PlanPool {
    data: Vec<f32>,
    ranges: Vec<BandRange>,
    storage_allocs: u64,
}

impl PlanPool {
    /// Number of heap allocations this pool has performed since creation
    /// (the pool vector + the range scratch). Constant after
    /// [`CompiledPlan::make_pool`]: the compiled hot path is
    /// allocation-free, and tests pin this counter across runs.
    pub fn storage_allocs(&self) -> u64 {
        self.storage_allocs
    }

    /// f32 elements of backing storage.
    pub fn elems(&self) -> usize {
        self.data.len()
    }

    /// Stable address of the backing storage (test hook: the hot path
    /// never reallocates, so this never changes).
    pub fn storage_ptr(&self) -> *const f32 {
        self.data.as_ptr()
    }
}

/// A `(model, setting)` pair compiled into a static step list + pool
/// layout. Immutable after compilation and shareable across runs; all
/// per-run state lives in a [`PlanPool`].
pub struct CompiledPlan {
    model: ModelChain,
    params: Vec<LayerParams>,
    setting: FusionSetting,
    layout: PoolLayout,
    bufs: Vec<RtBuf>,
    buf_meta: Vec<BufMeta>,
    pool_elems: usize,
    ranges_scratch: usize,
    steps: Vec<Step>,
    /// `v_0` pool buffer to copy the external input into (only when the
    /// first span is a single layer; fused heads stream the input).
    input_buf: Option<usize>,
    out_buf: usize,
    out_len: usize,
}

impl CompiledPlan {
    /// Compile with deterministic per-layer parameters (same generator as
    /// [`super::Engine::new`], so compiled == interpreted bit-for-bit).
    pub fn compile(model: ModelChain, setting: FusionSetting) -> Self {
        let params = model
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| LayerParams::for_layer(l, i))
            .collect();
        Self::with_params(model, params, setting)
    }

    /// Compile with explicit parameters (`params[i]` for layer `i`).
    pub fn with_params(
        model: ModelChain,
        params: Vec<LayerParams>,
        setting: FusionSetting,
    ) -> Self {
        assert_eq!(params.len(), model.num_layers(), "params/layers mismatch");
        assert!(!setting.spans.is_empty(), "empty fusion setting");

        let sched = schedule_intervals(&model, &setting);
        // Accounting layout: Arena-convention bytes over accounting
        // lifetimes — the same builder `optimizer::Plan` serialization
        // uses, so the deploy memory map and what we execute against are
        // byte-identical by construction.
        let layout = layout_from_schedule(&sched);

        // Runtime layout: f32 element counts over *runtime* lifetimes
        // (`rt_death` extends the iterative-tail read-back chain).
        let rt_items: Vec<(u64, usize, usize)> =
            sched.iter().map(|s| (s.elems as u64, s.birth, s.rt_death)).collect();
        let (rt_offs, pool_elems) = assign_offsets(&rt_items);
        let bufs: Vec<RtBuf> = sched
            .iter()
            .zip(&rt_offs)
            .map(|(s, &off)| RtBuf { off: off as usize, elems: s.elems, dims: s.dims })
            .collect();
        let buf_meta: Vec<BufMeta> = sched
            .iter()
            .map(|s| BufMeta { label: s.label.clone(), birth: s.birth, rt_death: s.rt_death })
            .collect();

        let Lowered { steps, input_buf, out_buf, ranges_scratch } =
            lower_steps(&model, &params, &setting, &sched);
        let out_len = bufs[out_buf].elems;

        let plan = Self {
            model,
            params,
            setting,
            layout,
            bufs,
            buf_meta,
            pool_elems: pool_elems as usize,
            ranges_scratch,
            steps,
            input_buf,
            out_buf,
            out_len,
        };

        // Analyzer-backed promotion of the hot path's `two_muts`/
        // `three_muts` `debug_assert!`s: prove once, at
        // compile-time-of-plan, that no step's pool slices can alias (the
        // debug asserts stay in the split helpers as belt-and-braces; the
        // per-run hot path is untouched).
        let hazards = crate::analysis::check_step_hazards(
            &crate::analysis::AnalysisInput::from_compiled(&plan),
        );
        assert!(
            hazards.is_clean(),
            "compiled plan violates pool aliasing invariants:\n{}",
            hazards.render()
        );
        plan
    }

    /// The accounting pool layout (offsets, pool size, watermark) — what
    /// [`crate::optimizer::Plan`] serializes as the deploy memory map.
    pub fn layout(&self) -> &PoolLayout {
        &self.layout
    }

    /// The compiled fusion setting.
    pub fn setting(&self) -> &FusionSetting {
        &self.setting
    }

    /// The compiled model.
    pub fn model(&self) -> &ModelChain {
        &self.model
    }

    /// Length of the final output (logits) vector.
    pub fn output_len(&self) -> usize {
        self.out_len
    }

    /// Measured peak of every run of this plan: the max concurrent
    /// accounting footprint of the schedule — equal to the interpreted
    /// engine's arena high-water mark, known at compile time because the
    /// schedule is static.
    pub fn measured_peak(&self) -> u64 {
        self.layout.watermark
    }

    /// Static pool size in accounting bytes (>= [`Self::measured_peak`];
    /// the difference is offset-assignment fragmentation).
    pub fn pool_bytes(&self) -> u64 {
        self.layout.pool_bytes
    }

    /// Allocate the per-slot execution pool — the **only** allocation of
    /// the compiled path; every subsequent [`Self::run_into`] is
    /// allocation-free.
    pub fn make_pool(&self) -> PlanPool {
        PlanPool {
            data: vec![0.0; self.pool_elems],
            ranges: vec![BandRange { start: 0, rows: 0 }; self.ranges_scratch],
            storage_allocs: 2,
        }
    }

    /// Allocation-free inference: stream `input` through the step list
    /// inside `pool`, writing the logits into `out`
    /// (length [`Self::output_len`]). Returns the MACs performed
    /// (identical to the interpreted engine's count).
    ///
    /// This is [`Self::run_profiled`] monomorphized with the no-op
    /// [`NoProfiler`] — the profiling hooks compile to nothing, so the
    /// warm hot path stays bit-identical and allocation-free.
    pub fn run_into(&self, input: MapRef<'_>, pool: &mut PlanPool, out: &mut [f32]) -> u64 {
        self.run_profiled(input, pool, out, &mut NoProfiler)
    }

    /// [`Self::run_into`] with per-step instrumentation: `prof.begin(i)`
    /// / `prof.end(i, macs)` bracket every compiled step. The profiler
    /// is a **monomorphized** type parameter, not a trait object — with
    /// [`NoProfiler`] the hooks vanish at compile time; with
    /// [`crate::obs::StepRecorder`] each step's wall time and MACs feed
    /// the [`crate::obs::StepProfile`] attribution
    /// ([`crate::obs::profile_plan`] is the convenience wrapper).
    pub fn run_profiled<P: StepProfiler>(
        &self,
        input: MapRef<'_>,
        pool: &mut PlanPool,
        out: &mut [f32],
        prof: &mut P,
    ) -> u64 {
        let s0 = self.model.shapes[0];
        assert!(
            input.h == s0.h as usize && input.w == s0.w as usize && input.c == s0.c as usize,
            "input shape mismatch"
        );
        assert_eq!(out.len(), self.out_len, "output buffer length mismatch");
        assert_eq!(pool.data.len(), self.pool_elems, "pool belongs to a different plan");

        if let Some(id) = self.input_buf {
            pool.data[self.range_of(id)].copy_from_slice(input.data);
        }
        let mut macs = 0u64;
        for (i, step) in self.steps.iter().enumerate() {
            prof.begin(i);
            let step_macs = self.run_step(step, input, pool, prof);
            prof.end(i, step_macs);
            macs += step_macs;
        }
        let out_r = self.range_of(self.out_buf);
        out.copy_from_slice(&pool.data[out_r]);
        macs
    }

    /// Number of compiled steps ([`crate::obs::StepRecorder::new`]'s
    /// argument; profiler hook indices are `0..num_steps`).
    pub fn num_steps(&self) -> usize {
        self.steps.len()
    }

    /// Static per-step metadata — kind, label, model-layer range, and
    /// bytes touched per run — keyed by step index, for attributing
    /// profiled samples ([`crate::obs::StepProfile::from_recorder`]).
    pub fn step_metas(&self) -> Vec<StepMeta> {
        self.steps
            .iter()
            .enumerate()
            .map(|(index, step)| match step {
                Step::StashSave { src, dst } => StepMeta {
                    index,
                    kind: "stash",
                    label: format!("stash v{}", self.stash_tensor_of(*dst)),
                    layers: (self.stash_tensor_of(*dst), self.stash_tensor_of(*dst)),
                    bytes: 4 * (self.src_elems(*src) + self.bufs[*dst].elems) as u64,
                },
                Step::Single { layer, src, out, residual } => {
                    let l = &self.model.layers[*layer];
                    let mut elems = self.src_elems(*src) + self.bufs[*out].elems;
                    if let Some(stash) = residual {
                        elems += self.bufs[*stash].elems;
                    }
                    StepMeta {
                        index,
                        kind: "single",
                        label: format!("{}[{layer}]", kind_name(l.kind)),
                        layers: (*layer, *layer + 1),
                        bytes: 4 * elems as u64 + self.param_bytes(*layer, *layer + 1),
                    }
                }
                Step::Fused { a, conv_end, src, bands, out, .. } => StepMeta {
                    index,
                    kind: "fused",
                    label: format!("fused[{a}..{conv_end})"),
                    layers: (*a, *conv_end),
                    bytes: 4
                        * (self.src_elems(*src)
                            + self.bufs[*bands].elems
                            + self.bufs[*out].elems) as u64
                        + self.param_bytes(*a, *conv_end),
                },
                Step::FusedIter { a, conv_end, src, bands, pool_acc, dense, logits, .. } => {
                    let end = dense.last().map_or(*conv_end + 1, |&(li, _)| li + 1);
                    let elems = self.src_elems(*src)
                        + self.bufs[*bands].elems
                        + self.bufs[*pool_acc].elems
                        + dense.iter().map(|&(_, acc)| self.bufs[acc].elems).sum::<usize>()
                        + self.bufs[*logits].elems;
                    StepMeta {
                        index,
                        kind: "fused-iter",
                        label: format!("fused-iter[{a}..{end})"),
                        layers: (*a, end),
                        bytes: 4 * elems as u64 + self.param_bytes(*a, end),
                    }
                }
            })
            .collect()
    }

    /// Static labels of the sub-step **units** inside every compiled
    /// step, keyed `[step][unit]` — the naming side of the
    /// [`crate::ops::UnitProfiler`] brackets that
    /// [`crate::ops::FusedBlock::run_streaming_units`] and the
    /// fused-iter tail emit. Fused steps expose one unit per block
    /// layer plus the copy-out sink; fused-iter steps expose the block
    /// layers, the global-pool unit (streamed accumulate + final
    /// scale), each trailing dense layer, and the logits copy.
    /// Stash/single steps have no interior units (empty vec).
    pub fn step_unit_labels(&self) -> Vec<Vec<String>> {
        self.steps
            .iter()
            .map(|step| match step {
                Step::StashSave { .. } | Step::Single { .. } => Vec::new(),
                Step::Fused { a, conv_end, .. } => {
                    let mut labels: Vec<String> = (*a..*conv_end)
                        .map(|li| format!("{}[{li}]", kind_name(self.model.layers[li].kind)))
                        .collect();
                    labels.push("copy-out".to_string());
                    labels
                }
                Step::FusedIter { a, conv_end, dense, .. } => {
                    let mut labels: Vec<String> = (*a..*conv_end)
                        .map(|li| format!("{}[{li}]", kind_name(self.model.layers[li].kind)))
                        .collect();
                    labels.push(format!("gap[{conv_end}]"));
                    for &(li, _) in dense {
                        labels.push(format!("dense[{li}]"));
                    }
                    labels.push("logits".to_string());
                    labels
                }
            })
            .collect()
    }

    /// Pool size in f32 elements (the runtime storage bound every
    /// [`BufAccess`] must fall inside).
    pub fn pool_elem_len(&self) -> usize {
        self.pool_elems
    }

    /// The pool buffer pre-populated with the external input before the
    /// step list runs (`None` when a fused head streams the input
    /// instead) — the verifier's only predefined range.
    pub fn input_buffer(&self) -> Option<usize> {
        self.input_buf
    }

    /// The pool buffer the logits are copied out of after the last step.
    pub fn output_buffer(&self) -> usize {
        self.out_buf
    }

    /// Label-carrying view of the runtime pool buffers, indexed by the
    /// `buf` field of every [`BufAccess`].
    pub fn runtime_buffers(&self) -> Vec<RtBufInfo> {
        self.bufs
            .iter()
            .zip(&self.buf_meta)
            .map(|(b, m)| RtBufInfo {
                label: m.label.clone(),
                off: b.off,
                elems: b.elems,
                dims: b.dims,
                birth: m.birth,
                death: m.rt_death,
            })
            .collect()
    }

    /// The symbolic access set of every compiled step, in execution
    /// order — what [`crate::analysis::verify_dataflow`] walks instead
    /// of running the kernels.
    pub fn step_accesses(&self) -> Vec<StepAccess> {
        self.step_metas()
            .into_iter()
            .zip(&self.steps)
            .map(|(meta, step)| {
                let mut acc = StepAccess {
                    index: meta.index,
                    kind: meta.kind,
                    label: meta.label,
                    reads_external_input: false,
                    reads: Vec::new(),
                    writes: Vec::new(),
                    scratch: Vec::new(),
                    in_place_safe: false,
                };
                match step {
                    Step::StashSave { src, dst } => {
                        self.src_access(*src, &mut acc);
                        acc.writes.push(self.full_access(*dst));
                    }
                    Step::Single { src, out, residual, .. } => {
                        self.src_access(*src, &mut acc);
                        if let Some(stash) = residual {
                            acc.reads.push(self.full_access(*stash));
                        }
                        acc.writes.push(self.full_access(*out));
                    }
                    Step::Fused { src, bands, out, .. } => {
                        self.src_access(*src, &mut acc);
                        acc.scratch.push(self.full_access(*bands));
                        acc.writes.push(self.full_access(*out));
                    }
                    Step::FusedIter { src, bands, pool_acc, dense, logits, .. } => {
                        self.src_access(*src, &mut acc);
                        acc.scratch.push(self.full_access(*bands));
                        acc.scratch.push(self.full_access(*pool_acc));
                        for &(_, dense_acc) in dense {
                            acc.scratch.push(self.full_access(dense_acc));
                        }
                        acc.writes.push(self.full_access(*logits));
                    }
                }
                acc
            })
            .collect()
    }

    /// Whole-buffer access (every current kernel touches its buffers in
    /// full).
    fn full_access(&self, buf: usize) -> BufAccess {
        BufAccess { buf, start: 0, len: self.bufs[buf].elems }
    }

    /// Record a step source: either the external input flag or a
    /// whole-buffer pool read.
    fn src_access(&self, src: Src, acc: &mut StepAccess) {
        match src {
            Src::Input => acc.reads_external_input = true,
            Src::Buf(id) => acc.reads.push(self.full_access(id)),
        }
    }

    /// f32 elements a step source reads.
    fn src_elems(&self, src: Src) -> usize {
        match src {
            Src::Input => self.model.shapes[0].elems() as usize,
            Src::Buf(id) => self.bufs[id].elems,
        }
    }

    /// Parameter bytes of model layers `[a, b)` (f32 weights + biases).
    fn param_bytes(&self, a: usize, b: usize) -> u64 {
        self.params[a..b]
            .iter()
            .map(|p| 4 * (p.weights.len() + p.bias.len()) as u64)
            .sum()
    }

    /// The boundary-tensor index a stash buffer snapshots (label help).
    fn stash_tensor_of(&self, buf: usize) -> usize {
        self.layout
            .buffers
            .get(buf)
            .and_then(|b| b.label.strip_prefix("stash:v"))
            .and_then(|s| s.parse().ok())
            .unwrap_or(buf)
    }

    /// Convenience wrapper: run and materialize a [`RunReport`]
    /// (compiled runs have a compile-time-constant measured peak and no
    /// per-span breakdown — `spans` is empty).
    pub fn run(&self, input: &Tensor, pool: &mut PlanPool) -> RunReport {
        let mut out = vec![0.0f32; self.out_len];
        let macs = self.run_into(input.as_map(), pool, &mut out);
        RunReport {
            output: out,
            peak_ram: self.layout.watermark,
            macs,
            spans: Vec::new(),
        }
    }

    fn range_of(&self, id: usize) -> Range<usize> {
        let b = &self.bufs[id];
        b.off..b.off + b.elems
    }

    fn map_of<'p>(&self, id: usize, data: &'p [f32]) -> MapRef<'p> {
        let d = self.bufs[id].dims;
        MapRef::new(d.0, d.1, d.2, data)
    }

    fn run_step<U: UnitProfiler>(
        &self,
        step: &Step,
        input: MapRef<'_>,
        pool: &mut PlanPool,
        prof: &mut U,
    ) -> u64 {
        match step {
            Step::StashSave { src, dst } => {
                let dst_r = self.range_of(*dst);
                match *src {
                    Src::Input => pool.data[dst_r].copy_from_slice(input.data),
                    Src::Buf(sid) => {
                        let (s, d) = two_muts(&mut pool.data, self.range_of(sid), dst_r);
                        d.copy_from_slice(s);
                    }
                }
                0
            }

            Step::Single { layer, src, out, residual } => {
                let l = &self.model.layers[*layer];
                let p = &self.params[*layer];
                let out_r = self.range_of(*out);
                let macs = match *src {
                    // A single-layer first span materializes `v_0` in the
                    // pool (`input_buf`), so single steps always read a
                    // pool buffer.
                    Src::Input => unreachable!("single-layer step reading the external input"),
                    Src::Buf(sid) => {
                        let (src_s, out_s) =
                            two_muts(&mut pool.data, self.range_of(sid), out_r.clone());
                        let x = self.map_of(sid, src_s);
                        self.single_kernel(l, p, *layer, x, out_s)
                    }
                };
                // Cross-span residual add from the stash slice.
                if let Some(stash_id) = residual {
                    let (st, o) = two_muts(&mut pool.data, self.range_of(*stash_id), out_r);
                    for (a, b) in o.iter_mut().zip(st.iter()) {
                        *a += *b;
                    }
                }
                macs
            }

            Step::Fused { a, conv_end, src, bands, out, geom } => {
                let block = FusedBlock::new(&self.model, *a, *conv_end, &self.params);
                let depth = conv_end - a;
                let bands_r = self.range_of(*bands);
                let out_r = self.range_of(*out);
                let (_, wo, co) = self.bufs[*out].dims;
                let stats = match *src {
                    Src::Input => {
                        let (bands_s, out_s) = two_muts(&mut pool.data, bands_r, out_r);
                        let cache = HCache::new(geom, bands_s, &mut pool.ranges[..depth + 1]);
                        block.run_streaming_units(
                            input,
                            cache,
                            |r, row| {
                                out_s[r * wo * co..(r + 1) * wo * co]
                                    .copy_from_slice(&row[..wo * co]);
                            },
                            prof,
                        )
                    }
                    Src::Buf(sid) => {
                        let [src_s, bands_s, out_s] =
                            three_muts(&mut pool.data, [self.range_of(sid), bands_r, out_r]);
                        let x = self.map_of(sid, src_s);
                        let cache = HCache::new(geom, bands_s, &mut pool.ranges[..depth + 1]);
                        block.run_streaming_units(
                            x,
                            cache,
                            |r, row| {
                                out_s[r * wo * co..(r + 1) * wo * co]
                                    .copy_from_slice(&row[..wo * co]);
                            },
                            prof,
                        )
                    }
                };
                stats.macs
            }

            Step::FusedIter { a, conv_end, src, bands, geom, pool_acc, dense, logits } => {
                let block = FusedBlock::new(&self.model, *a, *conv_end, &self.params);
                let depth = conv_end - a;
                let out_shape = self.model.output_of(*conv_end - 1);
                let bands_r = self.range_of(*bands);
                let acc_r = self.range_of(*pool_acc);

                // Phase 1: stream final rows into the global-pool
                // accumulator (same op order as GlobalPoolIter).
                let mut macs = match *src {
                    Src::Input => {
                        let (bands_s, acc_s) =
                            two_muts(&mut pool.data, bands_r, acc_r.clone());
                        acc_s.fill(0.0);
                        let cache = HCache::new(geom, bands_s, &mut pool.ranges[..depth + 1]);
                        block
                            .run_streaming_units(
                                input,
                                cache,
                                |_r, row| {
                                    accumulate_row_major(&mut *acc_s, row);
                                },
                                prof,
                            )
                            .macs
                    }
                    Src::Buf(sid) => {
                        let [src_s, bands_s, acc_s] = three_muts(
                            &mut pool.data,
                            [self.range_of(sid), bands_r, acc_r.clone()],
                        );
                        acc_s.fill(0.0);
                        let x = self.map_of(sid, src_s);
                        let cache = HCache::new(geom, bands_s, &mut pool.ranges[..depth + 1]);
                        block
                            .run_streaming_units(
                                x,
                                cache,
                                |_r, row| {
                                    accumulate_row_major(&mut *acc_s, row);
                                },
                                prof,
                            )
                            .macs
                    }
                };
                // finish(): the shared in-place scale — bit-identical to
                // GlobalPoolIter::finish. Folded into unit `depth` (the
                // "gap" row the accumulate sink already timed into).
                prof.unit_begin();
                scale_avg(
                    &mut pool.data[acc_r.clone()],
                    out_shape.h as usize * out_shape.w as usize,
                );
                macs += out_shape.elems();
                prof.unit_end(depth, out_shape.elems());

                // Phase 2: iterative dense chain, one accumulator per
                // trailing Dense layer (same order as DenseIter).
                let mut prev_r = acc_r;
                for (di, &(li, acc_id)) in dense.iter().enumerate() {
                    let p = &self.params[li];
                    let dout = self.model.layers[li].cout as usize;
                    let next_r = self.range_of(acc_id);
                    prof.unit_begin();
                    let (x_s, y_s) = two_muts(&mut pool.data, prev_r.clone(), next_r.clone());
                    dense_into(x_s, &p.weights, &p.bias, dout, y_s);
                    let dmacs = (x_s.len() * dout) as u64;
                    macs += dmacs;
                    prof.unit_end(depth + 1 + di, dmacs);
                    prev_r = next_r;
                }

                // Phase 3: logits copy.
                prof.unit_begin();
                let (v_s, l_s) = two_muts(&mut pool.data, prev_r, self.range_of(*logits));
                l_s.copy_from_slice(v_s);
                prof.unit_end(depth + 1 + dense.len(), 0);
                macs
            }
        }
    }

    /// Single unfused layer through the allocation-free kernels — same
    /// loops, same MAC accounting as the interpreted engine.
    fn single_kernel(
        &self,
        l: &Layer,
        p: &LayerParams,
        li: usize,
        x: MapRef<'_>,
        out: &mut [f32],
    ) -> u64 {
        match l.kind {
            LayerKind::Conv2d => {
                conv2d_into(
                    x,
                    &p.weights,
                    &p.bias,
                    l.k as usize,
                    l.stride as usize,
                    l.padding as usize,
                    l.cout as usize,
                    l.act,
                    out,
                );
                self.model.layer_macs(li)
            }
            LayerKind::DwConv2d => {
                dwconv2d_into(
                    x,
                    &p.weights,
                    &p.bias,
                    l.k as usize,
                    l.stride as usize,
                    l.padding as usize,
                    l.act,
                    out,
                );
                self.model.layer_macs(li)
            }
            LayerKind::AvgPool => {
                avg_pool2d_into(x, l.k as usize, l.stride as usize, out);
                self.model.layer_macs(li)
            }
            LayerKind::MaxPool => {
                max_pool2d_into(x, l.k as usize, l.stride as usize, out);
                self.model.layer_macs(li)
            }
            LayerKind::GlobalAvgPool => {
                global_avg_pool_into(x, out);
                x.elems() as u64
            }
            LayerKind::Dense => {
                dense_into(x.data, &p.weights, &p.bias, l.cout as usize, out);
                self.model.layer_macs(li)
            }
        }
    }
}

/// Step-label name of a layer kind.
fn kind_name(k: LayerKind) -> &'static str {
    match k {
        LayerKind::Conv2d => "conv2d",
        LayerKind::DwConv2d => "dwconv2d",
        LayerKind::AvgPool => "avg_pool",
        LayerKind::MaxPool => "max_pool",
        LayerKind::GlobalAvgPool => "global_avg_pool",
        LayerKind::Dense => "dense",
    }
}

/// Two disjoint mutable slices out of one backing slice.
fn two_muts(data: &mut [f32], a: Range<usize>, b: Range<usize>) -> (&mut [f32], &mut [f32]) {
    if a.start <= b.start {
        debug_assert!(a.end <= b.start, "pool ranges overlap");
        let (l, r) = data.split_at_mut(b.start);
        (&mut l[a.start..a.end], &mut r[..b.end - b.start])
    } else {
        let (bs, as_) = two_muts(data, b, a);
        (as_, bs)
    }
}

/// Three disjoint mutable slices out of one backing slice (any order).
fn three_muts(data: &mut [f32], r: [Range<usize>; 3]) -> [&mut [f32]; 3] {
    let mut idx = [0usize, 1, 2];
    idx.sort_by_key(|&i| r[i].start);
    let (lo, mid, hi) = (r[idx[0]].clone(), r[idx[1]].clone(), r[idx[2]].clone());
    debug_assert!(lo.end <= mid.start && mid.end <= hi.start, "pool ranges overlap");
    let (l, rest) = data.split_at_mut(mid.start);
    let (m, h) = rest.split_at_mut(hi.start - mid.start);
    let s_lo = &mut l[lo.start..lo.end];
    let s_mid = &mut m[..mid.end - mid.start];
    let s_hi = &mut h[..hi.end - hi.start];
    let mut out: [Option<&mut [f32]>; 3] = [None, None, None];
    out[idx[0]] = Some(s_lo);
    out[idx[1]] = Some(s_mid);
    out[idx[2]] = Some(s_hi);
    out.map(|o| o.expect("all three slots assigned"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Engine;
    use crate::memory::Arena;
    use crate::ops::ParamGen;
    use crate::optimizer::{strategy, Constraints, Planner};
    use crate::zoo;

    fn rand_input(m: &ModelChain, seed: u64) -> Tensor {
        let s = m.shapes[0];
        Tensor::from_data(
            s.h as usize,
            s.w as usize,
            s.c as usize,
            ParamGen::new(seed).fill(s.elems() as usize, 2.0),
        )
    }

    #[test]
    fn compiled_is_bit_identical_to_interpreted() {
        let m = zoo::quickstart();
        let engine = Engine::new(m.clone());
        let mut planner = Planner::for_model(m.clone());
        let fused = planner.setting().unwrap();
        let vanilla = planner
            .plan_with(&strategy::Vanilla, Constraints::none())
            .unwrap()
            .setting;
        let x = rand_input(&m, 21);
        for setting in [vanilla, fused] {
            let mut arena = Arena::unbounded();
            let interp = engine.run(&setting, &x, &mut arena).unwrap();
            let compiled = engine.compile(&setting);
            let mut pool = compiled.make_pool();
            let report = compiled.run(&x, &mut pool);
            assert_eq!(report.output, interp.output, "{}", setting.describe());
            assert_eq!(report.macs, interp.macs, "{}", setting.describe());
            assert_eq!(report.peak_ram, interp.peak_ram, "{}", setting.describe());
        }
    }

    #[test]
    fn hot_path_performs_zero_allocations_after_compile() {
        let m = zoo::tiny_cnn();
        let setting = Planner::for_model(m.clone()).setting().unwrap();
        let compiled = CompiledPlan::compile(m.clone(), setting);
        let mut pool = compiled.make_pool();
        let allocs0 = pool.storage_allocs();
        let ptr0 = pool.storage_ptr();
        let elems0 = pool.elems();
        let x = rand_input(&m, 5);
        let mut out = vec![0.0f32; compiled.output_len()];
        let mut first: Option<Vec<f32>> = None;
        for _ in 0..50 {
            compiled.run_into(x.as_map(), &mut pool, &mut out);
            match &first {
                None => first = Some(out.clone()),
                Some(f) => assert_eq!(&out, f, "warm pool reuse changed the output"),
            }
        }
        assert_eq!(pool.storage_allocs(), allocs0, "hot path allocated");
        assert_eq!(pool.storage_ptr(), ptr0, "pool storage moved");
        assert_eq!(pool.elems(), elems0, "pool storage resized");
    }

    #[test]
    fn pool_layout_is_consistent() {
        let m = zoo::kws_cnn();
        let setting = Planner::for_model(m.clone()).setting().unwrap();
        let compiled = CompiledPlan::compile(m, setting);
        let layout = compiled.layout();
        assert!(layout.pool_bytes >= layout.watermark);
        // Lifetime-overlapping buffers never overlap in pool space.
        for (i, a) in layout.buffers.iter().enumerate() {
            for b in layout.buffers.iter().skip(i + 1) {
                let live = a.birth < b.death && b.birth < a.death;
                let space = a.offset < b.offset + b.bytes && b.offset < a.offset + a.bytes;
                assert!(!(live && space), "'{}' and '{}' collide", a.label, b.label);
            }
        }
    }

    #[test]
    fn residual_model_compiles_and_matches() {
        let m = zoo::mcunet_vww5();
        let engine = Engine::new(m.clone());
        let setting = Planner::for_model(m.clone()).setting().unwrap();
        let x = rand_input(&m, 9);
        let mut arena = Arena::unbounded();
        let interp = engine.run(&setting, &x, &mut arena).unwrap();
        let compiled = engine.compile(&setting);
        let mut pool = compiled.make_pool();
        let report = compiled.run(&x, &mut pool);
        assert_eq!(report.output, interp.output);
        assert_eq!(report.macs, interp.macs);
        assert_eq!(compiled.measured_peak(), interp.peak_ram);
    }
}
