//! Fusion-block analytics — the paper's Appendices B & C made executable.
//!
//! A *fusion block* spans layers `[a, b)` of a [`ModelChain`] and executes
//! them patch-by-patch under the **H-cache** scheme (paper §4): horizontal
//! overlaps are cached (each horizontal window position is computed once),
//! vertical overlaps between successive row-bands are recomputed. The
//! streaming unit is a full-width row band; the block emits one final
//! output row per iteration (the paper fixes "output elements per
//! iteration" to one — §9 Parameter Space).
//!
//! Submodules:
//! * [`tiles`]  — receptive band recursion (the `t_i` of Eq. 11/12)
//! * [`hcache`] — cache buffer sizing (Eq. 11)
//! * [`macs`]   — fused MAC counts (Eq. 12–15; see note on the Eq. 14
//!   `c_out`/`c_in` typo in `macs.rs`)
//! * [`ram`]    — peak-RAM encoding of single layers and blocks (Eq. 5–6)
//! * [`memo`]   — thread-shared per-model edge-cost memo for repeated DAG
//!   builds (the [`crate::optimizer::PlanBatch`] fast path)

pub mod hcache;
pub mod macs;
pub mod memo;
pub mod ram;
pub mod scheme;
pub mod tiles;

pub use hcache::{block_cache_bytes, layer_cache_bytes};
pub use macs::{block_macs, fused_layer_macs};
pub use memo::{span_edge_cost, CostMemo};
pub use ram::{block_peak_ram, block_peak_ram_scheme, single_layer_ram, EdgeCost};
pub use scheme::{scheme_block_macs, scheme_cache_bytes, CacheScheme};
pub use tiles::{band_heights, stride_products};

use crate::model::ModelChain;

/// Fully analyzed fusion block candidate: layers `[a, b)` of `model`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockSpan {
    pub a: usize,
    pub b: usize,
}

impl BlockSpan {
    pub fn new(a: usize, b: usize) -> Self {
        assert!(b > a, "empty span");
        Self { a, b }
    }

    pub fn len(&self) -> usize {
        self.b - self.a
    }

    pub fn is_single(&self) -> bool {
        self.len() == 1
    }

    /// Edge cost (RAM + MACs) of this span in `model`, under H-cache fusion
    /// when `len() > 1`. `iterative_tail` marks that the block's output
    /// streams straight into an iterative pool/dense tail (§7), so the full
    /// output map is never materialized.
    pub fn cost(&self, model: &ModelChain, iterative_tail: bool) -> EdgeCost {
        self.cost_scheme(model, iterative_tail, CacheScheme::HCache)
    }

    /// [`Self::cost`] under an explicit cache scheme (§9 ablations).
    pub fn cost_scheme(
        &self,
        model: &ModelChain,
        iterative_tail: bool,
        scheme: CacheScheme,
    ) -> EdgeCost {
        if self.is_single() {
            EdgeCost {
                ram_bytes: single_layer_ram(model, self.a),
                macs: model.layer_macs(self.a),
            }
        } else {
            EdgeCost {
                ram_bytes: block_peak_ram_scheme(model, self.a, self.b, iterative_tail, scheme),
                macs: scheme_block_macs(model, self.a, self.b, scheme),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Activation, Layer, ModelChain, TensorShape};

    fn two_convs() -> ModelChain {
        ModelChain::new(
            "t",
            TensorShape::new(16, 16, 3),
            vec![
                Layer::conv("c0", 3, 1, 0, 3, 8, Activation::Relu6),
                Layer::conv("c1", 3, 1, 0, 8, 4, Activation::Relu6),
            ],
        )
    }

    #[test]
    fn single_span_cost_is_vanilla() {
        let m = two_convs();
        let c = BlockSpan::new(0, 1).cost(&m, false);
        assert_eq!(c.macs, m.layer_macs(0));
        assert_eq!(c.ram_bytes, m.tensor_bytes(0) + m.tensor_bytes(1));
    }

    #[test]
    fn fused_span_trades_ram_for_macs() {
        let m = two_convs();
        let vanilla_peak = m.vanilla_peak_ram();
        let fused = BlockSpan::new(0, 2).cost(&m, false);
        let vanilla_macs = m.total_macs();
        assert!(fused.ram_bytes < vanilla_peak, "fusion must cut peak RAM");
        assert!(fused.macs >= vanilla_macs, "H-cache recompute can only add MACs");
    }
}
