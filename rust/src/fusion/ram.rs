//! Peak-RAM encoding of edges (paper Eq. 5–6).
//!
//! Edge RAM convention (context-free per edge, as the paper's DAG
//! requires — Eq. 6 takes a max over edge weights):
//!
//! * **Single layer** `[a, a+1)`:  `P = I_full + O_full (+ residual stash)`
//!   — both boundary maps materialized (Eq. 5 with `Buf = 0`).
//! * **Fusion block** `[a, b)`:
//!   `P = I_strip + O + Buf (+ residual stash inside the block)` where
//!   - `I_strip` = the first layer's live input band
//!     (`t_a × w_a × c_a` rows of the source — streamed, so the *full*
//!     input never occupies RAM; this is how fusion "decouples input size
//!     from memory usage"),
//!   - `O` = the full output map `v_b` **unless** the block's tail streams
//!     into the iterative pool/dense rewrite (§7), in which case `O` is
//!     just the accumulator chain (`c_last + Σ dense outs`, 4-byte accs),
//!   - `Buf` = Eq. 11 H-cache bytes ([`super::hcache`]).
//!
//! The producer of `v_a` counts the full `v_a` in *its* edge weight, so a
//! path's max-over-edges still sees every materialized tensor.

use crate::model::ModelChain;

use super::tiles::band_heights;

/// RAM+MAC weight attached to a DAG edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EdgeCost {
    pub ram_bytes: u64,
    pub macs: u64,
}

/// Eq. 5 for an unfused layer: full input + full output + residual stash.
pub fn single_layer_ram(model: &ModelChain, li: usize) -> u64 {
    model.tensor_bytes(li) + model.tensor_bytes(li + 1) + model.residual_stash_bytes(li)
}

/// Eq. 5 for fusion block `[a, b)` under H-cache.
pub fn block_peak_ram(model: &ModelChain, a: usize, b: usize, iterative_tail: bool) -> u64 {
    block_peak_ram_scheme(model, a, b, iterative_tail, super::CacheScheme::HCache)
}

/// Eq. 5 under an explicit cache scheme (§9 "Caching Paradigm").
pub fn block_peak_ram_scheme(
    model: &ModelChain,
    a: usize,
    b: usize,
    iterative_tail: bool,
    scheme: super::CacheScheme,
) -> u64 {
    let eb = model.elem_bytes as u64;
    let t = band_heights(model, a, b, 1);
    let first_in = model.input_of(a);
    let l0 = &model.layers[a];
    // Live input window of the first layer: a `t_0`-row, `k_0`-column tile
    // of the (streamed) source — the same Eq. 11 strip every cached layer
    // keeps; the first layer's window is the block's I term (which is why
    // Eq. 11 sets Buf_1 = 0 instead of charging it twice). `t_0` counts
    // *rows* (band height), so it clamps against the padded map height;
    // the kernel extent `k_0` spans columns and clamps against the padded
    // width — non-square inputs (e.g. 49×10 KWS spectrograms) hit the two
    // clamps differently.
    let t0 = t[0].min(first_in.h + 2 * l0.padding) as u64;
    let i_strip = t0 * l0.k.min(first_in.w + 2 * l0.padding) as u64 * first_in.c as u64 * eb;

    let o_bytes = if iterative_tail {
        // §7: output rows stream into iterative global-pool + dense; only
        // f32 accumulators live (pool acc of c_last + each dense output).
        let c_last = model.output_of(b - 1).c as u64;
        let dense_outs: u64 = model.layers[b..]
            .iter()
            .filter(|l| matches!(l.kind, crate::model::LayerKind::Dense))
            .map(|l| l.cout as u64)
            .sum();
        4 * (c_last + dense_outs)
    } else {
        model.tensor_bytes(b)
    };

    let stash: u64 = (a..b).map(|i| model.residual_stash_bytes(i)).max().unwrap_or(0);
    i_strip + o_bytes + super::scheme_cache_bytes(model, a, b, scheme) + stash
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Activation, Layer, ModelChain, TensorShape};

    fn chain() -> ModelChain {
        ModelChain::new(
            "r",
            TensorShape::new(32, 32, 3),
            vec![
                Layer::conv("c0", 3, 1, 0, 3, 8, Activation::Relu6), // v1 = 30x30x8
                Layer::conv("c1", 3, 2, 0, 8, 16, Activation::Relu6), // v2 = 14x14x16
                Layer::global_pool("gp", 16),
                Layer::dense("fc", 16, 10),
            ],
        )
    }

    #[test]
    fn single_layer_is_io_sum() {
        let m = chain();
        assert_eq!(single_layer_ram(&m, 0), 32 * 32 * 3 + 30 * 30 * 8);
    }

    #[test]
    fn fused_head_drops_input_map() {
        let m = chain();
        let fused = block_peak_ram(&m, 0, 2, false);
        // Tile model (Eq. 11): tiles for 1 output elem: c1 tile 3, c0 tile
        // (3-1)*1+3 = 5. I_strip = 5*3*3 = 45; Buf(c1) = 3*3*8 = 72;
        // O = 14*14*16 = 3136 (materialized block output).
        assert_eq!(fused, 45 + 72 + 3136);
        assert!(fused < single_layer_ram(&m, 0));
    }

    #[test]
    fn iterative_tail_shrinks_output_term() {
        let m = chain();
        let solid = block_peak_ram(&m, 0, 2, false);
        let streamed = block_peak_ram(&m, 0, 2, true);
        // O term becomes 4*(16 + 10) = 104 instead of 3136.
        assert_eq!(solid - streamed, 3136 - 104);
    }

    #[test]
    fn input_size_decoupling() {
        // Doubling the input image must not change the fused block's RAM
        // except via the (band × width) strip — the paper's larger-input
        // enablement claim.
        let small = chain();
        let big = ModelChain::new(
            "r2",
            TensorShape::new(64, 64, 3),
            small.layers.clone(),
        );
        let rs = block_peak_ram(&small, 0, 2, true);
        let rb = block_peak_ram(&big, 0, 2, true);
        // Full-map vanilla grows ~4x; the fused strip terms only ~2x (width).
        assert!(rb < 3 * rs);
        assert!(big.vanilla_peak_ram() > 3 * small.vanilla_peak_ram());
    }
}
