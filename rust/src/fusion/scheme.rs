//! Cache-scheme taxonomy (paper §2, after DeFiNES; §9 "Caching Paradigm").
//!
//! The paper evaluates the **H-cache** point of the DeFiNES spectrum and
//! names the other two as future work; all three are implemented here so
//! the ablation bench (`cargo bench` → `tables`) can show the
//! cache-vs-recompute trade-off the paper describes: "enhanced caching
//! progressively reduces compute redundancy but proportionally increases
//! RAM usage".
//!
//! * [`CacheScheme::FullyRecompute`] — no caches (`Buf = 0`); every
//!   overlapping element of every tile pyramid is recomputed on both
//!   axes.
//! * [`CacheScheme::HCache`] — the paper's default: horizontal overlaps
//!   cached (Eq. 11 strips), vertical overlaps recomputed (Eq. 12–15).
//! * [`CacheScheme::FullyCache`] — full line buffers per layer: no
//!   recompute at all (fused MACs = vanilla MACs), at the cost of
//!   full-width `w×k×c` caches.

use crate::model::{LayerKind, ModelChain};

use super::tiles::band_heights;

/// Intra-block caching strategy for a fusion block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum CacheScheme {
    /// No fusion cache; recompute every overlap (DeFiNES "fully-recompute").
    FullyRecompute,
    /// Cache horizontal strips, recompute vertical overlap (the paper's
    /// working point — "a good trade-off between buffer size and
    /// recompute cost on MCUs", §4).
    #[default]
    HCache,
    /// Cache everything that would otherwise be recomputed
    /// (DeFiNES "fully-cache"): line buffers of the full map width.
    FullyCache,
}

impl CacheScheme {
    pub const ALL: [CacheScheme; 3] =
        [CacheScheme::FullyRecompute, CacheScheme::HCache, CacheScheme::FullyCache];

    pub fn name(self) -> &'static str {
        match self {
            CacheScheme::FullyRecompute => "fully-recompute",
            CacheScheme::HCache => "h-cache",
            CacheScheme::FullyCache => "fully-cache",
        }
    }
}

/// Cache bytes of block `[a, b)` under `scheme`.
pub fn scheme_cache_bytes(model: &ModelChain, a: usize, b: usize, scheme: CacheScheme) -> u64 {
    match scheme {
        CacheScheme::FullyRecompute => 0,
        CacheScheme::HCache => super::hcache::block_cache_bytes(model, a, b),
        CacheScheme::FullyCache => {
            // Full-width line buffers: w × k × c_in per non-first layer.
            (a + 1..b)
                .map(|li| {
                    let l = &model.layers[li];
                    let inp = model.input_of(li);
                    (inp.w + 2 * l.padding) as u64
                        * l.k as u64
                        * l.cin as u64
                        * model.elem_bytes as u64
                })
                .sum()
        }
    }
}

/// Fused MACs of block `[a, b)` under `scheme`.
pub fn scheme_block_macs(model: &ModelChain, a: usize, b: usize, scheme: CacheScheme) -> u64 {
    match scheme {
        // Caches eliminate all recompute: fused == vanilla MACs.
        CacheScheme::FullyCache => (a..b).map(|li| model.layer_macs(li)).sum(),
        CacheScheme::HCache => super::macs::block_macs(model, a, b),
        CacheScheme::FullyRecompute => {
            // Square t_i × t_i tile pyramid recomputed per final output
            // element: both axes pay the overlap.
            let t = band_heights(model, a, b, 1);
            let out = model.output_of(b - 1);
            let n_tiles = out.h as u64 * out.w as u64;
            (0..b - a)
                .map(|idx| {
                    let li = a + idx;
                    let l = &model.layers[li];
                    if !matches!(
                        l.kind,
                        LayerKind::Conv2d
                            | LayerKind::DwConv2d
                            | LayerKind::AvgPool
                            | LayerKind::MaxPool
                    ) {
                        return model.layer_macs(li);
                    }
                    let inp = model.input_of(li);
                    let t_i = t[idx]
                        .min(inp.h + 2 * l.padding)
                        .min(inp.w + 2 * l.padding);
                    let per_axis = ((t_i - l.k) / l.stride + 1) as u64;
                    let out_elems_per_tile = per_axis * per_axis * l.cout as u64;
                    n_tiles * out_elems_per_tile * l.macs_per_out_elem()
                })
                .sum()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Activation, Layer, ModelChain, TensorShape};

    fn chain() -> ModelChain {
        ModelChain::new(
            "s",
            TensorShape::new(24, 24, 3),
            vec![
                Layer::conv("c0", 3, 1, 1, 3, 8, Activation::Relu6),
                Layer::conv("c1", 3, 1, 1, 8, 8, Activation::Relu6),
                Layer::conv("c2", 3, 2, 1, 8, 16, Activation::Relu6),
            ],
        )
    }

    #[test]
    fn cache_bytes_ordering() {
        // More caching => more RAM: FR <= HC <= FC (DeFiNES trade-off).
        let m = chain();
        let fr = scheme_cache_bytes(&m, 0, 3, CacheScheme::FullyRecompute);
        let hc = scheme_cache_bytes(&m, 0, 3, CacheScheme::HCache);
        let fc = scheme_cache_bytes(&m, 0, 3, CacheScheme::FullyCache);
        assert_eq!(fr, 0);
        assert!(hc > fr);
        assert!(fc > hc, "full-width line buffers exceed tile strips");
    }

    #[test]
    fn macs_ordering() {
        // More caching => less recompute: FR >= HC >= FC == vanilla.
        let m = chain();
        let fr = scheme_block_macs(&m, 0, 3, CacheScheme::FullyRecompute);
        let hc = scheme_block_macs(&m, 0, 3, CacheScheme::HCache);
        let fc = scheme_block_macs(&m, 0, 3, CacheScheme::FullyCache);
        let vanilla: u64 = (0..3).map(|i| m.layer_macs(i)).sum();
        assert_eq!(fc, vanilla);
        assert!(hc >= fc);
        assert!(fr > hc, "fully-recompute must pay both axes");
    }

    #[test]
    fn hcache_is_the_default() {
        assert_eq!(CacheScheme::default(), CacheScheme::HCache);
        assert_eq!(CacheScheme::ALL.len(), 3);
    }

    #[test]
    fn single_layer_blocks_degenerate_consistently() {
        // Depth-1 "block": every scheme should cost vanilla MACs.
        let m = chain();
        for scheme in CacheScheme::ALL {
            assert_eq!(scheme_block_macs(&m, 1, 2, scheme), m.layer_macs(1), "{scheme:?}");
        }
    }
}
