//! H-cache buffer sizing (paper Eq. 11, literal).
//!
//! Under the H-cache scheme each non-first layer `i` of a fusion block
//! keeps a cache of `t_i × k_i × c_i_in` elements: a `t_i`-wide, `k_i`-tall
//! strip of its input tile, so horizontal window positions are computed
//! exactly once. `t_i` is the tile size at layer `i`'s input — the
//! receptive extent of one final-output element propagated backwards
//! through the block ([`super::tiles::band_heights`], clamped to the
//! padded map extent). The first layer needs no cache (`Buf_1 = 0`) — its
//! input streams from the block's source (previous boundary tensor, or the
//! sensor/flash for the model input, which is how fusion "decouples input
//! size from memory usage").

use crate::model::ModelChain;

use super::tiles::band_heights;

/// Eq. 11 for one layer: `t_i × k_i × c_i_in` bytes, where `t_i` is the
/// block-dependent tile extent at layer `li = a + idx` of block `[a, b)`.
pub fn layer_cache_bytes(model: &ModelChain, a: usize, b: usize, idx: usize) -> u64 {
    let t = band_heights(model, a, b, 1);
    let li = a + idx;
    let l = &model.layers[li];
    let inp = model.input_of(li);
    // Tile extent cannot exceed the padded map width.
    let t_i = (t[idx]).min(inp.w + 2 * l.padding) as u64;
    t_i * l.k as u64 * l.cin as u64 * model.elem_bytes as u64
}

/// Total H-cache bytes of block `[a, b)` (Eq. 11 summed; first layer free).
pub fn block_cache_bytes(model: &ModelChain, a: usize, b: usize) -> u64 {
    (1..b - a).map(|idx| layer_cache_bytes(model, a, b, idx)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Activation, Layer, ModelChain, TensorShape};

    fn chain() -> ModelChain {
        ModelChain::new(
            "h",
            TensorShape::new(16, 16, 3),
            vec![
                Layer::conv("c0", 3, 1, 0, 3, 8, Activation::Relu6), // in 16x16x3
                Layer::conv("c1", 3, 1, 0, 8, 4, Activation::Relu6), // in 14x14x8
                Layer::conv("c2", 3, 2, 0, 4, 4, Activation::Relu6), // in 12x12x4
            ],
        )
    }

    #[test]
    fn eq11_uses_tile_extent() {
        let m = chain();
        // Block [0,2): tiles (1 out elem): c1 tile t=3 -> 3*3*8 = 72 B.
        assert_eq!(layer_cache_bytes(&m, 0, 2, 1), 72);
        // Block [0,3): c2 (s=2) tile t=(1-1)*2+3=3 -> 3*3*4 = 36;
        // c1 tile t=(3-1)*1+3=5 -> 5*3*8 = 120.
        assert_eq!(layer_cache_bytes(&m, 0, 3, 2), 36);
        assert_eq!(layer_cache_bytes(&m, 0, 3, 1), 120);
    }

    #[test]
    fn first_layer_is_free() {
        let m = chain();
        assert_eq!(block_cache_bytes(&m, 0, 2), 72);
        assert_eq!(block_cache_bytes(&m, 0, 3), 120 + 36);
        // c1 as block head pays nothing; only c2's cache counts.
        assert_eq!(block_cache_bytes(&m, 1, 3), 36);
    }

    #[test]
    fn tile_clamped_to_map_width() {
        // A deep block over a tiny map: tile extent cannot exceed width.
        let m = ModelChain::new(
            "tiny",
            TensorShape::new(6, 6, 2),
            vec![
                Layer::conv("c0", 3, 1, 1, 2, 2, Activation::None),
                Layer::conv("c1", 3, 1, 1, 2, 2, Activation::None),
                Layer::conv("c2", 3, 1, 1, 2, 2, Activation::None),
            ],
        );
        // c1's unclamped tile would be 5; padded width is 6+2=8 -> 5 ok.
        // Force the clamp with block [0,3) at layer 1: t=5 <= 8 fine; the
        // clamp guards deep blocks where t would exceed the map.
        let deep = layer_cache_bytes(&m, 0, 3, 1);
        assert!(deep <= 8 * 3 * 2);
    }

    #[test]
    fn deeper_block_grows_cache_of_early_layers() {
        let m = chain();
        // c1's cache inside [0,3) (tile 7) exceeds its cache inside [0,2)
        // (tile 3): deeper fusion needs wider tiles upstream.
        assert!(layer_cache_bytes(&m, 0, 3, 1) > layer_cache_bytes(&m, 0, 2, 1));
    }

    #[test]
    fn pointwise_needs_single_element_row() {
        let m = ModelChain::new(
            "pw",
            TensorShape::new(8, 8, 4),
            vec![
                Layer::conv("c0", 3, 1, 0, 4, 8, Activation::None),
                Layer::pointwise("pw", 8, 2, Activation::None), // k=1 -> t=1
            ],
        );
        assert_eq!(layer_cache_bytes(&m, 0, 2, 1), 1 * 1 * 8);
    }
}
