//! Receptive row-band recursion through a fusion block.
//!
//! With one final-output row emitted per iteration, layer `i`'s input must
//! supply a band of `t_i` rows where (walking backwards through the block)
//!
//! ```text
//! t_last_out = 1
//! t_i = (t_{i+1}^{in-rows-as-output} - 1) * stride_i + k_i
//! ```
//!
//! This is the same recursion the L1 Pallas kernel uses
//! (`python/compile/kernels/fused_conv.py::band_rows_needed`) — kept in
//! lockstep by the cross-layer test in `rust/tests/fusion_vs_kernel.rs`.

use crate::model::ModelChain;

/// `t[i]` = input band height (rows) at layer `a+i` of block `[a, b)` to
/// produce `out_rows` rows of the block's final output.
pub fn band_heights(model: &ModelChain, a: usize, b: usize, out_rows: u32) -> Vec<u32> {
    assert!(b > a && b <= model.num_layers());
    let mut rows = out_rows;
    let mut t = vec![0u32; b - a];
    for (idx, li) in (a..b).enumerate().rev() {
        let l = &model.layers[li];
        rows = (rows - 1) * l.stride + l.k;
        t[idx] = rows;
    }
    t
}

/// `sp[i]` = vertical step (rows) the band advances at layer `a+i`'s input
/// when the block's final output advances by one row: the product of the
/// strides of layers `a+i .. b`.
pub fn stride_products(model: &ModelChain, a: usize, b: usize) -> Vec<u32> {
    let mut sp = vec![1u32; b - a + 1];
    for (idx, li) in (a..b).enumerate().rev() {
        sp[idx] = sp[idx + 1] * model.layers[li].stride;
    }
    sp.truncate(b - a);
    sp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Activation, Layer, ModelChain, TensorShape};

    fn stack(strides: &[u32], ks: &[u32]) -> ModelChain {
        let mut layers = Vec::new();
        let mut c = 3;
        for (i, (&s, &k)) in strides.iter().zip(ks).enumerate() {
            layers.push(Layer::conv(format!("c{i}"), k, s, 0, c, c + 1, Activation::None));
            c += 1;
        }
        ModelChain::new("s", TensorShape::new(64, 64, 3), layers)
    }

    #[test]
    fn matches_python_kernel_recursion() {
        // Mirror of test_band_rows_needed_recursion in test_kernel.py:
        // two 3x3 s1 layers -> [5, 3]; one 3x3 s2 layer, 4 out rows -> [9].
        let m = stack(&[1, 1], &[3, 3]);
        assert_eq!(band_heights(&m, 0, 2, 1), vec![5, 3]);
        let m = stack(&[2], &[3]);
        assert_eq!(band_heights(&m, 0, 1, 4), vec![9]);
    }

    #[test]
    fn stride_products_multiply_backwards() {
        let m = stack(&[2, 1, 2], &[3, 3, 3]);
        assert_eq!(stride_products(&m, 0, 3), vec![4, 2, 2]);
        assert_eq!(stride_products(&m, 1, 3), vec![2, 2]);
    }

    #[test]
    fn single_layer_band_is_kernel() {
        let m = stack(&[1], &[5]);
        assert_eq!(band_heights(&m, 0, 1, 1), vec![5]);
    }
}
