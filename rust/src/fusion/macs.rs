//! Fused MAC accounting (paper Eq. 12–15, H-cached & V-recompute).
//!
//! Per fused layer `i` of block `[a, b)` with input height `h_i`, band
//! height `t_i` ([`super::tiles::band_heights`]) and vertical band step
//! `sp_i` ([`super::tiles::stride_products`]):
//!
//! * vertical tile count (Eq. 12, vertical factor):
//!   `N_vert = floor((h_i - t_i) / sp_i) + 1`
//! * horizontal positions are H-cached, so the horizontal factor is the
//!   plain output width `w_out_i` (Eq. 12's horizontal factor with layer
//!   stride);
//! * output rows per band (Eq. 13): `rows = floor((t_i - k_i)/s_i) + 1`
//! * per-layer fused MACs (Eq. 14):
//!   `C = N_vert × rows × w_out × c_out × k² × c_in`.
//!
//! **Eq. 14 typo note**: the paper prints `C = N_tile × O_tile × k² ×
//! c_out`, but `O_tile` (Eq. 13) already carries the `c_out` factor; taking
//! the formula literally double-counts `c_out` and drops `c_in`, and would
//! not reduce to the vanilla conv MAC count when the block is a single
//! layer. We use `k² × c_in` per output element (the standard conv MAC
//! count; `k²` for depthwise), which makes the fused count collapse to the
//! vanilla count exactly when no vertical overlap exists — the property
//! `tests::no_overlap_means_no_overhead` locks in.

use crate::model::{LayerKind, ModelChain};

use super::tiles::{band_heights, stride_products};

/// MACs per output element of layer `li` (conv: `k²·c_in`; dw/pool: `k²`).
fn macs_per_elem(model: &ModelChain, li: usize) -> u64 {
    model.layers[li].macs_per_out_elem()
}

/// Fused MAC count of layer index `li` = `a + idx` inside block `[a, b)`.
pub fn fused_layer_macs(model: &ModelChain, a: usize, b: usize, idx: usize) -> u64 {
    let t = band_heights(model, a, b, 1);
    let sp = stride_products(model, a, b);
    let li = a + idx;
    let l = &model.layers[li];
    let inp = model.input_of(li);
    let out = model.output_of(li);

    // Padded input height (padding rows are materialized as zeros in the
    // stream; the analytical model folds them into h).
    let h = inp.h + 2 * l.padding;
    let t_i = t[idx].min(h); // a shallow block may see a band taller than the map
    let n_vert = if h >= t_i { (h - t_i) / sp[idx] + 1 } else { 1 };
    let rows_per_band = (t_i - l.k) / l.stride + 1;
    n_vert as u64 * rows_per_band as u64 * out.w as u64 * out.c as u64 * macs_per_elem(model, li)
}

/// Total fused MACs of block `[a, b)` (Eq. 15).
pub fn block_macs(model: &ModelChain, a: usize, b: usize) -> u64 {
    (0..b - a)
        .map(|idx| {
            let li = a + idx;
            match model.layers[li].kind {
                // Streamable ops only; guarded by ModelChain::fusable_span.
                LayerKind::Conv2d
                | LayerKind::DwConv2d
                | LayerKind::AvgPool
                | LayerKind::MaxPool => fused_layer_macs(model, a, b, idx),
                _ => model.layer_macs(li),
            }
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Activation, Layer, ModelChain, TensorShape};

    fn convs(n: usize, stride: u32) -> ModelChain {
        let mut layers = Vec::new();
        let mut c = 3;
        for i in 0..n {
            layers.push(Layer::conv(format!("c{i}"), 3, stride, 0, c, c, Activation::None));
            let _ = i;
            c = c; // channels constant
        }
        ModelChain::new("m", TensorShape::new(32, 32, 3), layers)
    }

    #[test]
    fn last_layer_never_recomputes() {
        // The final layer of a block emits each output row exactly once.
        let m = convs(2, 1);
        let fused_last = fused_layer_macs(&m, 0, 2, 1);
        assert_eq!(fused_last, m.layer_macs(1));
    }

    #[test]
    fn earlier_layers_pay_vertical_recompute() {
        let m = convs(2, 1);
        let fused_first = fused_layer_macs(&m, 0, 2, 0);
        // Band t_0 = 5, step 1: bands overlap by 2 rows -> recompute.
        assert!(fused_first > m.layer_macs(0));
    }

    #[test]
    fn no_overlap_means_no_overhead() {
        // k == stride: bands tile the input exactly; fused == vanilla.
        let m = ModelChain::new(
            "p",
            TensorShape::new(16, 16, 4),
            vec![
                Layer::avg_pool("p0", 2, 2, 4),
                Layer::avg_pool("p1", 2, 2, 4),
            ],
        );
        assert_eq!(block_macs(&m, 0, 2), m.layer_macs(0) + m.layer_macs(1));
    }

    #[test]
    fn deeper_blocks_cost_more() {
        let m = convs(4, 1);
        let f2 = block_macs(&m, 0, 2) + m.layer_macs(2) + m.layer_macs(3);
        let f4 = block_macs(&m, 0, 4);
        let vanilla = m.total_macs();
        assert!(f2 > vanilla);
        assert!(f4 > f2, "deeper fusion ⇒ more recompute (paper §3)");
    }

    #[test]
    fn overhead_factor_in_paper_range_for_small_stack() {
        // Sanity: 2-3 layer fusion overhead should be tens of percent, not
        // orders of magnitude (paper Table 1: F between 1.0 and 3.25).
        let m = convs(3, 1);
        let f = block_macs(&m, 0, 3) as f64 / m.total_macs() as f64;
        assert!(f > 1.0 && f < 3.0, "F = {f}");
    }
}
