//! Shared edge-cost memo for repeated DAG builds (the PlanBatch fast path).
//!
//! Eq. 5/11/12 edge costs depend only on `(a, b, iterative_tail, scheme)`
//! for a fixed model, yet every [`crate::graph::FusionDag::build`] call
//! recomputes all of them from scratch. A [`CostMemo`] caches the results
//! behind a mutex so concurrent planner workers sweeping many budgets over
//! the same model ([`crate::optimizer::PlanBatch`]) pay for each edge once.
//!
//! A memo is **per model**: keys carry no model identity, so sharing one
//! across models silently mixes costs. `PlanBatch` allocates one per
//! distinct model.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::model::ModelChain;

use super::{scheme_block_macs, BlockSpan, CacheScheme, EdgeCost};

/// Cost of the DAG edge for span `[a, b)` of `model`: single layer when
/// `b == a + 1`, H-cache-family fusion block otherwise. With
/// `iterative_tail`, the block streams into the §7 pool/dense rewrite and
/// the cost includes the tail layers' MACs (the edge jumps to the output
/// node). This is the single source of truth the DAG builder and the memo
/// both use.
pub fn span_edge_cost(
    model: &ModelChain,
    a: usize,
    b: usize,
    iterative_tail: bool,
    scheme: CacheScheme,
) -> EdgeCost {
    if !iterative_tail {
        BlockSpan::new(a, b).cost_scheme(model, false, scheme)
    } else {
        let n = model.num_layers();
        let tail_macs: u64 = (b..n).map(|i| model.layer_macs(i)).sum();
        EdgeCost {
            ram_bytes: super::ram::block_peak_ram_scheme(model, a, b, true, scheme),
            macs: scheme_block_macs(model, a, b, scheme) + tail_macs,
        }
    }
}

/// Thread-shared memo of [`span_edge_cost`] results for **one** model,
/// keyed by `(a, b, iterative_tail, scheme)`.
#[derive(Debug, Default)]
pub struct CostMemo {
    map: Mutex<HashMap<(usize, usize, bool, CacheScheme), EdgeCost>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CostMemo {
    pub fn new() -> Self {
        Self::default()
    }

    /// Memoized [`span_edge_cost`]. The analytical model runs outside the
    /// lock, so concurrent misses may compute the same edge twice — both
    /// arrive at the same pure result, and solver time dominates anyway.
    pub fn edge_cost(
        &self,
        model: &ModelChain,
        a: usize,
        b: usize,
        iterative_tail: bool,
        scheme: CacheScheme,
    ) -> EdgeCost {
        let key = (a, b, iterative_tail, scheme);
        if let Some(c) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *c;
        }
        let c = span_edge_cost(model, a, b, iterative_tail, scheme);
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.map.lock().unwrap().insert(key, c);
        c
    }

    /// `(hits, misses)` counters — the PlanBatch bench reports reuse.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Activation, Layer, TensorShape};

    fn chain() -> ModelChain {
        ModelChain::new(
            "m",
            TensorShape::new(24, 24, 3),
            vec![
                Layer::conv("c0", 3, 1, 1, 3, 8, Activation::Relu6),
                Layer::conv("c1", 3, 2, 1, 8, 16, Activation::Relu6),
                Layer::conv("c2", 3, 1, 1, 16, 16, Activation::Relu6),
                Layer::global_pool("gp", 16),
                Layer::dense("fc", 16, 10),
            ],
        )
    }

    #[test]
    fn memo_matches_direct_computation() {
        let m = chain();
        let memo = CostMemo::new();
        for (a, b, tail) in [(0usize, 1usize, false), (0, 2, false), (0, 3, false), (0, 3, true)] {
            for scheme in CacheScheme::ALL {
                let direct = span_edge_cost(&m, a, b, tail, scheme);
                assert_eq!(memo.edge_cost(&m, a, b, tail, scheme), direct);
                // Second lookup is a hit and returns the same cost.
                assert_eq!(memo.edge_cost(&m, a, b, tail, scheme), direct);
            }
        }
        let (hits, misses) = memo.stats();
        assert_eq!(misses, 12);
        assert_eq!(hits, 12);
    }

    #[test]
    fn tail_cost_includes_tail_macs() {
        let m = chain();
        let plain = span_edge_cost(&m, 0, 3, false, CacheScheme::HCache);
        let tail = span_edge_cost(&m, 0, 3, true, CacheScheme::HCache);
        let tail_macs: u64 = (3..5).map(|i| m.layer_macs(i)).sum();
        assert_eq!(tail.macs, plain.macs + tail_macs);
        assert!(tail.ram_bytes < plain.ram_bytes, "streamed tail drops the output map");
    }

    #[test]
    fn shared_across_threads() {
        let m = chain();
        let memo = CostMemo::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..8 {
                        memo.edge_cost(&m, 0, 3, false, CacheScheme::HCache);
                    }
                });
            }
        });
        let direct = span_edge_cost(&m, 0, 3, false, CacheScheme::HCache);
        assert_eq!(memo.edge_cost(&m, 0, 3, false, CacheScheme::HCache), direct);
        let (hits, misses) = memo.stats();
        assert_eq!(hits + misses, 33);
        assert!(hits >= 29, "concurrent misses are bounded by the thread count");
    }
}
