//! Quantized band executor: the int8 twin of [`crate::ops::FusedBlock`].
//!
//! Walks the identical receptive-field recursion (shared
//! [`crate::ops::required_input`]) over an i8 band pyramid: every band
//! holds its boundary tensor's values quantized under that tensor's own
//! [`QParams`] (`spec.tensors[a + band_idx]`), i32 accumulation inside
//! each layer, one fused requantize epilogue per output element. Padding
//! rows carry the owning tensor's *zero point* (so `(x - zp)` over them
//! is exactly 0 — the quantized image of the f32 path's zero rows), and
//! internal residual adds dequant-add-requant row-aligned, mirroring the
//! f32 `add_aligned`. MACs are counted with the same analytic formulas as
//! the f32 block, so Eq. 12–15 reconciliation carries over unchanged.

use crate::model::{Layer, LayerKind, ModelChain};
use crate::ops::{
    interior_hi, interior_lo, qact, required_input, BandGeom, BandRange, QBLOCK, QLayerParams,
    QMapRef, QParams, QuantSpec,
};

/// Read-only view of one i8 band inside the pyramid.
#[derive(Clone, Copy)]
struct QBandIn<'a> {
    w: usize,
    c: usize,
    data: &'a [i8],
}

/// Mutable view of one i8 band inside the pyramid.
struct QBandOut<'a> {
    h: usize,
    w: usize,
    c: usize,
    data: &'a mut [i8],
}

/// Executes layers `[a, b)` of `model` patch-by-patch in int8.
pub struct QFusedBlock<'m> {
    model: &'m ModelChain,
    a: usize,
    b: usize,
    params: &'m [QLayerParams],
    spec: &'m QuantSpec,
}

impl<'m> QFusedBlock<'m> {
    /// `params[i]`/`spec.tensors[i]` use absolute model indexing, same as
    /// the f32 block's `params`.
    pub fn new(
        model: &'m ModelChain,
        a: usize,
        b: usize,
        params: &'m [QLayerParams],
        spec: &'m QuantSpec,
    ) -> Self {
        assert!(model.fusable_span(a, b), "span [{a},{b}) is not fusable");
        Self { model, a, b, params, spec }
    }

    /// Run the block over `source` (streamed row bands, never the whole
    /// map) inside borrowed i8 `storage` shaped by `geom` (the same
    /// [`BandGeom`] the f32 block computes — one i8 element per byte),
    /// calling `sink(row_index, row_data)` for each final output row.
    /// Returns MACs performed. Zero heap allocations.
    pub fn run_streaming_in(
        &self,
        source: QMapRef<'_>,
        geom: &BandGeom,
        storage: &mut [i8],
        ranges: &mut [BandRange],
        mut sink: impl FnMut(usize, &[i8]),
    ) -> u64 {
        let out_shape = self.model.output_of(self.b - 1);
        let h_out = out_shape.h as usize;
        let depth = self.b - self.a;
        assert!(storage.len() >= geom.total_elems(), "band storage too small");
        assert_eq!(ranges.len(), geom.dims.len(), "range scratch length mismatch");
        let mut macs = 0u64;

        for r in 0..h_out {
            ranges[depth] = BandRange { start: r as isize, rows: 1 };
            for idx in (0..depth).rev() {
                ranges[idx] = required_input(&self.model.layers[self.a + idx], ranges[idx + 1]);
            }
            // Materialize the first band; padding rows are the input
            // tensor's zero point, not raw 0.
            source.read_band_into(
                ranges[0].start,
                ranges[0].rows,
                &mut storage[geom.offs[0]..geom.offs[1]],
                self.spec.tensors[self.a].zero_point as i8,
            );

            for idx in 0..depth {
                let li = self.a + idx;
                let layer = &self.model.layers[li];
                let h_map = if idx + 1 < depth {
                    self.model.input_of(li + 1).h as usize
                } else {
                    h_out
                };
                let (head, tail) = storage.split_at_mut(geom.offs[idx + 1]);
                let (_, in_w, in_c) = geom.dims[idx];
                let (out_rows, out_w, out_c) = geom.dims[idx + 1];
                let in_band = QBandIn { w: in_w, c: in_c, data: &head[geom.offs[idx]..] };
                let mut out_band = QBandOut {
                    h: out_rows,
                    w: out_w,
                    c: out_c,
                    data: &mut tail[..out_rows * out_w * out_c],
                };
                let in_qp = self.spec.tensors[li];
                let out_qp = self.spec.tensors[li + 1];
                let r_out = ranges[idx + 1];
                let lo = (-r_out.start).max(0) as usize;
                let hi = (h_map as isize - r_out.start).clamp(0, r_out.rows as isize) as usize;
                macs += qband_layer(
                    layer,
                    &self.params[li],
                    in_qp,
                    out_qp,
                    in_band,
                    &mut out_band,
                    lo,
                    hi.max(lo),
                );
                // Rows outside the real map are the next layer's padding:
                // fill with *this* tensor's zero point.
                zp_outside(&mut out_band, r_out, h_map, out_qp.zero_point as i8);
                if let Some(src) = layer.residual_from {
                    if src >= self.a && src < self.b {
                        let src_idx = src - self.a;
                        let (sr, sw, sc) = geom.dims[src_idx];
                        let src_band = QBandIn {
                            w: sw,
                            c: sc,
                            data: &head[geom.offs[src_idx]..geom.offs[src_idx] + sr * sw * sc],
                        };
                        qadd_aligned(
                            src_band,
                            self.spec.tensors[src],
                            ranges[src_idx],
                            &mut out_band,
                            out_qp,
                            ranges[idx + 1],
                        );
                    }
                }
            }
            let (out_rows, out_w, out_c) = geom.dims[depth];
            let out_lo = geom.offs[depth];
            sink(r, &storage[out_lo..out_lo + out_rows * out_w * out_c]);
        }
        macs
    }
}

/// Compute band-local output rows `[row_lo, row_hi)` of `layer`: i32
/// accumulate `(x - zp_x)(w - zp_w)`, fused requantize epilogue. Vertical
/// padding is pre-materialized in the band (zero-point rows contribute
/// 0); horizontal padding is a skipped contribution, also exactly 0.
/// Returns MACs (same analytic formulas as the f32 `band_layer`).
///
/// Interior columns (whole window inside the band width) run blocked
/// like the standalone `q*_into` kernels: a `QBLOCK`-wide i32 stack
/// accumulator sweeps contiguous weight/input slices so each
/// loaded byte feeds a whole block of output channels, with an exact
/// `x == zero_point` skip. Only the two padded edge columns keep the
/// guarded per-channel scalar path. i32 accumulation is associative, so
/// the restructure is exactly identical to the scalar loops.
#[allow(clippy::too_many_arguments)]
fn qband_layer(
    layer: &Layer,
    params: &QLayerParams,
    x_qp: QParams,
    out_qp: QParams,
    in_band: QBandIn<'_>,
    out_band: &mut QBandOut<'_>,
    row_lo: usize,
    row_hi: usize,
) -> u64 {
    let k = layer.k as usize;
    let s = layer.stride as usize;
    let p = layer.padding as usize;
    let cin = in_band.c;
    let wo = (in_band.w + 2 * p - k) / s + 1;
    debug_assert!(out_band.w == wo && out_band.h >= row_hi);
    let cout = out_band.c;
    let zx = x_qp.zero_point;
    let zw = params.w_qp.zero_point;
    let rs = x_qp.scale * params.w_qp.scale;

    match layer.kind {
        LayerKind::Conv2d if k == 1 && p == 0 && s == 1 => {
            // Pointwise fast path with the quantized image of the f32
            // relu-sparsity skip: inputs at the zero point contribute 0.
            // Output-channel-blocked: each input byte loads once per
            // block and sweeps a contiguous weight-row slice.
            let w = &params.w_q;
            let mut acc = [0i32; QBLOCK];
            for oy in row_lo..row_hi {
                for ox in 0..wo {
                    let xoff = (oy * in_band.w + ox) * cin;
                    let base = (oy * wo + ox) * cout;
                    let mut co0 = 0;
                    while co0 < cout {
                        let bl = QBLOCK.min(cout - co0);
                        let accs = &mut acc[..bl];
                        accs.fill(0);
                        for ci in 0..cin {
                            let xv = in_band.data[xoff + ci] as i32 - zx;
                            if xv == 0 {
                                continue;
                            }
                            let ws = &w[ci * cout + co0..ci * cout + co0 + bl];
                            for (a, &wq) in accs.iter_mut().zip(ws) {
                                *a += xv * (wq as i32 - zw);
                            }
                        }
                        for (j, &a) in accs.iter().enumerate() {
                            let real = qact(a as f32 * rs + params.bias[co0 + j], layer.act);
                            out_band.data[base + co0 + j] = out_qp.quantize(real);
                        }
                        co0 += bl;
                    }
                }
            }
            ((row_hi - row_lo) * wo * cout * cin) as u64
        }
        LayerKind::Conv2d => {
            // Vertical padding is pre-materialized in the band, so only
            // the horizontal interior/edge split is needed; interior
            // columns run output-channel-blocked over the contiguous
            // k·cin window row.
            let w = &params.w_q;
            let ox_lo = interior_lo(s, p, wo);
            let ox_hi = interior_hi(in_band.w, k, s, p, wo);
            let mut acc = [0i32; QBLOCK];
            for oy in row_lo..row_hi {
                let edge = |data: &mut [i8], ox: usize| {
                    let base = (oy * wo + ox) * cout;
                    for co in 0..cout {
                        let mut sum: i32 = 0;
                        for ky in 0..k {
                            let sy = oy * s + ky; // vertical pad already in band
                            for kx in 0..k {
                                let sx = (ox * s + kx) as isize - p as isize;
                                if sx < 0 || sx as usize >= in_band.w {
                                    continue;
                                }
                                let xoff = (sy * in_band.w + sx as usize) * cin;
                                let woff = (ky * k + kx) * cin * cout;
                                for ci in 0..cin {
                                    let xv = in_band.data[xoff + ci] as i32 - zx;
                                    let wv = w[woff + ci * cout + co] as i32 - zw;
                                    sum += xv * wv;
                                }
                            }
                        }
                        let real = qact(sum as f32 * rs + params.bias[co], layer.act);
                        data[base + co] = out_qp.quantize(real);
                    }
                };
                for ox in 0..ox_lo {
                    edge(&mut *out_band.data, ox);
                }
                for ox in ox_lo..ox_hi {
                    let base = (oy * wo + ox) * cout;
                    let x0 = ox * s - p;
                    let mut co0 = 0;
                    while co0 < cout {
                        let bl = QBLOCK.min(cout - co0);
                        let accs = &mut acc[..bl];
                        accs.fill(0);
                        for ky in 0..k {
                            let xrow = ((oy * s + ky) * in_band.w + x0) * cin;
                            let wrow = ky * k * cin;
                            for (t, &xq) in in_band.data[xrow..xrow + k * cin].iter().enumerate()
                            {
                                let xv = xq as i32 - zx;
                                if xv == 0 {
                                    continue;
                                }
                                let woff = (wrow + t) * cout + co0;
                                let ws = &w[woff..woff + bl];
                                for (a, &wq) in accs.iter_mut().zip(ws) {
                                    *a += xv * (wq as i32 - zw);
                                }
                            }
                        }
                        for (j, &a) in accs.iter().enumerate() {
                            let real = qact(a as f32 * rs + params.bias[co0 + j], layer.act);
                            out_band.data[base + co0 + j] = out_qp.quantize(real);
                        }
                        co0 += bl;
                    }
                }
                for ox in ox_hi.max(ox_lo)..wo {
                    edge(&mut *out_band.data, ox);
                }
            }
            ((row_hi - row_lo) * wo * cout * k * k * cin) as u64
        }
        LayerKind::DwConv2d => {
            // Channel-blocked interior over contiguous per-tap slices;
            // guarded per-channel scalar path on the padded edges.
            let w = &params.w_q;
            let ox_lo = interior_lo(s, p, wo);
            let ox_hi = interior_hi(in_band.w, k, s, p, wo);
            let mut acc = [0i32; QBLOCK];
            for oy in row_lo..row_hi {
                let edge = |data: &mut [i8], ox: usize| {
                    let base = (oy * wo + ox) * cout;
                    for ci in 0..cin {
                        let mut sum: i32 = 0;
                        for ky in 0..k {
                            let sy = oy * s + ky;
                            for kx in 0..k {
                                let sx = (ox * s + kx) as isize - p as isize;
                                if sx < 0 || sx as usize >= in_band.w {
                                    continue;
                                }
                                let xoff = (sy * in_band.w + sx as usize) * cin;
                                let woff = (ky * k + kx) * cin;
                                sum += (in_band.data[xoff + ci] as i32 - zx)
                                    * (w[woff + ci] as i32 - zw);
                            }
                        }
                        let real = qact(sum as f32 * rs + params.bias[ci], layer.act);
                        data[base + ci] = out_qp.quantize(real);
                    }
                };
                for ox in 0..ox_lo {
                    edge(&mut *out_band.data, ox);
                }
                for ox in ox_lo..ox_hi {
                    let base = (oy * wo + ox) * cout;
                    let x0 = ox * s - p;
                    let mut c0 = 0;
                    while c0 < cin {
                        let bl = QBLOCK.min(cin - c0);
                        let accs = &mut acc[..bl];
                        accs.fill(0);
                        for ky in 0..k {
                            let xrow = ((oy * s + ky) * in_band.w + x0) * cin;
                            let wrow = ky * k * cin;
                            for kx in 0..k {
                                let xo = xrow + kx * cin + c0;
                                let wo2 = wrow + kx * cin + c0;
                                let xs = &in_band.data[xo..xo + bl];
                                let ws = &w[wo2..wo2 + bl];
                                for ((a, &xq), &wq) in accs.iter_mut().zip(xs).zip(ws) {
                                    *a += (xq as i32 - zx) * (wq as i32 - zw);
                                }
                            }
                        }
                        for (j, &a) in accs.iter().enumerate() {
                            let real = qact(a as f32 * rs + params.bias[c0 + j], layer.act);
                            out_band.data[base + c0 + j] = out_qp.quantize(real);
                        }
                        c0 += bl;
                    }
                }
                for ox in ox_hi.max(ox_lo)..wo {
                    edge(&mut *out_band.data, ox);
                }
            }
            ((row_hi - row_lo) * wo * cout * k * k) as u64
        }
        LayerKind::AvgPool | LayerKind::MaxPool => {
            // Pools are unpadded here: every window row is one contiguous
            // k·cin slice, swept in channel blocks (i32 sums for avg,
            // raw-q maxes for max).
            let is_avg = matches!(layer.kind, LayerKind::AvgPool);
            let count = (k * k) as f32;
            let zxf = x_qp.zero_point as f32;
            let mut sums = [0i32; QBLOCK];
            let mut maxs = [i8::MIN; QBLOCK];
            for oy in row_lo..row_hi {
                for ox in 0..wo {
                    let base = (oy * wo + ox) * cout;
                    let mut c0 = 0;
                    while c0 < cout {
                        let bl = QBLOCK.min(cout - c0);
                        if is_avg {
                            let accs = &mut sums[..bl];
                            accs.fill(0);
                            for ky in 0..k {
                                let row = ((oy * s + ky) * in_band.w + ox * s) * cin;
                                for kx in 0..k {
                                    let xo = row + kx * cin + c0;
                                    for (a, &xq) in accs.iter_mut().zip(&in_band.data[xo..xo + bl])
                                    {
                                        *a += xq as i32;
                                    }
                                }
                            }
                            for (j, &sum) in accs.iter().enumerate() {
                                let real = (sum as f32 - count * zxf) * x_qp.scale / count;
                                out_band.data[base + c0 + j] = out_qp.quantize(real);
                            }
                        } else {
                            let accs = &mut maxs[..bl];
                            accs.fill(i8::MIN);
                            for ky in 0..k {
                                let row = ((oy * s + ky) * in_band.w + ox * s) * cin;
                                for kx in 0..k {
                                    let xo = row + kx * cin + c0;
                                    for (a, &xq) in accs.iter_mut().zip(&in_band.data[xo..xo + bl])
                                    {
                                        *a = (*a).max(xq);
                                    }
                                }
                            }
                            for (j, &m) in accs.iter().enumerate() {
                                out_band.data[base + c0 + j] = out_qp.quantize(x_qp.dequantize(m));
                            }
                        }
                        c0 += bl;
                    }
                }
            }
            ((row_hi - row_lo) * wo * cout * k * k) as u64
        }
        _ => unreachable!("non-streamable layer inside fused block"),
    }
}

/// Fill band rows whose absolute index lies outside `[0, h_map)` with the
/// band tensor's zero point (the quantized image of `zero_outside`).
fn zp_outside(band: &mut QBandOut<'_>, range: BandRange, h_map: usize, zp: i8) {
    let rowlen = band.w * band.c;
    for row in 0..range.rows {
        let abs = range.start + row as isize;
        if abs < 0 || abs as usize >= h_map {
            let off = row * rowlen;
            band.data[off..off + rowlen].fill(zp);
        }
    }
}

/// Row-aligned residual add on i8 payloads: dequant both sides, add in
/// real space, requantize under the destination tensor's parameters.
fn qadd_aligned(
    src: QBandIn<'_>,
    src_qp: QParams,
    src_range: BandRange,
    dst: &mut QBandOut<'_>,
    dst_qp: QParams,
    dst_range: BandRange,
) {
    debug_assert_eq!(src.w, dst.w);
    debug_assert_eq!(src.c, dst.c);
    let rowlen = dst.w * dst.c;
    for row in 0..dst_range.rows {
        let abs = dst_range.start + row as isize;
        let s_row = abs - src_range.start;
        if s_row < 0 || s_row as usize >= src_range.rows {
            continue; // outside the stashed band: padding rows, add 0
        }
        let soff = s_row as usize * rowlen;
        let doff = row * rowlen;
        for i in 0..rowlen {
            let real = dst_qp.dequantize(dst.data[doff + i]) + src_qp.dequantize(src.data[soff + i]);
            dst.data[doff + i] = dst_qp.quantize(real);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Activation, Layer, TensorShape};
    use crate::ops::{quantize_into, FusedBlock, LayerParams, ParamGen, Tensor};
    use crate::qexec::calibrate;

    fn params_for(model: &ModelChain) -> Vec<LayerParams> {
        model.layers.iter().enumerate().map(|(i, l)| LayerParams::for_layer(l, i)).collect()
    }

    fn rand_input(shape: TensorShape, seed: u64) -> Tensor {
        let mut g = ParamGen::new(seed);
        let n = shape.elems() as usize;
        Tensor::from_data(shape.h as usize, shape.w as usize, shape.c as usize, g.fill(n, 2.0))
    }

    /// Run both blocks over the full span and compare dequantized output
    /// against f32 within a small multiple of the output step.
    fn assert_block_parity(m: &ModelChain, seed: u64) {
        let p = params_for(m);
        let x = rand_input(m.shapes[0], seed);
        let depth = m.num_layers();
        let block = FusedBlock::new(m, 0, depth, &p);
        let (f32_out, f32_stats) = block.run(&x);

        let spec = calibrate(m, &p, &x);
        let qp: Vec<_> = p
            .iter()
            .zip(&spec.weights)
            .map(|(lp, &wq)| QLayerParams::from_params(lp, wq))
            .collect();
        let qblock = QFusedBlock::new(m, 0, depth, &qp, &spec);
        let geom = block.band_geom();
        let mut storage = vec![0i8; geom.total_elems()];
        let mut ranges = vec![BandRange { start: 0, rows: 0 }; geom.dims.len()];
        let mut xq = vec![0i8; x.elems()];
        quantize_into(&x.data, spec.tensors[0], &mut xq);
        let out_shape = m.output_of(depth - 1);
        let (wo, co) = (out_shape.w as usize, out_shape.c as usize);
        let mut got = vec![0i8; out_shape.elems() as usize / 1];
        let macs = qblock.run_streaming_in(
            QMapRef::new(x.h, x.w, x.c, &xq),
            &geom,
            &mut storage,
            &mut ranges,
            |r, row| got[r * wo * co..(r + 1) * wo * co].copy_from_slice(&row[..wo * co]),
        );
        assert_eq!(macs, f32_stats.macs, "quantized MAC count diverged from f32");

        let out_qp = spec.tensors[depth];
        let tol = 8.0 * out_qp.scale + 0.1;
        let mut max_err = 0.0f32;
        for (q, f) in got.iter().zip(&f32_out.data) {
            max_err = max_err.max((out_qp.dequantize(*q) - f).abs());
        }
        assert!(max_err < tol, "{}: max_err {max_err} vs tol {tol}", m.name);
    }

    #[test]
    fn qfused_matches_f32_block_with_padding_and_dw() {
        let m = ModelChain::new(
            "t",
            TensorShape::new(16, 16, 4),
            vec![
                Layer::conv("c0", 3, 2, 1, 4, 8, Activation::Relu6),
                Layer::dwconv("d1", 3, 1, 1, 8, Activation::Relu6),
                Layer::pointwise("p2", 8, 6, Activation::None),
            ],
        );
        assert_block_parity(&m, 2);
    }

    #[test]
    fn qfused_matches_f32_block_with_pool_member() {
        let m = ModelChain::new(
            "t",
            TensorShape::new(12, 12, 2),
            vec![
                Layer::conv("c0", 3, 1, 0, 2, 4, Activation::Relu),
                Layer::avg_pool("pl", 2, 2, 4),
            ],
        );
        assert_block_parity(&m, 3);
    }

    #[test]
    fn qfused_handles_internal_residual() {
        let m = ModelChain::new(
            "res",
            TensorShape::new(10, 10, 6),
            vec![
                Layer::pointwise("expand", 6, 12, Activation::Relu6),
                Layer::dwconv("dw", 3, 1, 1, 12, Activation::Relu6),
                Layer::pointwise("project", 12, 6, Activation::None).with_residual(0),
            ],
        );
        assert_block_parity(&m, 4);
    }

    #[test]
    fn qfused_deep_stride_chain() {
        let m = ModelChain::new(
            "deep",
            TensorShape::new(33, 29, 3),
            vec![
                Layer::conv("c0", 3, 2, 1, 3, 4, Activation::Relu6),
                Layer::conv("c1", 3, 1, 0, 4, 4, Activation::Relu6),
                Layer::conv("c2", 3, 2, 1, 4, 8, Activation::None),
                Layer::conv("c3", 1, 1, 0, 8, 5, Activation::Relu6),
            ],
        );
        assert_block_parity(&m, 6);
    }
}
