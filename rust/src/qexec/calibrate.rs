//! Calibration: observe per-tensor dynamic ranges over one representative
//! input and derive the [`QuantSpec`] a quantized plan executes under.
//!
//! The pass is a plain vanilla f32 walk (mirroring
//! [`crate::exec::Engine::run`]'s layer loop — fusion-independent: the
//! boundary tensors are the same under every [`FusionSetting`], so one
//! calibration serves all of a model's plans). For residual *target*
//! tensors both the pre-add kernel output and the post-add sum are
//! observed: the quantized band executor requantizes the conv output
//! under `tensors[i+1]` *before* the dequant-add-requant, so that one
//! parameter set must cover both value distributions.

use crate::model::{LayerKind, ModelChain};
use crate::ops::{
    avg_pool2d, conv2d, dense, dwconv2d, global_avg_pool, max_pool2d, LayerParams, ParamGen,
    QParams, QuantSpec, Tensor,
};

fn observe(r: &mut (f32, f32), data: &[f32]) {
    for &v in data {
        r.0 = r.0.min(v);
        r.1 = r.1.max(v);
    }
}

/// Observe every boundary tensor `v_0..v_n` and every weight array over
/// one calibration `input`, returning the per-tensor [`QParams`] a
/// [`super::QCompiledPlan`] (and its serialized
/// [`crate::optimizer::Plan`]) quantizes under.
pub fn calibrate(model: &ModelChain, params: &[LayerParams], input: &Tensor) -> QuantSpec {
    assert_eq!(params.len(), model.num_layers(), "params/layers mismatch");
    assert_eq!(input.shape(), model.shapes[0], "calibration input shape mismatch");
    let n = model.num_layers();
    let mut ranges = vec![(f32::INFINITY, f32::NEG_INFINITY); n + 1];
    observe(&mut ranges[0], &input.data);

    let mut cur = input.clone();
    let mut stash: Vec<Option<Tensor>> = vec![None; n + 1];
    for (i, l) in model.layers.iter().enumerate() {
        if model.layers.iter().enumerate().any(|(j, ll)| ll.residual_from == Some(i) && j >= i) {
            stash[i] = Some(cur.clone());
        }
        let p = &params[i];
        let mut out = match l.kind {
            LayerKind::Conv2d => conv2d(
                &cur,
                &p.weights,
                &p.bias,
                l.k as usize,
                l.stride as usize,
                l.padding as usize,
                l.cout as usize,
                l.act,
            ),
            LayerKind::DwConv2d => dwconv2d(
                &cur,
                &p.weights,
                &p.bias,
                l.k as usize,
                l.stride as usize,
                l.padding as usize,
                l.act,
            ),
            LayerKind::AvgPool => avg_pool2d(&cur, l.k as usize, l.stride as usize),
            LayerKind::MaxPool => max_pool2d(&cur, l.k as usize, l.stride as usize),
            LayerKind::GlobalAvgPool => Tensor::vector(global_avg_pool(&cur)),
            LayerKind::Dense => {
                Tensor::vector(dense(&cur.data, &p.weights, &p.bias, l.cout as usize))
            }
        };
        // Pre-add observation: the quantized executors requantize the
        // kernel output under tensors[i+1] before any residual add.
        observe(&mut ranges[i + 1], &out.data);
        if let Some(src) = l.residual_from {
            let st = stash[src].as_ref().expect("residual source never materialized");
            for (o, s) in out.data.iter_mut().zip(&st.data) {
                *o += s;
            }
            observe(&mut ranges[i + 1], &out.data);
        }
        cur = out;
    }

    QuantSpec {
        tensors: ranges
            .iter()
            .map(|&(lo, hi)| {
                if lo.is_finite() && hi.is_finite() {
                    QParams::from_range(lo, hi)
                } else {
                    QParams { scale: 1.0, zero_point: 0 }
                }
            })
            .collect(),
        weights: params.iter().map(|p| QParams::observe(&p.weights)).collect(),
    }
}

/// [`calibrate`] over the deterministic calibration input every
/// quantized plan in this repo uses by default (seed 42, same generator
/// idiom as the parity tests) — so a serialized [`QuantSpec`] is fully
/// reproducible from `(model, params)` alone.
pub fn calibrate_default(model: &ModelChain, params: &[LayerParams]) -> QuantSpec {
    let s = model.shapes[0];
    let mut g = ParamGen::new(42);
    let input = Tensor::from_data(
        s.h as usize,
        s.w as usize,
        s.c as usize,
        g.fill(s.elems() as usize, 2.0),
    );
    calibrate(model, params, &input)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    fn params_for(m: &ModelChain) -> Vec<LayerParams> {
        m.layers.iter().enumerate().map(|(i, l)| LayerParams::for_layer(l, i)).collect()
    }

    #[test]
    fn spec_has_one_entry_per_tensor_and_weight() {
        let m = zoo::quickstart();
        let p = params_for(&m);
        let spec = calibrate_default(&m, &p);
        assert_eq!(spec.tensors.len(), m.num_layers() + 1);
        assert_eq!(spec.weights.len(), m.num_layers());
        for qp in spec.tensors.iter().chain(&spec.weights) {
            assert!(qp.scale > 0.0 && qp.scale.is_finite());
        }
    }

    #[test]
    fn input_tensor_params_cover_the_calibration_input() {
        let m = zoo::quickstart();
        let p = params_for(&m);
        let s = m.shapes[0];
        let mut g = ParamGen::new(42);
        let input = Tensor::from_data(
            s.h as usize,
            s.w as usize,
            s.c as usize,
            g.fill(s.elems() as usize, 2.0),
        );
        let spec = calibrate(&m, &p, &input);
        let qp = spec.tensors[0];
        // Round-tripping any calibration value stays within one step.
        for &v in input.data.iter().take(64) {
            let err = (qp.dequantize(qp.quantize(v)) - v).abs();
            assert!(err <= qp.scale, "v {v} err {err} scale {}", qp.scale);
        }
    }

    #[test]
    fn residual_targets_cover_post_add_range() {
        // mcunet_vww5 has skip connections; the target tensor's params
        // must cover the summed values, not just the kernel output.
        let m = zoo::mcunet_vww5();
        let p = params_for(&m);
        let spec = calibrate_default(&m, &p);
        assert_eq!(spec.tensors.len(), m.num_layers() + 1);
        // Deterministic: calibrating twice yields the identical spec.
        let again = calibrate_default(&m, &p);
        assert_eq!(spec, again);
    }
}
