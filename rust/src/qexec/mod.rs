//! `qexec` — end-to-end int8 compiled execution.
//!
//! The f32 stack ([`crate::exec`]) *prices* RAM as if activations were
//! int8 (the paper's Eq. 5/6 accounting, `elem_bytes = 1`) while
//! executing in f32. This module closes that gap: it lowers a
//! `(ModelChain, FusionSetting)` into a [`QCompiledPlan`] whose pool is
//! an actual byte array — activations stored at 1 byte per element, i32
//! accumulator stashes at 4 — so the measured pool watermark **is** the
//! analytic Eq. 5/6 peak, not a scaled proxy of it.
//!
//! Pipeline:
//!
//! 1. **Calibrate** ([`calibrate`] / [`calibrate_default`]): a vanilla
//!    f32 forward pass observes every boundary tensor's dynamic range
//!    and derives per-tensor asymmetric [`crate::ops::QParams`]
//!    (`real = scale · (q − zp)`), plus per-layer weight params.
//! 2. **Compile** ([`QCompiledPlan::compile`]): the same schedule replay
//!    and step lowering as the f32 [`crate::exec::CompiledPlan`], but
//!    offsets are assigned over byte-granular intervals and every kernel
//!    is the int8 twin from [`crate::ops::quant`] — i8 in, i32
//!    accumulate, fused requantize-to-i8 epilogue folding the ReLU
//!    clamps. No per-element dequantize anywhere between the input
//!    quantization and the logits dequantization.
//! 3. **Serve** ([`QCompiledPlan::run_into`] over a warm
//!    [`QPlanPool`]): allocation-free, including the f32→i8 input
//!    quantization (preallocated staging buffer).
//!
//! Parity oracle: the interpreted f32 [`crate::exec::Engine`]. Compiled
//! int8 logits must land within quantization tolerance of the f32
//! output, and the measured int8 pool peak must equal the interpreted
//! arena peak exactly — asserted across the model zoo in
//! `tests/qexec_parity.rs` and proved statically by
//! `msfcnn verify --zoo` via [`crate::analysis`]'s byte-width-aware
//! dataflow pass.

mod calibrate;
mod qband;
mod qcompiled;

pub use calibrate::{calibrate, calibrate_default};
pub use qband::QFusedBlock;
pub use qcompiled::{QCompiledPlan, QPlanPool, QStepNumerics, QUnitNumerics};
