//! Compile-once int8 execution: the quantized twin of
//! [`crate::exec::CompiledPlan`].
//!
//! Consumes the **same** lowered step list (shared
//! [`crate::exec::lower_steps`] over the same schedule replay), but
//! offset-assigns a *byte*-granular runtime pool: activations at 1 byte
//! per element, i32 accumulator stashes at 4 — exactly the mixed widths
//! of the Eq. 5/6 accounting. That makes this the regime where runtime
//! storage and analytic accounting finally coincide: the measured pool
//! watermark ([`QCompiledPlan::measured_peak`]) *is* the accounting
//! watermark, equal to the interpreted engine's arena peak.
//!
//! Payload convention: the i8 payload of a buffer occupies its leading
//! `elems` bytes. 4-byte-wide buffers (global-pool / dense accumulators,
//! logits) use their full extent as i32 scratch while accumulating and
//! collapse into the i8 payload at their epilogue
//! ([`crate::ops::qgap_finish`]); the dense chain writes i8 payloads
//! directly — no f32 tensor ever materializes between the input
//! quantization and the logits dequantization.

use std::ops::Range;

use crate::exec::{lower_steps, BufAccess, Lowered, RtBufInfo, Src, Step, StepAccess};
use crate::memory::{assign_offsets, layout_from_schedule, schedule_intervals, PoolLayout};
use crate::model::{Activation, Layer, LayerKind, ModelChain};
use crate::ops::{
    dequantize_into, qavg_pool2d_into, qconv2d_into, qdense_into, qdwconv2d_into,
    qgap_accumulate, qgap_finish, qgap_reset, qmax_pool2d_into, qresidual_add, quantize_into,
    BandRange, LayerParams, MapRef, QLayerParams, QMapRef, QParams, QuantSpec,
};
use crate::optimizer::FusionSetting;

use super::qband::QFusedBlock;

/// Runtime view of one pool buffer: byte offset, full byte extent, and
/// the i8 payload element count at its head.
#[derive(Debug, Clone, Copy)]
struct QRtBuf {
    off: usize,
    /// Full byte extent (accounting bytes — equal to runtime bytes in
    /// the int8 regime).
    bytes: usize,
    /// i8 payload elements at the buffer's head (`== bytes` for
    /// activations, `bytes / 4` for accumulator-backed buffers).
    elems: usize,
    /// `(h, w, c)` of the payload; vectors are `(1, 1, len)`.
    dims: (usize, usize, usize),
}

/// Schedule-derived identity (label + runtime lifetime) of a buffer.
#[derive(Debug, Clone)]
struct QBufMeta {
    label: String,
    birth: usize,
    rt_death: usize,
}

/// One layer's worth of numeric metadata inside a compiled step — the
/// unit of the value-range abstract interpretation
/// ([`crate::analysis::verify_ranges`]). Carries exactly what the
/// concrete kernel consumes: the quantization parameters of its input /
/// weight / output tensors, the activation fold, the bias range, and
/// the accumulation count per output element.
#[derive(Debug, Clone)]
pub struct QUnitNumerics {
    /// Model layer index this unit executes.
    pub layer: usize,
    pub kind: LayerKind,
    /// Activation folded into the requantization epilogue.
    pub act: Activation,
    /// Label of the pool buffer this unit's outputs land in
    /// (diagnostics).
    pub buffer: String,
    /// i32 accumulation terms per output element: `k²·cin` for conv,
    /// `k²` for depthwise and pools (raw-q sums), `h·w` pixels for the
    /// global pool, `din` for dense. Max pooling accumulates nothing.
    pub macs_per_out: u64,
    /// Input tensor parameters (`spec.tensors[layer]`).
    pub x_qp: QParams,
    /// Weight parameters (`spec.weights[layer]`); `None` for weightless
    /// pool layers.
    pub w_qp: Option<QParams>,
    /// Output tensor parameters (`spec.tensors[layer + 1]`).
    pub out_qp: QParams,
    /// `[min, max]` of the f32 bias folded into the epilogue (0 when
    /// the layer carries no bias).
    pub bias_lo: f32,
    pub bias_hi: f32,
    /// Parameters of the residual stash added after this layer's
    /// epilogue (`spec.tensors[residual_from]`), when one exists.
    pub residual_qp: Option<QParams>,
}

/// Numeric metadata of one compiled step: every layer it executes, in
/// kernel order ([`QCompiledPlan::step_numerics`]).
#[derive(Debug, Clone)]
pub struct QStepNumerics {
    /// Step index in execution order.
    pub index: usize,
    /// Step label (matches [`QCompiledPlan::step_accesses`]).
    pub label: String,
    pub units: Vec<QUnitNumerics>,
}

/// The per-serving-slot mutable state of a quantized plan: the int8 byte
/// pool, a preallocated input-quantization staging buffer, and the
/// band-range scratch. Created once ([`QCompiledPlan::make_pool`]); the
/// warm hot path — including the f32→i8 input quantization — never
/// allocates again.
pub struct QPlanPool {
    data: Vec<i8>,
    input_q: Vec<i8>,
    ranges: Vec<BandRange>,
    storage_allocs: u64,
}

impl QPlanPool {
    /// Heap allocations since creation (pool + input staging + range
    /// scratch = 3). Constant after [`QCompiledPlan::make_pool`]; tests
    /// pin this across warm runs.
    pub fn storage_allocs(&self) -> u64 {
        self.storage_allocs
    }

    /// Bytes of int8 pool storage.
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Stable address of the backing storage (test hook).
    pub fn storage_ptr(&self) -> *const i8 {
        self.data.as_ptr()
    }
}

/// A `(model, setting, quant spec)` triple compiled into a static int8
/// step list + byte pool layout. Immutable after compilation; all
/// per-run state lives in a [`QPlanPool`].
pub struct QCompiledPlan {
    model: ModelChain,
    qparams: Vec<QLayerParams>,
    spec: QuantSpec,
    setting: FusionSetting,
    layout: PoolLayout,
    bufs: Vec<QRtBuf>,
    buf_meta: Vec<QBufMeta>,
    pool_bytes_rt: usize,
    ranges_scratch: usize,
    steps: Vec<Step>,
    input_buf: Option<usize>,
    out_buf: usize,
    out_len: usize,
}

impl QCompiledPlan {
    /// Compile with deterministic per-layer parameters (same generator
    /// as [`crate::exec::Engine::new`], so the f32 parity oracle uses
    /// the exact weights these int8 weights were quantized from).
    pub fn compile(model: ModelChain, setting: FusionSetting, spec: QuantSpec) -> Self {
        let params: Vec<LayerParams> = model
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| LayerParams::for_layer(l, i))
            .collect();
        Self::with_params(model, params, setting, spec)
    }

    /// Compile with explicit f32 parameters; weights are quantized under
    /// `spec.weights[i]` (the calibration observation), **not**
    /// re-observed — so a serialized spec fully determines numerics.
    pub fn with_params(
        model: ModelChain,
        params: Vec<LayerParams>,
        setting: FusionSetting,
        spec: QuantSpec,
    ) -> Self {
        assert_eq!(params.len(), model.num_layers(), "params/layers mismatch");
        assert_eq!(
            spec.tensors.len(),
            model.num_layers() + 1,
            "quant spec tensors/model mismatch"
        );
        assert_eq!(spec.weights.len(), model.num_layers(), "quant spec weights/model mismatch");
        assert!(!setting.spans.is_empty(), "empty fusion setting");

        let sched = schedule_intervals(&model, &setting);
        // Accounting layout — identical to the f32 plan's and to what
        // `optimizer::Plan` serializes.
        let layout = layout_from_schedule(&sched);

        // Runtime byte layout: in the int8 regime runtime storage bytes
        // equal accounting bytes per buffer; only the lifetimes differ
        // (`rt_death` extends the iterative-tail read-back chain), so
        // `pool_bytes_rt` may exceed the accounting watermark by
        // fragmentation + extension, never the per-buffer sizing.
        let rt_items: Vec<(u64, usize, usize)> =
            sched.iter().map(|s| (s.bytes, s.birth, s.rt_death)).collect();
        let (rt_offs, pool_bytes_rt) = assign_offsets(&rt_items);
        let bufs: Vec<QRtBuf> = sched
            .iter()
            .zip(&rt_offs)
            .map(|(s, &off)| QRtBuf {
                off: off as usize,
                bytes: s.bytes as usize,
                elems: s.elems,
                dims: s.dims,
            })
            .collect();
        let buf_meta: Vec<QBufMeta> = sched
            .iter()
            .map(|s| QBufMeta { label: s.label.clone(), birth: s.birth, rt_death: s.rt_death })
            .collect();

        let qparams: Vec<QLayerParams> = params
            .iter()
            .zip(&spec.weights)
            .map(|(p, &wq)| QLayerParams::from_params(p, wq))
            .collect();

        let Lowered { steps, input_buf, out_buf, ranges_scratch } =
            lower_steps(&model, &params, &setting, &sched);
        let out_len = bufs[out_buf].elems;

        let plan = Self {
            model,
            qparams,
            spec,
            setting,
            layout,
            bufs,
            buf_meta,
            pool_bytes_rt: pool_bytes_rt as usize,
            ranges_scratch,
            steps,
            input_buf,
            out_buf,
            out_len,
        };

        // Same compile-time promotion as the f32 plan: prove byte-level
        // disjointness of every step's pool slices before the first run.
        let hazards = crate::analysis::check_step_hazards(
            &crate::analysis::AnalysisInput::from_qcompiled(&plan),
        );
        assert!(
            hazards.is_clean(),
            "quantized plan violates pool aliasing invariants:\n{}",
            hazards.render()
        );
        plan
    }

    /// The accounting pool layout — byte-identical to the f32
    /// [`crate::exec::CompiledPlan::layout`] for the same setting.
    pub fn layout(&self) -> &PoolLayout {
        &self.layout
    }

    /// The compiled fusion setting.
    pub fn setting(&self) -> &FusionSetting {
        &self.setting
    }

    /// The compiled model.
    pub fn model(&self) -> &ModelChain {
        &self.model
    }

    /// The quantization spec this plan executes under.
    pub fn spec(&self) -> &QuantSpec {
        &self.spec
    }

    /// Length of the final logits vector.
    pub fn output_len(&self) -> usize {
        self.out_len
    }

    /// Quantization parameters of the logits payload
    /// ([`Self::run_into_i8`]'s output tensor).
    pub fn logits_qp(&self) -> QParams {
        self.spec.tensors[self.model.num_layers()]
    }

    /// Measured peak of every run: the max concurrent accounting
    /// footprint. In the int8 regime the runtime buffers *are* sized in
    /// accounting bytes, so this is exactly the analytic Eq. 5/6 peak —
    /// and equal to the interpreted engine's arena high-water mark.
    pub fn measured_peak(&self) -> u64 {
        self.layout.watermark
    }

    /// Static pool size in accounting bytes.
    pub fn pool_bytes(&self) -> u64 {
        self.layout.pool_bytes
    }

    /// Runtime byte pool length (>= [`Self::pool_bytes`] only through
    /// the iterative-tail lifetime extension; sizing is identical).
    pub fn pool_byte_len(&self) -> usize {
        self.pool_bytes_rt
    }

    /// The pool buffer pre-populated with the quantized input before the
    /// step list runs, if any (fused heads stream it instead).
    pub fn input_buffer(&self) -> Option<usize> {
        self.input_buf
    }

    /// The pool buffer the logits payload is read from after the last
    /// step.
    pub fn output_buffer(&self) -> usize {
        self.out_buf
    }

    /// Allocate the per-slot execution pool — the only allocations of
    /// the quantized path; every subsequent run is allocation-free.
    pub fn make_pool(&self) -> QPlanPool {
        QPlanPool {
            data: vec![0i8; self.pool_bytes_rt],
            input_q: vec![0i8; self.model.shapes[0].elems() as usize],
            ranges: vec![BandRange { start: 0, rows: 0 }; self.ranges_scratch],
            storage_allocs: 3,
        }
    }

    /// Allocation-free int8 inference with f32 endpoints: quantize
    /// `input` (into the pool's preallocated staging buffer), run the
    /// step list entirely in int8, dequantize the logits into `out`.
    /// Returns MACs performed (identical count to the f32 executors).
    pub fn run_into(&self, input: MapRef<'_>, pool: &mut QPlanPool, out: &mut [f32]) -> u64 {
        assert_eq!(out.len(), self.out_len, "output buffer length mismatch");
        let macs = self.run_quantized(input, pool);
        let r = self.payload_of(self.out_buf);
        dequantize_into(&pool.data[r], self.logits_qp(), out);
        macs
    }

    /// [`Self::run_into`] without the final dequantization: raw i8
    /// logits under [`Self::logits_qp`].
    pub fn run_into_i8(&self, input: MapRef<'_>, pool: &mut QPlanPool, out: &mut [i8]) -> u64 {
        assert_eq!(out.len(), self.out_len, "output buffer length mismatch");
        let macs = self.run_quantized(input, pool);
        let r = self.payload_of(self.out_buf);
        out.copy_from_slice(&pool.data[r]);
        macs
    }

    fn run_quantized(&self, input: MapRef<'_>, pool: &mut QPlanPool) -> u64 {
        let s0 = self.model.shapes[0];
        assert!(
            input.h == s0.h as usize && input.w == s0.w as usize && input.c == s0.c as usize,
            "input shape mismatch"
        );
        assert_eq!(pool.data.len(), self.pool_bytes_rt, "pool belongs to a different plan");
        quantize_into(input.data, self.spec.tensors[0], &mut pool.input_q);
        if let Some(id) = self.input_buf {
            let r = self.payload_of(id);
            pool.data[r].copy_from_slice(&pool.input_q);
        }
        let mut macs = 0u64;
        for step in &self.steps {
            macs += self.run_step(step, pool);
        }
        macs
    }

    /// Full byte extent of buffer `id` in the runtime pool.
    fn full_of(&self, id: usize) -> Range<usize> {
        let b = &self.bufs[id];
        b.off..b.off + b.bytes
    }

    /// Leading i8 payload of buffer `id`.
    fn payload_of(&self, id: usize) -> Range<usize> {
        let b = &self.bufs[id];
        b.off..b.off + b.elems
    }

    fn qmap_of<'p>(&self, id: usize, data: &'p [i8]) -> QMapRef<'p> {
        let d = self.bufs[id].dims;
        QMapRef::new(d.0, d.1, d.2, data)
    }

    fn run_step(&self, step: &Step, pool: &mut QPlanPool) -> u64 {
        match step {
            Step::StashSave { src, dst } => {
                match *src {
                    // A stash from the streamed input snapshots the
                    // quantized staging buffer (tensors[0] payload).
                    Src::Input => {
                        let r = self.payload_of(*dst);
                        pool.data[r].copy_from_slice(&pool.input_q);
                    }
                    Src::Buf(sid) => {
                        let n = self.bufs[*dst].elems;
                        let (s, d) =
                            two_muts_i8(&mut pool.data, self.full_of(sid), self.full_of(*dst));
                        d[..n].copy_from_slice(&s[..n]);
                    }
                }
                0
            }

            Step::Single { layer, src, out, residual } => {
                let l = &self.model.layers[*layer];
                let p = &self.qparams[*layer];
                let x_qp = self.spec.tensors[*layer];
                let out_qp = self.spec.tensors[*layer + 1];
                let out_r = self.full_of(*out);
                let macs = match *src {
                    Src::Input => unreachable!("single-layer step reading the external input"),
                    Src::Buf(sid) => {
                        let (src_s, out_s) =
                            two_muts_i8(&mut pool.data, self.full_of(sid), out_r.clone());
                        let x = self.qmap_of(sid, &src_s[..self.bufs[sid].elems]);
                        self.single_kernel(l, p, *layer, x, x_qp, out_qp, out_s)
                    }
                };
                if let Some(stash_id) = residual {
                    let stash_qp =
                        self.spec.tensors[l.residual_from.expect("residual step without source")];
                    let n = self.bufs[*out].elems;
                    let (st, o) = two_muts_i8(&mut pool.data, self.full_of(*stash_id), out_r);
                    qresidual_add(&mut o[..n], out_qp, &st[..n], stash_qp);
                }
                macs
            }

            Step::Fused { a, conv_end, src, bands, out, geom } => {
                let block =
                    QFusedBlock::new(&self.model, *a, *conv_end, &self.qparams, &self.spec);
                let depth = conv_end - a;
                let bands_r = self.full_of(*bands);
                let out_r = self.full_of(*out);
                let (_, wo, co) = self.bufs[*out].dims;
                match *src {
                    Src::Input => {
                        let QPlanPool { data, input_q, ranges, .. } = pool;
                        let (bands_s, out_s) = two_muts_i8(data, bands_r, out_r);
                        let s0 = self.model.shapes[0];
                        let x =
                            QMapRef::new(s0.h as usize, s0.w as usize, s0.c as usize, input_q);
                        block.run_streaming_in(x, geom, bands_s, &mut ranges[..depth + 1], |r, row| {
                            out_s[r * wo * co..(r + 1) * wo * co].copy_from_slice(&row[..wo * co]);
                        })
                    }
                    Src::Buf(sid) => {
                        let QPlanPool { data, ranges, .. } = pool;
                        let [src_s, bands_s, out_s] =
                            three_muts_i8(data, [self.full_of(sid), bands_r, out_r]);
                        let x = self.qmap_of(sid, &src_s[..self.bufs[sid].elems]);
                        block.run_streaming_in(x, geom, bands_s, &mut ranges[..depth + 1], |r, row| {
                            out_s[r * wo * co..(r + 1) * wo * co].copy_from_slice(&row[..wo * co]);
                        })
                    }
                }
            }

            Step::FusedIter { a, conv_end, src, bands, geom, pool_acc, dense, logits } => {
                let block =
                    QFusedBlock::new(&self.model, *a, *conv_end, &self.qparams, &self.spec);
                let depth = conv_end - a;
                let out_shape = self.model.output_of(*conv_end - 1);
                let c_last = out_shape.c as usize;
                let bands_r = self.full_of(*bands);
                let acc_r = self.full_of(*pool_acc);

                // Phase 1: stream rows into the i32 global-pool
                // accumulator (raw-q sums; the epilogue folds the scale).
                let mut macs = match *src {
                    Src::Input => {
                        let QPlanPool { data, input_q, ranges, .. } = pool;
                        let (bands_s, acc_s) = two_muts_i8(data, bands_r, acc_r.clone());
                        qgap_reset(acc_s, c_last);
                        let s0 = self.model.shapes[0];
                        let x =
                            QMapRef::new(s0.h as usize, s0.w as usize, s0.c as usize, input_q);
                        block.run_streaming_in(
                            x,
                            geom,
                            bands_s,
                            &mut ranges[..depth + 1],
                            |_r, row| qgap_accumulate(acc_s, row, c_last),
                        )
                    }
                    Src::Buf(sid) => {
                        let QPlanPool { data, ranges, .. } = pool;
                        let [src_s, bands_s, acc_s] =
                            three_muts_i8(data, [self.full_of(sid), bands_r, acc_r.clone()]);
                        qgap_reset(acc_s, c_last);
                        let x = self.qmap_of(sid, &src_s[..self.bufs[sid].elems]);
                        block.run_streaming_in(
                            x,
                            geom,
                            bands_s,
                            &mut ranges[..depth + 1],
                            |_r, row| qgap_accumulate(acc_s, row, c_last),
                        )
                    }
                };
                // finish(): i32 sums collapse into the i8 payload.
                qgap_finish(
                    &mut pool.data[acc_r],
                    c_last,
                    out_shape.h as usize * out_shape.w as usize,
                    self.spec.tensors[*conv_end],
                    self.spec.tensors[*conv_end + 1],
                );
                macs += out_shape.elems();

                // Phase 2: iterative dense chain, i8 payload to i8
                // payload (the i32 accumulator is a per-scalar register).
                let mut prev = *pool_acc;
                for &(li, acc_id) in dense {
                    let p = &self.qparams[li];
                    let dout = self.model.layers[li].cout as usize;
                    let din = self.bufs[prev].elems;
                    let (x_s, y_s) =
                        two_muts_i8(&mut pool.data, self.full_of(prev), self.full_of(acc_id));
                    qdense_into(
                        &x_s[..din],
                        self.spec.tensors[li],
                        p,
                        dout,
                        self.spec.tensors[li + 1],
                        y_s,
                    );
                    macs += (din * dout) as u64;
                    prev = acc_id;
                }

                // Phase 3: logits payload copy.
                let n = self.bufs[*logits].elems;
                let (v_s, l_s) =
                    two_muts_i8(&mut pool.data, self.full_of(prev), self.full_of(*logits));
                l_s[..n].copy_from_slice(&v_s[..n]);
                macs
            }
        }
    }

    /// Single unfused layer through the allocation-free int8 kernels —
    /// same MAC accounting as the f32 executors.
    #[allow(clippy::too_many_arguments)]
    fn single_kernel(
        &self,
        l: &Layer,
        p: &QLayerParams,
        li: usize,
        x: QMapRef<'_>,
        x_qp: QParams,
        out_qp: QParams,
        out: &mut [i8],
    ) -> u64 {
        match l.kind {
            LayerKind::Conv2d => {
                qconv2d_into(
                    x,
                    x_qp,
                    p,
                    l.k as usize,
                    l.stride as usize,
                    l.padding as usize,
                    l.cout as usize,
                    l.act,
                    out_qp,
                    out,
                );
                self.model.layer_macs(li)
            }
            LayerKind::DwConv2d => {
                qdwconv2d_into(
                    x,
                    x_qp,
                    p,
                    l.k as usize,
                    l.stride as usize,
                    l.padding as usize,
                    l.act,
                    out_qp,
                    out,
                );
                self.model.layer_macs(li)
            }
            LayerKind::AvgPool => {
                qavg_pool2d_into(x, x_qp, l.k as usize, l.stride as usize, out_qp, out);
                self.model.layer_macs(li)
            }
            LayerKind::MaxPool => {
                qmax_pool2d_into(x, x_qp, l.k as usize, l.stride as usize, out_qp, out);
                self.model.layer_macs(li)
            }
            LayerKind::GlobalAvgPool => {
                let c = x.c;
                qgap_reset(out, c);
                qgap_accumulate(out, x.data, c);
                qgap_finish(out, c, x.h * x.w, x_qp, out_qp);
                x.elems() as u64
            }
            LayerKind::Dense => {
                qdense_into(x.data, x_qp, p, l.cout as usize, out_qp, out);
                self.model.layer_macs(li)
            }
        }
    }

    /// Label-carrying view of the runtime pool buffers, byte-granular:
    /// `off`/`elems` are byte offsets/extents (`unit_bytes = 1`), and
    /// every buffer's *full* extent is exposed — i32 accumulator regions
    /// included.
    pub fn runtime_buffers(&self) -> Vec<RtBufInfo> {
        self.bufs
            .iter()
            .zip(&self.buf_meta)
            .map(|(b, m)| RtBufInfo {
                label: m.label.clone(),
                off: b.off,
                elems: b.bytes,
                // Payload dims only describe the full extent for 1-byte
                // buffers; accumulator-backed extents are opaque bytes.
                dims: if b.dims.0 * b.dims.1 * b.dims.2 == b.bytes {
                    b.dims
                } else {
                    (1, 1, b.bytes)
                },
                birth: m.birth,
                death: m.rt_death,
            })
            .collect()
    }

    /// The symbolic access set of every step, in execution order, with
    /// conservative full-byte-extent accesses (payload writes are
    /// over-approximated to the owning buffer's whole region — safe for
    /// both the hazard and def-before-use passes, since reads are
    /// over-approximated identically).
    pub fn step_accesses(&self) -> Vec<StepAccess> {
        self.steps
            .iter()
            .enumerate()
            .map(|(index, step)| {
                let (kind, label) = match step {
                    Step::StashSave { dst, .. } => {
                        ("stash", format!("q-{}", self.buf_meta[*dst].label))
                    }
                    Step::Single { layer, .. } => ("single", format!("q-single[{layer}]")),
                    Step::Fused { a, conv_end, .. } => {
                        ("fused", format!("q-fused[{a}..{conv_end})"))
                    }
                    Step::FusedIter { a, conv_end, dense, .. } => {
                        let end = dense.last().map_or(*conv_end + 1, |&(li, _)| li + 1);
                        ("fused-iter", format!("q-fused-iter[{a}..{end})"))
                    }
                };
                let mut acc = StepAccess {
                    index,
                    kind,
                    label,
                    reads_external_input: false,
                    reads: Vec::new(),
                    writes: Vec::new(),
                    scratch: Vec::new(),
                    in_place_safe: false,
                };
                match step {
                    Step::StashSave { src, dst } => {
                        self.src_access(*src, &mut acc);
                        acc.writes.push(self.full_access(*dst));
                    }
                    Step::Single { src, out, residual, .. } => {
                        self.src_access(*src, &mut acc);
                        if let Some(stash) = residual {
                            acc.reads.push(self.full_access(*stash));
                        }
                        acc.writes.push(self.full_access(*out));
                    }
                    Step::Fused { src, bands, out, .. } => {
                        self.src_access(*src, &mut acc);
                        acc.scratch.push(self.full_access(*bands));
                        acc.writes.push(self.full_access(*out));
                    }
                    Step::FusedIter { src, bands, pool_acc, dense, logits, .. } => {
                        self.src_access(*src, &mut acc);
                        acc.scratch.push(self.full_access(*bands));
                        acc.scratch.push(self.full_access(*pool_acc));
                        for &(_, dense_acc) in dense {
                            acc.scratch.push(self.full_access(dense_acc));
                        }
                        acc.writes.push(self.full_access(*logits));
                    }
                }
                acc
            })
            .collect()
    }

    /// One layer's numeric metadata; residual parameters attach at the
    /// call site (only `Step::Single` carries a residual add).
    fn unit_numerics(&self, li: usize, buffer: String) -> QUnitNumerics {
        let l = &self.model.layers[li];
        let s_in = self.model.shapes[li];
        let k = l.k as u64;
        let macs_per_out = match l.kind {
            LayerKind::Conv2d => k * k * s_in.c as u64,
            LayerKind::DwConv2d | LayerKind::AvgPool | LayerKind::MaxPool => k * k,
            LayerKind::GlobalAvgPool => s_in.h as u64 * s_in.w as u64,
            LayerKind::Dense => s_in.elems(),
        };
        let w_qp = match l.kind {
            LayerKind::Conv2d | LayerKind::DwConv2d | LayerKind::Dense => {
                Some(self.spec.weights[li])
            }
            _ => None,
        };
        let bias = &self.qparams[li].bias;
        let (bias_lo, bias_hi) = if bias.is_empty() {
            (0.0, 0.0)
        } else {
            bias.iter()
                .fold((f32::INFINITY, f32::NEG_INFINITY), |(lo, hi), &b| (lo.min(b), hi.max(b)))
        };
        QUnitNumerics {
            layer: li,
            kind: l.kind,
            act: l.act,
            buffer,
            macs_per_out,
            x_qp: self.spec.tensors[li],
            w_qp,
            out_qp: self.spec.tensors[li + 1],
            bias_lo,
            bias_hi,
            residual_qp: None,
        }
    }

    /// The numeric metadata of every step, in execution order — exactly
    /// the quantization parameters and per-output-element accumulation
    /// geometry the kernels in [`crate::ops`] consume, so the
    /// value-range pass ([`crate::analysis::verify_ranges`]) analyzes
    /// the same arithmetic the hot path executes. Fused bands run the
    /// same per-layer kernel math as unfused layers (padding rows carry
    /// the zero point, contributing exactly 0), so one unit per layer
    /// covers both lowerings.
    pub fn step_numerics(&self) -> Vec<QStepNumerics> {
        self.steps
            .iter()
            .enumerate()
            .map(|(index, step)| {
                let (label, units) = match step {
                    Step::StashSave { dst, .. } => {
                        (format!("q-{}", self.buf_meta[*dst].label), Vec::new())
                    }
                    Step::Single { layer, out, residual, .. } => {
                        let mut u =
                            self.unit_numerics(*layer, self.buf_meta[*out].label.clone());
                        if residual.is_some() {
                            let src = self.model.layers[*layer]
                                .residual_from
                                .expect("residual step without source");
                            u.residual_qp = Some(self.spec.tensors[src]);
                        }
                        (format!("q-single[{layer}]"), vec![u])
                    }
                    Step::Fused { a, conv_end, bands, out, .. } => {
                        let units = (*a..*conv_end)
                            .map(|li| {
                                let dst = if li + 1 == *conv_end { *out } else { *bands };
                                self.unit_numerics(li, self.buf_meta[dst].label.clone())
                            })
                            .collect();
                        (format!("q-fused[{a}..{conv_end})"), units)
                    }
                    Step::FusedIter { a, conv_end, bands, pool_acc, dense, .. } => {
                        let mut units: Vec<QUnitNumerics> = (*a..*conv_end)
                            .map(|li| {
                                self.unit_numerics(li, self.buf_meta[*bands].label.clone())
                            })
                            .collect();
                        // The rewritten global pool (layer `conv_end`)
                        // accumulates into the i32 pool accumulator.
                        units.push(
                            self.unit_numerics(
                                *conv_end,
                                self.buf_meta[*pool_acc].label.clone(),
                            ),
                        );
                        for &(li, acc_id) in dense {
                            units.push(
                                self.unit_numerics(li, self.buf_meta[acc_id].label.clone()),
                            );
                        }
                        let end = dense.last().map_or(*conv_end + 1, |&(li, _)| li + 1);
                        (format!("q-fused-iter[{a}..{end})"), units)
                    }
                };
                QStepNumerics { index, label, units }
            })
            .collect()
    }

    fn full_access(&self, buf: usize) -> BufAccess {
        BufAccess { buf, start: 0, len: self.bufs[buf].bytes }
    }

    fn src_access(&self, src: Src, acc: &mut StepAccess) {
        match src {
            // The streamed input lives in the staging buffer outside the
            // pool — no pool bytes are read.
            Src::Input => acc.reads_external_input = true,
            Src::Buf(id) => acc.reads.push(self.full_access(id)),
        }
    }
}

/// Two disjoint mutable slices out of one i8 backing slice.
fn two_muts_i8(data: &mut [i8], a: Range<usize>, b: Range<usize>) -> (&mut [i8], &mut [i8]) {
    if a.start <= b.start {
        debug_assert!(a.end <= b.start, "pool ranges overlap");
        let (l, r) = data.split_at_mut(b.start);
        (&mut l[a.start..a.end], &mut r[..b.end - b.start])
    } else {
        let (bs, as_) = two_muts_i8(data, b, a);
        (as_, bs)
    }
}

/// Three disjoint mutable slices out of one i8 backing slice (any order).
fn three_muts_i8(data: &mut [i8], r: [Range<usize>; 3]) -> [&mut [i8]; 3] {
    let mut idx = [0usize, 1, 2];
    idx.sort_by_key(|&i| r[i].start);
    let (lo, mid, hi) = (r[idx[0]].clone(), r[idx[1]].clone(), r[idx[2]].clone());
    debug_assert!(lo.end <= mid.start && mid.end <= hi.start, "pool ranges overlap");
    let (l, rest) = data.split_at_mut(mid.start);
    let (m, h) = rest.split_at_mut(hi.start - mid.start);
    let s_lo = &mut l[lo.start..lo.end];
    let s_mid = &mut m[..mid.end - mid.start];
    let s_hi = &mut h[..hi.end - hi.start];
    let mut out: [Option<&mut [i8]>; 3] = [None, None, None];
    out[idx[0]] = Some(s_lo);
    out[idx[1]] = Some(s_mid);
    out[idx[2]] = Some(s_hi);
    out.map(|o| o.expect("all three slots assigned"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Engine;
    use crate::memory::Arena;
    use crate::ops::{ParamGen, Tensor};
    use crate::optimizer::{strategy, Constraints, Planner};
    use crate::qexec::calibrate_default;
    use crate::zoo;

    fn rand_input(m: &ModelChain, seed: u64) -> Tensor {
        let s = m.shapes[0];
        Tensor::from_data(
            s.h as usize,
            s.w as usize,
            s.c as usize,
            ParamGen::new(seed).fill(s.elems() as usize, 2.0),
        )
    }

    #[test]
    fn qcompiled_matches_f32_engine_within_quant_tolerance() {
        let m = zoo::quickstart();
        let engine = Engine::new(m.clone());
        let spec = calibrate_default(&m, engine.params());
        let mut planner = Planner::for_model(m.clone());
        let fused = planner.setting().unwrap();
        let vanilla =
            planner.plan_with(&strategy::Vanilla, Constraints::none()).unwrap().setting;
        let x = rand_input(&m, 21);
        for setting in [vanilla, fused] {
            let mut arena = Arena::unbounded();
            let interp = engine.run(&setting, &x, &mut arena).unwrap();
            let q = QCompiledPlan::compile(m.clone(), setting.clone(), spec.clone());
            let mut pool = q.make_pool();
            let mut out = vec![0.0f32; q.output_len()];
            let macs = q.run_into(x.as_map(), &mut pool, &mut out);
            assert_eq!(macs, interp.macs, "{}", setting.describe());
            let tol = 10.0 * q.logits_qp().scale + 0.15;
            for (a, b) in out.iter().zip(&interp.output) {
                assert!((a - b).abs() < tol, "{}: {a} vs {b}", setting.describe());
            }
        }
    }

    #[test]
    fn qpool_peak_equals_interpreted_arena_peak() {
        // The int8 regime is where measured == analytic: the pool's
        // accounting watermark equals the engine's arena high-water mark
        // for every setting, and the vanilla closed form exactly.
        let m = zoo::kws_cnn();
        let engine = Engine::new(m.clone());
        let spec = calibrate_default(&m, engine.params());
        let x = rand_input(&m, 5);
        let mut planner = Planner::for_model(m.clone());
        let fused = planner.setting().unwrap();
        let vanilla =
            planner.plan_with(&strategy::Vanilla, Constraints::none()).unwrap().setting;
        for setting in [vanilla.clone(), fused] {
            let mut arena = Arena::unbounded();
            let interp = engine.run(&setting, &x, &mut arena).unwrap();
            let q = QCompiledPlan::compile(m.clone(), setting.clone(), spec.clone());
            assert_eq!(q.measured_peak(), interp.peak_ram, "{}", setting.describe());
        }
        let q = QCompiledPlan::compile(m.clone(), vanilla, spec);
        assert_eq!(q.measured_peak(), m.vanilla_peak_ram());
    }

    #[test]
    fn warm_hot_path_performs_zero_allocations() {
        let m = zoo::tiny_cnn();
        let setting = Planner::for_model(m.clone()).setting().unwrap();
        let spec = calibrate_default(&m, Engine::new(m.clone()).params());
        let q = QCompiledPlan::compile(m.clone(), setting, spec);
        let mut pool = q.make_pool();
        let allocs0 = pool.storage_allocs();
        let ptr0 = pool.storage_ptr();
        let bytes0 = pool.bytes();
        let x = rand_input(&m, 7);
        let mut out = vec![0.0f32; q.output_len()];
        let mut first: Option<Vec<f32>> = None;
        for _ in 0..50 {
            q.run_into(x.as_map(), &mut pool, &mut out);
            match &first {
                None => first = Some(out.clone()),
                Some(f) => assert_eq!(&out, f, "warm pool reuse changed the output"),
            }
        }
        assert_eq!(pool.storage_allocs(), allocs0, "hot path allocated");
        assert_eq!(pool.storage_ptr(), ptr0, "pool storage moved");
        assert_eq!(pool.bytes(), bytes0, "pool storage resized");
    }

    #[test]
    fn residual_model_compiles_and_matches() {
        let m = zoo::mcunet_vww5();
        let engine = Engine::new(m.clone());
        let spec = calibrate_default(&m, engine.params());
        let setting = Planner::for_model(m.clone()).setting().unwrap();
        let x = rand_input(&m, 9);
        let mut arena = Arena::unbounded();
        let interp = engine.run(&setting, &x, &mut arena).unwrap();
        let q = QCompiledPlan::compile(m.clone(), setting, spec);
        let mut pool = q.make_pool();
        let mut out = vec![0.0f32; q.output_len()];
        let macs = q.run_into(x.as_map(), &mut pool, &mut out);
        assert_eq!(macs, interp.macs);
        assert_eq!(q.measured_peak(), interp.peak_ram);
        let tol = 10.0 * q.logits_qp().scale + 0.25;
        for (a, b) in out.iter().zip(&interp.output) {
            assert!((a - b).abs() < tol, "{a} vs {b}");
        }
    }

    #[test]
    fn i8_logits_round_trip_through_logits_qp() {
        let m = zoo::quickstart();
        let setting = Planner::for_model(m.clone()).setting().unwrap();
        let spec = calibrate_default(&m, Engine::new(m.clone()).params());
        let q = QCompiledPlan::compile(m.clone(), setting, spec);
        let mut pool = q.make_pool();
        let x = rand_input(&m, 13);
        let mut f_out = vec![0.0f32; q.output_len()];
        let mut i_out = vec![0i8; q.output_len()];
        q.run_into(x.as_map(), &mut pool, &mut f_out);
        q.run_into_i8(x.as_map(), &mut pool, &mut i_out);
        let qp = q.logits_qp();
        for (f, i) in f_out.iter().zip(&i_out) {
            assert_eq!(*f, qp.dequantize(*i));
        }
    }
}
