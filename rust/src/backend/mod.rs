//! [`InferBackend`]: one execution surface for every way a plan can run.
//!
//! The crate has two executors — the pure-Rust tracked engine
//! ([`crate::exec::Engine`]) and the AOT-artifact runtime
//! ([`crate::runtime::Runtime`]) — with historically incompatible entry
//! points that the coordinator, the reports, and every example re-stitched
//! by hand. This module unifies them behind one trait:
//! `run(&input) -> logits` plus `peak_ram()` (the analytic Eq. 5–6 peak of
//! the plan being served).
//!
//! [`BackendSpec`] is the serializable *description* of a backend
//! (registry entries must cross threads; live runtimes must not —
//! PJRT-style handles are not `Send`). [`BackendSpec::connect`] is the
//! single place a spec becomes a live [`InferBackend`], and is called
//! inside each executor thread by
//! [`crate::coordinator::MultiModelServer`].

use std::path::{Path, PathBuf};

use crate::anyhow;
use crate::exec::{CompiledPlan, Engine, PlanPool};
use crate::model::ModelChain;
use crate::ops::MapRef;
use crate::optimizer::{FusionSetting, Plan};
use crate::qexec::{QCompiledPlan, QPlanPool};
use crate::runtime::Runtime;
use crate::util::error::Result;

/// A live inference backend serving one plan.
pub trait InferBackend {
    /// Stable backend kind for logs/metrics ("engine", "artifact", …).
    fn kind(&self) -> &'static str;

    /// Run one inference on a flattened f32 input tensor.
    fn run(&mut self, input: &[f32]) -> Result<Vec<f32>>;

    /// Analytic peak RAM (Eq. 5–6) of the plan this backend serves — the
    /// number the optimizer promised, comparable across backends.
    fn peak_ram(&self) -> u64;

    /// Measured arena high-water mark of the most recent [`Self::run`],
    /// when the backend tracks allocations (`None` for backends that
    /// cannot measure).
    fn measured_peak(&self) -> Option<u64> {
        None
    }
}

/// [`InferBackend`] over the pure-Rust executor: serves any
/// [`ModelChain`] + [`FusionSetting`] without artifacts.
///
/// **Compile-once / run-many**: construction lowers the setting into a
/// [`CompiledPlan`] (static step list, offset-assigned pool, parameters
/// generated exactly once) and allocates the warm [`PlanPool`]. Every
/// [`InferBackend::run`] after that executes allocation-free inside the
/// pool — the per-request hot path the coordinator's executor threads
/// serve from after [`BackendSpec::connect`].
pub struct EngineBackend {
    compiled: CompiledPlan,
    pool: PlanPool,
    measured: Option<u64>,
}

impl EngineBackend {
    /// Backend for `setting` on `model` (deterministic engine weights).
    pub fn new(model: ModelChain, setting: FusionSetting) -> Self {
        Self::with_engine(Engine::new(model), setting)
    }

    /// Backend over an existing engine — e.g. one loaded with artifact
    /// weights via [`Engine::quickstart_from_artifacts`]. The engine is
    /// compiled once here; the interpreted path is not used for serving.
    pub fn with_engine(engine: Engine, setting: FusionSetting) -> Self {
        let compiled = engine.compile(&setting);
        let pool = compiled.make_pool();
        Self { compiled, pool, measured: None }
    }

    /// Backend for a serialized [`Plan`], resolving the model through
    /// [`Plan::resolve_model`] — the zoo by name, or the referenced
    /// artifact directory for artifact-backed plans (whose engine then
    /// carries the AOT weights, not the deterministic generator's).
    pub fn from_plan(plan: &Plan) -> Result<Self> {
        if let Some(art) = &plan.artifact {
            let model = plan.resolve_model()?;
            plan.validate_for(&model)?;
            let engine = Engine::quickstart_from_artifacts(&art.dir)?;
            return Ok(Self::with_engine(engine, plan.setting.clone()));
        }
        let model = plan.resolve_model()?;
        Self::for_model(model, plan)
    }

    /// Backend for a [`Plan`] on an explicitly supplied model (non-zoo
    /// chains); validates that the plan covers the model's layers.
    pub fn for_model(model: ModelChain, plan: &Plan) -> Result<Self> {
        plan.validate_for(&model)?;
        Ok(Self::new(model, plan.setting.clone()))
    }

    /// The fusion setting this backend executes.
    pub fn setting(&self) -> &FusionSetting {
        self.compiled.setting()
    }

    /// The served model.
    pub fn model(&self) -> &ModelChain {
        self.compiled.model()
    }

    /// The compiled form (step list + pool layout) this backend serves.
    pub fn compiled(&self) -> &CompiledPlan {
        &self.compiled
    }
}

impl InferBackend for EngineBackend {
    fn kind(&self) -> &'static str {
        "engine"
    }

    fn run(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        let shape = self.compiled.model().shapes[0];
        if input.len() as u64 != shape.elems() {
            return Err(anyhow!(
                "input length {} != expected {} for {shape}",
                input.len(),
                shape.elems()
            ));
        }
        // Warm-pool hot path: no tensor clone, no arena, no allocation
        // beyond the reply vector the trait contract returns.
        let x = MapRef::new(
            shape.h as usize,
            shape.w as usize,
            shape.c as usize,
            input,
        );
        let mut out = vec![0.0f32; self.compiled.output_len()];
        self.compiled.run_into(x, &mut self.pool, &mut out);
        self.measured = Some(self.compiled.measured_peak());
        Ok(out)
    }

    fn peak_ram(&self) -> u64 {
        self.compiled.setting().cost.peak_ram
    }

    fn measured_peak(&self) -> Option<u64> {
        self.measured
    }
}

/// [`InferBackend`] over the int8 compiled executor
/// ([`crate::qexec::QCompiledPlan`]): serves a quantized [`Plan`]
/// (`plan.quant` set) from a warm [`QPlanPool`].
///
/// The f32 trait surface is preserved — `run` quantizes the input into
/// the pool's preallocated staging buffer, executes entirely in
/// i8/i32, and dequantizes the logits on copy-out — so the coordinator
/// serves quantized and f32 plans interchangeably. The warm hot path
/// performs zero heap allocations beyond the reply vector.
pub struct QuantBackend {
    compiled: QCompiledPlan,
    pool: QPlanPool,
    measured: Option<u64>,
}

impl QuantBackend {
    /// Backend for a quantized serialized [`Plan`]: resolves the model
    /// ([`Plan::resolve_model`]), validates plan/spec arity, lowers into
    /// the int8 compiled form, and allocates the warm pool.
    pub fn from_plan(plan: &Plan) -> Result<Self> {
        let spec = plan
            .quant
            .clone()
            .ok_or_else(|| anyhow!("plan '{}' carries no quant spec", plan.model))?;
        let model = plan.resolve_model()?;
        plan.validate_for(&model)?;
        let compiled = QCompiledPlan::compile(model, plan.setting.clone(), spec);
        let pool = compiled.make_pool();
        Ok(Self { compiled, pool, measured: None })
    }

    /// The int8 compiled form this backend serves.
    pub fn compiled(&self) -> &QCompiledPlan {
        &self.compiled
    }
}

impl InferBackend for QuantBackend {
    fn kind(&self) -> &'static str {
        "qexec"
    }

    fn run(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        let shape = self.compiled.model().shapes[0];
        if input.len() as u64 != shape.elems() {
            return Err(anyhow!(
                "input length {} != expected {} for {shape}",
                input.len(),
                shape.elems()
            ));
        }
        let x = MapRef::new(
            shape.h as usize,
            shape.w as usize,
            shape.c as usize,
            input,
        );
        let mut out = vec![0.0f32; self.compiled.output_len()];
        self.compiled.run_into(x, &mut self.pool, &mut out);
        self.measured = Some(self.compiled.measured_peak());
        Ok(out)
    }

    fn peak_ram(&self) -> u64 {
        self.compiled.setting().cost.peak_ram
    }

    /// Int8 pool watermark — by construction equal to the analytic
    /// Eq. 5/6 peak of the served setting's schedule.
    fn measured_peak(&self) -> Option<u64> {
        self.measured
    }
}

/// [`InferBackend`] over the AOT-artifact runtime: serves one manifest
/// entry point.
pub struct ArtifactBackend {
    rt: Runtime,
    entry: String,
    peak: u64,
}

impl ArtifactBackend {
    /// Open `dir`'s manifest and load `entry` (weights cached inside the
    /// runtime). Fails when the artifacts are missing or the entry has no
    /// offline interpretation.
    pub fn open(dir: impl AsRef<Path>, entry: impl Into<String>) -> Result<Self> {
        let entry = entry.into();
        let mut rt = Runtime::open(dir.as_ref())?;
        rt.load(&entry)
            .map_err(|e| e.wrap(format!("load '{entry}'")))?;
        // Kernel entries (conv2d, iter_pool, …) serve no fusion plan;
        // report 0 rather than failing the whole backend.
        let peak = rt.plan_peak_ram(&entry).unwrap_or(0);
        Ok(Self { rt, entry, peak })
    }

    /// The manifest entry this backend serves.
    pub fn entry(&self) -> &str {
        &self.entry
    }
}

impl InferBackend for ArtifactBackend {
    fn kind(&self) -> &'static str {
        "artifact"
    }

    fn run(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        self.rt.run_f32(&self.entry, input)
    }

    fn peak_ram(&self) -> u64 {
        self.peak
    }
}

/// Serializable description of a backend — what a
/// [`crate::coordinator::ModelSpec`] registers and ships across threads.
#[derive(Debug, Clone)]
pub enum BackendSpec {
    /// A fusion setting run by the pure-Rust tracked executor.
    Engine { model: ModelChain, setting: FusionSetting },
    /// An AOT artifact entry run by the [`Runtime`].
    Artifact { dir: PathBuf, entry: String },
    /// A pre-solved serialized [`Plan`] (model resolved via the zoo or
    /// the plan's artifact reference). Plans carrying a quant spec are
    /// served by the int8 [`QuantBackend`]; plain ones by the f32
    /// [`EngineBackend`].
    Plan { plan: Plan },
}

impl BackendSpec {
    /// Instantiate the live backend this spec describes — the only place
    /// the enum is matched.
    pub fn connect(&self) -> Result<Box<dyn InferBackend>> {
        match self {
            BackendSpec::Engine { model, setting } => {
                Ok(Box::new(EngineBackend::new(model.clone(), setting.clone())))
            }
            BackendSpec::Artifact { dir, entry } => {
                Ok(Box::new(ArtifactBackend::open(dir, entry.clone())?))
            }
            BackendSpec::Plan { plan } if plan.quant.is_some() => {
                Ok(Box::new(QuantBackend::from_plan(plan)?))
            }
            BackendSpec::Plan { plan } => Ok(Box::new(EngineBackend::from_plan(plan)?)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::Planner;
    use crate::ops::ParamGen;
    use crate::zoo;

    fn quickstart_plan() -> Plan {
        Planner::for_model(zoo::quickstart()).plan().unwrap()
    }

    #[test]
    fn engine_backend_runs_and_reports_both_peaks() {
        let plan = quickstart_plan();
        let mut backend = EngineBackend::from_plan(&plan).unwrap();
        assert_eq!(backend.kind(), "engine");
        assert_eq!(backend.peak_ram(), plan.cost().peak_ram);
        assert_eq!(backend.measured_peak(), None, "no run yet");

        let x = ParamGen::new(3).fill(32 * 32 * 3, 2.0);
        let logits = backend.run(&x).unwrap();
        assert_eq!(logits.len(), 10);
        let measured = backend.measured_peak().expect("tracked run");
        // Band executor holds >= the analytic tile model (exec_reconcile).
        assert!(measured >= backend.peak_ram());
    }

    #[test]
    fn engine_backend_rejects_bad_input_length() {
        let plan = quickstart_plan();
        let mut backend = EngineBackend::from_plan(&plan).unwrap();
        let err = backend.run(&[0.0; 7]).unwrap_err();
        assert!(err.to_string().contains("input length"), "{err}");
    }

    #[test]
    fn plan_spec_connects_through_the_trait() {
        let spec = BackendSpec::Plan { plan: quickstart_plan() };
        let mut backend = spec.connect().unwrap();
        let x = ParamGen::new(5).fill(32 * 32 * 3, 2.0);
        assert_eq!(backend.run(&x).unwrap().len(), 10);
        assert!(backend.peak_ram() > 0);
    }

    #[test]
    fn plan_for_unknown_model_fails_to_connect() {
        let mut plan = quickstart_plan();
        plan.model = "not-a-zoo-model".into();
        let err = BackendSpec::Plan { plan }.connect().unwrap_err();
        assert!(err.to_string().contains("unknown model"), "{err}");
    }

    #[test]
    fn quantized_plan_connects_to_the_int8_backend() {
        let plan = {
            let m = zoo::quickstart();
            let params: Vec<crate::ops::LayerParams> = m
                .layers
                .iter()
                .enumerate()
                .map(|(i, l)| crate::ops::LayerParams::for_layer(l, i))
                .collect();
            let spec = crate::qexec::calibrate_default(&m, &params);
            quickstart_plan().with_quant(spec)
        };
        let mut q = BackendSpec::Plan { plan: plan.clone() }.connect().unwrap();
        assert_eq!(q.kind(), "qexec");

        let x = ParamGen::new(3).fill(32 * 32 * 3, 2.0);
        let qlogits = q.run(&x).unwrap();
        assert_eq!(qlogits.len(), 10);

        // Same plan without the spec: f32 engine. Logits must agree
        // within quantization tolerance.
        let mut fplan = plan.clone();
        fplan.quant = None;
        let mut f = BackendSpec::Plan { plan: fplan }.connect().unwrap();
        assert_eq!(f.kind(), "engine");
        let flogits = f.run(&x).unwrap();
        let scale = plan.quant.as_ref().unwrap().tensors.last().unwrap().scale;
        let tol = 10.0 * scale + 0.15;
        for (a, b) in qlogits.iter().zip(&flogits) {
            assert!((a - b).abs() <= tol, "int8 {a} vs f32 {b} (tol {tol})");
        }

        // Both executors account the same static schedule, so the int8
        // pool watermark equals the f32 plan's (int8-priced) watermark.
        let qpeak = q.measured_peak().expect("tracked run");
        let fpeak = f.measured_peak().expect("tracked run");
        assert_eq!(qpeak, fpeak);
    }

    #[test]
    fn quant_backend_requires_a_spec() {
        let err = QuantBackend::from_plan(&quickstart_plan()).unwrap_err();
        assert!(err.to_string().contains("no quant spec"), "{err}");
    }

    #[test]
    fn for_model_validates_span_coverage() {
        let plan = quickstart_plan();
        assert!(EngineBackend::for_model(zoo::quickstart(), &plan).is_ok());
        assert!(EngineBackend::for_model(zoo::lenet(), &plan).is_err());
    }

    #[test]
    fn artifact_backend_open_fails_cleanly_without_artifacts() {
        let err = ArtifactBackend::open("/nonexistent-artifacts", "model_fused").unwrap_err();
        assert!(format!("{err:#}").contains("artifacts"), "{err:#}");
    }
}
