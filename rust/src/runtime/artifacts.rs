//! `artifacts/manifest.json` parsing (written by `python/compile/aot.py`),
//! via the in-tree JSON parser (`util::json`).

use std::collections::BTreeMap;
use std::path::Path;

use crate::anyhow;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Shape+dtype of one tensor as recorded by the AOT step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(v: &Json) -> Result<Self> {
        let shape = v
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor spec missing 'shape'"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("non-numeric dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor spec missing 'dtype'"))?
            .to_string();
        Ok(Self { shape, dtype })
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT entry point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EntrySpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest: entry name → spec.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    pub entries: BTreeMap<String, EntrySpec>,
}

impl ArtifactManifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let obj = root.as_obj().ok_or_else(|| anyhow!("manifest is not an object"))?;
        let mut entries = BTreeMap::new();
        for (name, v) in obj {
            let file = v
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("entry '{name}' missing 'file'"))?
                .to_string();
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                v.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("entry '{name}' missing '{key}'"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntrySpec { file, inputs: parse_specs("inputs")?, outputs: parse_specs("outputs")? },
            );
        }
        Ok(Self { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let json = r#"{
            "model_vanilla": {
                "file": "model_vanilla.hlo.txt",
                "inputs": [{"shape": [32, 32, 3], "dtype": "float32"}],
                "outputs": [{"shape": [10], "dtype": "float32"}]
            }
        }"#;
        let m = ArtifactManifest::parse(json).unwrap();
        let e = &m.entries["model_vanilla"];
        assert_eq!(e.inputs[0].shape, vec![32, 32, 3]);
        assert_eq!(e.outputs[0].shape, vec![10]);
        assert_eq!(e.inputs[0].elems(), 3072);
    }

    #[test]
    fn rejects_malformed_entries() {
        assert!(ArtifactManifest::parse(r#"{"x": {"inputs": []}}"#).is_err());
        assert!(ArtifactManifest::parse("[1,2]").is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if std::path::Path::new(p).exists() {
            let m = ArtifactManifest::load(p).unwrap();
            assert!(m.entries.contains_key("model_vanilla"));
            assert!(m.entries.contains_key("model_fused"));
        }
    }
}
