//! PJRT artifact runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the XLA CPU client.
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids which xla_extension 0.5.1 rejects; the
//! text parser reassigns ids. See `/opt/xla-example/README.md` and
//! `python/compile/aot.py`.
//!
//! Python never runs at request time: `make artifacts` is build-time only,
//! and this module is the entire model-execution path of the serving
//! coordinator.

mod artifacts;

pub use artifacts::{ArtifactManifest, EntrySpec, TensorSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// A compiled artifact ready to execute.
pub struct LoadedModel {
    pub name: String,
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    /// Execute with a single f32 input tensor (flattened, row-major).
    /// Returns the flattened f32 outputs (artifacts are lowered with
    /// `return_tuple=True`, so the single result is a 1-tuple).
    pub fn run_f32(&self, input: &[f32]) -> Result<Vec<f32>> {
        let spec = &self.spec.inputs[0];
        let expect: usize = spec.shape.iter().product::<usize>();
        if input.len() != expect {
            return Err(anyhow!(
                "input length {} != expected {} for {:?}",
                input.len(),
                expect,
                spec.shape
            ));
        }
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// The artifact runtime: one PJRT CPU client, many compiled entry points.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: ArtifactManifest,
    loaded: HashMap<String, LoadedModel>,
}

impl Runtime {
    /// Open `artifacts/` (reads `manifest.json`; compiles lazily).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ArtifactManifest::load(dir.join("manifest.json"))
            .context("artifacts not built? run `make artifacts`")?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, dir, manifest, loaded: HashMap::new() })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Entry-point names available in the manifest.
    pub fn entries(&self) -> Vec<&str> {
        self.manifest.entries.keys().map(|s| s.as_str()).collect()
    }

    /// Load + compile an entry point (cached).
    pub fn load(&mut self, name: &str) -> Result<&LoadedModel> {
        if !self.loaded.contains_key(name) {
            let spec = self
                .manifest
                .entries
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact entry '{name}'"))?
                .clone();
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile '{name}': {e:?}"))?;
            self.loaded.insert(
                name.to_string(),
                LoadedModel { name: name.to_string(), spec, exe },
            );
        }
        Ok(&self.loaded[name])
    }

    /// Load + run in one call.
    pub fn run_f32(&mut self, name: &str, input: &[f32]) -> Result<Vec<f32>> {
        self.load(name)?;
        self.loaded[name].run_f32(input)
    }
}
