//! Artifact runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes their entry points.
//!
//! The native PJRT/XLA bindings are unavailable in the offline vendor set
//! (DESIGN.md §Substitutions), so this runtime executes each manifest
//! entry with the in-tree reference interpreter instead: the same
//! `weights.json` the AOT step bakes into the artifacts is loaded into
//! the pure-Rust executor ([`crate::exec::Engine`]), whose numerics are
//! cross-checked against the XLA outputs in
//! `rust/tests/artifacts_roundtrip.rs` whenever a native build exists.
//! The API (open → load → run_f32, manifest-driven shape checks) is the
//! PJRT surface, so swapping the native client back in is a drop-in.
//!
//! Python never runs at request time: `make artifacts` is build-time
//! only, and this module is the entire model-execution path of the
//! serving coordinator.

mod artifacts;

pub use artifacts::{ArtifactManifest, EntrySpec, TensorSpec};

use std::collections::HashSet;
use std::path::{Path, PathBuf};

use crate::bail;
use crate::exec::Engine;
use crate::ops::{conv2d, dense, FusedBlock, Tensor};
use crate::optimizer::{strategy::Vanilla, Constraints, FusionSetting, Planner};
use crate::util::error::{Context, Result};

/// The artifact runtime: one manifest, many executable entry points.
pub struct Runtime {
    dir: PathBuf,
    manifest: ArtifactManifest,
    /// Quickstart engine with the artifact weights (lazily loaded).
    engine: Option<Engine>,
    vanilla: Option<FusionSetting>,
    fused: Option<FusionSetting>,
    loaded: HashSet<String>,
}

impl Runtime {
    /// Open `artifacts/` (reads `manifest.json`; loads weights lazily).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ArtifactManifest::load(dir.join("manifest.json"))
            .context("artifacts not built? run `make artifacts`")?;
        Ok(Self {
            dir,
            manifest,
            engine: None,
            vanilla: None,
            fused: None,
            loaded: HashSet::new(),
        })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Entry-point names available in the manifest.
    pub fn entries(&self) -> Vec<&str> {
        self.manifest.entries.keys().map(|s| s.as_str()).collect()
    }

    fn ensure_engine(&mut self) -> Result<&Engine> {
        if self.engine.is_none() {
            let engine = Engine::quickstart_from_artifacts(&self.dir)?;
            // One planner, two strategies: the DAG and edge costs are
            // shared between the vanilla and min-RAM plans.
            let mut planner = Planner::for_model(engine.model().clone());
            self.fused = Some(planner.setting().map_err(|e| e.wrap("fused plan"))?);
            self.vanilla = Some(planner.plan_with(&Vanilla, Constraints::none())?.setting);
            self.engine = Some(engine);
        }
        Ok(self.engine.as_ref().unwrap())
    }

    /// Analytic peak RAM (Eq. 5–6) of the fusion plan behind a model
    /// entry — the number [`crate::backend::InferBackend::peak_ram`]
    /// reports for artifact-backed serving.
    pub fn plan_peak_ram(&mut self, name: &str) -> Result<u64> {
        match name {
            "model_fused" => {
                self.ensure_engine()?;
                Ok(self.fused.as_ref().unwrap().cost.peak_ram)
            }
            "model_vanilla" => {
                self.ensure_engine()?;
                Ok(self.vanilla.as_ref().unwrap().cost.peak_ram)
            }
            other => bail!("entry '{other}' serves no fusion plan"),
        }
    }

    /// Load an entry point: validates it exists in the manifest and has an
    /// offline interpretation, and loads the artifact weights (cached).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.loaded.contains(name) {
            return Ok(());
        }
        if !self.manifest.entries.contains_key(name) {
            bail!("unknown artifact entry '{name}'");
        }
        match name {
            "model_vanilla" | "model_fused" | "conv2d" | "fused_block" | "iter_dense" => {
                self.ensure_engine().map_err(|e| e.wrap(format!("load '{name}'")))?;
            }
            "iter_pool" => {}
            other => bail!(
                "entry '{other}' has no offline interpretation (native PJRT unavailable)"
            ),
        }
        self.loaded.insert(name.to_string());
        Ok(())
    }

    /// Execute an entry with a single flattened f32 input tensor; returns
    /// the flattened f32 output. Input length is validated against the
    /// manifest's recorded shape.
    pub fn run_f32(&mut self, name: &str, input: &[f32]) -> Result<Vec<f32>> {
        self.load(name)?;
        let spec = &self.manifest.entries[name];
        let expect = spec.inputs[0].elems();
        if input.len() != expect {
            bail!(
                "input length {} != expected {} for {:?}",
                input.len(),
                expect,
                spec.inputs[0].shape
            );
        }

        match name {
            "model_vanilla" | "model_fused" => {
                let setting = if name == "model_fused" {
                    self.fused.clone().unwrap()
                } else {
                    self.vanilla.clone().unwrap()
                };
                let engine = self.engine.as_ref().unwrap();
                let s = engine.model().shapes[0];
                let t = Tensor::from_data(
                    s.h as usize,
                    s.w as usize,
                    s.c as usize,
                    input.to_vec(),
                );
                let mut arena = crate::memory::Arena::unbounded();
                let r = engine.run(&setting, &t, &mut arena)?;
                Ok(r.output)
            }
            "conv2d" => {
                let engine = self.engine.as_ref().unwrap();
                let model = engine.model();
                let l = &model.layers[0];
                let p = &engine.params()[0];
                let s = model.shapes[0];
                let t = Tensor::from_data(
                    s.h as usize,
                    s.w as usize,
                    s.c as usize,
                    input.to_vec(),
                );
                let out = conv2d(
                    &t,
                    &p.weights,
                    &p.bias,
                    l.k as usize,
                    l.stride as usize,
                    l.padding as usize,
                    l.cout as usize,
                    l.act,
                );
                Ok(out.data)
            }
            "fused_block" => {
                let engine = self.engine.as_ref().unwrap();
                let model = engine.model();
                // The artifact's fused pyramid spans the streamable conv
                // prefix of the quickstart chain.
                let conv_end = model
                    .layers
                    .iter()
                    .position(|l| !l.kind.streamable())
                    .unwrap_or(model.num_layers());
                let s = model.shapes[0];
                let t = Tensor::from_data(
                    s.h as usize,
                    s.w as usize,
                    s.c as usize,
                    input.to_vec(),
                );
                let block = FusedBlock::new(model, 0, conv_end, engine.params());
                let (out, _stats) = block.run(&t);
                Ok(out.data)
            }
            "iter_pool" => {
                // Global average pool over the manifest-declared HWC map.
                let shape = &spec.inputs[0].shape;
                if shape.len() != 3 {
                    bail!("iter_pool expects an HWC input, got {shape:?}");
                }
                let (h, w, c) = (shape[0], shape[1], shape[2]);
                let mut acc = vec![0.0f32; c];
                for (i, v) in input.iter().enumerate() {
                    acc[i % c] += v;
                }
                let n = (h * w) as f32;
                for a in acc.iter_mut() {
                    *a /= n;
                }
                Ok(acc)
            }
            "iter_dense" => {
                let engine = self.engine.as_ref().unwrap();
                let model = engine.model();
                let li = model.num_layers() - 1;
                let l = &model.layers[li];
                let p = &engine.params()[li];
                Ok(dense(input, &p.weights, &p.bias, l.cout as usize))
            }
            other => bail!("entry '{other}' has no offline interpretation"),
        }
    }
}
