//! Value-interval abstract interpretation over quantized plans — the
//! numeric-safety domain of the static verifier.
//!
//! Where [`super::verify_dataflow`] proves a plan's *memory* behavior
//! over byte intervals, this pass proves its *arithmetic* over value
//! intervals: for every layer a [`crate::qexec::QCompiledPlan`] step
//! executes ([`crate::qexec::QCompiledPlan::step_numerics`]), it
//! propagates worst-case bounds through exactly the computation the
//! concrete kernels in [`crate::ops`] perform — i32 accumulation of
//! `(x − zx)(w − zw)` products, the `acc·s_x·s_w + bias` epilogue, the
//! activation fold, the requantize clamp — and checks:
//!
//! * **accumulator overflow** ([`DefectClass::AccumulatorOverflow`],
//!   error): the worst-case `|x−zx|·|w−zw|` product times the MAC count
//!   per output element must fit in i32; pooling layers' raw-q sums
//!   likewise. Computed in wide integers so a corrupted zero point
//!   widens the bound instead of wrapping it.
//! * **calibration well-formedness**
//!   ([`DefectClass::DegenerateScale`] /
//!   [`DefectClass::ZeroPointRange`], errors): every tensor and weight
//!   scale must be finite and above [`QParams::MIN_SCALE`], every zero
//!   point inside `[-128, 127]`.
//! * **saturation risk** ([`DefectClass::SaturationRisk`], warning):
//!   where the achievable pre-requantize range is *certain* — a Relu6
//!   fold bounds outputs to `[0, 6]` regardless of calibration, and a
//!   residual add sums two already-clamped representable ranges — the
//!   output tensor's representable range must cover most of it. The
//!   finding reports the estimated clipped fraction. Unbounded
//!   activations (`None`, `Relu`) are skipped: their worst-case range
//!   is vacuously wide, and a calibrated range much tighter than the
//!   worst case is the *normal* product of calibration, not a defect.
//!
//! The pass consumes only plan metadata ([`NumericInput`], mutable in
//! tests for defect injection) and never executes a MAC. Its soundness
//! against the concrete kernels is parity-tested by running adversarial
//! inputs through [`crate::ops`] and asserting measured extrema fall
//! inside [`unit_real_bounds`].

use crate::model::{Activation, LayerKind};
use crate::ops::QParams;
use crate::qexec::{QCompiledPlan, QStepNumerics, QUnitNumerics};

use super::{AnalysisReport, DefectClass, Finding};

/// Warn when the requantization epilogue would clip more than this
/// fraction of the certainly-achievable value range. Very high on
/// purpose: a calibrated range legitimately sits well inside the
/// worst-case bound (a Relu6 layer whose outputs peak at 0.5 covers
/// ~8% of `[0, 6]` and is perfectly sound), so only near-total
/// clipping — the signature of an order-of-magnitude scale corruption —
/// is worth a warning.
pub const SATURATION_CLIP_THRESHOLD: f64 = 0.995;

/// The symbolic view the value-range pass consumes: per-step, per-layer
/// numeric metadata extracted from a compiled quantized plan. Built by
/// [`NumericInput::from_qcompiled`]; tests mutate it directly to inject
/// numeric defects that [`crate::optimizer::Plan`] parsing or
/// [`crate::qexec::QCompiledPlan::compile`] would reject earlier.
#[derive(Debug, Clone)]
pub struct NumericInput {
    /// Numeric metadata of every compiled step, in execution order.
    pub steps: Vec<QStepNumerics>,
}

impl NumericInput {
    /// Extract the numeric view of a compiled quantized plan.
    pub fn from_qcompiled(plan: &QCompiledPlan) -> Self {
        Self { steps: plan.step_numerics() }
    }
}

/// Worst-case i32 accumulator bounds of one unit, in wide integers:
/// `macs_per_out` terms each bounded by the extreme `(x−zx)(w−zw)`
/// products (conv / depthwise / dense) or by the raw q range (average
/// and global pooling sums). `None` for max pooling, which accumulates
/// nothing. Zero is always included — padding taps contribute exactly 0.
pub fn unit_acc_bounds(u: &QUnitNumerics) -> Option<(i128, i128)> {
    let m = u.macs_per_out as i128;
    match u.kind {
        LayerKind::Conv2d | LayerKind::DwConv2d | LayerKind::Dense => {
            let w = u.w_qp?;
            let (xl, xh) = u.x_qp.q_dev_bounds();
            let (wl, wh) = w.q_dev_bounds();
            let products = [xl * wl, xl * wh, xh * wl, xh * wh];
            let p_lo = *products.iter().min().expect("non-empty") as i128;
            let p_hi = *products.iter().max().expect("non-empty") as i128;
            Some((m * p_lo.min(0), m * p_hi.max(0)))
        }
        LayerKind::AvgPool | LayerKind::GlobalAvgPool => Some((m * -128, m * 127)),
        LayerKind::MaxPool => None,
    }
}

/// The proven post-activation, pre-requantize real interval of one
/// unit's outputs — the abstract transfer function the parity tests
/// check the concrete kernels against. Finite for every layer kind:
/// accumulator bounds are finite, pooling outputs stay inside the input
/// tensor's representable range, and the activation fold clamps.
pub fn unit_real_bounds(u: &QUnitNumerics) -> (f64, f64) {
    let (lo, hi) = match u.kind {
        LayerKind::Conv2d | LayerKind::DwConv2d | LayerKind::Dense => {
            let (acc_lo, acc_hi) = unit_acc_bounds(u).expect("weighted kind");
            let rs = u.x_qp.scale as f64
                * u.w_qp.map_or(1.0, |w| w.scale as f64);
            (
                acc_lo as f64 * rs + u.bias_lo as f64,
                acc_hi as f64 * rs + u.bias_hi as f64,
            )
        }
        // Mean and max of q values stay inside the input's q range, so
        // outputs stay inside the input's representable real range.
        LayerKind::AvgPool | LayerKind::MaxPool | LayerKind::GlobalAvgPool => {
            let (rlo, rhi) = u.x_qp.representable();
            (rlo as f64, rhi as f64)
        }
    };
    match u.act {
        Activation::None => (lo, hi),
        Activation::Relu => (lo.max(0.0), hi.max(0.0)),
        Activation::Relu6 => (lo.clamp(0.0, 6.0), hi.clamp(0.0, 6.0)),
    }
}

/// Fraction of `[a_lo, a_hi]` outside `[r_lo, r_hi]` (0 when the
/// achievable interval is empty or fully covered).
fn clipped_fraction(a_lo: f64, a_hi: f64, r_lo: f64, r_hi: f64) -> f64 {
    let width = a_hi - a_lo;
    if width <= 0.0 {
        return 0.0;
    }
    let over = (a_hi - r_hi).max(0.0) + (r_lo - a_lo).max(0.0);
    (over / width).min(1.0)
}

/// Calibration well-formedness of one `QParams`: scale must be usable,
/// zero point representable.
fn check_qp(
    qp: QParams,
    what: &str,
    step: usize,
    buffer: &str,
    report: &mut AnalysisReport,
) {
    if qp.is_degenerate() {
        report.push(
            Finding::new(
                DefectClass::DegenerateScale,
                format!(
                    "{what} scale {:e} is degenerate (non-finite, non-positive, or below {:e})",
                    qp.scale,
                    QParams::MIN_SCALE
                ),
            )
            .at_step(step)
            .on_buffer(buffer),
        );
    }
    if !(-128..=127).contains(&qp.zero_point) {
        report.push(
            Finding::new(
                DefectClass::ZeroPointRange,
                format!("{what} zero point {} outside [-128, 127]", qp.zero_point),
            )
            .at_step(step)
            .on_buffer(buffer),
        );
    }
}

/// Saturation check over a *certain* achievable interval: warn when the
/// output tensor's representable range (widened by half a quantization
/// step — the rounding slack of a single requantize) covers less than
/// `1 - SATURATION_CLIP_THRESHOLD` of it.
fn check_saturation(
    a_lo: f64,
    a_hi: f64,
    out_qp: QParams,
    what: &str,
    step: usize,
    buffer: &str,
    report: &mut AnalysisReport,
) {
    if out_qp.is_degenerate() {
        return; // already an error; the range below would be garbage
    }
    let (r_lo, r_hi) = out_qp.representable();
    let slack = out_qp.scale as f64 * 0.5;
    let frac = clipped_fraction(a_lo, a_hi, r_lo as f64 - slack, r_hi as f64 + slack);
    if frac > SATURATION_CLIP_THRESHOLD {
        report.push(
            Finding::new(
                DefectClass::SaturationRisk,
                format!(
                    "{what}: representable [{:.4}, {:.4}] clips an estimated {:.1}% of the \
                     achievable range [{a_lo:.4}, {a_hi:.4}]",
                    r_lo,
                    r_hi,
                    frac * 100.0
                ),
            )
            .warn()
            .at_step(step)
            .on_buffer(buffer),
        );
    }
}

/// The value-range pass: accumulator-overflow freedom, calibration
/// well-formedness, and saturation risk over every step of a quantized
/// plan. Collects **all** defects; overflow and calibration findings
/// are `Error` severity, saturation findings `Warn`.
pub fn verify_ranges(input: &NumericInput) -> AnalysisReport {
    let mut report = AnalysisReport::new();
    // Each model layer appears in exactly one unit, with `tensors[li]`
    // as its input — so checking every unit's input (and the final
    // unit's output) covers each boundary tensor exactly once.
    let last_unit = input
        .steps
        .iter()
        .flat_map(|s| s.units.iter().map(move |u| (s.index, u)))
        .max_by_key(|(_, u)| u.layer);

    for step in &input.steps {
        for u in &step.units {
            let li = u.layer;
            check_qp(u.x_qp, &format!("layer {li} input tensor v{li}"), step.index, &u.buffer, &mut report);
            if let Some(w) = u.w_qp {
                check_qp(w, &format!("layer {li} weights"), step.index, &u.buffer, &mut report);
            }

            if let Some((acc_lo, acc_hi)) = unit_acc_bounds(u) {
                if acc_lo < i32::MIN as i128 || acc_hi > i32::MAX as i128 {
                    report.push(
                        Finding::new(
                            DefectClass::AccumulatorOverflow,
                            format!(
                                "layer {li} ({:?}): worst-case accumulator in [{acc_lo}, \
                                 {acc_hi}] over {} accumulation term(s) per output exceeds \
                                 the i32 range [{}, {}]",
                                u.kind,
                                u.macs_per_out,
                                i32::MIN,
                                i32::MAX
                            ),
                        )
                        .at_step(step.index)
                        .on_buffer(&u.buffer),
                    );
                }
            }

            // Saturation only where the achievable range is certain: a
            // Relu6 fold bounds any calibration's outputs to [0, 6].
            if u.act == Activation::Relu6 {
                let (a_lo, a_hi) = unit_real_bounds(u);
                check_saturation(
                    a_lo,
                    a_hi,
                    u.out_qp,
                    &format!("layer {li} relu6 epilogue"),
                    step.index,
                    &u.buffer,
                    &mut report,
                );
            }

            // Residual add: both operands are clamped to their tensors'
            // representable ranges, so the sum range is certain too —
            // the double-requant must be able to express it.
            if let Some(res) = u.residual_qp {
                if !u.out_qp.is_degenerate() && !res.is_degenerate() {
                    let (o_lo, o_hi) = u.out_qp.representable();
                    let (s_lo, s_hi) = res.representable();
                    check_saturation(
                        o_lo as f64 + s_lo as f64,
                        o_hi as f64 + s_hi as f64,
                        u.out_qp,
                        &format!("layer {li} residual add"),
                        step.index,
                        &u.buffer,
                        &mut report,
                    );
                }
            }
        }
    }

    if let Some((step, u)) = last_unit {
        let li = u.layer;
        check_qp(
            u.out_qp,
            &format!("layer {li} output tensor v{}", li + 1),
            step,
            &u.buffer,
            &mut report,
        );
    }

    report.steps_checked = input.steps.len();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Engine;
    use crate::optimizer::Planner;
    use crate::qexec::calibrate_default;
    use crate::zoo;

    fn numeric_input(name: &str) -> NumericInput {
        let m = zoo::by_name(name).unwrap();
        let spec = calibrate_default(&m, Engine::new(m.clone()).params());
        let setting = Planner::for_model(m.clone()).setting().unwrap();
        NumericInput::from_qcompiled(&QCompiledPlan::compile(m, setting, spec))
    }

    #[test]
    fn calibrated_zoo_plans_prove_numerically_clean() {
        for name in ["quickstart", "tiny", "kws", "lenet"] {
            let input = numeric_input(name);
            let report = verify_ranges(&input);
            assert!(report.is_clean(), "{name}:\n{}", report.render());
            assert!(report.steps_checked > 0);
        }
    }

    #[test]
    fn every_boundary_tensor_is_covered_exactly_once() {
        let input = numeric_input("quickstart");
        let mut layers: Vec<usize> = input
            .steps
            .iter()
            .flat_map(|s| s.units.iter().map(|u| u.layer))
            .collect();
        layers.sort_unstable();
        let n = layers.len();
        assert_eq!(layers, (0..n).collect::<Vec<_>>(), "each layer exactly once");
    }

    #[test]
    fn clipped_fraction_is_a_fraction() {
        assert_eq!(clipped_fraction(0.0, 10.0, 0.0, 10.0), 0.0);
        assert!((clipped_fraction(0.0, 10.0, 0.0, 5.0) - 0.5).abs() < 1e-12);
        assert_eq!(clipped_fraction(0.0, 10.0, 20.0, 30.0), 1.0);
        assert_eq!(clipped_fraction(5.0, 5.0, 0.0, 1.0), 0.0, "empty interval");
    }

    #[test]
    fn degenerate_scale_and_bad_zero_point_are_flagged() {
        let mut input = numeric_input("quickstart");
        let u = &mut input.steps[0].units[0];
        u.x_qp.scale = 0.0;
        u.w_qp.as_mut().unwrap().zero_point = 300;
        let report = verify_ranges(&input);
        let classes: Vec<_> = report.findings.iter().map(|f| f.class).collect();
        assert!(classes.contains(&DefectClass::DegenerateScale), "{}", report.render());
        assert!(classes.contains(&DefectClass::ZeroPointRange), "{}", report.render());
        assert!(report.has_errors());
    }

    #[test]
    fn huge_mac_count_overflows_the_accumulator_bound() {
        let mut input = numeric_input("quickstart");
        let u = &mut input.steps[0].units[0];
        // 2^31 / 255² ≈ 33k: anything well past that must be flagged.
        u.macs_per_out = 10_000_000;
        let report = verify_ranges(&input);
        assert!(
            report.findings.iter().any(|f| f.class == DefectClass::AccumulatorOverflow),
            "{}",
            report.render()
        );
    }
}
