//! Static plan verifier: two abstract domains over compiled plans and
//! pool layouts, without executing a single MAC.
//!
//! The optimizer's whole promise is that a fusion setting is *safe to run
//! in a fixed RAM budget* — this module proves it ahead of time instead
//! of trusting the hot path's `debug_assert!`s. Two abstract domains
//! cover the two ways an int8 deploy can be wrong:
//!
//! **Byte intervals** (memory safety): a symbolic walk over a
//! [`crate::exec::CompiledPlan`]'s step list ([`verify_dataflow`]) and a
//! serialized [`crate::memory::PoolLayout`] ([`verify_layout`]) checking:
//!
//! * **def-before-use** — no step reads pool elements never written
//!   (aliasing writes clobber: a write to shared pool bytes undefines
//!   every other buffer mapped there);
//! * **alias/hazard** — a step's input and output ranges may not overlap
//!   while both buffers are alive, unless the kernel is declared
//!   in-place-safe (the static form of the executor's
//!   `two_muts`/`three_muts` split invariants);
//! * **lifetime conformance** — every access falls inside its buffer's
//!   declared `[alloc, free)` interval and inside the pool;
//! * **shape/size agreement** — step access extents against buffer
//!   extents, dims against element counts;
//! * **watermark recomputation** — the serialized layout's watermark must
//!   equal the max concurrent footprint of its own lifetimes, and the
//!   serialized layout itself must match a fresh schedule replay
//!   ([`verify_plan`]'s cross-check);
//! * **dead stores** ([`lint_dead_stores`], warning severity) — a step
//!   writes pool bytes that are clobbered or abandoned before any read.
//!
//! **Value intervals** (numeric safety, [`verify_ranges`]): interval
//! abstract interpretation over a [`crate::qexec::QCompiledPlan`]'s
//! per-layer numeric metadata, proving the i32 accumulator cannot
//! overflow under worst-case `|x−zx|·|w−zw|` products, that calibration
//! is well-formed (no degenerate scales, in-range zero points), and that
//! the requantization epilogue's representable range covers the
//! certainly-achievable value range (saturation risk, warning severity).
//!
//! Findings are structured [`Finding`]s (defect class, [`Severity`],
//! step index, buffer name, byte range) collected into an
//! [`AnalysisReport`] — **all** defects, not just the first. `Error`
//! findings block deployment; `Warn` findings are surfaced but never
//! fail a verify or a deploy. The gate is wired end to end:
//! [`crate::exec::CompiledPlan`] asserts [`check_step_hazards`] at
//! compile-time-of-plan, [`crate::optimizer::Plan::validate`] runs
//! [`verify_layout`] on parse, [`crate::coordinator::PlanRegistry`] runs
//! [`verify_plan_file`] per scanned file (plans with errors are never
//! deployed), and `msfcnn verify` exposes the same gate on the CLI.

mod dataflow;
mod interval;
mod layout;
mod lint;
pub mod ranges;

pub use dataflow::{check_step_hazards, verify_dataflow};
pub use interval::IntervalSet;
pub use layout::verify_layout;
pub use lint::lint_dead_stores;
pub use ranges::{verify_ranges, NumericInput};

use std::path::Path;

use crate::exec::{CompiledPlan, RtBufInfo, StepAccess};
use crate::model::{LayerKind, ModelChain};
use crate::optimizer::{FusionSetting, Plan};
use crate::util::error::Result;

/// What kind of defect a [`Finding`] reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DefectClass {
    /// A step reads pool elements no prior step (or the input copy)
    /// wrote, or the final output is never fully produced.
    DefBeforeUse,
    /// Two accesses of one step overlap in pool space while both buffers
    /// are alive, and the kernel is not declared in-place-safe.
    Hazard,
    /// An access or buffer extends past the pool, or names a buffer
    /// outside the table.
    OutOfPool,
    /// An access outside its buffer's `[alloc, free)` interval, or an
    /// empty lifetime.
    LifetimeViolation,
    /// Step access extents or buffer dims disagree with the buffer's
    /// element count.
    ShapeMismatch,
    /// The serialized watermark does not equal the recomputed concurrent
    /// peak, or the pool is smaller than the watermark.
    WatermarkMismatch,
    /// A layout buffer's byte size disagrees with its declared element
    /// width (`bytes != elems * elem_bytes`) — e.g. an f32 plan claiming
    /// int8-sized pools.
    WidthMismatch,
    /// Two lifetime-overlapping layout buffers share pool bytes.
    LayoutCollision,
    /// The serialized layout diverges from a fresh schedule replay of the
    /// plan's own setting (hand-edited or stale memory map).
    LayoutDivergence,
    /// The fusion setting itself cannot be compiled (broken span chain,
    /// unfusable span, missing iterative-tail pool, non-positive cost).
    MalformedSetting,
    /// A step's i32 accumulator can overflow under worst-case
    /// `|x−zx|·|w−zw|` products given its MAC count per output element.
    AccumulatorOverflow,
    /// A quantization scale that is non-finite, non-positive, or so
    /// close to zero the affine map collapses.
    DegenerateScale,
    /// A zero point outside the representable int8 range `[-128, 127]`.
    ZeroPointRange,
    /// The requantization epilogue's representable output range covers
    /// too little of the certainly-achievable value range — a large
    /// fraction of outputs would clamp (warning severity).
    SaturationRisk,
    /// A step writes pool bytes that are clobbered or abandoned before
    /// any read consumes them (warning severity).
    DeadStore,
}

impl DefectClass {
    /// Every defect class, in declaration order — keep in sync with the
    /// enum (the [`Self::from_name`] round-trip test is exhaustive over
    /// this list).
    pub const ALL: [DefectClass; 15] = [
        DefectClass::DefBeforeUse,
        DefectClass::Hazard,
        DefectClass::OutOfPool,
        DefectClass::LifetimeViolation,
        DefectClass::ShapeMismatch,
        DefectClass::WatermarkMismatch,
        DefectClass::WidthMismatch,
        DefectClass::LayoutCollision,
        DefectClass::LayoutDivergence,
        DefectClass::MalformedSetting,
        DefectClass::AccumulatorOverflow,
        DefectClass::DegenerateScale,
        DefectClass::ZeroPointRange,
        DefectClass::SaturationRisk,
        DefectClass::DeadStore,
    ];

    /// Stable kebab-case identifier (diagnostic rendering, CLI output).
    pub fn name(self) -> &'static str {
        match self {
            DefectClass::DefBeforeUse => "def-before-use",
            DefectClass::Hazard => "hazard",
            DefectClass::OutOfPool => "out-of-pool",
            DefectClass::LifetimeViolation => "lifetime-violation",
            DefectClass::ShapeMismatch => "shape-mismatch",
            DefectClass::WatermarkMismatch => "watermark-mismatch",
            DefectClass::WidthMismatch => "width-mismatch",
            DefectClass::LayoutCollision => "layout-collision",
            DefectClass::LayoutDivergence => "layout-divergence",
            DefectClass::MalformedSetting => "malformed-setting",
            DefectClass::AccumulatorOverflow => "accumulator-overflow",
            DefectClass::DegenerateScale => "degenerate-scale",
            DefectClass::ZeroPointRange => "zero-point-range",
            DefectClass::SaturationRisk => "saturation-risk",
            DefectClass::DeadStore => "dead-store",
        }
    }

    /// Parse a [`Self::name`] back; `None` for unknown identifiers.
    pub fn from_name(name: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|c| c.name() == name)
    }
}

/// How severe a [`Finding`] is: `Error` findings block deployment
/// (`verify` exits nonzero, the registry refuses the plan), `Warn`
/// findings are surfaced and logged but never fail a gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warn,
}

impl Severity {
    /// Stable lowercase identifier (JSON export, rendering).
    pub fn name(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warn => "warn",
        }
    }
}

/// One structured diagnostic: defect class plus whatever location is
/// known — step index, buffer name, pool byte range — and a
/// human-readable detail line.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    pub class: DefectClass,
    /// Deploy-blocking (`Error`, the default) or advisory (`Warn`).
    pub severity: Severity,
    /// Compiled step index the defect was observed at, when step-local.
    pub step: Option<usize>,
    /// Label of the offending buffer (empty when not buffer-local).
    pub buffer: String,
    /// Offending pool byte range `[lo, hi)`, when known.
    pub bytes: Option<(u64, u64)>,
    pub detail: String,
}

impl Finding {
    /// A bare `Error`-severity finding of `class`; attach location with
    /// the builder methods (downgrade with [`Self::warn`]).
    pub fn new(class: DefectClass, detail: impl Into<String>) -> Self {
        Self {
            class,
            severity: Severity::Error,
            step: None,
            buffer: String::new(),
            bytes: None,
            detail: detail.into(),
        }
    }

    /// Downgrade to `Warn` severity (surfaced, never deploy-blocking).
    #[must_use]
    pub fn warn(mut self) -> Self {
        self.severity = Severity::Warn;
        self
    }

    /// Attach the compiled step index.
    #[must_use]
    pub fn at_step(mut self, step: usize) -> Self {
        self.step = Some(step);
        self
    }

    /// Attach the offending buffer's label.
    #[must_use]
    pub fn on_buffer(mut self, label: impl Into<String>) -> Self {
        self.buffer = label.into();
        self
    }

    /// Attach the offending pool byte range `[lo, hi)`.
    #[must_use]
    pub fn in_bytes(mut self, lo: u64, hi: u64) -> Self {
        self.bytes = Some((lo, hi));
        self
    }

    /// One-line rendering:
    /// `[class] step N buffer 'label' bytes [lo..hi): detail` for
    /// errors; warnings render distinctly as `[warn:class] …`.
    pub fn render(&self) -> String {
        let mut s = match self.severity {
            Severity::Error => format!("[{}]", self.class.name()),
            Severity::Warn => format!("[warn:{}]", self.class.name()),
        };
        if let Some(i) = self.step {
            s.push_str(&format!(" step {i}"));
        }
        if !self.buffer.is_empty() {
            s.push_str(&format!(" buffer '{}'", self.buffer));
        }
        if let Some((lo, hi)) = self.bytes {
            s.push_str(&format!(" bytes [{lo}..{hi})"));
        }
        s.push_str(": ");
        s.push_str(&self.detail);
        s
    }
}

/// Every defect one analysis pass found, plus how much it covered — the
/// verifier's product, renderable for CLI / registry diagnostics.
#[derive(Debug, Clone, Default)]
pub struct AnalysisReport {
    /// All findings, in discovery order (never truncated to the first).
    pub findings: Vec<Finding>,
    /// Compiled steps the pass walked.
    pub steps_checked: usize,
    /// Buffers the pass examined.
    pub buffers_checked: usize,
}

impl AnalysisReport {
    pub fn new() -> Self {
        Self::default()
    }

    /// True when no defect was found (warnings included).
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// True when any `Error`-severity finding is present — the
    /// deploy-blocking condition (warnings alone never block).
    pub fn has_errors(&self) -> bool {
        self.findings.iter().any(|f| f.severity == Severity::Error)
    }

    /// Number of `Error`-severity findings.
    pub fn error_count(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Error).count()
    }

    /// Number of `Warn`-severity findings.
    pub fn warn_count(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Warn).count()
    }

    /// Append one finding.
    pub fn push(&mut self, finding: Finding) {
        self.findings.push(finding);
    }

    /// Fold another pass's findings and coverage counters into this one.
    pub fn merge(&mut self, other: AnalysisReport) {
        self.findings.extend(other.findings);
        self.steps_checked += other.steps_checked;
        self.buffers_checked += other.buffers_checked;
    }

    /// All findings, one rendered line each.
    pub fn render(&self) -> String {
        self.findings.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
    }
}

/// The symbolic view of a compiled plan the dataflow passes consume:
/// buffer table (runtime offsets + lifetimes), per-step access lists, and
/// the distinguished input/output buffers. Built by
/// [`AnalysisInput::from_compiled`]; tests mutate it directly to inject
/// defects.
#[derive(Debug, Clone)]
pub struct AnalysisInput {
    /// Units (see `unit_bytes`) of the runtime pool.
    pub pool_elems: usize,
    /// Buffer table ([`crate::exec::CompiledPlan::runtime_buffers`]).
    pub buffers: Vec<RtBufInfo>,
    /// Per-step access lists ([`crate::exec::CompiledPlan::step_accesses`]).
    pub steps: Vec<StepAccess>,
    /// Buffer pre-defined before step 0 (the external-input copy), if
    /// any.
    pub predefined: Option<usize>,
    /// Buffer the logits are read from after the last step.
    pub output: usize,
    /// Bytes per pool unit the offsets/extents above are expressed in: 4
    /// for the f32 [`CompiledPlan`] (element-indexed), 1 for the
    /// byte-indexed int8 [`crate::qexec::QCompiledPlan`]. Diagnostics
    /// scale finding byte ranges by this.
    pub unit_bytes: u64,
}

impl AnalysisInput {
    /// Extract the symbolic view of `plan`.
    pub fn from_compiled(plan: &CompiledPlan) -> Self {
        Self {
            pool_elems: plan.pool_elem_len(),
            buffers: plan.runtime_buffers(),
            steps: plan.step_accesses(),
            predefined: plan.input_buffer(),
            output: plan.output_buffer(),
            unit_bytes: 4,
        }
    }

    /// Extract the symbolic (byte-granular) view of an int8 `plan`.
    pub fn from_qcompiled(plan: &crate::qexec::QCompiledPlan) -> Self {
        Self {
            pool_elems: plan.pool_byte_len(),
            buffers: plan.runtime_buffers(),
            steps: plan.step_accesses(),
            predefined: plan.input_buffer(),
            output: plan.output_buffer(),
            unit_bytes: 1,
        }
    }
}

/// Structural span-chain validation: everything that must hold before
/// `CompiledPlan::compile` can run without panicking. Returns `true` when
/// the setting is compilable.
fn check_setting(
    model: &ModelChain,
    setting: &FusionSetting,
    report: &mut AnalysisReport,
) -> bool {
    let before = report.findings.len();
    let malformed = |d: String| Finding::new(DefectClass::MalformedSetting, d);
    if setting.spans.is_empty() {
        report.push(malformed("setting has no spans".to_string()));
    }
    let mut at = 0usize;
    for (i, &(a, b, iter_tail)) in setting.spans.iter().enumerate() {
        if a != at || b <= a || b > model.num_layers() {
            report.push(malformed(format!(
                "span {i} = [{a}, {b}) does not continue from layer {at} inside the model's {} layers",
                model.num_layers()
            )));
            break;
        }
        at = b;
        if b - a <= 1 {
            continue;
        }
        if iter_tail {
            let Some(gp) = (a..b)
                .find(|&li| matches!(model.layers[li].kind, LayerKind::GlobalAvgPool))
            else {
                report.push(malformed(format!(
                    "iterative-tail span {i} = [{a}, {b}) has no GlobalAvgPool to rewrite (§7)"
                )));
                continue;
            };
            if !model.layers[gp + 1..b].iter().all(|l| matches!(l.kind, LayerKind::Dense)) {
                report.push(malformed(format!(
                    "iterative-tail span {i} = [{a}, {b}) has non-Dense layers after the global pool at {gp}"
                )));
            }
            if !model.fusable_span(a, gp) {
                report.push(malformed(format!(
                    "span {i}: conv pyramid [{a}, {gp}) ahead of the iterative tail is not fusable"
                )));
            }
        } else if !model.fusable_span(a, b) {
            report.push(malformed(format!("span {i} = [{a}, {b}) is not fusable")));
        }
    }
    if report.findings.len() == before && at != model.num_layers() {
        report.push(malformed(format!(
            "spans cover layers 0..{at} but the model has {} layers",
            model.num_layers()
        )));
    }
    report.findings.len() == before
}

/// Full static verification of a serialized [`Plan`] against its model:
/// span-chain structure, the serialized pool layout in isolation
/// ([`verify_layout`]), a cross-check of that layout against a fresh
/// schedule replay (any divergence means the memory map on disk is not
/// the one execution would use), and the compiled step list's dataflow
/// ([`verify_dataflow`]).
pub fn verify_plan(plan: &Plan, model: &ModelChain) -> AnalysisReport {
    let mut report = AnalysisReport::new();
    let compilable = check_setting(model, &plan.setting, &mut report);
    if plan.setting.cost.peak_ram == 0 {
        report.push(Finding::new(
            DefectClass::MalformedSetting,
            "non-positive peak_ram (cost was negative, zero, or lost in parsing)",
        ));
    }
    if let Some(pool) = &plan.pool {
        report.merge(verify_layout(pool));
        if compilable {
            let expected = crate::memory::plan_layout(model, &plan.setting);
            layout::cross_check_layout(pool, &expected, &mut report);
        }
    }
    if compilable {
        let compiled = CompiledPlan::compile(model.clone(), plan.setting.clone());
        let input = AnalysisInput::from_compiled(&compiled);
        report.merge(verify_dataflow(&input));
        report.merge(lint_dead_stores(&input));
    }
    if let Some(spec) = &plan.quant {
        let n = model.num_layers();
        if spec.tensors.len() != n + 1 || spec.weights.len() != n {
            report.push(Finding::new(
                DefectClass::ShapeMismatch,
                format!(
                    "quant spec has {} tensor / {} weight params but the model needs {} / {}",
                    spec.tensors.len(),
                    spec.weights.len(),
                    n + 1,
                    n
                ),
            ));
        } else if compilable {
            // Prove the quantized lowering too: byte-granular dataflow
            // over the int8 step list and its mixed-width pool, the
            // dead-store lint, and the numeric value-range pass
            // (accumulator overflow, calibration well-formedness,
            // saturation risk).
            let q = crate::qexec::QCompiledPlan::compile(
                model.clone(),
                plan.setting.clone(),
                spec.clone(),
            );
            let input = AnalysisInput::from_qcompiled(&q);
            report.merge(verify_dataflow(&input));
            report.merge(lint_dead_stores(&input));
            report.merge(verify_ranges(&NumericInput::from_qcompiled(&q)));
        }
    }
    report
}

/// [`verify_dataflow`] + [`verify_layout`] over an already-compiled plan
/// (both the runtime step list and the accounting layout it carries).
pub fn verify_compiled(plan: &CompiledPlan) -> AnalysisReport {
    let mut report = verify_dataflow(&AnalysisInput::from_compiled(plan));
    report.merge(verify_layout(plan.layout()));
    report
}

/// Load a plan JSON and statically verify it: the one deploy-time gate
/// shared by `msfcnn verify`, [`crate::coordinator::PlanRegistry`] scans,
/// and [`crate::coordinator::ModelSpec::plan_file`]. `Err` means the file
/// could not even be analyzed (unreadable, unparseable — including a pool
/// layout [`Plan::validate`] rejects at parse — or an unresolvable
/// model); `Ok` carries the plan plus its [`AnalysisReport`], whose
/// findings the caller must treat as a rejection.
///
/// Artifact-backed plans (`plan.artifact` set) resolve their model
/// through the referenced [`crate::runtime`] directory instead of the
/// zoo.
pub fn verify_plan_file(path: impl AsRef<Path>) -> Result<(Plan, AnalysisReport)> {
    let path = path.as_ref();
    let plan = Plan::load(path)?;
    let model = plan.resolve_model()?;
    let report = verify_plan(&plan, &model);
    Ok((plan, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::Planner;
    use crate::zoo;

    #[test]
    fn finding_renders_every_location_part() {
        let f = Finding::new(DefectClass::DefBeforeUse, "reads 4 element(s) never written")
            .at_step(3)
            .on_buffer("bands:0..4")
            .in_bytes(128, 144);
        assert_eq!(
            f.render(),
            "[def-before-use] step 3 buffer 'bands:0..4' bytes [128..144): \
             reads 4 element(s) never written"
        );
        let bare = Finding::new(DefectClass::WatermarkMismatch, "off by 8");
        assert_eq!(bare.render(), "[watermark-mismatch]: off by 8");
    }

    #[test]
    fn fresh_plans_verify_clean() {
        let m = zoo::quickstart();
        let plan = Planner::for_model(m.clone()).plan().unwrap();
        let report = verify_plan(&plan, &m);
        assert!(report.is_clean(), "{}", report.render());
        assert!(report.steps_checked > 0);
        assert!(report.buffers_checked > 0);
    }

    #[test]
    fn malformed_settings_are_flagged_not_panicked() {
        let m = zoo::quickstart();
        let mut plan = Planner::for_model(m.clone()).plan().unwrap();
        // Break the span chain: the verifier must report, not panic in
        // the compiler it guards.
        plan.setting.spans[0].0 = 1;
        let report = verify_plan(&plan, &m);
        assert!(report
            .findings
            .iter()
            .any(|f| f.class == DefectClass::MalformedSetting));

        // An iterative-tail span without a GlobalAvgPool would panic
        // `conv_end_of`; the verifier flags it instead.
        let mut iter = Planner::for_model(m.clone()).plan().unwrap();
        if let Some(first) = iter.setting.spans.first_mut() {
            if first.1 - first.0 > 1 {
                first.2 = true;
            }
        }
        let report = verify_plan(&iter, &m);
        if iter.setting.spans.first().is_some_and(|s| s.2) {
            assert!(
                report.findings.iter().any(|f| f.class == DefectClass::MalformedSetting),
                "{}",
                report.render()
            );
        }
    }
}
