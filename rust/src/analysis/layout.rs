//! Pool-layout checks: integrity of a serialized [`PoolLayout`] and
//! cross-checking it against the layout the planner would rebuild from
//! the same `(model, setting)` pair.
//!
//! These passes work on accounting bytes (the unit the layout is
//! serialized in), independent of the compiled f32 step list — they are
//! what [`crate::optimizer::Plan::validate`] runs on every plan read
//! back from disk, so a hand-edited or corrupted memory map is rejected
//! before a registry can deploy it.

use super::{AnalysisReport, DefectClass, Finding};
use crate::memory::{max_concurrent, PoolLayout};

/// Self-consistency of one serialized layout: non-degenerate buffers,
/// every buffer inside `pool_bytes`, exhaustive live/space collision
/// checking (every offending pair, not just the first), and a watermark
/// recomputation that must equal the serialized value.
pub fn verify_layout(layout: &PoolLayout) -> AnalysisReport {
    let mut report = AnalysisReport::new();
    report.buffers_checked = layout.buffers.len();

    if layout.buffers.is_empty() {
        report.push(Finding::new(
            DefectClass::LayoutDivergence,
            "layout has no buffers (every real schedule allocates at least the output)",
        ));
    }
    for b in &layout.buffers {
        if b.bytes == 0 {
            report.push(
                Finding::new(DefectClass::LayoutDivergence, "zero-byte buffer serialized")
                    .on_buffer(&b.label),
            );
        }
        if b.birth >= b.death {
            report.push(
                Finding::new(
                    DefectClass::LifetimeViolation,
                    format!("lifetime [{}, {}) is empty", b.birth, b.death),
                )
                .on_buffer(&b.label)
                .in_bytes(b.offset, b.offset + b.bytes),
            );
        }
        // Byte math vs declared element width (Eq. 5/6 mixed-width
        // pricing: 1 B activations, 4 B accumulators). `elems == 0`
        // means the width predates serialization (legacy layouts) — no
        // claim to check.
        if b.elems > 0 && b.bytes != b.elems * b.elem_bytes as u64 {
            report.push(
                Finding::new(
                    DefectClass::WidthMismatch,
                    format!(
                        "{} B serialized but {} element(s) x {} byte(s) = {} B declared",
                        b.bytes,
                        b.elems,
                        b.elem_bytes,
                        b.elems * b.elem_bytes as u64
                    ),
                )
                .on_buffer(&b.label)
                .in_bytes(b.offset, b.offset + b.bytes),
            );
        }
        if b.offset + b.bytes > layout.pool_bytes {
            report.push(
                Finding::new(
                    DefectClass::OutOfPool,
                    format!(
                        "buffer ends at byte {} but the pool holds {}",
                        b.offset + b.bytes,
                        layout.pool_bytes
                    ),
                )
                .on_buffer(&b.label)
                .in_bytes(b.offset, b.offset + b.bytes),
            );
        }
    }
    for (a, b) in layout.collisions() {
        let lo = a.offset.max(b.offset);
        let hi = (a.offset + a.bytes).min(b.offset + b.bytes);
        report.push(
            Finding::new(
                DefectClass::LayoutCollision,
                format!(
                    "overlaps '{}' while both are alive (ticks [{}, {}) vs [{}, {}))",
                    b.label, a.birth, a.death, b.birth, b.death
                ),
            )
            .on_buffer(&a.label)
            .in_bytes(lo, hi),
        );
    }

    let items: Vec<(u64, usize, usize)> =
        layout.buffers.iter().map(|b| (b.bytes, b.birth, b.death)).collect();
    let recomputed = max_concurrent(&items);
    if recomputed != layout.watermark {
        report.push(Finding::new(
            DefectClass::WatermarkMismatch,
            format!(
                "serialized watermark {} B but the buffer intervals peak at {recomputed} B",
                layout.watermark
            ),
        ));
    }
    if layout.pool_bytes < layout.watermark {
        report.push(Finding::new(
            DefectClass::WatermarkMismatch,
            format!(
                "pool of {} B cannot hold the {} B watermark",
                layout.pool_bytes, layout.watermark
            ),
        ));
    }
    report
}

/// Compare a serialized layout against the one the planner rebuilds from
/// the plan's `(model, setting)` — a self-consistent but *divergent*
/// layout (e.g. every offset shifted into a grown pool) passes
/// [`verify_layout`] yet no longer describes the schedule the executor
/// will replay, so it must still be rejected.
pub(super) fn cross_check_layout(
    stored: &PoolLayout,
    expected: &PoolLayout,
    report: &mut AnalysisReport,
) {
    if stored.pool_bytes != expected.pool_bytes {
        report.push(Finding::new(
            DefectClass::LayoutDivergence,
            format!(
                "serialized pool is {} B but the schedule needs {} B",
                stored.pool_bytes, expected.pool_bytes
            ),
        ));
    }
    if stored.watermark != expected.watermark {
        report.push(Finding::new(
            DefectClass::WatermarkMismatch,
            format!(
                "serialized watermark {} B but the schedule peaks at {} B",
                stored.watermark, expected.watermark
            ),
        ));
    }
    if stored.buffers.len() != expected.buffers.len() {
        report.push(Finding::new(
            DefectClass::LayoutDivergence,
            format!(
                "serialized layout has {} buffer(s) but the schedule allocates {}",
                stored.buffers.len(),
                expected.buffers.len()
            ),
        ));
        return; // per-buffer zip below would misattribute every entry
    }
    for (s, e) in stored.buffers.iter().zip(&expected.buffers) {
        // Placement must match exactly; widths only when the stored
        // layout declares them (legacy pre-width JSON carries elems 0).
        let placement_ok = s.label == e.label
            && s.offset == e.offset
            && s.bytes == e.bytes
            && s.birth == e.birth
            && s.death == e.death;
        let width_ok = s.elems == 0 || (s.elems == e.elems && s.elem_bytes == e.elem_bytes);
        if !placement_ok || !width_ok {
            report.push(
                Finding::new(
                    DefectClass::LayoutDivergence,
                    format!(
                        "serialized as {} B at offset {} alive [{}, {}), but the schedule \
                         places '{}' with {} B at offset {} alive [{}, {})",
                        s.bytes, s.offset, s.birth, s.death, e.label, e.bytes, e.offset, e.birth,
                        e.death
                    ),
                )
                .on_buffer(&s.label)
                .in_bytes(s.offset, s.offset + s.bytes),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::plan_layout;
    use crate::optimizer::{strategy, Constraints, Planner};
    use crate::zoo;

    fn fresh_layout(name: &str) -> PoolLayout {
        let m = zoo::by_name(name).unwrap();
        let setting = Planner::for_model(m.clone())
            .plan_with(&strategy::P1, Constraints::none())
            .unwrap()
            .setting;
        plan_layout(&m, &setting)
    }

    fn classes(report: &AnalysisReport) -> Vec<DefectClass> {
        report.findings.iter().map(|f| f.class).collect()
    }

    #[test]
    fn fresh_layouts_verify_clean() {
        for name in ["quickstart", "lenet", "kws"] {
            let layout = fresh_layout(name);
            let report = verify_layout(&layout);
            assert!(report.is_clean(), "{name}:\n{}", report.render());
            assert_eq!(report.buffers_checked, layout.buffers.len());
        }
    }

    #[test]
    fn corrupted_watermark_and_shrunk_pool_are_flagged() {
        let mut layout = fresh_layout("quickstart");
        layout.watermark += 1;
        let report = verify_layout(&layout);
        assert!(
            classes(&report).contains(&DefectClass::WatermarkMismatch),
            "{}",
            report.render()
        );

        let mut small = fresh_layout("quickstart");
        small.pool_bytes = 1;
        let report = verify_layout(&small);
        let found = classes(&report);
        assert!(found.contains(&DefectClass::OutOfPool), "{}", report.render());
        assert!(found.contains(&DefectClass::WatermarkMismatch), "{}", report.render());
    }

    #[test]
    fn width_mismatch_is_flagged_and_names_the_buffer() {
        let mut layout = fresh_layout("quickstart");
        // An "f32 plan claiming int8-sized pools": widen the declared
        // element bytes without growing the serialized byte size.
        let victim = layout.buffers[0].label.clone();
        layout.buffers[0].elem_bytes *= 4;
        let report = verify_layout(&layout);
        let f = report
            .findings
            .iter()
            .find(|f| f.class == DefectClass::WidthMismatch)
            .unwrap_or_else(|| panic!("no width finding:\n{}", report.render()));
        assert_eq!(f.buffer, victim);
        assert!(f.render().contains("width-mismatch"));

        // Undeclared widths (legacy layouts) make no claim to check.
        let mut legacy = fresh_layout("quickstart");
        for b in &mut legacy.buffers {
            b.elems = 0;
            b.elem_bytes = 0;
        }
        assert!(verify_layout(&legacy).is_clean());
    }

    #[test]
    fn cross_check_rejects_self_consistent_divergence() {
        let original = fresh_layout("quickstart");
        // Shift every buffer up by 8 bytes into a grown pool and keep the
        // watermark recomputable: verify_layout alone stays happy...
        let mut shifted = original.clone();
        for b in &mut shifted.buffers {
            b.offset += 8;
        }
        shifted.pool_bytes += 8;
        assert!(verify_layout(&shifted).is_clean());
        // ...but the cross-check catches the divergence per buffer.
        let mut report = AnalysisReport::new();
        cross_check_layout(&shifted, &original, &mut report);
        let found = classes(&report);
        assert!(found.contains(&DefectClass::LayoutDivergence), "{}", report.render());
        assert!(report.findings.iter().any(|f| f.bytes.is_some() && !f.buffer.is_empty()));
    }
}
