//! Byte/element interval sets — the dataflow lattice of the static
//! verifier.
//!
//! [`IntervalSet`] tracks which element ranges of the execution pool hold
//! defined data. Writes [`IntervalSet::insert`] their range, aliasing
//! writes [`IntervalSet::subtract`] it from every other buffer's set
//! (pool bytes are shared), and reads ask for the
//! [`IntervalSet::uncovered`] gaps — each gap is a def-before-use defect.

/// A set of disjoint half-open `[start, end)` runs over `usize`
/// coordinates, kept sorted and coalesced.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct IntervalSet {
    /// Sorted, non-adjacent, non-empty runs.
    runs: Vec<(usize, usize)>,
}

impl IntervalSet {
    /// The empty set.
    pub fn new() -> Self {
        Self::default()
    }

    /// True when no run is present.
    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }

    /// Total covered length across all runs.
    pub fn covered_len(&self) -> usize {
        self.runs.iter().map(|&(s, e)| e - s).sum()
    }

    /// Insert `[start, end)`, merging with any overlapping or adjacent
    /// runs. Empty ranges are ignored.
    pub fn insert(&mut self, start: usize, end: usize) {
        if start >= end {
            return;
        }
        let mut merged = (start, end);
        let mut out: Vec<(usize, usize)> = Vec::with_capacity(self.runs.len() + 1);
        let mut placed = false;
        for &(rs, re) in &self.runs {
            if re < merged.0 {
                // Strictly before (and not adjacent): keep as-is.
                out.push((rs, re));
            } else if rs > merged.1 {
                // Strictly after: flush the merged run once, keep the rest.
                if !placed {
                    out.push(merged);
                    placed = true;
                }
                out.push((rs, re));
            } else {
                // Overlapping or adjacent: absorb into the merged run.
                merged.0 = merged.0.min(rs);
                merged.1 = merged.1.max(re);
            }
        }
        if !placed {
            out.push(merged);
        }
        self.runs = out;
    }

    /// Remove `[start, end)` from the set (a write elsewhere clobbered
    /// these coordinates).
    pub fn subtract(&mut self, start: usize, end: usize) {
        if start >= end || self.runs.is_empty() {
            return;
        }
        let mut out: Vec<(usize, usize)> = Vec::with_capacity(self.runs.len() + 1);
        for &(rs, re) in &self.runs {
            if re <= start || rs >= end {
                out.push((rs, re));
                continue;
            }
            if rs < start {
                out.push((rs, start));
            }
            if re > end {
                out.push((end, re));
            }
        }
        self.runs = out;
    }

    /// True when `[start, end)` is fully covered (empty ranges trivially
    /// are).
    pub fn covers(&self, start: usize, end: usize) -> bool {
        self.uncovered(start, end).is_empty()
    }

    /// The sub-ranges of `[start, end)` *not* covered by the set, in
    /// ascending order — the def-before-use gaps of a read.
    pub fn uncovered(&self, start: usize, end: usize) -> Vec<(usize, usize)> {
        let mut gaps = Vec::new();
        if start >= end {
            return gaps;
        }
        let mut at = start;
        for &(rs, re) in &self.runs {
            if re <= at {
                continue;
            }
            if rs >= end {
                break;
            }
            if rs > at {
                gaps.push((at, rs.min(end)));
            }
            at = at.max(re);
            if at >= end {
                break;
            }
        }
        if at < end {
            gaps.push((at, end));
        }
        gaps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_merges_overlapping_and_adjacent_runs() {
        let mut s = IntervalSet::new();
        s.insert(10, 20);
        s.insert(30, 40);
        assert_eq!(s.covered_len(), 20);
        // Adjacent on the left edge merges.
        s.insert(20, 25);
        assert!(s.covers(10, 25));
        // Bridging run coalesces everything into one.
        s.insert(24, 31);
        assert!(s.covers(10, 40));
        assert_eq!(s.covered_len(), 30);
        // Empty inserts are no-ops.
        s.insert(50, 50);
        assert_eq!(s.covered_len(), 30);
    }

    #[test]
    fn subtract_splits_and_trims_runs() {
        let mut s = IntervalSet::new();
        s.insert(0, 100);
        s.subtract(40, 60);
        assert!(s.covers(0, 40));
        assert!(s.covers(60, 100));
        assert!(!s.covers(39, 41));
        assert_eq!(s.uncovered(0, 100), vec![(40, 60)]);
        // Subtracting past the edges trims without panicking.
        s.subtract(90, 200);
        assert_eq!(s.uncovered(0, 100), vec![(40, 60), (90, 100)]);
        s.subtract(0, 1000);
        assert!(s.is_empty());
    }

    #[test]
    fn uncovered_reports_every_gap_in_order() {
        let mut s = IntervalSet::new();
        s.insert(10, 20);
        s.insert(30, 40);
        assert_eq!(s.uncovered(0, 50), vec![(0, 10), (20, 30), (40, 50)]);
        assert_eq!(s.uncovered(12, 18), vec![]);
        assert_eq!(s.uncovered(15, 35), vec![(20, 30)]);
        // Queries over an empty set are one whole gap.
        assert_eq!(IntervalSet::new().uncovered(5, 9), vec![(5, 9)]);
    }
}
