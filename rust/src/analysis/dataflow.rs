//! Step-list dataflow passes: the symbolic walk over a compiled plan's
//! access lists.
//!
//! Four passes over one [`AnalysisInput`]:
//!
//! * **structural** — buffer dims/extent/lifetime sanity, access extents
//!   against buffer extents, accesses against the pool bound;
//! * **hazard** — within each step, two accesses may not overlap in pool
//!   space while both buffers are alive (the static form of the
//!   executor's `two_muts`/`three_muts` invariants). Buffers whose
//!   runtime lifetimes are disjoint may legally share pool bytes — e.g.
//!   an iterative-tail's logits reusing the band pyramid's storage — so
//!   only lifetime-overlapping pairs are constrained;
//! * **lifetime** — a monotone clock over the access order: each access
//!   advances the clock to its buffer's birth and must stay below the
//!   buffer's death (steps never reach back to a freed buffer);
//! * **def-before-use** — per-buffer [`IntervalSet`]s of defined pool
//!   elements. Writes define their own range and *subtract* it from every
//!   other buffer's set (pool bytes are shared, so an aliasing write
//!   clobbers), reads report every uncovered gap, and the final output
//!   must end fully defined. Scratch ranges (band pyramids, iterative
//!   accumulators) are produced before the step's outputs, mirroring the
//!   kernels' intra-step write order.

use super::interval::IntervalSet;
use super::{AnalysisInput, AnalysisReport, DefectClass, Finding};
use crate::exec::{BufAccess, RtBufInfo};

/// Findings report byte ranges: pool unit indices scale by the input's
/// declared unit width (4 B f32 elements, 1 B int8 pool bytes).
pub(super) fn byte_range(unit: u64, start: usize, end: usize) -> (u64, u64) {
    (start as u64 * unit, end as u64 * unit)
}

/// Absolute pool element range of one access (saturating: structurally
/// broken inputs must produce findings, not overflow panics).
pub(super) fn abs_range(buf: &RtBufInfo, acc: &BufAccess) -> (usize, usize) {
    let start = buf.off.saturating_add(acc.start);
    (start, start.saturating_add(acc.len))
}

fn structural_pass(input: &AnalysisInput, report: &mut AnalysisReport) {
    for b in &input.buffers {
        let (h, w, c) = b.dims;
        if h * w * c != b.elems {
            report.push(
                Finding::new(
                    DefectClass::ShapeMismatch,
                    format!("dims {h}x{w}x{c} = {} elems but the buffer holds {}", h * w * c, b.elems),
                )
                .on_buffer(&b.label),
            );
        }
        if b.elems == 0 {
            continue;
        }
        let end = b.off.saturating_add(b.elems);
        if end > input.pool_elems {
            let (lo, hi) = byte_range(input.unit_bytes, b.off, end);
            report.push(
                Finding::new(
                    DefectClass::OutOfPool,
                    format!(
                        "buffer ends at element {end} but the pool holds {}",
                        input.pool_elems
                    ),
                )
                .on_buffer(&b.label)
                .in_bytes(lo, hi),
            );
        }
        if b.birth >= b.death {
            report.push(
                Finding::new(
                    DefectClass::LifetimeViolation,
                    format!("lifetime [{}, {}) is empty", b.birth, b.death),
                )
                .on_buffer(&b.label),
            );
        }
    }
    for step in &input.steps {
        let accesses = step.reads.iter().chain(&step.scratch).chain(&step.writes);
        for acc in accesses {
            let Some(b) = input.buffers.get(acc.buf) else {
                report.push(
                    Finding::new(
                        DefectClass::OutOfPool,
                        format!(
                            "access names buffer #{} but the table holds {}",
                            acc.buf,
                            input.buffers.len()
                        ),
                    )
                    .at_step(step.index),
                );
                continue;
            };
            let end = acc.start.saturating_add(acc.len);
            if end > b.elems {
                let (lo, hi) = byte_range(input.unit_bytes, acc.start, end);
                report.push(
                    Finding::new(
                        DefectClass::ShapeMismatch,
                        format!(
                            "access [{}, {end}) exceeds the buffer's {} elements",
                            acc.start, b.elems
                        ),
                    )
                    .at_step(step.index)
                    .on_buffer(&b.label)
                    .in_bytes(lo, hi),
                );
            }
        }
    }
}

fn hazard_pass(input: &AnalysisInput, report: &mut AnalysisReport) {
    for step in &input.steps {
        if step.in_place_safe {
            continue;
        }
        // Access list in kernel order, tagged with its role.
        let mut accesses: Vec<(&'static str, &BufAccess)> = Vec::new();
        accesses.extend(step.reads.iter().map(|a| ("read", a)));
        accesses.extend(step.scratch.iter().map(|a| ("scratch", a)));
        accesses.extend(step.writes.iter().map(|a| ("write", a)));
        for (i, &(role_a, a)) in accesses.iter().enumerate() {
            for &(role_b, b) in accesses.iter().skip(i + 1) {
                if role_a == "read" && role_b == "read" {
                    continue; // two reads never race
                }
                let (Some(ba), Some(bb)) =
                    (input.buffers.get(a.buf), input.buffers.get(b.buf))
                else {
                    continue; // structural pass already reported it
                };
                if a.buf != b.buf {
                    // Distinct buffers with disjoint runtime lifetimes may
                    // legally share pool bytes (the dead one's contents
                    // are gone by construction).
                    let live = ba.birth < bb.death && bb.birth < ba.death;
                    if !live {
                        continue;
                    }
                }
                let (sa, ea) = abs_range(ba, a);
                let (sb, eb) = abs_range(bb, b);
                if sa < eb && sb < ea {
                    let (lo, hi) = byte_range(input.unit_bytes, sa.max(sb), ea.min(eb));
                    report.push(
                        Finding::new(
                            DefectClass::Hazard,
                            format!(
                                "{role_a} of '{}' overlaps {role_b} of '{}' while both are \
                                 alive (kernel not declared in-place-safe)",
                                ba.label, bb.label
                            ),
                        )
                        .at_step(step.index)
                        .on_buffer(&ba.label)
                        .in_bytes(lo, hi),
                    );
                }
            }
        }
    }
}

fn lifetime_pass(input: &AnalysisInput, report: &mut AnalysisReport) {
    // Steps run in order and every buffer access implies "its birth has
    // happened": the clock is the latest birth seen so far. A buffer
    // whose death is at or before the clock was freed by the schedule
    // before this access could run.
    let mut clock = 0usize;
    for step in &input.steps {
        let accesses = step.reads.iter().chain(&step.scratch).chain(&step.writes);
        for acc in accesses {
            let Some(b) = input.buffers.get(acc.buf) else { continue };
            if acc.len == 0 || b.birth >= b.death {
                continue; // empty access / structurally-reported lifetime
            }
            clock = clock.max(b.birth);
            if clock >= b.death {
                let (s, e) = abs_range(b, acc);
                let (lo, hi) = byte_range(input.unit_bytes, s, e);
                report.push(
                    Finding::new(
                        DefectClass::LifetimeViolation,
                        format!(
                            "accessed at schedule tick {clock}, outside its lifetime [{}, {})",
                            b.birth, b.death
                        ),
                    )
                    .at_step(step.index)
                    .on_buffer(&b.label)
                    .in_bytes(lo, hi),
                );
            }
        }
    }
}

fn defined_pass(input: &AnalysisInput, report: &mut AnalysisReport) {
    let mut defined: Vec<IntervalSet> = vec![IntervalSet::new(); input.buffers.len()];
    if let Some(pid) = input.predefined {
        if let Some(b) = input.buffers.get(pid) {
            defined[pid].insert(b.off, b.off + b.elems);
        }
    }
    for step in &input.steps {
        for acc in &step.reads {
            let Some(b) = input.buffers.get(acc.buf) else { continue };
            let (s, e) = abs_range(b, acc);
            for (gs, ge) in defined[acc.buf].uncovered(s, e) {
                let (lo, hi) = byte_range(input.unit_bytes, gs, ge);
                report.push(
                    Finding::new(
                        DefectClass::DefBeforeUse,
                        format!("reads {} element(s) never written", ge - gs),
                    )
                    .at_step(step.index)
                    .on_buffer(&b.label)
                    .in_bytes(lo, hi),
                );
            }
        }
        // Scratch before writes: within a step the scratch pyramid is
        // produced first and the output last, so an output that legally
        // aliases a by-then-dead scratch buffer must subtract *after* the
        // scratch insert, not before.
        for acc in step.scratch.iter().chain(&step.writes) {
            let Some(_) = input.buffers.get(acc.buf) else { continue };
            let b = &input.buffers[acc.buf];
            let (s, e) = abs_range(b, acc);
            for (j, set) in defined.iter_mut().enumerate() {
                if j == acc.buf {
                    set.insert(s, e);
                } else {
                    set.subtract(s, e);
                }
            }
        }
    }
    match input.buffers.get(input.output) {
        Some(b) => {
            for (gs, ge) in defined[input.output].uncovered(b.off, b.off + b.elems) {
                let (lo, hi) = byte_range(input.unit_bytes, gs, ge);
                report.push(
                    Finding::new(
                        DefectClass::DefBeforeUse,
                        "final output element(s) never written".to_string(),
                    )
                    .on_buffer(&b.label)
                    .in_bytes(lo, hi),
                );
            }
        }
        None => report.push(Finding::new(
            DefectClass::OutOfPool,
            format!(
                "output names buffer #{} but the table holds {}",
                input.output,
                input.buffers.len()
            ),
        )),
    }
}

/// Structural + alias/hazard checking only — the invariant set
/// [`crate::exec::CompiledPlan`] asserts once at compile-time-of-plan
/// (promoting the hot path's `two_muts`/`three_muts` `debug_assert!`s to
/// an ahead-of-time proof; the debug asserts stay as belt-and-braces).
pub fn check_step_hazards(input: &AnalysisInput) -> AnalysisReport {
    let mut report = AnalysisReport::new();
    structural_pass(input, &mut report);
    hazard_pass(input, &mut report);
    report.steps_checked = input.steps.len();
    report.buffers_checked = input.buffers.len();
    report
}

/// The full symbolic walk: structural, hazard, lifetime-conformance, and
/// def-before-use passes over one compiled step list. Collects **all**
/// defects.
pub fn verify_dataflow(input: &AnalysisInput) -> AnalysisReport {
    let mut report = AnalysisReport::new();
    structural_pass(input, &mut report);
    hazard_pass(input, &mut report);
    lifetime_pass(input, &mut report);
    defined_pass(input, &mut report);
    report.steps_checked = input.steps.len();
    report.buffers_checked = input.buffers.len();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{CompiledPlan, StepAccess};
    use crate::optimizer::{strategy, Constraints, Planner};
    use crate::zoo;

    fn vanilla_input(name: &str) -> AnalysisInput {
        let m = zoo::by_name(name).unwrap();
        let setting = Planner::for_model(m.clone())
            .plan_with(&strategy::Vanilla, Constraints::none())
            .unwrap()
            .setting;
        AnalysisInput::from_compiled(&CompiledPlan::compile(m, setting))
    }

    fn classes(report: &AnalysisReport) -> Vec<DefectClass> {
        report.findings.iter().map(|f| f.class).collect()
    }

    #[test]
    fn clean_compiled_plans_have_no_findings() {
        for name in ["quickstart", "tiny", "kws"] {
            let input = vanilla_input(name);
            let report = verify_dataflow(&input);
            assert!(report.is_clean(), "{name}:\n{}", report.render());
        }
    }

    #[test]
    fn reordered_steps_are_def_before_use() {
        let mut input = vanilla_input("quickstart");
        assert!(input.steps.len() >= 2);
        input.steps.swap(0, 1);
        let report = verify_dataflow(&input);
        assert!(
            classes(&report).contains(&DefectClass::DefBeforeUse),
            "{}",
            report.render()
        );
    }

    #[test]
    fn missing_input_copy_is_def_before_use() {
        let mut input = vanilla_input("quickstart");
        assert!(input.predefined.is_some(), "vanilla plans materialize v0");
        input.predefined = None;
        let report = verify_dataflow(&input);
        assert!(
            classes(&report).contains(&DefectClass::DefBeforeUse),
            "{}",
            report.render()
        );
    }

    #[test]
    fn aliased_read_write_is_a_hazard() {
        let mut input = vanilla_input("quickstart");
        // Force the first step's output on top of its own input.
        let (rbuf, wbuf) = {
            let s: &StepAccess = &input.steps[0];
            (s.reads[0].buf, s.writes[0].buf)
        };
        input.buffers[wbuf].off = input.buffers[rbuf].off;
        let report = verify_dataflow(&input);
        assert!(classes(&report).contains(&DefectClass::Hazard), "{}", report.render());

        // The same overlap is sanctioned by the in-place-safe flag.
        let mut safe = input.clone();
        for s in &mut safe.steps {
            s.in_place_safe = true;
        }
        let report = verify_dataflow(&safe);
        assert!(!classes(&report).contains(&DefectClass::Hazard), "{}", report.render());
    }

    #[test]
    fn truncated_lifetime_and_shrunk_buffer_are_flagged() {
        let mut input = vanilla_input("quickstart");
        let out = input.output;
        input.buffers[out].death = input.buffers[out].birth;
        let report = verify_dataflow(&input);
        assert!(
            classes(&report).contains(&DefectClass::LifetimeViolation),
            "{}",
            report.render()
        );

        let mut shrunk = vanilla_input("quickstart");
        shrunk.buffers[out].elems /= 2;
        let report = verify_dataflow(&shrunk);
        assert!(
            classes(&report).contains(&DefectClass::ShapeMismatch),
            "{}",
            report.render()
        );
    }
}
