//! Dead-store lint: a warning-severity pass over the same byte-interval
//! dataflow the hard verifier walks.
//!
//! A dead store is a write whose bytes are clobbered (by a later write
//! or scratch production aliasing the same pool bytes) or abandoned (the
//! plan ends) before any read consumes them. On a sound plan this never
//! happens — every boundary tensor is read by its consumer step, every
//! stash by its residual add, and the final output is the plan's
//! product — so a [`DefectClass::DeadStore`] finding flags wasted
//! kernel work and pool bytes: a scheduling or lowering inefficiency,
//! not a memory-safety violation. Accordingly findings are
//! [`super::Severity::Warn`] and never block deployment.
//!
//! The walk tracks written-but-unread absolute pool byte runs, each
//! tagged with the step that produced it. Reads consume same-buffer
//! runs; scratch productions and writes clobber overlapping runs of
//! *any* buffer (pool bytes are shared); writes then open a new run.
//! Scratch ranges open no runs of their own: a kernel's scratch is
//! produced and consumed within the step, so tracking it would only
//! manufacture noise. Findings are attributed to the step and buffer
//! that performed the dead write, with the dead byte range.

use super::dataflow::{abs_range, byte_range};
use super::{AnalysisInput, AnalysisReport, DefectClass, Finding};

/// A written-but-not-yet-read absolute pool byte run, tagged with its
/// producing step and buffer for attribution.
#[derive(Debug, Clone, Copy)]
struct StoreRun {
    start: usize,
    end: usize,
    step: usize,
    buf: usize,
}

/// Remove `[s, e)` from every run of buffer `buf`, splitting partial
/// overlaps: these bytes were read, so they are no longer dead-store
/// candidates.
fn consume(runs: &mut Vec<StoreRun>, buf: usize, s: usize, e: usize) {
    let mut next = Vec::with_capacity(runs.len());
    for r in runs.drain(..) {
        if r.buf != buf || e <= r.start || r.end <= s {
            next.push(r);
            continue;
        }
        if r.start < s {
            next.push(StoreRun { end: s, ..r });
        }
        if e < r.end {
            next.push(StoreRun { start: e, ..r });
        }
    }
    *runs = next;
}

/// Clobber `[s, e)` across every run regardless of buffer (pool bytes
/// are shared): each overlapped portion is a dead store, reported
/// against the run's original writer.
fn clobber(
    runs: &mut Vec<StoreRun>,
    s: usize,
    e: usize,
    clobber_step: usize,
    input: &AnalysisInput,
    report: &mut AnalysisReport,
) {
    let mut next = Vec::with_capacity(runs.len());
    for r in runs.drain(..) {
        if e <= r.start || r.end <= s {
            next.push(r);
            continue;
        }
        let (ds, de) = (r.start.max(s), r.end.min(e));
        flag(&StoreRun { start: ds, end: de, ..r }, Some(clobber_step), input, report);
        if r.start < s {
            next.push(StoreRun { end: s, ..r });
        }
        if e < r.end {
            next.push(StoreRun { start: e, ..r });
        }
    }
    *runs = next;
}

fn flag(run: &StoreRun, clobbered_at: Option<usize>, input: &AnalysisInput, report: &mut AnalysisReport) {
    let label = input
        .buffers
        .get(run.buf)
        .map_or("?", |b| b.label.as_str());
    let detail = match clobbered_at {
        Some(at) => format!("store is overwritten at step {at} before any read"),
        None => "store is never read before the plan ends".to_string(),
    };
    let (lo, hi) = byte_range(input.unit_bytes, run.start, run.end);
    report.push(
        Finding::new(DefectClass::DeadStore, detail)
            .warn()
            .at_step(run.step)
            .on_buffer(label)
            .in_bytes(lo, hi),
    );
}

/// The dead-store lint: walk the compiled step list in order, tracking
/// written-but-unread pool byte runs, and emit a warning-severity
/// [`DefectClass::DeadStore`] finding for every store that is clobbered
/// or abandoned unread. Sound plans produce an empty report.
pub fn lint_dead_stores(input: &AnalysisInput) -> AnalysisReport {
    let mut report = AnalysisReport::new();
    let mut runs: Vec<StoreRun> = Vec::new();

    for step in &input.steps {
        // Reads first: an in-place step legally consumes before writing.
        for acc in &step.reads {
            let Some(b) = input.buffers.get(acc.buf) else { continue };
            if acc.len == 0 {
                continue;
            }
            let (s, e) = abs_range(b, acc);
            consume(&mut runs, acc.buf, s, e);
        }
        // Scratch productions clobber but open no runs; writes clobber
        // then open their own run. Kernel order: scratch before writes.
        for (is_write, acc) in step
            .scratch
            .iter()
            .map(|a| (false, a))
            .chain(step.writes.iter().map(|a| (true, a)))
        {
            let Some(b) = input.buffers.get(acc.buf) else { continue };
            if acc.len == 0 {
                continue;
            }
            let (s, e) = abs_range(b, acc);
            clobber(&mut runs, s, e, step.index, input, &mut report);
            if is_write {
                runs.push(StoreRun { start: s, end: e, step: step.index, buf: acc.buf });
            }
        }
    }

    // The final output is the plan's product: consumed by definition.
    if let Some(b) = input.buffers.get(input.output) {
        consume(&mut runs, input.output, b.off, b.off + b.elems);
    }
    for r in &runs {
        flag(r, None, input, &mut report);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{BufAccess, CompiledPlan, StepAccess};
    use crate::optimizer::{strategy, Constraints, Planner};
    use crate::zoo;

    fn vanilla_input(name: &str) -> AnalysisInput {
        let m = zoo::by_name(name).unwrap();
        let setting = Planner::for_model(m.clone())
            .plan_with(&strategy::Vanilla, Constraints::none())
            .unwrap()
            .setting;
        AnalysisInput::from_compiled(&CompiledPlan::compile(m, setting))
    }

    #[test]
    fn sound_plans_have_no_dead_stores() {
        for name in ["quickstart", "tiny", "kws", "lenet"] {
            let report = lint_dead_stores(&vanilla_input(name));
            assert!(report.is_clean(), "{name}:\n{}", report.render());
        }
    }

    /// A synthetic step that rewrites an already-written boundary before
    /// its consumer runs makes the *original* store dead.
    #[test]
    fn clobbered_store_is_flagged_against_its_writer() {
        let mut input = vanilla_input("quickstart");
        let first_write = input.steps[0].writes[0];
        let redundant = StepAccess {
            index: input.steps[0].index,
            kind: "synthetic",
            label: "redundant rewrite".to_string(),
            reads_external_input: false,
            reads: vec![],
            writes: vec![first_write],
            scratch: vec![],
            in_place_safe: false,
        };
        input.steps.insert(1, redundant);
        let report = lint_dead_stores(&input);
        let dead: Vec<_> = report
            .findings
            .iter()
            .filter(|f| f.class == DefectClass::DeadStore)
            .collect();
        assert_eq!(dead.len(), 1, "{}", report.render());
        assert_eq!(dead[0].step, Some(input.steps[0].index), "attributed to the writer");
        assert_eq!(dead[0].severity, crate::analysis::Severity::Warn);
        assert!(!report.has_errors(), "dead stores are warnings");
    }

    /// A write whose bytes nothing ever reads is flagged at plan end.
    #[test]
    fn abandoned_store_is_flagged() {
        let mut input = vanilla_input("quickstart");
        let nbufs = input.buffers.len();
        // Give the orphan its own buffer past everything else so no
        // later access touches it.
        let pool_end = input.pool_elems;
        input.pool_elems += 16;
        input.buffers.push(crate::exec::RtBufInfo {
            label: "orphan".to_string(),
            off: pool_end,
            elems: 16,
            dims: (1, 1, 16),
            birth: 0,
            death: usize::MAX,
        });
        let last_index = input.steps.last().unwrap().index;
        input.steps.push(StepAccess {
            index: last_index + 1,
            kind: "synthetic",
            label: "orphan write".to_string(),
            reads_external_input: false,
            reads: vec![],
            writes: vec![BufAccess { buf: nbufs, start: 0, len: 16 }],
            scratch: vec![],
            in_place_safe: false,
        });
        let report = lint_dead_stores(&input);
        assert_eq!(report.warn_count(), 1, "{}", report.render());
        let f = &report.findings[0];
        assert_eq!(f.class, DefectClass::DeadStore);
        assert!(f.detail.contains("never read"), "{}", f.render());
        assert_eq!(f.buffer, "orphan");
    }

    #[test]
    fn partial_consume_keeps_the_unread_remainder() {
        let mut runs = vec![StoreRun { start: 0, end: 100, step: 3, buf: 7 }];
        consume(&mut runs, 7, 20, 60);
        assert_eq!(runs.len(), 2);
        assert_eq!((runs[0].start, runs[0].end), (0, 20));
        assert_eq!((runs[1].start, runs[1].end), (60, 100));
        // A different buffer's read does not consume.
        consume(&mut runs, 8, 0, 100);
        assert_eq!(runs.len(), 2);
    }
}
