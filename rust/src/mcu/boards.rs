//! Board catalog — paper Table 4, verbatim.

/// Instruction-set family; drives the per-ISA MAC throughput of the
/// latency model (Cortex-M7 is dual-issue with DSP MAC; Xtensa LX7 has a
/// MAC16; single-issue RV32IMC does multiply+add sequences).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Isa {
    CortexM7,
    CortexM4,
    Xtensa,
    RiscV,
}

/// One evaluation board (paper Table 4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Board {
    pub name: &'static str,
    pub mcu: &'static str,
    pub isa: Isa,
    pub mhz: u32,
    pub ram_kb: u32,
    pub flash_kb: u32,
}

impl Board {
    pub fn ram_bytes(&self) -> u64 {
        self.ram_kb as u64 * 1024
    }

    pub fn flash_bytes(&self) -> u64 {
        self.flash_kb as u64 * 1024
    }
}

/// Paper Table 4, in paper order.
pub const BOARDS: &[Board] = &[
    Board { name: "nucleo-f767zi", mcu: "STM32F767ZI", isa: Isa::CortexM7, mhz: 216, ram_kb: 512, flash_kb: 2048 },
    Board { name: "stm32f746g-disco", mcu: "STM32F746NG", isa: Isa::CortexM7, mhz: 216, ram_kb: 320, flash_kb: 1024 },
    Board { name: "nucleo-f412zg", mcu: "STM32F412ZG", isa: Isa::CortexM4, mhz: 100, ram_kb: 256, flash_kb: 1024 },
    Board { name: "esp32s3-devkit", mcu: "ESP32-S3-WROOM-1N8", isa: Isa::Xtensa, mhz: 240, ram_kb: 512, flash_kb: 8192 },
    Board { name: "esp32c3-devkit", mcu: "ESP32C3-MINI", isa: Isa::RiscV, mhz: 160, ram_kb: 384, flash_kb: 4096 },
    Board { name: "hifive1b", mcu: "SiFive FE310-G002", isa: Isa::RiscV, mhz: 320, ram_kb: 16, flash_kb: 4096 },
];

pub fn board_by_name(name: &str) -> Option<&'static Board> {
    BOARDS.iter().find(|b| b.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_complete() {
        assert_eq!(BOARDS.len(), 6);
        let f767 = board_by_name("nucleo-f767zi").unwrap();
        assert_eq!(f767.mhz, 216);
        assert_eq!(f767.ram_kb, 512);
        let hifive = board_by_name("hifive1b").unwrap();
        assert_eq!(hifive.ram_kb, 16, "the 16 kB board that OOMs in Table 3");
    }

    #[test]
    fn lookup_misses_are_none() {
        assert!(board_by_name("arduino-uno").is_none());
    }
}
