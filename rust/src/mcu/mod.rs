//! MCU substrate: the board catalog (paper Table 4) and the latency model
//! used to regenerate Tables 3 and 5 without physical hardware.

mod boards;
mod latency;

pub use boards::{board_by_name, Board, Isa, BOARDS};
pub use latency::{
    edge_latency_cycles, estimate_latency_ms, path_latency_ms, LatencyBreakdown, LatencyModel,
};
