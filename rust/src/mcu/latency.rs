//! Cycle-level latency model for fused CNN inference on MCUs.
//!
//! The paper measures wall-clock on six boards; we model it as
//!
//! ```text
//! cycles = MACs · cpm(ISA)
//!        + flash_refetch_bytes · fpb(ISA)
//!        + iterations · TILE_OVERHEAD
//! latency_ms = cycles / (MHz · 1000)
//! ```
//!
//! * `cpm` — cycles per MAC of the int8 conv inner loop, calibrated per
//!   ISA against the *vanilla* rows of paper Table 5 (Cortex-M7 ≈ 10,
//!   single-issue RISC-V/Xtensa much higher — which reproduces the paper's
//!   esp32s3-vs-esp32c3 crossover on MN2-320K);
//! * `flash_refetch` — §8.3's observation: fused blocks refetch their
//!   weights from flash **once per band iteration** (recomputation
//!   disrupts the weight cache), vanilla layers read weights once;
//! * `TILE_OVERHEAD` — per-iteration loop/bookkeeping cost.
//!
//! Absolute milliseconds are testbed-specific; the model is calibrated so
//! orderings, ratios, and crossovers (who wins, F vs measured-overhead
//! divergence) match the paper — see EXPERIMENTS.md.

use crate::graph::{DagEdge, FusionDag};
use crate::model::ModelChain;
use crate::optimizer::FusionSetting;

use super::boards::{Board, Isa};

/// Per-iteration loop/bookkeeping cycles of the band scheduler.
pub const TILE_OVERHEAD_CYCLES: u64 = 400;

/// Per-ISA cost constants.
#[derive(Debug, Clone, Copy)]
pub struct LatencyModel {
    /// Cycles per multiply-accumulate (int8 conv inner loop, weights warm).
    pub cycles_per_mac: f64,
    /// Multiplier on `cycles_per_mac` *inside fusion blocks*: per-patch
    /// recomputation refetches weights from flash and "disrupts cache
    /// hits" (§8.3), slowing every MAC of the fused inner loop — this is
    /// why the paper measures wall-clock overhead well above the F factor
    /// (2–5x at min-RAM, §8.1).
    pub fused_mac_multiplier: f64,
    /// Cycles per weight byte fetched from flash (refetch path).
    pub flash_cycles_per_byte: f64,
}

impl LatencyModel {
    /// Calibrated against the vanilla/min-RAM rows of paper Tables 3 & 5:
    /// vanilla latencies set the `cycles_per_mac` scale; the min-RAM
    /// latency inflation (2–5x) sets the fused multiplier; XIP-from-SPI
    /// parts (ESP32, SiFive) pay more on both axes, which reproduces the
    /// paper's esp32c3-vs-esp32s3 crossover on MN2-320K.
    pub fn for_isa(isa: Isa) -> Self {
        match isa {
            Isa::CortexM7 => Self {
                cycles_per_mac: 10.0,
                fused_mac_multiplier: 1.55,
                flash_cycles_per_byte: 8.0,
            },
            Isa::CortexM4 => Self {
                cycles_per_mac: 12.5,
                fused_mac_multiplier: 1.6,
                flash_cycles_per_byte: 10.0,
            },
            // ESP32-S3 Xtensa: higher clock but slower int8 path + SPI flash.
            Isa::Xtensa => Self {
                cycles_per_mac: 38.0,
                fused_mac_multiplier: 2.4,
                flash_cycles_per_byte: 30.0,
            },
            // ESP32-C3 / SiFive single-issue RV32IMC, XIP from SPI flash.
            Isa::RiscV => Self {
                cycles_per_mac: 25.0,
                fused_mac_multiplier: 2.3,
                flash_cycles_per_byte: 28.0,
            },
        }
    }
}

/// Latency decomposition (all in cycles; `total_ms` scaled by the clock).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyBreakdown {
    pub mac_cycles: f64,
    pub flash_cycles: f64,
    pub overhead_cycles: f64,
    pub total_ms: f64,
}

/// Estimate inference latency of `setting` for `model` on `board`.
pub fn estimate_latency_ms(
    model: &ModelChain,
    setting: &FusionSetting,
    board: &Board,
) -> LatencyBreakdown {
    let lm = LatencyModel::for_isa(board.isa);
    let mut mac_cycles = 0.0;
    let mut flash_cycles = 0.0;
    let mut overhead_cycles = 0.0;

    for &(a, b, _iter_tail) in &setting.spans {
        let span_params: u64 = (a..b).map(|i| model.layers[i].param_bytes()).sum();
        if b - a == 1 {
            mac_cycles += model.layer_macs(a) as f64 * lm.cycles_per_mac;
            flash_cycles += span_params as f64 * lm.flash_cycles_per_byte;
        } else {
            let macs = crate::fusion::block_macs(model, a, b);
            mac_cycles += macs as f64 * lm.cycles_per_mac * lm.fused_mac_multiplier;
            // One band iteration per final-output row; the whole block's
            // weights stream from flash every iteration (§8.3).
            let iterations = model.output_of(b - 1).h as u64;
            flash_cycles += (span_params * iterations) as f64 * lm.flash_cycles_per_byte;
            overhead_cycles += (iterations * TILE_OVERHEAD_CYCLES) as f64;
        }
    }

    let total_cycles = mac_cycles + flash_cycles + overhead_cycles;
    LatencyBreakdown {
        mac_cycles,
        flash_cycles,
        overhead_cycles,
        total_ms: total_cycles / (board.mhz as f64 * 1000.0),
    }
}

/// Latency cycles of one fusion-DAG edge under `lm` — the additive form
/// of the model above, computed from the edge's precomputed ingredients
/// ([`DagEdge::param_bytes`], [`DagEdge::band_iterations`],
/// [`DagEdge::latency_macs`]) so constrained planners
/// ([`crate::optimizer::strategy::LatencyAware`]) can walk the DAG
/// without the model in hand. For any complete path, the per-edge sum
/// equals [`estimate_latency_ms`] on the resulting setting (up to float
/// summation order).
pub fn edge_latency_cycles(edge: &DagEdge, lm: &LatencyModel) -> f64 {
    if edge.b - edge.a == 1 && !edge.iterative_tail {
        edge.latency_macs as f64 * lm.cycles_per_mac
            + edge.param_bytes as f64 * lm.flash_cycles_per_byte
    } else {
        edge.latency_macs as f64 * lm.cycles_per_mac * lm.fused_mac_multiplier
            + (edge.param_bytes * edge.band_iterations) as f64 * lm.flash_cycles_per_byte
            + (edge.band_iterations * TILE_OVERHEAD_CYCLES) as f64
    }
}

/// Estimated latency (ms) of a complete DAG `path` on `board`: the sum of
/// [`edge_latency_cycles`] scaled by the clock. Agrees with
/// [`estimate_latency_ms`] on the setting the path denotes (up to float
/// summation order).
pub fn path_latency_ms(dag: &FusionDag, path: &[usize], board: &Board) -> f64 {
    let lm = LatencyModel::for_isa(board.isa);
    let cycles: f64 = path.iter().map(|&e| edge_latency_cycles(&dag.edges[e], &lm)).sum();
    cycles / (board.mhz as f64 * 1000.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcu::board_by_name;
    use crate::model::ModelChain;
    use crate::optimizer::{strategy, Constraints, FusionSetting, Planner};
    use crate::zoo;

    /// `(vanilla, min-RAM)` settings off one shared planner.
    fn plans_for(m: &ModelChain) -> (FusionSetting, FusionSetting) {
        let mut planner = Planner::for_model(m.clone());
        let fused = planner.setting().unwrap();
        let vanilla = planner
            .plan_with(&strategy::Vanilla, Constraints::none())
            .unwrap()
            .setting;
        (vanilla, fused)
    }

    #[test]
    fn fused_is_slower_than_vanilla() {
        let m = zoo::mcunet_vww5();
        let (vanilla, fused) = plans_for(&m);
        let b = board_by_name("nucleo-f767zi").unwrap();
        let v = estimate_latency_ms(&m, &vanilla, b);
        let f = estimate_latency_ms(&m, &fused, b);
        assert!(f.total_ms > v.total_ms, "fusion trades latency for RAM");
    }

    #[test]
    fn clock_scales_latency_within_isa() {
        let m = zoo::tiny_cnn();
        let (s, _) = plans_for(&m);
        let f767 = estimate_latency_ms(&m, &s, board_by_name("nucleo-f767zi").unwrap());
        let f412 = estimate_latency_ms(&m, &s, board_by_name("nucleo-f412zg").unwrap());
        assert!(f412.total_ms > f767.total_ms, "100 MHz M4 slower than 216 MHz M7");
    }

    #[test]
    fn esp32c3_beats_s3_on_big_models() {
        // Paper §8.1: RISC-V esp32c3 @160 MHz edges out Xtensa esp32s3
        // @240 MHz on MN2-320K despite the lower clock.
        let m = zoo::mcunet_320k();
        let (_, s) = plans_for(&m);
        let s3 = estimate_latency_ms(&m, &s, board_by_name("esp32s3-devkit").unwrap());
        let c3 = estimate_latency_ms(&m, &s, board_by_name("esp32c3-devkit").unwrap());
        assert!(c3.total_ms < s3.total_ms);
    }

    #[test]
    fn edge_sum_matches_span_estimate() {
        // The per-edge (DAG-walk) form and the per-span (model) form are
        // the same latency model; constrained planning prunes with the
        // former, plans record the latter.
        use crate::graph::{DagOptions, FusionDag};
        for m in [zoo::tiny_cnn(), zoo::kws_cnn(), zoo::quickstart()] {
            let dag = FusionDag::build(&m, DagOptions::default());
            let mut planner = Planner::for_model(m.clone());
            for s in [
                planner.setting().unwrap(),
                planner
                    .plan_with(&strategy::Vanilla, Constraints::none())
                    .unwrap()
                    .setting,
            ] {
                for b in crate::mcu::BOARDS {
                    let span_ms = estimate_latency_ms(&m, &s, b).total_ms;
                    let edge_ms = path_latency_ms(&dag, &s.path, b);
                    assert!(
                        (span_ms - edge_ms).abs() <= span_ms.abs() * 1e-9 + 1e-9,
                        "{}@{}: {span_ms} vs {edge_ms}",
                        m.name,
                        b.name
                    );
                }
            }
        }
    }

    #[test]
    fn measured_overhead_exceeds_f_factor() {
        // §8.3: wall-clock overhead > F because of flash refetch.
        let m = zoo::mcunet_vww5();
        let b = board_by_name("nucleo-f767zi").unwrap();
        let (v, f) = plans_for(&m);
        let lat_ratio = estimate_latency_ms(&m, &f, b).total_ms
            / estimate_latency_ms(&m, &v, b).total_ms;
        assert!(
            lat_ratio > f.cost.overhead,
            "latency ratio {lat_ratio:.2} should exceed F={:.2}",
            f.cost.overhead
        );
    }
}
