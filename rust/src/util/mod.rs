//! In-tree utilities replacing crates unavailable in the offline build
//! (DESIGN.md §Substitutions): a minimal JSON parser (↔ `serde_json`),
//! a micro-benchmark harness (↔ `criterion`), and a seeded property-test
//! runner (↔ `proptest`).

pub mod bench;
pub mod json;
pub mod prop;
