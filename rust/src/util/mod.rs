//! In-tree utilities replacing crates unavailable in the offline build
//! (DESIGN.md §Substitutions): a minimal JSON parser (↔ `serde_json`),
//! a micro-benchmark harness (↔ `criterion`), a seeded property-test
//! runner (↔ `proptest`), and an opaque error type (↔ `anyhow`).

pub mod bench;
pub mod error;
pub mod json;
pub mod prop;
