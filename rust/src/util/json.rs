//! Minimal JSON parser — enough for `artifacts/manifest.json` (objects,
//! arrays, strings, numbers, bools, null; UTF-8 escapes for ASCII inputs).

use std::collections::BTreeMap;
use std::fmt;

/// Parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// `obj["key"]` convenience.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

/// Parse failure with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(c) => {
                    // Copy the raw byte run up to the next quote/escape.
                    let start = self.pos;
                    while let Some(c2) = self.peek() {
                        if c2 == b'"' || c2 == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                    let _ = c;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Escape a string for JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "model_vanilla": {
            "file": "model_vanilla.hlo.txt",
            "inputs": [{"shape": [32, 32, 3], "dtype": "float32"}],
            "outputs": [{"shape": [10], "dtype": "float32"}]
          }
        }"#;
        let v = Json::parse(doc).unwrap();
        let entry = v.get("model_vanilla").unwrap();
        assert_eq!(entry.get("file").unwrap().as_str(), Some("model_vanilla.hlo.txt"));
        let shape: Vec<usize> = entry.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![32, 32, 3]);
    }

    #[test]
    fn scalars_and_nesting() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#"[1, [2, {"a": 3}]]"#).unwrap().as_arr().unwrap().len(),
            2
        );
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn errors_have_positions() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("123 x").is_err());
    }

    #[test]
    fn escape_roundtrip() {
        let s = "line\n\"quoted\"\tand\\slash";
        let parsed = Json::parse(&format!("\"{}\"", escape(s))).unwrap();
        assert_eq!(parsed.as_str(), Some(s));
    }
}
