//! Minimal error handling replacing `anyhow` (unavailable in the offline
//! vendor set — DESIGN.md §Substitutions).
//!
//! Mirrors the subset of the `anyhow` API this crate uses: an opaque
//! string-backed [`Error`], the [`crate::anyhow!`] / [`crate::bail!`]
//! macros, a [`Context`] extension trait, and a defaulted [`Result`]
//! alias. Context frames prepend to the message the way `anyhow`'s
//! `{:#}` formatting renders its chain, so messages like
//! `"reading manifest: No such file"` come out identically.

use std::fmt;

/// Opaque error: a message plus outer context frames.
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    /// Build from anything printable (the `anyhow::Error::msg` shape).
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string(), context: Vec::new() }
    }

    /// Wrap with an outer context frame (printed before the cause).
    pub fn wrap(mut self, c: impl fmt::Display) -> Self {
        self.context.push(c.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for c in self.context.iter().rev() {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    // Debug = Display: anyhow prints the context chain either way.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error::msg(e)
    }
}

impl From<crate::memory::OomError> for Error {
    fn from(e: crate::memory::OomError) -> Self {
        Error::msg(e)
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Self {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error::msg(s)
    }
}

/// `Result` defaulted to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context` stand-in: attach context to any displayable error.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] message, like `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::util::error::Error::msg(format!($($arg)*)) };
}

/// Early-return with a formatted [`Error`], like `anyhow::bail!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prints_context_outermost_first() {
        let e = Error::msg("root cause").wrap("inner").wrap("outer");
        assert_eq!(e.to_string(), "outer: inner: root cause");
        assert_eq!(format!("{e:#}"), "outer: inner: root cause");
    }

    #[test]
    fn macros_format() {
        let e = crate::anyhow!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
        fn fails() -> Result<()> {
            crate::bail!("nope: {}", "reason");
        }
        assert_eq!(fails().unwrap_err().to_string(), "nope: reason");
    }

    #[test]
    fn context_trait_on_results_and_options() {
        let r: std::result::Result<(), &str> = Err("io broke");
        assert_eq!(r.context("reading file").unwrap_err().to_string(), "reading file: io broke");
        let o: Option<u32> = None;
        assert_eq!(o.with_context(|| "missing key").unwrap_err().to_string(), "missing key");
    }

    #[test]
    fn std_conversions() {
        fn parse(s: &str) -> Result<u64> {
            Ok(s.parse::<u64>()?)
        }
        assert!(parse("12").is_ok());
        assert!(parse("x").is_err());
    }

    #[test]
    fn composes_with_question_mark_in_downstream_binaries() {
        // The whole point of `impl std::error::Error`: a downstream
        // binary returning `Box<dyn Error>` can use `?` on crate results.
        fn downstream() -> std::result::Result<u32, Box<dyn std::error::Error>> {
            Err(Error::msg("backend down").wrap("loading plan"))?
        }
        let e = downstream().unwrap_err();
        assert_eq!(e.to_string(), "loading plan: backend down");

        fn via_json() -> std::result::Result<(), Box<dyn std::error::Error>> {
            crate::util::json::Json::parse("not json")?;
            Ok(())
        }
        assert!(via_json().is_err());
    }
}
