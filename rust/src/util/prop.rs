//! Seeded property-test runner (offline stand-in for `proptest`).
//!
//! Deterministic xorshift generation with per-case seeds: a failing case
//! prints its seed so it can be replayed exactly. No shrinking — cases are
//! kept small by construction.

use crate::ops::ParamGen;

/// Random-value source handed to each property case.
pub struct Gen {
    inner: ParamGen,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { inner: ParamGen::new(seed), seed }
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        let unit = self.inner.next(1.0) + 0.5; // [0, 1)
        lo + ((hi - lo + 1) as f64 * unit as f64) as usize
    }

    pub fn u32_in(&mut self, lo: u32, hi: u32) -> u32 {
        self.usize_in(lo as usize, hi as usize) as u32
    }

    pub fn bool(&mut self) -> bool {
        self.inner.next(1.0) > 0.0
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.inner.next(1.0) + 0.5) * (hi - lo)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    pub fn vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.inner.next(scale)).collect()
    }
}

/// Run `cases` seeded property checks; panics with the replay seed on the
/// first failure. `f` returns `Err(msg)` to fail a case.
pub fn check(name: &str, cases: u32, mut f: impl FnMut(&mut Gen) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ ((case as u64) << 17) ^ case as u64;
        let mut g = Gen::new(seed);
        if let Err(msg) = f(&mut g) {
            panic!("property '{name}' failed (case {case}, replay seed {seed:#x}): {msg}");
        }
    }
}

/// Replay one property case by seed (debugging helper).
pub fn replay(seed: u64, mut f: impl FnMut(&mut Gen) -> Result<(), String>) {
    let mut g = Gen::new(seed);
    if let Err(msg) = f(&mut g) {
        panic!("replay {seed:#x} failed: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usize_in_bounds() {
        check("bounds", 200, |g| {
            let v = g.usize_in(3, 9);
            if (3..=9).contains(&v) {
                Ok(())
            } else {
                Err(format!("{v} out of [3,9]"))
            }
        });
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Gen::new(42);
        let mut b = Gen::new(42);
        for _ in 0..32 {
            assert_eq!(a.usize_in(0, 1000), b.usize_in(0, 1000));
        }
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failure_reports_seed() {
        check("always-fails", 1, |_| Err("nope".into()));
    }
}
