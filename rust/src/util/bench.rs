//! Micro-benchmark harness (offline stand-in for `criterion`).
//!
//! Warmup + timed iterations with mean/median/p95 and a black-box sink to
//! defeat dead-code elimination. Used by every `rust/benches/*.rs` target
//! (`harness = false`).

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Summary of one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean.as_secs_f64() * 1e6
    }
}

/// Benchmark runner with configurable budget.
pub struct Bencher {
    pub warmup_iters: u32,
    pub min_iters: u32,
    pub max_iters: u32,
    pub target_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            target_time: Duration::from_secs(2),
        }
    }
}

impl Bencher {
    /// Quick profile for slow end-to-end cases.
    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            target_time: Duration::from_millis(800),
        }
    }

    /// Run `f`, black-boxing its output; prints a criterion-like line.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples: Vec<Duration> = Vec::new();
        let t_start = Instant::now();
        while (samples.len() < self.min_iters as usize)
            || (samples.len() < self.max_iters as usize && t_start.elapsed() < self.target_time)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let n = samples.len();
        let mean = samples.iter().sum::<Duration>() / n as u32;
        let result = BenchResult {
            iters: n as u32,
            mean,
            median: samples[n / 2],
            p95: samples[((n - 1) as f64 * 0.95) as usize],
            min: samples[0],
        };
        println!(
            "bench {name:<44} {:>10} mean  {:>10} median  {:>10} p95  ({} iters)",
            fmt_dur(result.mean),
            fmt_dur(result.median),
            fmt_dur(result.p95),
            n
        );
        result
    }
}

fn fmt_dur(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}us")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1000.0)
    } else {
        format!("{:.2}s", us / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_orders_stats() {
        let b = Bencher {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 10,
            target_time: Duration::from_millis(10),
        };
        let r = b.run("noop", || 1 + 1);
        assert!(r.iters >= 5);
        assert!(r.min <= r.median);
        assert!(r.median <= r.p95);
    }
}
