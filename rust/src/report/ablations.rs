//! Ablations for the design choices DESIGN.md calls out — the paper's §9
//! "Discussion" axes, made measurable.

use crate::fusion::tiles::band_heights;
use crate::fusion::CacheScheme;
use crate::graph::DagOptions;
use crate::model::ModelChain;
use crate::optimizer::Planner;
use crate::zoo;

use super::{kb, render};

/// §9 "Caching Paradigm": min peak RAM + F per DeFiNES cache scheme.
#[derive(Debug, Clone)]
pub struct SchemeRow {
    pub scheme: CacheScheme,
    /// Per paper model: (min peak RAM kB, overhead F).
    pub cells: Vec<(f64, f64)>,
}

pub fn ablation_cache_schemes() -> (Vec<SchemeRow>, String) {
    let models = zoo::paper_models();
    // One planner per model across the scheme sweep: same-scheme edge
    // costs come from the shared memo on every rebuild.
    let mut planners: Vec<Planner> =
        models.iter().map(|(_, m)| Planner::for_model(m.clone())).collect();
    let mut rows = Vec::new();
    for scheme in CacheScheme::ALL {
        let cells = planners
            .iter_mut()
            .map(|p| {
                p.set_dag_options(DagOptions::default().scheme(scheme));
                let plan = p.plan().expect("path");
                (kb(plan.cost().peak_ram), plan.cost().overhead)
            })
            .collect();
        rows.push(SchemeRow { scheme, cells });
    }
    let grid: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut v = vec![r.scheme.name().to_string()];
            for &(ram, f) in &r.cells {
                v.push(format!("{ram:.3}"));
                v.push(format!("{f:.2}"));
            }
            v
        })
        .collect();
    let text = format!(
        "Ablation (§9 caching paradigm): unconstrained min peak RAM per scheme\n{}",
        render(
            &["scheme", "MBV2 RAM", "F", "vww5 RAM", "F", "320K RAM", "F"],
            &grid
        )
    );
    (rows, text)
}

/// §9 "Parameter Space": the paper fixes output elements per iteration to
/// one; sweep the output rows per iteration for a representative fusion
/// block and show the buffer-vs-recompute trade-off it controls.
#[derive(Debug, Clone)]
pub struct GranularityRow {
    pub out_rows: u32,
    pub buf_bytes: u64,
    pub overhead: f64,
}

pub fn ablation_output_granularity(model: &ModelChain, a: usize, b: usize) -> (Vec<GranularityRow>, String) {
    let vanilla: u64 = (a..b).map(|i| model.layer_macs(i)).sum();
    let mut rows = Vec::new();
    for out_rows in [1u32, 2, 4, 8] {
        let t = band_heights(model, a, b, out_rows);
        // Buf with larger bands: each cached layer keeps its (clamped)
        // t_i × k_i × c_i strip — Eq. 11 with the wider tile.
        let buf: u64 = (1..b - a)
            .map(|idx| {
                let li = a + idx;
                let l = &model.layers[li];
                let inp = model.input_of(li);
                t[idx].min(inp.w + 2 * l.padding) as u64
                    * l.k as u64
                    * l.cin as u64
                    * model.elem_bytes as u64
            })
            .sum();
        // MACs: the band advances `out_rows × stride_product` input rows
        // per iteration, so fewer, taller bands => less vertical overlap
        // recomputed (Eq. 12 with a taller tile and larger tile stride).
        let sp = crate::fusion::stride_products(model, a, b);
        let macs: u64 = (0..b - a)
            .map(|idx| {
                let li = a + idx;
                let l = &model.layers[li];
                let inp = model.input_of(li);
                let out = model.output_of(li);
                let h = inp.h + 2 * l.padding;
                let t_i = t[idx].min(h);
                let step = (out_rows * sp[idx]).max(1);
                // ceil so partial bands at the bottom edge are counted...
                let n_vert = if h >= t_i { (h - t_i + step - 1) / step + 1 } else { 1 };
                let rows_per_band = (t_i - l.k) / l.stride + 1;
                // ...and never below full coverage (F >= 1 per layer).
                let rows_total =
                    (n_vert as u64 * rows_per_band as u64).max(out.h as u64);
                rows_total * out.w as u64 * out.c as u64 * l.macs_per_out_elem()
            })
            .sum();
        rows.push(GranularityRow {
            out_rows,
            buf_bytes: buf,
            overhead: macs as f64 / vanilla as f64,
        });
    }
    let grid: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.out_rows),
                format!("{}", r.buf_bytes),
                format!("{:.3}", r.overhead),
            ]
        })
        .collect();
    let text = format!(
        "Ablation (§9 parameter space): output rows/iteration for block [{a},{b}) of {}\n{}",
        model.name,
        render(&["out rows", "Buf bytes", "F (block)"], &grid)
    );
    (rows, text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_tradeoff_direction() {
        // DeFiNES: more caching => lower F; RAM minima move accordingly.
        let (rows, text) = ablation_cache_schemes();
        assert_eq!(rows.len(), 3);
        for model_idx in 0..3 {
            let f_fr = rows[0].cells[model_idx].1; // fully-recompute
            let f_hc = rows[1].cells[model_idx].1; // h-cache
            let f_fc = rows[2].cells[model_idx].1; // fully-cache
            assert!(f_fr >= f_hc - 1e-9, "model {model_idx}: {f_fr} < {f_hc}");
            assert!(f_hc >= f_fc - 1e-9, "model {model_idx}: {f_hc} < {f_fc}");
            // Fully-cache eliminates recompute entirely.
            assert!(f_fc <= 1.0 + 1e-9);
        }
        assert!(text.contains("fully-cache"));
    }

    #[test]
    fn granularity_tradeoff_direction() {
        // Taller iteration bands: bigger Buf, less vertical recompute.
        let m = zoo::quickstart();
        let (rows, _) = ablation_output_granularity(&m, 0, 3);
        for w in rows.windows(2) {
            assert!(w[1].buf_bytes >= w[0].buf_bytes, "Buf must grow with band height");
            assert!(
                w[1].overhead <= w[0].overhead + 1e-9,
                "recompute must shrink with band height: {} -> {}",
                w[0].overhead,
                w[1].overhead
            );
        }
        // out_rows=1 is the paper's working point; F > 1 there.
        assert!(rows[0].overhead > 1.0);
    }
}
