//! Table/figure renderers: regenerate every table and figure of the
//! paper's evaluation from the optimizer + MCU simulator.
//!
//! Each generator returns structured rows (testable) plus a
//! formatted-table `String` (what `msfcnn tables` and the benches print).

mod ablations;
mod figures;
mod profile;
mod tables;

pub use ablations::{
    ablation_cache_schemes, ablation_output_granularity, GranularityRow, SchemeRow,
};
pub use figures::{fig2_pooling, fig3_dense, fig4_series, FigRow};
pub use profile::{step_table, table_steps, top_k_table};
pub use tables::{
    table1, table2, table3, table5, table5_joint, Table1Row, Table2Row, Table3Row,
    Table5JointRow, Table5Row,
};

/// The constraint grids used throughout the paper's evaluation (§6.3).
pub const F_MAX_GRID: &[f64] = &[1.1, 1.2, 1.3, 1.4, 1.5, f64::INFINITY];
pub const P_MAX_GRID_KB: &[u64] = &[16, 32, 64, 128, 256];

/// kB with the paper's convention (1 kB = 1000 B, matching e.g.
/// "309.76 kB" = 309 760 B).
pub fn kb(bytes: u64) -> f64 {
    bytes as f64 / 1000.0
}

/// Render a grid of cells as an aligned text table.
pub fn render(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(c.len())))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let hdr: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kb_matches_paper_convention() {
        assert_eq!(kb(309_760), 309.76);
        assert_eq!(kb(96_000), 96.0);
    }

    #[test]
    fn render_aligns_columns() {
        let s = render(
            &["a", "bb"],
            &[vec!["x".into(), "y".into()], vec!["long".into(), "z".into()]],
        );
        assert!(s.contains("a     bb"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
    }
}
