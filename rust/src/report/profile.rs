//! Per-step attribution tables: render a [`StepProfile`] as the aligned
//! text table `msfcnn profile` and `msfcnn tables --which steps` print.

use crate::exec::CompiledPlan;
use crate::obs::{profile_plan, StepProfile};
use crate::ops::{ParamGen, Tensor};
use crate::optimizer::Planner;
use crate::zoo;

/// Render one profile as an aligned per-step table: execution order,
/// label, mean/p50/p95 latency, time share, MACs, and bytes touched.
/// Fused spans are followed by indented per-unit sub-rows (one per
/// block layer / tail stage) so the span's interior is attributable.
pub fn step_table(p: &StepProfile) -> String {
    let mut rows: Vec<Vec<String>> = Vec::new();
    for s in &p.steps {
        rows.push(vec![
            s.meta.index.to_string(),
            s.meta.label.clone(),
            s.meta.kind.to_string(),
            format!("{:.1}", s.mean_us),
            format!("{:.1}", s.p50_us),
            format!("{:.1}", s.p95_us),
            format!("{:.1}%", s.share * 100.0),
            s.macs.to_string(),
            s.meta.bytes.to_string(),
        ]);
        for u in &s.units {
            rows.push(vec![
                String::new(),
                format!("  - {}", u.label),
                "unit".to_string(),
                format!("{:.1}", u.mean_us),
                String::new(),
                String::new(),
                format!("{:.1}%", u.share * 100.0),
                u.macs.to_string(),
                String::new(),
            ]);
        }
    }
    let mut out = format!(
        "{} [{}] — {} runs, mean in-plan {:.1} us, {} MACs/run\n",
        p.model,
        p.setting,
        p.runs,
        p.total_mean_us,
        p.total_macs(),
    );
    out.push_str(&super::render(
        &["#", "step", "kind", "mean us", "p50 us", "p95 us", "share", "MACs", "bytes"],
        &rows,
    ));
    out
}

/// Render the top-`k` dominating steps of a profile, descending by mean
/// latency — the "where does the time go" summary under the full table.
pub fn top_k_table(p: &StepProfile, k: usize) -> String {
    let rows: Vec<Vec<String>> = p
        .top_k(k)
        .iter()
        .map(|s| {
            vec![
                s.meta.label.clone(),
                format!("{:.1}", s.mean_us),
                format!("{:.1}%", s.share * 100.0),
            ]
        })
        .collect();
    let mut out = format!("top {} steps by mean latency:\n", rows.len());
    out.push_str(&super::render(&["step", "mean us", "share"], &rows));
    out
}

/// Per-step attribution of a few small zoo models under their planned
/// default settings (the `msfcnn tables --which steps` view). Returns
/// the structured profiles plus the rendered tables.
pub fn table_steps() -> (Vec<StepProfile>, String) {
    let mut profiles = Vec::new();
    let mut out = String::new();
    for name in ["quickstart", "kws", "tiny"] {
        let model = zoo::by_name(name).expect("zoo model");
        let setting = Planner::for_model(model.clone()).setting().expect("plannable model");
        let compiled = CompiledPlan::compile(model, setting);
        let s = compiled.model().shapes[0];
        let x = Tensor::from_data(
            s.h as usize,
            s.w as usize,
            s.c as usize,
            ParamGen::new(7).fill(s.elems() as usize, 2.0),
        );
        let p = profile_plan(&compiled, &x, 12);
        out.push_str(&step_table(&p));
        out.push('\n');
        profiles.push(p);
    }
    (profiles, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_table_lists_every_step() {
        let (profiles, text) = table_steps();
        assert_eq!(profiles.len(), 3);
        for p in &profiles {
            assert!(text.contains(&p.model), "missing model header for {}", p.model);
            for s in &p.steps {
                assert!(text.contains(&s.meta.label), "missing step '{}'", s.meta.label);
                for u in &s.units {
                    let sub = format!("- {}", u.label);
                    assert!(text.contains(&sub), "missing unit row '{}'", u.label);
                }
            }
        }
    }

    #[test]
    fn top_k_table_is_bounded() {
        let (profiles, _) = table_steps();
        let t = top_k_table(&profiles[0], 2);
        // Header + table header + separator + at most 2 rows.
        assert!(t.lines().count() <= 5, "{t}");
    }
}
