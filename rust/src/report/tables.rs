//! Paper tables 1, 2, 3, 5.
//!
//! The constraint-grid tables (1 and 5) are generated through
//! [`PlanBatch`]: the whole model × budget grid is one parallel sweep
//! (bit-identical to the serial solves the rows used to make one by one).

use crate::mcu::{estimate_latency_ms, Board, BOARDS};
use crate::model::ModelChain;
use crate::optimizer::{
    strategy, Constraints, FusionSetting, PlanBatch, PlanJob, Planner, PlanObjective,
    PlanOutcome, PlanStrategy,
};
use crate::zoo;

use super::{kb, render, F_MAX_GRID, P_MAX_GRID_KB};

/// Row specs (section, constraint label, objective) for a grid table, and
/// the row-major `PlanBatch` outcomes for `models × specs`.
fn solve_grid(
    models: &[(&'static str, ModelChain)],
    specs: &[(&'static str, String, PlanObjective)],
) -> Vec<PlanOutcome> {
    let mut batch = PlanBatch::new();
    for (label, m) in models {
        batch.add_model(*label, m.clone());
    }
    for (_, _, objective) in specs {
        for mi in 0..models.len() {
            batch.push(PlanJob::new(mi, *objective));
        }
    }
    batch.solve()
}

fn grid_specs(with_streamnet: bool) -> Vec<(&'static str, String, PlanObjective)> {
    let mut specs: Vec<(&'static str, String, PlanObjective)> = vec![
        ("Vanilla", "-".into(), PlanObjective::Vanilla),
        ("Heuristic", "-".into(), PlanObjective::Heuristic),
    ];
    if with_streamnet {
        specs.push(("StreamNet", "-".into(), PlanObjective::StreamNet));
    }
    for &f_max in F_MAX_GRID {
        let label = if f_max.is_infinite() { "Inf".into() } else { format!("{f_max}") };
        specs.push(("P1: F_max", label, PlanObjective::MinRam { f_max }));
    }
    for &p_kb in P_MAX_GRID_KB {
        specs.push((
            "P2: P_max",
            format!("{p_kb} kB"),
            PlanObjective::MinMacs { p_max_bytes: p_kb * 1000 },
        ));
    }
    specs
}

/// One row of Table 1 (per model column pair).
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub section: &'static str,
    pub constraint: String,
    /// Per model: `Some((ram_kb, f))` or `None` for "(No Solution)".
    pub cells: Vec<Option<(f64, f64)>>,
}

/// Table 1: analytical optimizer results under the constraint grids, via
/// one parallel [`PlanBatch`] sweep.
pub fn table1() -> (Vec<Table1Row>, String) {
    let models = zoo::paper_models();
    let specs = grid_specs(false);
    let outcomes = solve_grid(&models, &specs);
    let n = models.len();

    let rows: Vec<Table1Row> = specs
        .iter()
        .enumerate()
        .map(|(ri, (section, constraint, _))| Table1Row {
            section: *section,
            constraint: constraint.clone(),
            cells: (0..n)
                .map(|mi| {
                    outcomes[ri * n + mi]
                        .setting
                        .as_ref()
                        .map(|s| (kb(s.cost.peak_ram), s.cost.overhead))
                })
                .collect(),
        })
        .collect();

    let mut grid = Vec::new();
    for r in &rows {
        let mut row = vec![r.section.to_string(), r.constraint.clone()];
        for c in &r.cells {
            match c {
                Some((ram, f)) => {
                    row.push(format!("{ram:.3}"));
                    row.push(format!("{f:.2}"));
                }
                None => {
                    row.push("(NoSol)".into());
                    row.push("-".into());
                }
            }
        }
        grid.push(row);
    }
    let headers = [
        "", "Constraint", "MBV2 RAM", "F", "vww5 RAM", "F", "320K RAM", "F",
    ];
    let text = format!("Table 1: analytical results (RAM in kB)\n{}", render(&headers, &grid));
    (rows, text)
}

/// One row of Table 2.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub method: &'static str,
    pub ram_kb: Vec<f64>,
}

/// Table 2: minimal peak RAM per method — one [`Planner`] per model, the
/// method column a [`PlanStrategy`] swap on the shared DAG/memo.
pub fn table2() -> (Vec<Table2Row>, String) {
    let models = zoo::paper_models();
    let mut planners: Vec<Planner> =
        models.iter().map(|(_, m)| Planner::for_model(m.clone())).collect();
    let mut method_row = |method: &'static str, s: &dyn PlanStrategy| -> Table2Row {
        Table2Row {
            method,
            ram_kb: planners
                .iter_mut()
                .map(|p| kb(p.plan_with(s, Constraints::none()).unwrap().cost().peak_ram))
                .collect(),
        }
    };

    let rows = vec![
        method_row("Vanilla", &strategy::Vanilla),
        Table2Row {
            // §10's scheduling-based family (TinyEngine/vMCU): pool reuse
            // without tiling — floor = largest I+O pair.
            method: "Memory planner",
            ram_kb: models
                .iter()
                .map(|(_, m)| kb(crate::memory::plan_pool(m).pool_bytes))
                .collect(),
        },
        method_row("MCUNetV2 (heuristic)", &strategy::HeadFusion),
        method_row("StreamNet (1 block)", &strategy::StreamNet),
        method_row("msf-CNN", &strategy::P1),
    ];

    let grid: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut v = vec![r.method.to_string()];
            v.extend(r.ram_kb.iter().map(|x| format!("{x:.3}")));
            v
        })
        .collect();
    let text = format!(
        "Table 2: minimal peak RAM (kB)\n{}",
        render(&["Fusion", "MBV2-w0.35", "MN2-vww5", "MN2-320K"], &grid)
    );
    (rows, text)
}

/// One row of Table 3.
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub board: &'static str,
    /// `Some(ms)` or `None` = OOM.
    pub latency_ms: Vec<Option<f64>>,
}

/// Table 3: latency of the min-RAM settings across the Table 4 boards
/// (OOM when the setting's peak RAM exceeds the board's RAM).
pub fn table3() -> (Vec<Table3Row>, String) {
    let models = zoo::paper_models();
    let settings: Vec<(ModelChain, FusionSetting)> = models
        .iter()
        .map(|(_, m)| {
            let s = Planner::for_model(m.clone()).setting().unwrap();
            (m.clone(), s)
        })
        .collect();

    let rows: Vec<Table3Row> = BOARDS
        .iter()
        .map(|b: &Board| Table3Row {
            board: b.name,
            latency_ms: settings
                .iter()
                .map(|(m, s)| {
                    if s.cost.peak_ram <= b.ram_bytes() {
                        Some(estimate_latency_ms(m, s, b).total_ms)
                    } else {
                        None
                    }
                })
                .collect(),
        })
        .collect();

    let grid: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut v = vec![r.board.to_string()];
            v.extend(r.latency_ms.iter().map(|c| match c {
                Some(ms) => format!("{ms:.1}"),
                None => "OOM".into(),
            }));
            v
        })
        .collect();
    let text = format!(
        "Table 3: inference time at minimal peak RAM (ms, simulated)\n{}",
        render(&["Board", "MBV2-w0.35", "MN2-vww5", "MN2-320K"], &grid)
    );
    (rows, text)
}

/// One row of Table 5 (f767zi trade-off table behind Fig. 4).
#[derive(Debug, Clone)]
pub struct Table5Row {
    pub section: &'static str,
    pub constraint: String,
    /// Per model: `Some((ram_kb, latency_ms))` or None.
    pub cells: Vec<Option<(f64, f64)>>,
}

/// Table 5: optimal settings on nucleo-f767zi (RAM kB, latency ms), via
/// one parallel [`PlanBatch`] sweep.
pub fn table5() -> (Vec<Table5Row>, String) {
    let board = crate::mcu::board_by_name("nucleo-f767zi").unwrap();
    let models = zoo::paper_models();
    let specs = grid_specs(true);
    let outcomes = solve_grid(&models, &specs);
    let n = models.len();

    let eval = |m: &ModelChain, s: &FusionSetting| -> (f64, f64) {
        (kb(s.cost.peak_ram), estimate_latency_ms(m, s, board).total_ms)
    };

    let rows: Vec<Table5Row> = specs
        .iter()
        .enumerate()
        .map(|(ri, (section, constraint, _))| Table5Row {
            // Table 5 uses the paper's method names for its sections.
            section: match *section {
                "Heuristic" => "MCUNetV2",
                "P1: F_max" => "P1",
                "P2: P_max" => "P2",
                other => other,
            },
            constraint: constraint.clone(),
            cells: (0..n)
                .map(|mi| {
                    outcomes[ri * n + mi]
                        .setting
                        .as_ref()
                        .map(|s| eval(&models[mi].1, s))
                })
                .collect(),
        })
        .collect();

    let grid: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut v = vec![r.section.to_string(), r.constraint.clone()];
            for c in &r.cells {
                match c {
                    Some((ram, ms)) => {
                        v.push(format!("{ram:.3}"));
                        v.push(format!("{ms:.1}"));
                    }
                    None => {
                        v.push("(NoSol)".into());
                        v.push("-".into());
                    }
                }
            }
            v
        })
        .collect();
    let headers = [
        "", "Constraint", "MBV2 RAM", "ms", "vww5 RAM", "ms", "320K RAM", "ms",
    ];
    let text = format!(
        "Table 5: optimal fusion settings on nucleo-f767zi (RAM kB, latency ms, simulated)\n{}",
        render(&headers, &grid)
    );
    (rows, text)
}

/// One row of the joint-constraint Table 5 companion: a method under a
/// (board RAM, latency budget) pair.
#[derive(Debug, Clone)]
pub struct Table5JointRow {
    pub method: &'static str,
    /// Latency budget as a multiple of the model's vanilla latency.
    pub factor: f64,
    /// Per model: the absolute budget in ms that factor denotes.
    pub budgets_ms: Vec<f64>,
    /// Per model: `Some((ram_kb, latency_ms))` or `None` (infeasible
    /// under the joint budget).
    pub cells: Vec<Option<(f64, f64)>>,
}

/// Table 5 under **joint** budgets on nucleo-f767zi: peak RAM capped by
/// the board's physical RAM *and* estimated latency capped at a multiple
/// of each model's vanilla latency. The msf-CNN rows are the
/// [`strategy::LatencyAware`] walk (solved through one parallel
/// [`PlanBatch`] sweep via [`PlanObjective::MinRamLatency`]); the
/// baseline rows run MCUNetV2-style head fusion and StreamNet under the
/// identical constraint set, so the paper's msf-vs-baseline trade-off is
/// reproducible end-to-end on both axes at once.
pub fn table5_joint() -> (Vec<Table5JointRow>, String) {
    let board = crate::mcu::board_by_name("nucleo-f767zi").unwrap();
    let models = zoo::paper_models();
    let factors = [1.5, 3.0, 10.0];
    let n = models.len();

    // Vanilla latency per model sets the budget scale; the planners are
    // reused for the baseline solves (shared DAG + memo per model).
    let mut planners: Vec<Planner> =
        models.iter().map(|(_, m)| Planner::for_model(m.clone())).collect();
    let vanilla_ms: Vec<f64> = planners
        .iter_mut()
        .zip(&models)
        .map(|(p, (_, m))| {
            let s = p.plan_with(&strategy::Vanilla, Constraints::none()).unwrap().setting;
            estimate_latency_ms(m, &s, board).total_ms
        })
        .collect();
    let eval = |mi: usize, s: &FusionSetting| -> (f64, f64) {
        (kb(s.cost.peak_ram), estimate_latency_ms(&models[mi].1, s, board).total_ms)
    };

    // msf-CNN rows: one batch, factor-major × model-minor.
    let mut batch = PlanBatch::new();
    let idx: Vec<usize> = models
        .iter()
        .map(|(label, m)| batch.add_model(*label, m.clone()))
        .collect();
    for &factor in &factors {
        for (mi, &i) in idx.iter().enumerate() {
            batch.push(PlanJob::new(
                i,
                PlanObjective::MinRamLatency {
                    board,
                    budget_ms: vanilla_ms[mi] * factor,
                    p_max_bytes: Some(board.ram_bytes()),
                },
            ));
        }
    }
    let outcomes = batch.solve();

    let mut rows: Vec<Table5JointRow> = Vec::new();
    for (fi, &factor) in factors.iter().enumerate() {
        rows.push(Table5JointRow {
            method: "msf-CNN (latency-aware)",
            factor,
            budgets_ms: vanilla_ms.iter().map(|v| v * factor).collect(),
            cells: (0..n)
                .map(|mi| outcomes[fi * n + mi].setting.as_ref().map(|s| eval(mi, s)))
                .collect(),
        });
    }

    // Baselines under the identical joint constraint set (the uniform
    // `admit` filter enforces both axes behind the trait).
    let baselines: [(&'static str, &dyn PlanStrategy); 2] = [
        ("MCUNetV2", &strategy::HeadFusion),
        ("StreamNet", &strategy::StreamNet),
    ];
    for (method, s) in baselines {
        for &factor in &factors {
            let cells = (0..n)
                .map(|mi| {
                    let c = Constraints::none()
                        .with(crate::optimizer::Constraint::Ram(board.ram_bytes()))
                        .with(crate::optimizer::Constraint::LatencyMs {
                            board,
                            budget: vanilla_ms[mi] * factor,
                        });
                    planners[mi].plan_with(s, c).ok().map(|p| eval(mi, &p.setting))
                })
                .collect();
            rows.push(Table5JointRow {
                method,
                factor,
                budgets_ms: vanilla_ms.iter().map(|v| v * factor).collect(),
                cells,
            });
        }
    }

    let grid: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut v = vec![r.method.to_string(), format!("{}x vanilla", r.factor)];
            for c in &r.cells {
                match c {
                    Some((ram, ms)) => {
                        v.push(format!("{ram:.3}"));
                        v.push(format!("{ms:.1}"));
                    }
                    None => {
                        v.push("(NoSol)".into());
                        v.push("-".into());
                    }
                }
            }
            v
        })
        .collect();
    let headers = [
        "", "Latency budget", "MBV2 RAM", "ms", "vww5 RAM", "ms", "320K RAM", "ms",
    ];
    let text = format!(
        "Table 5 (joint): min peak RAM under RAM<=board AND latency budget, \
         nucleo-f767zi (RAM kB, latency ms, simulated)\n{}",
        render(&headers, &grid)
    );
    (rows, text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_msf_dominates() {
        let (rows, text) = table2();
        assert_eq!(rows.len(), 5);
        let vanilla = &rows[0].ram_kb;
        let planner = &rows[1].ram_kb;
        let msf = &rows[4].ram_kb;
        for i in 0..3 {
            // Paper: msf-CNN cuts >=50% vs prior art; certainly vs vanilla.
            assert!(msf[i] < vanilla[i] * 0.5, "model {i}: {} vs {}", msf[i], vanilla[i]);
            // And beats the single-block baselines and the §10 planner.
            assert!(msf[i] <= rows[2].ram_kb[i]);
            assert!(msf[i] <= rows[3].ram_kb[i]);
            assert!(msf[i] < planner[i] * 0.5, "planner floor stands");
            // The planner cannot go below the vanilla I+O floor.
            assert!(planner[i] <= vanilla[i] + 1e-9);
        }
        assert!(text.contains("msf-CNN"));
        assert!(text.contains("Memory planner"));
    }

    #[test]
    fn table1_constraints_hold() {
        let (rows, _) = table1();
        for r in &rows {
            if r.section == "P1: F_max" {
                if let Ok(f_max) = r.constraint.parse::<f64>() {
                    for c in r.cells.iter().flatten() {
                        assert!(c.1 <= f_max + 1e-9, "{}: F {} > {}", r.constraint, c.1, f_max);
                    }
                }
            }
            if r.section == "P2: P_max" {
                let p: f64 = r.constraint.trim_end_matches(" kB").parse().unwrap();
                for c in r.cells.iter().flatten() {
                    assert!(c.0 <= p + 1e-9, "{}: RAM {} > {}", r.constraint, c.0, p);
                }
            }
        }
    }

    #[test]
    fn table3_has_oom_on_hifive() {
        let (rows, _) = table3();
        let hifive = rows.iter().find(|r| r.board == "hifive1b").unwrap();
        // The 16 kB board cannot hold the larger models' min-RAM settings
        // (paper Table 3 reports OOM for MN2-vww5 / MN2-320K there).
        assert!(hifive.latency_ms.iter().any(|c| c.is_none()));
        let f767 = rows.iter().find(|r| r.board == "nucleo-f767zi").unwrap();
        assert!(f767.latency_ms.iter().all(|c| c.is_some()));
    }

    #[test]
    fn table5_joint_budgets_hold_and_msf_dominates() {
        let (rows, text) = table5_joint();
        assert_eq!(rows.len(), 9, "3 methods x 3 latency factors");
        for r in &rows {
            for (mi, c) in r.cells.iter().enumerate() {
                if let Some((ram_kb, ms)) = c {
                    // Joint feasibility: both axes hold on every cell.
                    assert!(*ram_kb * 1000.0 <= 512.0 * 1024.0 + 1e-6, "{}: {ram_kb}", r.method);
                    assert!(
                        *ms <= r.budgets_ms[mi] * (1.0 + 1e-9) + 1e-9,
                        "{} factor {}: {ms} > {}",
                        r.method,
                        r.factor,
                        r.budgets_ms[mi]
                    );
                }
            }
        }
        let msf: Vec<&Table5JointRow> =
            rows.iter().filter(|r| r.method.starts_with("msf")).collect();
        for baseline in rows.iter().filter(|r| !r.method.starts_with("msf")) {
            let msf_row = msf
                .iter()
                .find(|r| r.factor == baseline.factor)
                .expect("matching msf row");
            for (mi, cell) in baseline.cells.iter().enumerate() {
                if let Some((base_ram, _)) = cell {
                    // The DAG walk searches a superset of every baseline's
                    // settings: feasible wherever they are, never worse on RAM.
                    let (msf_ram, _) = msf_row.cells[mi]
                        .expect("msf feasible wherever a baseline is");
                    assert!(
                        msf_ram <= base_ram + 1e-9,
                        "{} beat msf at factor {}",
                        baseline.method,
                        baseline.factor
                    );
                }
            }
        }
        // Looser budgets never lose feasibility.
        for w in msf.windows(2) {
            for mi in 0..3 {
                if w[0].cells[mi].is_some() {
                    assert!(w[1].cells[mi].is_some(), "feasibility must be monotone in budget");
                }
            }
        }
        assert!(text.contains("joint"), "{text}");
    }

    #[test]
    fn table5_ram_budget_monotone_latency() {
        // §8.2: higher RAM budgets -> shorter latency (P2 section).
        let (rows, _) = table5();
        let p2: Vec<&Table5Row> = rows.iter().filter(|r| r.section == "P2").collect();
        for model_idx in 0..3 {
            let lat: Vec<f64> = p2
                .iter()
                .filter_map(|r| r.cells[model_idx].map(|c| c.1))
                .collect();
            for w in lat.windows(2) {
                assert!(
                    w[1] <= w[0] * 1.001,
                    "latency should not increase with budget: {lat:?}"
                );
            }
        }
    }
}
