//! Paper figures 2, 3, 4 (as data series / CSV).

use crate::mcu::{board_by_name, estimate_latency_ms};
use crate::optimizer::{strategy, Constraint, Constraints, Planner};
use crate::zoo;

use super::{kb, render, F_MAX_GRID, P_MAX_GRID_KB};

/// Generic (label, x, y) figure point.
#[derive(Debug, Clone)]
pub struct FigRow {
    pub label: String,
    pub x: f64,
    pub y: f64,
}

/// Fig. 2: common vs iterative global pooling RAM over map sizes.
/// Returns (rows, text); `y` = live bytes, two series per size.
pub fn fig2_pooling() -> (Vec<FigRow>, String) {
    let mut rows = Vec::new();
    let mut grid = Vec::new();
    for (h, c) in [(4u64, 64u64), (7, 64), (7, 448), (14, 160)] {
        // Element counts (dtype-agnostic), matching the paper's "2% of the
        // original" framing: the whole resident map vs the accumulator
        // (the streamed rows come from the upstream fusion block).
        let common = h * h * c; // full H×W×C map resident
        let iterative = c; // C-sized running accumulator
        rows.push(FigRow { label: format!("common {h}x{h}x{c}"), x: (h * h * c) as f64, y: common as f64 });
        rows.push(FigRow { label: format!("iter {h}x{h}x{c}"), x: (h * h * c) as f64, y: iterative as f64 });
        grid.push(vec![
            format!("{h}x{h}x{c}"),
            format!("{common}"),
            format!("{iterative}"),
            format!("{:.1}%", 100.0 * iterative as f64 / common as f64),
        ]);
    }
    let text = format!(
        "Fig 2: global pooling RAM, common vs iterative (bytes)\n{}",
        render(&["map", "common", "iterative", "ratio"], &grid)
    );
    (rows, text)
}

/// Fig. 3: common vs iterative dense RAM over layer sizes.
pub fn fig3_dense() -> (Vec<FigRow>, String) {
    let mut rows = Vec::new();
    let mut grid = Vec::new();
    for (din, dout) in [(256u64, 64u64), (1024, 256), (448, 1000), (160, 2)] {
        // Element counts: common holds the full input vector + output;
        // iterative holds the accumulator + the current input element
        // (paper: 1024→256 compresses to 20% = 256/1280).
        let common = din + dout;
        let iterative = dout + 1;
        rows.push(FigRow { label: format!("common {din}->{dout}"), x: din as f64, y: common as f64 });
        rows.push(FigRow { label: format!("iter {din}->{dout}"), x: din as f64, y: iterative as f64 });
        grid.push(vec![
            format!("{din}->{dout}"),
            format!("{common}"),
            format!("{iterative}"),
            format!("{:.1}%", 100.0 * iterative as f64 / common as f64),
        ]);
    }
    let text = format!(
        "Fig 3: dense layer RAM, common vs iterative (bytes, f32 activations)\n{}",
        render(&["layer", "common", "iterative", "ratio"], &grid)
    );
    (rows, text)
}

/// Fig. 4: RAM–latency trade-off on nucleo-f767zi. Returns per-model
/// series (P1 sweep + P2 sweep) and a CSV string.
pub fn fig4_series() -> (Vec<FigRow>, String) {
    let board = board_by_name("nucleo-f767zi").unwrap();
    let mut rows = Vec::new();
    let mut csv = String::from("model,problem,constraint,ram_kb,latency_ms\n");

    for (label, model) in zoo::paper_models() {
        // One planner per model: both constraint sweeps share its DAG and
        // edge-cost memo.
        let mut planner = Planner::for_model(model.clone());
        for &f_max in F_MAX_GRID {
            let c = Constraints::none().with(Constraint::Overhead(f_max));
            if let Ok(p) = planner.plan_with(&strategy::P1, c) {
                let s = &p.setting;
                let lat = estimate_latency_ms(&model, s, board).total_ms;
                rows.push(FigRow {
                    label: format!("{label}/P1"),
                    x: kb(s.cost.peak_ram),
                    y: lat,
                });
                csv.push_str(&format!(
                    "{label},P1,{f_max},{:.3},{lat:.1}\n",
                    kb(s.cost.peak_ram)
                ));
            }
        }
        for &p_kb in P_MAX_GRID_KB {
            let c = Constraints::none().with(Constraint::Ram(p_kb * 1000));
            if let Ok(p) = planner.plan_with(&strategy::P2, c) {
                let s = &p.setting;
                let lat = estimate_latency_ms(&model, s, board).total_ms;
                rows.push(FigRow {
                    label: format!("{label}/P2"),
                    x: kb(s.cost.peak_ram),
                    y: lat,
                });
                csv.push_str(&format!(
                    "{label},P2,{p_kb}kB,{:.3},{lat:.1}\n",
                    kb(s.cost.peak_ram)
                ));
            }
        }
    }
    (rows, csv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_paper_ratio_7x7() {
        // Paper: 7x7 pooling compresses to ~2% of the original.
        let (rows, _) = fig2_pooling();
        let common = rows.iter().find(|r| r.label == "common 7x7x448").unwrap();
        let iter = rows.iter().find(|r| r.label == "iter 7x7x448").unwrap();
        let ratio = iter.y / common.y;
        assert!(ratio < 0.05, "ratio {ratio}");
    }

    #[test]
    fn fig3_paper_ratio_1024_256() {
        // Paper: 1024->256 dense compresses to ~20%.
        let (rows, _) = fig3_dense();
        let common = rows.iter().find(|r| r.label == "common 1024->256").unwrap();
        let iter = rows.iter().find(|r| r.label == "iter 1024->256").unwrap();
        let ratio = iter.y / common.y;
        assert!((0.15..0.25).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn fig4_tradeoff_direction() {
        // Across each model's P1 series, lower RAM should pair with
        // higher latency at the extremes.
        let (rows, csv) = fig4_series();
        assert!(csv.lines().count() > 10);
        for (label, _) in zoo::paper_models() {
            let series: Vec<&FigRow> = rows
                .iter()
                .filter(|r| r.label == format!("{label}/P1"))
                .collect();
            if series.len() >= 2 {
                let first = series.first().unwrap(); // loosest F in grid order
                let last = series.last().unwrap(); // F = inf
                assert!(last.x <= first.x, "{label}: RAM should shrink");
                assert!(last.y >= first.y, "{label}: latency should grow");
            }
        }
    }
}
