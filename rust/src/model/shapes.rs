//! Tensor shape arithmetic (HWC, single-image inference).

use std::fmt;

/// Spatial+channel shape of a boundary tensor. `h == w == 1` for vectors
/// (post-global-pool / dense activations).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TensorShape {
    pub h: u32,
    pub w: u32,
    pub c: u32,
}

impl TensorShape {
    pub const fn new(h: u32, w: u32, c: u32) -> Self {
        Self { h, w, c }
    }

    /// Vector shape (1×1×d) for dense activations.
    pub const fn vec(d: u32) -> Self {
        Self { h: 1, w: 1, c: d }
    }

    pub fn elems(&self) -> u64 {
        self.h as u64 * self.w as u64 * self.c as u64
    }

    /// Output spatial size of a window op: `floor((n + 2p - k)/s) + 1`.
    pub fn conv_out(n: u32, k: u32, stride: u32, padding: u32) -> Option<u32> {
        let padded = n + 2 * padding;
        if padded < k || stride == 0 {
            return None;
        }
        Some((padded - k) / stride + 1)
    }
}

impl fmt::Display for TensorShape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}x{}", self.h, self.w, self.c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_out_basic() {
        assert_eq!(TensorShape::conv_out(32, 3, 1, 0), Some(30));
        assert_eq!(TensorShape::conv_out(32, 3, 2, 1), Some(16));
        assert_eq!(TensorShape::conv_out(2, 3, 1, 0), None);
        assert_eq!(TensorShape::conv_out(3, 3, 1, 0), Some(1));
    }

    #[test]
    fn elems_and_vec() {
        assert_eq!(TensorShape::new(4, 5, 6).elems(), 120);
        assert_eq!(TensorShape::vec(10).elems(), 10);
    }
}
