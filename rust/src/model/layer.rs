//! Layer definitions: the operator vocabulary of the paper's model zoo
//! (MobileNetV2 / MCUNet family) plus the pooling/dense tail.

use super::TensorShape;

/// Pointwise nonlinearity applied after a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    None,
    Relu,
    Relu6,
}

/// Operator kind. `streamable()` kinds can join a patch-based fusion block
/// (they consume a bounded spatial window per output element).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// Standard convolution (`k×k×cin` per output element).
    Conv2d,
    /// Depthwise convolution (`k×k` per output element, cin == cout).
    DwConv2d,
    /// Average pooling window.
    AvgPool,
    /// Max pooling window.
    MaxPool,
    /// Global average pooling (HW→1). Rewritten to iterative form (§7).
    GlobalAvgPool,
    /// Fully connected. Rewritten to iterative form (§7).
    Dense,
}

impl LayerKind {
    /// Whether the op can live inside a patch-based fusion block.
    pub fn streamable(self) -> bool {
        matches!(
            self,
            LayerKind::Conv2d | LayerKind::DwConv2d | LayerKind::AvgPool | LayerKind::MaxPool
        )
    }
}

/// One layer of the chain. Spatial params are meaningless (set to 1/0) for
/// `GlobalAvgPool` and `Dense`.
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub k: u32,
    pub stride: u32,
    pub padding: u32,
    pub cin: u32,
    pub cout: u32,
    pub act: Activation,
    /// `Some(j)` ⇒ the *input* tensor of layer `j` is added to this layer's
    /// output (MobileNetV2 inverted-residual skip).
    pub residual_from: Option<usize>,
}

impl Layer {
    pub fn conv(
        name: impl Into<String>,
        k: u32,
        stride: u32,
        padding: u32,
        cin: u32,
        cout: u32,
        act: Activation,
    ) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::Conv2d,
            k,
            stride,
            padding,
            cin,
            cout,
            act,
            residual_from: None,
        }
    }

    pub fn dwconv(
        name: impl Into<String>,
        k: u32,
        stride: u32,
        padding: u32,
        c: u32,
        act: Activation,
    ) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::DwConv2d,
            k,
            stride,
            padding,
            cin: c,
            cout: c,
            act,
            residual_from: None,
        }
    }

    /// 1×1 (pointwise) convolution — the expand/project ops of MBV2 blocks.
    pub fn pointwise(name: impl Into<String>, cin: u32, cout: u32, act: Activation) -> Self {
        Self::conv(name, 1, 1, 0, cin, cout, act)
    }

    pub fn avg_pool(name: impl Into<String>, k: u32, stride: u32, c: u32) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::AvgPool,
            k,
            stride,
            padding: 0,
            cin: c,
            cout: c,
            act: Activation::None,
            residual_from: None,
        }
    }

    pub fn max_pool(name: impl Into<String>, k: u32, stride: u32, c: u32) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::MaxPool,
            k,
            stride,
            padding: 0,
            cin: c,
            cout: c,
            act: Activation::None,
            residual_from: None,
        }
    }

    pub fn global_pool(name: impl Into<String>, c: u32) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::GlobalAvgPool,
            k: 1,
            stride: 1,
            padding: 0,
            cin: c,
            cout: c,
            act: Activation::None,
            residual_from: None,
        }
    }

    pub fn dense(name: impl Into<String>, din: u32, dout: u32) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::Dense,
            k: 1,
            stride: 1,
            padding: 0,
            cin: din,
            cout: dout,
            act: Activation::None,
            residual_from: None,
        }
    }

    pub fn with_residual(mut self, from: usize) -> Self {
        self.residual_from = Some(from);
        self
    }

    /// Shape inference; `Err` when the layer cannot consume `input`.
    pub fn output_shape(&self, input: TensorShape) -> Result<TensorShape, String> {
        if input.c != self.cin && !matches!(self.kind, LayerKind::Dense) {
            return Err(format!(
                "channel mismatch: input c={} but layer cin={}",
                input.c, self.cin
            ));
        }
        match self.kind {
            LayerKind::Conv2d | LayerKind::DwConv2d | LayerKind::AvgPool | LayerKind::MaxPool => {
                let h = TensorShape::conv_out(input.h, self.k, self.stride, self.padding)
                    .ok_or_else(|| format!("spatial underflow: h={} k={}", input.h, self.k))?;
                let w = TensorShape::conv_out(input.w, self.k, self.stride, self.padding)
                    .ok_or_else(|| format!("spatial underflow: w={} k={}", input.w, self.k))?;
                Ok(TensorShape::new(h, w, self.cout))
            }
            LayerKind::GlobalAvgPool => Ok(TensorShape::vec(self.cout)),
            LayerKind::Dense => {
                if input.elems() != self.cin as u64 {
                    return Err(format!(
                        "dense input elems {} != cin {}",
                        input.elems(),
                        self.cin
                    ));
                }
                Ok(TensorShape::vec(self.cout))
            }
        }
    }

    /// MACs per output element for this op.
    pub fn macs_per_out_elem(&self) -> u64 {
        match self.kind {
            LayerKind::Conv2d => self.k as u64 * self.k as u64 * self.cin as u64,
            LayerKind::DwConv2d => self.k as u64 * self.k as u64,
            // Pooling adds, counted as 1 op per window element (the paper
            // counts conv MACs; pools are negligible but nonzero).
            LayerKind::AvgPool | LayerKind::MaxPool => self.k as u64 * self.k as u64,
            LayerKind::GlobalAvgPool => 1, // one add per input element, per channel amortized
            LayerKind::Dense => self.cin as u64,
        }
    }

    /// Vanilla MAC count of this layer for given input/output shapes.
    pub fn macs(&self, input: TensorShape, output: TensorShape) -> u64 {
        match self.kind {
            LayerKind::GlobalAvgPool => input.elems(),
            _ => output.elems() * self.macs_per_out_elem(),
        }
    }

    /// Bytes of parameters (int8 weights + 4-byte bias per cout), for flash
    /// footprint and the refetch term of the MCU latency model.
    pub fn param_bytes(&self) -> u64 {
        let weights = match self.kind {
            LayerKind::Conv2d => self.k as u64 * self.k as u64 * self.cin as u64 * self.cout as u64,
            LayerKind::DwConv2d => self.k as u64 * self.k as u64 * self.cin as u64,
            LayerKind::Dense => self.cin as u64 * self.cout as u64,
            _ => 0,
        };
        let bias = match self.kind {
            LayerKind::Conv2d | LayerKind::DwConv2d | LayerKind::Dense => 4 * self.cout as u64,
            _ => 0,
        };
        weights + bias
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape_and_macs() {
        let l = Layer::conv("c", 3, 2, 1, 3, 16, Activation::Relu6);
        let out = l.output_shape(TensorShape::new(32, 32, 3)).unwrap();
        assert_eq!(out, TensorShape::new(16, 16, 16));
        assert_eq!(l.macs(TensorShape::new(32, 32, 3), out), 16 * 16 * 16 * 27);
    }

    #[test]
    fn dwconv_preserves_channels() {
        let l = Layer::dwconv("d", 3, 1, 1, 8, Activation::Relu6);
        let out = l.output_shape(TensorShape::new(10, 10, 8)).unwrap();
        assert_eq!(out, TensorShape::new(10, 10, 8));
        assert_eq!(l.macs_per_out_elem(), 9);
    }

    #[test]
    fn channel_mismatch_rejected() {
        let l = Layer::conv("c", 3, 1, 0, 4, 8, Activation::None);
        assert!(l.output_shape(TensorShape::new(8, 8, 3)).is_err());
    }

    #[test]
    fn dense_elems_checked() {
        let l = Layer::dense("fc", 32, 10);
        assert!(l.output_shape(TensorShape::vec(32)).is_ok());
        assert!(l.output_shape(TensorShape::vec(33)).is_err());
    }

    #[test]
    fn pointwise_is_1x1_conv() {
        let l = Layer::pointwise("pw", 8, 16, Activation::None);
        assert_eq!(l.k, 1);
        let out = l.output_shape(TensorShape::new(5, 5, 8)).unwrap();
        assert_eq!(out, TensorShape::new(5, 5, 16));
    }

    #[test]
    fn param_bytes() {
        let l = Layer::conv("c", 3, 1, 0, 4, 8, Activation::None);
        assert_eq!(l.param_bytes(), 3 * 3 * 4 * 8 + 4 * 8);
        let d = Layer::dwconv("d", 3, 1, 1, 8, Activation::None);
        assert_eq!(d.param_bytes(), 3 * 3 * 8 + 4 * 8);
    }
}
