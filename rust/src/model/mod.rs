//! CNN model intermediate representation.
//!
//! msf-CNN operates on the *chain view* of a CNN (paper §4: "without loss
//! of generality, we only discuss fusion blocks of convolutions"): an
//! ordered list of layers `L0..L{n-1}` with tensor boundaries `v0..vn`.
//! [`ModelChain`] owns the layers and the inferred boundary shapes; the
//! fusion analytics ([`crate::fusion`]) and the DAG builder
//! ([`crate::graph`]) consume it.

mod layer;
mod shapes;

pub use layer::{Activation, Layer, LayerKind};
pub use shapes::TensorShape;

/// A CNN as an ordered layer chain with inferred tensor boundaries.
///
/// `shapes[i]` is the input tensor of `layers[i]`; `shapes[n]` is the model
/// output. Residual (skip) connections are carried as an attribute on the
/// consuming layer (`Layer::residual_from`) — the chain order is still the
/// execution order, matching how the paper's models (MobileNetV2 family)
/// linearize.
#[derive(Debug, Clone)]
pub struct ModelChain {
    pub name: String,
    pub layers: Vec<Layer>,
    pub shapes: Vec<TensorShape>,
    /// Bytes per tensor element (1 = int8 quantized, the TinyML default).
    pub elem_bytes: u32,
}

impl ModelChain {
    /// Build a chain from an input shape and layer list, inferring every
    /// boundary shape. Panics if a layer is inconsistent with its input
    /// (catching zoo construction bugs early).
    pub fn new(name: impl Into<String>, input: TensorShape, layers: Vec<Layer>) -> Self {
        let mut shapes = Vec::with_capacity(layers.len() + 1);
        shapes.push(input);
        for (i, layer) in layers.iter().enumerate() {
            let inp = *shapes.last().unwrap();
            let out = layer
                .output_shape(inp)
                .unwrap_or_else(|e| panic!("layer {i} ({}): {e}", layer.name));
            shapes.push(out);
        }
        Self { name: name.into(), layers, shapes, elem_bytes: 1 }
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Input tensor shape of layer `i`.
    pub fn input_of(&self, i: usize) -> TensorShape {
        self.shapes[i]
    }

    /// Output tensor shape of layer `i`.
    pub fn output_of(&self, i: usize) -> TensorShape {
        self.shapes[i + 1]
    }

    /// Size in bytes of boundary tensor `v_i` (int8-quantized by default).
    pub fn tensor_bytes(&self, i: usize) -> u64 {
        self.shapes[i].elems() * self.elem_bytes as u64
    }

    /// MAC count of a single (unfused, *vanilla*) layer.
    pub fn layer_macs(&self, i: usize) -> u64 {
        self.layers[i].macs(self.shapes[i], self.shapes[i + 1])
    }

    /// Total vanilla MACs for a full inference.
    pub fn total_macs(&self) -> u64 {
        (0..self.layers.len()).map(|i| self.layer_macs(i)).sum()
    }

    /// Vanilla peak RAM (bytes): max over layers of input+output (+residual
    /// stash), the paper's un-fused baseline.
    pub fn vanilla_peak_ram(&self) -> u64 {
        (0..self.layers.len())
            .map(|i| {
                self.tensor_bytes(i)
                    + self.tensor_bytes(i + 1)
                    + self.residual_stash_bytes(i)
            })
            .max()
            .unwrap_or(0)
    }

    /// Extra bytes held live across layer `i` because a later layer adds a
    /// skip connection whose source tensor spans `i`.
    pub fn residual_stash_bytes(&self, i: usize) -> u64 {
        self.layers
            .iter()
            .enumerate()
            .filter_map(|(j, l)| l.residual_from.map(|src| (j, src)))
            .filter(|&(j, src)| src < i && i <= j)
            .map(|(_, src)| self.tensor_bytes(src))
            .sum()
    }

    /// Whether layers `[a, b)` may form a fusion block: all spatially
    /// streamable (conv / depthwise / pool), at least 2 layers, and no skip
    /// connection crossing into or out of the span.
    pub fn fusable_span(&self, a: usize, b: usize) -> bool {
        if b <= a + 1 || b > self.layers.len() {
            return false;
        }
        if !self.layers[a..b].iter().all(|l| l.kind.streamable()) {
            return false;
        }
        // A skip edge (src -> j) must lie entirely inside or outside [a, b).
        for (j, l) in self.layers.iter().enumerate() {
            if let Some(src) = l.residual_from {
                let j_in = a <= j && j < b;
                // The stashed tensor is the *input* of layer src.
                let src_in = a < src && src < b || (src == a && j_in && j < b);
                let src_inside = a <= src && src < b;
                if j_in != src_inside {
                    return false;
                }
                let _ = (j_in, src_in);
            }
        }
        true
    }

    /// True if the model tail after boundary `t` is exactly
    /// `[GlobalPool, Dense*]` — the pattern the paper rewrites into
    /// iterative form (§7) so it fuses with an upstream fusion block.
    pub fn iterative_tail_at(&self, t: usize) -> bool {
        if t >= self.layers.len() {
            return false;
        }
        matches!(self.layers[t].kind, LayerKind::GlobalAvgPool)
            && self.layers[t + 1..]
                .iter()
                .all(|l| matches!(l.kind, LayerKind::Dense))
    }

    /// Human-readable one-line summary per layer (for `msfcnn zoo`).
    pub fn describe(&self) -> String {
        let mut s = String::new();
        for (i, l) in self.layers.iter().enumerate() {
            let inp = self.shapes[i];
            let out = self.shapes[i + 1];
            s.push_str(&format!(
                "{i:3}  {:<24} {:>12} -> {:<12} k={} s={} p={}{}\n",
                l.name,
                inp.to_string(),
                out.to_string(),
                l.k,
                l.stride,
                l.padding,
                l.residual_from.map(|r| format!("  +skip(v{r})")).unwrap_or_default(),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelChain {
        ModelChain::new(
            "tiny",
            TensorShape::new(8, 8, 3),
            vec![
                Layer::conv("c0", 3, 1, 0, 3, 4, Activation::Relu6),
                Layer::conv("c1", 3, 1, 0, 4, 8, Activation::Relu6),
                Layer::global_pool("gp", 8),
                Layer::dense("fc", 8, 10),
            ],
        )
    }

    #[test]
    fn shape_inference_chains() {
        let m = tiny();
        assert_eq!(m.shapes[1], TensorShape::new(6, 6, 4));
        assert_eq!(m.shapes[2], TensorShape::new(4, 4, 8));
        assert_eq!(m.shapes[3], TensorShape::new(1, 1, 8));
        assert_eq!(m.shapes[4], TensorShape::new(1, 1, 10));
    }

    #[test]
    fn vanilla_peak_is_max_io_pair() {
        let m = tiny();
        // v0=192, v1=144, v2=128, v3=8, v4=10 bytes (int8).
        assert_eq!(m.vanilla_peak_ram(), 192 + 144);
    }

    #[test]
    fn macs_of_conv() {
        let m = tiny();
        // c0: 6*6*4 outputs, each k^2*cin = 27 MACs.
        assert_eq!(m.layer_macs(0), 6 * 6 * 4 * 27);
    }

    #[test]
    fn fusable_span_rules() {
        let m = tiny();
        assert!(m.fusable_span(0, 2)); // two convs
        assert!(!m.fusable_span(0, 1)); // single layer is not a block
        assert!(!m.fusable_span(1, 3)); // global pool not streamable as conv
    }

    #[test]
    fn iterative_tail_detected() {
        let m = tiny();
        assert!(m.iterative_tail_at(2));
        assert!(!m.iterative_tail_at(1));
        assert!(!m.iterative_tail_at(3));
    }

    #[test]
    fn residual_stash_accounted() {
        let m = ModelChain::new(
            "res",
            TensorShape::new(8, 8, 4),
            vec![
                Layer::conv("c0", 3, 1, 1, 4, 4, Activation::Relu6),
                Layer::conv("c1", 3, 1, 1, 4, 4, Activation::None).with_residual(0),
            ],
        );
        // While c0 runs, v0 must also survive for the skip into c1.
        assert_eq!(m.residual_stash_bytes(0), 0); // src==0, j==1: stash spans layers in (0..1)
        let peak = m.vanilla_peak_ram();
        // c1's edge: I(v1) + O(v2) + stash(v0)
        assert_eq!(peak, 256 + 256 + 256);
    }
}
