//! Small models: the AOT quickstart model and test/example networks.

use crate::model::{Activation, Layer, ModelChain, TensorShape};

/// The exact model `python/compile/model.py` lowers into `artifacts/`
/// (kept in lockstep — see `CONV_CFG` there and
/// `rust/tests/artifacts_roundtrip.rs`):
///
/// ```text
/// 32×32×3 → conv3x3 s1 3→8 relu6 → conv3x3 s2 8→16 relu6
///         → conv3x3 s2 16→32 relu6 → global-pool → dense 32→10
/// ```
pub fn quickstart() -> ModelChain {
    ModelChain::new(
        "quickstart",
        TensorShape::new(32, 32, 3),
        vec![
            Layer::conv("conv0", 3, 1, 0, 3, 8, Activation::Relu6),
            Layer::conv("conv1", 3, 2, 0, 8, 16, Activation::Relu6),
            Layer::conv("conv2", 3, 2, 0, 16, 32, Activation::Relu6),
            Layer::global_pool("pool", 32),
            Layer::dense("fc", 32, 10),
        ],
    )
}

/// Minimal 2-conv net for unit tests and doc examples.
pub fn tiny_cnn() -> ModelChain {
    ModelChain::new(
        "tiny",
        TensorShape::new(16, 16, 3),
        vec![
            Layer::conv("c0", 3, 1, 1, 3, 8, Activation::Relu6),
            Layer::conv("c1", 3, 2, 1, 8, 16, Activation::Relu6),
            Layer::global_pool("gp", 16),
            Layer::dense("fc", 16, 4),
        ],
    )
}

/// LeNet-5-style net (28×28 grayscale): classic conv/pool alternation —
/// exercises pooling layers inside fusion blocks.
pub fn lenet() -> ModelChain {
    ModelChain::new(
        "lenet",
        TensorShape::new(28, 28, 1),
        vec![
            Layer::conv("c1", 5, 1, 2, 1, 6, Activation::Relu),
            Layer::avg_pool("s2", 2, 2, 6),
            Layer::conv("c3", 5, 1, 0, 6, 16, Activation::Relu),
            Layer::avg_pool("s4", 2, 2, 16),
            Layer::conv("c5", 5, 1, 0, 16, 120, Activation::Relu),
            Layer::global_pool("gp", 120),
            Layer::dense("f6", 120, 84),
            Layer::dense("out", 84, 10),
        ],
    )
}

/// Keyword-spotting CNN over a 49×10 MFCC "image" (the paper's §1 audio
/// use-case family): tall non-square input exercises the H/W asymmetry of
/// the row-band analytics.
pub fn kws_cnn() -> ModelChain {
    ModelChain::new(
        "kws",
        TensorShape::new(49, 10, 1),
        vec![
            Layer::conv("c0", 3, 1, 1, 1, 16, Activation::Relu6),
            Layer::dwconv("dw1", 3, 1, 1, 16, Activation::Relu6),
            Layer::pointwise("pw1", 16, 32, Activation::Relu6),
            Layer::dwconv("dw2", 3, 2, 1, 32, Activation::Relu6),
            Layer::pointwise("pw2", 32, 48, Activation::Relu6),
            Layer::global_pool("gp", 48),
            Layer::dense("fc", 48, 12),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quickstart_matches_python_model() {
        // Shapes mirrored from python/compile/model.py CONV_CFG.
        let m = quickstart();
        assert_eq!(m.shapes[1], TensorShape::new(30, 30, 8));
        assert_eq!(m.shapes[2], TensorShape::new(14, 14, 16));
        assert_eq!(m.shapes[3], TensorShape::new(6, 6, 32));
        assert_eq!(*m.shapes.last().unwrap(), TensorShape::vec(10));
    }

    #[test]
    fn all_small_models_build() {
        for m in [quickstart(), tiny_cnn(), lenet(), kws_cnn()] {
            assert!(m.num_layers() >= 4);
            assert!(m.vanilla_peak_ram() > 0);
            assert!(m.total_macs() > 0);
        }
    }

    #[test]
    fn lenet_pools_are_fusable() {
        let m = lenet();
        assert!(m.fusable_span(0, 4)); // conv,pool,conv,pool
    }

    #[test]
    fn kws_nonsquare_shapes() {
        let m = kws_cnn();
        assert_eq!(m.shapes[0].h, 49);
        assert_eq!(m.shapes[0].w, 10);
        // dw2 stride 2: 49 -> 25, 10 -> 5.
        assert_eq!(m.shapes[4].h, 25);
        assert_eq!(m.shapes[4].w, 5);
    }
}
