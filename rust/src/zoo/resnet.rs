//! ResNet generator — the paper's §1 motivating example: "even a single
//! convolutional layer in quantized ResNet-34 consumes around 414.72 KiB
//! in RAM", i.e. far beyond RFC 7228 constrained-node budgets.
//!
//! Standard ResNet-34 at 224×224: stem 7×7/2 → 64ch, maxpool/2, then
//! basic blocks [3, 4, 6, 3] at 64/128/256/512 channels with stride-2
//! stage transitions, global pool, fc-1000.

use crate::model::{Activation, Layer, ModelChain, TensorShape};

/// Append one basic block (two 3×3 convs + identity skip when shapes
/// match). Returns the output channel count.
fn basic_block(layers: &mut Vec<Layer>, tag: &str, cin: u32, cout: u32, stride: u32) -> u32 {
    let start = layers.len();
    layers.push(Layer::conv(format!("{tag}.conv1"), 3, stride, 1, cin, cout, Activation::Relu));
    let mut conv2 = Layer::conv(format!("{tag}.conv2"), 3, 1, 1, cout, cout, Activation::Relu);
    if stride == 1 && cin == cout {
        conv2 = conv2.with_residual(start);
    }
    layers.push(conv2);
    cout
}

/// ResNet-34 (He et al. 2016) at a square `input` resolution.
pub fn resnet34(input: u32, classes: u32) -> ModelChain {
    let mut layers = Vec::new();
    layers.push(Layer::conv("stem", 7, 2, 3, 3, 64, Activation::Relu));
    layers.push(Layer::max_pool("pool1", 2, 2, 64));
    let mut c = 64;
    for (stage, &(cout, n, s)) in [(64u32, 3u32, 1u32), (128, 4, 2), (256, 6, 2), (512, 3, 2)]
        .iter()
        .enumerate()
    {
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            c = basic_block(&mut layers, &format!("s{stage}.b{r}"), c, cout, stride);
        }
    }
    layers.push(Layer::global_pool("gap", c));
    layers.push(Layer::dense("fc", c, classes));
    ModelChain::new(format!("resnet34@{input}"), TensorShape::new(input, input, 3), layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::Planner;

    #[test]
    fn paper_intro_claim_single_layer_ram() {
        // §1: a single conv layer of int8 ResNet-34 needs ~414.72 KiB.
        // 414.72 kB = 414 720 B = 2 × (56·56·64 + 56·56·? ) ... precisely:
        // the stage-1 3x3 conv at 56×56×64 -> 56×56×64 costs
        // I + O = 2·56²·64 = 401 408 B ≈ 392 KiB; the paper's 414.72 kB
        // (= 2·57.6²·... ) matches the 112×112 stem output pair at int8:
        // none lands exactly — what must hold is the *magnitude*: some
        // single layer needs hundreds of kB, dwarfing RFC-7228 budgets.
        let m = resnet34(224, 1000);
        let worst = (0..m.num_layers())
            .map(|i| m.tensor_bytes(i) + m.tensor_bytes(i + 1))
            .max()
            .unwrap();
        assert!(
            worst > 400_000,
            "worst single ResNet-34 layer should exceed 400 kB, got {worst}"
        );
        assert!(m.vanilla_peak_ram() > 400_000);
    }

    #[test]
    fn shapes_and_depth() {
        let m = resnet34(224, 1000);
        // stem 224->112, pool ->56, stages keep 56/28/14/7.
        assert_eq!(m.shapes[1].h, 112);
        assert_eq!(m.shapes[2].h, 56);
        let pre_pool = m.shapes[m.shapes.len() - 3];
        assert_eq!((pre_pool.h, pre_pool.c), (7, 512));
        // 2 stem ops + 2*(3+4+6+3) convs + pool + fc = 36 layers.
        assert_eq!(m.num_layers(), 36);
    }

    #[test]
    fn fusion_rescues_resnet_for_mcus() {
        // The paper's implicit §1 promise: fusion brings such a layer
        // within MCU reach. On ResNet-34@96 the identity skips bound the
        // fusable spans (each basic block fuses, but spans cannot cross
        // skip boundaries), so the cut is smaller than on the MBV2 family:
        // ~63% here, landing the model inside a 256 kB Cortex-M4 budget.
        let m = resnet34(96, 100);
        let s = Planner::for_model(m.clone()).plan().unwrap().setting;
        assert!(
            (s.cost.peak_ram as f64) < 0.4 * m.vanilla_peak_ram() as f64,
            "{} vs {}",
            s.cost.peak_ram,
            m.vanilla_peak_ram()
        );
        assert!(s.cost.peak_ram < 256 * 1024, "must fit the M4 class");
    }

    #[test]
    fn residual_shapes_consistent() {
        let m = resnet34(224, 1000);
        for (j, l) in m.layers.iter().enumerate() {
            if let Some(src) = l.residual_from {
                assert_eq!(m.input_of(src), m.output_of(j), "skip at {j}");
            }
        }
    }
}
