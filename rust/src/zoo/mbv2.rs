//! MobileNetV2 generator (Sandler et al., CVPR 2018) with width multiplier.

use crate::model::{Activation, Layer, ModelChain, TensorShape};

/// Channel rounding used by the MobileNet family: round to the nearest
/// multiple of `divisor`, never dropping below 90% of the request.
pub fn make_divisible(v: f64, divisor: u32) -> u32 {
    let d = divisor as f64;
    let new_v = ((v + d / 2.0) / d).floor() * d;
    let new_v = new_v.max(d);
    if new_v < 0.9 * v {
        (new_v + d) as u32
    } else {
        new_v as u32
    }
}

/// Inverted-residual bottleneck schedule: (expand ratio t, channels c,
/// repeats n, first stride s).
const SCHEDULE: &[(u32, u32, u32, u32)] = &[
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

/// Build MobileNetV2 at `width` multiplier for a square `input` resolution
/// and `classes` outputs. `mbv2(0.35, 144, 1000)` is the paper's
/// MBV2-w0.35 evaluation model.
pub fn mbv2(width: f64, input: u32, classes: u32) -> ModelChain {
    let mut layers: Vec<Layer> = Vec::new();
    let wm = |c: u32| make_divisible(c as f64 * width, 8);

    let first = wm(32);
    layers.push(Layer::conv("stem", 3, 2, 1, 3, first, Activation::Relu6));

    let mut cin = first;
    for (bi, &(t, c, n, s)) in SCHEDULE.iter().enumerate() {
        let cout = wm(c);
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            let hidden = cin * t;
            let tag = format!("b{bi}.{r}");
            let block_start = layers.len();
            if t != 1 {
                layers.push(Layer::pointwise(
                    format!("{tag}.expand"),
                    cin,
                    hidden,
                    Activation::Relu6,
                ));
            }
            layers.push(Layer::dwconv(
                format!("{tag}.dw"),
                3,
                stride,
                1,
                hidden,
                Activation::Relu6,
            ));
            let mut project =
                Layer::pointwise(format!("{tag}.project"), hidden, cout, Activation::None);
            // Identity residual when shapes match (stride 1, same channels).
            if stride == 1 && cin == cout {
                project = project.with_residual(block_start);
            }
            layers.push(project);
            cin = cout;
        }
    }

    // TinyML convention (MCUNet/TinyEngine): the final 1×1 conv also scales
    // with the width multiplier (1280·w), unlike the server-side variant.
    let last = make_divisible(1280.0 * width, 8).max(wm(320) * 2);
    layers.push(Layer::pointwise("head", cin, last, Activation::Relu6));
    layers.push(Layer::global_pool("pool", last));
    layers.push(Layer::dense("fc", last, classes));

    ModelChain::new(
        format!("mbv2-w{width}@{input}"),
        TensorShape::new(input, input, 3),
        layers,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LayerKind;

    #[test]
    fn make_divisible_matches_reference_values() {
        assert_eq!(make_divisible(32.0 * 0.35, 8), 16); // 11.2 -> 8 < 10.08 -> 16
        assert_eq!(make_divisible(16.0 * 0.35, 8), 8);
        assert_eq!(make_divisible(24.0 * 0.35, 8), 8);
        assert_eq!(make_divisible(64.0 * 0.35, 8), 24);
        assert_eq!(make_divisible(160.0 * 0.35, 8), 56);
        assert_eq!(make_divisible(320.0 * 0.35, 8), 112);
        assert_eq!(make_divisible(1280.0 * 0.35, 8), 448);
    }

    #[test]
    fn w035_at_144_shapes() {
        let m = mbv2(0.35, 144, 1000);
        // Stem: 144 -> 72; strides 2,2,2,1,2 across stages -> final map 5x5.
        assert_eq!(m.shapes[1].h, 72);
        let pre_pool = m.shapes[m.shapes.len() - 3];
        assert_eq!((pre_pool.h, pre_pool.w, pre_pool.c), (5, 5, 448));
        assert_eq!(m.shapes.last().unwrap().c, 1000);
    }

    #[test]
    fn block_counts() {
        let m = mbv2(0.35, 144, 1000);
        // 17 bottlenecks: 1 with t=1 (2 layers) + 16 with t=6 (3 layers)
        // + stem + head + pool + fc = 2 + 48 + 4 = 54 layers.
        assert_eq!(m.num_layers(), 54);
        let n_dw = m
            .layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::DwConv2d))
            .count();
        assert_eq!(n_dw, 17);
    }

    #[test]
    fn residuals_only_on_matching_shapes() {
        let m = mbv2(0.35, 144, 1000);
        for (j, l) in m.layers.iter().enumerate() {
            if let Some(src) = l.residual_from {
                assert_eq!(m.input_of(src), m.output_of(j), "skip at layer {j}");
            }
        }
    }

    #[test]
    fn vanilla_peak_is_input_dominated() {
        let m = mbv2(0.35, 144, 1000);
        let peak = m.vanilla_peak_ram();
        // Early layers dominate (the paper's MCUNetV2 §2 observation):
        // peak must equal one of the first few boundary pairs.
        let early_peak: u64 = (0..6)
            .map(|i| m.tensor_bytes(i) + m.tensor_bytes(i + 1) + m.residual_stash_bytes(i))
            .max()
            .unwrap();
        assert_eq!(peak, early_peak);
        assert!(peak > 100_000, "MBV2-w0.35@144 peak should be ~100-300 kB, got {peak}");
    }

    #[test]
    fn width_one_is_bigger_than_w035() {
        let a = mbv2(1.0, 144, 1000);
        let b = mbv2(0.35, 144, 1000);
        assert!(a.vanilla_peak_ram() > b.vanilla_peak_ram());
        assert!(a.total_macs() > b.total_macs());
    }
}
