//! MCUNetV2 model reconstructions.
//!
//! The paper evaluates MCUNetV2-VWW-5fps (input 80×80×3, vanilla peak
//! 96 kB) and MCUNetV2-320KB-ImageNet (input 176×176×3, vanilla peak
//! 309.76 kB). The full NAS-derived layer lists are not published in the
//! paper; we reconstruct MBV2-family backbones whose **vanilla peak RAM
//! matches the reported values exactly**:
//!
//! * vww5: `80²·3 + 40²·48 = 96 000 B` (stem edge; the stride-2 depthwise
//!   that follows peaks at the same value).
//! * 320k: `88²·16 + 88²·24 = 309 760 B` (the b0 expand edge).
//!
//! Every downstream number (Tables 1/2/3/5) is normalized against this
//! vanilla footprint, so matching it anchors the comparisons; residual
//! architecture deltas are documented in EXPERIMENTS.md.

use crate::model::{Activation, Layer, ModelChain, TensorShape};

/// Append one inverted-residual block (expand ratio `t`, output channels
/// `cout`, stride `s`, depthwise kernel `k`). Returns the output channels.
fn bottleneck(
    layers: &mut Vec<Layer>,
    tag: &str,
    cin: u32,
    cout: u32,
    t: u32,
    s: u32,
    k: u32,
) -> u32 {
    let hidden = cin * t;
    let start = layers.len();
    if t != 1 {
        layers.push(Layer::pointwise(format!("{tag}.expand"), cin, hidden, Activation::Relu6));
    }
    layers.push(Layer::dwconv(format!("{tag}.dw"), k, s, (k - 1) / 2, hidden, Activation::Relu6));
    let mut project = Layer::pointwise(format!("{tag}.project"), hidden, cout, Activation::None);
    if s == 1 && cin == cout {
        project = project.with_residual(start);
    }
    layers.push(project);
    cout
}

/// MCUNetV2-VWW-5fps reconstruction: 80×80×3 input, 2 classes
/// (visual wake words: person / no person), vanilla peak RAM = 96 kB.
pub fn mcunet_vww5() -> ModelChain {
    let mut layers = Vec::new();
    // Wide stem, immediately downsampled — peak edges:
    //   stem:  80²·3 + 40²·48 = 96 000 B
    //   b0.dw: 40²·48 + 20²·48 = 96 000 B
    layers.push(Layer::conv("stem", 3, 2, 1, 3, 48, Activation::Relu6));
    layers.push(Layer::dwconv("b0.dw", 3, 2, 1, 48, Activation::Relu6));
    layers.push(Layer::pointwise("b0.project", 48, 16, Activation::None));
    let mut c = 16; // 20×20×16
    c = bottleneck(&mut layers, "b1", c, 24, 3, 1, 3);
    c = bottleneck(&mut layers, "b2", c, 24, 3, 1, 3); // residual
    c = bottleneck(&mut layers, "b3", c, 40, 4, 2, 5); // -> 10x10
    c = bottleneck(&mut layers, "b4", c, 40, 4, 1, 5); // residual
    c = bottleneck(&mut layers, "b5", c, 48, 4, 1, 3);
    c = bottleneck(&mut layers, "b6", c, 96, 4, 2, 5); // -> 5x5
    c = bottleneck(&mut layers, "b7", c, 96, 4, 1, 3); // residual
    layers.push(Layer::pointwise("head", c, 160, Activation::Relu6));
    layers.push(Layer::global_pool("pool", 160));
    layers.push(Layer::dense("fc", 160, 2));
    ModelChain::new("mcunet-vww5@80", TensorShape::new(80, 80, 3), layers)
}

/// MCUNetV2-320KB-ImageNet reconstruction: 176×176×3 input, 1000 classes,
/// vanilla peak RAM = 309.76 kB (88²·16 + 88²·24 at the b0 expand edge).
pub fn mcunet_320k() -> ModelChain {
    let mut layers = Vec::new();
    layers.push(Layer::conv("stem", 3, 2, 1, 3, 16, Activation::Relu6)); // -> 88x88x16
    // b0: the peak edge — pw 16->24 at 88²: 123 904 + 185 856 = 309 760 B.
    layers.push(Layer::pointwise("b0.expand", 16, 24, Activation::Relu6));
    layers.push(Layer::dwconv("b0.dw", 3, 2, 1, 24, Activation::Relu6)); // -> 44x44
    layers.push(Layer::pointwise("b0.project", 24, 16, Activation::None));
    let mut c = 16; // 44×44×16
    c = bottleneck(&mut layers, "b1", c, 24, 3, 1, 3);
    c = bottleneck(&mut layers, "b2", c, 24, 2, 1, 3); // residual (t=2: the
    // dw edge at 44²·72 with the skip stash would exceed the 309.76 kB peak)
    c = bottleneck(&mut layers, "b3", c, 40, 3, 2, 5); // -> 22x22
    c = bottleneck(&mut layers, "b4", c, 40, 4, 1, 5); // residual
    c = bottleneck(&mut layers, "b5", c, 48, 4, 1, 3);
    c = bottleneck(&mut layers, "b6", c, 96, 4, 2, 5); // -> 11x11
    c = bottleneck(&mut layers, "b7", c, 96, 4, 1, 3); // residual
    c = bottleneck(&mut layers, "b8", c, 160, 4, 2, 5); // -> 6x6
    c = bottleneck(&mut layers, "b9", c, 160, 4, 1, 3); // residual
    layers.push(Layer::pointwise("head", c, 448, Activation::Relu6));
    layers.push(Layer::global_pool("pool", 448));
    layers.push(Layer::dense("fc", 448, 1000));
    ModelChain::new("mcunet-320k@176", TensorShape::new(176, 176, 3), layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vww5_vanilla_peak_matches_paper() {
        let m = mcunet_vww5();
        assert_eq!(m.vanilla_peak_ram(), 96_000, "paper Table 1: 96 kB");
    }

    #[test]
    fn mn320k_vanilla_peak_matches_paper() {
        let m = mcunet_320k();
        assert_eq!(m.vanilla_peak_ram(), 309_760, "paper Table 1: 309.76 kB");
    }

    #[test]
    fn vww5_tail_is_iterative_rewritable() {
        let m = mcunet_vww5();
        let gp = m
            .layers
            .iter()
            .position(|l| matches!(l.kind, crate::model::LayerKind::GlobalAvgPool))
            .unwrap();
        assert!(m.iterative_tail_at(gp));
    }

    #[test]
    fn input_shapes_match_paper() {
        assert_eq!(mcunet_vww5().shapes[0], TensorShape::new(80, 80, 3));
        assert_eq!(mcunet_320k().shapes[0], TensorShape::new(176, 176, 3));
    }

    #[test]
    fn residual_shapes_consistent() {
        for m in [mcunet_vww5(), mcunet_320k()] {
            for (j, l) in m.layers.iter().enumerate() {
                if let Some(src) = l.residual_from {
                    assert_eq!(m.input_of(src), m.output_of(j), "{} layer {j}", m.name);
                }
            }
        }
    }

    #[test]
    fn head_layers_dominate_ram() {
        // MCUNetV2's §2 observation that motivates fusion in the first
        // place: the peak lives in the first few layers.
        for m in [mcunet_vww5(), mcunet_320k()] {
            let peak = m.vanilla_peak_ram();
            let head_peak: u64 = (0..4)
                .map(|i| m.tensor_bytes(i) + m.tensor_bytes(i + 1) + m.residual_stash_bytes(i))
                .max()
                .unwrap();
            assert_eq!(peak, head_peak, "{}", m.name);
        }
    }
}
