//! Model zoo: the paper's three evaluation backbones (§6.3, §8) plus small
//! models for tests, examples, and the quickstart artifact cross-check.
//!
//! * [`mbv2`] — MobileNetV2 with a width multiplier (MBV2-w0.35 @ 144).
//! * [`mcunet_vww5`] / [`mcunet_320k`] — reconstructions of
//!   MCUNetV2-VWW-5fps (@80) and MCUNetV2-320KB-ImageNet (@176). The exact
//!   MCUNet NAS architectures are not fully published; these are
//!   MBV2-family backbones scaled so the *vanilla* peak-RAM footprint
//!   matches the paper's reported values (96 kB and 309.76 kB) — the
//!   quantity every experiment is normalized against. Deltas are recorded
//!   in EXPERIMENTS.md.
//! * [`quickstart`] — the exact model `python/compile/model.py` AOT-lowers
//!   (kept in lockstep by `rust/tests/artifacts_roundtrip.rs`).

mod mbv2;
mod mcunet;
mod resnet;
mod small;

pub use mbv2::{make_divisible, mbv2};
pub use mcunet::{mcunet_320k, mcunet_vww5};
pub use resnet::resnet34;
pub use small::{kws_cnn, lenet, quickstart, tiny_cnn};

use crate::model::ModelChain;

/// All paper evaluation models, as `(label, model)` in Table order.
pub fn paper_models() -> Vec<(&'static str, ModelChain)> {
    vec![
        ("MBV2-w0.35", mbv2(0.35, 144, 1000)),
        ("MN2-vww5", mcunet_vww5()),
        ("MN2-320K", mcunet_320k()),
    ]
}

/// Look a model up by CLI alias *or* by its canonical `ModelChain::name`
/// string — the latter is what a serialized [`crate::optimizer::Plan`]
/// records, so plan files resolve back to their zoo model.
pub fn by_name(name: &str) -> Option<ModelChain> {
    match name {
        "mbv2-w0.35" | "mbv2" | "mbv2-w0.35@144" => Some(mbv2(0.35, 144, 1000)),
        "mn2-vww5" | "vww5" | "mcunet-vww5@80" => Some(mcunet_vww5()),
        "mn2-320k" | "320k" | "mcunet-320k@176" => Some(mcunet_320k()),
        "quickstart" => Some(quickstart()),
        "tiny" => Some(tiny_cnn()),
        "lenet" => Some(lenet()),
        "kws" => Some(kws_cnn()),
        "resnet34" | "resnet34@224" => Some(resnet34(224, 1000)),
        "resnet34-96" | "resnet34@96" => Some(resnet34(96, 100)),
        _ => None,
    }
}

/// CLI-visible zoo names.
pub const MODEL_NAMES: &[&str] = &[
    "mbv2-w0.35",
    "mn2-vww5",
    "mn2-320k",
    "quickstart",
    "tiny",
    "lenet",
    "kws",
    "resnet34",
    "resnet34-96",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_names_resolve_for_plan_roundtrips() {
        // A serialized Plan records `ModelChain::name`; every zoo model
        // must resolve back through `by_name` under that exact string.
        let models = [
            mbv2(0.35, 144, 1000),
            mcunet_vww5(),
            mcunet_320k(),
            quickstart(),
            tiny_cnn(),
            lenet(),
            kws_cnn(),
            resnet34(224, 1000),
            resnet34(96, 100),
        ];
        for m in models {
            let resolved =
                by_name(&m.name).unwrap_or_else(|| panic!("'{}' not resolvable", m.name));
            assert_eq!(resolved.name, m.name);
            assert_eq!(resolved.num_layers(), m.num_layers());
        }
    }

    #[test]
    fn cli_names_all_resolve() {
        for name in MODEL_NAMES {
            assert!(by_name(name).is_some(), "{name}");
        }
    }
}
