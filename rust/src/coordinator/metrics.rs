//! Request metrics, per registered model: counts, latency percentiles,
//! queue-wait vs execute split, throughput, queue depth/peak,
//! backpressure rejections, and shutdown drops — plus aggregate views
//! across the whole registry.
//!
//! Two complementary latency stores coexist per model. An **exact ring**
//! of the most recent [`SAMPLE_WINDOW`] samples gives tight percentiles
//! over recent traffic ([`ModelMetrics::stats`]), while a fixed-bucket
//! [`LatencyHistogram`] absorbs every sample ever recorded in O(1)
//! memory and stays **mergeable** across models
//! ([`Metrics::histogram`]) — the aggregation exact windows cannot do
//! without re-shipping samples. `count` and `mean` are exact lifetime
//! values in both views.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::obs::{nearest_rank, LatencyHistogram};

/// Latency summary over a set of completed requests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyStats {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

fn stats_of(samples: &[f64]) -> Option<LatencyStats> {
    if samples.is_empty() {
        return None;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(LatencyStats {
        count: v.len(),
        mean_us: v.iter().sum::<f64>() / v.len() as f64,
        p50_us: nearest_rank(&v, 0.50),
        p95_us: nearest_rank(&v, 0.95),
        p99_us: nearest_rank(&v, 0.99),
        max_us: *v.last().unwrap(),
    })
}

/// Cap on retained latency samples per model: percentiles/max are
/// computed over a ring of the most recent samples so a long-running
/// server's metrics stay O(1) in memory; `count` and `mean` stay exact
/// over the full lifetime.
const SAMPLE_WINDOW: usize = 4096;

/// Per-model accumulator.
#[derive(Debug, Default, Clone)]
pub struct ModelMetrics {
    samples_us: Vec<f64>,
    next_sample: usize,
    hist: LatencyHistogram,
    completed: usize,
    sum_us: f64,
    /// Requests recorded with a queue-wait/execute split
    /// ([`Self::record_timed`]); the split means divide by this, not by
    /// `completed`, so split-less [`Self::record`] calls don't skew them.
    timed: usize,
    sum_queue_wait_us: f64,
    sum_exec_us: f64,
    first_done: Option<Instant>,
    last_done: Option<Instant>,
    batches: usize,
    queue_full_rejections: usize,
    shutdown_drops: usize,
    queue_depth: usize,
    queue_peak: usize,
}

impl ModelMetrics {
    /// Record one completed request's end-to-end latency.
    pub fn record(&mut self, latency: Duration) {
        let us = latency.as_secs_f64() * 1e6;
        self.completed += 1;
        self.sum_us += us;
        self.hist.record_us(us);
        let now = Instant::now();
        self.first_done.get_or_insert(now);
        self.last_done = Some(now);
        if self.samples_us.len() < SAMPLE_WINDOW {
            self.samples_us.push(us);
        } else {
            self.samples_us[self.next_sample] = us;
            self.next_sample = (self.next_sample + 1) % SAMPLE_WINDOW;
        }
    }

    /// [`Self::record`] with the latency split into the time the request
    /// waited in the bounded queue and the time its backend ran. The
    /// end-to-end sample is `queue_wait + exec`.
    pub fn record_timed(&mut self, queue_wait: Duration, exec: Duration) {
        self.record(queue_wait + exec);
        self.timed += 1;
        self.sum_queue_wait_us += queue_wait.as_secs_f64() * 1e6;
        self.sum_exec_us += exec.as_secs_f64() * 1e6;
    }

    pub fn record_batch(&mut self, _size: usize) {
        self.batches += 1;
    }

    pub fn record_rejection(&mut self) {
        self.queue_full_rejections += 1;
    }

    /// A queued request discarded by the shutdown drain (it received a
    /// structured `ServeError::ShuttingDown` reply, never a result).
    pub fn record_shutdown_drop(&mut self) {
        self.shutdown_drops += 1;
    }

    pub(crate) fn queue_inc(&mut self) {
        self.queue_depth += 1;
        self.queue_peak = self.queue_peak.max(self.queue_depth);
    }

    pub(crate) fn queue_dec(&mut self) {
        self.queue_depth = self.queue_depth.saturating_sub(1);
    }

    /// Requests currently enqueued (submitted, not yet popped by the
    /// model's executor).
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// High-water mark of [`Self::queue_depth`] over the model's
    /// lifetime.
    pub fn queue_peak(&self) -> usize {
        self.queue_peak
    }

    pub fn rejections(&self) -> usize {
        self.queue_full_rejections
    }

    pub fn shutdown_drops(&self) -> usize {
        self.shutdown_drops
    }

    pub fn batches(&self) -> usize {
        self.batches
    }

    /// Total requests completed over the model's lifetime (exact, not
    /// capped by the sample window).
    pub fn completed(&self) -> usize {
        self.completed
    }

    /// Mean time completed requests spent queued before an executor
    /// popped them (over [`Self::record_timed`] requests).
    pub fn queue_wait_mean_us(&self) -> Option<f64> {
        if self.timed > 0 {
            Some(self.sum_queue_wait_us / self.timed as f64)
        } else {
            None
        }
    }

    /// Mean backend execution time (over [`Self::record_timed`]
    /// requests).
    pub fn exec_mean_us(&self) -> Option<f64> {
        if self.timed > 0 {
            Some(self.sum_exec_us / self.timed as f64)
        } else {
            None
        }
    }

    /// Completed requests per second over the model's active window
    /// (first to last completion); `None` below 2 completions.
    pub fn throughput_rps(&self) -> Option<f64> {
        let (first, last) = (self.first_done?, self.last_done?);
        let window = last.duration_since(first).as_secs_f64();
        if self.completed >= 2 && window > 0.0 {
            Some((self.completed - 1) as f64 / window)
        } else {
            None
        }
    }

    /// The model's lifetime latency histogram (every sample ever
    /// recorded; mergeable across models).
    pub fn histogram(&self) -> &LatencyHistogram {
        &self.hist
    }

    /// Latency summary: `count`/`mean_us` are exact lifetime values;
    /// percentiles and `max_us` come from the recent-sample window.
    pub fn stats(&self) -> Option<LatencyStats> {
        let mut s = stats_of(&self.samples_us)?;
        s.count = self.completed;
        s.mean_us = self.sum_us / self.completed as f64;
        Some(s)
    }
}

/// Metrics for the whole registry; cheap to snapshot. Aggregate accessors
/// ([`Self::stats`], [`Self::rejections`], …) fold over every model, so
/// single-model callers keep working unchanged.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    models: BTreeMap<String, ModelMetrics>,
}

impl Metrics {
    /// Per-model view (`None` if the model never saw traffic or isn't
    /// registered).
    pub fn model(&self, id: &str) -> Option<&ModelMetrics> {
        self.models.get(id)
    }

    pub(crate) fn model_mut(&mut self, id: &str) -> &mut ModelMetrics {
        self.models.entry(id.to_string()).or_default()
    }

    /// Iterate `(model_id, metrics)` in id order.
    pub fn per_model(&self) -> impl Iterator<Item = (&str, &ModelMetrics)> {
        self.models.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn rejections(&self) -> usize {
        self.models.values().map(ModelMetrics::rejections).sum()
    }

    pub fn shutdown_drops(&self) -> usize {
        self.models.values().map(ModelMetrics::shutdown_drops).sum()
    }

    pub fn batches(&self) -> usize {
        self.models.values().map(ModelMetrics::batches).sum()
    }

    /// Total requests completed across every model.
    pub fn completed(&self) -> usize {
        self.models.values().map(ModelMetrics::completed).sum()
    }

    /// The per-model lifetime histograms folded into one fleet-wide
    /// histogram (identical fixed bucket bounds, so merging is exact
    /// count addition).
    pub fn histogram(&self) -> LatencyHistogram {
        let mut merged = LatencyHistogram::new();
        for m in self.models.values() {
            merged.merge(&m.hist);
        }
        merged
    }

    /// Latency stats pooled across every model (`count`/`mean_us` exact
    /// lifetime values, percentiles over the per-model sample windows).
    pub fn stats(&self) -> Option<LatencyStats> {
        let all: Vec<f64> =
            self.models.values().flat_map(|m| m.samples_us.iter().copied()).collect();
        let mut s = stats_of(&all)?;
        s.count = self.models.values().map(|m| m.completed).sum();
        s.mean_us =
            self.models.values().map(|m| m.sum_us).sum::<f64>() / s.count.max(1) as f64;
        Some(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_pinned_on_known_samples() {
        // 1..=100 µs: the ceil-based nearest rank is exact — p50 is the
        // 50th sample, p95 the 95th, p99 the 99th.
        let mut m = Metrics::default();
        for i in 1..=100 {
            m.model_mut("a").record(Duration::from_micros(i));
        }
        let s = m.stats().unwrap();
        assert_eq!(s.count, 100);
        assert_eq!(s.p50_us, 50.0);
        assert_eq!(s.p95_us, 95.0);
        assert_eq!(s.p99_us, 99.0);
        assert_eq!(s.max_us, 100.0);

        // Small windows must not round the rank down: p95 over 10
        // samples is the 10th ((0.95 * 10).ceil() = 10), p50 the 5th.
        let mut small = ModelMetrics::default();
        for i in 1..=10 {
            small.record(Duration::from_micros(i * 10));
        }
        let s = small.stats().unwrap();
        assert_eq!(s.p50_us, 50.0);
        assert_eq!(s.p95_us, 100.0);
        assert_eq!(s.p99_us, 100.0);

        // A single sample is every percentile.
        let mut one = ModelMetrics::default();
        one.record(Duration::from_micros(7));
        let s = one.stats().unwrap();
        assert_eq!((s.p50_us, s.p95_us, s.p99_us, s.max_us), (7.0, 7.0, 7.0, 7.0));
    }

    #[test]
    fn empty_stats_none() {
        assert!(Metrics::default().stats().is_none());
        assert!(ModelMetrics::default().stats().is_none());
        assert!(ModelMetrics::default().throughput_rps().is_none());
        assert!(ModelMetrics::default().queue_wait_mean_us().is_none());
        assert_eq!(Metrics::default().histogram().count(), 0);
    }

    #[test]
    fn per_model_isolation_and_aggregates() {
        let mut m = Metrics::default();
        m.model_mut("a").record(Duration::from_micros(10));
        m.model_mut("a").record_batch(1);
        m.model_mut("b").record(Duration::from_micros(30));
        m.model_mut("b").record(Duration::from_micros(50));
        m.model_mut("b").record_rejection();
        m.model_mut("b").record_shutdown_drop();

        assert_eq!(m.model("a").unwrap().completed(), 1);
        assert_eq!(m.model("b").unwrap().completed(), 2);
        assert_eq!(m.model("a").unwrap().rejections(), 0);
        assert_eq!(m.rejections(), 1);
        assert_eq!(m.shutdown_drops(), 1);
        assert_eq!(m.batches(), 1);
        assert_eq!(m.completed(), 3);
        assert_eq!(m.stats().unwrap().count, 3);
        let ids: Vec<&str> = m.per_model().map(|(id, _)| id).collect();
        assert_eq!(ids, vec!["a", "b"]);
    }

    #[test]
    fn sample_window_caps_memory_but_counts_stay_exact() {
        let mut m = ModelMetrics::default();
        let total = SAMPLE_WINDOW + 1000;
        for i in 0..total {
            m.record(Duration::from_micros(i as u64 + 1));
        }
        assert_eq!(m.completed(), total);
        let s = m.stats().unwrap();
        assert_eq!(s.count, total);
        // Mean is exact over the lifetime: sum of 1..=total over total.
        let exact_mean = (1..=total as u64).sum::<u64>() as f64 / total as f64;
        assert!((s.mean_us - exact_mean).abs() < 1e-6, "{} vs {exact_mean}", s.mean_us);
        // Percentiles come from the recent window only: every retained
        // sample is one of the most recent SAMPLE_WINDOW, so even the
        // window's minimum exceeds the evicted prefix.
        assert!(s.p50_us > (total - SAMPLE_WINDOW) as f64);
        assert!(s.p95_us >= s.p50_us && s.p99_us >= s.p95_us);
        assert_eq!(s.max_us, total as f64);
        // The histogram saw every sample, not just the window.
        assert_eq!(m.histogram().count(), total as u64);
    }

    #[test]
    fn timed_records_split_wait_and_exec() {
        let mut m = ModelMetrics::default();
        m.record_timed(Duration::from_micros(30), Duration::from_micros(70));
        m.record_timed(Duration::from_micros(10), Duration::from_micros(90));
        assert_eq!(m.completed(), 2);
        assert!((m.queue_wait_mean_us().unwrap() - 20.0).abs() < 1e-9);
        assert!((m.exec_mean_us().unwrap() - 80.0).abs() < 1e-9);
        // The end-to-end sample is the sum of the split.
        let s = m.stats().unwrap();
        assert!((s.mean_us - 100.0).abs() < 1e-9);
        // Split-less records don't dilute the split means.
        m.record(Duration::from_micros(500));
        assert!((m.queue_wait_mean_us().unwrap() - 20.0).abs() < 1e-9);
        assert_eq!(m.completed(), 3);
    }

    #[test]
    fn histograms_merge_across_models() {
        let mut m = Metrics::default();
        for i in 1..=40 {
            m.model_mut("a").record(Duration::from_micros(i));
        }
        for i in 1..=60 {
            m.model_mut("b").record(Duration::from_micros(i * 100));
        }
        let h = m.histogram();
        assert_eq!(h.count(), 100);
        assert_eq!(
            h.count(),
            m.model("a").unwrap().histogram().count()
                + m.model("b").unwrap().histogram().count()
        );
        // Quantiles of the merged view span both models' ranges.
        assert!(h.quantile(0.99).unwrap() >= 1000.0);
        assert!(h.quantile(0.05).unwrap() <= 100.0);
    }

    #[test]
    fn queue_depth_saturates_at_zero_and_peak_is_sticky() {
        let mut m = ModelMetrics::default();
        m.queue_inc();
        m.queue_inc();
        m.queue_dec();
        assert_eq!(m.queue_depth(), 1);
        assert_eq!(m.queue_peak(), 2);
        m.queue_dec();
        m.queue_dec();
        assert_eq!(m.queue_depth(), 0);
        assert_eq!(m.queue_peak(), 2, "peak survives the drain");
        m.queue_inc();
        assert_eq!(m.queue_peak(), 2, "peak only moves on a new high-water mark");
    }

    #[test]
    fn throughput_needs_an_active_window() {
        let mut m = ModelMetrics::default();
        m.record(Duration::from_micros(5));
        assert!(m.throughput_rps().is_none(), "one completion has no window");
        std::thread::sleep(Duration::from_millis(5));
        m.record(Duration::from_micros(5));
        let rps = m.throughput_rps().unwrap();
        // 1 inter-completion interval over >= 5 ms.
        assert!(rps > 0.0 && rps <= 220.0, "{rps}");
    }
}
