//! Request metrics: counts, latency percentiles, throughput.

use std::time::Duration;

/// Latency summary over a set of completed requests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyStats {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

/// Accumulates per-request latencies; cheap to snapshot.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    samples_us: Vec<f64>,
    batches: usize,
    queue_full_rejections: usize,
}

impl Metrics {
    pub fn record(&mut self, latency: Duration) {
        self.samples_us.push(latency.as_secs_f64() * 1e6);
    }

    pub fn record_batch(&mut self, _size: usize) {
        self.batches += 1;
    }

    pub fn record_rejection(&mut self) {
        self.queue_full_rejections += 1;
    }

    pub fn rejections(&self) -> usize {
        self.queue_full_rejections
    }

    pub fn batches(&self) -> usize {
        self.batches
    }

    pub fn stats(&self) -> Option<LatencyStats> {
        if self.samples_us.is_empty() {
            return None;
        }
        let mut v = self.samples_us.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
            v[idx]
        };
        Some(LatencyStats {
            count: v.len(),
            mean_us: v.iter().sum::<f64>() / v.len() as f64,
            p50_us: pct(0.50),
            p99_us: pct(0.99),
            max_us: *v.last().unwrap(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::default();
        for i in 1..=100 {
            m.record(Duration::from_micros(i));
        }
        let s = m.stats().unwrap();
        assert_eq!(s.count, 100);
        assert!(s.p50_us <= s.p99_us);
        assert!(s.p99_us <= s.max_us);
        assert!((s.p50_us - 50.0).abs() <= 1.0);
    }

    #[test]
    fn empty_stats_none() {
        assert!(Metrics::default().stats().is_none());
    }
}
