//! Request metrics, per registered model: counts, latency percentiles,
//! queue depth, backpressure rejections, and shutdown drops — plus
//! aggregate views across the whole registry.

use std::collections::BTreeMap;
use std::time::Duration;

/// Latency summary over a set of completed requests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyStats {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

fn stats_of(samples: &[f64]) -> Option<LatencyStats> {
    if samples.is_empty() {
        return None;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| -> f64 {
        let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
        v[idx]
    };
    Some(LatencyStats {
        count: v.len(),
        mean_us: v.iter().sum::<f64>() / v.len() as f64,
        p50_us: pct(0.50),
        p99_us: pct(0.99),
        max_us: *v.last().unwrap(),
    })
}

/// Cap on retained latency samples per model: percentiles/max are
/// computed over a ring of the most recent samples so a long-running
/// server's metrics stay O(1) in memory; `count` and `mean` stay exact
/// over the full lifetime.
const SAMPLE_WINDOW: usize = 4096;

/// Per-model accumulator.
#[derive(Debug, Default, Clone)]
pub struct ModelMetrics {
    samples_us: Vec<f64>,
    next_sample: usize,
    completed: usize,
    sum_us: f64,
    batches: usize,
    queue_full_rejections: usize,
    shutdown_drops: usize,
    queue_depth: usize,
}

impl ModelMetrics {
    pub fn record(&mut self, latency: Duration) {
        let us = latency.as_secs_f64() * 1e6;
        self.completed += 1;
        self.sum_us += us;
        if self.samples_us.len() < SAMPLE_WINDOW {
            self.samples_us.push(us);
        } else {
            self.samples_us[self.next_sample] = us;
            self.next_sample = (self.next_sample + 1) % SAMPLE_WINDOW;
        }
    }

    pub fn record_batch(&mut self, _size: usize) {
        self.batches += 1;
    }

    pub fn record_rejection(&mut self) {
        self.queue_full_rejections += 1;
    }

    /// A queued request discarded by the shutdown drain (it received a
    /// structured `ServeError::ShuttingDown` reply, never a result).
    pub fn record_shutdown_drop(&mut self) {
        self.shutdown_drops += 1;
    }

    pub(crate) fn queue_inc(&mut self) {
        self.queue_depth += 1;
    }

    pub(crate) fn queue_dec(&mut self) {
        self.queue_depth = self.queue_depth.saturating_sub(1);
    }

    /// Requests currently enqueued (submitted, not yet popped by the
    /// model's executor).
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    pub fn rejections(&self) -> usize {
        self.queue_full_rejections
    }

    pub fn shutdown_drops(&self) -> usize {
        self.shutdown_drops
    }

    pub fn batches(&self) -> usize {
        self.batches
    }

    /// Total requests completed over the model's lifetime (exact, not
    /// capped by the sample window).
    pub fn completed(&self) -> usize {
        self.completed
    }

    /// Latency summary: `count`/`mean_us` are exact lifetime values;
    /// percentiles and `max_us` come from the recent-sample window.
    pub fn stats(&self) -> Option<LatencyStats> {
        let mut s = stats_of(&self.samples_us)?;
        s.count = self.completed;
        s.mean_us = self.sum_us / self.completed as f64;
        Some(s)
    }
}

/// Metrics for the whole registry; cheap to snapshot. Aggregate accessors
/// ([`Self::stats`], [`Self::rejections`], …) fold over every model, so
/// single-model callers keep working unchanged.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    models: BTreeMap<String, ModelMetrics>,
}

impl Metrics {
    /// Per-model view (`None` if the model never saw traffic or isn't
    /// registered).
    pub fn model(&self, id: &str) -> Option<&ModelMetrics> {
        self.models.get(id)
    }

    pub(crate) fn model_mut(&mut self, id: &str) -> &mut ModelMetrics {
        self.models.entry(id.to_string()).or_default()
    }

    /// Iterate `(model_id, metrics)` in id order.
    pub fn per_model(&self) -> impl Iterator<Item = (&str, &ModelMetrics)> {
        self.models.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn rejections(&self) -> usize {
        self.models.values().map(ModelMetrics::rejections).sum()
    }

    pub fn shutdown_drops(&self) -> usize {
        self.models.values().map(ModelMetrics::shutdown_drops).sum()
    }

    pub fn batches(&self) -> usize {
        self.models.values().map(ModelMetrics::batches).sum()
    }

    /// Latency stats pooled across every model (`count`/`mean_us` exact
    /// lifetime values, percentiles over the per-model sample windows).
    pub fn stats(&self) -> Option<LatencyStats> {
        let all: Vec<f64> =
            self.models.values().flat_map(|m| m.samples_us.iter().copied()).collect();
        let mut s = stats_of(&all)?;
        s.count = self.models.values().map(|m| m.completed).sum();
        s.mean_us =
            self.models.values().map(|m| m.sum_us).sum::<f64>() / s.count.max(1) as f64;
        Some(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::default();
        for i in 1..=100 {
            m.model_mut("a").record(Duration::from_micros(i));
        }
        let s = m.stats().unwrap();
        assert_eq!(s.count, 100);
        assert!(s.p50_us <= s.p99_us);
        assert!(s.p99_us <= s.max_us);
        assert!((s.p50_us - 50.0).abs() <= 1.0);
    }

    #[test]
    fn empty_stats_none() {
        assert!(Metrics::default().stats().is_none());
        assert!(ModelMetrics::default().stats().is_none());
    }

    #[test]
    fn per_model_isolation_and_aggregates() {
        let mut m = Metrics::default();
        m.model_mut("a").record(Duration::from_micros(10));
        m.model_mut("a").record_batch(1);
        m.model_mut("b").record(Duration::from_micros(30));
        m.model_mut("b").record(Duration::from_micros(50));
        m.model_mut("b").record_rejection();
        m.model_mut("b").record_shutdown_drop();

        assert_eq!(m.model("a").unwrap().completed(), 1);
        assert_eq!(m.model("b").unwrap().completed(), 2);
        assert_eq!(m.model("a").unwrap().rejections(), 0);
        assert_eq!(m.rejections(), 1);
        assert_eq!(m.shutdown_drops(), 1);
        assert_eq!(m.batches(), 1);
        assert_eq!(m.stats().unwrap().count, 3);
        let ids: Vec<&str> = m.per_model().map(|(id, _)| id).collect();
        assert_eq!(ids, vec!["a", "b"]);
    }

    #[test]
    fn sample_window_caps_memory_but_counts_stay_exact() {
        let mut m = ModelMetrics::default();
        let total = SAMPLE_WINDOW + 1000;
        for i in 0..total {
            m.record(Duration::from_micros(i as u64 + 1));
        }
        assert_eq!(m.completed(), total);
        let s = m.stats().unwrap();
        assert_eq!(s.count, total);
        // Mean is exact over the lifetime: sum of 1..=total over total.
        let exact_mean = (1..=total as u64).sum::<u64>() as f64 / total as f64;
        assert!((s.mean_us - exact_mean).abs() < 1e-6, "{} vs {exact_mean}", s.mean_us);
        // Percentiles come from the recent window only.
        assert!(s.p50_us >= 1000.0);
    }

    #[test]
    fn queue_depth_saturates_at_zero() {
        let mut m = ModelMetrics::default();
        m.queue_inc();
        m.queue_inc();
        m.queue_dec();
        assert_eq!(m.queue_depth(), 1);
        m.queue_dec();
        m.queue_dec();
        assert_eq!(m.queue_depth(), 0);
    }
}
