//! Serving coordinator — the L3 request path and its deployment control
//! plane.
//!
//! msf-CNN's contribution is the offline optimizer (L3 at *deploy* time);
//! at *request* time the coordinator routes traffic across a **live
//! registry of named plans** ([`MultiModelServer`]): each deployed model
//! gets a bounded queue with backpressure and a dedicated executor thread
//! that owns its live [`crate::backend::InferBackend`] (XLA-style handles
//! are not `Send`, so backends are instantiated inside their executor via
//! [`crate::backend::BackendSpec::connect`]) and drains per-model
//! micro-batches.
//!
//! The registry is mutable at runtime: [`ServerHandle::deploy`] adds a
//! model, [`ServerHandle::swap`] hot-replaces one (in-flight requests
//! drain on the old backend, new submits route to the new plan),
//! [`ServerHandle::retire`] removes one. [`PlanRegistry`] feeds that
//! control plane from a directory of plan JSON files — versioned,
//! re-scanned on demand (mtime/size-based), queryable by `(model_id,
//! version)`, and [`PlanRegistry::sync`]able onto a running server:
//! `msfcnn serve --registry DIR` serves whatever the directory holds and
//! follows its changes.
//!
//! Specs describe AOT artifacts, in-memory fusion settings, or pre-solved
//! serialized [`crate::optimizer::Plan`]s ([`ModelSpec::plan_file`]), so
//! many zoo models can be served concurrently without a Python step.
//! [`Metrics`] reports queue depth/peak, latency percentiles (exact
//! recent window + mergeable [`crate::obs::LatencyHistogram`]s),
//! queue-wait vs execute splits, throughput, rejections, and shutdown
//! drops per model, and survives hot swaps; shutdown drains queued
//! requests with structured [`ServeError::ShuttingDown`] replies instead
//! of dropping them. Control-plane transitions emit structured
//! [`crate::obs::TraceEvent`]s into a pluggable sink
//! ([`ServerHandle::set_trace_sink`]). [`InferenceServer`] keeps the
//! original single-model surface. Built on std threads/channels (offline
//! environment; DESIGN.md §Substitutions).

mod metrics;
mod registry;
mod server;

pub use metrics::{LatencyStats, Metrics, ModelMetrics};
pub use registry::{PlanEntry, PlanRegistry, PlanVerdict, ScanConflict, ScanReport};
pub use server::{
    BoundHandle, InferenceServer, ModelSpec, MultiModelServer, Pending, ServeError,
    ServerConfig, ServerHandle,
};
