//! Serving coordinator — the L3 request path.
//!
//! msf-CNN's contribution is the offline optimizer (L3 at *deploy* time);
//! at *request* time the coordinator routes traffic across a **registry
//! of named plans** ([`MultiModelServer`]): each registered model gets a
//! bounded queue with backpressure and a dedicated executor thread that
//! owns its live [`crate::backend::InferBackend`] (XLA-style handles are
//! not `Send`, so backends are instantiated inside their executor via
//! [`crate::backend::BackendSpec::connect`]) and drains per-model
//! micro-batches. Specs describe AOT artifacts, in-memory fusion
//! settings, or pre-solved serialized [`crate::optimizer::Plan`]s
//! ([`ModelSpec::plan_file`]), so many zoo models can be served
//! concurrently without a Python step. [`Metrics`] reports queue depth,
//! latency percentiles, rejections, and shutdown drops per model;
//! shutdown drains queued requests with structured
//! [`ServeError::ShuttingDown`] replies instead of dropping them.
//! [`InferenceServer`] keeps the original single-model surface. Built on
//! std threads/channels (offline environment; DESIGN.md §Substitutions).

mod metrics;
mod server;

pub use metrics::{LatencyStats, Metrics, ModelMetrics};
pub use server::{
    BoundHandle, InferenceServer, ModelSpec, MultiModelServer, Pending, ServeError,
    ServerConfig, ServerHandle,
};
