//! Serving coordinator — the L3 request path.
//!
//! msf-CNN's contribution is the offline optimizer (L3 at *deploy* time);
//! at *request* time the coordinator routes traffic across a **registry
//! of named plans** ([`MultiModelServer`]): each registered model gets a
//! bounded queue with backpressure and a dedicated executor thread that
//! owns its runtime (XLA-style handles are not `Send`, so runtimes never
//! cross threads) and drains per-model micro-batches. Backends are either
//! AOT artifacts ([`ModelBackend::Artifact`]) or pure-Rust fusion plans
//! ([`ModelBackend::Engine`]), so many zoo models can be served
//! concurrently without a Python step. [`Metrics`] reports queue depth,
//! latency percentiles, rejections, and shutdown drops per model;
//! shutdown drains queued requests with structured
//! [`ServeError::ShuttingDown`] replies instead of dropping them.
//! [`InferenceServer`] keeps the original single-model surface. Built on
//! std threads/channels (offline environment; DESIGN.md §Substitutions).

mod metrics;
mod server;

pub use metrics::{LatencyStats, Metrics, ModelMetrics};
pub use server::{
    BoundHandle, InferenceServer, ModelBackend, ModelSpec, MultiModelServer, Pending,
    ServeError, ServerConfig, ServerHandle,
};
