//! Serving coordinator — the L3 request path.
//!
//! msf-CNN's contribution is the offline optimizer (L3 at *deploy* time);
//! at *request* time the coordinator is a thin driver per the paper's
//! deployment story: a bounded queue with backpressure and a dedicated
//! executor thread that owns the PJRT runtime (XLA handles are not
//! `Send`, so the runtime never crosses threads) and drains the queue in
//! micro-batches. Python is never on this path — artifacts were
//! AOT-compiled at build time. Built on std threads/channels (offline
//! environment; DESIGN.md §Substitutions).

mod metrics;
mod server;

pub use metrics::{LatencyStats, Metrics};
pub use server::{InferenceServer, Pending, ServerConfig, ServerHandle};
