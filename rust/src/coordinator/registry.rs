//! [`PlanRegistry`]: a versioned, directory-backed store of named
//! [`Plan`]s — the deploy side of the control plane.
//!
//! A registry watches one directory of plan JSON files (the artifacts
//! [`crate::optimizer::Planner`] writes via [`Plan::save`]). Each file
//! named `<model_id>.plan.json` (or `<model_id>.json`) is one deployable
//! model; re-[`scan`](PlanRegistry::scan)ning the directory picks up new,
//! changed (mtime/size-based — no inotify dependency), and deleted files,
//! bumping a per-model version on every change and keeping the full
//! version history queryable by `(model_id, version)`.
//!
//! [`PlanRegistry::sync`] turns a scan into control-plane actions on a
//! running [`super::MultiModelServer`]: new files are
//! [`deploy`](super::ServerHandle::deploy)ed, changed files are
//! hot-[`swap`](super::ServerHandle::swap)ped (in-flight requests drain
//! on the old plan), and deleted files are
//! [`retire`](super::ServerHandle::retire)d — `msfcnn serve --registry
//! DIR` is exactly this loop.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::time::SystemTime;

use crate::optimizer::Plan;
use crate::util::error::{Context, Result};

use super::server::{ModelSpec, ServerHandle};

/// One versioned registry entry: a validated plan plus its file
/// provenance.
#[derive(Debug, Clone)]
pub struct PlanEntry {
    /// Registry key (the plan file's stem) — what the serving registry
    /// routes on.
    pub model_id: String,
    /// Monotonic per-model version, starting at 1 and bumped on every
    /// observed file change.
    pub version: u64,
    /// The validated plan (model resolved against the zoo at scan time).
    pub plan: Plan,
    /// File the entry was loaded from.
    pub path: PathBuf,
    /// File modification time at load.
    pub mtime: SystemTime,
    /// File size at load (changes the mtime heuristic would miss on
    /// coarse-grained filesystems still bump the version).
    pub file_len: u64,
}

/// Two plan files claiming the same model id in one scan: the registry
/// deterministically prefers the `.plan.json` spelling (then the first
/// path in sorted order) and skips the rest, but the collision is
/// surfaced — a silently shadowed plan file is a deploy footgun.
#[derive(Debug, Clone)]
pub struct ScanConflict {
    pub model_id: String,
    /// The file the registry loaded for this id.
    pub chosen: PathBuf,
    /// The file it skipped.
    pub skipped: PathBuf,
}

/// Static-analysis verdict of one plan file the scanner loaded
/// ([`crate::analysis::verify_plan_file`]): files without
/// `Error`-severity findings deploy (warnings are carried in the
/// verdict and logged), files with errors are rejected and land in
/// [`ScanReport::errors`] too — the verdict is *why*, one rendered
/// diagnostic per defect, so `serve --registry` can log the cause.
#[derive(Debug, Clone)]
pub struct PlanVerdict {
    pub model_id: String,
    pub path: PathBuf,
    /// Rendered findings (`[class] step N buffer 'x' bytes [a..b): …`,
    /// warnings prefixed `[warn:class]`); empty for a clean plan.
    pub findings: Vec<String>,
    /// `Error`-severity findings among `findings` — nonzero means the
    /// file was rejected.
    pub errors: usize,
}

impl PlanVerdict {
    /// No findings at all (warnings included).
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Deployable: free of `Error`-severity findings (possibly with
    /// logged warnings).
    pub fn is_deployable(&self) -> bool {
        self.errors == 0
    }
}

/// What one [`PlanRegistry::scan`] observed, as model ids (load failures
/// as `(path, error)` pairs and id collisions as [`ScanConflict`]s — a
/// broken or shadowed file never poisons the rest of the directory, and
/// the previous good version stays live).
#[derive(Debug, Default, Clone)]
pub struct ScanReport {
    /// Models seen for the first time.
    pub added: Vec<String>,
    /// Models whose file changed since the last scan (version bumped).
    pub updated: Vec<String>,
    /// Models whose file disappeared (dropped from the registry).
    pub removed: Vec<String>,
    /// Files that could not be loaded or validated this scan.
    pub errors: Vec<(PathBuf, String)>,
    /// Model ids claimed by more than one plan file this scan.
    pub conflicts: Vec<ScanConflict>,
    /// Static-analysis verdict of every file (re)loaded this scan —
    /// unchanged files are not re-verified.
    pub verdicts: Vec<PlanVerdict>,
}

impl ScanReport {
    /// True when the scan observed no change (errors and conflicts
    /// included: a file that turned unreadable or shadowed is a change
    /// worth surfacing).
    pub fn is_empty(&self) -> bool {
        self.added.is_empty()
            && self.updated.is_empty()
            && self.removed.is_empty()
            && self.errors.is_empty()
            && self.conflicts.is_empty()
    }
}

/// Versioned store of named plans, loaded from a directory of plan JSON
/// files and re-scannable for changes.
#[derive(Debug)]
pub struct PlanRegistry {
    dir: PathBuf,
    /// Per model id: version history, ascending (last = live).
    versions: BTreeMap<String, Vec<PlanEntry>>,
}

/// `<stem>.plan.json` / `<stem>.json` → `stem`; `None` for other files.
fn model_id_of(path: &Path) -> Option<String> {
    let name = path.file_name()?.to_str()?;
    let stem = name
        .strip_suffix(".plan.json")
        .or_else(|| name.strip_suffix(".json"))?;
    (!stem.is_empty()).then(|| stem.to_string())
}

impl PlanRegistry {
    /// Open a registry over `dir`. Fails when the directory cannot be
    /// read. No plan files are loaded yet — the first [`Self::scan`] (or
    /// [`Self::sync`]) discovers every file as `added`, so a fresh
    /// registry synced onto a fresh server deploys its full contents.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::read_dir(&dir)
            .with_context(|| format!("opening plan registry {}", dir.display()))?;
        Ok(Self { dir, versions: BTreeMap::new() })
    }

    /// The watched directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Live model ids, sorted.
    pub fn model_ids(&self) -> Vec<String> {
        self.versions.keys().cloned().collect()
    }

    /// Number of live models.
    pub fn len(&self) -> usize {
        self.versions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.versions.is_empty()
    }

    /// The live (latest-version) entry of `model_id`.
    pub fn latest(&self, model_id: &str) -> Option<&PlanEntry> {
        self.versions.get(model_id).and_then(|h| h.last())
    }

    /// A specific `(model_id, version)` entry — older versions stay
    /// queryable after a file change (audit / rollback inspection).
    pub fn get(&self, model_id: &str, version: u64) -> Option<&PlanEntry> {
        self.versions
            .get(model_id)?
            .iter()
            .find(|e| e.version == version)
    }

    /// Iterate the live entry of every model, in id order.
    pub fn entries(&self) -> impl Iterator<Item = &PlanEntry> {
        self.versions.values().filter_map(|h| h.last())
    }

    /// Re-scan the directory: load new files, reload files whose
    /// `(mtime, size)` changed (bumping their version), and drop models
    /// whose file disappeared. Every (re)loaded plan runs through the
    /// static verifier ([`crate::analysis::verify_plan_file`]) — a file
    /// that fails to parse, validate, or analyze cleanly lands in
    /// [`ScanReport::errors`] (with its [`PlanVerdict`] saying why) and
    /// the previous good version (if any) stays live.
    pub fn scan(&mut self) -> Result<ScanReport> {
        let mut report = ScanReport::default();
        let mut seen: BTreeSet<String> = BTreeSet::new();

        let mut files: Vec<PathBuf> = std::fs::read_dir(&self.dir)
            .with_context(|| format!("scanning plan registry {}", self.dir.display()))?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.is_file())
            .collect();
        files.sort();

        // Group candidate files by model id so collisions resolve
        // deterministically: `.plan.json` beats `.json`, then sorted
        // order; every skipped file is reported as a conflict.
        let mut by_id: BTreeMap<String, Vec<PathBuf>> = BTreeMap::new();
        for path in files {
            let Some(model_id) = model_id_of(&path) else { continue };
            by_id.entry(model_id).or_default().push(path);
        }
        let mut chosen_files: Vec<(String, PathBuf)> = Vec::new();
        for (model_id, mut candidates) in by_id {
            let pick = candidates
                .iter()
                .position(|p| {
                    p.file_name()
                        .and_then(|n| n.to_str())
                        .is_some_and(|n| n.ends_with(".plan.json"))
                })
                .unwrap_or(0);
            let chosen = candidates.remove(pick);
            for skipped in candidates {
                report.conflicts.push(ScanConflict {
                    model_id: model_id.clone(),
                    chosen: chosen.clone(),
                    skipped,
                });
            }
            chosen_files.push((model_id, chosen));
        }

        for (model_id, path) in chosen_files {
            seen.insert(model_id.clone());
            let (mtime, file_len) = match std::fs::metadata(&path) {
                Ok(md) => (md.modified().unwrap_or(SystemTime::UNIX_EPOCH), md.len()),
                Err(e) => {
                    report.errors.push((path, format!("stat failed: {e}")));
                    continue;
                }
            };
            let history = self.versions.get(&model_id);
            if let Some(live) = history.and_then(|h| h.last()) {
                if live.mtime == mtime && live.file_len == file_len && live.path == path {
                    continue; // unchanged
                }
            }
            match crate::analysis::verify_plan_file(&path) {
                Ok((plan, analysis)) => {
                    report.verdicts.push(PlanVerdict {
                        model_id: model_id.clone(),
                        path: path.clone(),
                        findings: analysis.findings.iter().map(|f| f.render()).collect(),
                        errors: analysis.error_count(),
                    });
                    if analysis.has_errors() {
                        // Never deploy a plan with error-severity
                        // findings: the error keeps the previous good
                        // version live, the verdict above says why.
                        // Warning-only plans deploy (the verdict carries
                        // the warnings for the caller to log).
                        let first = analysis
                            .findings
                            .iter()
                            .find(|f| f.severity == crate::analysis::Severity::Error)
                            .expect("has_errors");
                        report.errors.push((
                            path,
                            format!(
                                "rejected by static analysis ({} error(s)): {}",
                                analysis.error_count(),
                                first.render()
                            ),
                        ));
                        continue;
                    }
                    let history = self.versions.entry(model_id.clone()).or_default();
                    let version = history.last().map_or(1, |e| e.version + 1);
                    let fresh = history.is_empty();
                    history.push(PlanEntry {
                        model_id: model_id.clone(),
                        version,
                        plan,
                        path,
                        mtime,
                        file_len,
                    });
                    if fresh {
                        report.added.push(model_id);
                    } else {
                        report.updated.push(model_id);
                    }
                }
                Err(e) => report.errors.push((path, format!("{e:#}"))),
            }
        }

        // Files gone ⇒ models retired from the registry.
        let gone: Vec<String> =
            self.versions.keys().filter(|id| !seen.contains(*id)).cloned().collect();
        for id in gone {
            self.versions.remove(&id);
            report.removed.push(id);
        }
        Ok(report)
    }

    /// Scan, then reconcile the running server against the registry:
    /// every live entry not yet deployed is deployed, entries whose file
    /// changed this scan are hot-swapped, and models whose file
    /// disappeared are retired. Reconciling *all* live entries (not just
    /// this scan's deltas) makes sync idempotent and safe after a server
    /// restart or a standalone [`Self::scan`] consumed the deltas.
    pub fn sync(&mut self, handle: &ServerHandle) -> Result<ScanReport> {
        let report = self.scan()?;
        for entry in self.entries() {
            let id = &entry.model_id;
            let spec = ModelSpec::plan(id.clone(), entry.plan.clone());
            match handle.deploy(spec) {
                Ok(()) => {}
                Err(super::ServeError::AlreadyDeployed { .. }) => {
                    if report.updated.iter().any(|u| u == id) {
                        handle
                            .swap(ModelSpec::plan(id.clone(), entry.plan.clone()))
                            .map_err(|e| crate::anyhow!("syncing '{id}': {e}"))?;
                    }
                }
                Err(e) => return Err(crate::anyhow!("syncing '{id}': {e}")),
            }
        }
        for id in &report.removed {
            match handle.retire(id) {
                // Already gone server-side: nothing to retire.
                Ok(()) | Err(super::ServeError::UnknownModel { .. }) => {}
                Err(e) => return Err(crate::anyhow!("retiring '{id}': {e}")),
            }
        }
        // One structured summary event per non-trivial sync pass, after
        // the per-model Deploy/Swap/Retire events it caused.
        if !report.is_empty() {
            handle.emit(crate::obs::TraceEvent::RegistrySync {
                added: report.added.clone(),
                updated: report.updated.clone(),
                removed: report.removed.clone(),
                errors: report.errors.len(),
                conflicts: report.conflicts.len(),
            });
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::Planner;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "msfcnn-registry-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn open_is_lazy_and_first_scan_adds_everything() {
        let dir = tmp_dir("open");
        Planner::for_model(crate::zoo::tiny_cnn())
            .plan()
            .unwrap()
            .save(dir.join("tiny.plan.json"))
            .unwrap();
        let mut registry = PlanRegistry::open(&dir).unwrap();
        assert!(registry.is_empty(), "open binds the directory without loading");
        let report = registry.scan().unwrap();
        assert_eq!(report.added, vec!["tiny".to_string()]);
        assert_eq!(registry.model_ids(), vec!["tiny".to_string()]);
        let e = registry.latest("tiny").unwrap();
        assert_eq!(e.version, 1);
        assert_eq!(e.plan.model, "tiny");
        assert_eq!(registry.get("tiny", 1).unwrap().version, 1);
        assert!(registry.get("tiny", 2).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_files_are_reported_not_fatal() {
        let dir = tmp_dir("bad");
        std::fs::write(dir.join("broken.plan.json"), "{ not json").unwrap();
        std::fs::write(dir.join("notes.txt"), "ignored").unwrap();
        let mut registry = PlanRegistry::open(&dir).unwrap();
        assert!(registry.is_empty());
        let report = registry.scan().unwrap();
        assert_eq!(report.errors.len(), 1, "{report:?}");
        assert!(report.errors[0].1.contains("broken.plan.json"), "{report:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn conflicting_files_prefer_plan_json_and_are_reported() {
        let dir = tmp_dir("conflict");
        let plan = Planner::for_model(crate::zoo::tiny_cnn()).plan().unwrap();
        // Both spellings claim model id "tiny"; `.plan.json` must win.
        plan.save(dir.join("tiny.json")).unwrap();
        plan.save(dir.join("tiny.plan.json")).unwrap();
        let mut registry = PlanRegistry::open(&dir).unwrap();
        let report = registry.scan().unwrap();
        assert_eq!(report.added, vec!["tiny".to_string()]);
        assert!(report.errors.is_empty(), "{report:?}");
        assert_eq!(report.conflicts.len(), 1, "{report:?}");
        let c = &report.conflicts[0];
        assert_eq!(c.model_id, "tiny");
        assert!(c.chosen.ends_with("tiny.plan.json"), "{c:?}");
        assert!(c.skipped.ends_with("tiny.json"), "{c:?}");
        assert!(!report.is_empty(), "conflicts count as an observed change");
        assert!(registry.latest("tiny").unwrap().path.ends_with("tiny.plan.json"));
        // A re-scan with nothing changed still reports the standing
        // conflict — it is a property of the directory, not an event.
        let again = registry.scan().unwrap();
        assert!(again.added.is_empty() && again.updated.is_empty());
        assert_eq!(again.conflicts.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_directory_is_an_error() {
        assert!(PlanRegistry::open("/nonexistent-plan-registry").is_err());
    }

    #[test]
    fn model_id_parsing() {
        assert_eq!(model_id_of(Path::new("/x/kws.plan.json")).as_deref(), Some("kws"));
        assert_eq!(model_id_of(Path::new("/x/kws.json")).as_deref(), Some("kws"));
        assert_eq!(model_id_of(Path::new("/x/kws.txt")), None);
        assert_eq!(model_id_of(Path::new("/x/.json")), None);
    }
}
