//! The inference server: bounded-queue front door + dedicated executor
//! thread that owns the (non-`Send`) PJRT runtime.
//!
//! Built on std threads + channels (tokio is unavailable in the offline
//! build — DESIGN.md §Substitutions); the architecture is identical to the
//! async version: submitters get a future-like [`Pending`] reply handle,
//! the bounded queue applies backpressure, and a single executor thread
//! drains micro-batches.

use std::sync::mpsc as std_mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::runtime::Runtime;

use super::metrics::Metrics;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Artifact entry point to serve (e.g. `"model_fused"`).
    pub entry: String,
    /// Bounded queue depth; senders get backpressure errors beyond this.
    pub queue_cap: usize,
    /// Max requests drained per executor wakeup (micro-batch).
    pub batch_max: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { entry: "model_fused".into(), queue_cap: 256, batch_max: 8 }
    }
}

struct Request {
    input: Vec<f32>,
    enqueued: Instant,
    reply: std_mpsc::SyncSender<Result<Vec<f32>>>,
}

/// Reply handle for one submitted request.
pub struct Pending {
    rx: std_mpsc::Receiver<Result<Vec<f32>>>,
}

impl Pending {
    /// Block until the executor replies.
    pub fn wait(self) -> Result<Vec<f32>> {
        self.rx.recv().map_err(|_| anyhow!("server dropped request"))?
    }

    /// Non-blocking poll; `None` while still in flight.
    pub fn poll(&self) -> Option<Result<Vec<f32>>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(std_mpsc::TryRecvError::Empty) => None,
            Err(std_mpsc::TryRecvError::Disconnected) => {
                Some(Err(anyhow!("server dropped request")))
            }
        }
    }
}

/// Handle for submitting requests; cheap to clone.
#[derive(Clone)]
pub struct ServerHandle {
    tx: std_mpsc::SyncSender<Request>,
    metrics: Arc<Mutex<Metrics>>,
}

impl ServerHandle {
    /// Submit one inference; errors immediately when the queue is full
    /// (backpressure). Await the result via [`Pending::wait`].
    pub fn submit(&self, input: Vec<f32>) -> Result<Pending> {
        let (reply_tx, reply_rx) = std_mpsc::sync_channel(1);
        let req = Request { input, enqueued: Instant::now(), reply: reply_tx };
        match self.tx.try_send(req) {
            Ok(()) => Ok(Pending { rx: reply_rx }),
            Err(std_mpsc::TrySendError::Full(_)) => {
                self.metrics.lock().unwrap().record_rejection();
                Err(anyhow!("queue full (backpressure)"))
            }
            Err(std_mpsc::TrySendError::Disconnected(_)) => Err(anyhow!("server stopped")),
        }
    }

    /// Submit and block for the reply.
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(input)?.wait()
    }

    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }
}

/// The running server: executor thread + handle factory.
pub struct InferenceServer {
    handle: ServerHandle,
    worker: Option<JoinHandle<()>>,
}

impl InferenceServer {
    /// Start serving `config.entry` from the artifact directory. The
    /// runtime is created *inside* the executor thread (PJRT handles are
    /// not `Send`); startup errors surface through the first request.
    pub fn start(
        artifact_dir: impl Into<std::path::PathBuf>,
        config: ServerConfig,
    ) -> Result<Self> {
        let dir = artifact_dir.into();
        let (tx, rx) = std_mpsc::sync_channel::<Request>(config.queue_cap);
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let metrics_w = metrics.clone();
        let entry = config.entry.clone();
        let batch_max = config.batch_max.max(1);

        let worker = std::thread::Builder::new()
            .name("msfcnn-executor".into())
            .spawn(move || {
                let mut rt = match Runtime::open(&dir) {
                    Ok(rt) => rt,
                    Err(e) => {
                        while let Ok(req) = rx.recv() {
                            let _ = req.reply.send(Err(anyhow!("runtime init failed: {e:#}")));
                        }
                        return;
                    }
                };
                if let Err(e) = rt.load(&entry) {
                    while let Ok(req) = rx.recv() {
                        let _ = req.reply.send(Err(anyhow!("load '{entry}': {e:#}")));
                    }
                    return;
                }
                // Drain loop: block for one, then opportunistically batch.
                while let Ok(first) = rx.recv() {
                    let mut batch = vec![first];
                    while batch.len() < batch_max {
                        match rx.try_recv() {
                            Ok(req) => batch.push(req),
                            Err(_) => break,
                        }
                    }
                    metrics_w.lock().unwrap().record_batch(batch.len());
                    for req in batch {
                        let res = rt.run_f32(&entry, &req.input);
                        let latency = req.enqueued.elapsed();
                        metrics_w.lock().unwrap().record(latency);
                        let _ = req.reply.send(res);
                    }
                }
            })?;

        let handle = ServerHandle { tx, metrics };
        Ok(Self { handle, worker: Some(worker) })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Stop accepting requests and join the executor thread. (Any
    /// outstanding `ServerHandle` clones keep the queue open; drop them
    /// first for a clean join.)
    pub fn shutdown(mut self) {
        let ServerHandle { tx, metrics } = self.handle.clone();
        drop(tx);
        drop(metrics);
        // Drop our own handle (closes the last in-struct sender).
        self.handle = ServerHandle {
            tx: std_mpsc::sync_channel(1).0,
            metrics: Arc::new(Mutex::new(Metrics::default())),
        };
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_sane() {
        let c = ServerConfig::default();
        assert!(c.queue_cap > 0);
        assert!(c.batch_max > 0);
        assert_eq!(c.entry, "model_fused");
    }

    #[test]
    fn startup_error_surfaces_via_request() {
        let server =
            InferenceServer::start("/nonexistent-artifacts", ServerConfig::default()).unwrap();
        let h = server.handle();
        let err = h.infer(vec![0.0; 4]).unwrap_err();
        assert!(format!("{err:#}").contains("runtime init failed"), "{err:#}");
        drop(h);
        server.shutdown();
    }
}
