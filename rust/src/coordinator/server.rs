//! The inference server: a **live** registry of named plans, each with a
//! bounded front-door queue and a dedicated executor thread that owns its
//! (non-`Send`) runtime and drains per-model micro-batches.
//!
//! The registry is a control plane, not a static configuration: models
//! are [`ServerHandle::deploy`]ed, hot-[`ServerHandle::swap`]ped, and
//! [`ServerHandle::retire`]d at runtime. A swap is drain-safe: requests
//! already queued (or racing the swap) execute on the *old* backend to
//! completion, while every submit after the swap routes to the new plan —
//! no request is dropped and no reply changes shape. Retiring a model
//! drains its queue the same way, after which submits fail with
//! [`ServeError::UnknownModel`]. Per-model [`Metrics`] are keyed by model
//! id and survive swaps.
//!
//! Built on std threads + channels (tokio is unavailable in the offline
//! build — DESIGN.md §Substitutions); the architecture mirrors the async
//! version: submitters tag a request with a model id and get a
//! future-like [`Pending`] reply handle, each model's bounded queue
//! applies backpressure independently, and the executor pool (one thread
//! per registered model) drains micro-batches. Shutdown is explicit:
//! queued requests are drained with a structured
//! [`ServeError::ShuttingDown`] reply and counted in the per-model
//! [`Metrics`] — never silently dropped.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc as std_mpsc;
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::backend::{BackendSpec, InferBackend};
use crate::model::ModelChain;
use crate::obs::trace::NullSink;
use crate::obs::{SharedSink, TraceEvent, TraceSink};
use crate::optimizer::{FusionSetting, Plan};
use crate::util::error::{Error, Result};

use super::metrics::Metrics;

/// How often a blocked executor re-checks the shutdown flag; bounds
/// shutdown latency without requiring every handle clone to be dropped.
const STOP_POLL: Duration = Duration::from_millis(25);

/// One entry of the server's model registry.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Registry key; `submit` routes on this.
    pub id: String,
    /// What executes this model's requests, instantiated inside the
    /// executor thread via [`BackendSpec::connect`] — for engine/plan
    /// specs that is the **compile step**: the fusion setting is lowered
    /// once into a [`crate::exec::CompiledPlan`] with a warm
    /// offset-assigned pool, and every request after that runs
    /// allocation-free (params generated once, not per run).
    pub backend: BackendSpec,
    /// Bounded queue depth; senders get backpressure errors beyond this.
    pub queue_cap: usize,
    /// Max requests drained per executor wakeup (micro-batch).
    pub batch_max: usize,
}

impl ModelSpec {
    fn with_backend(id: impl Into<String>, backend: BackendSpec) -> Self {
        Self { id: id.into(), backend, queue_cap: 256, batch_max: 8 }
    }

    /// An AOT artifact entry served by the artifact runtime.
    pub fn artifact(
        id: impl Into<String>,
        dir: impl Into<PathBuf>,
        entry: impl Into<String>,
    ) -> Self {
        Self::with_backend(id, BackendSpec::Artifact { dir: dir.into(), entry: entry.into() })
    }

    /// A fusion setting served by the pure-Rust tracked executor — any
    /// zoo model without artifacts (and what the tests register).
    pub fn engine(id: impl Into<String>, model: ModelChain, setting: FusionSetting) -> Self {
        Self::with_backend(id, BackendSpec::Engine { model, setting })
    }

    /// A pre-solved [`Plan`] (e.g. [`crate::optimizer::Planner`] output
    /// loaded from disk); the model is resolved from the zoo by name.
    pub fn plan(id: impl Into<String>, plan: Plan) -> Self {
        Self::with_backend(id, BackendSpec::Plan { plan })
    }

    /// [`ModelSpec::plan`] from a plan JSON on disk — parse errors, an
    /// unresolvable model name, and span/model mismatches all surface at
    /// registration time, not through the first request.
    pub fn plan_file(id: impl Into<String>, path: impl AsRef<Path>) -> Result<Self> {
        Ok(Self::plan(id, load_validated_plan(path.as_ref())?))
    }

    #[must_use]
    pub fn with_queue(mut self, queue_cap: usize, batch_max: usize) -> Self {
        self.queue_cap = queue_cap;
        self.batch_max = batch_max;
        self
    }
}

/// Load + statically verify one plan file
/// ([`crate::analysis::verify_plan_file`]): parse, resolve the model
/// against the zoo, and run the full analyzer — the registration-time
/// gate behind [`ModelSpec::plan_file`]. A plan with `Error`-severity
/// findings is never registered; the error carries every rendered
/// diagnostic. Warning-only findings are logged to stderr and do not
/// block registration.
pub(super) fn load_validated_plan(path: &Path) -> Result<Plan> {
    let (plan, report) = crate::analysis::verify_plan_file(path)?;
    if report.has_errors() {
        return Err(crate::anyhow!(
            "plan {} rejected by static analysis:\n{}",
            path.display(),
            report.render()
        ));
    }
    for f in &report.findings {
        eprintln!("plan {}: {}", path.display(), f.render());
    }
    Ok(plan)
}

/// Single-model server configuration (the [`InferenceServer`] wrapper).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Artifact entry point to serve (e.g. `"model_fused"`).
    pub entry: String,
    /// Bounded queue depth; senders get backpressure errors beyond this.
    pub queue_cap: usize,
    /// Max requests drained per executor wakeup (micro-batch).
    pub batch_max: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { entry: "model_fused".into(), queue_cap: 256, batch_max: 8 }
    }
}

/// Structured request-path error: every reply states which model and why.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// `submit` named a model id that is not in the registry.
    UnknownModel { model_id: String },
    /// `deploy` named a model id already in the registry (use
    /// [`ServerHandle::swap`] to replace a live model).
    AlreadyDeployed { model_id: String },
    /// The model's bounded queue is full (backpressure).
    QueueFull { model_id: String },
    /// The server is stopping; queued requests are drained with this
    /// reply (and counted as `shutdown_drops` in [`Metrics`]).
    ShuttingDown { model_id: String },
    /// The model's backend failed to initialize.
    BackendInit { model_id: String, detail: String },
    /// The backend ran and failed.
    Failed { model_id: String, detail: String },
    /// The executor disappeared without replying (should not happen in
    /// orderly shutdown — the drain path replies `ShuttingDown` instead).
    Dropped { model_id: String },
}

impl ServeError {
    pub fn model_id(&self) -> &str {
        match self {
            ServeError::UnknownModel { model_id }
            | ServeError::AlreadyDeployed { model_id }
            | ServeError::QueueFull { model_id }
            | ServeError::ShuttingDown { model_id }
            | ServeError::BackendInit { model_id, .. }
            | ServeError::Failed { model_id, .. }
            | ServeError::Dropped { model_id } => model_id,
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownModel { model_id } => {
                write!(f, "unknown model '{model_id}' (not registered)")
            }
            ServeError::AlreadyDeployed { model_id } => {
                write!(f, "model '{model_id}' is already deployed (swap to replace it)")
            }
            ServeError::QueueFull { model_id } => {
                write!(f, "queue full for model '{model_id}' (backpressure)")
            }
            ServeError::ShuttingDown { model_id } => write!(
                f,
                "server shutting down: request for model '{model_id}' drained without execution"
            ),
            ServeError::BackendInit { model_id, detail } => {
                write!(f, "runtime init failed for model '{model_id}': {detail}")
            }
            ServeError::Failed { model_id, detail } => {
                write!(f, "inference failed for model '{model_id}': {detail}")
            }
            ServeError::Dropped { model_id } => {
                write!(f, "server dropped request for model '{model_id}'")
            }
        }
    }
}

impl std::error::Error for ServeError {}

impl From<ServeError> for Error {
    fn from(e: ServeError) -> Self {
        Error::msg(e)
    }
}

struct Request {
    input: Vec<f32>,
    enqueued: Instant,
    reply: std_mpsc::SyncSender<Result<Vec<f32>, ServeError>>,
}

/// Reply handle for one submitted request.
pub struct Pending {
    rx: std_mpsc::Receiver<Result<Vec<f32>, ServeError>>,
    model_id: String,
}

impl Pending {
    /// Block until the executor replies.
    pub fn wait(self) -> Result<Vec<f32>, ServeError> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(ServeError::Dropped { model_id: self.model_id.clone() }))
    }

    /// Non-blocking poll; `None` while still in flight.
    pub fn poll(&self) -> Option<Result<Vec<f32>, ServeError>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(std_mpsc::TryRecvError::Empty) => None,
            Err(std_mpsc::TryRecvError::Disconnected) => {
                Some(Err(ServeError::Dropped { model_id: self.model_id.clone() }))
            }
        }
    }
}

/// Submit-side state of one model's queue. `inflight` counts submits
/// between their shutdown check and the end of `try_send`, so the
/// executor's shutdown drain can wait out racing submitters instead of
/// leaking their requests (see `drain_shutdown`).
#[derive(Clone)]
struct QueueEntry {
    tx: std_mpsc::SyncSender<Request>,
    inflight: Arc<AtomicUsize>,
}

/// Handle for driving the control plane: submit requests to any live
/// model, and [`deploy`](Self::deploy) / [`swap`](Self::swap) /
/// [`retire`](Self::retire) models at runtime. Cheap to clone; every
/// clone sees the same live registry.
#[derive(Clone)]
pub struct ServerHandle {
    queues: Arc<RwLock<BTreeMap<String, QueueEntry>>>,
    metrics: Arc<Mutex<Metrics>>,
    stopping: Arc<AtomicBool>,
    workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    trace: SharedSink,
}

impl ServerHandle {
    /// Submit one inference for `model_id`; errors immediately when the
    /// model is unknown, the server is stopping, or the model's queue is
    /// full (backpressure). Await the result via [`Pending::wait`].
    pub fn submit(&self, model_id: &str, input: Vec<f32>) -> Result<Pending, ServeError> {
        if self.stopping.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown { model_id: model_id.into() });
        }
        // Clone the entry out of the read lock: a concurrent swap/retire
        // replaces the map entry without blocking on this submit, and a
        // send racing the swap lands on the *old* queue, whose executor
        // drains it on the old backend before exiting.
        let entry = self
            .queues
            .read()
            .unwrap()
            .get(model_id)
            .cloned()
            .ok_or_else(|| ServeError::UnknownModel { model_id: model_id.into() })?;
        entry.inflight.fetch_add(1, Ordering::SeqCst);
        let result = self.submit_inner(&entry, model_id, input);
        entry.inflight.fetch_sub(1, Ordering::SeqCst);
        result
    }

    fn submit_inner(
        &self,
        entry: &QueueEntry,
        model_id: &str,
        input: Vec<f32>,
    ) -> Result<Pending, ServeError> {
        // Checked *after* the in-flight increment: a submit that read
        // `stopping == false` is guaranteed visible to the shutdown drain
        // until its send completes.
        if self.stopping.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown { model_id: model_id.into() });
        }
        let (reply_tx, reply_rx) = std_mpsc::sync_channel(1);
        let req = Request { input, enqueued: Instant::now(), reply: reply_tx };
        // Count the queue slot before sending so the executor's decrement
        // can never observe a request its increment hasn't recorded.
        self.metrics.lock().unwrap().model_mut(model_id).queue_inc();
        match entry.tx.try_send(req) {
            Ok(()) => Ok(Pending { rx: reply_rx, model_id: model_id.into() }),
            Err(std_mpsc::TrySendError::Full(_)) => {
                let mut m = self.metrics.lock().unwrap();
                let mm = m.model_mut(model_id);
                mm.queue_dec();
                mm.record_rejection();
                Err(ServeError::QueueFull { model_id: model_id.into() })
            }
            Err(std_mpsc::TrySendError::Disconnected(_)) => {
                self.metrics.lock().unwrap().model_mut(model_id).queue_dec();
                Err(ServeError::ShuttingDown { model_id: model_id.into() })
            }
        }
    }

    /// Submit and block for the reply.
    pub fn infer(&self, model_id: &str, input: Vec<f32>) -> Result<Vec<f32>, ServeError> {
        self.submit(model_id, input)?.wait()
    }

    /// Snapshot of the per-model + aggregate metrics.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Route control-plane lifecycle events (deploy / swap / retire /
    /// drain / shutdown, plus registry-sync deltas) into `sink` —
    /// [`crate::obs::TraceLog`] to buffer them,
    /// [`crate::obs::StderrSink`] to print them live (`msfcnn serve
    /// --trace`). The default sink discards events. Every handle clone
    /// and executor thread shares the sink, so events from all of them
    /// interleave in emission order.
    pub fn set_trace_sink(&self, sink: impl TraceSink + 'static) {
        *self.trace.lock().unwrap() = Box::new(sink);
    }

    /// Emit one event into the current trace sink.
    pub(super) fn emit(&self, event: TraceEvent) {
        self.trace.lock().unwrap().emit(event);
    }

    /// Live model ids, sorted.
    pub fn model_ids(&self) -> Vec<String> {
        self.queues.read().unwrap().keys().cloned().collect()
    }

    /// Add a model to the live registry. Errors when the id is already
    /// deployed ([`ServeError::AlreadyDeployed`] — use [`Self::swap`] to
    /// replace a running model) or the server is shutting down. Backend
    /// initialization happens inside the new executor thread; init
    /// failures surface through the model's requests as
    /// [`ServeError::BackendInit`].
    pub fn deploy(&self, spec: ModelSpec) -> Result<(), ServeError> {
        let mut queues = self.queues.write().unwrap();
        if self.stopping.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown { model_id: spec.id.clone() });
        }
        if queues.contains_key(&spec.id) {
            return Err(ServeError::AlreadyDeployed { model_id: spec.id.clone() });
        }
        let id = spec.id.clone();
        let entry = self.spawn_executor(spec)?;
        queues.insert(id.clone(), entry);
        self.emit(TraceEvent::Deploy { model_id: id });
        Ok(())
    }

    /// Hot-swap a live model: requests already queued (or racing this
    /// call) drain to completion on the **old** backend; every submit
    /// that returns after `swap` routes to the new spec. The model keeps
    /// its id and its [`Metrics`] history. Errors with
    /// [`ServeError::UnknownModel`] when the id is not deployed.
    pub fn swap(&self, spec: ModelSpec) -> Result<(), ServeError> {
        let mut queues = self.queues.write().unwrap();
        if self.stopping.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown { model_id: spec.id.clone() });
        }
        if !queues.contains_key(&spec.id) {
            return Err(ServeError::UnknownModel { model_id: spec.id.clone() });
        }
        let id = spec.id.clone();
        let entry = self.spawn_executor(spec)?;
        // Dropping the old entry's sender is the drain signal: the old
        // executor keeps executing buffered requests and exits once the
        // channel reports disconnected (all racing submit clones gone).
        queues.insert(id.clone(), entry);
        self.emit(TraceEvent::Swap { model_id: id });
        Ok(())
    }

    /// Remove a model from the live registry. Queued requests drain to
    /// completion on its backend; subsequent submits fail with
    /// [`ServeError::UnknownModel`]. The model's [`Metrics`] entry is
    /// retained for post-mortem inspection.
    pub fn retire(&self, model_id: &str) -> Result<(), ServeError> {
        self.queues
            .write()
            .unwrap()
            .remove(model_id)
            .map(|_| ())
            .ok_or_else(|| ServeError::UnknownModel { model_id: model_id.into() })?;
        self.emit(TraceEvent::Retire { model_id: model_id.into() });
        Ok(())
    }

    /// Spawn the executor thread for `spec` and hand back its queue
    /// entry. Pre-registers the metrics entry so zero-traffic models
    /// still show up in per-model reports.
    fn spawn_executor(&self, spec: ModelSpec) -> Result<QueueEntry, ServeError> {
        let id = spec.id.clone();
        self.metrics.lock().unwrap().model_mut(&id);
        let (tx, rx) = std_mpsc::sync_channel::<Request>(spec.queue_cap.max(1));
        let inflight = Arc::new(AtomicUsize::new(0));
        let inflight_w = inflight.clone();
        let metrics_w = self.metrics.clone();
        let stopping_w = self.stopping.clone();
        let trace_w = self.trace.clone();
        let worker = std::thread::Builder::new()
            .name(format!("msfcnn-exec-{id}"))
            .spawn(move || worker_loop(spec, rx, inflight_w, metrics_w, stopping_w, trace_w))
            .map_err(|e| ServeError::Failed {
                model_id: id,
                detail: format!("executor thread spawn: {e}"),
            })?;
        // Reap executors that already drained and exited (earlier swaps /
        // retires), so a long-lived control plane with frequent swaps
        // doesn't accumulate finished JoinHandles until shutdown.
        let mut workers = self.workers.lock().unwrap();
        workers.retain(|w| !w.is_finished());
        workers.push(worker);
        Ok(QueueEntry { tx, inflight })
    }
}

/// A handle bound to one model id (the single-model ergonomic surface).
#[derive(Clone)]
pub struct BoundHandle {
    inner: ServerHandle,
    model_id: String,
}

impl BoundHandle {
    pub fn submit(&self, input: Vec<f32>) -> Result<Pending, ServeError> {
        self.inner.submit(&self.model_id, input)
    }

    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>, ServeError> {
        self.inner.infer(&self.model_id, input)
    }

    pub fn metrics(&self) -> Metrics {
        self.inner.metrics()
    }
}

/// The running control plane: one executor thread per live model, with
/// models deployed, swapped, and retired at runtime through
/// [`ServerHandle`].
pub struct MultiModelServer {
    handle: ServerHandle,
}

impl Default for MultiModelServer {
    fn default() -> Self {
        Self::new()
    }
}

impl MultiModelServer {
    /// Start an **empty** control plane: no models, ready for
    /// [`ServerHandle::deploy`] (e.g. from a
    /// [`crate::coordinator::PlanRegistry`] sync).
    pub fn new() -> Self {
        Self {
            handle: ServerHandle {
                queues: Arc::new(RwLock::new(BTreeMap::new())),
                metrics: Arc::new(Mutex::new(Metrics::default())),
                stopping: Arc::new(AtomicBool::new(false)),
                workers: Arc::new(Mutex::new(Vec::new())),
                trace: Arc::new(Mutex::new(Box::new(NullSink))),
            },
        }
    }

    /// Convenience over [`Self::new`] + [`ServerHandle::deploy`]: start
    /// with an initial registry. Errors on an empty or duplicate spec
    /// list. Backend initialization happens inside each executor thread;
    /// init errors surface through that model's requests as
    /// [`ServeError::BackendInit`].
    pub fn start(specs: Vec<ModelSpec>) -> Result<Self> {
        if specs.is_empty() {
            return Err(crate::anyhow!("empty model registry"));
        }
        let server = Self::new();
        for spec in specs {
            let id = spec.id.clone();
            server
                .handle
                .deploy(spec)
                .map_err(|e| crate::anyhow!("deploying '{id}': {e}"))?;
        }
        Ok(server)
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Handle bound to one registered model.
    pub fn bound_handle(&self, model_id: impl Into<String>) -> BoundHandle {
        BoundHandle { inner: self.handle(), model_id: model_id.into() }
    }

    /// Stop accepting requests, drain every queue with structured
    /// [`ServeError::ShuttingDown`] replies (recorded as `shutdown_drops`
    /// in the metrics), and join the executors — including executors
    /// already draining from earlier swaps/retires. Outstanding handle
    /// clones stay valid for metrics but all further submits fail fast.
    pub fn shutdown(self) {
        self.handle.stopping.store(true, Ordering::SeqCst);
        self.handle.emit(TraceEvent::Shutdown);
        self.handle.queues.write().unwrap().clear(); // drop the queue senders
        let workers: Vec<JoinHandle<()>> =
            self.handle.workers.lock().unwrap().drain(..).collect();
        for w in workers {
            let _ = w.join();
        }
    }
}

/// Reply a structured `ShuttingDown` to one drained request.
fn reply_shutdown(req: Request, metrics: &Mutex<Metrics>, id: &str) {
    {
        let mut m = metrics.lock().unwrap();
        let mm = m.model_mut(id);
        mm.queue_dec();
        mm.record_shutdown_drop();
    }
    let _ = req.reply.send(Err(ServeError::ShuttingDown { model_id: id.to_string() }));
}

/// Terminal drain: once the worker decided to exit, empty the queue with
/// structured replies and wait out any submit racing with the shutdown
/// flag (its `inflight` increment is visible before its `stopping` check,
/// so observing `inflight == 0` *before* an empty sweep proves no further
/// request can arrive). Returns the number of requests shed with a
/// structured `ShuttingDown` reply (reported in the executor's
/// [`TraceEvent::Drain`]).
fn drain_shutdown(
    rx: &std_mpsc::Receiver<Request>,
    inflight: &AtomicUsize,
    metrics: &Mutex<Metrics>,
    id: &str,
) -> usize {
    let mut drained = 0usize;
    loop {
        let quiescent = inflight.load(Ordering::SeqCst) == 0;
        let mut got = false;
        while let Ok(req) = rx.try_recv() {
            got = true;
            drained += 1;
            reply_shutdown(req, metrics, id);
        }
        if quiescent && !got {
            break;
        }
        std::thread::yield_now();
    }
    drained
}

fn worker_loop(
    spec: ModelSpec,
    rx: std_mpsc::Receiver<Request>,
    inflight: Arc<AtomicUsize>,
    metrics: Arc<Mutex<Metrics>>,
    stopping: Arc<AtomicBool>,
    trace: SharedSink,
) {
    let id = spec.id.clone();
    let batch_max = spec.batch_max.max(1);
    let emit_drain = |drained: usize| {
        trace
            .lock()
            .unwrap()
            .emit(TraceEvent::Drain { model_id: id.clone(), drained });
    };

    // The live backend is created *inside* the worker thread
    // (PJRT-style handles are not `Send`); the spec crossed instead.
    let mut backend: Box<dyn InferBackend> =
        match spec.backend.connect().map_err(|e| format!("{e:#}")) {
            Ok(b) => b,
            Err(detail) => {
                // Reply the structured init failure to everything that
                // ever arrives, until shutdown or all senders drop.
                loop {
                    match rx.recv_timeout(STOP_POLL) {
                        Ok(req) => {
                            metrics.lock().unwrap().model_mut(&id).queue_dec();
                            let _ = req.reply.send(Err(ServeError::BackendInit {
                                model_id: id.clone(),
                                detail: detail.clone(),
                            }));
                        }
                        Err(std_mpsc::RecvTimeoutError::Timeout) => {
                            if stopping.load(Ordering::SeqCst) {
                                break;
                            }
                        }
                        Err(std_mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                let drained = drain_shutdown(&rx, &inflight, &metrics, &id);
                emit_drain(drained);
                return;
            }
        };

    loop {
        let first = match rx.recv_timeout(STOP_POLL) {
            Ok(req) => req,
            Err(std_mpsc::RecvTimeoutError::Timeout) => {
                if stopping.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            Err(std_mpsc::RecvTimeoutError::Disconnected) => break,
        };
        if stopping.load(Ordering::SeqCst) {
            // Shutdown: structured replies, never silent drops. The rest
            // of the queue is emptied by the terminal drain below.
            reply_shutdown(first, &metrics, &id);
            break;
        }
        // Drain loop: block for one, then opportunistically micro-batch.
        let mut batch = vec![first];
        while batch.len() < batch_max {
            match rx.try_recv() {
                Ok(req) => batch.push(req),
                Err(_) => break,
            }
        }
        {
            let mut m = metrics.lock().unwrap();
            let mm = m.model_mut(&id);
            mm.record_batch(batch.len());
            for _ in &batch {
                mm.queue_dec();
            }
        }
        for req in batch {
            // Queue wait = submit to execution start; exec = backend run.
            // The recorded end-to-end sample is their sum, so the split
            // always reconciles with the total.
            let queue_wait = req.enqueued.elapsed();
            let exec_start = Instant::now();
            let res = backend.run(&req.input).map_err(|e| ServeError::Failed {
                model_id: id.clone(),
                detail: format!("{e:#}"),
            });
            metrics
                .lock()
                .unwrap()
                .model_mut(&id)
                .record_timed(queue_wait, exec_start.elapsed());
            let _ = req.reply.send(res);
        }
    }
    // Closes the submit/shutdown race: no request that made it into the
    // queue is ever dropped without a structured reply.
    let drained = drain_shutdown(&rx, &inflight, &metrics, &id);
    emit_drain(drained);
}

/// Single-model wrapper over [`MultiModelServer`]: serves one artifact
/// entry, registry key = entry name (the original seed API).
pub struct InferenceServer {
    inner: MultiModelServer,
    entry: String,
}

impl InferenceServer {
    /// Start serving `config.entry` from the artifact directory. The
    /// runtime is created *inside* the executor thread (PJRT handles are
    /// not `Send`); startup errors surface through the first request.
    pub fn start(
        artifact_dir: impl Into<std::path::PathBuf>,
        config: ServerConfig,
    ) -> Result<Self> {
        let spec = ModelSpec::artifact(&config.entry, artifact_dir, &config.entry)
            .with_queue(config.queue_cap, config.batch_max);
        let inner = MultiModelServer::start(vec![spec])?;
        Ok(Self { inner, entry: config.entry })
    }

    pub fn handle(&self) -> BoundHandle {
        self.inner.bound_handle(&self.entry)
    }

    /// Stop accepting requests, drain the queue with structured replies,
    /// and join the executor thread.
    pub fn shutdown(self) {
        self.inner.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_sane() {
        let c = ServerConfig::default();
        assert!(c.queue_cap > 0);
        assert!(c.batch_max > 0);
        assert_eq!(c.entry, "model_fused");
    }

    #[test]
    fn startup_error_surfaces_via_request() {
        let server =
            InferenceServer::start("/nonexistent-artifacts", ServerConfig::default()).unwrap();
        let h = server.handle();
        let err = h.infer(vec![0.0; 4]).unwrap_err();
        assert!(format!("{err:#}").contains("runtime init failed"), "{err:#}");
        assert_eq!(err.model_id(), "model_fused");
        drop(h);
        server.shutdown();
    }

    fn tiny_vanilla() -> (ModelChain, FusionSetting) {
        let m = crate::zoo::tiny_cnn();
        let setting = crate::optimizer::Planner::for_model(m.clone())
            .strategy(crate::optimizer::strategy::Vanilla)
            .setting()
            .unwrap();
        (m, setting)
    }

    #[test]
    fn unknown_model_is_structured() {
        let (m, setting) = tiny_vanilla();
        let server =
            MultiModelServer::start(vec![ModelSpec::engine("tiny", m, setting)]).unwrap();
        let h = server.handle();
        let err = h.submit("nope", vec![0.0; 4]).unwrap_err();
        assert_eq!(err, ServeError::UnknownModel { model_id: "nope".into() });
        drop(h);
        server.shutdown();
    }

    #[test]
    fn duplicate_ids_rejected() {
        let (m, setting) = tiny_vanilla();
        let specs = vec![
            ModelSpec::engine("m", m.clone(), setting.clone()),
            ModelSpec::engine("m", m, setting),
        ];
        assert!(MultiModelServer::start(specs).is_err());
    }

    #[test]
    fn serve_error_composes_with_question_mark() {
        fn downstream() -> std::result::Result<(), Box<dyn std::error::Error>> {
            Err(ServeError::UnknownModel { model_id: "x".into() })?
        }
        let e = downstream().unwrap_err();
        assert!(e.to_string().contains("unknown model 'x'"), "{e}");
    }

    #[test]
    fn empty_control_plane_accepts_runtime_deploys() {
        let (m, setting) = tiny_vanilla();
        let server = MultiModelServer::new();
        let h = server.handle();
        assert!(h.model_ids().is_empty());
        assert_eq!(
            h.infer("tiny", vec![0.0; 4]).unwrap_err(),
            ServeError::UnknownModel { model_id: "tiny".into() }
        );

        h.deploy(ModelSpec::engine("tiny", m.clone(), setting.clone())).unwrap();
        assert_eq!(h.model_ids(), vec!["tiny".to_string()]);
        let logits = h.infer("tiny", vec![0.5; 16 * 16 * 3]).unwrap();
        assert_eq!(logits.len(), 4);

        // Second deploy under the same id is a structured error…
        let err = h.deploy(ModelSpec::engine("tiny", m.clone(), setting.clone())).unwrap_err();
        assert_eq!(err, ServeError::AlreadyDeployed { model_id: "tiny".into() });
        // …swap of an unknown id likewise.
        let err = h.swap(ModelSpec::engine("other", m, setting)).unwrap_err();
        assert_eq!(err, ServeError::UnknownModel { model_id: "other".into() });

        h.retire("tiny").unwrap();
        assert!(h.model_ids().is_empty());
        assert_eq!(
            h.retire("tiny").unwrap_err(),
            ServeError::UnknownModel { model_id: "tiny".into() }
        );
        drop(h);
        server.shutdown();
    }

    #[test]
    fn plan_spec_serves_a_presolved_plan() {
        let plan = crate::optimizer::Planner::for_model(crate::zoo::tiny_cnn()).plan().unwrap();
        let server = MultiModelServer::start(vec![ModelSpec::plan("tiny", plan)]).unwrap();
        let h = server.handle();
        let logits = h.infer("tiny", vec![0.5; 16 * 16 * 3]).unwrap();
        assert_eq!(logits.len(), 4);
        drop(h);
        server.shutdown();
    }
}
