//! `msfcnn` — CLI for the msf-CNN reproduction.
//!
//! ```text
//! msfcnn zoo [--model NAME]
//! msfcnn optimize --model NAME [--f-max F|inf | --p-max-kb N]
//!                 [--latency-budget MS [--board B]] [--baselines]
//! msfcnn infer --plan FILE [--input FILE | --seed N] [--quant]
//! msfcnn profile --plan FILE [--runs N] [--seed N] [--top K] [--json FILE]
//! msfcnn simulate --model NAME [--f-max F|inf | --p-max-kb N] [--board B]
//! msfcnn tables [--which 1|2|3|5|5j|fig2|fig3|fig4|steps|all]
//! msfcnn verify [--plan FILE | --dir DIR | --zoo] [--json FILE]
//! msfcnn registry scan [--dir DIR]
//! msfcnn bench check [--infer FILE] [--serve FILE] [--kernels FILE]
//! msfcnn serve --registry DIR [--requests N] [--watch-ms MS] [--trace]
//! msfcnn serve [--artifacts DIR] [--entry NAME] [--requests N]
//! ```
//!
//! (Arg parsing is hand-rolled — `clap` is unavailable in the offline
//! vendor set; DESIGN.md §Substitutions.)

use msf_cnn::util::error::Result;
use msf_cnn::{anyhow, bail};

use msf_cnn::exec::Engine;
use msf_cnn::mcu::{board_by_name, estimate_latency_ms, BOARDS};
use msf_cnn::memory::Arena;
use msf_cnn::ops::{ParamGen, Tensor};
use msf_cnn::optimizer::{strategy, Constraint, Constraints, Plan, Planner, PlanStrategy};
use msf_cnn::report;
use msf_cnn::zoo;

const USAGE: &str = "\
msfcnn — patch-based multi-stage fusion for TinyML (msf-CNN reproduction)

USAGE:
  msfcnn zoo [--model NAME]
  msfcnn optimize --model NAME [--f-max F|inf | --p-max-kb N] [--baselines] [--save FILE]
  msfcnn optimize --model NAME --latency-budget MS [--board BOARD] [--p-max-kb N] [--save FILE]
  msfcnn infer --plan FILE [--input FILE | --seed N] [--quant]
  msfcnn profile --plan FILE [--runs N] [--seed N] [--top K] [--json FILE]
  msfcnn simulate --model NAME [--f-max F|inf | --p-max-kb N] [--board BOARD] [--trace]
  msfcnn tables [--which 1|2|3|5|5j|fig2|fig3|fig4|steps|all]
  msfcnn verify [--plan FILE | --dir DIR | --zoo] [--json FILE]
  msfcnn registry scan [--dir DIR]
  msfcnn bench check [--infer FILE] [--serve FILE] [--kernels FILE]
  msfcnn serve --registry DIR [--requests N] [--watch-ms MS] [--trace]
  msfcnn serve [--artifacts DIR] [--entry NAME] [--requests N]
  msfcnn serve --plan FILE [--id NAME] [--requests N]
";

/// Tiny flag parser: `--key value` and boolean `--key` pairs.
struct Args {
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("unexpected argument '{a}'\n\n{USAGE}"))?;
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(Self { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("bad --{key} '{v}': {e}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn parse_f_max(s: &str) -> Result<f64> {
    if s.eq_ignore_ascii_case("inf") {
        Ok(f64::INFINITY)
    } else {
        s.parse::<f64>().map_err(|e| anyhow!("bad f-max '{s}': {e}"))
    }
}

/// `(strategy, constraints)` the CLI flags denote: `--latency-budget` is
/// the latency-constrained walk (optionally joint with `--p-max-kb`),
/// `--f-max` is problem P1, `--p-max-kb` alone is problem P2, nothing is
/// the vanilla baseline.
fn pick_objective(args: &Args) -> Result<(&'static dyn PlanStrategy, Constraints)> {
    if let Some(ms) = args.get("latency-budget") {
        let budget: f64 = ms.parse().map_err(|e| anyhow!("bad --latency-budget '{ms}': {e}"))?;
        let board_name = args.get("board").unwrap_or("nucleo-f767zi");
        let board = board_by_name(board_name)
            .ok_or_else(|| anyhow!("unknown board '{board_name}'"))?;
        if args.has("f-max") {
            bail!("--latency-budget combines with --p-max-kb, not --f-max");
        }
        let mut c = Constraints::none().with(Constraint::LatencyMs { board, budget });
        if let Some(p) = args.get("p-max-kb") {
            let p: u64 = p.parse()?;
            c = c.with(Constraint::Ram(p * 1000));
        }
        return Ok((&strategy::LatencyAware, c));
    }
    match (args.get("f-max"), args.get("p-max-kb")) {
        (Some(f), None) => {
            let f = parse_f_max(f)?;
            Ok((&strategy::P1, Constraints::none().with(Constraint::Overhead(f))))
        }
        (None, Some(p)) => {
            let p: u64 = p.parse()?;
            Ok((&strategy::P2, Constraints::none().with(Constraint::Ram(p * 1000))))
        }
        (None, None) => Ok((&strategy::Vanilla, Constraints::none())),
        (Some(_), Some(_)) => bail!("choose either --f-max (P1) or --p-max-kb (P2)"),
    }
}

fn pick_plan(planner: &mut Planner, args: &Args) -> Result<Plan> {
    let (s, c) = pick_objective(args)?;
    planner.plan_with(s, c)
}

fn model_arg(args: &Args) -> Result<msf_cnn::model::ModelChain> {
    let name = args.get("model").ok_or_else(|| anyhow!("--model required\n\n{USAGE}"))?;
    zoo::by_name(name).ok_or_else(|| {
        anyhow!("unknown model '{name}' (known: {})", zoo::MODEL_NAMES.join(", "))
    })
}

/// Statically verify one plan file for `msfcnn verify`: print its
/// verdict, collect its report into `rows` (for `--json` export), and
/// return the number of `Error`-severity findings charged against it
/// (an unanalyzable file counts as one). Warnings are printed but never
/// counted against the exit code.
fn verify_one(
    path: &std::path::Path,
    rows: &mut Vec<(String, msf_cnn::analysis::AnalysisReport)>,
) -> Result<usize> {
    let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("plan").to_string();
    match msf_cnn::analysis::verify_plan_file(path) {
        Ok((_plan, report)) => {
            let errors = report.error_count();
            let warnings = report.warn_count();
            if errors == 0 {
                let warn_note = if warnings > 0 {
                    format!(", {warnings} warning(s)")
                } else {
                    String::new()
                };
                println!(
                    "{}: ok ({} buffer(s), {} step(s) checked{warn_note})",
                    path.display(),
                    report.buffers_checked,
                    report.steps_checked
                );
            } else {
                eprintln!("{}: {errors} error(s), {warnings} warning(s)", path.display());
            }
            for f in &report.findings {
                eprintln!("  {}", f.render());
            }
            rows.push((name, report));
            Ok(errors)
        }
        Err(e) => {
            eprintln!("{}: FAIL: {e:#}", path.display());
            Ok(1)
        }
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        print!("{USAGE}");
        return Ok(());
    };
    // `registry` and `bench` take a positional subcommand before flags.
    let (args, subcommand) = if cmd == "registry" || cmd == "bench" {
        let sub = argv.get(1).cloned();
        (Args::parse(argv.get(2..).unwrap_or(&[]))?, sub)
    } else {
        (Args::parse(&argv[1..])?, None)
    };

    match cmd {
        "zoo" => match args.get("model") {
            None => {
                println!("models: {}", zoo::MODEL_NAMES.join(", "));
                println!("\nboards (paper Table 4):");
                for b in BOARDS {
                    println!(
                        "  {:<18} {:<20} {:>4} MHz  {:>4} kB RAM  {:>5} kB flash",
                        b.name, b.mcu, b.mhz, b.ram_kb, b.flash_kb
                    );
                }
            }
            Some(_) => {
                let m = model_arg(&args)?;
                println!("{}: {} layers", m.name, m.num_layers());
                println!(
                    "vanilla peak RAM {:.3} kB, total MACs {}",
                    report::kb(m.vanilla_peak_ram()),
                    m.total_macs()
                );
                print!("{}", m.describe());
            }
        },
        "optimize" => {
            let m = model_arg(&args)?;
            let name = m.name.clone();
            let vanilla_peak = m.vanilla_peak_ram();
            let mut planner = Planner::for_model(m);
            let (n_nodes, n_edges) = {
                let dag = planner.dag();
                (dag.n_nodes, dag.num_edges())
            };
            println!(
                "{name}: {n_nodes} nodes, {n_edges} edges, vanilla peak {:.3} kB",
                report::kb(vanilla_peak)
            );
            let plan = if !args.has("f-max") && !args.has("p-max-kb") && !args.has("latency-budget")
            {
                planner.plan_with(&strategy::P2, Constraints::none())?
            } else {
                pick_plan(&mut planner, &args)?
            };
            let s = &plan.setting;
            println!(
                "setting {}  peak RAM {:.3} kB  F {:.3}  ({} fused blocks)",
                s.describe(),
                report::kb(s.cost.peak_ram),
                s.cost.overhead,
                s.num_fused_blocks()
            );
            if let Some(lat) = &plan.latency {
                println!("estimated latency {:.1} ms on {}", lat.estimate_ms, lat.board);
            }
            if args.has("baselines") {
                let baselines: [(&str, &dyn PlanStrategy); 3] = [
                    ("vanilla", &strategy::Vanilla),
                    ("heuristic", &strategy::HeadFusion),
                    ("streamnet", &strategy::StreamNet),
                ];
                for (name, b) in baselines {
                    if let Ok(p) = planner.plan_with(b, Constraints::none()) {
                        println!(
                            "  {name:<10} peak {:.3} kB  F {:.3}",
                            report::kb(p.cost().peak_ram),
                            p.cost().overhead
                        );
                    }
                }
            }
            if let Some(path) = args.get("save") {
                plan.save(path)?;
                println!("plan written to {path}");
            }
        }
        "infer" => {
            // Single-shot inference of a saved plan through the compiled
            // (allocation-free) path: compile once, run once, report the
            // analytic vs measured memory story.
            let path = args
                .get("plan")
                .ok_or_else(|| anyhow!("--plan FILE required\n\n{USAGE}"))?;
            let plan = Plan::load(path)?;
            let model = plan.resolve_model()?;
            let shape = model.shapes[0];
            let n = shape.elems() as usize;
            let data: Vec<f32> = match args.get("input") {
                Some(f) => {
                    let text = std::fs::read_to_string(f)
                        .map_err(|e| anyhow!("reading --input {f}: {e}"))?;
                    let vals: Vec<f32> = text
                        .split(|c: char| c.is_whitespace() || matches!(c, ',' | '[' | ']'))
                        .filter(|s| !s.is_empty())
                        .map(|s| {
                            s.parse::<f32>().map_err(|e| anyhow!("bad input value '{s}': {e}"))
                        })
                        .collect::<Result<_>>()?;
                    if vals.len() != n {
                        bail!(
                            "--input has {} values; model '{}' expects {n} ({shape})",
                            vals.len(),
                            plan.model
                        );
                    }
                    vals
                }
                None => {
                    let seed = args.get_usize("seed", 42)? as u64;
                    ParamGen::new(seed).fill(n, 2.0)
                }
            };
            println!("{}", plan.describe());
            let engine = Engine::new(model.clone());
            let t_compile = std::time::Instant::now();
            let compiled = engine.compile(&plan.setting);
            let mut pool = compiled.make_pool();
            let compile_ms = t_compile.elapsed().as_secs_f64() * 1e3;
            let input = Tensor::from_data(
                shape.h as usize,
                shape.w as usize,
                shape.c as usize,
                data,
            );
            let t_run = std::time::Instant::now();
            let r = compiled.run(&input, &mut pool);
            let run_ms = t_run.elapsed().as_secs_f64() * 1e3;
            let k = r.output.len().min(10);
            println!("logits[..{k}] = {:?}", &r.output[..k]);
            println!(
                "analytic peak {:.3} kB (Eq. 5-6) | measured pool peak {:.3} kB | static pool {:.3} kB",
                report::kb(plan.cost().peak_ram),
                report::kb(r.peak_ram),
                report::kb(compiled.pool_bytes()),
            );
            println!(
                "{} MACs | compile {compile_ms:.2} ms, run {run_ms:.2} ms",
                r.macs
            );
            if let Some(p) = &plan.pool {
                println!(
                    "plan memory map: {} buffers in a {} B pool (watermark {} B)",
                    p.buffers.len(),
                    p.pool_bytes,
                    p.watermark
                );
            }
            if args.has("quant") {
                // Int8 side-by-side: same plan lowered through qexec.
                // The spec rides in the plan when it ships one;
                // otherwise calibrate on the fly (deterministic input).
                let spec = match &plan.quant {
                    Some(s) => s.clone(),
                    None => msf_cnn::qexec::calibrate_default(&model, engine.params()),
                };
                let q = msf_cnn::qexec::QCompiledPlan::compile(
                    model.clone(),
                    plan.setting.clone(),
                    spec,
                );
                let mut qpool = q.make_pool();
                let mut qout = vec![0.0f32; q.output_len()];
                let t_q = std::time::Instant::now();
                q.run_into(input.as_map(), &mut qpool, &mut qout);
                let q_ms = t_q.elapsed().as_secs_f64() * 1e3;
                let k = qout.len().min(10);
                println!("int8 logits[..{k}] = {:?}", &qout[..k]);
                let mut max_abs = 0.0f32;
                let mut sq = 0.0f64;
                for (a, b) in qout.iter().zip(&r.output) {
                    let d = (a - b).abs();
                    max_abs = max_abs.max(d);
                    sq += (d as f64) * (d as f64);
                }
                let rmse = (sq / qout.len().max(1) as f64).sqrt();
                println!("int8 vs f32: max-abs {max_abs:.5}, RMSE {rmse:.5}");
                println!(
                    "int8 pool peak {:.3} kB | f32 measured peak {:.3} kB (both = Eq. 5-6 watermark) | int8 run {q_ms:.2} ms",
                    report::kb(q.measured_peak()),
                    report::kb(r.peak_ram),
                );
            }
        }
        "profile" => {
            // Per-step attribution of a saved plan's compiled hot path:
            // where the warm in-plan time goes, step by step, plus the
            // top-k dominating steps kernel work should start from.
            let path = args
                .get("plan")
                .ok_or_else(|| anyhow!("--plan FILE required\n\n{USAGE}"))?;
            let plan = Plan::load(path)?;
            let model = plan.resolve_model()?;
            let runs = args.get_usize("runs", 30)?;
            let top = args.get_usize("top", 3)?;
            let seed = args.get_usize("seed", 42)? as u64;
            let shape = model.shapes[0];
            let input = Tensor::from_data(
                shape.h as usize,
                shape.w as usize,
                shape.c as usize,
                ParamGen::new(seed).fill(shape.elems() as usize, 2.0),
            );
            let compiled = Engine::new(model).compile(&plan.setting);
            let profile = msf_cnn::obs::profile_plan(&compiled, &input, runs);
            println!("{}", report::step_table(&profile));
            println!("{}", report::top_k_table(&profile, top));
            if let Some(f) = args.get("json") {
                let doc = msf_cnn::obs::export::profile_snapshot(&profile);
                msf_cnn::obs::export::validate_profile_snapshot(&doc)?;
                std::fs::write(f, &doc).map_err(|e| anyhow!("writing --json {f}: {e}"))?;
                println!("profile written to {f}");
            }
        }
        "simulate" => {
            let m = model_arg(&args)?;
            let mut planner = Planner::for_model(m.clone());
            let s = pick_plan(&mut planner, &args)?.setting;
            let engine = Engine::new(m.clone());
            let mut gen = ParamGen::new(42);
            let shape = m.shapes[0];
            let input = Tensor::from_data(
                shape.h as usize,
                shape.w as usize,
                shape.c as usize,
                gen.fill(shape.elems() as usize, 2.0),
            );
            let mut arena = match args.get("board") {
                Some(bn) => {
                    let b = board_by_name(bn).ok_or_else(|| anyhow!("unknown board '{bn}'"))?;
                    Arena::with_budget(b.ram_bytes())
                }
                None => Arena::unbounded(),
            };
            if args.has("trace") {
                arena.enable_trace();
            }
            println!(
                "setting {}  predicted peak {:.3} kB",
                s.describe(),
                report::kb(s.cost.peak_ram)
            );
            match engine.run(&s, &input, &mut arena) {
                Ok(r) => {
                    println!(
                        "measured peak {:.3} kB, {} MACs, output[0..4] = {:?}",
                        report::kb(r.peak_ram),
                        r.macs,
                        &r.output[..r.output.len().min(4)]
                    );
                    if let Some(bn) = args.get("board") {
                        let b = board_by_name(bn).unwrap();
                        let lat = estimate_latency_ms(&m, &s, b);
                        println!(
                            "{}: simulated latency {:.1} ms (mac {:.0}c flash {:.0}c ovh {:.0}c)",
                            b.name,
                            lat.total_ms,
                            lat.mac_cycles,
                            lat.flash_cycles,
                            lat.overhead_cycles
                        );
                    }
                    if args.has("trace") {
                        println!("\nRAM over time (one row per alloc/free, # = live bytes):");
                        let peak = arena.peak_bytes().max(1);
                        for (label, delta, live) in arena.trace() {
                            let bars = (*live as f64 / peak as f64 * 50.0) as usize;
                            println!(
                                "  {:>10} {:>+9}  |{:<50}| {:.1} kB",
                                label,
                                delta,
                                "#".repeat(bars),
                                *live as f64 / 1000.0
                            );
                        }
                    }
                }
                Err(oom) => println!("OOM: {oom}"),
            }
        }
        "tables" => {
            let which = args.get("which").unwrap_or("all");
            let all = which == "all";
            if all || which == "1" {
                println!("{}", report::table1().1);
            }
            if all || which == "2" {
                println!("{}", report::table2().1);
            }
            if all || which == "3" {
                println!("{}", report::table3().1);
            }
            if all || which == "5" {
                println!("{}", report::table5().1);
            }
            if all || which == "5j" {
                println!("{}", report::table5_joint().1);
            }
            if all || which == "fig2" {
                println!("{}", report::fig2_pooling().1);
            }
            if all || which == "fig3" {
                println!("{}", report::fig3_dense().1);
            }
            if all || which == "fig4" {
                println!("Fig 4 series (CSV):\n{}", report::fig4_series().1);
            }
            if all || which == "ablations" {
                println!("{}", report::ablation_cache_schemes().1);
                let m = zoo::quickstart();
                println!("{}", report::ablation_output_granularity(&m, 0, 3).1);
            }
            if all || which == "steps" {
                println!("{}", report::table_steps().1);
            }
        }
        "verify" => {
            // The static plan verifier as a CLI gate: analyze plan
            // JSON(s) without executing them; nonzero exit on
            // `Error`-severity findings (warnings are surfaced but never
            // fail the gate). `--json FILE` exports every analyzed
            // plan's structured report under `msfcnn.analysis/v1`.
            let mut checked = 0usize;
            let mut errors = 0usize;
            let mut rows: Vec<(String, msf_cnn::analysis::AnalysisReport)> = Vec::new();
            if let Some(path) = args.get("plan") {
                checked += 1;
                errors += verify_one(std::path::Path::new(path), &mut rows)?;
            } else if let Some(dir) = args.get("dir") {
                let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
                    .map_err(|e| anyhow!("reading {dir}: {e}"))?
                    .filter_map(|e| e.ok())
                    .map(|e| e.path())
                    .filter(|p| {
                        p.is_file()
                            && p.extension().and_then(|x| x.to_str()) == Some("json")
                    })
                    .collect();
                files.sort();
                if files.is_empty() {
                    bail!("no plan JSON files in {dir}");
                }
                for path in files {
                    checked += 1;
                    errors += verify_one(&path, &mut rows)?;
                }
            } else if args.has("zoo") {
                // Plan the whole zoo across every strategy, write the
                // artifacts to a temp dir, and verify each — the CI
                // `analysis` gate (`make analysis`).
                let strategies: [(&str, &dyn PlanStrategy); 5] = [
                    ("p1", &strategy::P1),
                    ("p2", &strategy::P2),
                    ("vanilla", &strategy::Vanilla),
                    ("head-fusion", &strategy::HeadFusion),
                    ("streamnet", &strategy::StreamNet),
                ];
                let dir = std::env::temp_dir()
                    .join(format!("msfcnn-verify-zoo-{}", std::process::id()));
                let _ = std::fs::remove_dir_all(&dir);
                std::fs::create_dir_all(&dir)
                    .map_err(|e| anyhow!("creating {}: {e}", dir.display()))?;
                for name in zoo::MODEL_NAMES {
                    let m = zoo::by_name(name).expect("zoo name");
                    // One calibration per model serves every strategy's
                    // quantized variant: boundary tensors are identical
                    // under any fusion setting.
                    let params: Vec<msf_cnn::ops::LayerParams> = m
                        .layers
                        .iter()
                        .enumerate()
                        .map(|(i, l)| msf_cnn::ops::LayerParams::for_layer(l, i))
                        .collect();
                    let spec = msf_cnn::qexec::calibrate_default(&m, &params);
                    let mut planner = Planner::for_model(m);
                    for (sname, s) in strategies {
                        let plan = match planner.plan_with(s, Constraints::none()) {
                            Ok(p) => p,
                            Err(e) => {
                                eprintln!("WARN: {name} x {sname}: infeasible, skipped ({e:#})");
                                continue;
                            }
                        };
                        let path = dir.join(format!("{name}--{sname}.plan.json"));
                        plan.save(&path)?;
                        checked += 1;
                        errors += verify_one(&path, &mut rows)?;
                        // The int8 twin: same setting + calibrated spec,
                        // proved over byte-granular mixed-width intervals
                        // plus the numeric value-range pass (accumulator
                        // overflow, calibration well-formedness,
                        // saturation risk).
                        let qplan = plan.with_quant(spec.clone());
                        let qpath = dir.join(format!("{name}--{sname}--int8.plan.json"));
                        qplan.save(&qpath)?;
                        checked += 1;
                        errors += verify_one(&qpath, &mut rows)?;
                    }
                }
                let _ = std::fs::remove_dir_all(&dir);
            } else {
                bail!("verify needs --plan FILE, --dir DIR, or --zoo\n\n{USAGE}");
            }
            // Export before gating so a failing run still leaves the
            // structured report behind for diagnosis.
            if let Some(f) = args.get("json") {
                if rows.is_empty() {
                    bail!("--json {f}: no analyzable plans to export");
                }
                let doc = msf_cnn::obs::export::analysis_snapshot(&rows);
                msf_cnn::obs::export::validate_analysis_snapshot(&doc)?;
                std::fs::write(f, &doc).map_err(|e| anyhow!("writing --json {f}: {e}"))?;
                println!("analysis report written to {f}");
            }
            if errors > 0 {
                bail!("{errors} error(s) across {checked} plan(s)");
            }
            let warnings: usize = rows.iter().map(|(_, r)| r.warn_count()).sum();
            if warnings > 0 {
                println!("verify: {checked} plan(s) deployable ({warnings} warning(s))");
            } else {
                println!("verify: {checked} plan(s) clean");
            }
        }
        "registry" => {
            use msf_cnn::coordinator::PlanRegistry;
            match subcommand.as_deref() {
                Some("scan") => {
                    let dir = args.get("dir").unwrap_or("plans");
                    let mut registry = PlanRegistry::open(dir)?;
                    let report = registry.scan()?;
                    for (path, err) in &report.errors {
                        eprintln!("WARN: {}: {err}", path.display());
                    }
                    for c in &report.conflicts {
                        eprintln!(
                            "WARN: {}: multiple files define '{}'; using {}",
                            c.skipped.display(),
                            c.model_id,
                            c.chosen.display()
                        );
                    }
                    // Static-analysis verdict per (re)loaded file: why a
                    // plan was rejected (or deployed with warnings),
                    // finding by finding.
                    for v in &report.verdicts {
                        if v.is_clean() {
                            continue;
                        }
                        if v.is_deployable() {
                            eprintln!(
                                "WARN: {} ('{}') deployed with {} warning(s):",
                                v.path.display(),
                                v.model_id,
                                v.findings.len()
                            );
                        } else {
                            eprintln!(
                                "WARN: {} ('{}') rejected by static analysis:",
                                v.path.display(),
                                v.model_id
                            );
                        }
                        for f in &v.findings {
                            eprintln!("  {f}");
                        }
                    }
                    println!("plan registry {dir}: {} model(s)", registry.len());
                    for e in registry.entries() {
                        let lat = match &e.plan.latency {
                            Some(l) => format!("  {:.1} ms @ {}", l.estimate_ms, l.board),
                            None => String::new(),
                        };
                        println!(
                            "  {:<14} v{}  {:<22} [{}]  {:.3} kB{}  ({})",
                            e.model_id,
                            e.version,
                            e.plan.strategy,
                            e.plan.constraints.describe(),
                            report::kb(e.plan.cost().peak_ram),
                            lat,
                            e.path.display()
                        );
                    }
                }
                other => bail!(
                    "unknown registry subcommand {:?} (expected: scan)\n\n{USAGE}",
                    other.unwrap_or("<none>")
                ),
            }
        }
        "bench" => match subcommand.as_deref() {
            Some("check") => {
                // Schema gate over the committed perf snapshots: a
                // drifted BENCH_*.json fails here (and in CI) instead of
                // silently rotting the perf trajectory.
                use msf_cnn::obs::export;
                let checks: [(&str, fn(&str) -> Result<()>); 3] = [
                    (
                        args.get("infer").unwrap_or("BENCH_infer.json"),
                        export::validate_infer_snapshot,
                    ),
                    (
                        args.get("serve").unwrap_or("BENCH_serve.json"),
                        export::validate_serve_snapshot,
                    ),
                    (
                        args.get("kernels").unwrap_or("BENCH_kernels.json"),
                        export::validate_kernels_snapshot,
                    ),
                ];
                let mut failures = 0usize;
                for (path, validate) in checks {
                    let verdict = std::fs::read_to_string(path)
                        .map_err(|e| anyhow!("reading {path}: {e}"))
                        .and_then(|text| validate(&text));
                    match verdict {
                        Ok(()) => println!("{path}: ok (schema {})", export::BENCH_SCHEMA),
                        Err(e) => {
                            eprintln!("{path}: FAIL: {e}");
                            failures += 1;
                        }
                    }
                }
                if failures > 0 {
                    bail!("{failures} snapshot(s) failed the schema check");
                }
            }
            other => bail!(
                "unknown bench subcommand {:?} (expected: check)\n\n{USAGE}",
                other.unwrap_or("<none>")
            ),
        },
        "serve" if args.has("registry") => {
            use msf_cnn::coordinator::{MultiModelServer, PlanRegistry};
            let dir = args.get("registry").unwrap();
            let requests = args.get_usize("requests", 100)?;
            let watch_ms = args.get_usize("watch-ms", 0)?;

            let mut registry = PlanRegistry::open(dir)?;
            let server = MultiModelServer::new();
            let handle = server.handle();
            if args.has("trace") {
                // Control-plane lifecycle events (deploy/swap/retire/
                // drain + registry sync deltas) go to stderr.
                handle.set_trace_sink(msf_cnn::obs::StderrSink);
            }
            let report = registry.sync(&handle)?;
            for (path, err) in &report.errors {
                eprintln!("WARN: {}: {err}", path.display());
            }
            for c in &report.conflicts {
                eprintln!(
                    "WARN: {}: multiple files define '{}'; using {}",
                    c.skipped.display(),
                    c.model_id,
                    c.chosen.display()
                );
            }
            // Say *why* a plan was rejected (or deployed with
            // warnings): the scan's static-analysis verdicts, one
            // rendered finding per line.
            for v in &report.verdicts {
                if v.is_clean() {
                    continue;
                }
                if v.is_deployable() {
                    eprintln!(
                        "WARN: {} ('{}') deployed with {} warning(s):",
                        v.path.display(),
                        v.model_id,
                        v.findings.len()
                    );
                } else {
                    eprintln!(
                        "WARN: {} ('{}') rejected by static analysis:",
                        v.path.display(),
                        v.model_id
                    );
                }
                for f in &v.findings {
                    eprintln!("  {f}");
                }
            }
            if registry.is_empty() {
                bail!("no deployable plans in {dir}");
            }
            println!("serving {} model(s) from {dir}:", registry.len());
            for e in registry.entries() {
                println!("  {} v{}: {}", e.model_id, e.version, e.plan.describe());
            }

            // Round-robin traffic across the live registry; between
            // rounds, optionally re-sync so file changes deploy/swap/
            // retire models mid-serve (the directory watch).
            let mut gen = ParamGen::new(123);
            let mut ok = 0usize;
            let mut sent = 0usize;
            let t0 = std::time::Instant::now();
            while sent < requests {
                let ids = handle.model_ids();
                if ids.is_empty() {
                    println!("registry drained to empty; stopping after {sent} request(s)");
                    break;
                }
                for id in ids {
                    if sent >= requests {
                        break;
                    }
                    let Some(entry) = registry.latest(&id) else { continue };
                    let model = entry.plan.resolve_model()?;
                    let input = gen.fill(model.shapes[0].elems() as usize, 2.0);
                    sent += 1;
                    if handle.infer(&id, input).is_ok() {
                        ok += 1;
                    }
                }
                if watch_ms > 0 {
                    std::thread::sleep(std::time::Duration::from_millis(watch_ms as u64));
                    let changes = registry.sync(&handle)?;
                    if !changes.is_empty() {
                        println!(
                            "registry change: +{:?} ~{:?} -{:?} ({} error(s), {} conflict(s))",
                            changes.added,
                            changes.updated,
                            changes.removed,
                            changes.errors.len(),
                            changes.conflicts.len()
                        );
                    }
                }
            }
            let dt = t0.elapsed();
            println!(
                "{ok}/{requests} ok in {:.2}s ({:.1} req/s)",
                dt.as_secs_f64(),
                ok as f64 / dt.as_secs_f64()
            );
            for (id, m) in handle.metrics().per_model() {
                if let Some(stats) = m.stats() {
                    let split = match (m.queue_wait_mean_us(), m.exec_mean_us()) {
                        (Some(w), Some(x)) => format!("  | wait {w:.0} us  exec {x:.0} us"),
                        _ => String::new(),
                    };
                    println!(
                        "  {id:<14} {} done | p50 {:>6.0} us  p95 {:>6.0} us  p99 {:>6.0} us{split}",
                        stats.count, stats.p50_us, stats.p95_us, stats.p99_us
                    );
                }
            }
            drop(handle);
            server.shutdown();
        }
        "serve" => {
            use msf_cnn::coordinator::{ModelSpec, MultiModelServer};
            let requests = args.get_usize("requests", 100)?;
            // Either a pre-solved plan file (the Planner's output) or an
            // AOT artifact entry — both serve through the same backend
            // trait and registry.
            let (spec, input_len) = match args.get("plan") {
                Some(path) => {
                    let plan = Plan::load(path)?;
                    let id = args.get("id").unwrap_or(&plan.model).to_string();
                    let model = plan.resolve_model()?;
                    let input_len = model.shapes[0].elems() as usize;
                    println!("serving {}", plan.describe());
                    (ModelSpec::plan(id, plan), input_len)
                }
                None => {
                    let artifacts = args.get("artifacts").unwrap_or("artifacts").to_string();
                    let entry = args.get("entry").unwrap_or("model_fused").to_string();
                    (ModelSpec::artifact(entry.clone(), artifacts, entry), 32 * 32 * 3)
                }
            };
            let id = spec.id.clone();
            let server = MultiModelServer::start(vec![spec])?;
            let handle = server.handle();
            let mut gen = ParamGen::new(123);
            let mut ok = 0usize;
            let t0 = std::time::Instant::now();
            for _ in 0..requests {
                let input = gen.fill(input_len, 2.0);
                if handle.infer(&id, input).is_ok() {
                    ok += 1;
                }
            }
            let dt = t0.elapsed();
            if let Some(stats) = handle.metrics().stats() {
                println!(
                    "{ok}/{requests} ok in {:.2}s ({:.1} req/s); p50 {:.0}us p95 {:.0}us p99 {:.0}us",
                    dt.as_secs_f64(),
                    ok as f64 / dt.as_secs_f64(),
                    stats.p50_us,
                    stats.p95_us,
                    stats.p99_us
                );
            }
            drop(handle);
            server.shutdown();
        }
        other => {
            bail!("unknown command '{other}'\n\n{USAGE}");
        }
    }
    Ok(())
}
