//! Tracking arena allocator — the MCU RAM-pool model.
//!
//! The executor ([`crate::exec`]) routes every tensor/cache allocation
//! through an [`Arena`], which tracks the live-byte watermark and enforces
//! a board's RAM budget. This is how the repo *measures* peak RAM (to be
//! checked against the analytical Eq. 5–6 predictions) instead of merely
//! predicting it.

mod planner;

pub use planner::{
    assign_offsets, layout_from_schedule, max_concurrent, plan_layout, plan_pool,
    schedule_intervals, BufRole, PlannedBuffer, PoolBuffer, PoolLayout, PoolPlan, ScheduledBuf,
};
pub(crate) use planner::{band_sizes, conv_end_of, stash_needed};

use std::collections::HashMap;

/// Handle to a live arena allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AllocId(u64);

/// Out-of-memory against the configured budget.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OomError {
    pub requested: u64,
    pub live: u64,
    pub budget: u64,
    pub label: String,
}

impl std::fmt::Display for OomError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "OOM: alloc '{}' of {} B with {} B live exceeds budget {} B",
            self.label, self.requested, self.live, self.budget
        )
    }
}

impl std::error::Error for OomError {}

/// A RAM pool with live-set tracking, peak watermark, and optional budget.
#[derive(Debug)]
pub struct Arena {
    budget: Option<u64>,
    live: u64,
    peak: u64,
    next_id: u64,
    allocs: HashMap<AllocId, (u64, String)>,
    /// (label, bytes, live_after) event log for post-mortem RAM profiles.
    trace: Vec<(String, i64, u64)>,
    trace_enabled: bool,
}

impl Arena {
    /// Unbounded arena (peak measurement only).
    pub fn unbounded() -> Self {
        Self::new(None)
    }

    /// Arena with a hard budget (a board's RAM size).
    pub fn with_budget(budget_bytes: u64) -> Self {
        Self::new(Some(budget_bytes))
    }

    fn new(budget: Option<u64>) -> Self {
        Self {
            budget,
            live: 0,
            peak: 0,
            next_id: 0,
            allocs: HashMap::new(),
            trace: Vec::new(),
            trace_enabled: false,
        }
    }

    /// Record every alloc/free for RAM-over-time profiles (`msfcnn simulate
    /// --trace`).
    pub fn enable_trace(&mut self) {
        self.trace_enabled = true;
    }

    pub fn alloc(&mut self, bytes: u64, label: impl Into<String>) -> Result<AllocId, OomError> {
        let label = label.into();
        if let Some(budget) = self.budget {
            if self.live + bytes > budget {
                return Err(OomError {
                    requested: bytes,
                    live: self.live,
                    budget,
                    label,
                });
            }
        }
        self.live += bytes;
        self.peak = self.peak.max(self.live);
        let id = AllocId(self.next_id);
        self.next_id += 1;
        if self.trace_enabled {
            self.trace.push((label.clone(), bytes as i64, self.live));
        }
        self.allocs.insert(id, (bytes, label));
        Ok(id)
    }

    pub fn free(&mut self, id: AllocId) {
        if let Some((bytes, label)) = self.allocs.remove(&id) {
            self.live -= bytes;
            if self.trace_enabled {
                self.trace.push((label, -(bytes as i64), self.live));
            }
        }
    }

    pub fn live_bytes(&self) -> u64 {
        self.live
    }

    /// High-water mark since construction (or last [`reset_peak`]).
    pub fn peak_bytes(&self) -> u64 {
        self.peak
    }

    pub fn reset_peak(&mut self) {
        self.peak = self.live;
    }

    pub fn budget(&self) -> Option<u64> {
        self.budget
    }

    /// The alloc/free event log (label, signed bytes, live-after).
    pub fn trace(&self) -> &[(String, i64, u64)] {
        &self.trace
    }

    /// Labels of currently-live allocations (leak diagnostics in tests).
    pub fn live_labels(&self) -> Vec<&str> {
        self.allocs.values().map(|(_, l)| l.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_watermark() {
        let mut a = Arena::unbounded();
        let x = a.alloc(100, "x").unwrap();
        let y = a.alloc(50, "y").unwrap();
        a.free(x);
        let _z = a.alloc(20, "z").unwrap();
        assert_eq!(a.peak_bytes(), 150);
        assert_eq!(a.live_bytes(), 70);
        a.free(y);
        assert_eq!(a.live_bytes(), 20);
    }

    #[test]
    fn budget_enforced() {
        let mut a = Arena::with_budget(128);
        let _x = a.alloc(100, "x").unwrap();
        let err = a.alloc(29, "y").unwrap_err();
        assert_eq!(err.live, 100);
        assert_eq!(err.budget, 128);
        assert!(a.alloc(28, "y2").is_ok());
    }

    #[test]
    fn double_free_is_noop() {
        let mut a = Arena::unbounded();
        let x = a.alloc(10, "x").unwrap();
        a.free(x);
        a.free(x);
        assert_eq!(a.live_bytes(), 0);
    }

    #[test]
    fn trace_records_events() {
        let mut a = Arena::unbounded();
        a.enable_trace();
        let x = a.alloc(10, "t").unwrap();
        a.free(x);
        assert_eq!(a.trace().len(), 2);
        assert_eq!(a.trace()[0], ("t".to_string(), 10, 10));
        assert_eq!(a.trace()[1], ("t".to_string(), -10, 0));
    }

    #[test]
    fn reset_peak_rebases_to_live() {
        let mut a = Arena::unbounded();
        let x = a.alloc(100, "x").unwrap();
        a.free(x);
        let _y = a.alloc(10, "y").unwrap();
        a.reset_peak();
        assert_eq!(a.peak_bytes(), 10);
    }
}
