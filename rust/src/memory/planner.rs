//! Scheduling-based memory planner — the §10 "Memory Optimization for CNN
//! layers" baseline family (TinyEngine / vMCU / MoDeL): reuse one RAM pool
//! across tensor lifetimes by offset assignment, **without** changing the
//! execution order or tiling. The paper's contrast: such planners "still
//! generate a complete output tensor for each layer", so their floor is
//! the largest I+O pair — exactly where patch-based fusion keeps winning.
//!
//! Greedy best-fit offset assignment over lifetime intervals (the classic
//! offset-calculation heuristic).

use crate::model::ModelChain;

/// One planned buffer: the boundary tensor `v_i`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedBuffer {
    pub tensor: usize,
    pub offset: u64,
    pub bytes: u64,
    /// Alive during layer steps `[birth, death]` (inclusive).
    pub birth: usize,
    pub death: usize,
}

/// Result of planning a model's vanilla execution into one pool.
#[derive(Debug, Clone)]
pub struct PoolPlan {
    pub buffers: Vec<PlannedBuffer>,
    pub pool_bytes: u64,
}

/// Lifetime of boundary tensor `v_i` in layer steps: born when produced
/// (step `i-1`; the input is born at step 0), dies after its last
/// consumer (layer `i`, or a later residual add).
fn lifetime(model: &ModelChain, i: usize) -> (usize, usize) {
    let birth = i.saturating_sub(1);
    let mut death = i.min(model.num_layers() - 1);
    for (j, l) in model.layers.iter().enumerate() {
        if l.residual_from == Some(i) {
            death = death.max(j);
        }
    }
    (birth, death)
}

/// Plan the vanilla execution of `model` into a single reused pool.
pub fn plan_pool(model: &ModelChain) -> PoolPlan {
    let n = model.num_layers();
    // Tensors v_0..v_n with sizes and lifetimes.
    let mut tensors: Vec<(usize, u64, usize, usize)> = (0..=n)
        .map(|i| {
            let (b, d) = lifetime(model, i);
            (i, model.tensor_bytes(i), b, d)
        })
        .collect();
    // Classic heuristic: place big tensors first.
    tensors.sort_by(|a, b| b.1.cmp(&a.1));

    let mut placed: Vec<PlannedBuffer> = Vec::new();
    for (tensor, bytes, birth, death) in tensors {
        if bytes == 0 {
            continue;
        }
        // Collect forbidden intervals from overlapping-lifetime buffers.
        let mut overlaps: Vec<(u64, u64)> = placed
            .iter()
            .filter(|p| !(p.death < birth || death < p.birth))
            .map(|p| (p.offset, p.offset + p.bytes))
            .collect();
        overlaps.sort();
        // First gap that fits (best-fit on a sorted free list).
        let mut offset = 0u64;
        for (lo, hi) in overlaps {
            if offset + bytes <= lo {
                break;
            }
            offset = offset.max(hi);
        }
        placed.push(PlannedBuffer { tensor, offset, bytes, birth, death });
    }
    let pool_bytes = placed.iter().map(|p| p.offset + p.bytes).max().unwrap_or(0);
    PoolPlan { buffers: placed, pool_bytes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::Planner;
    use crate::zoo;

    fn assert_no_live_overlap(plan: &PoolPlan) {
        for (i, a) in plan.buffers.iter().enumerate() {
            for b in plan.buffers.iter().skip(i + 1) {
                let lifetimes_overlap = !(a.death < b.birth || b.death < a.birth);
                let space_overlap = a.offset < b.offset + b.bytes && b.offset < a.offset + a.bytes;
                assert!(
                    !(lifetimes_overlap && space_overlap),
                    "buffers v{} and v{} collide",
                    a.tensor,
                    b.tensor
                );
            }
        }
    }

    #[test]
    fn plan_is_collision_free_and_bounded() {
        for name in ["quickstart", "lenet", "kws", "mn2-vww5"] {
            let m = zoo::by_name(name).unwrap();
            let plan = plan_pool(&m);
            assert_no_live_overlap(&plan);
            // Lower bound: the largest I+O pair must coexist.
            assert!(plan.pool_bytes >= m.vanilla_peak_ram());
            // Upper bound: never worse than keeping everything alive.
            let total: u64 = (0..=m.num_layers()).map(|i| m.tensor_bytes(i)).sum();
            assert!(plan.pool_bytes <= total);
        }
    }

    #[test]
    fn planner_floor_equals_biggest_io_pair() {
        // The §10 contrast: a scheduling-based planner cannot go below the
        // largest adjacent I+O pair (full maps still materialize)...
        let m = zoo::mcunet_vww5();
        let plan = plan_pool(&m);
        assert_eq!(plan.pool_bytes, m.vanilla_peak_ram());
    }

    #[test]
    fn fusion_beats_the_planner() {
        // ...while msf-CNN's patch-based execution goes far below it.
        for (_, m) in zoo::paper_models() {
            let plan = plan_pool(&m);
            let msf = Planner::for_model(m.clone()).plan().unwrap().setting;
            assert!(
                (msf.cost.peak_ram as f64) < 0.5 * plan.pool_bytes as f64,
                "{}: fusion {} vs planner {}",
                m.name,
                msf.cost.peak_ram,
                plan.pool_bytes
            );
        }
    }

    #[test]
    fn residual_lifetimes_respected() {
        let m = zoo::mcunet_vww5();
        let plan = plan_pool(&m);
        // Every skip source must stay allocated until its consumer.
        for (j, l) in m.layers.iter().enumerate() {
            if let Some(src) = l.residual_from {
                let buf = plan.buffers.iter().find(|p| p.tensor == src).unwrap();
                assert!(buf.death >= j, "v{src} freed before skip consumer {j}");
            }
        }
    }
}
