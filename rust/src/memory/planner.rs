//! Scheduling-based memory planner: offset assignment of buffer lifetimes
//! into one reused RAM pool.
//!
//! Two planning surfaces share the same greedy best-fit allocator
//! ([`assign_offsets`]):
//!
//! * [`plan_pool`] — the §10 "Memory Optimization for CNN layers" baseline
//!   family (TinyEngine / vMCU / MoDeL): vanilla execution, boundary
//!   tensors only, **without** changing execution order or tiling. The
//!   paper's contrast: such planners "still generate a complete output
//!   tensor for each layer", so their floor is the largest I+O pair —
//!   exactly where patch-based fusion keeps winning.
//! * [`plan_layout`] — the compile-once generalization: the **full fused
//!   schedule** of a [`FusionSetting`] (band-buffer pyramids,
//!   iterative-tail accumulators, residual stashes, logits), with lifetime
//!   intervals derived from a tick-accurate replay of the executor's span
//!   walk ([`schedule_intervals`]). Its `watermark` reproduces the
//!   interpreted engine's arena high-water mark event for event, and its
//!   `pool_bytes` is the static pool a deploy artifact bakes in
//!   ([`crate::optimizer::Plan`] serializes the layout).

use crate::model::{LayerKind, ModelChain};
use crate::optimizer::FusionSetting;

/// One planned buffer: the boundary tensor `v_i` (vanilla [`plan_pool`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedBuffer {
    pub tensor: usize,
    pub offset: u64,
    pub bytes: u64,
    /// Alive during layer steps `[birth, death]` (inclusive).
    pub birth: usize,
    pub death: usize,
}

/// Result of planning a model's vanilla execution into one pool.
#[derive(Debug, Clone)]
pub struct PoolPlan {
    pub buffers: Vec<PlannedBuffer>,
    pub pool_bytes: u64,
}

/// Lifetime of boundary tensor `v_i` in layer steps: born when produced
/// (step `i-1`; the input is born at step 0), dies after its last
/// consumer (layer `i` — clamped to the final layer step for the output
/// tensor — or a later residual add).
fn lifetime(model: &ModelChain, i: usize) -> (usize, usize) {
    let birth = i.saturating_sub(1);
    let mut death = i.min(model.num_layers() - 1);
    for (j, l) in model.layers.iter().enumerate() {
        if l.residual_from == Some(i) {
            death = death.max(j);
        }
    }
    (birth, death)
}

/// Greedy big-first best-fit offset assignment over half-open lifetime
/// intervals `(bytes, birth, death)` (the classic offset-calculation
/// heuristic). Returns each item's offset (input order) and the total
/// pool size. Two items whose intervals overlap never overlap in space.
pub fn assign_offsets(items: &[(u64, usize, usize)]) -> (Vec<u64>, u64) {
    let mut order: Vec<usize> = (0..items.len()).collect();
    // Big tensors first; stable on ties by original index.
    order.sort_by(|&x, &y| items[y].0.cmp(&items[x].0).then(x.cmp(&y)));

    let mut offsets = vec![0u64; items.len()];
    let mut placed: Vec<usize> = Vec::new();
    let mut total = 0u64;
    for &i in &order {
        let (bytes, birth, death) = items[i];
        if bytes == 0 {
            continue;
        }
        // Forbidden intervals from lifetime-overlapping placed buffers.
        let mut overlaps: Vec<(u64, u64)> = placed
            .iter()
            .filter(|&&j| {
                let (_, jb, jd) = items[j];
                jb < death && birth < jd
            })
            .map(|&j| (offsets[j], offsets[j] + items[j].0))
            .collect();
        overlaps.sort();
        // First gap that fits (best-fit on a sorted free list).
        let mut offset = 0u64;
        for (lo, hi) in overlaps {
            if offset + bytes <= lo {
                break;
            }
            offset = offset.max(hi);
        }
        offsets[i] = offset;
        total = total.max(offset + bytes);
        placed.push(i);
    }
    (offsets, total)
}

/// Max concurrent footprint of half-open `(bytes, birth, death)` intervals
/// — the watermark any offset assignment is lower-bounded by.
pub fn max_concurrent(items: &[(u64, usize, usize)]) -> u64 {
    let mut events: Vec<(usize, i64)> = Vec::with_capacity(items.len() * 2);
    for &(bytes, birth, death) in items {
        if bytes == 0 {
            continue;
        }
        events.push((birth, bytes as i64));
        events.push((death, -(bytes as i64)));
    }
    // Frees sort before allocs at the same tick (negative delta first).
    events.sort();
    let mut live = 0i64;
    let mut peak = 0i64;
    for (_, delta) in events {
        live += delta;
        peak = peak.max(live);
    }
    peak as u64
}

/// Plan the vanilla execution of `model` into a single reused pool.
pub fn plan_pool(model: &ModelChain) -> PoolPlan {
    let n = model.num_layers();
    // Tensors v_0..v_n with sizes and (inclusive) lifetimes.
    let tensors: Vec<(usize, u64, usize, usize)> = (0..=n)
        .map(|i| {
            let (b, d) = lifetime(model, i);
            (i, model.tensor_bytes(i), b, d)
        })
        .collect();
    let items: Vec<(u64, usize, usize)> =
        tensors.iter().map(|&(_, bytes, b, d)| (bytes, b, d + 1)).collect();
    let (offsets, pool_bytes) = assign_offsets(&items);
    let buffers = tensors
        .iter()
        .enumerate()
        .filter(|&(_, &(_, bytes, _, _))| bytes > 0)
        .map(|(idx, &(tensor, bytes, birth, death))| PlannedBuffer {
            tensor,
            offset: offsets[idx],
            bytes,
            birth,
            death,
        })
        .collect();
    PoolPlan { buffers, pool_bytes }
}

/// What a scheduled buffer *is* in the fused execution timeline — the key
/// the compiled executor uses to wire steps to pool slices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufRole {
    /// Materialized model input `v_0` (only when the first span is a
    /// single layer; fused heads stream the input).
    Input,
    /// Boundary tensor `v_tensor` produced by a span.
    Boundary { tensor: usize },
    /// Band-buffer pyramid of the fused span whose conv pyramid covers
    /// layers `[a, b)`.
    Bands { a: usize, b: usize },
    /// Residual stash of boundary tensor `v_tensor` held across spans.
    Stash { tensor: usize },
    /// Iterative-tail global-pool accumulator of span `span`.
    PoolAcc { span: usize },
    /// Iterative-tail dense accumulator of model layer `layer`.
    DenseAcc { layer: usize },
    /// Final logits vector of an iterative-tail span.
    Logits,
}

/// One buffer of the fused schedule with its lifetime interval.
///
/// `bytes`/`[birth, death)` follow the **accounting** convention of the
/// tracking [`crate::memory::Arena`] (int8-element boundary/band sizing,
/// 4-byte accumulators) — tick-for-tick the interpreted engine's
/// alloc/free order, so `max_concurrent` over them equals the engine's
/// measured arena peak. `elems`/`[birth, rt_death)` describe the f32
/// **runtime storage** the compiled executor actually reserves
/// (`rt_death >= death`: the iterative-tail chain reads each accumulator
/// while the accounting has already moved on).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScheduledBuf {
    pub role: BufRole,
    pub label: String,
    /// Accounting bytes (Arena / Eq. 5–6 convention).
    pub bytes: u64,
    /// Runtime f32 element count.
    pub elems: usize,
    /// Runtime view dims `(h, w, c)`; vectors are `(1, 1, len)`, band
    /// pyramids `(1, 1, elems)` (sub-shaped by [`crate::ops::BandGeom`]).
    pub dims: (usize, usize, usize),
    /// Allocation tick.
    pub birth: usize,
    /// Accounting free tick (exclusive).
    pub death: usize,
    /// Runtime free tick (exclusive, `>= death`).
    pub rt_death: usize,
}

fn alloc_buf(
    bufs: &mut Vec<ScheduledBuf>,
    tick: &mut usize,
    role: BufRole,
    label: String,
    bytes: u64,
    dims: (usize, usize, usize),
) -> usize {
    let id = bufs.len();
    bufs.push(ScheduledBuf {
        role,
        label,
        bytes,
        elems: dims.0 * dims.1 * dims.2,
        dims,
        birth: *tick,
        death: usize::MAX,
        rt_death: usize::MAX,
    });
    *tick += 1;
    id
}

fn free_buf(bufs: &mut [ScheduledBuf], tick: &mut usize, id: usize) {
    bufs[id].death = *tick;
    *tick += 1;
}

/// Whether span `[a, b)` stashes `v_a` at its start: some later layer
/// skips from `a` and the skip crosses a span boundary (skips inside one
/// fused span are handled by the block executor). The **single** copy of
/// the predicate the interpreted engine, the schedule replay, and the
/// step compiler all share — drift here would silently desynchronize the
/// pool layout from execution.
pub(crate) fn stash_needed(model: &ModelChain, a: usize, b: usize, fused: bool) -> bool {
    let wanted = model
        .layers
        .iter()
        .enumerate()
        .any(|(j, l)| l.residual_from == Some(a) && (j >= b || !fused) && j >= a);
    wanted
        && model
            .layers
            .iter()
            .enumerate()
            .any(|(j, l)| l.residual_from == Some(a) && !(fused && j < b))
}

/// End of the conv pyramid of fused span `[a, b)`: the GlobalAvgPool
/// index for an iterative-tail span (§7), `b` otherwise. Panics on an
/// iterative-tail span without a GlobalAvgPool (malformed setting).
pub(crate) fn conv_end_of(model: &ModelChain, a: usize, b: usize, iter_tail: bool) -> usize {
    if iter_tail {
        (a..b)
            .find(|&i| matches!(model.layers[i].kind, LayerKind::GlobalAvgPool))
            .expect("iterative-tail edge without GlobalAvgPool")
    } else {
        b
    }
}

/// Band-pyramid sizes of fused span `[a, conv_end)`:
/// `(accounting bytes, f32 storage elements)` — per-layer input bands
/// (heights from the Eq. 11 recursion) plus the one-row output band.
/// Accounting uses `elem_bytes` sizing, matching the engine's single
/// `bands:` arena allocation.
pub(crate) fn band_sizes(model: &ModelChain, a: usize, conv_end: usize) -> (u64, usize) {
    let eb = model.elem_bytes as u64;
    let t = crate::fusion::band_heights(model, a, conv_end, 1);
    let mut bytes = 0u64;
    let mut elems = 0usize;
    for (idx, &rows) in t.iter().enumerate() {
        let s = model.input_of(a + idx);
        bytes += rows as u64 * s.w as u64 * s.c as u64 * eb;
        elems += rows as usize * s.w as usize * s.c as usize;
    }
    let os = model.output_of(conv_end - 1);
    bytes += os.w as u64 * os.c as u64 * eb;
    elems += os.w as usize * os.c as usize;
    (bytes, elems)
}

/// Replay `setting`'s span walk as a tick sequence of buffer allocations
/// and frees — the lifetime oracle both [`plan_layout`] (accounting) and
/// the compiled executor (runtime storage) consume. The event order
/// mirrors [`crate::exec::Engine::run`] exactly, so the accounting
/// watermark reconciles with the interpreted engine's measured peak.
pub fn schedule_intervals(model: &ModelChain, setting: &FusionSetting) -> Vec<ScheduledBuf> {
    let n = model.num_layers();
    let mut bufs: Vec<ScheduledBuf> = Vec::new();
    let mut tick = 0usize;

    let map_dims = |i: usize| {
        let s = model.shapes[i];
        (s.h as usize, s.w as usize, s.c as usize)
    };

    let first_fused = setting.spans.first().map(|&(a, b, _)| b - a > 1).unwrap_or(false);
    let mut cur: Option<usize> = None;
    if !first_fused {
        cur = Some(alloc_buf(
            &mut bufs,
            &mut tick,
            BufRole::Input,
            "v0:input".to_string(),
            model.tensor_bytes(0),
            map_dims(0),
        ));
    }

    let mut stash: Vec<Option<usize>> = vec![None; n + 1];

    for (si, &(a, b, iter_tail)) in setting.spans.iter().enumerate() {
        let fused = b - a > 1;

        // Stash the current tensor if a later layer skips from here —
        // same decision (and tick position) as the engine.
        if stash_needed(model, a, b, fused) {
            stash[a] = Some(alloc_buf(
                &mut bufs,
                &mut tick,
                BufRole::Stash { tensor: a },
                format!("stash:v{a}"),
                model.tensor_bytes(a),
                map_dims(a),
            ));
        }

        if fused {
            let conv_end = conv_end_of(model, a, b, iter_tail);
            // Band pyramid: analytically-equivalent accounting (same
            // formula as the engine's single `bands:` allocation).
            let (band_bytes, band_elems) = band_sizes(model, a, conv_end);
            let os = model.output_of(conv_end - 1);
            let bands = alloc_buf(
                &mut bufs,
                &mut tick,
                BufRole::Bands { a, b: conv_end },
                format!("bands:{a}..{conv_end}"),
                band_bytes,
                (1, 1, band_elems),
            );

            if iter_tail {
                let gp = conv_end;
                let c_last = os.c as usize;
                let pool_acc = alloc_buf(
                    &mut bufs,
                    &mut tick,
                    BufRole::PoolAcc { span: si },
                    "iter-pool-acc".to_string(),
                    4 * c_last as u64,
                    (1, 1, c_last),
                );
                free_buf(&mut bufs, &mut tick, pool_acc);
                let mut accs = vec![pool_acc];
                for li in gp + 1..b {
                    let dout = model.layers[li].cout as usize;
                    let acc = alloc_buf(
                        &mut bufs,
                        &mut tick,
                        BufRole::DenseAcc { layer: li },
                        format!("iter-dense:{li}"),
                        4 * dout as u64,
                        (1, 1, dout),
                    );
                    free_buf(&mut bufs, &mut tick, acc);
                    accs.push(acc);
                }
                if let Some(c) = cur.take() {
                    free_buf(&mut bufs, &mut tick, c);
                }
                free_buf(&mut bufs, &mut tick, bands);
                let c_final = model.output_of(b - 1).c as usize;
                let logits = alloc_buf(
                    &mut bufs,
                    &mut tick,
                    BufRole::Logits,
                    "logits".to_string(),
                    4 * c_final as u64,
                    (1, 1, c_final),
                );
                // Runtime: the accumulator chain is read back (pool acc ->
                // dense -> ... -> logits copy) after its accounting frees,
                // so its storage must survive until the logits exist.
                let extend = bufs[logits].birth + 1;
                for id in accs {
                    bufs[id].rt_death = extend;
                }
                cur = Some(logits);
            } else {
                let out = alloc_buf(
                    &mut bufs,
                    &mut tick,
                    BufRole::Boundary { tensor: b },
                    format!("v{b}"),
                    model.tensor_bytes(b),
                    map_dims(b),
                );
                if let Some(c) = cur.take() {
                    free_buf(&mut bufs, &mut tick, c);
                }
                free_buf(&mut bufs, &mut tick, bands);
                cur = Some(out);
            }
        } else {
            // Single layer.
            let li = a;
            let l = &model.layers[li];
            let (bytes, dims, label) = match l.kind {
                LayerKind::GlobalAvgPool => {
                    (4 * l.cout as u64, (1, 1, l.cout as usize), format!("v{b}:gap"))
                }
                LayerKind::Dense => {
                    (4 * l.cout as u64, (1, 1, l.cout as usize), format!("v{b}:fc"))
                }
                _ => (model.tensor_bytes(b), map_dims(b), format!("v{b}")),
            };
            let out = alloc_buf(
                &mut bufs,
                &mut tick,
                BufRole::Boundary { tensor: b },
                label,
                bytes,
                dims,
            );
            if let Some(src) = l.residual_from {
                if let Some(sid) = stash[src].take() {
                    free_buf(&mut bufs, &mut tick, sid);
                }
            }
            if let Some(c) = cur.take() {
                free_buf(&mut bufs, &mut tick, c);
            }
            cur = Some(out);
        }
    }

    if let Some(c) = cur.take() {
        free_buf(&mut bufs, &mut tick, c);
    }
    // Any leftover stash (skip whose consumer was inside a fused span).
    for sid in stash.into_iter().flatten() {
        free_buf(&mut bufs, &mut tick, sid);
    }

    for buf in bufs.iter_mut() {
        debug_assert_ne!(buf.death, usize::MAX, "buffer never freed: {}", buf.label);
        if buf.rt_death == usize::MAX {
            buf.rt_death = buf.death;
        } else {
            buf.rt_death = buf.rt_death.max(buf.death);
        }
    }
    bufs
}

/// One buffer of a serialized pool layout.
///
/// `bytes` is the accounting byte size; `elems`/`elem_bytes` declare the
/// element width behind it (`bytes == elems * elem_bytes`): 1 byte per
/// activation element, 4 per i32/f32 accumulator element — the mixed
/// widths of Eq. 5/6 pricing, checked by
/// [`crate::analysis::verify_layout`]. Layouts parsed from pre-width
/// JSON carry `elems == 0` ("width undeclared"), which skips the check.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolBuffer {
    pub label: String,
    pub offset: u64,
    pub bytes: u64,
    /// Element count behind `bytes` (0 = undeclared, legacy layouts).
    pub elems: u64,
    /// Bytes per element (1 activations, 4 accumulators; 0 = undeclared).
    pub elem_bytes: u32,
    /// Alive during ticks `[birth, death)` of the schedule replay.
    pub birth: usize,
    pub death: usize,
}

/// The static pool layout of a fused schedule: offset-assigned buffers,
/// the pool size, and the max concurrent footprint (== the interpreted
/// engine's measured arena peak for the same setting). Serialized into
/// [`crate::optimizer::Plan`] so a deploy artifact fully describes its
/// memory map.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolLayout {
    pub buffers: Vec<PoolBuffer>,
    pub pool_bytes: u64,
    pub watermark: u64,
}

impl PoolLayout {
    /// First pair of buffers that are alive at the same tick **and**
    /// overlap in pool space — `None` for a sound layout. Thin wrapper
    /// over [`PoolLayout::collisions`] for callers that only need a
    /// yes/no probe.
    pub fn collision(&self) -> Option<(&PoolBuffer, &PoolBuffer)> {
        self.collisions().into_iter().next()
    }

    /// **Every** pair of buffers that are alive at the same tick and
    /// overlap in pool space — empty for a sound layout. Layouts built
    /// by [`assign_offsets`] are collision-free by construction; this is
    /// the integrity check for layouts read back from disk (run by the
    /// static verifier behind [`crate::optimizer::Plan::validate`] and
    /// `msfcnn verify`, which reports all defects, not just the first).
    pub fn collisions(&self) -> Vec<(&PoolBuffer, &PoolBuffer)> {
        let mut pairs = Vec::new();
        for (i, a) in self.buffers.iter().enumerate() {
            for b in self.buffers.iter().skip(i + 1) {
                let live = a.birth < b.death && b.birth < a.death;
                let space = a.offset < b.offset + b.bytes && b.offset < a.offset + a.bytes;
                if live && space {
                    pairs.push((a, b));
                }
            }
        }
        pairs
    }
}

/// Offset-assign a schedule's accounting intervals into one static pool —
/// the **single** layout builder behind both the serialized
/// [`crate::optimizer::Plan`] memory map and
/// [`crate::exec::CompiledPlan`]'s accounting layout (the two must stay
/// byte-identical).
pub fn layout_from_schedule(sched: &[ScheduledBuf]) -> PoolLayout {
    let items: Vec<(u64, usize, usize)> =
        sched.iter().map(|s| (s.bytes, s.birth, s.death)).collect();
    let (offsets, pool_bytes) = assign_offsets(&items);
    let watermark = max_concurrent(&items);
    let buffers = sched
        .iter()
        .zip(&offsets)
        .filter(|(s, _)| s.bytes > 0)
        .map(|(s, &offset)| {
            debug_assert_eq!(
                s.bytes % s.elems.max(1) as u64,
                0,
                "{}: accounting bytes not a whole element width",
                s.label
            );
            PoolBuffer {
                label: s.label.clone(),
                offset,
                bytes: s.bytes,
                elems: s.elems as u64,
                elem_bytes: (s.bytes / s.elems.max(1) as u64) as u32,
                birth: s.birth,
                death: s.death,
            }
        })
        .collect();
    PoolLayout { buffers, pool_bytes, watermark }
}

/// Offset-assign the full fused schedule of `(model, setting)` into one
/// static pool (accounting-byte sizing).
pub fn plan_layout(model: &ModelChain, setting: &FusionSetting) -> PoolLayout {
    layout_from_schedule(&schedule_intervals(model, setting))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::Planner;
    use crate::zoo;

    fn assert_no_live_overlap(plan: &PoolPlan) {
        for (i, a) in plan.buffers.iter().enumerate() {
            for b in plan.buffers.iter().skip(i + 1) {
                let lifetimes_overlap = !(a.death < b.birth || b.death < a.birth);
                let space_overlap = a.offset < b.offset + b.bytes && b.offset < a.offset + a.bytes;
                assert!(
                    !(lifetimes_overlap && space_overlap),
                    "buffers v{} and v{} collide",
                    a.tensor,
                    b.tensor
                );
            }
        }
    }

    #[test]
    fn plan_is_collision_free_and_bounded() {
        for name in ["quickstart", "lenet", "kws", "mn2-vww5"] {
            let m = zoo::by_name(name).unwrap();
            let plan = plan_pool(&m);
            assert_no_live_overlap(&plan);
            // Lower bound: the largest I+O pair must coexist.
            assert!(plan.pool_bytes >= m.vanilla_peak_ram());
            // Upper bound: never worse than keeping everything alive.
            let total: u64 = (0..=m.num_layers()).map(|i| m.tensor_bytes(i)).sum();
            assert!(plan.pool_bytes <= total);
        }
    }

    #[test]
    fn planner_floor_equals_biggest_io_pair() {
        // The §10 contrast: a scheduling-based planner cannot go below the
        // largest adjacent I+O pair (full maps still materialize)...
        let m = zoo::mcunet_vww5();
        let plan = plan_pool(&m);
        assert_eq!(plan.pool_bytes, m.vanilla_peak_ram());
    }

    #[test]
    fn fusion_beats_the_planner() {
        // ...while msf-CNN's patch-based execution goes far below it.
        for (_, m) in zoo::paper_models() {
            let plan = plan_pool(&m);
            let msf = Planner::for_model(m.clone()).plan().unwrap().setting;
            assert!(
                (msf.cost.peak_ram as f64) < 0.5 * plan.pool_bytes as f64,
                "{}: fusion {} vs planner {}",
                m.name,
                msf.cost.peak_ram,
                plan.pool_bytes
            );
        }
    }

    #[test]
    fn residual_lifetimes_respected() {
        let m = zoo::mcunet_vww5();
        let plan = plan_pool(&m);
        // Every skip source must stay allocated until its consumer.
        for (j, l) in m.layers.iter().enumerate() {
            if let Some(src) = l.residual_from {
                let buf = plan.buffers.iter().find(|p| p.tensor == src).unwrap();
                assert!(buf.death >= j, "v{src} freed before skip consumer {j}");
            }
        }
    }

    #[test]
    fn assign_offsets_packs_disjoint_lifetimes() {
        // A [0,2) and B [2,4) never coexist: same offset, pool = max size.
        let (offs, total) = assign_offsets(&[(100, 0, 2), (80, 2, 4)]);
        assert_eq!(offs, vec![0, 0]);
        assert_eq!(total, 100);
        // Overlapping C forces a stack.
        let (offs, total) = assign_offsets(&[(100, 0, 2), (80, 2, 4), (10, 0, 4)]);
        assert_eq!(offs[2], 100);
        assert_eq!(total, 110);
        assert_eq!(max_concurrent(&[(100, 0, 2), (80, 2, 4), (10, 0, 4)]), 110);
    }

    #[test]
    fn fused_schedule_watermark_matches_arena_convention() {
        // The schedule replay must reproduce the interpreted engine's
        // measured peak; the vanilla case has a closed form (Eq. 5).
        use crate::optimizer::strategy::Vanilla;
        for name in ["quickstart", "tiny", "kws"] {
            let m = zoo::by_name(name).unwrap();
            let vanilla = Planner::for_model(m.clone())
                .strategy(Vanilla)
                .setting()
                .unwrap();
            let layout = plan_layout(&m, &vanilla);
            assert_eq!(layout.watermark, m.vanilla_peak_ram(), "{name}");
            assert!(layout.pool_bytes >= layout.watermark, "{name}");
        }
    }

    #[test]
    fn collisions_reports_every_offending_pair() {
        let buf = |label: &str, offset: u64, bytes: u64, birth: usize, death: usize| PoolBuffer {
            label: label.to_string(),
            offset,
            bytes,
            elems: bytes,
            elem_bytes: 1,
            birth,
            death,
        };
        // a/b/c all live over [0, 4) and all packed at offset 0: three
        // colliding pairs. d lives later and may legally reuse the bytes.
        let layout = PoolLayout {
            buffers: vec![
                buf("a", 0, 100, 0, 4),
                buf("b", 0, 80, 1, 4),
                buf("c", 50, 60, 0, 2),
                buf("d", 0, 100, 4, 6),
            ],
            pool_bytes: 110,
            watermark: 240,
        };
        let pairs = layout.collisions();
        assert_eq!(pairs.len(), 3);
        let names: Vec<(&str, &str)> =
            pairs.iter().map(|(a, b)| (a.label.as_str(), b.label.as_str())).collect();
        assert_eq!(names, vec![("a", "b"), ("a", "c"), ("b", "c")]);
        // The old single-probe API surfaces the first of them.
        let first = layout.collision().unwrap();
        assert_eq!((first.0.label.as_str(), first.1.label.as_str()), ("a", "b"));
        // Fresh layouts stay collision-free through the exhaustive check.
        let m = zoo::quickstart();
        let fused = Planner::for_model(m.clone()).setting().unwrap();
        assert!(plan_layout(&m, &fused).collisions().is_empty());
    }

    #[test]
    fn layout_declares_mixed_element_widths() {
        // Eq. 5/6 pricing: activations at 1 byte/element, accumulator
        // stashes at 4 — the layout carries both, consistently.
        let m = zoo::kws_cnn();
        let setting = Planner::for_model(m.clone()).setting().unwrap();
        let layout = plan_layout(&m, &setting);
        for b in &layout.buffers {
            assert_eq!(b.bytes, b.elems * b.elem_bytes as u64, "{}", b.label);
            assert!(b.elem_bytes == 1 || b.elem_bytes == 4, "{}: {}", b.label, b.elem_bytes);
        }
        assert!(layout.buffers.iter().any(|b| b.elem_bytes == 1));
        // The classifier head (gap/dense/logits accumulators) is f32/i32
        // priced at 4 bytes per element.
        assert!(layout.buffers.iter().any(|b| b.elem_bytes == 4));
    }

    #[test]
    fn fused_schedule_has_band_and_boundary_roles() {
        let m = zoo::quickstart();
        let fused = Planner::for_model(m.clone()).setting().unwrap();
        assert!(fused.num_fused_blocks() >= 1);
        let sched = schedule_intervals(&m, &fused);
        assert!(sched.iter().any(|s| matches!(s.role, BufRole::Bands { .. })));
        // Runtime lifetimes never end before accounting lifetimes.
        for s in &sched {
            assert!(s.rt_death >= s.death, "{}", s.label);
            assert!(s.birth < s.death, "{}", s.label);
        }
    }
}
