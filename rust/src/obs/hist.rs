//! Fixed-bucket latency histograms and the shared percentile rank.
//!
//! A long-running server cannot retain every latency sample; the
//! coordinator keeps an exact recent-sample ring per model for tight
//! percentiles *and* one of these histograms for lossless-count,
//! O(1)-memory aggregation. Histograms with identical (compile-time)
//! bucket bounds are **mergeable**: per-model histograms fold into a
//! fleet-wide view by adding counts, which exact sample windows cannot
//! do without re-shipping samples.

/// Upper bounds (inclusive, microseconds) of the fixed buckets: a
/// 1–2–5 ladder from 1 µs to 20 s. One extra overflow bucket catches
/// everything beyond the last bound.
pub const BUCKET_BOUNDS_US: [f64; 23] = [
    1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1e3, 2e3, 5e3, 1e4, 2e4, 5e4, 1e5,
    2e5, 5e5, 1e6, 2e6, 5e6, 1e7, 2e7,
];

const N_BUCKETS: usize = BUCKET_BOUNDS_US.len() + 1;

/// Ceil-based nearest-rank percentile over an ascending-sorted slice:
/// the smallest sample such that at least `ceil(p * n)` samples are <=
/// it (the textbook nearest-rank definition). The previous
/// `((n - 1) * p).round()` index biased small windows low — e.g. p95 of
/// 10 samples picked index 9 only by rounding luck; ceil makes the rank
/// exact: p50 of 1..=100 is 50, p95 is 95, p99 is 99.
pub fn nearest_rank(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of an empty sample set");
    let rank = (p * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// A fixed-bucket latency histogram (microseconds). Cheap to clone,
/// cheap to [`merge`](Self::merge), and bounded in memory regardless of
/// how many samples it absorbs. Quantiles are bucket-resolution
/// estimates: the upper bound of the bucket containing the rank,
/// clamped to the observed max.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: [u64; N_BUCKETS],
    count: u64,
    sum_us: f64,
    min_us: f64,
    max_us: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self {
            counts: [0; N_BUCKETS],
            count: 0,
            sum_us: 0.0,
            min_us: f64::INFINITY,
            max_us: 0.0,
        }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Index of the bucket holding `us`.
    fn bucket_of(us: f64) -> usize {
        BUCKET_BOUNDS_US
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(N_BUCKETS - 1)
    }

    /// Record one latency sample.
    pub fn record_us(&mut self, us: f64) {
        self.counts[Self::bucket_of(us)] += 1;
        self.count += 1;
        self.sum_us += us;
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
    }

    /// Fold `other` into `self` (identical compile-time bucket bounds,
    /// so merging is element-wise addition).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact mean over every recorded sample.
    pub fn mean_us(&self) -> Option<f64> {
        if self.count > 0 {
            Some(self.sum_us / self.count as f64)
        } else {
            None
        }
    }

    /// Largest recorded sample (exact).
    pub fn max_us(&self) -> Option<f64> {
        (self.count > 0).then_some(self.max_us)
    }

    /// Bucket-resolution quantile estimate: the upper bound of the
    /// bucket containing the ceil-based nearest rank, clamped to the
    /// exact observed `[min, max]` range (so `quantile(1.0)` is the true
    /// max and estimates never exceed it).
    pub fn quantile(&self, p: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let rank = ((p * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let ub = BUCKET_BOUNDS_US.get(i).copied().unwrap_or(self.max_us);
                return Some(ub.clamp(self.min_us, self.max_us));
            }
        }
        Some(self.max_us)
    }

    /// Iterate `(upper_bound_us, count)` over the non-empty buckets (the
    /// overflow bucket reports the observed max as its bound).
    pub fn nonzero_buckets(&self) -> impl Iterator<Item = (f64, u64)> + '_ {
        self.counts.iter().enumerate().filter(|(_, &c)| c > 0).map(|(i, &c)| {
            (BUCKET_BOUNDS_US.get(i).copied().unwrap_or(self.max_us), c)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_pins_textbook_percentiles() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(nearest_rank(&v, 0.50), 50.0);
        assert_eq!(nearest_rank(&v, 0.95), 95.0);
        assert_eq!(nearest_rank(&v, 0.99), 99.0);
        assert_eq!(nearest_rank(&v, 1.0), 100.0);
        let small: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(nearest_rank(&small, 0.50), 5.0);
        assert_eq!(nearest_rank(&small, 0.95), 10.0);
        assert_eq!(nearest_rank(&[7.0], 0.5), 7.0);
    }

    #[test]
    fn histogram_counts_and_quantiles() {
        let mut h = LatencyHistogram::new();
        assert!(h.quantile(0.5).is_none());
        for us in [3.0, 4.0, 4.5, 90.0, 450.0, 9e6] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 6);
        // 3 of 6 samples land in the <=5us bucket: p50 reports its bound.
        assert_eq!(h.quantile(0.5), Some(5.0));
        // Estimates never leave the observed range.
        assert_eq!(h.quantile(1.0), Some(9e6));
        let lo = h.quantile(0.01).unwrap();
        assert!(lo >= 3.0, "{lo}");
        assert_eq!(h.max_us(), Some(9e6));
        let mean = h.mean_us().unwrap();
        assert!((mean - (3.0 + 4.0 + 4.5 + 90.0 + 450.0 + 9e6) / 6.0).abs() < 1e-9);
    }

    #[test]
    fn merge_is_count_addition() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for i in 1..=50 {
            a.record_us(i as f64);
        }
        for i in 51..=100 {
            b.record_us(i as f64 * 10.0);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.count(), 100);
        assert_eq!(merged.max_us(), Some(1000.0));
        // The merged median sits between the two halves.
        let p50 = merged.quantile(0.5).unwrap();
        assert!(p50 >= 50.0 && p50 <= 510.0, "{p50}");
        // Merge equals recording everything into one histogram.
        let mut direct = LatencyHistogram::new();
        for i in 1..=50 {
            direct.record_us(i as f64);
        }
        for i in 51..=100 {
            direct.record_us(i as f64 * 10.0);
        }
        assert_eq!(direct.quantile(0.95), merged.quantile(0.95));
        assert_eq!(direct.count(), merged.count());
    }

    #[test]
    fn overflow_bucket_catches_huge_samples() {
        let mut h = LatencyHistogram::new();
        h.record_us(5e7);
        assert_eq!(h.count(), 1);
        assert_eq!(h.quantile(0.5), Some(5e7));
    }
}
